"""End-to-end driver (the paper's flagship task): PageRank on the
twitter-scale stand-in, run the way a deployment would — from an on-disk
``.dsss`` container through the disk residency tier.

The graph is preprocessed + sharded once and serialized to a ``.dsss``
store (cached next to this script; delete it to rebuild); every later run
just ``GraphSession.open()``s the file — the sub-shard blocks and packed
tiles are mmap views, streamed disk→device under the three-level
``memory_budget`` / ``host_memory_budget`` hierarchy with adaptive
strategy selection and MTEPS reporting.

    PYTHONPATH=src python examples/pagerank_e2e.py [--iters 10]
"""
import argparse
import os
import time

from repro.core import ExecutionPlan, GraphSession, PageRank, build_dsss
from repro.graph.generators import paper_dataset
from repro.graph.preprocess import degree_and_densify
from repro.storage import write_dsss


def ensure_store(path: str, P: int) -> None:
    if os.path.exists(path):
        return
    t0 = time.time()
    src, dst = paper_dataset("twitter")
    el = degree_and_densify(src, dst, drop_self_loops=True)
    g = build_dsss(el, P)
    write_dsss(g, path)
    print(
        f"built {path}: n={g.n} m={g.m} P={g.P} "
        f"({os.path.getsize(path)/1e6:.1f}MB, {time.time()-t0:.1f}s)"
    )
    # For graphs that don't fit in RAM, the same container comes out of
    # the bounded-memory pipeline instead:
    #   python -m repro.storage build edges.txt twitter.dsss --P 12


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--P", type=int, default=12)
    ap.add_argument("--budget-frac", type=float, default=None,
                    help="device memory budget as a fraction of full working set")
    ap.add_argument("--store", default=None,
                    help=".dsss path (default: cached next to this script)")
    args = ap.parse_args()

    path = args.store or os.path.join(
        os.path.dirname(os.path.abspath(__file__)), f"twitter_P{args.P}.dsss"
    )
    ensure_store(path, args.P)

    budget = None
    if args.budget_frac is not None:
        # Size the budget from the store metadata alone — no need to
        # assemble the graph twice.
        from repro.storage import open_dsss

        meta = open_dsss(path).meta
        n_pad = meta["P"] * meta["interval_size"]
        budget = int((2 * n_pad * 8 + meta["m"] * 8) * args.budget_frac)

    t0 = time.time()
    session = GraphSession.open(
        path,
        memory_budget=budget,
        # mid tier: 4x the device budget (None = unlimited RAM cache)
        host_memory_budget=None if budget is None else budget * 4,
        verify=False,
    )
    g = session.graph
    print(f"opened {path}: n={g.n} m={g.m} P={g.P} ({time.time()-t0:.2f}s, mmap)")

    plan = ExecutionPlan(PageRank(), strategy="auto",
                         max_iters=args.iters, tol=0.0)
    compiled = session.compile(plan)
    print(
        f"strategy: {compiled.choice.strategy} (Q={compiled.choice.Q}) "
        f"residency={compiled.residency} execution={compiled.execution}"
    )
    res = session.run(plan)
    m = res.meters
    print(
        f"{res.iterations} iterations in {m.wall_seconds:.2f}s "
        f"({m.wall_seconds/res.iterations:.3f}s/iter, {m.mteps():.1f} MTEPS)"
    )
    print(
        f"slow-tier: read {m.bytes_read/1e6:.1f}MB write {m.bytes_written/1e6:.1f}MB"
        f" | disk tier: {m.bytes_disk_read/1e6:.1f}MB mmap-streamed"
    )
    print("paper reference: 2.05s/iter on real Twitter (1.47B edges), 1 PC")


if __name__ == "__main__":
    main()
