"""End-to-end driver (the paper's flagship task): PageRank on the
twitter-scale stand-in with adaptive strategy selection and MTEPS.

    PYTHONPATH=src python examples/pagerank_e2e.py [--iters 10]
"""
import argparse
import time

from repro.core import NXGraphEngine, PageRank, build_dsss
from repro.graph.generators import paper_dataset
from repro.graph.preprocess import degree_and_densify


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--P", type=int, default=12)
    ap.add_argument("--budget-frac", type=float, default=None,
                    help="memory budget as a fraction of full working set")
    args = ap.parse_args()

    t0 = time.time()
    src, dst = paper_dataset("twitter")
    el = degree_and_densify(src, dst, drop_self_loops=True)
    g = build_dsss(el, args.P)
    print(f"preprocess: n={g.n} m={g.m} P={g.P} ({time.time()-t0:.1f}s)")

    budget = None
    if args.budget_frac is not None:
        budget = int((2 * g.n_pad * 8 + g.m * 8) * args.budget_frac)
    eng = NXGraphEngine(g, PageRank(), strategy="auto", memory_budget=budget)
    print(f"strategy: {eng.choice.strategy} (Q={eng.choice.Q})")
    res = eng.run(max_iters=args.iters, tol=0.0)
    m = res.meters
    print(
        f"{res.iterations} iterations in {m.wall_seconds:.2f}s "
        f"({m.wall_seconds/res.iterations:.3f}s/iter, {m.mteps():.1f} MTEPS)"
    )
    print(f"slow-tier: read {m.bytes_read/1e6:.1f}MB write {m.bytes_written/1e6:.1f}MB")
    print("paper reference: 2.05s/iter on real Twitter (1.47B edges), 1 PC")


if __name__ == "__main__":
    main()
