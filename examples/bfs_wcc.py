"""Targeted queries with interval-activity skipping: BFS / WCC / SCC.

    PYTHONPATH=src python examples/bfs_wcc.py
"""
from repro.core import bfs, scc, wcc
from repro.graph.generators import paper_dataset
from repro.graph.preprocess import degree_and_densify


def main():
    src, dst = paper_dataset("live-journal")
    el = degree_and_densify(src, dst, drop_self_loops=True)
    print(f"graph: n={el.n} m={el.m}")

    res = bfs(el, root=0, P=8)
    m = res.meters
    print(
        f"BFS : depth={res.output} iters={res.iterations} "
        f"blocks processed={m.blocks_processed} skipped={m.blocks_skipped} "
        f"(activity tracking, paper §II-B)"
    )
    res = wcc(el, P=8)
    import numpy as np

    n_comp = len(np.unique(res.attrs))
    print(f"WCC : {n_comp} components, iters={res.iterations}")
    labels = scc(el, P=8)
    print(f"SCC : {len(set(labels.tolist()))} components")


if __name__ == "__main__":
    main()
