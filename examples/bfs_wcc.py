"""Targeted queries with interval-activity skipping: BFS / WCC / SCC,
plus a batched 16-source BFS sharing one edge-stream pass.

    PYTHONPATH=src python examples/bfs_wcc.py
"""
import numpy as np

from repro.core import bfs, multi_bfs, scc, wcc
from repro.graph.generators import paper_dataset
from repro.graph.preprocess import degree_and_densify


def main():
    src, dst = paper_dataset("live-journal")
    el = degree_and_densify(src, dst, drop_self_loops=True)
    print(f"graph: n={el.n} m={el.m}")

    res = bfs(el, root=0, P=8)
    m = res.meters
    print(
        f"BFS : depth={res.output} iters={res.iterations} "
        f"blocks processed={m.blocks_processed} skipped={m.blocks_skipped} "
        f"(activity tracking, paper §II-B)"
    )

    # Multi-source BFS: 16 roots, one batched pass per sweep. The driver
    # re-uses the session (and staged blocks) from the single-source run.
    roots = np.linspace(0, el.n - 1, 16).astype(int).tolist()
    batch = multi_bfs(el, roots, P=8)
    print(
        f"BFS×{len(roots)}: fused={batch.fused} sweeps={batch.iterations} "
        f"mean depth={np.mean([r.output for r in batch]):.1f} "
        f"(one edge stream for all sources)"
    )

    res = wcc(el, P=8)
    n_comp = len(np.unique(res.attrs))
    print(f"WCC : {n_comp} components, iters={res.iterations}")
    labels = scc(el, P=8)
    print(f"SCC : {len(set(labels.tolist()))} components")


if __name__ == "__main__":
    main()
