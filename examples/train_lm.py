"""Train a small LM for a few hundred steps with the fault-tolerant loop
(async checkpointing, auto-resume, straggler watchdog).

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse

from repro.configs import get_config
from repro.train.loop import TrainLoopConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--ckpt", default="/tmp/repro_example_train")
    args = ap.parse_args()
    cfg = get_config(args.arch, smoke=True)
    stats = train(
        cfg,
        TrainLoopConfig(
            total_steps=args.steps,
            checkpoint_every=50,
            checkpoint_dir=args.ckpt,
            seq_len=64,
            global_batch=8,
            learning_rate=3e-3,
            log_every=20,
        ),
    )
    print(
        f"loss {stats['first_loss']:.3f} -> {stats['last_loss']:.3f} "
        f"over {len(stats['losses'])} steps"
    )


if __name__ == "__main__":
    main()
