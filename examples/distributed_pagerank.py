"""Multi-device NXgraph: the DSSS grid on a (data × model) mesh.

Run with forced host devices (this is how the multi-pod engine is
exercised without TPUs):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/distributed_pagerank.py
"""
import os

if "device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import numpy as np

from repro.core import NXGraphEngine, PageRank, build_dsss
from repro.core.distributed import distributed_pagerank
from repro.graph.generators import rmat
from repro.graph.preprocess import degree_and_densify


def main():
    src, dst = rmat(12, edge_factor=8, seed=3)
    el = degree_and_densify(src, dst, drop_self_loops=True)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    print(f"mesh: {dict(mesh.shape)} — sub-shard grid 4x2")
    ranks, iters = distributed_pagerank(el, mesh, iters=15)
    ref = NXGraphEngine(build_dsss(el, 4), PageRank(), strategy="fused").run(
        15, tol=0.0
    )
    err = float(np.abs(ranks - ref.attrs).max())
    print(f"n={el.n} m={el.m} iters={iters} max|Δ| vs single-device = {err:.2e}")
    assert err < 1e-6


if __name__ == "__main__":
    main()
