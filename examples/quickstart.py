"""Quickstart: stage a graph once, run many programs, batch many queries.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import ExecutionPlan, GraphSession, BFS, PageRank, build_dsss
from repro.graph.generators import rmat
from repro.graph.preprocess import degree_and_densify


def main():
    # 1. raw edges -> degreeing (dense ids) -> DSSS sharding
    src, dst = rmat(12, edge_factor=8, seed=0)
    el = degree_and_densify(src, dst, drop_self_loops=True)
    graph = build_dsss(el, P=8)
    print(f"graph: n={graph.n} m={graph.m} P={graph.P} "
          f"hub-factor d={graph.mean_hub_in_degree():.1f}")

    # 2. stage the graph ONCE: the session owns the device-resident
    #    sub-shard blocks; every plan below re-uses them.
    session = GraphSession(graph, memory_budget=graph.n_pad * 8)  # force MPU to mix

    # 3. run PageRank under each strategy — identical results, different
    #    slow-tier traffic (paper Table II). Same staged blocks every time.
    for strategy in ["spu", "dpu", "mpu", "fused"]:
        plan = ExecutionPlan(PageRank(), strategy=strategy, max_iters=20, tol=1e-9)
        res = session.run(plan)
        per = res.meters.per_iteration()
        top = np.argsort(res.output)[-3:][::-1]
        print(
            f"{strategy:6s} iters={res.iterations:2d} "
            f"read/iter={per.bytes_read:9.0f}B write/iter={per.bytes_written:8.0f}B "
            f"top vertices={top.tolist()}"
        )

    # 4. batch 32 BFS sources into ONE streamed pass over the edge blocks:
    #    the edge traffic is paid once per sweep, not 32 times.
    roots = np.linspace(0, graph.n - 1, 32).astype(int).tolist()
    batch = session.run_batch(
        [
            ExecutionPlan(BFS(), max_iters=graph.n + 1, program_kwargs={"root": r})
            for r in roots
        ]
    )
    depths = [res.output for res in batch]
    print(
        f"bfs×{len(roots)}: fused={batch.fused} sweeps={batch.iterations} "
        f"edge-bytes={batch.meters.bytes_read_edges:.0f} "
        f"(single pass, not {len(roots)}×) max-depths={sorted(set(depths))}"
    )


if __name__ == "__main__":
    main()
