"""Quickstart: build a graph, run PageRank under every update strategy.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import NXGraphEngine, PageRank, build_dsss
from repro.graph.generators import rmat
from repro.graph.preprocess import degree_and_densify


def main():
    # 1. raw edges -> degreeing (dense ids) -> DSSS sharding
    src, dst = rmat(12, edge_factor=8, seed=0)
    el = degree_and_densify(src, dst, drop_self_loops=True)
    graph = build_dsss(el, P=8)
    print(f"graph: n={graph.n} m={graph.m} P={graph.P} "
          f"hub-factor d={graph.mean_hub_in_degree():.1f}")

    # 2. run PageRank under each strategy — identical results, different
    #    slow-tier traffic (paper Table II)
    for strategy in ["spu", "dpu", "mpu", "fused"]:
        eng = NXGraphEngine(
            graph,
            PageRank(),
            strategy=strategy,
            memory_budget=graph.n_pad * 8,  # force MPU to mix
        )
        res = eng.run(max_iters=20, tol=1e-9)
        per = res.meters.per_iteration()
        top = np.argsort(res.output)[-3:][::-1]
        print(
            f"{strategy:6s} iters={res.iterations:2d} "
            f"read/iter={per.bytes_read:9.0f}B write/iter={per.bytes_written:8.0f}B "
            f"top vertices={top.tolist()}"
        )


if __name__ == "__main__":
    main()
