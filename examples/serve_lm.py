"""Serve a small LM with batched requests (length-bucketed batching).

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import Model
from repro.serving.llm_demo import Request, ServeEngine


def main():
    cfg = get_config("gemma-2b", smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_batch=4)
    rng = np.random.default_rng(0)
    t0 = time.time()
    n_req = 8
    for i in range(n_req):
        ln = 12 if i % 2 else 20
        eng.submit(
            Request(
                request_id=i,
                prompt=rng.integers(0, cfg.vocab_size, ln).tolist(),
                max_new_tokens=12,
                temperature=0.8 if i >= 6 else 0.0,
                top_k=20,
            )
        )
    results = eng.run()
    dt = time.time() - t0
    toks = sum(len(v) for v in results.values())
    for rid in sorted(results):
        print(f"req {rid}: {results[rid]}")
    print(f"{toks} tokens for {n_req} requests in {dt:.1f}s ({toks/dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
