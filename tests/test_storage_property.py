"""Property tests for the on-disk DSSS store (repro.storage).

Two contracts the disk tier rests on:

1. **Build equivalence** — for arbitrary small graphs (weighted or not,
   with duplicate edges and self loops, any interval count, any chunking
   of the input stream), the bounded-RAM external-memory build produces a
   container whose every engine-facing artifact — graph arrays, padded
   host blocks, the stored adaptive PackedSweep — is layout-for-layout
   (values *and* dtypes) identical to the in-memory
   ``degree_and_densify → build_dsss`` pipeline. This is what makes
   ``residency="disk"`` bit-identity a corollary rather than a separate
   proof.
2. **Integrity** — a bit flip in any segment, at any offset, fails
   verification with a :class:`ChecksumError` (never garbage results),
   and truncation fails at open.
"""
import os
import shutil
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import build_dsss
from repro.graph.generators import erdos_renyi
from repro.graph.preprocess import degree_and_densify
from repro.storage import ChecksumError, build_dsss_file, open_dsss, verify_dsss, write_dsss

from test_storage import assert_store_matches_graph


def _raw(seed, n, m, weighted):
    rng = np.random.default_rng(seed)
    src, dst = erdos_renyi(n, m, seed=seed)
    # duplicates + self loops: the dedup/drop semantics must round-trip
    dup = rng.integers(0, len(src), size=max(len(src) // 10, 1))
    src = np.concatenate([src, src[dup], [0, 1]])
    dst = np.concatenate([dst, dst[dup], [0, 1]])
    w = rng.uniform(0.1, 4.0, size=len(src)).astype(np.float32) if weighted else None
    return src, dst, w


class _Tmp:
    """Self-cleaning temp dir (hypothesis re-runs the body many times;
    pytest fixtures cannot be mixed into @given bodies)."""

    def __enter__(self):
        self.d = tempfile.mkdtemp(prefix="dsss-prop-")
        return self.d

    def __exit__(self, *exc):
        shutil.rmtree(self.d, ignore_errors=True)


class TestBuildEquivalence:
    @settings(max_examples=10, deadline=None)
    @given(
        seed=st.integers(0, 1000),
        n=st.integers(20, 200),
        P=st.integers(1, 8),
        weighted=st.booleans(),
        drop_loops=st.booleans(),
        step=st.integers(13, 400),
        tight_budget=st.booleans(),
    )
    def test_external_build_matches_in_memory(
        self, seed, n, P, weighted, drop_loops, step, tight_budget
    ):
        src, dst, w = _raw(seed, n, 6 * n, weighted)
        el = degree_and_densify(
            src, dst, weights=w, drop_self_loops=drop_loops
        )
        g = build_dsss(el, P)

        def chunks():
            for lo in range(0, len(src), step):
                if w is None:
                    yield src[lo : lo + step], dst[lo : lo + step]
                else:
                    yield (
                        src[lo : lo + step],
                        dst[lo : lo + step],
                        w[lo : lo + step],
                    )

        # A tight budget forces the streamed k-way merge + tiny copy
        # windows; a loose one takes the load-and-sort path. Both must be
        # byte-equivalent.
        budget = 4096 if tight_budget else 1 << 20
        with _Tmp() as d:
            out = os.path.join(d, "g.dsss")
            build_dsss_file(
                chunks, out, P, chunk_budget=budget,
                drop_self_loops=drop_loops,
            )
            assert_store_matches_graph(open_dsss(out, verify=True), g)

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 1000), P=st.integers(1, 6))
    def test_writer_roundtrip_any_graph(self, seed, P):
        src, dst, w = _raw(seed, 80, 500, weighted=True)
        el = degree_and_densify(src, dst, weights=w, drop_self_loops=True)
        g = build_dsss(el, P)
        with _Tmp() as d:
            out = os.path.join(d, "g.dsss")
            assert_store_matches_graph(write_dsss(g, out), g)


class TestIntegrity:
    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_any_bit_flip_is_detected(self, seed):
        rng = np.random.default_rng(seed)
        src, dst, w = _raw(seed, 60, 300, weighted=True)
        el = degree_and_densify(src, dst, weights=w, drop_self_loops=True)
        g = build_dsss(el, 4)
        with _Tmp() as d:
            path = os.path.join(d, "g.dsss")
            store = write_dsss(g, path)
            segs = [s for s in store.segments.values() if s.nbytes > 0]
            seg = segs[int(rng.integers(0, len(segs)))]
            off = seg.offset + int(rng.integers(0, seg.nbytes))
            bit = 1 << int(rng.integers(0, 8))
            with open(path, "r+b") as f:
                f.seek(off)
                byte = f.read(1)[0]
                f.seek(off)
                f.write(bytes([byte ^ bit]))
            with pytest.raises(ChecksumError):
                verify_dsss(path)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 10_000), frac=st.floats(0.01, 0.99))
    def test_truncation_is_detected(self, seed, frac):
        src, dst, _ = _raw(seed, 60, 300, weighted=False)
        el = degree_and_densify(src, dst, drop_self_loops=True)
        g = build_dsss(el, 4)
        with _Tmp() as d:
            path = os.path.join(d, "g.dsss")
            write_dsss(g, path)
            size = os.path.getsize(path)
            with open(path, "r+b") as f:
                f.truncate(max(int(size * frac), 1))
            # FormatError (bad/missing footer) or its ChecksumError
            # subclass (truncated segment) — never a silent success
            from repro.storage import FormatError

            with pytest.raises(FormatError):
                verify_dsss(path)
