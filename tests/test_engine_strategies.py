"""SPU / DPU / MPU / fused equivalence + I/O-model property tests.

The paper's central systems claim is that all three update strategies
compute the same fixpoint while trading memory for slow-tier traffic
exactly as Table II predicts. Both halves are tested here.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    IOParams,
    NXGraphEngine,
    PageRank,
    build_dsss,
    dpu_io,
    mpu_io,
    mpu_q,
    select_strategy,
    spu_io,
    turbograph_like_io,
)
from repro.core.baselines import TurboGraphLikeEngine
from repro.core.vertex_programs import BFS, WCC
from repro.graph.generators import erdos_renyi, rmat
from repro.graph.preprocess import degree_and_densify

ITERS = 8


def _graph(n=120, m=600, seed=0, P=4):
    src, dst = erdos_renyi(n, m, seed=seed)
    el = degree_and_densify(src, dst, drop_self_loops=True)
    return build_dsss(el, P)


class TestStrategyEquivalence:
    @pytest.mark.parametrize("strategy", ["spu", "dpu", "mpu", "fused"])
    def test_pagerank_equal_across_strategies(self, strategy):
        g = _graph(seed=1)
        ref = NXGraphEngine(g, PageRank(), strategy="spu").run(ITERS, tol=0.0)
        eng = NXGraphEngine(
            g, PageRank(), strategy=strategy, memory_budget=4_000
        )
        got = eng.run(ITERS, tol=0.0)
        np.testing.assert_allclose(got.attrs, ref.attrs, rtol=1e-6, atol=1e-9)

    @pytest.mark.parametrize("strategy", ["spu", "dpu", "mpu", "fused"])
    @pytest.mark.parametrize("program_cls", [BFS, WCC])
    def test_monotone_programs_equal(self, strategy, program_cls):
        g = _graph(seed=2)
        kw = {"root": 0} if program_cls is BFS else {}
        ref = NXGraphEngine(g, program_cls(), strategy="spu").run(200, **kw)
        eng = NXGraphEngine(
            g, program_cls(), strategy=strategy, memory_budget=2_000
        )
        got = eng.run(200, **kw)
        np.testing.assert_array_equal(got.attrs, ref.attrs)

    def test_turbograph_like_same_fixpoint(self):
        g = _graph(seed=3)
        ref = NXGraphEngine(g, PageRank(), strategy="spu").run(ITERS, tol=0.0)
        got = TurboGraphLikeEngine(g, PageRank()).run(ITERS, tol=0.0)
        np.testing.assert_allclose(got.attrs, ref.attrs, rtol=1e-6, atol=1e-9)

    @settings(max_examples=10, deadline=None)
    @given(
        seed=st.integers(0, 50),
        P=st.integers(1, 6),
        budget=st.integers(500, 50_000),
    )
    def test_property_strategy_equivalence(self, seed, P, budget):
        """Any strategy × any budget × any partitioning → same PageRank."""
        g = _graph(n=60, m=240, seed=seed, P=P)
        ref = NXGraphEngine(g, PageRank(), strategy="fused").run(5, tol=0.0)
        for strategy in ["spu", "dpu", "mpu"]:
            got = NXGraphEngine(
                g, PageRank(), strategy=strategy, memory_budget=budget
            ).run(5, tol=0.0)
            np.testing.assert_allclose(
                got.attrs, ref.attrs, rtol=1e-5, atol=1e-8
            )


class TestByteMeters:
    """Engine meters must reproduce the paper's Table II closed forms."""

    def test_spu_edges_streamed_exactly(self):
        g = _graph(seed=4)
        eng = NXGraphEngine(g, PageRank(), strategy="spu", memory_budget=None)
        res = eng.run(ITERS, tol=0.0)
        # Unlimited memory: everything resident, zero slow-tier traffic
        # (B_read = 0 when B_M > 2n·Ba + m·Be).
        assert res.meters.bytes_read == 0 and res.meters.bytes_written == 0

    def test_spu_read_formula_with_budget(self):
        g = _graph(seed=4)
        prog = PageRank()
        Ba = prog.attr_bytes
        budget = 2 * g.n_pad * Ba + (g.m * 8) // 3  # 1/3 of edges resident
        eng = NXGraphEngine(g, prog, strategy="spu", memory_budget=budget)
        res = eng.run(ITERS, tol=0.0)
        per = res.meters.per_iteration()
        expect_read, expect_write = spu_io(eng.params, budget)
        # Residency is block-granular; allow one max-block slack.
        max_block = max(b["e"] for b in eng.blocks.values()) * eng.Be
        assert abs(per.bytes_read - expect_read) <= max_block
        assert per.bytes_written == expect_write == 0

    def test_dpu_formula_exact_with_measured_d(self):
        g = _graph(seed=5)
        prog = PageRank()
        eng = NXGraphEngine(g, prog, strategy="dpu")
        res = eng.run(ITERS, tol=0.0)
        per = res.meters.per_iteration()
        # Use the graph's actual hub factor d — then the formula is exact
        # for PageRank (non-monotone: no extra interval reads).
        p = eng.params
        expect_read, expect_write = dpu_io(p)
        # n·Ba in the formula vs n_pad·Ba in the engine (padded intervals).
        pad_slack = (g.n_pad - g.n) * p.Ba
        assert abs(per.bytes_read - expect_read) <= pad_slack + 1e-6
        assert abs(per.bytes_written - expect_write) <= pad_slack + 1e-6

    def test_mpu_between_spu_and_dpu(self):
        g = _graph(n=200, m=1000, seed=6, P=8)
        prog = PageRank()
        dpu = NXGraphEngine(g, prog, strategy="dpu").run(ITERS, tol=0.0)
        budget = 2 * g.interval_size * prog.attr_bytes * 5  # Q = 5 of 8
        mpu = NXGraphEngine(
            g, prog, strategy="mpu", memory_budget=budget
        ).run(ITERS, tol=0.0)
        spu = NXGraphEngine(g, prog, strategy="spu").run(ITERS, tol=0.0)
        assert (
            spu.meters.bytes_total
            <= mpu.meters.bytes_total
            <= dpu.meters.bytes_total
        )

    def test_mpu_endpoints(self):
        """Q=0 ⇒ MPU meters == DPU meters; Q=P ⇒ MPU == SPU (paper §III-B3)."""
        g = _graph(seed=7)
        prog = PageRank()
        d = NXGraphEngine(g, prog, strategy="dpu").run(ITERS, tol=0.0)
        m0 = NXGraphEngine(g, prog, strategy="mpu", memory_budget=0).run(
            ITERS, tol=0.0
        )
        assert m0.meters.bytes_total == d.meters.bytes_total
        big = 10**9
        s = NXGraphEngine(g, prog, strategy="spu", memory_budget=big).run(
            ITERS, tol=0.0
        )
        mP = NXGraphEngine(g, prog, strategy="mpu", memory_budget=big).run(
            ITERS, tol=0.0
        )
        # Full-memory MPU has zero hub/interval traffic; SPU may additionally
        # pin sub-shards, so MPU-edges vs SPU: both stream-or-resident.
        assert mP.meters.bytes_read_hubs == 0
        assert mP.meters.bytes_written_intervals == 0

    def test_turbograph_like_np_scaling(self):
        """The baseline's interval traffic is n·P·Ba + n·Ba (paper §III-C)."""
        g = _graph(n=160, m=800, seed=8, P=8)
        prog = PageRank()
        eng = TurboGraphLikeEngine(g, prog)
        res = eng.run(ITERS, tol=0.0)
        per = res.meters.per_iteration()
        Ba = prog.attr_bytes
        # Destination loads: P intervals; source loads: one per non-empty
        # (i, j) pair — n·P·Ba when the density matrix is full.
        nonempty = len(eng.blocks)
        expect_iv_read = (g.P + nonempty) * g.interval_size * Ba
        assert per.bytes_read_intervals == pytest.approx(expect_iv_read)
        assert per.bytes_written_intervals == pytest.approx(
            g.P * g.interval_size * Ba
        )

    def test_mpu_dominates_turbograph_like_in_paper_regime(self):
        """Measured version of paper Fig. 6: MPU total I/O ≤ TurboGraph-like.

        The paper's claim is made for Yahoo-web parameters where the hub
        factor d ≈ 10–20. It does NOT hold for sparse graphs with d ≈ 1
        (hub traffic m·(Ba+Bv)/d then dominates the baseline's n·P·Ba) —
        a boundary of the claim we document in EXPERIMENTS.md. Here we
        check the measured claim in the paper's regime: a dense graph
        whose sub-shard destinations have high in-degree.
        """
        src, dst = rmat(12, edge_factor=16, seed=2)
        el = degree_and_densify(src, dst, drop_self_loops=True)
        g = build_dsss(el, 12)  # paper §IV-B2: P = 12..48 are good practice
        prog = PageRank()
        for frac in [0.2, 0.5, 0.8]:
            budget = int(2 * g.n_pad * prog.attr_bytes * frac)
            mpu = NXGraphEngine(
                g, prog, strategy="mpu", memory_budget=budget
            ).run(ITERS, tol=0.0)
            tg = TurboGraphLikeEngine(g, prog, memory_budget=budget).run(
                ITERS, tol=0.0
            )
            assert mpu.meters.bytes_total <= tg.meters.bytes_total

    def test_small_d_flips_fig6_claim(self):
        """Beyond-paper finding: with hub factor d ≈ 1 (very sparse blocks),
        the TurboGraph-like strategy can beat MPU — Fig. 6's 'always
        outperforms' is parameter-dependent."""
        g = _graph(n=240, m=1400, seed=9, P=8)
        assert g.mean_hub_in_degree() < 2
        prog = PageRank()
        budget = int(2 * g.n_pad * prog.attr_bytes * 0.2)
        mpu = NXGraphEngine(g, prog, strategy="mpu", memory_budget=budget).run(
            ITERS, tol=0.0
        )
        tg = TurboGraphLikeEngine(g, prog, memory_budget=budget).run(
            ITERS, tol=0.0
        )
        assert tg.meters.bytes_total < mpu.meters.bytes_total


class TestIOModelClosedForms:
    @settings(max_examples=50, deadline=None)
    @given(
        n=st.integers(100, 10**7),
        deg=st.integers(1, 64),
        P=st.integers(1, 64),
        frac=st.floats(0.0, 1.5),
    )
    def test_model_monotonicity_and_endpoints(self, n, deg, P, frac):
        m = n * deg
        p = IOParams(n=n, m=m, P=P)
        B_M = int(2 * n * p.Ba * frac)
        # MPU interpolates: Q=0 -> DPU, budget >= 2nBa -> SPU-like traffic.
        r_mpu, w_mpu = mpu_io(p, B_M)
        r_dpu, w_dpu = dpu_io(p)
        assert r_mpu <= r_dpu + 1e-6 and w_mpu <= w_dpu + 1e-6
        if mpu_q(p, B_M) == 0:
            assert r_mpu == pytest.approx(r_dpu) and w_mpu == pytest.approx(w_dpu)
        # paper Fig. 6 claim: MPU total <= TurboGraph-like total, for all
        # budgets — in the paper's continuous-Q (large-P) setting. Our
        # analysis (EXPERIMENTS.md §Fig6) shows the claim is a theorem
        # exactly when hub traffic H = m(Ba+Bv)/d ≤ min_x (1/x−1+2x) /
        # (2(1−x)²) · n·Ba ≈ 2.98·n·Ba — satisfied by Yahoo-web (H/A≈0.92)
        # but not by arbitrarily dense graphs.
        H = p.m * (p.Ba + p.Bv) / p.d
        A = p.n * p.Ba
        if B_M > 0 and H <= 2.9 * A:
            r_tg, w_tg = turbograph_like_io(p, B_M)
            r_c, w_c = mpu_io(p, B_M, continuous=True)
            assert r_c + w_c <= r_tg + w_tg + 1e-6

    @settings(max_examples=30, deadline=None)
    @given(n=st.integers(100, 10**6), deg=st.integers(1, 32), frac=st.floats(0.0, 3.0))
    def test_selection_picks_min_io(self, n, deg, frac):
        p = IOParams(n=n, m=n * deg, P=16)
        B_M = int(2 * n * p.Ba * frac)
        choice = select_strategy(p, B_M)
        if B_M >= 2 * p.P * -(-n // p.P) * p.Ba:
            assert choice.strategy == "spu"
        else:
            assert choice.strategy in ("mpu", "dpu")
            assert 0 <= choice.Q < p.P
