"""flash_attention Pallas kernel vs jnp oracle: shape/dtype/feature sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import attention_ref

RNG = np.random.default_rng(1)


def _qkv(b, hq, hkv, sq, sk, d, dtype=jnp.float32, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((b, hq, sq, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, hkv, sk, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, hkv, sk, d)), dtype)
    return q, k, v


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize(
    "b,hq,hkv,sq,sk,d",
    [
        (2, 4, 4, 64, 64, 32),  # MHA
        (1, 8, 2, 128, 128, 64),  # GQA 4:1
        (1, 4, 1, 96, 160, 32),  # MQA, ragged kv, non-multiple block
        (2, 16, 8, 32, 32, 128),  # gemma2-like ratios
        (1, 2, 2, 257, 130, 64),  # non-aligned lengths (padding paths)
    ],
)
def test_matches_oracle_causal(b, hq, hkv, sq, sk, d, dtype, tol):
    q, k, v = _qkv(b, hq, hkv, sq, sk, d, dtype, seed=sq + sk)
    got = flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    want = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


@pytest.mark.parametrize("window", [1, 8, 64, 1024])
def test_sliding_window(window):
    q, k, v = _qkv(1, 4, 2, 128, 128, 32, seed=window)
    got = flash_attention(q, k, v, causal=True, window=window, block_q=32, block_k=32)
    want = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("softcap", [1.0, 30.0, 50.0])
def test_logit_softcap(softcap):
    """gemma2's attn_logit_softcapping."""
    q, k, v = _qkv(1, 4, 4, 64, 64, 32, seed=int(softcap))
    got = flash_attention(q, k, v, causal=True, softcap=softcap, block_q=32, block_k=32)
    want = attention_ref(q, k, v, causal=True, softcap=softcap)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_non_causal():
    q, k, v = _qkv(2, 4, 4, 64, 96, 32, seed=9)
    got = flash_attention(q, k, v, causal=False, block_q=32, block_k=32)
    want = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_decode_single_query():
    """Decode shape: one query token against a long KV cache."""
    q, k, v = _qkv(4, 8, 2, 1, 512, 64, seed=11)
    got = flash_attention(q, k, v, causal=False, block_q=1, block_k=128)
    want = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_custom_scale():
    q, k, v = _qkv(1, 2, 2, 64, 64, 32, seed=13)
    got = flash_attention(q, k, v, causal=True, scale=0.5, block_q=32, block_k=32)
    want = attention_ref(q, k, v, causal=True, scale=0.5)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 2),
    hkv=st.sampled_from([1, 2, 4]),
    group=st.sampled_from([1, 2, 4]),
    sq=st.integers(1, 150),
    sk=st.integers(1, 150),
    d=st.sampled_from([16, 32]),
    causal=st.booleans(),
    seed=st.integers(0, 100),
)
def test_property_matches_oracle(b, hkv, group, sq, sk, d, causal, seed):
    q, k, v = _qkv(b, hkv * group, hkv, sq, sk, d, seed=seed)
    got = flash_attention(q, k, v, causal=causal, block_q=32, block_k=32)
    want = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_rows_fully_masked_are_zero():
    """Causal rows before the first unmasked key (window past end) -> 0."""
    q, k, v = _qkv(1, 1, 1, 32, 32, 16, seed=3)
    got = flash_attention(q, k, v, causal=True, window=1, block_q=16, block_k=16)
    # window=1: each row attends only to itself -> output = v row
    np.testing.assert_allclose(
        np.asarray(got[0, 0]), np.asarray(v[0, 0]), rtol=2e-5, atol=2e-5
    )
