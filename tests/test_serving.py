"""The graph-query serving subsystem (repro.serving).

Covers the serving contract end to end on tiny graphs (fast lane):

* **Bit-identity**: every result delivered through :class:`GraphServer`
  equals the corresponding solo ``session.run(plan)`` — across programs ×
  strategies × residency ∈ {device, host, disk};
* **Meter shares**: per-request shares of the fused batch's ``Meters``
  recombine field-for-field exactly (``split_meters`` unit contract +
  the served path);
* **Micro-batching**: compatible queries fuse into few ``run_batch``
  dispatches (occupancy > 1), incompatible ones don't, ``max_batch`` is
  honored;
* **Admission control**: the bounded queue rejects/backpressures, and
  concurrent mixed-graph load never drives the admitted in-flight bytes —
  or the measured per-run device peaks — past capacity (staged-block
  accounting);
* **Session pool**: lazy open, LRU eviction under an explicit staged-bytes
  capacity, ``.dsss`` page-in after eviction, in-use pinning;
* ``get_session`` keys on the full session-axis set;
* ``import repro.serving`` stays cheap (graph serving must not drag in the
  LM stack).
"""
import asyncio
import dataclasses
import subprocess
import sys

import numpy as np
import pytest

from repro.core import BFS, ExecutionPlan, GraphSession, PageRank, SSSP, build_dsss
from repro.core.algorithms import multi_bfs, multi_sssp
from repro.core.session import MODEL_METER_FIELDS, Meters, get_session
from repro.graph.generators import erdos_renyi
from repro.graph.preprocess import degree_and_densify
from repro.serving import (
    AdmissionError,
    GraphServer,
    QueryRequest,
    SessionPool,
    estimate_inflight_bytes,
    split_meters,
)
from repro.storage import write_dsss


def _graph(n=130, m=800, seed=7, P=4, weighted=True):
    src, dst = erdos_renyi(n, m, seed=seed)
    w = None
    if weighted:
        rng = np.random.default_rng(seed)
        w = rng.uniform(0.1, 2.0, size=len(src)).astype(np.float32)
    el = degree_and_densify(src, dst, weights=w, drop_self_loops=True)
    return build_dsss(el, P)


@pytest.fixture(scope="module")
def graph():
    return _graph()


@pytest.fixture(scope="module")
def graph2():
    return _graph(n=90, m=500, seed=11, weighted=False)


@pytest.fixture(scope="module")
def dsss_path(graph, tmp_path_factory):
    path = str(tmp_path_factory.mktemp("serving") / "g.dsss")
    write_dsss(graph, path)
    return path


def _graph_model_bytes(session):
    return float(session.graph.m * session.Be)


def _plans(program, roots, max_iters):
    if isinstance(program, PageRank):
        return [
            ExecutionPlan(program, max_iters=5, tol=0.0) for _ in roots
        ]
    return [
        ExecutionPlan(
            program, max_iters=max_iters, program_kwargs={"root": int(r)}
        )
        for r in roots
    ]


# ---------------------------------------------------------------------------
# Bit-identity: served ≡ solo, across programs × strategies × residency.
# ---------------------------------------------------------------------------
class TestBitIdentity:
    @pytest.mark.parametrize("residency", ["device", "host", "disk"])
    @pytest.mark.parametrize("strategy", ["spu", "dpu"])
    @pytest.mark.parametrize(
        "program", [PageRank(), BFS(), SSSP()], ids=["pagerank", "bfs", "sssp"]
    )
    def test_served_equals_solo(
        self, graph, dsss_path, residency, strategy, program
    ):
        pool = SessionPool()
        budget = int(graph.m * 12 * 0.5)  # stream roughly half the topology
        if residency == "disk":
            pool.register(
                "g", dsss_path, memory_budget=budget, host_memory_budget=budget
            )
        else:
            pool.register("g", graph, memory_budget=budget, residency=residency)
        server = GraphServer(pool, max_batch=8, max_wait_ms=1.0)
        roots = [0, 3, 17, 42]
        plans = [
            dataclasses.replace(p, strategy=strategy)
            for p in _plans(program, roots, graph.n + 1)
        ]
        served = server.serve([QueryRequest("g", p) for p in plans])
        session = pool.session("g")
        assert session.resolved_residency() == residency
        for plan, q in zip(plans, served):
            solo = session.run(plan)
            np.testing.assert_array_equal(solo.attrs, q.result.attrs)
            assert solo.iterations == q.result.iterations
            assert solo.converged == q.result.converged
        st = server.stats()
        assert st.completed == len(plans)
        assert st.fused_batches >= 1  # the point queries really fused

    def test_multi_bfs_through_server_matches_direct(self, graph):
        roots = [1, 5, 9]
        direct = multi_bfs(graph, roots, P=graph.P)
        server = GraphServer(max_batch=8, max_wait_ms=1.0)
        via = multi_bfs(graph, roots, P=graph.P, server=server)
        assert len(via) == len(direct)
        for a, b in zip(direct, via):
            np.testing.assert_array_equal(a.attrs, b.attrs)
            assert a.iterations == b.iterations
        assert via.fused

    def test_multi_sssp_through_server_matches_direct(self, graph):
        roots = [2, 8]
        direct = multi_sssp(graph, roots, P=graph.P)
        server = GraphServer(max_batch=8, max_wait_ms=1.0)
        via = multi_sssp(graph, roots, P=graph.P, server=server)
        for a, b in zip(direct, via):
            np.testing.assert_array_equal(a.attrs, b.attrs)


# ---------------------------------------------------------------------------
# Meter shares.
# ---------------------------------------------------------------------------
class TestMeterShares:
    def test_split_meters_exact_recombination(self):
        total = Meters(
            bytes_read_edges=70001.0,
            bytes_read_intervals=333.0,
            bytes_read_hubs=17.0,
            bytes_written_hubs=5.0,
            bytes_written_intervals=999.0,
            bytes_h2d=123457.0,
            bytes_disk_read=31.0,
            peak_device_graph_bytes=4096.0,
            iterations=7,
            blocks_processed=23,
            blocks_skipped=3,
            edges_processed=5471,
            wall_seconds=0.3,
        )
        for k in (1, 2, 3, 5):
            shares = split_meters(total, k)
            merged = Meters()
            for s in shares:
                merged.merge(s)
                # high-water mark is replicated, not divided
                assert s.peak_device_graph_bytes == total.peak_device_graph_bytes
            for f in dataclasses.fields(Meters):
                a, b = getattr(merged, f.name), getattr(total, f.name)
                if f.name == "wall_seconds":
                    assert a == pytest.approx(b, rel=1e-12)
                else:
                    assert a == b, f.name
            # integral fields distribute as evenly as possible
            its = [s.iterations for s in shares]
            assert max(its) - min(its) <= 1

    def test_served_shares_sum_to_fused_batch(self, graph):
        pool = SessionPool()
        pool.register("g", graph, memory_budget=int(graph.m * 12 * 0.4))
        server = GraphServer(pool, max_batch=8, max_wait_ms=1.0)
        plans = _plans(BFS(), [0, 4, 8, 12, 16], graph.n + 1)
        served = server.serve([QueryRequest("g", p) for p in plans])
        assert all(q.fused for q in served)
        assert len({q.batch_size for q in served}) == 1  # one fused batch
        batch_meters = served[0].result.meters  # shared by every member
        merged = Meters()
        for q in served:
            merged.merge(q.meters)
        for f in MODEL_METER_FIELDS + ("bytes_h2d", "bytes_disk_read"):
            assert getattr(merged, f) == getattr(batch_meters, f), f
        assert (
            merged.peak_device_graph_bytes
            == batch_meters.peak_device_graph_bytes
        )
        assert merged.wall_seconds == pytest.approx(
            batch_meters.wall_seconds, rel=1e-9
        )
        # plain (non-merge) sums agree too for the additive byte fields
        assert sum(q.meters.bytes_read_edges for q in served) == (
            batch_meters.bytes_read_edges
        )


# ---------------------------------------------------------------------------
# Micro-batching.
# ---------------------------------------------------------------------------
class TestBatcher:
    def test_compatible_queries_fuse(self, graph):
        server = GraphServer(max_batch=16, max_wait_ms=5.0)
        plans = _plans(BFS(), range(8), graph.n + 1)
        served = server.serve([QueryRequest(graph, p) for p in plans])
        st = server.stats()
        assert st.batches == 1
        assert st.fused_batches == 1
        assert st.mean_occupancy == 8.0
        assert all(q.batch_size == 8 for q in served)

    def test_max_batch_is_honored(self, graph):
        server = GraphServer(max_batch=4, max_wait_ms=1.0)
        plans = _plans(BFS(), range(10), graph.n + 1)
        served = server.serve([QueryRequest(graph, p) for p in plans])
        assert all(q.batch_size <= 4 for q in served)
        assert server.stats().batches >= 3

    def test_incompatible_queries_do_not_fuse(self, graph):
        server = GraphServer(max_batch=16, max_wait_ms=5.0)
        reqs = [
            QueryRequest(graph, p) for p in _plans(BFS(), [0, 1, 2], graph.n + 1)
        ] + [
            QueryRequest(graph, ExecutionPlan(PageRank(), max_iters=4, tol=0.0))
        ]
        served = server.serve(reqs)
        st = server.stats()
        assert st.batches == 2  # one BFS bucket, one PageRank bucket
        assert served[0].batch_size == 3 and served[-1].batch_size == 1
        # timing is populated and ordered
        for q in served:
            assert q.timing.enqueued <= q.timing.dispatched <= q.timing.completed

    def test_incompatible_aux_falls_back_sequential(self, graph):
        # Same batch_key shape is impossible for two different damping
        # values (PageRank freezes damping into the program, which is part
        # of batch_key) — use two *plans* differing only in kwargs-borne
        # aux instead: MaxLabelForward-style cases live in core tests, so
        # here simply verify a singleton batch reports fused=True and the
        # sequential path is exercised via run_batch's own contract.
        server = GraphServer(max_batch=4, max_wait_ms=0.0)
        [q] = server.serve(
            [QueryRequest(graph, ExecutionPlan(PageRank(), max_iters=3, tol=0.0))]
        )
        assert q.fused and q.batch_size == 1


# ---------------------------------------------------------------------------
# Admission control.
# ---------------------------------------------------------------------------
class TestAdmission:
    def test_bounded_queue_rejects(self, graph):
        server = GraphServer(max_batch=4, max_wait_ms=500.0, max_queue=2)
        plans = _plans(BFS(), range(3), graph.n + 1)

        async def scenario():
            async with server:
                f1 = await server.submit(QueryRequest(graph, plans[0]))
                f2 = await server.submit(QueryRequest(graph, plans[1]))
                with pytest.raises(AdmissionError):
                    await server.submit(QueryRequest(graph, plans[2]))
                return await asyncio.gather(f1, f2)

        r1, r2 = asyncio.run(scenario())
        assert r1.result.converged and r2.result.converged
        assert server.stats().rejected == 1

    def test_bounded_queue_wait_policy_backpressures(self, graph):
        server = GraphServer(
            max_batch=2, max_wait_ms=0.0, max_queue=1, queue_policy="wait"
        )
        plans = _plans(BFS(), range(4), graph.n + 1)

        async def scenario():
            async with server:
                futures = [
                    await server.submit(QueryRequest(graph, p)) for p in plans
                ]
                return await asyncio.gather(*futures)

        served = asyncio.run(scenario())
        assert len(served) == 4
        assert server.stats().rejected == 0

    def test_inflight_capacity_bounds_mixed_graph_load(self, graph, graph2):
        # Constrained memory budgets → streamed residency with small
        # device working sets; capacity admits one batch at a time.
        budget1 = int(graph.m * 12 * 0.25)
        budget2 = int(graph2.m * 8 * 0.25)
        pool = SessionPool()
        pool.register("a", graph, memory_budget=budget1, residency="host")
        pool.register("b", graph2, memory_budget=budget2, residency="host")
        k = 4
        plans_a = _plans(BFS(), range(k), graph.n + 1)
        plans_b = _plans(BFS(), range(k), graph2.n + 1)
        est_a = estimate_inflight_bytes(pool.session("a"), plans_a[0], k)
        est_b = estimate_inflight_bytes(pool.session("b"), plans_b[0], k)
        capacity = max(est_a, est_b) * 1.5  # too small for both at once
        server = GraphServer(
            pool,
            max_batch=k,
            max_wait_ms=1.0,
            inflight_capacity=capacity,
            max_concurrent=2,
        )
        served = server.serve(
            [QueryRequest("a", p) for p in plans_a]
            + [QueryRequest("b", p) for p in plans_b]
        )
        st = server.stats()
        assert st.completed == 2 * k
        assert st.admission_overflows == 0
        # The admission high-water mark never exceeded capacity …
        assert st.peak_inflight_bytes <= capacity
        # … and the estimates are honest: each batch's measured device
        # peak (streamed topology ring + pinned prefix, staged-block
        # accounting) plus its attribute state fits its admitted estimate.
        for name, plans, est in (
            ("a", plans_a, est_a),
            ("b", plans_b, est_b),
        ):
            session = pool.session(name)
            ba = plans[0].program.attr_bytes
            attr = 2.0 * session.graph.n_pad * ba * k
            for q in served:
                if q.graph != name:
                    continue
                peak = q.result.meters.peak_device_graph_bytes
                assert peak + attr <= est + 1e-9
        # Serving under constrained budgets stayed bit-identical.
        solo = pool.session("a").run(plans_a[0])
        np.testing.assert_array_equal(solo.attrs, served[0].result.attrs)

    def test_same_graph_batches_charge_topology_once(self, graph):
        # Two point-query batches on one streamed graph admit
        # concurrently; the pinned prefix / stream ring they reserve is
        # *shared* session staging, so the admission ledger must charge
        # the topology term once, not per batch — otherwise
        # frontier-bounded point queries over-reserve and spuriously
        # serialize under capacity.
        from repro.serving.server import estimate_inflight_parts

        pool = SessionPool()
        pool.register(
            "g", graph, memory_budget=int(graph.m * 12 * 0.5), residency="host"
        )
        server = GraphServer(
            pool, max_batch=1, max_wait_ms=0.0, max_concurrent=2
        )
        plans = _plans(BFS(), [0, 3], graph.n + 1)
        served = server.serve([QueryRequest("g", p) for p in plans])
        assert len(served) == 2
        assert all(q.result.converged for q in served)
        session = pool.session("g")
        topo, attr = estimate_inflight_parts(session, plans[0], 1)
        st = server.stats()
        assert st.batches == 2
        # Pre-fix both admissions charged topo+attr (peak 2·(topo+attr));
        # graph-aware charging caps the shared topology at one share.
        assert st.peak_inflight_bytes <= topo + 2 * attr + 1e-6
        assert st.inflight_bytes == 0.0  # ledger fully released

    def test_oversized_batch_runs_alone(self, graph):
        pool = SessionPool()
        pool.register("g", graph, memory_budget=int(graph.m * 12 * 0.25))
        server = GraphServer(
            pool, max_batch=4, max_wait_ms=1.0, inflight_capacity=1.0
        )
        served = server.serve(
            [QueryRequest("g", p) for p in _plans(BFS(), range(4), graph.n + 1)]
        )
        assert len(served) == 4
        st = server.stats()
        assert st.admission_overflows >= 1  # documented solo-run escape


# ---------------------------------------------------------------------------
# Session pool.
# ---------------------------------------------------------------------------
class TestSessionPool:
    def test_lazy_open_and_hits(self, graph):
        pool = SessionPool()
        pool.register("g", graph)
        assert pool.stats().open_sessions == 0
        s1 = pool.session("g")
        s2 = pool.session("g")
        assert s1 is s2
        st = pool.stats()
        assert st.opens == 1 and st.hits == 1 and st.open_sessions == 1

    def test_capacity_evicts_lru(self, graph, graph2):
        pool = SessionPool(capacity_bytes=1)  # any two graphs exceed this
        pool.register("a", graph)
        pool.register("b", graph2)
        sa = pool.session("a")
        assert pool.stats().open_sessions == 1
        pool.session("b")  # opening b evicts idle a
        st = pool.stats()
        assert st.evictions == 1
        assert st.open_sessions == 1
        assert pool.session("b").graph is graph2  # b stayed
        sa2 = pool.session("a")  # a restages on demand
        assert sa2 is not sa
        assert pool.stats().opens == 3

    def test_dsss_graph_pages_back_in_after_eviction(self, graph, dsss_path):
        pool = SessionPool(capacity_bytes=None)
        pool.register("d", dsss_path)
        plan = ExecutionPlan(PageRank(), max_iters=3, tol=0.0)
        before = pool.session("d").run(plan)
        assert pool.session("d").resolved_residency() == "disk"
        assert pool.evict("d")
        assert pool.stats().open_sessions == 0
        after = pool.session("d").run(plan)  # re-opened from the container
        np.testing.assert_array_equal(before.attrs, after.attrs)
        assert pool.stats().opens == 2

    def test_in_use_sessions_are_never_evicted(self, graph, graph2):
        pool = SessionPool(capacity_bytes=1)
        pool.register("a", graph)
        pool.register("b", graph2)
        pool.acquire("a")
        pool.session("b")  # over capacity, but a is pinned
        assert pool.stats().open_sessions == 2  # a survived
        assert not pool.evict("a")
        # the unpin re-enforces the capacity bound: a's stale staged
        # bytes are dropped immediately, not parked until the next open
        pool.release("a")
        assert pool._entries["a"].session is None
        assert not pool.evict("a")  # already cold

    def test_max_open_bound(self):
        graphs = [_graph(n=40, m=150, seed=s, P=2, weighted=False) for s in range(3)]
        pool = SessionPool(max_open=2)
        for i, g in enumerate(graphs):
            pool.register(f"g{i}", g)
            pool.session(f"g{i}")
        assert pool.stats().open_sessions == 2
        assert pool.stats().evictions == 1

    def test_register_rejects_duplicates_and_bad_sources(self, graph):
        pool = SessionPool()
        pool.register("g", graph)
        with pytest.raises(ValueError):
            pool.register("g", graph)
        with pytest.raises(TypeError):
            pool.register("bad", 123)
        with pytest.raises(KeyError):
            pool.resolve("missing")

    def test_staged_bytes_accounting(self, graph, dsss_path):
        pool = SessionPool()
        pool.register("mem", graph)
        pool.register("disk", dsss_path)
        mem_bytes = pool.session("mem").staged_host_bytes()
        assert mem_bytes > 0  # padded numpy shard files are resident
        disk_sess = pool.session("disk")
        # mmap views: nothing edge-scale resident before any run
        assert disk_sess.staged_host_bytes() <= mem_bytes
        assert pool.staged_bytes() == (
            mem_bytes + disk_sess.staged_host_bytes()
        )


# ---------------------------------------------------------------------------
# get_session keying (kwarg-drift regression).
# ---------------------------------------------------------------------------
class TestGetSessionKeying:
    def test_distinct_axes_get_distinct_sessions(self, graph):
        base = get_session(graph)
        assert get_session(graph) is base
        assert get_session(graph, residency="device") is not base
        assert (
            get_session(graph, residency="device", execution="per_block")
            is not get_session(graph, residency="device")
        )
        assert get_session(graph, memory_budget=1 << 16) is not base

    def test_host_memory_budget_is_keyed_and_validated(self, graph):
        # In-memory graphs reject the disk tier's RAM bound with the
        # session's own error — but the kwarg must be accepted & keyed.
        with pytest.raises(ValueError, match="host_memory_budget"):
            get_session(graph, host_memory_budget=1 << 20)


# ---------------------------------------------------------------------------
# Import hygiene.
# ---------------------------------------------------------------------------
def test_import_serving_is_cheap():
    """Graph serving must not drag in the LM stack (models/configs) and
    must not trigger any jax computation at import time."""
    code = (
        "import sys; import repro.serving; "
        "assert 'repro.models' not in sys.modules, 'models imported'; "
        "assert 'repro.configs' not in sys.modules, 'configs imported'; "
        "assert 'repro.serving.llm_demo' not in sys.modules, 'llm demo imported'"
    )
    subprocess.run(
        [sys.executable, "-c", code],
        check=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd=".",
    )
