"""Session/Plan execution API: stage once, run many programs, batch queries.

Covers the API-redesign contract:
  * strategy equivalence (SPU == DPU == MPU == fused) through both the
    batched path (``session.run_batch``) and the ``NXGraphEngine`` shim,
    on a random *weighted* graph;
  * staged-block reuse across successive runs (no re-upload);
  * ``Result.iterations`` == "update sweeps executed" == ``meters.iterations``
    on every convergence path;
  * K-source batches stream the edge blocks once (bytes_read_edges equals a
    single-query run, not K×);
  * plan hashability / compile caching and the kernel-operand hookup.
"""
import numpy as np
import pytest

from repro.core import (
    BFS,
    ExecutionPlan,
    GraphSession,
    NXGraphEngine,
    PageRank,
    SSSP,
    WCC,
    bfs,
    build_dsss,
    multi_bfs,
    multi_sssp,
    sssp,
)
from repro.graph.generators import erdos_renyi, ring
from repro.graph.preprocess import degree_and_densify

ITERS = 8
STRATEGIES = ["spu", "dpu", "mpu", "fused"]


def _graph(n=120, m=600, seed=0, P=4, weighted=False):
    src, dst = erdos_renyi(n, m, seed=seed)
    w = None
    if weighted:
        rng = np.random.default_rng(seed)
        w = rng.uniform(0.1, 2.0, size=len(src)).astype(np.float32)
    el = degree_and_densify(src, dst, weights=w, drop_self_loops=True)
    return build_dsss(el, P)


class TestStrategyEquivalence:
    def test_weighted_pagerank_all_strategies_via_batch_and_shim(self):
        """One weighted graph, every strategy, both entry points, same ranks."""
        g = _graph(seed=3, weighted=True)
        sess = GraphSession(g, memory_budget=4_000)
        plans = [
            ExecutionPlan(PageRank(), strategy=s, max_iters=ITERS, tol=0.0)
            for s in STRATEGIES
        ]
        # Heterogeneous strategies cannot fuse — run_batch must still return
        # correct per-plan results via the sequential fallback.
        batch = sess.run_batch(plans)
        assert not batch.fused and len(batch) == len(STRATEGIES)
        ref = batch[0].attrs
        for res, strategy in zip(batch, STRATEGIES):
            assert res.strategy.strategy == strategy
            np.testing.assert_allclose(res.attrs, ref, rtol=1e-6, atol=1e-9)
        # The shim over the *same session* agrees with the batched path.
        for strategy in STRATEGIES:
            shim = NXGraphEngine(
                g, PageRank(), strategy=strategy, session=sess
            ).run(ITERS, tol=0.0)
            np.testing.assert_allclose(shim.attrs, ref, rtol=1e-6, atol=1e-9)

    def test_weighted_sssp_all_strategies_batched(self):
        g = _graph(seed=4, weighted=True)
        sess = GraphSession(g, memory_budget=2_000)
        ref = None
        for strategy in STRATEGIES:
            batch = sess.run_batch(
                [
                    ExecutionPlan(
                        SSSP(),
                        strategy=strategy,
                        max_iters=g.n + 1,
                        program_kwargs={"root": r},
                    )
                    for r in (0, 5, 9)
                ]
            )
            assert batch.fused
            got = np.stack([r.attrs for r in batch])
            if ref is None:
                ref = got
            np.testing.assert_array_equal(got, ref)


class TestStagedBlockReuse:
    def test_successive_runs_share_staged_blocks(self):
        """The graph is staged once per session: the block dict (and the
        device arrays inside it) must be identical objects across runs."""
        g = _graph(seed=1)
        sess = GraphSession(g)
        blocks_before = sess.blocks
        array_ids = {
            k: (id(b["src_local"]), id(b["dst_local"]))
            for k, b in blocks_before.items()
        }
        sess.run(ExecutionPlan(PageRank(), max_iters=3, tol=0.0))
        sess.run(ExecutionPlan(BFS(), max_iters=g.n + 1, program_kwargs={"root": 0}))
        sess.run(ExecutionPlan(PageRank(), strategy="dpu", max_iters=3, tol=0.0))
        assert sess.blocks is blocks_before
        assert {
            k: (id(b["src_local"]), id(b["dst_local"]))
            for k, b in sess.blocks.items()
        } == array_ids

    def test_engines_can_share_one_session(self):
        g = _graph(seed=2)
        sess = GraphSession(g)
        e1 = NXGraphEngine(g, PageRank(), strategy="spu", session=sess)
        e2 = NXGraphEngine(g, BFS(), strategy="dpu", session=sess)
        assert e1.blocks is e2.blocks is sess.blocks

    def test_compile_cache_hit(self):
        g = _graph(seed=2)
        sess = GraphSession(g, memory_budget=4_000)
        p = ExecutionPlan(PageRank(), strategy="auto", max_iters=3, tol=0.0)
        assert sess.compile(p) is sess.compile(
            ExecutionPlan(PageRank(damping=0.5), strategy="auto")
        )  # same (strategy, Ba) key


class TestIterationsSemantics:
    """Result.iterations == update sweeps executed == meters.iterations."""

    def test_fixed_iteration_path(self):
        g = _graph(seed=5)
        res = GraphSession(g).run(ExecutionPlan(PageRank(), max_iters=5, tol=0.0))
        assert res.iterations == 5 == res.meters.iterations
        assert not res.converged

    def test_early_convergence_path(self):
        """Monotone program goes inactive mid-run (top-of-loop break)."""
        el = degree_and_densify(*ring(24))
        g = build_dsss(el, 4)
        sess = GraphSession(g)
        res = sess.run(
            ExecutionPlan(BFS(), max_iters=g.n + 1, program_kwargs={"root": 0})
        )
        assert res.converged
        assert res.iterations == res.meters.iterations
        # "Sweeps executed" is exact: a budget of exactly `iterations` sweeps
        # reproduces the fixpoint, one fewer does not converge.
        again = sess.run(
            ExecutionPlan(
                BFS(), max_iters=res.iterations, program_kwargs={"root": 0}
            )
        )
        assert again.converged
        np.testing.assert_array_equal(again.attrs, res.attrs)
        short = sess.run(
            ExecutionPlan(
                BFS(), max_iters=res.iterations - 1, program_kwargs={"root": 0}
            )
        )
        assert not short.converged
        assert short.iterations == res.iterations - 1 == short.meters.iterations

    def test_tol_convergence_path(self):
        g = _graph(seed=5)
        res = GraphSession(g).run(
            ExecutionPlan(PageRank(), max_iters=500, tol=1e-10)
        )
        assert res.converged
        assert res.iterations == res.meters.iterations < 500


class TestBatchedQueries:
    """K queries share one streamed pass over the edge blocks."""

    def test_k_identical_queries_cost_one_edge_stream(self):
        """The acceptance check: bytes_read_edges of an 8-query batch equals
        the single-query run exactly — DPU streams every edge from the slow
        tier, so any per-query re-read would show up K×."""
        g = _graph(seed=6)
        sess = GraphSession(g)
        plan = ExecutionPlan(PageRank(), strategy="dpu", max_iters=ITERS, tol=0.0)
        single = sess.run(plan)
        batch = sess.run_batch([plan] * 8)
        assert batch.fused and len(batch) == 8
        assert batch.iterations == single.iterations
        assert batch.meters.bytes_read_edges == single.meters.bytes_read_edges
        assert single.meters.bytes_read_edges > 0
        # Attribute state is genuinely per-query: hub traffic scales K×.
        assert batch.meters.bytes_read_hubs == 8 * single.meters.bytes_read_hubs
        for res in batch:
            np.testing.assert_allclose(res.attrs, single.attrs, rtol=1e-6, atol=1e-9)

    def test_multi_bfs_one_pass_per_sweep(self):
        # P=1 keeps the activity schedule identical for every source, so the
        # per-sweep edge traffic of the batch must exactly equal a
        # single-query sweep (m·Be), not K of them.
        g = _graph(n=100, m=700, seed=7, P=1)
        roots = [0, 3, 11, 17, 23, 42, 57, 77]
        batch = multi_bfs(g, roots, P=1, strategy="dpu")
        assert batch.fused and len(batch) == len(roots)
        single = bfs(g, root=roots[0], P=1, strategy="dpu")
        per_batch = batch.meters.per_iteration().bytes_read_edges
        per_single = single.meters.per_iteration().bytes_read_edges
        assert per_batch == per_single == g.m * 8
        # And strictly sublinear overall vs. K independent runs.
        assert batch.meters.bytes_read_edges < len(roots) * per_single * (
            batch.iterations
        )

    def test_multi_bfs_matches_individual_runs(self):
        g = _graph(seed=8)
        roots = [0, 2, 5, 9, 14, 33, 47, 61]
        batch = multi_bfs(g, roots, P=4)
        assert batch.fused
        for res, root in zip(batch, roots):
            single = bfs(g, root=root, P=4)
            np.testing.assert_array_equal(res.attrs, single.attrs)
            assert res.output == single.output
            assert res.converged
            assert res.iterations <= batch.iterations

    def test_multi_sssp_matches_individual_runs(self):
        g = _graph(seed=9, weighted=True)
        roots = [0, 4, 8, 15]
        batch = multi_sssp(g, roots, P=4)
        assert batch.fused
        for res, root in zip(batch, roots):
            single = sssp(g, root=root, P=4)
            np.testing.assert_array_equal(res.attrs, single.attrs)


class TestPlanObject:
    def test_plans_are_hashable_and_value_equal(self):
        p1 = ExecutionPlan(BFS(), program_kwargs={"root": 3})
        p2 = ExecutionPlan(BFS(), program_kwargs={"root": 3})
        p3 = ExecutionPlan(BFS(), program_kwargs={"root": 4})
        assert p1 == p2 and hash(p1) == hash(p2)
        assert p1 != p3
        assert len({p1, p2, p3}) == 2

    def test_array_kwargs_freeze_by_content(self):
        from repro.core.vertex_programs import MaxLabelForward

        mask = np.ones(16, np.int32)
        p1 = ExecutionPlan(MaxLabelForward(), program_kwargs={"mask": mask})
        p2 = ExecutionPlan(
            MaxLabelForward(), program_kwargs={"mask": mask.copy()}
        )
        assert p1 == p2 and hash(p1) == hash(p2)
        np.testing.assert_array_equal(p1.kwargs_dict()["mask"], mask)
        # Mutating the source array after freezing must not leak in.
        mask[0] = 7
        assert p1.kwargs_dict()["mask"][0] == 1

    def test_with_kwargs(self):
        p = ExecutionPlan(BFS(), max_iters=17, program_kwargs={"root": 0})
        q = p.with_kwargs(root=5)
        assert q.max_iters == 17 and q.kwargs_dict() == {"root": 5}
        assert p.kwargs_dict() == {"root": 0}


class TestKernelHookup:
    def test_session_kernel_operands_cached_and_correct(self):
        import jax.numpy as jnp

        from repro.kernels.ops import subshard_update
        from repro.kernels.ref import subshard_update_ref

        g = _graph(seed=10, P=2)
        sess = GraphSession(g)
        key = next(iter(sess.blocks))
        i, j = key
        ops1 = sess.kernel_operands(i, j, jnp.float32, gather_op="mul", reduce="sum")
        ops2 = sess.kernel_operands(i, j, jnp.float32, gather_op="mul", reduce="sum")
        assert all(a is b for a, b in zip(ops1, ops2))  # staged once
        ss = g.subshard(i, j)
        vals = jnp.asarray(
            np.random.default_rng(0).random(g.interval_size), jnp.float32
        )
        got = subshard_update(
            vals, *ops1, ss.num_unique_dst, gather_op="mul", reduce="sum"
        )
        want = subshard_update_ref(
            vals,
            jnp.asarray(ss.src_local),
            jnp.asarray(ss.hub_inv),
            jnp.ones(ss.num_edges, jnp.float32),
            ss.num_unique_dst,
            gather_op="mul",
            reduce="sum",
        )
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
