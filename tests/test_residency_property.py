"""Property tests for out-of-core host-streamed execution (the tentpole).

Contract under test (see ``core/session.py``):

1. **Bit-identity** — host-streamed execution produces bit-identical
   attributes to device-resident execution, for PageRank / BFS / WCC,
   across strategies and budgets forcing 0%, partial and 100% edge
   residency. The modelled byte meters are also identical: under "host"
   the edge charges coincide with real transfers instead of being
   simulated.
2. **Budget enforcement** — with ``memory_budget`` below the total staged
   bytes, the persistently device-pinned topology plus both attribute
   copies stays ≤ budget (staged accounting), and the transient
   streaming ring adds at most two *stream units* on top of the pinned
   set — two sub-shard blocks for per-block execution, two tile chunks
   (``PackedStreamPlan.max_chunk_model_bytes``) for the packed compiled
   path, which since adaptive tiling no longer downgrades under host
   residency and is what these sessions run by default.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    BFS,
    ExecutionPlan,
    GraphSession,
    PageRank,
    WCC,
    build_dsss,
)
from repro.graph.generators import erdos_renyi
from repro.graph.preprocess import degree_and_densify

from repro.core.session import MODEL_METER_FIELDS as MODELLED_FIELDS

PROGRAMS = {
    "pagerank": lambda: (PageRank(), {}, 6, 0.0),
    "bfs": lambda: (BFS(), {"root": 0}, 200, 1e-10),
    "wcc": lambda: (WCC(), {}, 200, 1e-10),
}


def _graph(seed, P, n=100, m=450):
    src, dst = erdos_renyi(n, m, seed=seed)
    el = degree_and_densify(src, dst, drop_self_loops=True)
    return build_dsss(el, P)


def _budget(g, frac):
    """frac of (both attribute copies + all edge bytes): 0.0 → nothing
    fits, ≥1.0 → 100% residency."""
    return int((2 * g.n_pad * 8 + g.m * 8) * frac)


class TestHostDeviceBitIdentity:
    @settings(max_examples=10, deadline=None)
    @given(
        seed=st.integers(0, 30),
        P=st.integers(1, 5),
        strategy=st.sampled_from(["spu", "dpu", "mpu"]),
        prog_name=st.sampled_from(["pagerank", "bfs", "wcc"]),
        frac=st.sampled_from([0.0, 0.3, 0.6, 1.5]),
    )
    def test_host_streamed_equals_device_resident(
        self, seed, P, strategy, prog_name, frac
    ):
        g = _graph(seed, P)
        prog, kw, iters, tol = PROGRAMS[prog_name]()
        budget = _budget(g, frac)
        plan = ExecutionPlan(
            prog, strategy=strategy, max_iters=iters, tol=tol, program_kwargs=kw
        )
        dev = GraphSession(g, memory_budget=budget, residency="device").run(plan)
        host = GraphSession(g, memory_budget=budget, residency="host").run(plan)
        # Bit-identical, not approximately equal: the streamed blocks are
        # the same padded buffers, so every reduction runs in the same
        # order on the same values.
        np.testing.assert_array_equal(host.attrs, dev.attrs)
        assert host.iterations == dev.iterations
        assert host.converged == dev.converged
        for field in MODELLED_FIELDS:
            assert getattr(host.meters, field) == getattr(dev.meters, field), field
        # Device mode simulates the slow tier; host mode performs it. The
        # default (packed) host path streams the active chunks of its
        # non-pinned tile suffix every sweep: the exact physical volume is
        # the frontier-aware closed form over the run's activity_log
        # (all-ones for non-monotone PageRank, so the oracle degenerates
        # to the full-stream form there).
        from repro.core.iomodel import packed_h2d_bytes, selective_streamed_tiles

        assert dev.meters.bytes_h2d == 0.0
        host_sess = GraphSession(g, memory_budget=budget, residency="host")
        compiled = host_sess.compile(plan)
        assert compiled.execution == "packed"
        splan = host_sess.packed_stream_plan(
            compiled.choice.strategy, prog.attr_bytes
        )
        expected_h2d = sum(
            packed_h2d_bytes(
                selective_streamed_tiles(
                    host_sess._packed_tile_activity(log_s),
                    splan.pin_tiles,
                    splan.chunk_tiles,
                ),
                splan.tile_edges,
            )
            for log_s in host.activity_log
        )
        assert host.meters.bytes_h2d == expected_h2d

    def test_unlimited_budget_bit_identical_to_budgeted_host(self):
        """The acceptance identity: budget below staged bytes, results equal
        the unlimited-budget run bit for bit."""
        g = _graph(seed=3, P=4)
        plan = ExecutionPlan(PageRank(), strategy="spu", max_iters=8, tol=0.0)
        unlimited = GraphSession(g).run(plan)
        tight = GraphSession(
            g, memory_budget=_budget(g, 0.4), residency="host"
        ).run(plan)
        np.testing.assert_array_equal(tight.attrs, unlimited.attrs)

    def test_shim_accepts_equivalent_residency_on_shared_session(self):
        """'auto' with a budget resolves to 'host'; passing the resolved
        name to the shim over that session must not be rejected."""
        from repro.core import NXGraphEngine

        g = _graph(seed=2, P=3)
        sess = GraphSession(g, memory_budget=_budget(g, 0.5))  # auto → host
        assert sess.resolved_residency() == "host"
        eng = NXGraphEngine(g, PageRank(), residency="host", session=sess)
        assert eng.session is sess
        with pytest.raises(ValueError, match="residency"):
            NXGraphEngine(g, PageRank(), residency="device", session=sess)

    def test_plan_level_residency_override(self):
        g = _graph(seed=4, P=3)
        sess = GraphSession(g, memory_budget=_budget(g, 0.3), residency="device")
        base = ExecutionPlan(PageRank(), strategy="dpu", max_iters=4, tol=0.0)
        dev = sess.run(base)
        host = sess.run(
            ExecutionPlan(
                PageRank(), strategy="dpu", max_iters=4, tol=0.0, residency="host"
            )
        )
        np.testing.assert_array_equal(host.attrs, dev.attrs)
        assert dev.meters.bytes_h2d == 0.0 and host.meters.bytes_h2d > 0


class TestBudgetEnforcement:
    @settings(max_examples=10, deadline=None)
    @given(
        seed=st.integers(0, 30),
        P=st.integers(1, 5),
        frac=st.floats(0.0, 1.2),
    )
    def test_pinned_set_plus_attrs_within_budget(self, seed, P, frac):
        g = _graph(seed, P)
        prog = PageRank()
        Ba = prog.attr_bytes
        budget = _budget(g, frac)
        sess = GraphSession(g, memory_budget=budget, residency="host")
        res = sess.run(ExecutionPlan(prog, strategy="spu", max_iters=2, tol=0.0))
        pinned_model, pinned_actual = sess.pinned_device_bytes()
        if pinned_model > 0:
            # Staged accounting: persistent residency honors B_M.
            assert pinned_model + 2 * g.n_pad * Ba <= budget
        # Transient streaming ring: at most current + prefetched stream
        # units (tile chunks for the default packed path) on top.
        splan = sess.packed_stream_plan("spu", Ba)
        assert (
            res.meters.peak_device_graph_bytes
            <= pinned_model + 2 * splan.max_chunk_model_bytes
        )

    def test_zero_budget_streams_everything_every_sweep(self):
        g = _graph(seed=5, P=4)
        sess = GraphSession(g, memory_budget=0, residency="host")
        res = sess.run(ExecutionPlan(PageRank(), strategy="spu", max_iters=3, tol=0.0))
        assert sess.pinned_device_bytes() == (0.0, 0.0)
        total_model = sum(h["e"] for h in sess.host_blocks.values()) * sess.Be
        assert res.meters.bytes_read_edges == res.iterations * total_model

    def test_full_budget_streams_nothing(self):
        g = _graph(seed=6, P=4)
        sess = GraphSession(g, memory_budget=_budget(g, 2.0), residency="host")
        res = sess.run(ExecutionPlan(PageRank(), strategy="spu", max_iters=3, tol=0.0))
        assert res.meters.bytes_h2d == 0.0
        assert res.meters.bytes_read_edges == 0.0
        pinned_model, _ = sess.pinned_device_bytes()
        assert pinned_model == sum(h["e"] for h in sess.host_blocks.values()) * sess.Be

    def test_device_peak_below_budget_with_headroom(self):
        """The acceptance inequality end-to-end: peak device graph bytes +
        both attribute copies ≤ budget + the documented two-stream-unit
        slack, on a genuinely out-of-core budget."""
        g = _graph(seed=7, P=4, n=200, m=1200)
        prog = PageRank()
        Ba = prog.attr_bytes
        total = 2 * g.n_pad * Ba + g.m * 8
        budget = int(total * 0.6)
        sess = GraphSession(g, memory_budget=budget, residency="host")
        res = sess.run(ExecutionPlan(prog, strategy="spu", max_iters=3, tol=0.0))
        splan = sess.packed_stream_plan("spu", Ba)
        assert budget < total  # genuinely out-of-core
        assert (
            res.meters.peak_device_graph_bytes + 2 * g.n_pad * Ba
            <= budget + 2 * splan.max_chunk_model_bytes
        )

    def test_per_block_ring_still_bounded(self):
        """The legacy per-block streaming path keeps its two-block ring."""
        g = _graph(seed=9, P=4, n=200, m=1200)
        prog = PageRank()
        budget = _budget(g, 0.5)
        sess = GraphSession(g, memory_budget=budget, residency="host")
        res = sess.run(
            ExecutionPlan(
                prog, strategy="spu", max_iters=2, tol=0.0,
                execution="per_block",
            )
        )
        pinned_model, _ = sess.pinned_device_bytes()
        max_block = max(h["e"] for h in sess.host_blocks.values()) * sess.Be
        assert res.meters.bytes_h2d > 0
        assert (
            res.meters.peak_device_graph_bytes <= pinned_model + 2 * max_block
        )

    def test_pinned_blocks_released_when_strategy_changes(self):
        """SPU pins the leftover set; a following DPU plan must not keep
        those device copies alive (budget would silently be exceeded)."""
        g = _graph(seed=8, P=4)
        sess = GraphSession(g, memory_budget=_budget(g, 0.8), residency="host")
        sess.run(ExecutionPlan(PageRank(), strategy="spu", max_iters=2, tol=0.0))
        assert sess.pinned_device_bytes()[0] > 0
        sess.run(ExecutionPlan(PageRank(), strategy="dpu", max_iters=2, tol=0.0))
        assert sess.pinned_device_bytes() == (0.0, 0.0)


class TestBatchedHostStreaming:
    def test_batched_queries_stream_edges_once(self):
        """K BFS sources over a host-streamed session still pay the edge
        transfers once per sweep, not K× — the semi-external-memory win."""
        g = _graph(seed=9, P=1, n=80, m=500)
        sess = GraphSession(g, memory_budget=0, residency="host")
        roots = [0, 3, 7, 11]
        plans = [
            ExecutionPlan(BFS(), strategy="dpu", max_iters=200, program_kwargs={"root": r})
            for r in roots
        ]
        batch = sess.run_batch(plans)
        assert batch.fused
        single = sess.run(plans[0])
        per_batch = batch.meters.per_iteration()
        per_single = single.meters.per_iteration()
        assert per_batch.bytes_read_edges == per_single.bytes_read_edges > 0
        assert per_batch.bytes_h2d == per_single.bytes_h2d > 0
        for res, root in zip(batch, roots):
            ref = GraphSession(g).run(
                ExecutionPlan(BFS(), strategy="dpu", max_iters=200, program_kwargs={"root": root})
            )
            np.testing.assert_array_equal(res.attrs, ref.attrs)
