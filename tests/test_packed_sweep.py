"""Tile-packed compiled sweeps vs. the per-block executor.

The packed path's contract is strict: for every native schedule
(SPU/DPU/MPU), every program family (sum / min on weighted+unweighted
graphs) and batched K > 1 runs, it must produce

  * bit-identical attributes and outputs, and
  * field-for-field identical modelled ``Meters`` (edges, blocks, every
    byte counter — only ``wall_seconds`` may differ),

while actually running the compiled scan (one ``lax.scan`` + one batched
apply per sweep) instead of the per-sub-shard dispatch loop. Host-streamed
residency downgrades to per-block by design — also covered here.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (
    BFS,
    ExecutionPlan,
    GraphSession,
    NXGraphEngine,
    PageRank,
    SSSP,
    build_dsss,
)
from repro.core import session as session_mod
from repro.graph.generators import erdos_renyi, ring
from repro.graph.preprocess import degree_and_densify

STRATEGIES = ["spu", "dpu", "mpu"]

# (label, program factory, plan kwargs, weighted) — PageRank exercises the
# float-sum semiring (where re-association would show), BFS the monotone
# int-min path with activity skipping, SSSP the weighted float-min path.
PROGRAMS = [
    ("pagerank", PageRank, dict(max_iters=6, tol=0.0), True),
    ("bfs", BFS, dict(max_iters=100, program_kwargs={"root": 0}), False),
    ("sssp", SSSP, dict(max_iters=100, program_kwargs={"root": 0}), True),
]


def _graph(n=150, m=900, seed=0, P=5, weighted=False):
    src, dst = erdos_renyi(n, m, seed=seed)
    w = None
    if weighted:
        rng = np.random.default_rng(seed)
        w = rng.uniform(0.1, 2.0, size=len(src)).astype(np.float32)
    el = degree_and_densify(src, dst, weights=w, drop_self_loops=True)
    return build_dsss(el, P)


def _meters_dict(meters):
    d = dataclasses.asdict(meters)
    d.pop("wall_seconds")
    return d


def _assert_equivalent(res_pb, res_pk):
    np.testing.assert_array_equal(res_pb.attrs, res_pk.attrs)
    assert res_pb.iterations == res_pk.iterations
    assert res_pb.converged == res_pk.converged
    assert _meters_dict(res_pb.meters) == _meters_dict(res_pk.meters)


@pytest.mark.parametrize("label,prog_cls,kwargs,weighted", PROGRAMS)
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_bit_identity_and_meters(label, prog_cls, kwargs, weighted, strategy):
    g = _graph(seed=3, weighted=weighted)
    # memory_budget chosen so MPU resolves to a strict 0 < Q < P split for
    # both attribute widths (Ba=4 min-programs and Ba=8 PageRank), so the
    # mixed direct+hub two-phase path really runs; residency pinned to
    # "device" (a budget would otherwise flip the session into host
    # streaming, where packed doesn't apply).
    sess = GraphSession(g, memory_budget=720, residency="device")
    if strategy == "mpu":
        choice = sess.compile(ExecutionPlan(prog_cls(), strategy="mpu")).choice
        assert 0 < choice.Q < g.P, "budget must exercise the hub split"
    pb = sess.run(
        ExecutionPlan(prog_cls(), strategy=strategy, execution="per_block", **kwargs)
    )
    pk = sess.run(
        ExecutionPlan(prog_cls(), strategy=strategy, execution="packed", **kwargs)
    )
    _assert_equivalent(pb, pk)
    assert pk.meters.edges_processed > 0
    if label == "pagerank":
        # Non-monotone: every sweep touches every sub-shard.
        assert pk.meters.blocks_processed == pk.iterations * len(sess.block_keys)


@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize(
    "label,prog_cls,weighted",
    [("bfs", BFS, False), ("sssp", SSSP, True)],
)
def test_batched_k_gt_1(label, prog_cls, weighted, strategy):
    """K>1 fused batches: one packed scan serves all queries."""
    g = _graph(seed=7, weighted=weighted)
    sess = GraphSession(g, residency="device")
    roots = [0, 11, 29, 63]

    def plans(execution):
        return [
            ExecutionPlan(
                prog_cls(),
                strategy=strategy,
                max_iters=100,
                execution=execution,
                program_kwargs={"root": r},
            )
            for r in roots
        ]

    b_pb = sess.run_batch(plans("per_block"))
    b_pk = sess.run_batch(plans("packed"))
    assert b_pb.fused and b_pk.fused
    assert b_pb.iterations == b_pk.iterations
    for r_pb, r_pk in zip(b_pb, b_pk):
        np.testing.assert_array_equal(r_pb.attrs, r_pk.attrs)
        np.testing.assert_array_equal(r_pb.output, r_pk.output)
        assert r_pb.iterations == r_pk.iterations
    assert _meters_dict(b_pb.meters) == _meters_dict(b_pk.meters)


def test_batched_pagerank_shares_edge_stream():
    """Edge bytes are charged once per sweep under batching, K× for
    interval/hub state — identically in both execution modes."""
    g = _graph(seed=9)
    sess = GraphSession(g, residency="device")
    plan = ExecutionPlan(
        PageRank(), strategy="dpu", max_iters=4, tol=0.0, execution="packed"
    )
    single = sess.run(plan)
    batch = sess.run_batch([plan] * 6)
    assert batch.fused
    assert batch.meters.bytes_read_edges == single.meters.bytes_read_edges > 0
    assert batch.meters.bytes_read_hubs == 6 * single.meters.bytes_read_hubs


def test_packed_path_actually_runs(monkeypatch):
    """The packed run must never enter the per-block primitives, and must
    call the compiled sweep exactly once per update sweep."""
    g = _graph(seed=5)
    sess = GraphSession(g, residency="device")

    def boom(*a, **kw):
        raise AssertionError("per-block primitive dispatched in packed mode")

    monkeypatch.setattr(session_mod, "_block_gather_reduce", boom)
    monkeypatch.setattr(session_mod, "_block_to_hub", boom)
    monkeypatch.setattr(session_mod, "_block_from_hub", boom)
    monkeypatch.setattr(session_mod, "_apply_interval", boom)

    sweeps = []
    real_jits = session_mod._packed_jits

    def counting_jits(donate):
        sweep, apply_all = real_jits(donate)

        def counted(*a, **kw):
            sweeps.append(1)
            return sweep(*a, **kw)

        return counted, apply_all

    monkeypatch.setattr(session_mod, "_packed_jits", counting_jits)
    res = sess.run(
        ExecutionPlan(
            PageRank(), strategy="spu", max_iters=3, tol=0.0, execution="packed"
        )
    )
    assert res.iterations == 3
    assert len(sweeps) == 3  # one compiled sweep dispatch per update sweep


def test_activity_skipping_matches_per_block():
    """Monotone activity tracking: packed masks inactive rows to exact
    identities; block/edge meters must track the per-block skip counts."""
    el = degree_and_densify(*ring(36))
    g = build_dsss(el, 6)
    sess = GraphSession(g, residency="device")
    for strategy in STRATEGIES:
        pb = sess.run(
            ExecutionPlan(
                BFS(), strategy=strategy, max_iters=50, execution="per_block",
                program_kwargs={"root": 0},
            )
        )
        pk = sess.run(
            ExecutionPlan(
                BFS(), strategy=strategy, max_iters=50, execution="packed",
                program_kwargs={"root": 0},
            )
        )
        _assert_equivalent(pb, pk)
        assert pk.meters.blocks_skipped > 0  # the ring really does skip rows


def test_host_residency_downgrades_to_per_block():
    """Streaming is inherently per-block: packed requests under host
    residency run the fetcher path, bit-identical to device execution."""
    g = _graph(seed=6)
    budget = g.total_edge_bytes(8) // 3
    host = GraphSession(g, memory_budget=budget, residency="host")
    compiled = host.compile(ExecutionPlan(PageRank(), strategy="spu", execution="packed"))
    assert compiled.execution == "per_block"
    dev = GraphSession(g, residency="device")
    assert (
        dev.compile(ExecutionPlan(PageRank(), strategy="spu")).execution == "packed"
    )
    r_host = host.run(ExecutionPlan(PageRank(), strategy="spu", max_iters=4, tol=0.0))
    r_dev = dev.run(ExecutionPlan(PageRank(), strategy="spu", max_iters=4, tol=0.0))
    np.testing.assert_array_equal(r_host.attrs, r_dev.attrs)
    assert r_host.meters.bytes_h2d > 0  # host mode really streamed
    assert r_dev.meters.bytes_h2d == 0


def test_custom_and_fused_strategies_stay_per_block():
    import repro.core.baselines  # noqa: F401  (registers turbograph-like)

    g = _graph(seed=8)
    sess = GraphSession(g, residency="device", execution="packed")
    assert (
        sess.compile(ExecutionPlan(PageRank(), strategy="fused")).execution
        == "per_block"
    )
    assert (
        sess.compile(
            ExecutionPlan(PageRank(), strategy="turbograph-like")
        ).execution
        == "per_block"
    )
    # And they still run correctly under a packed-preferring session.
    ref = sess.run(
        ExecutionPlan(PageRank(), strategy="spu", max_iters=5, tol=0.0)
    )
    fused = sess.run(
        ExecutionPlan(PageRank(), strategy="fused", max_iters=5, tol=0.0)
    )
    np.testing.assert_allclose(fused.attrs, ref.attrs, rtol=1e-6, atol=1e-9)


def test_engine_shim_execution_knob():
    g = _graph(seed=4, weighted=True)
    sess = GraphSession(g, residency="device")
    pb = NXGraphEngine(
        g, PageRank(), strategy="spu", execution="per_block", session=sess
    )
    pk = NXGraphEngine(g, PageRank(), strategy="spu", execution="packed", session=sess)
    assert pb.execution == "per_block" and pk.execution == "packed"
    r_pb = pb.run(max_iters=5, tol=0.0)
    r_pk = pk.run(max_iters=5, tol=0.0)
    _assert_equivalent(r_pb, r_pk)


def test_packed_layout_shape_invariants():
    g = _graph(seed=2, weighted=True)
    packed = g.packed_sweep()
    host = g.host_blocks()
    assert packed.num_tiles == len(host)
    assert packed.keys == tuple(sorted(host))
    assert packed.src_local.shape == (packed.num_tiles, packed.tile_edges)
    assert packed.tile_edges >= max(b["e"] for b in host.values())
    # Per-tile metadata reproduces the host-block bookkeeping exactly.
    for t, key in enumerate(packed.keys):
        blk = host[key]
        assert packed.e_valid[t] == blk["e"]
        assert packed.u[t] == blk["u"]
        assert (packed.src_interval[t], packed.dst_interval[t]) == key
        e = blk["e"]
        np.testing.assert_array_equal(packed.src_local[t, :e], blk["src_local"][:e])
        np.testing.assert_array_equal(packed.dst_local[t, :e], blk["dst_local"][:e])
        np.testing.assert_array_equal(packed.weights[t, :e], blk["weights"][:e])
    # base_slot is the global hub-slot prefix sum in row-major key order.
    np.testing.assert_array_equal(
        packed.base_slot,
        [g.hub_offsets[i, j] for (i, j) in packed.keys],
    )


def test_invalid_execution_values_rejected():
    g = _graph(seed=1)
    with pytest.raises(ValueError):
        GraphSession(g, execution="warp")
    with pytest.raises(ValueError):
        ExecutionPlan(PageRank(), execution="warp")
