"""Tile-packed compiled sweeps vs. the per-block executor.

The packed path's contract is strict: for every native schedule
(SPU/DPU/MPU), every program family (sum / min on weighted+unweighted
graphs), both residencies (device-staged and host-streamed) and batched
K > 1 runs, it must produce

  * bit-identical attributes and outputs, and
  * field-for-field identical *model* ``Meters`` (edges, blocks, every
    modelled byte counter) — the physical fields (``wall_seconds``,
    ``bytes_h2d``, ``peak_device_graph_bytes``) describe whichever data
    path actually ran and are compared only where the paths coincide,

while actually running the compiled scan (one ``lax.scan`` + one batched
apply per sweep on device; one scan per streamed tile chunk under host
residency) instead of the per-sub-shard dispatch loop. Since the adaptive
destination-aligned tiling, host residency no longer downgrades packed
execution — also covered here, along with the layout invariants of
:class:`repro.core.dsss.PackedSweep` and the padding bound on power-law
graphs.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (
    BFS,
    ExecutionPlan,
    GraphSession,
    NXGraphEngine,
    PageRank,
    SSSP,
    build_dsss,
)
from repro.core import session as session_mod
from repro.graph.generators import erdos_renyi, ring, zipf
from repro.graph.preprocess import degree_and_densify

STRATEGIES = ["spu", "dpu", "mpu"]
RESIDENCIES = ["device", "host"]

# (label, program factory, plan kwargs, weighted) — PageRank exercises the
# float-sum semiring (where re-association would show), BFS the monotone
# int-min path with activity skipping, SSSP the weighted float-min path.
PROGRAMS = [
    ("pagerank", PageRank, dict(max_iters=6, tol=0.0), True),
    ("bfs", BFS, dict(max_iters=100, program_kwargs={"root": 0}), False),
    ("sssp", SSSP, dict(max_iters=100, program_kwargs={"root": 0}), True),
]

# Modelled meter fields — must be identical across execution modes AND
# residencies. The remaining fields (bytes_h2d, peak_device_graph_bytes,
# wall_seconds) are physical: they report what the chosen data path did.
MODEL_FIELDS = session_mod.MODEL_METER_FIELDS


def _graph(n=150, m=900, seed=0, P=5, weighted=False):
    src, dst = erdos_renyi(n, m, seed=seed)
    w = None
    if weighted:
        rng = np.random.default_rng(seed)
        w = rng.uniform(0.1, 2.0, size=len(src)).astype(np.float32)
    el = degree_and_densify(src, dst, weights=w, drop_self_loops=True)
    return build_dsss(el, P)


def _meters_dict(meters, model_only=False):
    d = dataclasses.asdict(meters)
    d.pop("wall_seconds")
    if model_only:
        d = {k: v for k, v in d.items() if k in MODEL_FIELDS}
    return d


def _assert_equivalent(res_pb, res_pk, model_only=False):
    np.testing.assert_array_equal(res_pb.attrs, res_pk.attrs)
    assert res_pb.iterations == res_pk.iterations
    assert res_pb.converged == res_pk.converged
    assert _meters_dict(res_pb.meters, model_only) == _meters_dict(
        res_pk.meters, model_only
    )


def _session(g, residency):
    # memory_budget chosen so MPU resolves to a strict 0 < Q < P split for
    # both attribute widths (Ba=4 min-programs and Ba=8 PageRank), so the
    # mixed direct+hub two-phase path really runs. Under "host" the same
    # budget also forces real streaming (it is far below the graph bytes).
    return GraphSession(g, memory_budget=720, residency=residency)


@pytest.mark.parametrize("label,prog_cls,kwargs,weighted", PROGRAMS)
@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("residency", RESIDENCIES)
def test_bit_identity_and_meters(
    label, prog_cls, kwargs, weighted, strategy, residency
):
    g = _graph(seed=3, weighted=weighted)
    sess = _session(g, residency)
    if strategy == "mpu":
        choice = sess.compile(ExecutionPlan(prog_cls(), strategy="mpu")).choice
        assert 0 < choice.Q < g.P, "budget must exercise the hub split"
    pb = sess.run(
        ExecutionPlan(prog_cls(), strategy=strategy, execution="per_block", **kwargs)
    )
    pk = sess.run(
        ExecutionPlan(prog_cls(), strategy=strategy, execution="packed", **kwargs)
    )
    # Model meters agree always; the physical fields additionally agree
    # under device residency (neither path streams: h2d 0, peak = total).
    _assert_equivalent(pb, pk, model_only=(residency == "host"))
    assert pk.meters.edges_processed > 0
    if residency == "host":
        assert pb.meters.bytes_h2d > 0 and pk.meters.bytes_h2d > 0
    if label == "pagerank":
        # Non-monotone: every sweep touches every sub-shard.
        assert pk.meters.blocks_processed == pk.iterations * len(sess.block_keys)


@pytest.mark.parametrize("residency", RESIDENCIES)
@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize(
    "label,prog_cls,weighted",
    [("bfs", BFS, False), ("sssp", SSSP, True)],
)
def test_batched_k_gt_1(label, prog_cls, weighted, strategy, residency):
    """K>1 fused batches: one packed scan serves all queries."""
    g = _graph(seed=7, weighted=weighted)
    budget = g.total_edge_bytes(8) // 3 if residency == "host" else None
    sess = GraphSession(g, memory_budget=budget, residency=residency)
    roots = [0, 11, 29, 63]

    def plans(execution):
        return [
            ExecutionPlan(
                prog_cls(),
                strategy=strategy,
                max_iters=100,
                execution=execution,
                program_kwargs={"root": r},
            )
            for r in roots
        ]

    b_pb = sess.run_batch(plans("per_block"))
    b_pk = sess.run_batch(plans("packed"))
    assert b_pb.fused and b_pk.fused
    assert b_pb.iterations == b_pk.iterations
    for r_pb, r_pk in zip(b_pb, b_pk):
        np.testing.assert_array_equal(r_pb.attrs, r_pk.attrs)
        np.testing.assert_array_equal(r_pb.output, r_pk.output)
        assert r_pb.iterations == r_pk.iterations
    assert _meters_dict(b_pb.meters, model_only=True) == _meters_dict(
        b_pk.meters, model_only=True
    )


def test_batched_pagerank_shares_edge_stream():
    """Edge bytes are charged once per sweep under batching, K× for
    interval/hub state — identically in both execution modes."""
    g = _graph(seed=9)
    sess = GraphSession(g, residency="device")
    plan = ExecutionPlan(
        PageRank(), strategy="dpu", max_iters=4, tol=0.0, execution="packed"
    )
    single = sess.run(plan)
    batch = sess.run_batch([plan] * 6)
    assert batch.fused
    assert batch.meters.bytes_read_edges == single.meters.bytes_read_edges > 0
    assert batch.meters.bytes_read_hubs == 6 * single.meters.bytes_read_hubs


@pytest.mark.parametrize("residency", RESIDENCIES)
def test_packed_path_actually_runs(monkeypatch, residency):
    """The packed run must never enter the per-block primitives; on device
    it calls the compiled sweep exactly once per update sweep, streaming
    calls it once per tile chunk."""
    g = _graph(seed=5)
    budget = g.total_edge_bytes(8) // 2 if residency == "host" else None
    sess = GraphSession(g, memory_budget=budget, residency=residency)

    def boom(*a, **kw):
        raise AssertionError("per-block primitive dispatched in packed mode")

    monkeypatch.setattr(session_mod, "_block_gather_reduce", boom)
    monkeypatch.setattr(session_mod, "_block_to_hub", boom)
    monkeypatch.setattr(session_mod, "_block_from_hub", boom)
    monkeypatch.setattr(session_mod, "_apply_interval", boom)

    sweeps = []
    real_jits = session_mod._packed_jits

    def counting_jits(donate):
        sweep, apply_all = real_jits(donate)

        def counted(*a, **kw):
            sweeps.append(1)
            return sweep(*a, **kw)

        return counted, apply_all

    monkeypatch.setattr(session_mod, "_packed_jits", counting_jits)
    res = sess.run(
        ExecutionPlan(
            PageRank(), strategy="spu", max_iters=3, tol=0.0, execution="packed"
        )
    )
    assert res.iterations == 3
    if residency == "device":
        assert len(sweeps) == 3  # one compiled sweep dispatch per update sweep
    else:
        assert len(sweeps) >= 3  # ≥ one chunk per sweep, no per-block entry


def test_activity_skipping_matches_per_block():
    """Monotone activity tracking: packed masks inactive rows to exact
    identities; block/edge meters must track the per-block skip counts."""
    el = degree_and_densify(*ring(36))
    g = build_dsss(el, 6)
    sess = GraphSession(g, residency="device")
    for strategy in STRATEGIES:
        pb = sess.run(
            ExecutionPlan(
                BFS(), strategy=strategy, max_iters=50, execution="per_block",
                program_kwargs={"root": 0},
            )
        )
        pk = sess.run(
            ExecutionPlan(
                BFS(), strategy=strategy, max_iters=50, execution="packed",
                program_kwargs={"root": 0},
            )
        )
        _assert_equivalent(pb, pk)
        assert pk.meters.blocks_skipped > 0  # the ring really does skip rows


def test_host_residency_runs_packed():
    """Since adaptive tiling, packed execution streams out-of-core instead
    of downgrading: auto resolves to packed under host residency, results
    are bit-identical to device residency, and the budget pins a tile
    prefix within the leftover while chunks stream on top."""
    g = _graph(seed=6)
    budget = 2 * g.n_pad * 8 + g.total_edge_bytes(8) // 2
    host = GraphSession(g, memory_budget=budget, residency="host")
    compiled = host.compile(ExecutionPlan(PageRank(), strategy="spu"))
    assert compiled.residency == "host" and compiled.execution == "packed"
    dev = GraphSession(g, residency="device")
    r_host = host.run(ExecutionPlan(PageRank(), strategy="spu", max_iters=4, tol=0.0))
    r_dev = dev.run(ExecutionPlan(PageRank(), strategy="spu", max_iters=4, tol=0.0))
    np.testing.assert_array_equal(r_host.attrs, r_dev.attrs)
    assert r_host.meters.bytes_h2d > 0  # host mode really streamed
    assert r_dev.meters.bytes_h2d == 0
    # Budget accounting: pinned tile prefix fits the leftover, and the
    # peak adds at most the two-chunk streaming ring on top.
    splan = host.packed_stream_plan("spu", PageRank().attr_bytes)
    pinned_model, _ = host.pinned_device_bytes()
    assert pinned_model == splan.pin_model_bytes
    assert pinned_model + 2 * g.n_pad * 8 <= budget
    assert (
        r_host.meters.peak_device_graph_bytes
        <= pinned_model + 2 * splan.max_chunk_model_bytes
    )
    # Physical stream volume is a closed form of the layout: every
    # non-pinned tile ships its dense leaves once per sweep.
    from repro.core import packed_h2d_bytes

    assert r_host.meters.bytes_h2d == r_host.iterations * packed_h2d_bytes(
        splan.num_tiles - splan.pin_tiles, splan.tile_edges
    )


def test_full_budget_host_packed_streams_nothing():
    g = _graph(seed=2)
    total = 2 * g.n_pad * 8 + g.total_edge_bytes(8)
    sess = GraphSession(g, memory_budget=2 * total, residency="host")
    res = sess.run(ExecutionPlan(PageRank(), strategy="spu", max_iters=3, tol=0.0))
    assert res.meters.bytes_h2d == 0.0
    assert res.meters.bytes_read_edges == 0.0
    assert sess.pinned_device_bytes()[0] == g.m * sess.Be


def test_custom_and_fused_strategies_stay_per_block():
    import repro.core.baselines  # noqa: F401  (registers turbograph-like)

    g = _graph(seed=8)
    sess = GraphSession(g, residency="device", execution="packed")
    assert (
        sess.compile(ExecutionPlan(PageRank(), strategy="fused")).execution
        == "per_block"
    )
    assert (
        sess.compile(
            ExecutionPlan(PageRank(), strategy="turbograph-like")
        ).execution
        == "per_block"
    )
    # And they still run correctly under a packed-preferring session.
    ref = sess.run(
        ExecutionPlan(PageRank(), strategy="spu", max_iters=5, tol=0.0)
    )
    fused = sess.run(
        ExecutionPlan(PageRank(), strategy="fused", max_iters=5, tol=0.0)
    )
    np.testing.assert_allclose(fused.attrs, ref.attrs, rtol=1e-6, atol=1e-9)


def test_engine_shim_execution_knob():
    g = _graph(seed=4, weighted=True)
    sess = GraphSession(g, residency="device")
    pb = NXGraphEngine(
        g, PageRank(), strategy="spu", execution="per_block", session=sess
    )
    pk = NXGraphEngine(g, PageRank(), strategy="spu", execution="packed", session=sess)
    assert pb.execution == "per_block" and pk.execution == "packed"
    r_pb = pb.run(max_iters=5, tol=0.0)
    r_pk = pk.run(max_iters=5, tol=0.0)
    _assert_equivalent(r_pb, r_pk)
    with pytest.raises(ValueError, match="packing"):
        NXGraphEngine(g, PageRank(), packing="subshard", session=sess)


def test_packed_layout_invariants_adaptive_and_subshard():
    from _layout_checks import check_layout

    g = _graph(seed=2, weighted=True)
    for mode in ("adaptive", "subshard"):
        packed = g.packed_sweep(mode)
        check_layout(g, packed)
    # Subshard mode reproduces the per-block bookkeeping exactly.
    old = g.packed_sweep("subshard")
    host = g.host_blocks()
    assert old.num_tiles == len(host)
    for t, key in enumerate(sorted(host)):
        blk = host[key]
        assert old.e_valid[t] == blk["e"]
        assert old.u[t] == blk["u"]
        assert (old.src_interval[t], old.dst_interval[t]) == key
        assert old.base_slot[t] == g.hub_offsets[key]


def test_adaptive_padding_bounded_on_power_law():
    """The acceptance bound: on a Zipf-degree graph at P=32 the adaptive
    packing pads ≤ 1.25× while the legacy sub-shard tiles are hub-bound."""
    el = degree_and_densify(*zipf(6000, 40000, alpha=1.9, seed=0), drop_self_loops=True)
    g = build_dsss(el, 32)
    from _layout_checks import check_layout

    adaptive = g.packed_sweep("adaptive")
    legacy = g.packed_sweep("subshard")
    assert adaptive.padding_ratio <= 1.25, adaptive.padding_ratio
    assert legacy.padding_ratio > adaptive.padding_ratio
    check_layout(g, adaptive)


def test_src_sorted_requires_subshard_packing():
    el = degree_and_densify(*erdos_renyi(80, 400, seed=1), drop_self_loops=True)
    g = build_dsss(el, 4, src_sorted=True)
    with pytest.raises(ValueError, match="src_sorted"):
        g.packed_sweep("adaptive")
    with pytest.raises(ValueError, match="adaptive"):
        GraphSession(g, packing="adaptive")
    sess = GraphSession(g)  # auto → subshard
    assert sess.packing == "subshard"
    pb = sess.run(
        ExecutionPlan(PageRank(), strategy="spu", max_iters=4, tol=0.0,
                      execution="per_block")
    )
    pk = sess.run(
        ExecutionPlan(PageRank(), strategy="spu", max_iters=4, tol=0.0,
                      execution="packed")
    )
    _assert_equivalent(pb, pk)


def test_kernel_operands_from_packed_tile():
    """Tiles are valid Pallas kernel streams: staging one through
    ops.prepare_from_packed_tile and running the windowed sub-shard update
    reproduces the reference per-slot reduction over global hub slots."""
    import jax.numpy as jnp

    from repro.kernels.ops import prepare_from_packed_tile, subshard_update

    g = _graph(n=80, m=400, seed=11, P=3, weighted=True)
    packed = g.packed_sweep("adaptive")
    gslot = g.global_hub_slots()
    num_slots = int(g.hub_offsets[-1, -1])
    rng = np.random.default_rng(0)
    vals = rng.uniform(0.1, 1.0, size=g.n_pad).astype(np.float32)
    for t in range(packed.num_tiles):
        operands = prepare_from_packed_tile(
            packed, t, jnp.float32, gather_op="mul", reduce="sum"
        )
        hub = subshard_update(
            jnp.asarray(vals), *operands, num_slots=num_slots,
            gather_op="mul", reduce="sum",
        )
        lo = int(packed.row_offset[t])
        hi = lo + int(packed.e_valid[t])
        ref = np.zeros(num_slots, np.float32)
        np.add.at(
            ref, gslot[lo:hi], vals[g.src[lo:hi]] * g.weights[lo:hi]
        )
        sl = slice(int(packed.base_slot[t]), int(packed.base_slot[t] + packed.u[t]))
        np.testing.assert_allclose(np.asarray(hub)[sl], ref[sl], rtol=1e-5)
    # src_sorted blocks scramble the slot stream — staging must refuse
    # rather than silently compute wrong windowed partials.
    el = degree_and_densify(*erdos_renyi(80, 400, seed=11), drop_self_loops=True)
    gs = build_dsss(el, 3, src_sorted=True)
    ps = gs.packed_sweep("subshard")
    raised = 0
    for t in range(ps.num_tiles):
        try:
            prepare_from_packed_tile(ps, t, jnp.float32, gather_op="mul", reduce="sum")
        except ValueError:
            raised += 1
    assert raised > 0


def test_invalid_execution_values_rejected():
    g = _graph(seed=1)
    with pytest.raises(ValueError):
        GraphSession(g, execution="warp")
    with pytest.raises(ValueError):
        GraphSession(g, packing="diagonal")
    with pytest.raises(ValueError):
        ExecutionPlan(PageRank(), execution="warp")
