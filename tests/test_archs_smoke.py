"""Per-arch smoke tests: reduced configs, one forward/train step on CPU,
shape checks, no NaNs, and prefill↔decode consistency (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, list_archs, shape_is_applicable
from repro.models import Model, input_specs

# Long-running training/serving smoke tests: excluded from the tier-1
# CI lane via -m "not slow" (see tests/conftest.py and .github/workflows).
pytestmark = pytest.mark.slow

ARCHS = list_archs()
KEY = jax.random.PRNGKey(0)


def _make_inputs(cfg, b, s, key=KEY):
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    extra = {}
    if cfg.vision is not None:
        extra["patch_embeds"] = jax.random.normal(
            key, (b, cfg.vision.num_patches, cfg.d_model), jnp.bfloat16
        )
    if cfg.is_enc_dec:
        extra["frames"] = jax.random.normal(
            key, (b, cfg.encdec.encoder_frames, cfg.d_model), jnp.bfloat16
        )
    return tokens, extra


@pytest.mark.parametrize("arch", ARCHS)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = get_config(arch, smoke=True)
        m = Model(cfg)
        params = m.init(KEY)
        tokens, extra = _make_inputs(cfg, 2, 16)
        logits, aux = m.apply(params, tokens, **extra)
        s_out = 16 + (cfg.vision.num_patches if cfg.vision else 0)
        assert logits.shape == (2, s_out, cfg.vocab_padded)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    def test_one_train_step_decreases_loss_direction(self, arch):
        """One SGD step on the smoke config must produce finite grads and
        change the loss (sanity of the whole backward path)."""
        cfg = get_config(arch, smoke=True)
        m = Model(cfg)
        params = m.init(KEY)
        tokens, extra = _make_inputs(cfg, 2, 16)
        labels = jnp.roll(tokens, -1, axis=1)

        def loss_fn(p):
            logits, aux = m.apply(p, tokens, **extra)
            lg = logits[:, -labels.shape[1] :, :].astype(jnp.float32)
            ll = jax.nn.log_softmax(lg, axis=-1)
            nll = -jnp.take_along_axis(ll, labels[..., None], axis=-1).mean()
            if "load_balance_loss" in aux:
                nll = nll + 0.01 * aux["load_balance_loss"]
            return nll

        loss, grads = jax.value_and_grad(loss_fn)(params)
        assert bool(jnp.isfinite(loss))
        flat = jax.tree.leaves(grads)
        assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat)
        gnorm = sum(float(jnp.sum(jnp.square(g))) for g in flat) ** 0.5
        assert gnorm > 0, "gradient must be nonzero"
        params2 = jax.tree.map(lambda p, g: p - 0.3 * g, params, grads)
        assert float(loss_fn(params2)) != float(loss)

    def test_prefill_then_decode_matches_forward(self, arch):
        """Greedy consistency: forward(tokens[: t+1]) logits at position t
        must equal prefill(tokens[:t]) + decode(token t)."""
        cfg = get_config(arch, smoke=True)
        m = Model(cfg)
        params = m.init(KEY)
        b, s = 2, 12
        tokens, extra = _make_inputs(cfg, b, s)
        full_logits, _ = m.apply(params, tokens, **extra)
        # prefill on the first s-1 tokens, then decode token s-1.
        # max_len covers the patch prefix for vlm archs.
        offset = cfg.vision.num_patches if cfg.vision else 0
        last, cache = m.prefill(
            params, tokens[:, : s - 1], max_len=offset + s + 4, **extra
        )
        np.testing.assert_allclose(
            np.asarray(last[:, 0], np.float32),
            np.asarray(full_logits[:, offset + s - 2], np.float32),
            rtol=2e-2,
            atol=2e-2,
        )
        step_logits, cache = m.decode(
            params, cache, tokens[:, s - 1 : s], jnp.asarray(offset + s - 1)
        )
        np.testing.assert_allclose(
            np.asarray(step_logits[:, 0], np.float32),
            np.asarray(full_logits[:, offset + s - 1], np.float32),
            rtol=2e-2,
            atol=2e-2,
        )

    def test_input_specs_cover_all_applicable_shapes(self, arch):
        cfg = get_config(arch)  # full config: specs only, no allocation
        for name, shape in SHAPES.items():
            if not shape_is_applicable(arch, name):
                continue
            specs = input_specs(cfg, shape)
            assert "tokens" in specs or "token" in specs
            for leaf in jax.tree.leaves(specs):
                assert isinstance(leaf, jax.ShapeDtypeStruct)

    def test_full_config_matches_assignment(self, arch):
        """The registered full config must carry the exact assigned dims."""
        assigned = {
            "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
            "deepseek-moe-16b": (28, 2048, 16, 16, 10944, 102400),
            "qwen2-moe-a2.7b": (24, 2048, 16, 16, 5632, 151936),
            "gemma2-9b": (42, 3584, 16, 8, 14336, 256000),
            "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
            "codeqwen1.5-7b": (32, 4096, 32, 32, 13440, 92416),
            "gemma-2b": (18, 2048, 8, 1, 16384, 256000),
            "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
            "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
            "falcon-mamba-7b": (64, 4096, 1, 1, 0, 65024),
        }
        cfg = get_config(arch)
        L, d, h, kv, ff, v = assigned[arch]
        assert cfg.num_layers == L and cfg.d_model == d
        assert cfg.num_heads == h and cfg.num_kv_heads == kv
        assert cfg.d_ff == ff and cfg.vocab_size == v


class TestArchSpecifics:
    def test_moe_expert_padding(self):
        cfg = get_config("qwen2-moe-a2.7b")
        assert cfg.moe.num_experts == 60 and cfg.moe.num_experts_padded == 64

    def test_moe_active_params_fraction(self):
        cfg = get_config("deepseek-moe-16b")
        assert cfg.active_params() / cfg.num_params() < 0.25

    def test_gemma2_alternating_pattern(self):
        cfg = get_config("gemma2-9b")
        kinds = cfg.layer_kinds()
        assert kinds[0] == "local" and kinds[1] == "global"
        assert len(kinds) == 42

    def test_recurrentgemma_ratio(self):
        kinds = get_config("recurrentgemma-9b").layer_kinds()
        assert kinds.count("recurrent") == 2 * kinds.count("local") + 2

    def test_long_context_applicability(self):
        assert shape_is_applicable("falcon-mamba-7b", "long_500k")
        assert shape_is_applicable("recurrentgemma-9b", "long_500k")
        for a in ["gemma2-9b", "qwen2.5-14b", "whisper-medium", "internvl2-26b"]:
            assert not shape_is_applicable(a, "long_500k")

    def test_vocab_padding_divisibility(self):
        for arch in ARCHS:
            assert get_config(arch).vocab_padded % 128 == 0

    def test_moe_identical_tokens_same_output(self):
        """Routing determinism: identical token rows route identically."""
        cfg = get_config("deepseek-moe-16b", smoke=True)
        m = Model(cfg)
        params = m.init(KEY)
        tokens = jnp.tile(jnp.arange(8)[None, :], (2, 1))
        logits, _ = m.apply(params, tokens)
        np.testing.assert_allclose(
            np.asarray(logits[0], np.float32),
            np.asarray(logits[1], np.float32),
            rtol=1e-4,
            atol=1e-4,
        )


class TestMoEDispatchCorrectness:
    def test_capacity_path_matches_dense_path_when_nothing_drops(self):
        """Regression: the sorted-dispatch gate weights must be permuted to
        sorted order. With a no-drop capacity factor, the capacity path and
        the exact dense path must agree token-for-token."""
        import dataclasses

        import jax
        import jax.numpy as jnp

        from repro.models.moe import (
            _moe_dense_path,
            _sorted_dispatch_compute,
            moe_init,
        )

        cfg = get_config("deepseek-moe-16b", smoke=True)
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0)
        )
        params = moe_init(jax.random.PRNGKey(0), cfg)
        t, d = 512, cfg.d_model
        xf = jax.random.normal(jax.random.PRNGKey(2), (t, d), jnp.float32)
        logits = xf @ params["router"]
        probs = jax.nn.softmax(logits, axis=-1)
        gv, ei = jax.lax.top_k(probs, cfg.moe.top_k)
        y_cap, dropped = _sorted_dispatch_compute(
            xf, probs, gv, ei, params["wi"], params["wo"], cfg
        )
        assert float(dropped) == 0.0
        y_dense, _ = _moe_dense_path(
            {k: v for k, v in params.items() if k != "shared"},
            xf,
            dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, shared_ff=0)
            ),
            probs,
            gv,
            ei,
            (1, t, d),
            False,
        )
        np.testing.assert_allclose(
            np.asarray(y_cap),
            np.asarray(y_dense.reshape(t, d)),
            rtol=2e-4,
            atol=2e-4,
        )
