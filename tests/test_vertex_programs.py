"""Algorithm correctness against networkx oracles (paper §IV tasks)."""
import collections

import networkx as nx
import numpy as np
import pytest

from repro.core import INF_DEPTH, bfs, pagerank, scc, sssp, wcc
from repro.graph.generators import erdos_renyi, ring, rmat, star
from repro.graph.preprocess import degree_and_densify


def _graph(n, m, seed):
    src, dst = erdos_renyi(n, m, seed=seed)
    el = degree_and_densify(src, dst, drop_self_loops=True)
    G = nx.DiGraph()
    G.add_nodes_from(range(el.n))
    G.add_edges_from(zip(el.src.tolist(), el.dst.tolist()))
    return el, G


def _partition_of(labels):
    groups = collections.defaultdict(set)
    for v, l in enumerate(labels):
        groups[int(l)].add(v)
    return set(map(frozenset, groups.values()))


class TestPageRank:
    @pytest.mark.parametrize("seed,P", [(0, 1), (1, 4), (2, 7)])
    def test_matches_networkx(self, seed, P):
        el, G = _graph(150, 600, seed)
        res = pagerank(el, P=P, iters=100, tol=1e-12)
        want = nx.pagerank(G, alpha=0.85, max_iter=300, tol=1e-13)
        got = res.output
        err = max(abs(got[v] - want[v]) for v in range(el.n))
        assert err < 1e-6

    def test_sums_to_one(self):
        el, _ = _graph(100, 400, 5)
        res = pagerank(el, P=4, iters=50, tol=1e-12)
        assert res.output.sum() == pytest.approx(1.0, abs=1e-4)

    def test_dangling_mass(self):
        # star: all leaves are dangling; mass must be redistributed.
        el = degree_and_densify(*star(20))
        res = pagerank(el, P=2, iters=80, tol=1e-13)
        G = nx.DiGraph()
        G.add_nodes_from(range(el.n))
        G.add_edges_from(zip(el.src.tolist(), el.dst.tolist()))
        want = nx.pagerank(G, alpha=0.85)
        err = max(abs(res.output[v] - want[v]) for v in range(el.n))
        assert err < 1e-6

    def test_fixed_iters_and_convergence_flag(self):
        el, _ = _graph(100, 500, 6)
        res = pagerank(el, P=4, iters=5, tol=0.0)
        assert res.iterations == 5 and not res.converged
        res2 = pagerank(el, P=4, iters=500, tol=1e-10)
        assert res2.converged


class TestBFS:
    @pytest.mark.parametrize("seed,P", [(0, 1), (3, 4), (4, 8)])
    def test_depths_match(self, seed, P):
        el, G = _graph(200, 700, seed)
        root = int(el.src[0])
        res = bfs(el, root=root, P=P)
        want = nx.single_source_shortest_path_length(G, root)
        got = np.asarray(res.attrs)
        for v in range(el.n):
            w = want.get(v)
            g = int(got[v]) if got[v] < INF_DEPTH else None
            assert w == g, f"vertex {v}: nx={w} ours={g}"

    def test_output_is_max_finite_depth(self):
        # Paper Algorithm 4.
        el = degree_and_densify(*ring(10))
        res = bfs(el, root=0, P=2)
        assert res.output == 9

    def test_unreachable_stays_inf(self):
        src = np.array([0, 2])
        dst = np.array([1, 3])
        el = degree_and_densify(src, dst)
        root = int(el.index_to_id(np.array([0]))[0])
        res = bfs(el, root=root, P=2)
        inf_count = int((np.asarray(res.attrs) >= INF_DEPTH).sum())
        assert inf_count == 2  # the 2-3 component

    def test_activity_skips_blocks(self):
        """BFS on a long ring must not touch every sub-shard every iteration."""
        el = degree_and_densify(*ring(64))
        res = bfs(el, root=0, P=8)
        total_blocks_if_dense = res.iterations * 8 * 8
        assert res.meters.blocks_skipped > 0
        assert res.meters.blocks_processed < total_blocks_if_dense


class TestWCC:
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_partition_matches(self, seed):
        el, G = _graph(150, 300, seed)
        res = wcc(el, P=4)
        want = set(map(frozenset, nx.weakly_connected_components(G)))
        assert _partition_of(np.asarray(res.attrs)) == want

    def test_min_label_is_component_min(self):
        el, G = _graph(100, 150, 9)
        res = wcc(el, P=4)
        labels = np.asarray(res.attrs)
        for comp in nx.weakly_connected_components(G):
            assert {int(labels[v]) for v in comp} == {min(comp)}


class TestSSSP:
    def test_weighted_shortest_paths(self):
        rng = np.random.default_rng(0)
        src, dst = erdos_renyi(80, 400, seed=7)
        w = rng.uniform(0.1, 2.0, size=len(src)).astype(np.float32)
        el = degree_and_densify(src, dst, weights=w, drop_self_loops=True)
        G = nx.DiGraph()
        G.add_nodes_from(range(el.n))
        for s, d, ww in zip(el.src.tolist(), el.dst.tolist(), el.weights):
            G.add_edge(s, d, weight=float(ww))
        root = 0
        res = sssp(el, root=root, P=4)
        want = nx.single_source_dijkstra_path_length(G, root)
        got = np.asarray(res.attrs)
        for v in range(el.n):
            if v in want:
                assert got[v] == pytest.approx(want[v], rel=1e-5)
            else:
                assert np.isinf(got[v])


class TestSCC:
    @pytest.mark.parametrize("seed,n,m", [(0, 60, 150), (1, 100, 260), (2, 150, 450)])
    def test_partition_matches(self, seed, n, m):
        el, G = _graph(n, m, seed)
        labels = scc(el, P=4)
        want = set(map(frozenset, nx.strongly_connected_components(G)))
        assert _partition_of(labels) == want

    def test_ring_is_one_scc(self):
        el = degree_and_densify(*ring(12))
        labels = scc(el, P=3)
        assert len(set(labels.tolist())) == 1

    def test_dag_is_all_singletons(self):
        src = np.array([0, 1, 2, 0, 1])
        dst = np.array([1, 2, 3, 2, 3])
        el = degree_and_densify(src, dst)
        labels = scc(el, P=2)
        assert len(set(labels.tolist())) == el.n
