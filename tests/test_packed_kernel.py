"""The fused Pallas kernel backend (``execution="packed_kernel"``) parity suite.

The kernel path's acceptance contract is the same strict one the packed
scan passed in tests/test_packed_sweep.py, now three-way: for every
native schedule (SPU/DPU/MPU), every program family (float-sum /
int-min / weighted float-min), every residency (device / host / disk)
and both activity modes, interpret-mode kernel results must be
**bit-identical** and the model ``Meters`` **field-identical** to both
``per_block`` and ``packed`` — while actually dispatching the fused
``pallas_call`` (never the scan, never the per-block primitives).

The kernel reproduces the scan's floating-point fold orders exactly
(ascending-edge-order windowed sum fold, ascending-run-order hub
scatter; see ``kernels/packed_sweep.py``), which is what makes bitwise —
not approximate — equality the right assertion.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (
    BFS,
    ExecutionPlan,
    GraphSession,
    PageRank,
    SSSP,
    build_dsss,
)
from repro.core import session as session_mod
from repro.core.vertex_programs import MaxLabelForward
from repro.graph.generators import erdos_renyi
from repro.graph.preprocess import degree_and_densify
from repro.storage import write_dsss

STRATEGIES = ["spu", "dpu", "mpu"]

# (label, program factory, plan kwargs, weighted) — PageRank exercises the
# float-sum semiring (where the kernel's fold order must match the scan's
# association exactly), BFS the monotone int-min path with activity
# skipping, SSSP the weighted float-min path.
PROGRAMS = [
    ("pagerank", PageRank, dict(max_iters=6, tol=0.0), True),
    ("bfs", BFS, dict(max_iters=100, program_kwargs={"root": 0}), False),
    ("sssp", SSSP, dict(max_iters=100, program_kwargs={"root": 0}), True),
]

MODEL_FIELDS = session_mod.MODEL_METER_FIELDS

BUDGET = 720  # forces streaming + a strict 0 < Q < P MPU split
HOST_BUDGET = 3000  # partial host cache: some tile chunks hit disk


def _graph(n=150, m=900, seed=0, P=5, weighted=False):
    src, dst = erdos_renyi(n, m, seed=seed)
    w = None
    if weighted:
        rng = np.random.default_rng(seed)
        w = rng.uniform(0.1, 2.0, size=len(src)).astype(np.float32)
    el = degree_and_densify(src, dst, weights=w, drop_self_loops=True)
    return build_dsss(el, P)


def _meters_dict(meters, model_only=False):
    d = dataclasses.asdict(meters)
    d.pop("wall_seconds")
    if model_only:
        d = {k: v for k, v in d.items() if k in MODEL_FIELDS}
    return d


def _assert_equivalent(ref, kern, model_only=False):
    np.testing.assert_array_equal(ref.attrs, kern.attrs)
    assert ref.iterations == kern.iterations
    assert ref.converged == kern.converged
    assert _meters_dict(ref.meters, model_only) == _meters_dict(
        kern.meters, model_only
    )


@pytest.fixture(scope="module")
def staged(tmp_path_factory):
    """One weighted + one unweighted graph, each with a .dsss store."""
    out = {}
    for weighted in (False, True):
        g = _graph(seed=3, weighted=weighted)
        path = str(
            tmp_path_factory.mktemp("kstore") / f"g{int(weighted)}.dsss"
        )
        write_dsss(g, path)
        out[weighted] = (g, path)
    return out


def _session(staged, weighted, residency):
    g, path = staged[weighted]
    if residency == "disk":
        return GraphSession.open(
            path, memory_budget=BUDGET, host_memory_budget=HOST_BUDGET
        )
    return GraphSession(g, memory_budget=BUDGET, residency=residency)


@pytest.mark.parametrize("label,prog_cls,kwargs,weighted", PROGRAMS)
@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("residency", ["device", "host", "disk"])
@pytest.mark.parametrize("activity", ["auto", "off"])
def test_three_way_parity(
    staged, label, prog_cls, kwargs, weighted, strategy, residency, activity
):
    sess = _session(staged, weighted, residency)
    if strategy == "mpu":
        choice = sess.compile(ExecutionPlan(prog_cls(), strategy="mpu")).choice
        assert 0 < choice.Q < sess.graph.P, "budget must exercise the hub split"

    def run(execution):
        return sess.run(
            ExecutionPlan(
                prog_cls(), strategy=strategy, execution=execution,
                activity=activity, **kwargs,
            )
        )

    pb, pk, kn = run("per_block"), run("packed"), run("packed_kernel")
    # vs per_block: model meters always agree; physical fields describe
    # different data paths (per-block streams blocks, packed streams tile
    # chunks), so they are compared model-only off-device.
    _assert_equivalent(pb, kn, model_only=residency != "device")
    # vs packed: same tile streaming/selective machinery drives both, so
    # under every residency even the physical fields must coincide.
    _assert_equivalent(pk, kn)


def test_kernel_path_actually_runs(monkeypatch):
    """``packed_kernel`` must dispatch the fused kernel executable — never
    the scan sweep, never the per-block primitives — once per update sweep
    on device."""
    g = _graph(seed=5)
    sess = GraphSession(g)

    def boom(*a, **kw):
        raise AssertionError("wrong executable dispatched in kernel mode")

    monkeypatch.setattr(session_mod, "_block_gather_reduce", boom)
    monkeypatch.setattr(session_mod, "_block_to_hub", boom)
    monkeypatch.setattr(session_mod, "_block_from_hub", boom)
    monkeypatch.setattr(session_mod, "_apply_interval", boom)
    # The scan sweep must not run either: the apply executable is shared,
    # so poison only the sweep half of _packed_jits.
    real_packed = session_mod._packed_jits

    def scan_poisoned(donate):
        _, apply_all = real_packed(donate)
        return boom, apply_all

    monkeypatch.setattr(session_mod, "_packed_jits", scan_poisoned)

    calls = []
    real_kernel = session_mod._packed_kernel_jits

    def counting(donate):
        sweep = real_kernel(donate)

        def counted(*a, **kw):
            calls.append(1)
            return sweep(*a, **kw)

        return counted

    monkeypatch.setattr(session_mod, "_packed_kernel_jits", counting)
    res = sess.run(
        ExecutionPlan(
            PageRank(), strategy="spu", max_iters=3, tol=0.0,
            execution="packed_kernel",
        )
    )
    assert res.iterations == 3
    assert len(calls) == 3  # one fused-kernel dispatch per update sweep


def test_auto_resolution_tracks_backend():
    """auto → the kernel only where Pallas compiles natively; explicit
    "packed_kernel" is honored everywhere; fused/custom downgrade."""
    import jax

    from repro.kernels.dsss_spmv import default_interpret

    g = _graph(seed=1)
    sess = GraphSession(g)
    auto = sess.resolved_execution("spu", "device")
    if default_interpret():
        assert jax.default_backend() != "tpu"
        assert auto == "packed"
    else:
        assert auto == "packed_kernel"
    assert sess.resolved_execution("spu", "device", "packed_kernel") == (
        "packed_kernel"
    )
    assert sess.resolved_execution("fused", "device", "packed_kernel") == (
        "per_block"
    )
    compiled = sess.compile(
        ExecutionPlan(PageRank(), strategy="dpu", execution="packed_kernel")
    )
    assert compiled.execution == "packed_kernel"


def test_src_sorted_subshard_tiles_parity():
    """src_sorted graphs force subshard packing; the kernel's windowed
    fold has no slot-ordering assumption (unlike dsss_spmv's one-hot
    window), so parity must hold on their scrambled-run tiles too."""
    el = degree_and_densify(*erdos_renyi(80, 400, seed=1), drop_self_loops=True)
    g = build_dsss(el, 4, src_sorted=True)
    sess = GraphSession(g)
    assert sess.packing == "subshard"
    plan = dict(strategy="spu", max_iters=4, tol=0.0)
    pk = sess.run(ExecutionPlan(PageRank(), execution="packed", **plan))
    kn = sess.run(ExecutionPlan(PageRank(), execution="packed_kernel", **plan))
    _assert_equivalent(pk, kn)


def test_batched_queries_and_stacked_aux():
    """K>1 fused batches run the kernel vmap-free (the query axis is a
    grid dimension): differing BFS roots (per-query attrs) and differing
    MaxLabelForward masks (vmap-stacked per-query aux) both stay
    bit-identical to the scan backend."""
    g = _graph(seed=7)
    sess = GraphSession(g)

    def batch(prog_factory, kwargs_list, **plan_kw):
        out = {}
        for exe in ("packed", "packed_kernel"):
            out[exe] = sess.run_batch(
                [
                    ExecutionPlan(
                        prog_factory(), execution=exe,
                        program_kwargs=kw, **plan_kw,
                    )
                    for kw in kwargs_list
                ]
            )
        assert out["packed"].fused and out["packed_kernel"].fused
        for a, b in zip(out["packed"].results, out["packed_kernel"].results):
            _assert_equivalent(a, b)

    batch(BFS, [{"root": r} for r in (0, 7, 33)], strategy="dpu")
    rng = np.random.default_rng(0)
    batch(
        MaxLabelForward,
        [{"mask": rng.random(g.n) < 0.5} for _ in range(3)],
        strategy="mpu",
        max_iters=30,
    )


def test_ppr_batch_kernel_parity():
    """Personalized PageRank point queries (differing reset vectors →
    vmap-stacked aux) fuse and match the scan backend bitwise."""
    g = _graph(seed=9)
    sess = GraphSession(g)
    seeds = (0, 5, 41)

    def plans(exe):
        return [
            ExecutionPlan(
                PageRank(), strategy="dpu", execution=exe, max_iters=15,
                tol=0.0, program_kwargs={"personalize": s},
            )
            for s in seeds
        ]

    bp = sess.run_batch(plans("packed"))
    bk = sess.run_batch(plans("packed_kernel"))
    assert bp.fused and bk.fused
    for a, b in zip(bp.results, bk.results):
        _assert_equivalent(a, b)


def test_invalid_execution_values_still_rejected():
    g = _graph(seed=1)
    with pytest.raises(ValueError, match="packed_kernel"):
        GraphSession(g, execution="kernel")
    with pytest.raises(ValueError, match="packed_kernel"):
        ExecutionPlan(PageRank(), execution="kernel")
    # and the new literal is accepted by both axes
    GraphSession(g, execution="packed_kernel")
    ExecutionPlan(PageRank(), execution="packed_kernel")
