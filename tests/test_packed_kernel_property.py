"""Property sweep: fused packed-sweep kernel vs the pure-jnp ref oracle.

Random destination-aligned tile layouts (arbitrary tile counts, window
widths, padding amounts, run structures), random attribute/aux values,
every reduce family and dtype in use, batched and activity-masked —
asserting **bitwise** equality of
:func:`repro.kernels.packed_sweep.packed_sweep_update` (interpret mode)
against :func:`repro.kernels.ref.packed_sweep_update_ref`. Bitwise, not
allclose: the kernel's claim is that it reproduces the segment-op fold
orders exactly, which is what lets the session swap executables without
perturbing a single result bit.
"""
import dataclasses
from typing import Any

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import BFS, PageRank, SSSP
from repro.core.identities import INF_DEPTH, reduce_identity
from repro.core.vertex_programs import MaxLabelForward, ReachBackward
from repro.kernels.packed_sweep import (
    packed_sweep_update,
    packed_sweep_update_select,
)
from repro.kernels.ref import packed_sweep_update_ref

PROGRAMS = ["pagerank", "bfs", "sssp", "max_label", "reach"]


def _random_tiles(rng, nt, t, n_pad, weighted):
    """A random but semantically coherent tile layout.

    Each tile holds a random number of destination runs; ``run_dst``
    carries the ``n_pad`` sentinel in unused slots and ``dst`` is derived
    from the run map, so dst-aux gathers see the same vertex the scatter
    folds into — the invariant real ``PackedSweep`` layouts guarantee.
    """
    src = rng.integers(0, n_pad, (nt, t)).astype(np.int32)
    run_local = np.zeros((nt, t), np.int32)
    run_dst = np.full((nt, t), n_pad, np.int32)
    for i in range(nt):
        u = int(rng.integers(1, t + 1))
        run_dst[i, :u] = rng.integers(0, n_pad, u)
        run_local[i] = np.sort(rng.integers(0, u, t))
    dst = np.take_along_axis(run_dst, run_local, axis=1)
    tiles = {
        "src": jnp.asarray(src),
        "dst": jnp.asarray(dst),
        "run_local": jnp.asarray(run_local),
        "run_dst": jnp.asarray(run_dst),
        "e_valid": jnp.asarray(rng.integers(0, t + 1, nt).astype(np.int32)),
    }
    if weighted:
        tiles["weights"] = jnp.asarray(
            (rng.random((nt, t)) + 0.1).astype(np.float32)
        )
    return tiles


def _program_case(name, rng, n_pad, k, aux_batched):
    """(program, attrs, aux, weighted) for one program family."""

    def vert(f):
        shape = (k,) + (n_pad,) if aux_batched else (n_pad,)
        return jnp.asarray(f(shape))

    if name == "pagerank":
        prog = PageRank()
        attrs = (rng.random((k, n_pad)) + 0.05).astype(np.float32)
        aux = {
            "inv_out_degree": vert(
                lambda s: rng.random(s).astype(np.float32)
            ),
            "dangling": vert(
                lambda s: (rng.random(s) < 0.2).astype(np.float32)
            ),
            "inv_n": (
                jnp.asarray(rng.random(k).astype(np.float32))
                if aux_batched
                else jnp.asarray(np.float32(rng.random()))
            ),
        }
        return prog, attrs, aux, True
    if name == "bfs":
        attrs = rng.integers(0, 20, (k, n_pad)).astype(np.int32)
        attrs[rng.random((k, n_pad)) < 0.3] = INF_DEPTH
        return BFS(), attrs, {}, False
    if name == "sssp":
        attrs = (rng.random((k, n_pad)) * 10).astype(np.float32)
        attrs[rng.random((k, n_pad)) < 0.3] = np.inf
        return SSSP(), attrs, {}, True
    if name == "max_label":
        attrs = rng.integers(-5, 50, (k, n_pad)).astype(np.int32)
        aux = {"mask": vert(lambda s: rng.integers(0, 2, s).astype(np.int32))}
        return MaxLabelForward(), attrs, aux, False
    # reach: exercises needs_dst_aux (gather reads destination-side aux)
    attrs = rng.integers(0, 2, (k, n_pad)).astype(np.int32)
    aux = {
        "mask": vert(lambda s: rng.integers(0, 2, s).astype(np.int32)),
        "color": vert(lambda s: rng.integers(0, 4, s).astype(np.int32)),
    }
    return ReachBackward(), attrs, aux, False


@settings(max_examples=25, deadline=None)
@given(
    nt=st.integers(1, 5),
    t=st.integers(1, 48),
    p=st.integers(1, 6),
    isz=st.integers(1, 24),
    k=st.integers(1, 3),
    name=st.sampled_from(PROGRAMS),
    aux_batched=st.booleans(),
    seed=st.integers(0, 10_000),
)
def test_kernel_bitwise_matches_ref(nt, t, p, isz, k, name, aux_batched, seed):
    rng = np.random.default_rng(seed)
    n_pad = p * isz
    prog, attrs, aux, weighted = _program_case(name, rng, n_pad, k, aux_batched)
    if aux_batched and not aux:
        aux_batched = False  # nothing to batch
    tiles = _random_tiles(rng, nt, t, n_pad, weighted)
    row_active = jnp.asarray(rng.random(p) < 0.8)
    attrs = jnp.asarray(attrs)
    ident = reduce_identity(prog.reduce, prog.dtype)
    acc = jnp.full((k, n_pad), ident, prog.dtype)
    got = packed_sweep_update(
        prog, attrs, acc, aux, tiles, row_active,
        has_weights=weighted, aux_batched=aux_batched, interpret=True,
    )
    want = packed_sweep_update_ref(
        prog, attrs, acc, aux, tiles, row_active,
        has_weights=weighted, aux_batched=aux_batched,
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=10, deadline=None)
@given(
    nt=st.integers(2, 6),
    t=st.integers(1, 32),
    seed=st.integers(0, 10_000),
)
def test_select_frontend_matches_full_sweep_on_active_tiles(nt, t, seed):
    """The compaction frontend == running only the active tiles in order
    (ascending idx, zeroed padding) — same contract as the scan's
    ``_packed_sweep_select_impl``."""
    rng = np.random.default_rng(seed)
    p, isz = 4, 8
    n_pad = p * isz
    prog, attrs, aux, weighted = _program_case("pagerank", rng, n_pad, 1, False)
    tiles = _random_tiles(rng, nt, t, n_pad, weighted)
    row_active = jnp.ones(p, bool)
    attrs = jnp.asarray(attrs)
    acc = jnp.zeros((1, n_pad), prog.dtype)
    active = rng.random(nt) < 0.6
    local = np.flatnonzero(active)
    if local.size == 0:
        return
    bucket = max(1, 1 << (int(local.size) - 1).bit_length())
    idx = np.zeros(bucket, np.int32)
    idx[: local.size] = local
    got = packed_sweep_update_select(
        prog, attrs, acc, aux, tiles,
        jnp.asarray(idx), jnp.asarray(np.int32(local.size)), row_active,
        has_weights=weighted, interpret=True,
    )
    compact = {key: v[local] for key, v in tiles.items()}
    want = packed_sweep_update_ref(
        prog, attrs, acc, aux, compact, row_active, has_weights=weighted
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
