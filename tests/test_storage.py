"""The on-disk DSSS store and the disk residency tier.

Covers the repro.storage contract end to end without hypothesis (the
randomized layout-equivalence sweep lives in
tests/test_storage_property.py):

* write → open round-trips every engine-facing artifact (graph arrays,
  padded host blocks, the stored PackedSweep) as zero-copy mmap views;
* the external-memory build produces a layout-identical container —
  including through the bounded k-way merge path — while its allocation
  ledger stays within ~2× the chunk budget;
* ``residency="disk"`` is bit-identical to device/host with
  field-identical model meters, and ``Meters.bytes_disk_read`` matches
  the ``disk_read_bytes`` / ``packed_disk_bytes`` closed forms exactly
  under the three-level budget;
* corruption (bit flip, truncation) fails checksums instead of computing
  garbage; the CLI builds, describes and verifies containers.
"""
import dataclasses
import os

import numpy as np
import pytest

from repro.core import (
    BFS,
    ExecutionPlan,
    GraphSession,
    PageRank,
    build_dsss,
    disk_read_bytes,
    packed_disk_bytes,
)
from repro.core.session import MODEL_METER_FIELDS, _host_block_nbytes
from repro.graph.generators import erdos_renyi
from repro.graph.preprocess import degree_and_densify
from repro.storage import (
    ChecksumError,
    FormatError,
    build_dsss_file,
    open_dsss,
    verify_dsss,
    write_dsss,
)
from repro.storage.__main__ import main as storage_cli


def _raw_edges(n=150, m=900, seed=3, weighted=True, with_dirt=True):
    src, dst = erdos_renyi(n, m, seed=seed)
    if with_dirt:  # duplicates + self loops must round through identically
        src = np.concatenate([src, src[:40], np.arange(8)])
        dst = np.concatenate([dst, dst[:40], np.arange(8)])
    w = None
    if weighted:
        rng = np.random.default_rng(seed)
        w = rng.uniform(0.1, 2.0, size=len(src)).astype(np.float32)
    return src, dst, w


def _graph(P=5, **kw):
    src, dst, w = _raw_edges(**kw)
    el = degree_and_densify(src, dst, weights=w, drop_self_loops=True)
    return build_dsss(el, P)


def assert_store_matches_graph(store, g):
    """Layout-for-layout: store views ≡ in-memory arrays (values + dtypes)."""
    g2 = store.graph()
    assert (g2.n, g2.m, g2.P, g2.interval_size) == (g.n, g.m, g.P, g.interval_size)
    assert g2.src_sorted == g.src_sorted
    for f in (
        "src", "dst", "weights", "offsets", "out_degree", "in_degree",
        "hub_dst_flat", "hub_inv_flat", "hub_offsets",
    ):
        a, b = getattr(g, f), getattr(g2, f)
        if a is None:
            assert b is None, f
            continue
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=f)
        assert np.asarray(a).dtype == np.asarray(b).dtype, f
    np.testing.assert_array_equal(
        np.asarray(g.edgelist.id_to_index), np.asarray(g2.edgelist.id_to_index)
    )
    hb, hb2 = g.host_blocks(), store.host_blocks()
    assert set(hb) == set(hb2)
    for k in hb:
        for leaf in ("src_local", "dst_local", "hub_inv", "hub_dst", "weights"):
            if hb[k][leaf] is None:
                assert hb2[k][leaf] is None
                continue
            np.testing.assert_array_equal(
                hb[k][leaf], hb2[k][leaf], err_msg=f"{k}:{leaf}"
            )
            assert hb[k][leaf].dtype == hb2[k][leaf].dtype
        for sc in ("e", "u", "u_bucket"):
            assert hb[k][sc] == hb2[k][sc], (k, sc)
    pk, pk2 = g.packed_sweep("adaptive"), store.packed()
    for f in dataclasses.fields(pk):
        a, b = getattr(pk, f.name), getattr(pk2, f.name)
        if isinstance(a, np.ndarray):
            np.testing.assert_array_equal(a, np.asarray(b), err_msg=f.name)
            assert a.dtype == np.asarray(b).dtype, f.name
        elif a is None:
            assert b is None, f.name
        else:
            assert a == b, f.name


class TestContainer:
    def test_write_open_roundtrip(self, tmp_path):
        g = _graph()
        store = write_dsss(g, str(tmp_path / "g.dsss"))
        assert_store_matches_graph(store, g)
        # mmap promise: the big views are file-backed, not RAM copies
        assert isinstance(store.array("src"), np.memmap)
        blk = next(iter(store.host_blocks().values()))
        assert isinstance(blk["src_local"].base, np.memmap) or isinstance(
            blk["src_local"], np.memmap
        )

    def test_unweighted_and_single_interval(self, tmp_path):
        g = _graph(P=1, weighted=False)
        store = write_dsss(g, str(tmp_path / "p1.dsss"))
        assert_store_matches_graph(store, g)

    def test_verify_detects_bit_flip(self, tmp_path):
        g = _graph(weighted=False)
        path = str(tmp_path / "g.dsss")
        store = write_dsss(g, path)
        seg = store.segments["p_src"]
        with open(path, "r+b") as f:
            f.seek(seg.offset + 3)
            byte = f.read(1)
            f.seek(seg.offset + 3)
            f.write(bytes([byte[0] ^ 0xFF]))
        with pytest.raises(ChecksumError, match="p_src"):
            verify_dsss(path)
        # the default session open verifies — corruption cannot reach
        # execution as garbage results
        with pytest.raises(ChecksumError):
            GraphSession.open(path)

    def test_truncation_fails_loudly(self, tmp_path):
        g = _graph(weighted=False)
        path = str(tmp_path / "g.dsss")
        write_dsss(g, path)
        size = os.path.getsize(path)
        with open(path, "r+b") as f:
            f.truncate(size - 64)
        with pytest.raises(FormatError):
            open_dsss(path)


class TestExternalBuild:
    def _chunks(self, src, dst, w, step=97):
        def factory():
            for lo in range(0, len(src), step):
                if w is None:
                    yield src[lo : lo + step], dst[lo : lo + step]
                else:
                    yield (
                        src[lo : lo + step],
                        dst[lo : lo + step],
                        w[lo : lo + step],
                    )

        return factory

    @pytest.mark.parametrize("weighted", [False, True])
    def test_matches_in_memory_pipeline(self, tmp_path, weighted):
        src, dst, w = _raw_edges(weighted=weighted)
        el = degree_and_densify(src, dst, weights=w, drop_self_loops=True)
        g = build_dsss(el, 5)
        out = str(tmp_path / "ext.dsss")
        stats = build_dsss_file(
            self._chunks(src, dst, w), out, 5,
            chunk_budget=1 << 20, drop_self_loops=True,
        )
        assert stats.m == g.m and stats.n == g.n
        assert_store_matches_graph(open_dsss(out, verify=True), g)

    def test_streamed_merge_path_identical(self, tmp_path):
        # A budget far below every bucket forces the k-way heapq merge.
        src, dst, w = _raw_edges(weighted=True)
        el = degree_and_densify(src, dst, weights=w, drop_self_loops=True)
        g = build_dsss(el, 2)
        out = str(tmp_path / "ext_stream.dsss")
        stats = build_dsss_file(
            self._chunks(src, dst, w), out, 2,
            chunk_budget=4096, drop_self_loops=True,
        )
        assert stats.streamed_buckets > 0, "tiny budget must exercise the merge"
        assert_store_matches_graph(open_dsss(out, verify=True), g)

    def test_bounded_memory_contract(self, tmp_path):
        # An edge list an order of magnitude past the chunk budget: the
        # ledger's peak resident edge-array bytes must stay ~within 2x.
        rng = np.random.default_rng(0)
        n, m = 3000, 60_000
        src = rng.integers(0, n, size=m)
        dst = rng.integers(0, n, size=m)
        budget = 96 * 1024
        raw_bytes = src.nbytes + dst.nbytes
        assert raw_bytes > 5 * budget, "the input must dwarf the budget"
        out = str(tmp_path / "big.dsss")
        stats = build_dsss_file(
            self._chunks(src, dst, None, step=20_000), out, 8,
            chunk_budget=budget, drop_self_loops=True,
        )
        assert stats.peak_edge_bytes <= 2.05 * budget, (
            f"peak {stats.peak_edge_bytes} exceeds 2x chunk budget {budget}"
        )
        verify_dsss(out)  # and the result is a sound container
        el = degree_and_densify(src, dst, drop_self_loops=True)
        assert_store_matches_graph(open_dsss(out), build_dsss(el, 8))


class TestCLI:
    def test_build_info_verify(self, tmp_path, capsys):
        src, dst, _ = _raw_edges(weighted=False, with_dirt=False)
        txt = tmp_path / "edges.txt"
        with open(txt, "w") as f:
            f.write("# snap-style header\n")
            for a, b in zip(src, dst):
                f.write(f"{a} {b}\n")
        out = str(tmp_path / "cli.dsss")
        assert storage_cli(["build", str(txt), out, "--P", "4",
                            "--drop-self-loops"]) == 0
        assert storage_cli(["info", out]) == 0
        assert storage_cli(["verify", out]) == 0
        printed = capsys.readouterr().out
        assert "OK" in printed and "segments" in printed
        # layout equals the in-memory pipeline over the same text input
        el = degree_and_densify(src, dst, drop_self_loops=True)
        assert_store_matches_graph(open_dsss(out), build_dsss(el, 4))
        # corrupt -> verify exits non-zero
        store = open_dsss(out)
        seg = store.segments["src"]
        with open(out, "r+b") as f:
            f.seek(seg.offset)
            f.write(b"\xff\xff\xff\xff")
        assert storage_cli(["verify", out]) == 1


# Shared staging for the residency matrix (module-scoped: the store and
# sessions are read-only across tests).
@pytest.fixture(scope="module")
def staged(tmp_path_factory):
    g = _graph()
    path = str(tmp_path_factory.mktemp("store") / "g.dsss")
    write_dsss(g, path)
    return g, path


BUDGET = 720  # forces streaming + a strict 0 < Q < P MPU split (see
# tests/test_packed_sweep.py) for both attribute widths
HOST_BUDGET = 3000  # partial host cache: some blocks/chunks hit disk


def _model(meters):
    d = dataclasses.asdict(meters)
    return {k: v for k, v in d.items() if k in MODEL_METER_FIELDS}


class TestDiskResidency:
    @pytest.mark.parametrize("strategy", ["spu", "dpu", "mpu"])
    @pytest.mark.parametrize("execution", ["per_block", "packed"])
    def test_bit_identity_and_closed_form(self, staged, strategy, execution):
        g, path = staged
        plan = ExecutionPlan(
            PageRank(), strategy=strategy, max_iters=4, tol=0.0,
            execution=execution,
        )
        dev = GraphSession(g, memory_budget=BUDGET, residency="device").run(plan)
        host = GraphSession(g, memory_budget=BUDGET, residency="host").run(plan)
        sess = GraphSession.open(
            path, memory_budget=BUDGET, host_memory_budget=HOST_BUDGET,
        )
        assert sess.resolved_residency() == "disk"
        disk = sess.run(plan)
        np.testing.assert_array_equal(dev.attrs, disk.attrs)
        np.testing.assert_array_equal(host.attrs, disk.attrs)
        assert _model(dev.meters) == _model(host.meters) == _model(disk.meters)
        # physical: disk ships the same bytes to the device as host mode
        assert host.meters.bytes_h2d == disk.meters.bytes_h2d
        assert dev.meters.bytes_disk_read == 0
        assert host.meters.bytes_disk_read == 0
        # ... and its disk traffic matches the closed form exactly
        compiled = sess.compile(plan)
        iters = disk.meters.iterations
        if execution == "per_block":
            nbytes = {
                k: _host_block_nbytes(h) for k, h in sess.host_blocks.items()
            }
            expect = disk_read_bytes(
                nbytes, compiled.resident, compiled.host_cached
            )
        else:
            splan = sess.packed_stream_plan(
                compiled.choice.strategy, compiled.params.Ba
            )
            expect = packed_disk_bytes(
                splan.num_tiles - splan.pin_tiles - splan.host_tiles,
                splan.tile_edges,
                weighted=sess.has_weights,
            )
        assert disk.meters.bytes_disk_read == expect * iters
        assert disk.meters.bytes_disk_read > 0

    def test_unlimited_host_cache_absorbs_disk_traffic(self, staged):
        _, path = staged
        sess = GraphSession.open(path, memory_budget=BUDGET)
        res = sess.run(
            ExecutionPlan(
                PageRank(), strategy="dpu", max_iters=2, tol=0.0,
                execution="per_block",
            )
        )
        assert res.meters.bytes_disk_read == 0
        assert res.meters.bytes_h2d > 0  # still streamed host->device

    def test_monotone_program_on_disk(self, staged):
        g, path = staged
        plan = ExecutionPlan(
            BFS(), strategy="mpu", max_iters=100,
            program_kwargs={"root": 0},
        )
        host = GraphSession(g, memory_budget=BUDGET, residency="host").run(plan)
        disk = GraphSession.open(
            path, memory_budget=BUDGET, host_memory_budget=0
        ).run(plan)
        np.testing.assert_array_equal(host.attrs, disk.attrs)
        assert _model(host.meters) == _model(disk.meters)
        assert disk.meters.bytes_disk_read > 0

    def test_disk_requires_store(self, staged):
        g, path = staged
        with pytest.raises(ValueError, match="disk"):
            GraphSession(g, residency="disk")
        sess = GraphSession(g)
        with pytest.raises(ValueError, match="disk"):
            sess.run(ExecutionPlan(PageRank(), max_iters=1, residency="disk"))

    def test_disk_session_supports_other_residencies(self, staged):
        g, path = staged
        sess = GraphSession.open(path, memory_budget=BUDGET)
        plan = ExecutionPlan(
            PageRank(), strategy="spu", max_iters=2, tol=0.0,
            residency="host", execution="per_block",
        )
        ref = GraphSession(g, memory_budget=BUDGET, residency="host").run(plan)
        got = sess.run(plan)
        np.testing.assert_array_equal(ref.attrs, got.attrs)
        assert got.meters.bytes_disk_read == 0  # host override: no disk charge
