"""Shared PackedSweep layout invariants.

One implementation of the tile-layout contract, used by the deterministic
suite (tests/test_packed_sweep.py) and the hypothesis property suite
(tests/test_packed_tiling_property.py) so the two cannot drift when the
schema changes.
"""
import numpy as np


def check_layout(g, packed):
    """Assert every invariant any packing mode must satisfy.

    * Exact coverage: the tiles' real edges are the flat DSSS edge stream,
      in stream order (``row_offset`` partitions ``[0, m)``).
    * Run integrity: global hub slots partition tile-contiguously — no
      (sub-shard, destination) run is ever split across tiles — and
      ``run_local`` reproduces the windowed global slots.
    * ``run_dst`` maps every real run to its global destination and every
      padded slot to the ``n_pad`` drop sentinel.
    * Per-tile interval metadata matches the first edge.
    """
    e_valid = packed.e_valid
    srcs = np.concatenate(
        [packed.src[t, :e] for t, e in enumerate(e_valid)]
    ) if packed.num_tiles else np.zeros(0, np.int32)
    dsts = np.concatenate(
        [packed.dst[t, :e] for t, e in enumerate(e_valid)]
    ) if packed.num_tiles else np.zeros(0, np.int32)
    np.testing.assert_array_equal(srcs, g.src)
    np.testing.assert_array_equal(dsts, g.dst)
    if packed.weights is not None:
        ws = np.concatenate([packed.weights[t, :e] for t, e in enumerate(e_valid)])
        np.testing.assert_array_equal(ws, g.weights)
    assert int(e_valid.sum()) == g.m == packed.m
    np.testing.assert_array_equal(
        packed.row_offset, np.concatenate([[0], np.cumsum(e_valid)[:-1]])
    )
    np.testing.assert_array_equal(
        packed.base_slot[1:], packed.base_slot[:-1] + packed.u[:-1]
    )
    if packed.num_tiles:
        assert packed.base_slot[0] == 0
        assert packed.base_slot[-1] + packed.u[-1] == g.hub_offsets[-1, -1]
    gslot = g.global_hub_slots()
    isz = g.interval_size
    for t, e in enumerate(e_valid):
        lo = packed.row_offset[t]
        np.testing.assert_array_equal(
            packed.run_local[t, :e].astype(np.int64) + packed.base_slot[t],
            gslot[lo : lo + e],
        )
        assert packed.run_local[t, :e].max(initial=0) < packed.u[t]
        np.testing.assert_array_equal(
            packed.run_dst[t, packed.run_local[t, :e]], packed.dst[t, :e]
        )
        assert (packed.run_dst[t, packed.u[t] :] == g.n_pad).all()
        i, j = packed.src_interval[t], packed.dst_interval[t]
        assert i == packed.src[t, 0] // isz and j == packed.dst[t, 0] // isz
