"""Layer unit tests: recurrences vs naive loops, caches, norms, rope."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.attention import attn_apply, attn_decode, attn_init, init_kv_cache
from repro.models.attention import chunked_attention, _plain_attention
from repro.models.layers import rmsnorm, rmsnorm_init, rope
from repro.models.rglru import init_rglru_state, rglru_apply, rglru_decode, rglru_init
from repro.models.ssm import init_mamba_state, mamba_apply, mamba_decode, mamba_init

KEY = jax.random.PRNGKey(0)


class TestRMSNorm:
    def test_unit_variance(self):
        p = rmsnorm_init(64)
        x = jax.random.normal(KEY, (4, 64)) * 10
        y = rmsnorm(p, x)
        rms = jnp.sqrt(jnp.mean(jnp.square(y), axis=-1))
        np.testing.assert_allclose(rms, 1.0, rtol=1e-3)


class TestRoPE:
    def test_preserves_norm(self):
        x = jax.random.normal(KEY, (2, 8, 4, 32))
        pos = jnp.arange(8)
        y = rope(x, pos)
        np.testing.assert_allclose(
            jnp.linalg.norm(y, axis=-1), jnp.linalg.norm(x, axis=-1), rtol=1e-5
        )

    def test_relative_property(self):
        """<rope(q,m), rope(k,n)> depends only on m-n."""
        q = jax.random.normal(KEY, (1, 1, 1, 32))
        k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 32))
        def dot(m, n):
            qm = rope(q, jnp.array([m]))
            kn = rope(k, jnp.array([n]))
            return float(jnp.sum(qm * kn))
        assert dot(3, 1) == pytest.approx(dot(10, 8), rel=1e-4)
        assert dot(5, 5) == pytest.approx(dot(0, 0), rel=1e-4)


class TestRGLRU:
    def test_scan_matches_naive_loop(self):
        cfg = get_config("recurrentgemma-9b", smoke=True)
        p = rglru_init(KEY, cfg)
        b, s = 2, 10
        u = jax.random.normal(KEY, (b, s, cfg.d_model), jnp.float32)
        full = rglru_apply(p, u, cfg)
        # naive: step through decode one token at a time
        state = init_rglru_state(cfg, b, jnp.float32)
        outs = []
        for t in range(s):
            y, state = rglru_decode(p, u[:, t : t + 1], state, cfg)
            outs.append(y)
        naive = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(full), np.asarray(naive), rtol=2e-4, atol=2e-4)

    def test_decay_in_unit_interval(self):
        cfg = get_config("recurrentgemma-9b", smoke=True)
        p = rglru_init(KEY, cfg)
        from repro.models.rglru import _gates
        x = jax.random.normal(KEY, (1, 5, cfg.rglru.lru_width or cfg.d_model))
        a, _ = _gates(p, x)
        assert bool(jnp.all((a > 0) & (a < 1)))


class TestMamba:
    def test_chunked_scan_matches_naive_loop(self):
        cfg = get_config("falcon-mamba-7b", smoke=True)
        p = mamba_init(KEY, cfg)
        b, s = 2, 9  # not a multiple of CHUNK: exercises padding masks
        u = jax.random.normal(KEY, (b, s, cfg.d_model), jnp.float32)
        full, state_full = mamba_apply(p, u, cfg, return_state=True)
        state = init_mamba_state(cfg, b, jnp.float32)
        outs = []
        for t in range(s):
            y, state = mamba_decode(p, u[:, t : t + 1], state, cfg)
            outs.append(y)
        naive = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(full), np.asarray(naive), rtol=2e-3, atol=2e-3)
        # final states must agree too (prefill -> decode handoff)
        np.testing.assert_allclose(
            np.asarray(state_full["ssm"]), np.asarray(state["ssm"]), rtol=2e-3, atol=2e-3
        )
        np.testing.assert_allclose(
            np.asarray(state_full["conv"]), np.asarray(state["conv"]), rtol=2e-3, atol=2e-3
        )

    def test_long_sequence_chunk_boundary(self):
        from repro.models.ssm import CHUNK
        cfg = get_config("falcon-mamba-7b", smoke=True)
        p = mamba_init(KEY, cfg)
        u = jax.random.normal(KEY, (1, CHUNK + 3, cfg.d_model), jnp.float32)
        y = mamba_apply(p, u, cfg)
        assert y.shape == (1, CHUNK + 3, cfg.d_model)
        assert bool(jnp.all(jnp.isfinite(y)))


class TestAttentionPaths:
    def test_chunked_equals_plain(self):
        cfg = get_config("gemma2-9b", smoke=True)
        p = attn_init(KEY, cfg)
        x = jax.random.normal(KEY, (2, 40, cfg.d_model), jnp.float32)
        pos = jnp.arange(40)
        y_plain, _ = attn_apply(p, x, cfg, pos, kind="global")
        q, k, v = None, None, None
        # force chunked path via a tiny chunk size
        object.__setattr__(cfg, "attn_impl", "chunked")
        object.__setattr__(cfg, "attn_chunk", 16)
        y_chunk, _ = attn_apply(p, x, cfg, pos, kind="global")
        np.testing.assert_allclose(
            np.asarray(y_plain), np.asarray(y_chunk), rtol=2e-4, atol=2e-4
        )

    def test_local_ring_cache_decode_matches_full(self):
        """Decode with the O(window) ring cache must equal full attention."""
        cfg = get_config("recurrentgemma-9b", smoke=True)  # window 32
        p = attn_init(KEY, cfg)
        b, s = 1, 50  # exceeds the window: ring wraps
        x = jax.random.normal(KEY, (b, s, cfg.d_model), jnp.float32)
        pos = jnp.arange(s)
        full, _ = attn_apply(p, x, cfg, pos, kind="local")
        cache = init_kv_cache(cfg, b, s + 8, kind="local", dtype=jnp.float32)
        outs = []
        for t in range(s):
            y, cache = attn_decode(
                p, x[:, t : t + 1], {"kv": cache}["kv"], cfg, jnp.asarray(t), kind="local"
            )
            outs.append(y)
        naive = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(
            np.asarray(full), np.asarray(naive), rtol=3e-4, atol=3e-4
        )

    def test_gqa_heads_see_right_kv(self):
        """With distinct kv heads, permuting them must change the output
        (guards against silent kv-head broadcast bugs)."""
        cfg = get_config("gemma2-9b", smoke=True)  # kv=2, q=4
        p = attn_init(KEY, cfg)
        x = jax.random.normal(KEY, (1, 8, cfg.d_model), jnp.float32)
        pos = jnp.arange(8)
        y1, _ = attn_apply(p, x, cfg, pos, kind="global")
        p2 = dict(p)
        p2["wk"] = p["wk"][:, ::-1, :]
        p2["wv"] = p["wv"][:, ::-1, :]
        y2, _ = attn_apply(p2, x, cfg, pos, kind="global")
        assert float(jnp.max(jnp.abs(y1 - y2))) > 1e-5
