"""Shared pytest configuration.

Two jobs:

1. The property-test modules need ``hypothesis``, which is not part of the
   runtime environment everywhere. When it is absent, skip *collecting*
   those five modules instead of erroring the whole run (install
   ``requirements-dev.txt`` to run them).
2. Register the ``slow`` marker used by the long-running training/serving
   smoke tests, so CI can run ``-m "not slow"`` under a wall-clock budget.
"""
import importlib.util

collect_ignore = []
if importlib.util.find_spec("hypothesis") is None:
    collect_ignore += [
        "test_dsss.py",
        "test_engine_strategies.py",
        "test_kernels_dsss_spmv.py",
        "test_kernels_flash_attention.py",
        "test_substrate.py",
    ]


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running training/serving smoke tests (deselect with -m 'not slow')",
    )
