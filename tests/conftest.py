"""Shared pytest configuration.

Two jobs:

1. The property-test modules need ``hypothesis``, which is not part of the
   runtime environment everywhere. When it is absent, skip *collecting*
   those modules instead of erroring the whole run (install
   ``requirements-dev.txt`` to run them; CI runs them all in a dedicated
   property lane, see .github/workflows/ci.yml).
2. Register the ``slow`` marker used by the long-running training/serving
   smoke tests, so CI can run ``-m "not slow"`` under a wall-clock budget,
   and the ``chaos`` marker for the fault-injection/recovery matrix
   (``pytest -m chaos`` is CI's dedicated reliability lane).
"""
import importlib.util

# Keep in sync with the `property` job in .github/workflows/ci.yml.
PROPERTY_TEST_MODULES = [
    "test_dsss.py",
    "test_engine_strategies.py",
    "test_iomodel_property.py",
    "test_kernels_dsss_spmv.py",
    "test_kernels_flash_attention.py",
    "test_packed_kernel_property.py",
    "test_packed_tiling_property.py",
    "test_reliability_property.py",
    "test_residency_property.py",
    "test_selective_property.py",
    "test_storage_property.py",
    "test_substrate.py",
]

collect_ignore = []
if importlib.util.find_spec("hypothesis") is None:
    collect_ignore += PROPERTY_TEST_MODULES


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running training/serving smoke tests (deselect with -m 'not slow')",
    )
    config.addinivalue_line(
        "markers",
        "chaos: fault-injection / crash-resume / degraded-read recovery matrix "
        "(select with -m chaos)",
    )
