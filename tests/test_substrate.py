"""Substrate tests: optimizer, schedules, data, checkpoint, sharding rules."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import CheckpointManager
from repro.data import SyntheticLM, SyntheticLMConfig
from repro.optim import (
    Adafactor,
    AdamW,
    clip_by_global_norm,
    compress_for_sync,
    cosine_with_warmup,
    decompress_after_sync,
    global_norm,
    linear_warmup,
)
from repro.sharding.rules import (
    LOGICAL_RULES,
    batch_spec,
    param_logical_axes,
    param_specs,
    spec_for,
)


class TestAdamW:
    def test_quadratic_convergence(self):
        """AdamW must drive a quadratic to its minimum."""
        opt = AdamW(learning_rate=0.1)
        params = {"w": jnp.asarray([5.0, -3.0])}
        state = opt.init(params)
        target = jnp.asarray([1.0, 2.0])
        for _ in range(200):
            grads = {"w": 2 * (params["w"] - target)}
            params, state = opt.update(grads, state, params)
        np.testing.assert_allclose(params["w"], target, atol=1e-2)

    def test_matches_reference_formula(self):
        """One step against a hand-computed Adam update."""
        opt = AdamW(learning_rate=0.01, b1=0.9, b2=0.999, eps=1e-8)
        p = {"w": jnp.asarray([1.0])}
        g = {"w": jnp.asarray([0.5])}
        state = opt.init(p)
        new_p, _ = opt.update(g, state, p)
        mhat = 0.1 * 0.5 / (1 - 0.9)
        vhat = 0.001 * 0.25 / (1 - 0.999)
        want = 1.0 - 0.01 * mhat / (np.sqrt(vhat) + 1e-8)
        np.testing.assert_allclose(new_p["w"], [want], rtol=1e-5)

    def test_weight_decay_masked_for_vectors(self):
        opt = AdamW(learning_rate=0.0, weight_decay=0.0)  # no-op update
        # nonzero lr + wd: vectors (ndim<=1) skip decay by default
        opt = AdamW(learning_rate=0.1, weight_decay=0.5)
        p = {"mat": jnp.ones((2, 2)), "vec": jnp.ones((2,))}
        g = jax.tree.map(jnp.zeros_like, p)
        state = opt.init(p)
        new_p, _ = opt.update(g, state, p)
        assert float(new_p["mat"][0, 0]) < 1.0  # decayed
        assert float(new_p["vec"][0]) == 1.0  # not decayed


class TestAdafactor:
    def test_quadratic_convergence(self):
        opt = Adafactor(learning_rate=0.2)
        params = {"w": jnp.full((4, 3), 5.0)}
        state = opt.init(params)
        for _ in range(300):
            grads = {"w": 2 * params["w"]}
            params, state = opt.update(grads, state, params)
        assert float(jnp.abs(params["w"]).max()) < 0.3

    def test_memory_factored(self):
        opt = Adafactor()
        p = {"w": jnp.zeros((128, 64))}
        state = opt.init(p)
        n_acc = sum(x.size for x in jax.tree.leaves(state["acc"]))
        assert n_acc == 128 + 64  # vr + vc, not 128*64


class TestSchedulesClipping:
    def test_warmup_then_cosine(self):
        f = cosine_with_warmup(1.0, warmup_steps=10, total_steps=100)
        assert float(f(jnp.asarray(0))) == 0.0
        assert float(f(jnp.asarray(10))) == pytest.approx(1.0)
        assert float(f(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-3)

    def test_clip_by_global_norm(self):
        g = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert float(norm) == pytest.approx(5.0)
        assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)

    def test_clip_noop_below_threshold(self):
        g = {"a": jnp.asarray([0.3])}
        clipped, _ = clip_by_global_norm(g, 1.0)
        np.testing.assert_allclose(clipped["a"], g["a"])


class TestGradCompression:
    def test_roundtrip_dtype(self):
        g = {"w": jnp.ones((4,), jnp.float32) * 1.5}
        c = compress_for_sync(g, "compressed_bf16")
        assert c["w"].dtype == jnp.bfloat16
        d = decompress_after_sync(c, "compressed_bf16")
        assert d["w"].dtype == jnp.float32

    def test_none_is_identity(self):
        g = {"w": jnp.ones((4,))}
        assert compress_for_sync(g, "none") is g


class TestSyntheticData:
    def test_deterministic_per_step(self):
        cfg = SyntheticLMConfig(vocab_size=100, seq_len=16, global_batch=4)
        ds = SyntheticLM(cfg)
        b1, b2 = ds.batch(7), ds.batch(7)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        b3 = ds.batch(8)
        assert not np.array_equal(b1["tokens"], b3["tokens"])

    def test_labels_are_shifted_tokens(self):
        ds = SyntheticLM(SyntheticLMConfig(100, 16, 2))
        b = ds.batch(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_host_sharding_partitions_batch(self):
        full = SyntheticLM(SyntheticLMConfig(100, 8, 4, num_hosts=1))
        h0 = SyntheticLM(SyntheticLMConfig(100, 8, 4, num_hosts=2, host_id=0))
        assert h0.local_batch == 2 and full.local_batch == 4

    def test_structure_learnable(self):
        """Markov structure: successor entropy must be far below uniform."""
        ds = SyntheticLM(SyntheticLMConfig(vocab_size=50, seq_len=64, global_batch=8))
        b = ds.batch(0)
        pairs = set()
        for row in b["tokens"]:
            pairs.update(zip(row[:-1].tolist(), row[1:].tolist()))
        # with branch=4 + restarts, distinct successors per token ~ 4-8 « 50
        from collections import defaultdict

        succ = defaultdict(set)
        for a, b_ in pairs:
            succ[a].add(b_)
        mean_branch = np.mean([len(v) for v in succ.values()])
        assert mean_branch < 15


class TestCheckpointManager:
    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        state = {"a": jnp.arange(5), "b": {"c": jnp.ones((2, 2))}}
        mgr.save(3, state)
        like = jax.tree.map(jnp.zeros_like, state)
        restored, step = mgr.restore(like)
        assert step == 3
        np.testing.assert_array_equal(restored["a"], state["a"])
        np.testing.assert_array_equal(restored["b"]["c"], state["b"]["c"])

    def test_keep_n_gc(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
        state = {"a": jnp.zeros(1)}
        for s in [1, 2, 3, 4]:
            mgr.save(s, state)
        assert mgr.all_steps() == [3, 4]

    def test_async_save_then_restore(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_save=True)
        state = {"a": jnp.arange(10)}
        mgr.save(1, state)
        mgr.wait()
        restored, _ = mgr.restore(jax.tree.map(jnp.zeros_like, state))
        np.testing.assert_array_equal(restored["a"], state["a"])

    def test_structure_mismatch_raises(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        mgr.save(1, {"a": jnp.zeros(3)})
        with pytest.raises(ValueError):
            mgr.restore({"a": jnp.zeros(3), "b": jnp.zeros(1)})


class TestShardingRules:
    def _mesh(self, shape=(2, 4), axes=("data", "model")):
        # abstract mesh-like shim: spec_for only reads mesh.shape
        class M:
            pass

        m = M()
        m.shape = dict(zip(axes, shape))
        return m

    def test_divisible_axes_kept(self):
        mesh = self._mesh()
        spec = spec_for(("vocab", "embed"), (128, 64), mesh)
        assert spec == jax.sharding.PartitionSpec("model", "data")

    def test_non_divisible_axis_dropped(self):
        mesh = self._mesh((2, 16))
        # 40 heads % 16 != 0 -> heads falls back to replicated
        spec = spec_for(("embed", "heads", "head_dim"), (5120, 40, 128), mesh)
        assert spec[1] is None

    def test_axis_never_used_twice(self):
        mesh = self._mesh((2, 4))
        spec = spec_for(("vocab", "mlp"), (128, 64), mesh)
        # both want "model"; only the first gets it
        assert spec[0] == "model" and spec[1] is None

    def test_param_pattern_lookup(self):
        axes = param_logical_axes("params/blocks/0/attn/wq", (18, 2048, 8, 256))
        assert axes == ("layers", "embed", "heads", "head_dim")
        axes = param_logical_axes("mu/embed", (256128, 2048))
        assert axes == ("vocab", "embed")
        axes = param_logical_axes("params/pre/0/moe/wi", (64, 2048, 2816))
        assert axes == ("experts", "embed", "expert_mlp")

    @settings(max_examples=30, deadline=None)
    @given(
        dim=st.integers(1, 4096),
        data=st.sampled_from([1, 2, 4, 16]),
        model=st.sampled_from([1, 2, 4, 16]),
    )
    def test_property_spec_always_valid(self, dim, data, model):
        """Any dim × any mesh: kept axes' product divides the dim."""
        mesh = self._mesh((data, model))
        spec = spec_for(("vocab",), (dim,), mesh)
        if spec[0] is not None:
            axes = spec[0] if isinstance(spec[0], tuple) else (spec[0],)
            prod = int(np.prod([mesh.shape[a] for a in axes]))
            assert dim % prod == 0
