"""Property tests for frontier-aware selective execution (the tentpole).

Contract under test (see ``core/session.py`` and ``core/iomodel.py``):

1. **Bit-identity** — for monotone programs (BFS / SSSP / WCC),
   ``activity="auto"`` produces bit-identical attributes, outputs and
   iteration counts to ``activity="off"`` across strategy ∈
   {spu, dpu, mpu} × execution ∈ {per_block, packed} × residency ∈
   {device, host}: skipped work contributes exact ⊕-identities, never a
   different fold order.
2. **Meter exactness** — the physical byte meters of a selective run are
   reconstructed *exactly* by the iomodel activity closed forms applied
   to the run's per-sweep ``activity_log``:
   ``packed_h2d_bytes(selective_streamed_tiles(...))`` for packed host
   streaming, ``streamed_block_bytes(..., active_rows)`` for per-block
   host streaming, ``selective_edge_bytes`` for the modelled slow-tier
   edge traffic. Model meters additionally agree across execution modes
   at the same activity setting (packed charges from metadata, per-block
   from the blocks it actually walks).
3. **Strict shrink** — once the frontier narrows below a full sweep,
   physical transfers are strictly smaller than the ``activity="off"``
   baseline (given the layout is skippable at all: more than one
   streamed chunk and per-tile spans narrower than the whole range).

The deterministic companions live in tests/test_selective_and_bugfixes.py
(tier-1) and the disk tier is exercised below on a concrete ``.dsss``
store (disk chunk skipping uses the ``pin+host_tiles`` boundary).
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    BFS,
    ExecutionPlan,
    GraphSession,
    SSSP,
    WCC,
    build_dsss,
)
from repro.core.iomodel import (
    packed_h2d_bytes,
    selective_edge_bytes,
    selective_streamed_tiles,
    streamed_block_bytes,
)
from repro.core.session import MODEL_METER_FIELDS
from repro.core.session import _host_block_nbytes
from repro.graph.generators import erdos_renyi, ring
from repro.graph.preprocess import degree_and_densify

PROGRAMS = {
    "bfs": lambda: (BFS(), {"root": 0}),
    "sssp": lambda: (SSSP(), {"root": 0}),
    "wcc": lambda: (WCC(), {}),
}


def _graph(seed, P, n=100, m=450):
    src, dst = erdos_renyi(n, m, seed=seed)
    el = degree_and_densify(src, dst, drop_self_loops=True)
    return build_dsss(el, P)


def _path_graph(n, P):
    src, dst = ring(n)
    el = degree_and_densify(src[:-1], dst[:-1])  # directed path
    return build_dsss(el, P)


def _budget(g, frac):
    return int((2 * g.n_pad * 8 + g.m * 8) * frac)


def _run_pair(g, prog, kw, *, strategy, execution, residency, budget):
    """(selective result, off result) on independent sessions."""
    results = []
    for activity in ("auto", "off"):
        sess = GraphSession(g, memory_budget=budget, residency=residency)
        results.append(
            sess.run(
                ExecutionPlan(
                    prog,
                    strategy=strategy,
                    max_iters=g.n + 1,
                    execution=execution,
                    activity=activity,
                    program_kwargs=kw,
                )
            )
        )
    return results


class TestSelectiveBitIdentity:
    @settings(max_examples=12, deadline=None)
    @given(
        seed=st.integers(0, 30),
        P=st.integers(1, 5),
        strategy=st.sampled_from(["spu", "dpu", "mpu"]),
        execution=st.sampled_from(["per_block", "packed"]),
        residency=st.sampled_from(["device", "host"]),
        prog_name=st.sampled_from(["bfs", "sssp", "wcc"]),
        frac=st.sampled_from([0.0, 0.4, 1.5]),
    )
    def test_selective_equals_off(
        self, seed, P, strategy, execution, residency, prog_name, frac
    ):
        g = _graph(seed, P)
        prog, kw = PROGRAMS[prog_name]()
        on, off = _run_pair(
            g, prog, kw,
            strategy=strategy, execution=execution, residency=residency,
            budget=_budget(g, frac) if residency == "host" else None,
        )
        np.testing.assert_array_equal(on.attrs, off.attrs)
        np.testing.assert_array_equal(on.output, off.output)
        assert on.iterations == off.iterations
        assert on.converged == off.converged
        # The selective run never streams *more* than the baseline.
        assert on.meters.bytes_h2d <= off.meters.bytes_h2d


class TestMeterExactness:
    @settings(max_examples=10, deadline=None)
    @given(
        seed=st.integers(0, 30),
        P=st.integers(2, 5),
        strategy=st.sampled_from(["spu", "dpu", "mpu"]),
        prog_name=st.sampled_from(["bfs", "sssp", "wcc"]),
        frac=st.sampled_from([0.0, 0.4]),
    )
    def test_packed_h2d_matches_closed_form(
        self, seed, P, strategy, prog_name, frac
    ):
        g = _graph(seed, P)
        prog, kw = PROGRAMS[prog_name]()
        budget = _budget(g, frac)
        sess = GraphSession(g, memory_budget=budget, residency="host")
        plan = ExecutionPlan(
            prog, strategy=strategy, max_iters=g.n + 1,
            execution="packed", program_kwargs=kw,
        )
        res = sess.run(plan)
        compiled = sess.compile(plan)
        assert compiled.activity == "selective"
        splan = sess.packed_stream_plan(compiled.choice.strategy, prog.attr_bytes)
        expected = sum(
            packed_h2d_bytes(
                selective_streamed_tiles(
                    sess._packed_tile_activity(log_s),
                    splan.pin_tiles,
                    splan.chunk_tiles,
                ),
                splan.tile_edges,
            )
            for log_s in res.activity_log
        )
        assert res.meters.bytes_h2d == expected

    @settings(max_examples=10, deadline=None)
    @given(
        seed=st.integers(0, 30),
        P=st.integers(2, 5),
        strategy=st.sampled_from(["spu", "dpu", "mpu"]),
        prog_name=st.sampled_from(["bfs", "sssp", "wcc"]),
        frac=st.sampled_from([0.0, 0.4]),
    )
    def test_per_block_h2d_and_edges_match_closed_forms(
        self, seed, P, strategy, prog_name, frac
    ):
        g = _graph(seed, P)
        prog, kw = PROGRAMS[prog_name]()
        budget = _budget(g, frac)
        sess = GraphSession(g, memory_budget=budget, residency="host")
        plan = ExecutionPlan(
            prog, strategy=strategy, max_iters=g.n + 1,
            execution="per_block", program_kwargs=kw,
        )
        res = sess.run(plan)
        compiled = sess.compile(plan)
        assert compiled.activity == "selective"
        nbytes = {k: _host_block_nbytes(h) for k, h in sess.host_blocks.items()}
        edges = {k: h["e"] for k, h in sess.host_blocks.items()}
        expected_h2d = sum(
            streamed_block_bytes(nbytes, compiled.resident, log_s)
            for log_s in res.activity_log
        )
        expected_edges = sum(
            selective_edge_bytes(edges, compiled.resident, log_s, sess.Be)
            for log_s in res.activity_log
        )
        assert res.meters.bytes_h2d == expected_h2d
        assert res.meters.bytes_read_edges == expected_edges

    @settings(max_examples=8, deadline=None)
    @given(
        seed=st.integers(0, 30),
        P=st.integers(1, 5),
        strategy=st.sampled_from(["spu", "dpu", "mpu"]),
        prog_name=st.sampled_from(["bfs", "sssp", "wcc"]),
        frac=st.sampled_from([0.0, 0.4, 1.5]),
    )
    def test_model_meters_agree_across_execution_modes(
        self, seed, P, strategy, prog_name, frac
    ):
        g = _graph(seed, P)
        prog, kw = PROGRAMS[prog_name]()
        budget = _budget(g, frac)
        runs = {}
        for execution in ("per_block", "packed"):
            sess = GraphSession(g, memory_budget=budget, residency="host")
            runs[execution] = sess.run(
                ExecutionPlan(
                    prog, strategy=strategy, max_iters=g.n + 1,
                    execution=execution, program_kwargs=kw,
                )
            )
        for field in MODEL_METER_FIELDS:
            assert getattr(runs["per_block"].meters, field) == getattr(
                runs["packed"].meters, field
            ), field


class TestStrictShrink:
    @pytest.mark.parametrize("execution", ["per_block", "packed"])
    def test_narrow_frontier_strictly_shrinks_stream(self, execution):
        # Directed path: the BFS frontier is a single interval almost
        # every sweep, and at n=1024 / P=8 each packed tile spans one
        # interval — every layout grain is skippable.
        g = _path_graph(1024, 8)
        on, off = _run_pair(
            g, BFS(), {"root": 0},
            strategy="spu", execution=execution, residency="host", budget=0,
        )
        np.testing.assert_array_equal(on.attrs, off.attrs)
        assert 0 < on.meters.bytes_h2d < off.meters.bytes_h2d
        # ≥5× on late-iteration-dominated runs is the acceptance bar for
        # this shape: 1022 of 1023 sweeps have a one-interval frontier.
        assert off.meters.bytes_h2d / on.meters.bytes_h2d >= 5.0

    def test_disk_tier_skips_chunk_reads(self, tmp_path):
        from repro.storage import write_dsss

        g = _path_graph(1024, 8)
        path = str(tmp_path / "g.dsss")
        write_dsss(g, path)
        runs = {}
        for activity in ("auto", "off"):
            sess = GraphSession.open(
                path, memory_budget=0, host_memory_budget=0
            )
            assert sess.resolved_residency() == "disk"
            runs[activity] = sess.run(
                ExecutionPlan(
                    BFS(), strategy="spu", max_iters=g.n + 1,
                    execution="packed", activity=activity,
                    program_kwargs={"root": 0},
                )
            )
        on, off = runs["auto"], runs["off"]
        np.testing.assert_array_equal(on.attrs, off.attrs)
        assert 0 < on.meters.bytes_disk_read < off.meters.bytes_disk_read
        assert 0 < on.meters.bytes_h2d < off.meters.bytes_h2d
