"""Graph text/binary I/O edge cases.

The chunked streaming text reader (comments, blank lines, CRLF, weights
column, configurable dtype, chunk boundaries) and the dtype-preservation
contract of ``save_edges`` / ``save_edgelist`` round-trips.
"""
import numpy as np
import pytest

from repro.graph.io import (
    iter_text_edges,
    load_edgelist,
    load_edges,
    load_text_edges,
    save_edgelist,
    save_edges,
)
from repro.graph.preprocess import EdgeList, degree_and_densify


class TestTextReader:
    def _write(self, path, payload: bytes):
        with open(path, "wb") as f:
            f.write(payload)
        return str(path)

    def test_comments_blanks_crlf_and_extra_columns(self, tmp_path):
        p = self._write(
            tmp_path / "e.txt",
            b"# header comment\r\n"
            b"1 2 0.5 extra tokens ignored\r\n"
            b"\r\n"
            b"   # indented comment\n"
            b"3\t4\t1.5\n"
            b"5 6 2.5",  # no trailing newline
        )
        src, dst = load_text_edges(p)
        np.testing.assert_array_equal(src, [1, 3, 5])
        np.testing.assert_array_equal(dst, [2, 4, 6])
        assert src.dtype == np.int64

    def test_weights_column_and_dtype(self, tmp_path):
        p = self._write(tmp_path / "w.txt", b"1 2 0.5\n3 4 1.5\n")
        src, dst, w = load_text_edges(p, weights=True, dtype=np.int32)
        assert src.dtype == np.int32 and w.dtype == np.float32
        np.testing.assert_allclose(w, [0.5, 1.5])

    def test_chunk_boundaries_cover_everything(self, tmp_path):
        lines = b"".join(b"%d %d\n" % (i, i + 1) for i in range(107))
        p = self._write(tmp_path / "c.txt", b"# head\n" + lines)
        chunks = list(iter_text_edges(p, chunk_edges=10))
        assert all(len(c[0]) <= 10 for c in chunks)
        src = np.concatenate([c[0] for c in chunks])
        dst = np.concatenate([c[1] for c in chunks])
        np.testing.assert_array_equal(src, np.arange(107))
        np.testing.assert_array_equal(dst, np.arange(107) + 1)
        # the one-shot loader agrees regardless of chunking
        s2, d2 = load_text_edges(p, chunk_edges=3)
        np.testing.assert_array_equal(s2, src)
        np.testing.assert_array_equal(d2, dst)

    def test_malformed_line_raises(self, tmp_path):
        p = self._write(tmp_path / "bad.txt", b"1 2\nonly_one_token\n")
        with pytest.raises(ValueError, match="malformed"):
            load_text_edges(p)
        p2 = self._write(tmp_path / "bad2.txt", b"1 2\n3 4\n")
        with pytest.raises(ValueError, match="malformed"):
            load_text_edges(p2, weights=True)  # missing third column

    def test_comment_only_file_is_empty(self, tmp_path):
        p = self._write(tmp_path / "empty.txt", b"# nothing\n\n# here\n")
        src, dst = load_text_edges(p)
        assert len(src) == 0 and len(dst) == 0
        assert src.dtype == np.int64
        assert list(iter_text_edges(p)) == []


class TestDtypePreservation:
    @pytest.mark.parametrize(
        "id_dtype,w_dtype",
        [
            (np.int32, np.float32),
            (np.int64, np.float64),
            (np.uint16, np.float16),
        ],
    )
    def test_save_edges_roundtrip(self, tmp_path, id_dtype, w_dtype):
        src = np.array([1, 2, 3], dtype=id_dtype)
        dst = np.array([4, 5, 6], dtype=id_dtype)
        w = np.array([0.5, 1.5, 2.5], dtype=w_dtype)
        p = str(tmp_path / "edges.npz")
        save_edges(p, src, dst, w)
        s2, d2, w2 = load_edges(p)
        for a, b in ((src, s2), (dst, d2), (w, w2)):
            np.testing.assert_array_equal(a, b)
            assert a.dtype == b.dtype, (a.dtype, b.dtype)

    def test_save_edgelist_preserves_attr_dtypes(self, tmp_path):
        el = degree_and_densify(
            np.array([0, 1, 7]), np.array([1, 7, 0]),
        )
        # a hand-built EdgeList with non-default weight dtype must not be
        # silently upcast/downcast through the container
        el64 = EdgeList(
            src=el.src, dst=el.dst, n=el.n,
            out_degree=el.out_degree, in_degree=el.in_degree,
            id_to_index=el.id_to_index,
            weights=np.array([1.0, 2.0, 3.0], dtype=np.float64),
        )
        p = str(tmp_path / "el.npz")
        save_edgelist(p, el64)
        back = load_edgelist(p)
        assert back.weights.dtype == np.float64
        assert back.src.dtype == el.src.dtype == np.int32
        assert back.id_to_index.dtype == np.int64
        assert back.out_degree.dtype == np.int32
        np.testing.assert_array_equal(back.src, el.src)
        np.testing.assert_array_equal(back.weights, el64.weights)
        assert back.n == el.n

    def test_unweighted_edgelist_roundtrip(self, tmp_path):
        el = degree_and_densify(np.array([0, 5]), np.array([5, 9]))
        p = str(tmp_path / "el0.npz")
        save_edgelist(p, el)
        back = load_edgelist(p)
        assert back.weights is None
        np.testing.assert_array_equal(back.in_degree, el.in_degree)
