"""End-to-end system behaviour tests (the paper's pipeline, whole-system).

Long-running classes carry ``pytest.mark.slow`` individually (not the whole
module), so the fast lane (``-m "not slow"``) keeps the cheap end-to-end
coverage — the pipeline, strategy equivalence, HLO analysis and the
distributed selftest all finish in seconds; only the 8-device dry-run
compile (~8 min) is deferred to the slow lane.
"""
import subprocess
import sys

import numpy as np
import pytest

from repro.core import (
    NXGraphEngine,
    PageRank,
    build_dsss,
    pagerank,
    select_strategy,
)
from repro.core.iomodel import IOParams
from repro.graph.generators import paper_dataset, rmat
from repro.graph.preprocess import degree_and_densify


class TestEndToEnd:
    def test_paper_pipeline_raw_edges_to_ranks(self):
        """Raw indices -> degreeing -> sharding -> adaptive engine -> output
        (the full §III pipeline), with rank mass conservation."""
        src, dst = rmat(11, edge_factor=8, seed=7)
        el = degree_and_densify(src, dst, drop_self_loops=True)
        g = build_dsss(el, 8)
        budget = int((2 * g.n_pad * 8 + g.m * 8) * 0.4)
        eng = NXGraphEngine(g, PageRank(), strategy="auto", memory_budget=budget)
        res = eng.run(max_iters=30, tol=1e-10)
        assert res.output.sum() == pytest.approx(1.0, abs=1e-3)
        assert res.meters.iterations == res.iterations
        # adaptive selection must match the closed-form decision
        want = select_strategy(eng.params, budget)
        assert eng.choice.strategy == want.strategy

    def test_all_strategies_one_command(self):
        src, dst = rmat(10, edge_factor=6, seed=3)
        el = degree_and_densify(src, dst, drop_self_loops=True)
        outs = {}
        for strategy in ["spu", "dpu", "mpu", "fused"]:
            outs[strategy] = pagerank(
                el, P=4, iters=10, strategy=strategy, memory_budget=10_000
            ).output
        base = outs.pop("spu")
        for k, v in outs.items():
            np.testing.assert_allclose(v, base, rtol=1e-5, atol=1e-8)

    def test_distributed_engine_selftest(self):
        """shard_map 2-D grid vs single-device engine (subprocess: needs
        forced host devices before jax init)."""
        out = subprocess.run(
            [sys.executable, "-m", "repro.core.distributed"],
            capture_output=True,
            text=True,
            env={
                "XLA_FLAGS": "--xla_force_host_platform_device_count=4",
                # Without an explicit platform, jax probes for accelerator
                # plugins (cloud-TPU metadata lookups) and can stall for
                # minutes in sandboxed environments.
                "JAX_PLATFORMS": "cpu",
                "PYTHONPATH": "src",
                "PATH": "/usr/bin:/bin",
            },
            cwd="/root/repo",
            timeout=600,
        )
        assert "selftest OK" in out.stdout, out.stdout + out.stderr


class TestHLOAnalysis:
    def test_collective_parser_on_synthetic_hlo(self):
        from repro.runtime.hlo_analysis import collective_bytes

        hlo = """
ENTRY %main (p: f32[8]) -> f32[8] {
  %ag = f32[1024,2]{1,0} all-gather(%x), replica_groups={}
  %ar.1 = bf16[512]{0} all-reduce-start(%y)
  %ar.2 = bf16[512]{0} all-reduce-done(%ar.1)
}
"""
        got = collective_bytes(hlo)
        assert got["all-gather"] == 1024 * 2 * 4
        assert got["all-reduce"] == 512 * 2  # start counted once

    def test_trip_count_weighting(self):
        from repro.runtime.hlo_loops import collective_bytes_weighted

        hlo = """
%cond (p: (s32[])) -> pred[] {
  %c = s32[] constant(10)
  ROOT %cmp = pred[] compare(%gte, %c), direction=LT
}

%body (p: (s32[])) -> (s32[]) {
  %ar = f32[100]{0} all-reduce(%z), replica_groups={}
  ROOT %t = (s32[]) tuple(%i)
}

ENTRY %main (p: s32[]) -> s32[] {
  %w = (s32[]) while(%t0), condition=%cond, body=%body
}
"""
        got = collective_bytes_weighted(hlo)
        assert got["all-reduce"] == 10 * 100 * 4  # ×trip count

    def test_analytic_cost_scales_with_tokens(self):
        from repro.configs import SHAPES, get_config
        from repro.runtime.analytic_cost import analytic_cost

        cfg = get_config("gemma-2b")
        train = analytic_cost(cfg, SHAPES["train_4k"])
        decode = analytic_cost(cfg, SHAPES["decode_32k"])
        assert train.flops_global > 1000 * decode.flops_global
        # train model flops = 6·N·T within definition
        t = 256 * 4096
        assert train.model_flops == pytest.approx(
            6.0 * cfg.active_params() * t
        )


@pytest.mark.slow
class TestSmallMeshDryrun:
    def test_train_cell_lowers_on_8_devices(self):
        """The dry-run machinery end-to-end on a small forced-device mesh
        (subprocess; the 512-device matrix runs via launch/dryrun.py)."""
        code = (
            "import os;"
            "os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=8';"
            "import jax;"
            "from repro.launch.mesh import make_mesh;"
            "from repro.launch.dryrun import lower_cell;"
            "mesh=make_mesh((4,2),('data','model'));"
            "cfg,lowered,chips=lower_cell('gemma-2b','train_4k',mesh,'test');"
            "c=lowered.compile();"
            "print('ok', c.memory_analysis().temp_size_in_bytes > 0)"
        )
        out = subprocess.run(
            [sys.executable, "-c", code],
            capture_output=True,
            text=True,
            env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
            cwd="/root/repo",
            timeout=900,
        )
        assert "ok True" in out.stdout, out.stdout[-500:] + out.stderr[-2000:]
