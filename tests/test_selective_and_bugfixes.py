"""Frontier-aware selective execution + the rode-along bugfix regressions.

Deterministic (tier-1) lane for this PR's contract:

* **Batched aux** — ``GraphSession._execute`` used to build the batch's
  aux arrays from query 0's kwargs alone (``make_aux(g, **kwargs_list[0])``),
  silently applying them to every query: a batch of ``MaxLabelForward``
  plans with different masks returned wrong labels for queries 1..K-1.
  Differing-but-stackable aux now runs vmapped with a leading query axis
  (and ``run_batch`` fuses such plans instead of falling back).
* **Kwarg validation** — unknown ``program_kwargs`` names used to be
  swallowed by the lifecycle methods' ``**kw`` catch-alls (a typo'd
  ``"rot"`` ran BFS from vertex 0); :class:`ExecutionPlan` now validates
  names against ``program.accepted_kwargs()`` at construction.
* **wcc driver** — the driver silently accepted an asymmetric
  :class:`DSSSGraph` (returning per-direction pseudo-components) and
  dropped the ``residency``/``execution`` axes every other driver plumbs.
* **Selective execution** — ``activity="auto"`` (default) must be
  bit-identical to ``activity="off"`` while strictly shrinking physical
  ``bytes_h2d`` once the frontier is narrower than the layout
  (the hypothesis lane, tests/test_selective_property.py, generalises
  this across the strategy × execution × residency grid).
"""
import numpy as np
import pytest

from repro.core import (
    BFS,
    ExecutionPlan,
    GraphSession,
    PageRank,
    build_dsss,
    wcc,
)
from repro.core.vertex_programs import MaxLabelForward
from repro.graph.generators import erdos_renyi, ring
from repro.graph.preprocess import degree_and_densify


def _graph(n=96, m=500, seed=0, P=4):
    src, dst = erdos_renyi(n, m, seed=seed)
    el = degree_and_densify(src, dst, drop_self_loops=True)
    return build_dsss(el, P)


# ---------------------------------------------------------------------------
# Bugfix 1: per-query aux in fused batches.
# ---------------------------------------------------------------------------
class TestBatchedAux:
    def _masks(self, g):
        full = np.ones(g.n_pad, np.int32)
        half = np.ones(g.n_pad, np.int32)
        half[g.n // 2 : g.n] = 0  # second half are spectators
        return full, half

    def test_execute_with_differing_aux_matches_individual_runs(self):
        # Regression: the old _execute applied query 0's mask to every
        # query, so query 1's labels leaked across its mask boundary.
        g = _graph()
        sess = GraphSession(g)
        full, half = self._masks(g)
        plan = ExecutionPlan(MaxLabelForward(), strategy="spu", max_iters=g.n + 1)
        batch = sess._execute(
            plan, [{"mask": full}, {"mask": half}]
        )
        assert batch.fused
        for mask, res in zip((full, half), batch.results):
            ref = sess.run(plan.with_kwargs(mask=mask))
            np.testing.assert_array_equal(res.attrs, ref.attrs)

    def test_run_batch_fuses_per_query_masks(self):
        # Stackable-but-differing aux now *fuses* (one streamed pass)
        # instead of silently downgrading to sequential runs.
        g = _graph(seed=1)
        sess = GraphSession(g)
        full, half = self._masks(g)
        plans = [
            ExecutionPlan(
                MaxLabelForward(),
                strategy="dpu",
                max_iters=g.n + 1,
                program_kwargs={"mask": m},
            )
            for m in (full, half)
        ]
        batch = sess.run_batch(plans)
        assert batch.fused
        for plan, res in zip(plans, batch.results):
            ref = sess.run(plan)
            np.testing.assert_array_equal(res.attrs, ref.attrs)

    def test_identical_aux_still_shared(self):
        g = _graph(seed=2)
        sess = GraphSession(g)
        plans = [
            ExecutionPlan(
                BFS(), strategy="spu", max_iters=g.n + 1,
                program_kwargs={"root": r},
            )
            for r in (0, 5)
        ]
        batch = sess.run_batch(plans)
        assert batch.fused
        for plan, res in zip(plans, batch.results):
            ref = sess.run(plan)
            np.testing.assert_array_equal(res.attrs, ref.attrs)


# ---------------------------------------------------------------------------
# Bugfix 2: unknown program_kwargs raise at plan construction.
# ---------------------------------------------------------------------------
class TestKwargValidation:
    def test_kwargless_program_rejects_any_kwarg(self):
        # WCC is the remaining kwargless program (PageRank grew
        # personalize/reset_dist); unknown names on a program with
        # accepted kwargs get the name-listing error instead.
        from repro.core.vertex_programs import WCC

        with pytest.raises(TypeError, match="accepts no program_kwargs"):
            ExecutionPlan(WCC(), program_kwargs={"root": 3})
        with pytest.raises(TypeError, match="accepted kwargs"):
            ExecutionPlan(PageRank(), program_kwargs={"root": 3})

    def test_typo_rejected_with_accepted_names(self):
        # Pre-fix this ran BFS silently from vertex 0.
        with pytest.raises(TypeError, match=r"rot.*root"):
            ExecutionPlan(BFS(), program_kwargs={"rot": 3})

    def test_known_kwargs_accepted(self):
        ExecutionPlan(BFS(), program_kwargs={"root": 3})
        ExecutionPlan(
            MaxLabelForward(),
            program_kwargs={"mask": np.ones(8, np.int32)},
        )

    def test_accepted_kwargs_harvest(self):
        assert PageRank().accepted_kwargs() == {"personalize", "reset_dist"}
        assert BFS().accepted_kwargs() == {"root"}
        assert MaxLabelForward().accepted_kwargs() == {"labels", "mask"}


# ---------------------------------------------------------------------------
# Bugfix 3: the wcc driver's symmetry contract and session axes.
# ---------------------------------------------------------------------------
class TestWCCDriver:
    def test_asymmetric_dsss_rejected(self):
        # Drop the ring's wrap edge → directed path 0→1→…→31, which has
        # in_degree != out_degree at the endpoints.
        src, dst = ring(32)
        el = degree_and_densify(src[:-1], dst[:-1])
        g = build_dsss(el, 4)
        with pytest.raises(ValueError, match="symmetrized"):
            wcc(g)

    def test_symmetrized_dsss_matches_edgelist_across_axes(self):
        src, dst = erdos_renyi(80, 200, seed=3)
        el = degree_and_densify(src, dst, drop_self_loops=True)
        ref = wcc(el, P=4)
        g_sym = build_dsss(el.symmetrized(), 4)
        for kw in (
            {},
            {"residency": "host", "memory_budget": 0},
            {"execution": "per_block"},
        ):
            res = wcc(g_sym, **kw)
            np.testing.assert_array_equal(res.attrs, ref.attrs)


# ---------------------------------------------------------------------------
# Tentpole smoke: selective ≡ off, with strictly fewer physical bytes.
# ---------------------------------------------------------------------------
class TestSelectiveExecution:
    def test_activity_axis_validated(self):
        with pytest.raises(ValueError, match="activity"):
            ExecutionPlan(BFS(), activity="sometimes")

    def test_selective_bit_identical_and_strictly_fewer_bytes(self):
        # A long directed path: the BFS frontier is one vertex per sweep,
        # so late sweeps touch exactly one interval out of P — streaming
        # must skip the rest.
        src, dst = ring(512)
        el = degree_and_densify(src[:-1], dst[:-1])  # path, no wrap
        g = build_dsss(el, 8)
        plan_kw = dict(max_iters=el.n + 1, program_kwargs={"root": 0})
        on_s = GraphSession(g, memory_budget=0, residency="host")
        off_s = GraphSession(g, memory_budget=0, residency="host")
        on = on_s.run(ExecutionPlan(BFS(), **plan_kw))
        off = off_s.run(ExecutionPlan(BFS(), activity="off", **plan_kw))
        np.testing.assert_array_equal(on.attrs, off.attrs)
        assert on.iterations == off.iterations
        assert 0 < on.meters.bytes_h2d < off.meters.bytes_h2d
        # The log shows a genuinely narrow frontier...
        assert any(log.sum() == 1 for log in on.activity_log)
        # ...and activity="off" records full sweeps.
        assert all(log.all() for log in off.activity_log)

    def test_non_monotone_programs_ignore_activity(self):
        g = _graph(seed=4)
        sess = GraphSession(g, memory_budget=0, residency="host")
        plan = ExecutionPlan(PageRank(), max_iters=3, tol=0.0)
        assert sess.compile(plan).activity == "off"
        res = sess.run(plan)
        assert all(log.all() for log in res.activity_log)

    def test_estimate_parts_sum_to_estimate(self):
        from repro.serving.server import (
            estimate_inflight_bytes,
            estimate_inflight_parts,
        )

        g = _graph(seed=5)
        sess = GraphSession(g, memory_budget=int(g.m * 12 * 0.5), residency="host")
        plan = ExecutionPlan(BFS(), max_iters=g.n + 1, program_kwargs={"root": 0})
        topo, attr = estimate_inflight_parts(sess, plan, 3)
        assert topo > 0 and attr > 0
        assert topo + attr == estimate_inflight_bytes(sess, plan, 3)
