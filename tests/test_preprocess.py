"""Degreeing-pass micro-invariants (no hypothesis — tier-1).

Pins the fused dedup + degree pass of :meth:`EdgeList.symmetrized`: the
decoded unique keys must equal the gather-based reference, the single
shared degree array must match an independent recomputation in both
directions, and weights must stay aligned with their surviving edge.
"""
import numpy as np

from repro.graph.generators import erdos_renyi, star
from repro.graph.preprocess import degree_and_densify


def _reference_symmetrized(el):
    """The pre-fusion implementation, kept as the oracle."""
    src = np.concatenate([el.src, el.dst])
    dst = np.concatenate([el.dst, el.src])
    key = src.astype(np.int64) * el.n + dst
    _, keep = np.unique(key, return_index=True)
    return src[keep], dst[keep], keep


def test_symmetrized_matches_gather_reference():
    el = degree_and_densify(*erdos_renyi(200, 1500, seed=3), drop_self_loops=True)
    sym = el.symmetrized()
    ref_src, ref_dst, _ = _reference_symmetrized(el)
    np.testing.assert_array_equal(sym.src, ref_src)
    np.testing.assert_array_equal(sym.dst, ref_dst)
    # Degrees: independently recomputed, and out == in (symmetric set).
    np.testing.assert_array_equal(
        sym.out_degree, np.bincount(sym.src, minlength=sym.n)
    )
    np.testing.assert_array_equal(
        sym.in_degree, np.bincount(sym.dst, minlength=sym.n)
    )
    np.testing.assert_array_equal(sym.out_degree, sym.in_degree)


def test_symmetrized_weights_stay_aligned():
    rng = np.random.default_rng(0)
    src, dst = erdos_renyi(60, 300, seed=1)
    w = rng.uniform(0.5, 2.0, size=len(src)).astype(np.float32)
    el = degree_and_densify(src, dst, weights=w, drop_self_loops=True)
    sym = el.symmetrized()
    ref_src, ref_dst, keep = _reference_symmetrized(el)
    w_doubled = np.concatenate([el.weights] * 2)
    np.testing.assert_array_equal(sym.weights, w_doubled[keep])
    assert len(sym.weights) == sym.m


def test_symmetrized_star_degrees():
    # Star: hub 0 -> n-1 leaves; symmetrized degree is n-1 at the hub and
    # 1 at every leaf, identically in both directions.
    el = degree_and_densify(*star(10))
    sym = el.symmetrized()
    assert sym.m == 18
    assert sym.out_degree[0] == sym.in_degree[0] == 9
    np.testing.assert_array_equal(sym.out_degree[1:], np.ones(9, np.int32))
