"""dsss_spmv Pallas kernel vs pure-jnp oracle: shape/dtype/semiring sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import PageRank, build_dsss
from repro.core.engine import NXGraphEngine
from repro.graph.generators import erdos_renyi, rmat
from repro.graph.preprocess import degree_and_densify
from repro.kernels.ops import prepare_subshard_operands, subshard_update
from repro.kernels.ref import subshard_update_ref

RNG = np.random.default_rng(0)


def _random_subshard(isize, e, nslots, seed, sorted_slots=True):
    rng = np.random.default_rng(seed)
    src_local = rng.integers(0, isize, e).astype(np.int32)
    hub_inv = rng.integers(0, nslots, e).astype(np.int32)
    if sorted_slots:
        hub_inv = np.sort(hub_inv)
    w = rng.random(e).astype(np.float32) + 0.1
    return src_local, hub_inv, w


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "isize,e,nslots",
    [(64, 100, 32), (300, 2000, 150), (128, 513, 128), (1000, 4096, 999), (16, 8, 4)],
)
@pytest.mark.parametrize(
    "gather_op,reduce", [("mul", "sum"), ("add", "min"), ("add", "max")]
)
def test_kernel_matches_oracle(isize, e, nslots, gather_op, reduce, dtype):
    src_local, hub_inv, w = _random_subshard(isize, e, nslots, seed=e + isize)
    src_vals = jnp.asarray(RNG.random(isize) + 0.5, dtype)
    ops_in = prepare_subshard_operands(
        src_local, hub_inv, w, dtype, gather_op=gather_op, reduce=reduce
    )
    got = subshard_update(
        src_vals, *ops_in, nslots, gather_op=gather_op, reduce=reduce
    )
    want = subshard_update_ref(
        src_vals,
        jnp.asarray(src_local),
        jnp.asarray(hub_inv),
        jnp.asarray(w, dtype),
        nslots,
        gather_op=gather_op,
        reduce=reduce,
    )
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(want, np.float32),
        rtol=tol,
        atol=tol,
    )


def test_mul_min_rejected():
    src_local, hub_inv, w = _random_subshard(8, 8, 4, seed=0)
    with pytest.raises(ValueError):
        prepare_subshard_operands(
            src_local, hub_inv, w, jnp.float32, gather_op="mul", reduce="min"
        )


@settings(max_examples=15, deadline=None)
@given(
    isize=st.integers(8, 256),
    e=st.integers(1, 1500),
    nslots=st.integers(1, 200),
    seed=st.integers(0, 1000),
    semiring=st.sampled_from([("mul", "sum"), ("add", "min"), ("add", "max")]),
)
def test_property_kernel_oracle(isize, e, nslots, seed, semiring):
    gather_op, reduce = semiring
    src_local, hub_inv, w = _random_subshard(isize, e, nslots, seed)
    src_vals = jnp.asarray(np.random.default_rng(seed).random(isize), jnp.float32)
    ops_in = prepare_subshard_operands(
        src_local, hub_inv, w, jnp.float32, gather_op=gather_op, reduce=reduce
    )
    got = subshard_update(
        src_vals, *ops_in, nslots, gather_op=gather_op, reduce=reduce
    )
    want = subshard_update_ref(
        src_vals,
        jnp.asarray(src_local),
        jnp.asarray(hub_inv),
        jnp.asarray(w),
        nslots,
        gather_op=gather_op,
        reduce=reduce,
    )
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_end_to_end_pagerank_iteration_via_kernel():
    """One PageRank iteration assembled from per-sub-shard kernel calls must
    equal the engine's fused iteration — the kernel really is the engine's
    hot loop on TPU."""
    src, dst = rmat(9, edge_factor=8, seed=4)
    el = degree_and_densify(src, dst, drop_self_loops=True)
    P = 4
    g = build_dsss(el, P)
    prog = PageRank()
    eng = NXGraphEngine(g, prog, strategy="fused")
    ref = eng.run(max_iters=1, tol=0.0)

    # Manual iteration: x' per interval via kernel ToHub + hub scatter.
    isz = g.interval_size
    x = np.full(g.n_pad, 0.0, np.float32)
    x[: g.n] = 1.0 / g.n
    deg = np.asarray(g.out_degree, np.float32)
    inv = np.where(deg > 0, 1.0 / np.maximum(deg, 1), 0.0)
    contrib_base = (x * inv).astype(np.float32)  # rank/outdeg, per vertex
    dangling = x[((deg == 0) & (np.arange(g.n_pad) < g.n))].sum()
    new = np.zeros(g.n_pad, np.float32)
    for j in range(P):
        acc = np.zeros(isz, np.float32)
        for i in range(P):
            ss = g.subshard(i, j)
            if ss.num_edges == 0:
                continue
            ops_in = prepare_subshard_operands(
                ss.src_local,
                ss.hub_inv,
                None,
                jnp.float32,
                gather_op="mul",
                reduce="sum",
            )
            hub = subshard_update(
                jnp.asarray(contrib_base[i * isz : (i + 1) * isz]),
                *ops_in,
                ss.num_unique_dst,
                gather_op="mul",
                reduce="sum",
            )
            acc[ss.hub_dst] += np.asarray(hub)
        new[j * isz : (j + 1) * isz] = (
            0.15 / g.n + 0.85 * (acc + dangling / g.n)
        )
    np.testing.assert_allclose(
        new[: g.n], ref.attrs, rtol=1e-5, atol=1e-7
    )
