"""Training-loop integration: loss decreases, checkpoint/restart is
bit-consistent, failure recovery works, watchdog flags stragglers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.optim import AdamW
from repro.runtime.fault import (
    FailureInjector,
    SimulatedFailure,
    StragglerWatchdog,
    elastic_device_count,
)
from repro.train.loop import TrainLoopConfig, train
from repro.train.state import make_train_state
from repro.train.step import make_train_step

# Long-running training/serving smoke tests: excluded from the tier-1
# CI lane via -m "not slow" (see tests/conftest.py and .github/workflows).
pytestmark = pytest.mark.slow


def _tiny_cfg():
    return get_config("gemma-2b", smoke=True)


def _loop(tmp_path, **kw):
    base = dict(
        total_steps=12,
        checkpoint_every=4,
        checkpoint_dir=str(tmp_path),
        seq_len=32,
        global_batch=4,
        learning_rate=1e-2,
        log_every=0,
    )
    base.update(kw)
    return TrainLoopConfig(**base)


class TestTraining:
    def test_loss_decreases(self, tmp_path):
        stats = train(_tiny_cfg(), _loop(tmp_path, total_steps=30))
        first = np.mean(stats["losses"][:5])
        last = np.mean(stats["losses"][-5:])
        assert last < first, f"loss did not decrease: {first} -> {last}"

    def test_resume_from_checkpoint_continues(self, tmp_path):
        cfg = _tiny_cfg()
        train(cfg, _loop(tmp_path, total_steps=8))
        stats2 = train(cfg, _loop(tmp_path, total_steps=12))
        # resumed run only executes the remaining 4 steps
        assert stats2["final_step"] == 12
        assert len(stats2["losses"]) == 4

    def test_interrupted_equals_uninterrupted(self, tmp_path):
        """Train 8 straight vs train 4 + restart + 4: identical final loss
        (deterministic data + exact state restore)."""
        cfg = _tiny_cfg()
        a = train(cfg, _loop(tmp_path / "a", total_steps=8, checkpoint_every=4))
        train(cfg, _loop(tmp_path / "b", total_steps=4, checkpoint_every=4))
        b = train(cfg, _loop(tmp_path / "b", total_steps=8, checkpoint_every=4))
        assert a["losses"][-1] == pytest.approx(b["losses"][-1], rel=1e-4)

    def test_failure_recovery(self, tmp_path):
        """Injected crash mid-training: loop restores latest and finishes."""
        inj = FailureInjector(fail_at_steps=(6,))
        stats = train(
            _tiny_cfg(),
            _loop(tmp_path, total_steps=10, checkpoint_every=4),
            failure_injector=inj,
        )
        assert stats["recoveries"] == 1
        assert stats["final_step"] == 10

    def test_failure_before_any_checkpoint(self, tmp_path):
        inj = FailureInjector(fail_at_steps=(2,))
        stats = train(
            _tiny_cfg(),
            _loop(tmp_path, total_steps=6, checkpoint_every=4),
            failure_injector=inj,
        )
        assert stats["recoveries"] == 1 and stats["final_step"] == 6

    def test_grad_accumulation_matches_large_batch(self, tmp_path):
        """accum_steps=2 over batch 8 ≈ one batch-8 step (same grads)."""
        cfg = _tiny_cfg()
        opt = AdamW(learning_rate=1e-2)
        from repro.data import SyntheticLM, SyntheticLMConfig

        data = SyntheticLM(SyntheticLMConfig(cfg.vocab_size, 32, 8, seed=1))
        batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
        s1 = make_train_state(cfg, opt, jax.random.PRNGKey(0))
        s2 = jax.tree.map(lambda x: x, s1)
        step1 = make_train_step(cfg, opt, accum_steps=1)
        step2 = make_train_step(cfg, opt, accum_steps=2)
        s1, m1 = step1(s1, batch)
        s2, m2 = step2(s2, batch)
        # loss and gradient norm must agree (same data, averaged grads).
        # Post-Adam params are NOT compared: Adam's first step is sign
        # descent, so numerically-tiny grad elements flip the ±lr update.
        assert float(m1["ce_loss"]) == pytest.approx(
            float(m2["ce_loss"]), rel=1e-3
        )
        assert float(m1["grad_norm"]) == pytest.approx(
            float(m2["grad_norm"]), rel=1e-3
        )


class TestFaultPrimitives:
    def test_injector_fires_once(self):
        inj = FailureInjector(fail_at_steps=(3,))
        inj.check(2)
        with pytest.raises(SimulatedFailure):
            inj.check(3)
        inj.check(3)  # second pass: already fired

    def test_watchdog_flags_outlier(self):
        wd = StragglerWatchdog(warmup=3, threshold=2.0)
        flagged = []
        times = [1.0, 1.0, 1.0, 1.0, 1.1, 5.0, 1.0]
        for i, t in enumerate(times):
            if wd.update(i, t):
                flagged.append(i)
        assert flagged == [5]

    def test_watchdog_does_not_poison_ewma(self):
        wd = StragglerWatchdog(warmup=2, threshold=2.0)
        for i in range(5):
            wd.update(i, 1.0)
        wd.update(5, 10.0)  # straggler: must NOT update the ewma
        assert wd.update(6, 1.0) is False

    def test_elastic_device_count(self):
        assert elastic_device_count(512, model_parallel=16) == 512
        assert elastic_device_count(500, model_parallel=16) == 496
        with pytest.raises(RuntimeError):
            elastic_device_count(8, model_parallel=16, minimum=16)


class TestServing:
    def test_batched_greedy_matches_single(self):
        from repro.serving.llm_demo import Request, ServeEngine
        from repro.models import Model

        cfg = _tiny_cfg()
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, cfg.vocab_size, 12).tolist() for _ in range(3)]
        # batched
        eng = ServeEngine(cfg, params, max_batch=4)
        for i, p in enumerate(prompts):
            eng.submit(Request(request_id=i, prompt=p, max_new_tokens=6))
        batched = eng.run()
        # singles
        for i, p in enumerate(prompts):
            eng1 = ServeEngine(cfg, params, max_batch=1)
            eng1.submit(Request(request_id=0, prompt=p, max_new_tokens=6))
            single = eng1.run()[0]
            assert batched[i] == single, f"request {i} diverged"

    def test_length_bucketing(self):
        from repro.serving.llm_demo import Request, ServeEngine
        from repro.models import Model

        cfg = _tiny_cfg()
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        eng = ServeEngine(cfg, params, max_batch=8)
        rng = np.random.default_rng(1)
        for i in range(5):
            ln = 8 if i % 2 == 0 else 14
            eng.submit(
                Request(
                    request_id=i,
                    prompt=rng.integers(0, cfg.vocab_size, ln).tolist(),
                    max_new_tokens=3,
                )
            )
        out = eng.run()
        assert set(out) == set(range(5))
        assert all(len(v) == 3 for v in out.values())

    def test_eos_stops_early(self):
        from repro.serving.llm_demo import Request, ServeEngine
        from repro.models import Model

        cfg = _tiny_cfg()
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        eng = ServeEngine(cfg, params, max_batch=1)
        prompt = list(range(10))
        # find the first greedy token, then use it as "eos"
        eng.submit(Request(request_id=0, prompt=prompt, max_new_tokens=4))
        first = eng.run()[0][0]
        eng.submit(
            Request(request_id=1, prompt=prompt, max_new_tokens=8, eos_id=first)
        )
        out = eng.run()[1]
        assert out[0] == first and len(out) == 1
