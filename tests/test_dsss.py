"""DSSS structure invariants (paper §II-A / §III-A)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.dsss import build_dsss
from repro.graph.generators import erdos_renyi, rmat, ring, star
from repro.graph.preprocess import degree_and_densify


def _random_el(n, m, seed):
    src, dst = erdos_renyi(n, m, seed=seed)
    return degree_and_densify(src, dst)


class TestDegreeing:
    def test_ids_dense_and_contiguous(self):
        # Sparse raw indices must densify to [0, n).
        src = np.array([10, 1000, 50, 10])
        dst = np.array([50, 10, 1000, 1000])
        el = degree_and_densify(src, dst)
        assert el.n == 3
        assert set(np.concatenate([el.src, el.dst]).tolist()) <= {0, 1, 2}

    def test_mapping_roundtrip(self):
        src, dst = erdos_renyi(100, 300, seed=0)
        el = degree_and_densify(src, dst)
        back = el.id_to_index[el.index_to_id(el.id_to_index)]
        np.testing.assert_array_equal(back, el.id_to_index)

    def test_dedup(self):
        src = np.array([0, 0, 1, 0])
        dst = np.array([1, 1, 0, 1])
        el = degree_and_densify(src, dst)
        assert el.m == 2

    def test_degrees(self):
        el = degree_and_densify(*star(10))
        hub = el.index_to_id(np.array([0]))[0]
        assert el.out_degree[hub] == 9
        assert el.in_degree[hub] == 0
        assert (el.out_degree.sum() == el.m) and (el.in_degree.sum() == el.m)

    def test_isolated_vertices_excluded(self):
        # Paper Table III footnote: vertex counts exclude isolated vertices.
        src = np.array([5, 7])
        dst = np.array([7, 5])
        el = degree_and_densify(src, dst)
        assert el.n == 2

    def test_self_loop_drop(self):
        el = degree_and_densify(
            np.array([0, 1]), np.array([0, 1]), drop_self_loops=True
        )
        assert el.m == 0


class TestSharding:
    @pytest.mark.parametrize("P", [1, 2, 3, 7, 16])
    def test_edge_conservation(self, P):
        el = _random_el(100, 500, seed=P)
        g = build_dsss(el, P)
        assert int(g.density_matrix().sum()) == el.m == g.m

    @pytest.mark.parametrize("P", [1, 2, 5])
    def test_subshard_membership(self, P):
        """SS[i,j] holds exactly the edges with src∈I_i, dst∈I_j."""
        el = _random_el(60, 240, seed=P + 10)
        g = build_dsss(el, P)
        seen = set()
        for i in range(P):
            for j in range(P):
                ss = g.subshard(i, j)
                src_g = ss.src_local + i * g.interval_size
                dst_g = ss.dst_local + j * g.interval_size
                assert (src_g // g.interval_size == i).all()
                assert (dst_g // g.interval_size == j).all()
                seen.update(zip(src_g.tolist(), dst_g.tolist()))
        assert seen == set(zip(el.src.tolist(), el.dst.tolist()))

    def test_destination_sorted_within_subshard(self):
        el = _random_el(80, 400, seed=1)
        g = build_dsss(el, 4)
        for i in range(4):
            for j in range(4):
                ss = g.subshard(i, j)
                d = ss.dst_local
                assert (np.diff(d) >= 0).all(), "edges must be dst-sorted"
                # Secondary sort by source within equal destinations
                # (paper: CPU-cache locality of the gather).
                s = ss.src_local
                same = np.diff(d) == 0
                assert (np.diff(s)[same] >= 0).all()

    def test_src_sorted_baseline_layout(self):
        el = _random_el(80, 400, seed=2)
        g = build_dsss(el, 4, src_sorted=True)
        for i in range(4):
            for j in range(4):
                ss = g.subshard(i, j)
                assert (np.diff(ss.src_local) >= 0).all()

    def test_hub_compression(self):
        """hub_dst = unique destinations; hub_inv maps each edge to its slot."""
        el = _random_el(70, 350, seed=3)
        g = build_dsss(el, 3)
        for i in range(3):
            for j in range(3):
                ss = g.subshard(i, j)
                if ss.num_edges == 0:
                    continue
                np.testing.assert_array_equal(
                    np.unique(ss.dst_local), np.sort(ss.hub_dst)
                )
                np.testing.assert_array_equal(
                    ss.hub_dst[ss.hub_inv], ss.dst_local
                )

    def test_mean_hub_in_degree(self):
        # star graph, P=1: every edge shares one destination? No — star has
        # distinct leaf destinations; use the reverse star (all -> 0).
        src, dst = star(11)
        el = degree_and_densify(dst, src)  # leaves -> hub
        g = build_dsss(el, 1)
        assert g.mean_hub_in_degree() == pytest.approx(10.0)

    @settings(max_examples=20, deadline=None)
    @given(
        n=st.integers(5, 60),
        m=st.integers(5, 300),
        P=st.integers(1, 8),
        seed=st.integers(0, 100),
    )
    def test_property_partition(self, n, m, P, seed):
        el = _random_el(n, m, seed)
        P = min(P, el.n)
        g = build_dsss(el, P)
        assert g.m == el.m
        assert g.P * g.interval_size >= g.n
        # offsets monotone
        flat = np.concatenate([g.offsets[i] for i in range(P)])
        assert (np.diff(g.offsets.reshape(-1, P + 1), axis=1) >= 0).all()
        # hub totals: sum of unique dst counts <= m
        assert 0 <= int(g.hub_offsets[-1, -1]) <= g.m
        assert len(g.hub_dst_flat) == int(g.hub_offsets[-1, -1])


class TestGenerators:
    def test_rmat_shapes(self):
        src, dst = rmat(8, edge_factor=4, seed=0)
        assert len(src) == len(dst) == 4 << 8
        assert src.max() < 256 and dst.max() < 256

    def test_rmat_skew(self):
        """RMAT with Graph500 params must be heavier-tailed than ER."""
        src, _ = rmat(10, edge_factor=8, seed=0)
        el = degree_and_densify(src, _)
        top = np.sort(el.out_degree)[-len(el.out_degree) // 100 :].sum()
        assert top / el.m > 0.05  # top 1% of vertices hold >5% of edges

    def test_ring(self):
        el = degree_and_densify(*ring(10))
        assert (el.out_degree == 1).all() and (el.in_degree == 1).all()
