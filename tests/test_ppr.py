"""Personalized PageRank as a first-class point query.

``PageRank`` now accepts ``personalize`` (a seed vertex — the PPR point
query) and ``reset_dist`` (an explicit teleport distribution) through the
standard Initialize-kwargs channel, so PPR queries flow through
``ExecutionPlan`` validation, ``run_batch`` fusion (differing reset
vectors ride the vmap-stacked per-query aux path from the selective PR)
and ``repro.serving`` micro-batching exactly like BFS roots do.

Contract pinned here:
  * the default (no-kwargs) program is byte-identical to the old
    unpersonalized PageRank — same aux leaves, same results, so existing
    plans keep batching/caching;
  * PPR mass localizes around the seed and teleports only to it;
  * a batch of differing seeds FUSES (one streamed pass) and each member
    equals its solo run bitwise;
  * served PPR == solo PPR through ``GraphServer`` micro-batching.
"""
import numpy as np
import pytest

from repro.core import ExecutionPlan, GraphSession, PageRank, build_dsss
from repro.graph.generators import erdos_renyi
from repro.graph.preprocess import degree_and_densify
from repro.serving import GraphServer, QueryRequest, SessionPool


def _graph(n=130, m=800, seed=7, P=4):
    src, dst = erdos_renyi(n, m, seed=seed)
    el = degree_and_densify(src, dst, drop_self_loops=True)
    return build_dsss(el, P)


@pytest.fixture(scope="module")
def graph():
    return _graph()


def test_accepted_kwargs_surfaced(graph):
    assert {"personalize", "reset_dist"} <= PageRank().accepted_kwargs()
    # plan-construction validation sees them (unknown names still raise)
    ExecutionPlan(PageRank(), program_kwargs={"personalize": 3})
    with pytest.raises(TypeError):
        ExecutionPlan(PageRank(), program_kwargs={"personalise": 3})


def test_default_path_unchanged(graph):
    """No kwargs → aux dict and results identical to the historical
    uniform-reset program (bit-compat: default plans must keep fusing
    with each other and reusing cached executables)."""
    p = PageRank()
    aux = p.make_aux(graph)
    assert set(aux) == {"inv_out_degree", "dangling", "inv_n"}
    sess = GraphSession(graph)
    res = sess.run(ExecutionPlan(p, max_iters=30))
    np.testing.assert_allclose(res.output.sum(), 1.0, atol=1e-4)


def test_ppr_localizes_at_seed(graph):
    sess = GraphSession(graph)
    seed = 11
    res = sess.run(
        ExecutionPlan(PageRank(), program_kwargs={"personalize": seed})
    )
    out = res.output
    np.testing.assert_allclose(out.sum(), 1.0, atol=1e-4)
    # the seed holds at least the teleport mass (1-damping), far above
    # the uniform share — the signature of a point query
    assert out[seed] >= (1 - PageRank().damping) * 0.99
    assert out[seed] > 10.0 / graph.n
    # a different seed gives a genuinely different ranking
    res2 = sess.run(
        ExecutionPlan(PageRank(), program_kwargs={"personalize": 42})
    )
    assert not np.array_equal(res.attrs, res2.attrs)


def test_reset_dist_teleport_set(graph):
    sess = GraphSession(graph)
    rd = np.zeros(graph.n)
    rd[[2, 3, 5]] = [2.0, 1.0, 1.0]  # normalized internally
    res = sess.run(ExecutionPlan(PageRank(), program_kwargs={"reset_dist": rd}))
    out = res.output
    np.testing.assert_allclose(out.sum(), 1.0, atol=1e-4)
    assert out[[2, 3, 5]].sum() >= (1 - PageRank().damping) * 0.99


def test_reset_validation(graph):
    sess = GraphSession(graph)
    with pytest.raises(ValueError, match="not both"):
        sess.run(
            ExecutionPlan(
                PageRank(),
                program_kwargs={
                    "personalize": 1, "reset_dist": np.ones(graph.n)
                },
            )
        )
    with pytest.raises(ValueError, match="out of range"):
        sess.run(
            ExecutionPlan(PageRank(), program_kwargs={"personalize": graph.n})
        )
    with pytest.raises(ValueError, match="shape"):
        sess.run(
            ExecutionPlan(
                PageRank(), program_kwargs={"reset_dist": np.ones(3)}
            )
        )
    with pytest.raises(ValueError, match="non-negative"):
        sess.run(
            ExecutionPlan(
                PageRank(), program_kwargs={"reset_dist": -np.ones(graph.n)}
            )
        )


@pytest.mark.parametrize("execution", ["packed", "per_block"])
def test_batch_of_differing_seeds_fuses(graph, execution):
    """The PR-7 vmap-stacked-aux path: differing personalization vectors
    stack into a leading (K,) aux axis and run as ONE streamed pass,
    each member bitwise equal to its solo run."""
    sess = GraphSession(graph)
    seeds = [0, 11, 42, 97]
    plans = [
        ExecutionPlan(
            PageRank(), strategy="dpu", execution=execution, max_iters=20,
            tol=0.0, program_kwargs={"personalize": s},
        )
        for s in seeds
    ]
    batch = sess.run_batch(plans)
    assert batch.fused, "differing reset vectors must stack, not serialize"
    for plan, res in zip(plans, batch.results):
        solo = sess.run(plan)
        np.testing.assert_array_equal(solo.attrs, res.attrs)


def test_mixed_default_and_ppr_falls_back(graph):
    """Default and personalized plans have different aux keys — they must
    run sequentially (correct results), never silently share a reset."""
    sess = GraphSession(graph)
    plans = [
        ExecutionPlan(PageRank(), max_iters=10, tol=0.0),
        ExecutionPlan(
            PageRank(), max_iters=10, tol=0.0,
            program_kwargs={"personalize": 5},
        ),
    ]
    batch = sess.run_batch(plans)
    assert not batch.fused
    for plan, res in zip(plans, batch.results):
        np.testing.assert_array_equal(sess.run(plan).attrs, res.attrs)


def test_ppr_through_serving(graph):
    """PPR point queries batch through GraphServer like BFS roots."""
    pool = SessionPool()
    pool.register("g", graph)
    server = GraphServer(pool, max_batch=8, max_wait_ms=1.0)
    seeds = [1, 7, 23, 61]
    plans = [
        ExecutionPlan(
            PageRank(), strategy="dpu", max_iters=20, tol=0.0,
            program_kwargs={"personalize": s},
        )
        for s in seeds
    ]
    served = server.serve([QueryRequest("g", p) for p in plans])
    session = pool.session("g")
    for plan, q in zip(plans, served):
        solo = session.run(plan)
        np.testing.assert_array_equal(solo.attrs, q.result.attrs)
    st = server.stats()
    assert st.completed == len(plans)
    assert st.fused_batches >= 1  # the point queries really fused
