"""Golden regression: fixed-seed graph, committed PageRank top-20 ranking.

The ranking below was produced by the SPU reference at seed time and is
committed as a frozen artifact: every strategy (spu/dpu/mpu/auto), and both
residency modes, must keep reproducing it. A failure here means an engine
change silently altered results — not just meters.

Graph: ``rmat(10, edge_factor=8, seed=42)`` densified, P=8 → n=795, m=6716.
30 PageRank iterations, tol=0. The top-21 scores are separated by ≥9.6e-7,
an order of magnitude above cross-strategy float32 reduction noise, so the
ranking is strategy-stable.
"""
import numpy as np
import pytest

from repro.core import ExecutionPlan, GraphSession, PageRank, build_dsss
from repro.graph.generators import rmat
from repro.graph.preprocess import degree_and_densify

GOLDEN_TOP20 = [
    0, 1, 232, 122, 2, 444, 16, 32, 4, 8,
    63, 263, 234, 71, 48, 18, 24, 10, 64, 5,
]


@pytest.fixture(scope="module")
def graph():
    src, dst = rmat(10, edge_factor=8, seed=42)
    el = degree_and_densify(src, dst, drop_self_loops=True)
    g = build_dsss(el, 8)
    assert (g.n, g.m) == (795, 6716), "generator changed — regenerate golden"
    return g


@pytest.mark.parametrize("strategy", ["spu", "dpu", "mpu", "auto"])
def test_top20_ranking_frozen(graph, strategy):
    budget = 2 * graph.n_pad * PageRank().attr_bytes + 3_000  # forces mpu Q<P
    sess = GraphSession(
        graph, memory_budget=budget if strategy != "spu" else None
    )
    res = sess.run(ExecutionPlan(PageRank(), strategy=strategy, max_iters=30, tol=0.0))
    top20 = np.argsort(-res.output, kind="stable")[:20]
    np.testing.assert_array_equal(top20, GOLDEN_TOP20)


@pytest.mark.parametrize("residency", ["device", "host"])
def test_top20_ranking_frozen_across_residency(graph, residency):
    sess = GraphSession(graph, memory_budget=10_000, residency=residency)
    res = sess.run(ExecutionPlan(PageRank(), strategy="auto", max_iters=30, tol=0.0))
    top20 = np.argsort(-res.output, kind="stable")[:20]
    np.testing.assert_array_equal(top20, GOLDEN_TOP20)
