"""Property tests: engine byte meters vs. the Table II closed forms.

This is the module promised by ``core/iomodel.py``'s docstring — the
paper-faithfulness proof of the I/O analysis. Two properties:

1. For randomized ``(n, m, P, B_M)`` the *measured* per-iteration byte
   meters of SPU / DPU / MPU runs reproduce ``spu_io`` / ``dpu_io`` /
   ``mpu_io`` within the documented discretization slack. The runs use
   ``residency="host"``, so the edge-byte meters being checked are real
   host→device transfers, not simulated counters.
2. ``select_strategy`` picks the argmin of the modelled totals over the
   feasible candidates (pure closed-form, large parameter ranges).

Documented slack terms (see :class:`repro.core.iomodel.IOComparison`):

* SPU residency is block-granular (≤ one max-block undershoot) and the
  engine budgets both attribute copies at ``n_pad`` (padded intervals)
  where the formula uses ``n``.
* DPU/MPU interval loads/saves move padded intervals: ≤ ``(n_pad−n)·Ba``.
* MPU's ``(P−Q)²/P²`` hub factor assumes uniform hub distribution across
  sub-shards; the engine meters the graph's actual per-block unique
  destination counts. The deviation is computable exactly from
  ``hub_offsets`` and is included in the slack.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    ExecutionPlan,
    GraphSession,
    IOParams,
    PageRank,
    build_dsss,
    compare_measured,
    dpu_io,
    modelled_io,
    mpu_io,
    mpu_q,
    select_strategy,
    spu_io,
)
from repro.graph.generators import erdos_renyi
from repro.graph.preprocess import degree_and_densify

ITERS = 2


def _graph(n, m, seed, P):
    src, dst = erdos_renyi(n, m, seed=seed)
    el = degree_and_densify(src, dst, drop_self_loops=True)
    return build_dsss(el, P)


def _cold_hub_unique(g, Q):
    """Actual unique-destination count over the cold (i≥Q, j≥Q) blocks."""
    return sum(
        int(g.hub_offsets[i, j + 1] - g.hub_offsets[i, j])
        for i in range(Q, g.P)
        for j in range(Q, g.P)
    )


def _mpu_hub_slack(g, Q, p):
    """|actual − uniform-model| cold hub traffic, one direction."""
    total_u = int(g.hub_offsets[-1, -1])
    cold = (g.P - Q) / g.P
    return abs(_cold_hub_unique(g, Q) - cold * cold * total_u) * (p.Ba + p.Bv)


class TestMeasuredMetersMatchClosedForms:
    """The engine's streamed bytes are the oracle's closed forms."""

    @settings(max_examples=8, deadline=None)
    @given(
        seed=st.integers(0, 40),
        P=st.integers(1, 6),
        frac=st.floats(0.0, 1.4),
    )
    def test_spu_measured_read(self, seed, P, frac):
        g = _graph(90, 420, seed, P)
        prog = PageRank()
        Ba = prog.attr_bytes
        budget = int((2 * g.n_pad * Ba + g.m * 8) * frac)
        sess = GraphSession(g, memory_budget=budget, residency="host")
        res = sess.run(ExecutionPlan(prog, strategy="spu", max_iters=ITERS, tol=0.0))
        per = res.meters.per_iteration()
        p = sess.params_for(prog)
        max_block = max(h["e"] for h in sess.host_blocks.values()) * sess.Be
        cmp = compare_measured(
            per,
            p,
            "spu",
            budget,
            slack_bytes=max_block + 2 * (g.n_pad - g.n) * Ba,
        )
        assert cmp.within_slack, cmp
        assert per.bytes_written == 0.0
        # Real streaming (packed host path): physical transfers happen iff
        # the budget's pinned tile prefix does not cover the whole graph —
        # which coincides with the model charging edge reads at all.
        splan = sess.packed_stream_plan("spu", Ba)
        assert (per.bytes_h2d > 0) == (splan.pin_tiles < splan.num_tiles)
        assert (per.bytes_h2d > 0) == (per.bytes_read_edges > 0)

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 40), P=st.integers(1, 6))
    def test_dpu_measured_exact(self, seed, P):
        g = _graph(90, 420, seed, P)
        prog = PageRank()
        sess = GraphSession(g, memory_budget=0, residency="host")
        res = sess.run(ExecutionPlan(prog, strategy="dpu", max_iters=ITERS, tol=0.0))
        per = res.meters.per_iteration()
        p = sess.params_for(prog)
        pad = (g.n_pad - g.n) * prog.attr_bytes
        cmp = compare_measured(per, p, "dpu", 0, slack_bytes=pad)
        assert cmp.within_slack, cmp

    @settings(max_examples=8, deadline=None)
    @given(
        seed=st.integers(0, 40),
        P=st.integers(2, 6),
        frac=st.floats(0.05, 1.2),
    )
    def test_mpu_measured_within_hub_nonuniformity(self, seed, P, frac):
        g = _graph(90, 420, seed, P)
        prog = PageRank()
        Ba = prog.attr_bytes
        budget = int(2 * g.n_pad * Ba * frac)
        sess = GraphSession(g, memory_budget=budget, residency="host")
        res = sess.run(ExecutionPlan(prog, strategy="mpu", max_iters=ITERS, tol=0.0))
        per = res.meters.per_iteration()
        p = sess.params_for(prog)
        Q = mpu_q(p, budget)
        assert res.strategy.Q == Q
        slack = (g.n_pad - g.n) * Ba + _mpu_hub_slack(g, Q, p)
        cmp = compare_measured(per, p, "mpu", budget, slack_bytes=slack)
        assert cmp.within_slack, cmp

    def test_modelled_io_dispatch_matches_primitives(self):
        p = IOParams(n=10_000, m=160_000, P=16)
        B = 60_000
        assert modelled_io(p, B, "spu") == spu_io(p, B)
        assert modelled_io(p, B, "dpu") == dpu_io(p)
        assert modelled_io(p, B, "mpu") == mpu_io(p, B)
        assert modelled_io(p, None, "spu") == (0.0, 0.0)
        # No budget ⇒ the engine's explicit-mpu resolution runs Q=0; the
        # oracle must model the same case, not a full-residency MPU.
        assert modelled_io(p, None, "mpu") == mpu_io(p, 0)
        with pytest.raises(ValueError):
            modelled_io(p, B, "fused")


class TestSelectionArgmin:
    """Adaptive selection must pick the modelled-I/O argmin."""

    @settings(max_examples=60, deadline=None)
    @given(
        n=st.integers(100, 10**7),
        deg=st.integers(1, 64),
        P=st.integers(1, 64),
        frac=st.floats(0.0, 2.0),
    )
    def test_choice_is_argmin_of_feasible_candidates(self, n, deg, P, frac):
        p = IOParams(n=n, m=n * deg, P=P)
        B_M = int(2 * n * p.Ba * frac)
        choice = select_strategy(p, B_M)
        candidates = {"dpu": sum(dpu_io(p)), "mpu": sum(mpu_io(p, B_M))}
        spu_feasible = B_M >= 2 * P * -(-n // P) * p.Ba  # 2·n_pad·Ba
        if spu_feasible:
            candidates["spu"] = sum(spu_io(p, B_M))
        # MPU quantizes to DPU at Q=0; the reported name tracks Q.
        assert choice.strategy in candidates
        best = min(candidates.values())
        assert choice.modelled_total <= best + 1e-6
        if choice.strategy == "mpu":
            assert 0 < choice.Q < P or P == 1
        if choice.strategy == "spu":
            assert spu_feasible

    @settings(max_examples=40, deadline=None)
    @given(
        n=st.integers(100, 10**6),
        deg=st.integers(1, 32),
        frac=st.floats(0.0, 1.0),
    )
    def test_mpu_monotone_in_budget(self, n, deg, frac):
        """More memory never costs more modelled I/O (the Q-monotonicity
        select_strategy relies on to skip the search)."""
        p = IOParams(n=n, m=n * deg, P=16)
        B1 = int(2 * n * p.Ba * frac)
        B2 = B1 + n * p.Ba
        assert sum(mpu_io(p, B2)) <= sum(mpu_io(p, B1)) + 1e-6
