"""The observability layer (repro.obs) and its engine/serving wiring.

The load-bearing contract under test is *exactness*: metrics and trace
spans are emitted at the same lines that charge ``Meters``, so

* registry deltas across a run recombine field-for-field with
  ``Result.meters`` — checked over the residency × execution matrix;
* a traced run's per-sweep ``bytes_h2d``/``bytes_disk_read`` span
  attributes sum exactly to the run totals;
* a ``/metrics`` scrape of a :class:`GraphServer` endpoint equals the
  ``ServerStats`` snapshot field-for-field, and per-request
  ``split_meters`` shares re-sum to the scraped serving meter totals.

Plus the plumbing: Prometheus render/parse round-trip, registry gating
(``REPRO_OBS=0`` semantics), tracer ring + Chrome export + the
``python -m repro.obs export-trace`` CLI, iomodel drift gauges,
checkpoint/storage counters, and the benchmark payload stamp.
"""
import dataclasses
import json
import subprocess
import sys

import numpy as np
import pytest

from repro.core import (
    BFS,
    CheckpointSpec,
    ExecutionPlan,
    GraphSession,
    PageRank,
    TraceSpec,
    build_dsss,
    modelled_io,
)
from repro.graph.generators import erdos_renyi
from repro.graph.preprocess import degree_and_densify
from repro.obs import (
    DEFAULT_LATENCY_BUCKETS,
    HistogramValue,
    MetricsRegistry,
    REGISTRY,
    TRACER,
    Tracer,
    parse_prometheus,
)
from repro.serving import GraphServer, QueryRequest, SessionPool
from repro.serving.api import split_meters
from repro.storage import write_dsss


def _graph(n=130, m=800, seed=7, P=4):
    src, dst = erdos_renyi(n, m, seed=seed)
    el = degree_and_densify(src, dst, drop_self_loops=True)
    return build_dsss(el, P)


@pytest.fixture(scope="module")
def graph():
    return _graph()


@pytest.fixture(scope="module")
def dsss_path(graph, tmp_path_factory):
    path = str(tmp_path_factory.mktemp("obs") / "g.dsss")
    write_dsss(graph, path)
    return path


def _session(graph, dsss_path, residency):
    budget = int(graph.total_edge_bytes(8) * 0.3)
    if residency == "disk":
        return GraphSession.open(
            dsss_path, memory_budget=budget, host_memory_budget=2 * budget
        )
    return GraphSession(graph, memory_budget=budget, residency=residency)


# ---------------------------------------------------------------------------
# registry plumbing
# ---------------------------------------------------------------------------
class TestRegistry:
    def test_counter_gauge_render_parse_roundtrip(self):
        reg = MetricsRegistry(enabled=True)
        c = reg.counter("t_bytes_total", "bytes", ("kind",))
        c.labels(kind="h2d").inc(7)
        c.labels(kind="disk").inc(3.5)
        reg.gauge("t_depth", "queue depth").set(4)
        parsed = parse_prometheus(reg.render())
        assert parsed[("t_bytes_total", (("kind", "h2d"),))] == 7
        assert parsed[("t_bytes_total", (("kind", "disk"),))] == 3.5
        assert parsed[("t_depth", ())] == 4

    def test_disabled_registry_records_nothing(self):
        reg = MetricsRegistry(enabled=False)
        c = reg.counter("t_total")
        c.inc(5)
        reg.gauge("t_g").set(9)
        reg.histogram("t_h").observe(0.1)
        assert reg.value("t_total") == 0.0
        assert reg.value("t_g") == 0.0
        assert reg.value("t_h") == 0.0
        reg.set_enabled(True)
        c.inc(5)
        assert reg.value("t_total") == 5.0

    def test_reregistration_idempotent_but_type_checked(self):
        reg = MetricsRegistry(enabled=True)
        a = reg.counter("t_total", "x", ("k",))
        assert reg.counter("t_total", "y", ("k",)) is a
        with pytest.raises(ValueError):
            reg.gauge("t_total")
        with pytest.raises(ValueError):
            reg.counter("t_total", labelnames=("other",))

    def test_histogram_quantiles_and_render(self):
        h = HistogramValue(buckets=(0.1, 1.0, 10.0))
        for v in (0.05, 0.05, 0.5, 0.5, 0.5, 5.0):
            h.observe(v)
        assert 0.0 < h.quantile(0.5) <= 1.0
        assert h.quantile(0.99) <= 10.0
        assert h.count == 6
        reg = MetricsRegistry(enabled=True)
        reg.histogram("t_lat", buckets=(0.1, 1.0)).observe(0.5)
        text = reg.render()
        assert 't_lat_bucket{le="+Inf"} 1' in text
        assert "t_lat_count 1" in text

    def test_value_missing_series_is_zero(self):
        reg = MetricsRegistry(enabled=True)
        assert reg.value("never_registered") == 0.0
        reg.counter("t_total", "x", ("k",))
        assert reg.value("t_total", k="absent") == 0.0


# ---------------------------------------------------------------------------
# tracer plumbing
# ---------------------------------------------------------------------------
class TestTracer:
    def test_ring_bounded_and_since_mark(self):
        tr = Tracer(capacity=4)
        tr.enabled = True
        for i in range(10):
            tr.instant(f"e{i}")
        assert len(tr.spans()) == 4
        mark = tr.mark()
        tr.instant("after")
        assert [s.name for s in tr.spans(since=mark)] == ["after"]

    def test_span_ctx_gates_on_enabled(self):
        tr = Tracer()
        with tr.span("off"):
            pass
        assert tr.spans() == []
        tr.enabled = True
        with tr.span("on", cat="t", k=1):
            pass
        (s,) = tr.spans()
        assert s.name == "on" and s.args_dict() == {"k": 1}

    def test_chrome_export_shape(self, tmp_path):
        tr = Tracer()
        tr.enabled = True
        tr.record("work", 1.0, 1.5, cat="t", args={"bytes": 3})
        doc = tr.to_chrome()
        xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
        assert xs[0]["ts"] == 1.0e6 and xs[0]["dur"] == 0.5e6
        assert any(e["ph"] == "M" for e in doc["traceEvents"])
        path = str(tmp_path / "t.json")
        tr.export(path)
        assert json.load(open(path))["traceEvents"]

    def test_cli_converts_jsonl_dump(self, tmp_path):
        tr = Tracer()
        tr.enabled = True
        tr.record("sweep", 0.0, 0.1, args={"bytes_h2d": 64})
        src = str(tmp_path / "spans.jsonl")
        tr.dump(src)
        out = str(tmp_path / "trace.json")
        proc = subprocess.run(
            [sys.executable, "-m", "repro.obs", "export-trace", src, "-o", out],
            capture_output=True, text=True,
        )
        assert proc.returncode == 0, proc.stderr
        events = json.load(open(out))["traceEvents"]
        xs = [e for e in events if e.get("ph") == "X"]
        assert xs[0]["name"] == "sweep"
        assert xs[0]["args"]["bytes_h2d"] == 64


# ---------------------------------------------------------------------------
# engine wiring: registry deltas == Result.meters over the matrix
# ---------------------------------------------------------------------------
_BYTE_KINDS = (
    ("h2d", "bytes_h2d"),
    ("disk_read", "bytes_disk_read"),
    ("read_edges", "bytes_read_edges"),
    ("read_intervals", "bytes_read_intervals"),
    ("read_hubs", "bytes_read_hubs"),
    ("written_hubs", "bytes_written_hubs"),
    ("written_intervals", "bytes_written_intervals"),
)


def _snap_bytes():
    return {
        kind: REGISTRY.value("repro_engine_bytes_total", kind=kind)
        for kind, _ in _BYTE_KINDS
    }


class TestEngineMetrics:
    @pytest.mark.parametrize("residency", ["device", "host", "disk"])
    @pytest.mark.parametrize("execution", ["per_block", "packed"])
    def test_registry_deltas_equal_meters(
        self, graph, dsss_path, residency, execution
    ):
        sess = _session(graph, dsss_path, residency)
        plan = ExecutionPlan(
            PageRank(), max_iters=3, tol=0.0, execution=execution
        )
        before = _snap_bytes()
        s_sweeps = REGISTRY.value("repro_engine_sweeps_total")
        res = sess.run(plan)
        after = _snap_bytes()
        for kind, field in _BYTE_KINDS:
            assert after[kind] - before[kind] == getattr(res.meters, field), (
                f"{residency}/{execution}: registry kind={kind} drifted "
                "from Meters"
            )
        assert (
            REGISTRY.value("repro_engine_sweeps_total") - s_sweeps
            == res.meters.iterations
        )
        assert (
            REGISTRY.value(
                "repro_engine_runs_total",
                program="pagerank",
                strategy=res.strategy.strategy,
                residency=sess.resolved_residency(),
                execution=sess.resolved_execution(
                    res.strategy.strategy, sess.resolved_residency(), execution
                ),
            )
            >= 1
        )

    def test_disabled_registry_freezes_engine_counters(self, graph):
        sess = GraphSession(graph)
        plan = ExecutionPlan(PageRank(), max_iters=2, tol=0.0)
        sess.run(plan)  # ensure series exist
        before = _snap_bytes()
        s_sweeps = REGISTRY.value("repro_engine_sweeps_total")
        REGISTRY.set_enabled(False)
        try:
            sess.run(plan)
        finally:
            REGISTRY.set_enabled(True)
        assert _snap_bytes() == before
        assert REGISTRY.value("repro_engine_sweeps_total") == s_sweeps

    def test_iomodel_drift_gauge_near_one(self, graph):
        budget = int(graph.total_edge_bytes(8) * 0.3)
        sess = GraphSession(graph, memory_budget=budget, residency="host")
        plan = ExecutionPlan(PageRank(), max_iters=4, tol=0.0)
        res = sess.run(plan)
        strat = res.strategy.strategy
        read, write = modelled_io(
            sess.params_for(plan.program), budget, strat
        )
        if read > 0:
            got = REGISTRY.value(
                "repro_iomodel_drift_ratio", direction="read", strategy=strat
            )
            want = res.meters.bytes_read / res.meters.iterations / read
            assert got == pytest.approx(want)
            assert 0.2 < got < 5.0  # full sweeps: same order as the model


# ---------------------------------------------------------------------------
# tracing wiring: per-sweep byte attrs sum exactly to meters
# ---------------------------------------------------------------------------
class TestEngineTracing:
    def test_traced_disk_run_sums_and_valid_chrome(
        self, graph, dsss_path, tmp_path
    ):
        sess = _session(graph, dsss_path, "disk")
        path = str(tmp_path / "run.json")
        plan = ExecutionPlan(
            PageRank(), max_iters=4, tol=0.0, trace=TraceSpec(path=path)
        )
        res = sess.run(plan)
        assert not TRACER.enabled  # plan-scoped enable was restored
        doc = json.load(open(path))
        xs = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
        sweeps = [e for e in xs if e["name"] == "sweep"]
        assert len(sweeps) == res.meters.iterations
        assert (
            sum(e["args"]["bytes_h2d"] for e in sweeps)
            == res.meters.bytes_h2d
        )
        assert (
            sum(e["args"]["bytes_disk_read"] for e in sweeps)
            == res.meters.bytes_disk_read
        )
        assert res.meters.bytes_disk_read > 0
        (run_span,) = [e for e in xs if e["name"] == "run"]
        assert run_span["args"]["bytes_h2d"] == res.meters.bytes_h2d
        assert run_span["args"]["residency"] == "disk"

    def test_trace_records_staging_and_checkpoint(self, graph, tmp_path):
        # Fresh device session: the first fused run always stages, so a
        # cat="staging" span is guaranteed alongside the checkpoint ones.
        sess = GraphSession(graph)
        path = str(tmp_path / "ck.json")
        plan = ExecutionPlan(
            PageRank(),
            max_iters=4,
            tol=0.0,
            checkpoint=CheckpointSpec(directory=str(tmp_path / "snaps"),
                                      every=2),
            trace=TraceSpec(path=path),
        )
        sess.run(plan)
        xs = [
            e
            for e in json.load(open(path))["traceEvents"]
            if e.get("ph") == "X"
        ]
        names = {e["name"] for e in xs}
        assert "checkpoint" in names
        assert any(e["cat"] == "staging" for e in xs)

    def test_tracespec_sweeps_off_and_batch_key_exclusion(
        self, graph, tmp_path
    ):
        path = str(tmp_path / "nosweeps.json")
        spec = TraceSpec(path=path, sweeps=False)
        plan = ExecutionPlan(PageRank(), max_iters=2, tol=0.0, trace=spec)
        bare = ExecutionPlan(PageRank(), max_iters=2, tol=0.0)
        assert plan.batch_key() == bare.batch_key()  # traced requests fuse
        GraphSession(graph).run(plan)
        xs = [
            e
            for e in json.load(open(path))["traceEvents"]
            if e.get("ph") == "X"
        ]
        assert all(e["name"] != "sweep" for e in xs)
        assert any(e["name"] == "run" for e in xs)

    def test_trace_type_validated(self):
        with pytest.raises(TypeError):
            ExecutionPlan(PageRank(), trace="run.json")


# ---------------------------------------------------------------------------
# serving wiring: scrape == stats, split_meters re-sums, healthz
# ---------------------------------------------------------------------------
def _scrape(server, path="/metrics"):
    import urllib.request

    return urllib.request.urlopen(server.telemetry.url(path), timeout=10)


class TestServingTelemetry:
    def test_scrape_equals_stats_and_meter_shares_resum(self, graph):
        pool = SessionPool()
        pool.register("g", graph)
        server = GraphServer(pool, max_batch=8, telemetry_port=0)
        try:
            k = 6
            plans = [
                ExecutionPlan(
                    BFS(), strategy="spu", max_iters=graph.n + 1,
                    program_kwargs={"root": r},
                )
                for r in range(k)
            ]
            served = server.serve([QueryRequest("g", p) for p in plans])
            st = server.stats()
            parsed = parse_prometheus(
                _scrape(server).read().decode()
            )
            for f in st.COUNTER_FIELDS:
                assert parsed[(f"repro_serving_{f}_total", ())] == getattr(
                    st, f
                ), f
            for f in ("p50_total_s", "p95_total_s", "p99_total_s", "qps"):
                assert parsed[(f"repro_serving_{f}", ())] == pytest.approx(
                    getattr(st, f)
                )
            # fused-batch shares re-sum to the scraped serving meters
            assert any(q.fused and q.batch_size > 1 for q in served)
            from repro.core.session import Meters

            merged = Meters()
            for q in served:
                merged.merge(q.meters)
            for f in dataclasses.fields(Meters):
                scraped = parsed[
                    ("repro_serving_meters_total", (("field", f.name),))
                ]
                assert scraped == pytest.approx(
                    float(getattr(st.meters, f.name))
                )
                if f.name not in ("wall_seconds", "peak_device_graph_bytes"):
                    assert float(getattr(merged, f.name)) == pytest.approx(
                        scraped
                    ), f.name
            # pool stats came along
            assert parsed[("repro_pool_open_sessions", ())] == 1
        finally:
            server.shutdown_telemetry()

    def test_healthz_and_unknown_route(self, graph):
        pool = SessionPool()
        pool.register("g", graph)
        server = GraphServer(pool, telemetry_port=0)
        try:
            resp = _scrape(server, "/healthz")
            doc = json.loads(resp.read())
            assert resp.status == 200 and doc["status"] == "ok"
            assert doc["queue_depth"] == 0
            import urllib.error

            with pytest.raises(urllib.error.HTTPError) as exc_info:
                _scrape(server, "/nope")
            assert exc_info.value.code == 404
        finally:
            server.shutdown_telemetry()

    def test_split_meters_percentiles_in_stats(self, graph):
        pool = SessionPool()
        pool.register("g", graph)
        server = GraphServer(pool, max_batch=4, telemetry_port=0)
        try:
            plan = ExecutionPlan(PageRank(), max_iters=2, tol=0.0)
            server.serve([QueryRequest("g", plan) for _ in range(4)])
            st = server.stats()
            assert st.p50_total_s > 0
            assert st.p50_total_s <= st.p95_total_s <= st.p99_total_s
            assert st.p99_total_s <= DEFAULT_LATENCY_BUCKETS[-1]
        finally:
            server.shutdown_telemetry()


# ---------------------------------------------------------------------------
# checkpoint + benchmark stamp
# ---------------------------------------------------------------------------
class TestCheckpointCounters:
    def test_save_snapshot_publishes_counters(self, graph, tmp_path):
        before_saves = REGISTRY.value("repro_checkpoint_saves_total")
        before_bytes = REGISTRY.value("repro_checkpoint_bytes_total")
        sess = GraphSession(graph)
        plan = ExecutionPlan(
            PageRank(),
            max_iters=4,
            tol=0.0,
            checkpoint=CheckpointSpec(directory=str(tmp_path), every=2),
        )
        sess.run(plan)
        assert REGISTRY.value("repro_checkpoint_saves_total") - before_saves == 2
        assert REGISTRY.value("repro_checkpoint_bytes_total") > before_bytes


class TestBenchStamp:
    def test_stamp_fields(self):
        sys.path.insert(0, ".")
        try:
            from benchmarks._util import BENCH_SCHEMA_VERSION, stamp
        finally:
            sys.path.pop(0)
        payload = stamp({"rows": []}, bench="t")
        meta = payload["meta"]
        assert meta["schema_version"] == BENCH_SCHEMA_VERSION
        assert meta["bench"] == "t"
        for key in ("git_sha", "backend", "created_utc", "created_unix",
                    "python", "platform"):
            assert meta[key], key
