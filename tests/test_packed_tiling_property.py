"""Property tests for adaptive destination-aligned tile packing.

The layout contract of :meth:`repro.core.dsss.DSSSGraph.packed_sweep`
(mode="adaptive") that the compiled-sweep bit-identity proof rests on:

1. **Exact coverage** — every edge of the flat DSSS stream appears in
   exactly one tile, in stream order (tiles are windows: ``row_offset``
   partitions ``[0, m)``).
2. **Run integrity** — a (sub-shard, destination) run is never split
   across tiles: global hub slots partition tile-contiguously
   (``base_slot`` advances by exactly ``u`` per tile), so every per-run
   partial ⊕ folds the same values in the same order as the per-block
   segment reduce.
3. **Bounded padding** — on Zipf-degree (power-law) graphs of realistic
   size the padded-edge ratio stays ≤ 1.25×, where the legacy
   one-tile-per-sub-shard packing is bound by the largest hub-heavy
   sub-shard.
"""
import numpy as np
from hypothesis import given, settings, strategies as st

from _layout_checks import check_layout
from repro.core import build_dsss
from repro.core.dsss import choose_tile_edges, cut_runs_into_tiles
from repro.graph.generators import zipf
from repro.graph.preprocess import degree_and_densify


def _zipf_graph(n, m, alpha, seed, P):
    el = degree_and_densify(*zipf(n, m, alpha=alpha, seed=seed), drop_self_loops=True)
    return build_dsss(el, P)


class TestAdaptiveTiling:
    @settings(max_examples=15, deadline=None)
    @given(
        seed=st.integers(0, 50),
        n=st.integers(50, 400),
        P=st.integers(1, 8),
        alpha=st.floats(1.2, 2.4),
    )
    def test_layout_contract_holds_on_generated_graphs(
        self, seed, n, P, alpha
    ):
        """The shared invariant suite (exact coverage in stream order, no
        destination run ever split across tiles, run_dst fold map, interval
        metadata — see tests/_layout_checks.py) on hypothesis-generated
        Zipf graphs across the whole parameter space."""
        g = _zipf_graph(n, 6 * n, alpha, seed, P)
        check_layout(g, g.packed_sweep("adaptive"))

    @settings(max_examples=10, deadline=None)
    @given(
        seed=st.integers(0, 30),
        alpha=st.floats(1.5, 2.2),
        P=st.sampled_from([16, 32]),
    )
    def test_padding_ratio_bounded_on_zipf(self, seed, alpha, P):
        # Realistic power-law regime (the acceptance bound's domain):
        # enough edges that tile granularity amortises across sub-shards.
        g = _zipf_graph(4000, 30000, alpha, seed, P)
        pk = g.packed_sweep("adaptive")
        assert pk.padding_ratio <= 1.25, (
            f"padding {pk.padding_ratio:.3f} > 1.25 "
            f"(T={pk.tile_edges}, NT={pk.num_tiles}, m={g.m})"
        )

    @settings(max_examples=20, deadline=None)
    @given(
        seed=st.integers(0, 100),
        num_runs=st.integers(1, 200),
    )
    def test_greedy_cut_respects_capacity_and_order(self, seed, num_runs):
        rng = np.random.default_rng(seed)
        run_len = rng.integers(1, 40, size=num_runs)
        T = choose_tile_edges(run_len)
        assert T >= int(run_len.max())
        bounds = np.concatenate([[0], np.cumsum(run_len)])
        tiles = cut_runs_into_tiles(bounds, T)
        # Tiles partition the run sequence in order...
        assert tiles[0][0] == 0 and tiles[-1][1] == num_runs
        for (a0, a1), (b0, b1) in zip(tiles, tiles[1:]):
            assert a1 == b0 and a0 < a1
        # ...and each stays within capacity.
        for r0, r1 in tiles:
            assert bounds[r1] - bounds[r0] <= T
