"""Property tests for the reliability layer (repro.reliability).

Three contracts under arbitrary inputs:

1. **Snapshot round-trip** — any dict of arrays (arbitrary dtypes/shapes,
   including empty arrays) plus any JSON-able metadata survives
   ``save_snapshot → load_snapshot`` value- and dtype-identically.
2. **Keep-N** — after any sequence of snapshot saves, exactly the newest
   ``keep`` sweeps remain on disk and ``latest_snapshot`` names the
   newest; no tmp debris survives a save.
3. **Injector determinism** — a ``FaultPlan`` is a pure function of
   ``(specs, seed)``: two injectors built from equal plans make identical
   fire/pass decisions for any identity stream, and the per-spec
   ``times`` budget is never exceeded.
"""
import os
import tempfile

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.reliability import (
    FaultPlan,
    FaultSpec,
    TransientFault,
    latest_snapshot,
    list_snapshots,
    load_snapshot,
    save_snapshot,
)

_dtypes = st.sampled_from(
    [np.float32, np.float64, np.int32, np.int64, np.uint8, np.bool_]
)


@st.composite
def _array(draw):
    dtype = draw(_dtypes)
    shape = draw(
        st.lists(st.integers(min_value=0, max_value=5), min_size=0, max_size=3)
    )
    rng = np.random.default_rng(draw(st.integers(0, 2**32 - 1)))
    if dtype == np.bool_:
        return rng.random(shape) < 0.5
    if np.issubdtype(dtype, np.floating):
        return rng.standard_normal(shape).astype(dtype)
    return rng.integers(0, 100, size=shape).astype(dtype)


_arrays = st.dictionaries(
    st.text(
        alphabet=st.characters(min_codepoint=97, max_codepoint=122),
        min_size=1,
        max_size=8,
    ),
    _array(),
    min_size=0,
    max_size=4,
)

_meta = st.dictionaries(
    st.text(
        alphabet=st.characters(min_codepoint=97, max_codepoint=122),
        min_size=1,
        max_size=8,
    ),
    st.one_of(
        st.integers(min_value=-(2**31), max_value=2**31),
        st.floats(allow_nan=False, allow_infinity=False, width=32),
        st.text(max_size=16),
        st.booleans(),
    ),
    max_size=4,
)


@settings(max_examples=40, deadline=None)
@given(arrays=_arrays, meta=_meta, sweep=st.integers(0, 10**6))
def test_snapshot_round_trip(arrays, meta, sweep):
    with tempfile.TemporaryDirectory() as d:
        path = save_snapshot(d, sweep, arrays, meta, keep=1)
        got_arrays, got_meta = load_snapshot(path)
        assert got_meta == meta
        assert set(got_arrays) == set(arrays)
        for k, a in arrays.items():
            assert got_arrays[k].dtype == a.dtype
            assert got_arrays[k].shape == a.shape
            assert np.array_equal(got_arrays[k], a)


@settings(max_examples=25, deadline=None)
@given(
    sweeps=st.lists(
        st.integers(1, 50), min_size=1, max_size=8, unique=True
    ),
    keep=st.integers(1, 4),
)
def test_keep_n_retention(sweeps, keep):
    with tempfile.TemporaryDirectory() as d:
        for s in sorted(sweeps):
            save_snapshot(d, s, {"x": np.arange(3)}, {"sweep": s}, keep=keep)
        kept = list_snapshots(d)
        expected = sorted(sweeps)[-keep:]
        assert [os.path.basename(p) for p in kept] == [
            f"sweep_{s:08d}.npz" for s in expected
        ]
        assert latest_snapshot(d) == kept[-1]
        assert not [n for n in os.listdir(d) if n.endswith(".tmp")]


@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(0, 2**31),
    rate=st.floats(min_value=0.0, max_value=1.0),
    times=st.one_of(st.none(), st.integers(0, 8)),
    n=st.integers(1, 64),
)
def test_injector_is_deterministic_and_budgeted(seed, rate, times, n):
    plan = FaultPlan(
        specs=(FaultSpec(site="h2d", kind="transient", rate=rate, times=times),),
        seed=seed,
    )

    def run():
        inj = plan.injector()
        out = []
        for i in range(n):
            try:
                inj.check("h2d", f"xfer:{i}")
                out.append(0)
            except TransientFault:
                out.append(1)
        return out, inj.fired()

    (a, fired_a), (b, fired_b) = run(), run()
    assert a == b and fired_a == fired_b
    if times is not None:
        assert fired_a <= times


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31), n=st.integers(1, 32))
def test_storage_decisions_deterministic(seed, n):
    plan = FaultPlan.storage_corrupt("seg", times=3, seed=seed)

    def run():
        inj = plan.injector()
        return [inj.storage_read("seg_a", attempt) for attempt in range(n)]

    assert run() == run()
    # attempt-indexed: corrupt exactly while attempt < times
    decisions = run()
    for attempt, d in enumerate(decisions):
        assert d == ("corrupt" if attempt < 3 else None)
