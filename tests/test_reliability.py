"""The reliability layer (repro.reliability) — chaos matrix and regressions.

Everything here runs on tiny graphs so the lane stays fast, but the
assertions are the strong ones the subsystem promises:

* **Crash/resume**: a PageRank/BFS run killed by an injected crash at
  sweep N and resumed from its latest snapshot produces *bit-identical*
  results and *field-identical* meters (minus wall clock) vs the same
  run never interrupted — across residency {device, host, disk} ×
  execution {per_block, packed, packed_kernel};
* **Self-healing reads**: an injected-corrupt segment is retried with
  backoff and healed, or quarantined behind a structured
  ``DegradedReadError`` naming the exact segment and tile range — the
  engine never computes on garbage. ``verify --repair`` rebuilds a
  really-byte-flipped container from its raw edge source;
* **Serving degradation**: past-deadline requests are shed or cancelled
  cooperatively at a sweep boundary (other in-flight requests
  unaffected), transient faults retry with backoff, a persistently
  failing graph trips its circuit breaker and recovers half-open;
* **Pool regressions**: pinned sessions are never evicted, deferred
  eviction on release drops stale staged bytes, acquire/evict races are
  atomic under the pool lock;
* **CheckpointManager hardening**: crash debris (orphan tmp dirs,
  truncated step dirs) is never offered for restore and is swept by GC;
* the ``repro.runtime.fault`` shim keeps exporting the legacy names.
"""
import asyncio
import dataclasses
import threading
import time

import numpy as np
import pytest

from repro.core import BFS, ExecutionPlan, GraphSession, PageRank, build_dsss
from repro.core.plan import CheckpointSpec
from repro.graph.generators import erdos_renyi
from repro.graph.preprocess import degree_and_densify
from repro.reliability import (
    FaultInjector,
    FaultPlan,
    FaultSpec,
    InjectedCrash,
    SnapshotError,
    TransientFault,
    latest_snapshot,
    list_snapshots,
)
from repro.serving import (
    CircuitOpenError,
    DeadlineExceeded,
    GraphServer,
    QueryRequest,
    SessionPool,
)
from repro.storage import DegradedReadError, ReadPolicy, write_dsss

pytestmark = pytest.mark.chaos

RESIDENCIES = ["device", "host", "disk"]
EXECUTIONS = ["per_block", "packed", "packed_kernel"]


def _graph(n=120, m=700, seed=11, P=4):
    src, dst = erdos_renyi(n, m, seed=seed)
    el = degree_and_densify(src, dst, drop_self_loops=True)
    return build_dsss(el, P)


@pytest.fixture(scope="module")
def graph():
    return _graph()


@pytest.fixture(scope="module")
def dsss_path(graph, tmp_path_factory):
    path = str(tmp_path_factory.mktemp("dsss") / "g.dsss")
    write_dsss(graph, path)
    return path


def _session(graph, dsss_path, residency, execution, **kw):
    if residency == "disk":
        return GraphSession.open(dsss_path, execution=execution, **kw)
    return GraphSession(graph, residency=residency, execution=execution, **kw)


def _meters_dict(meters, *, ignore_wall=True):
    d = {f.name: getattr(meters, f.name) for f in dataclasses.fields(meters)}
    if ignore_wall:
        d.pop("wall_seconds")
    return d


# ---------------------------------------------------------------------------
# FaultPlan / FaultInjector unit contract
# ---------------------------------------------------------------------------
class TestFaultPlan:
    def test_validation(self):
        with pytest.raises(ValueError):
            FaultSpec(site="nope")
        with pytest.raises(ValueError):
            FaultSpec(site="h2d", kind="meteor")
        with pytest.raises(ValueError):
            FaultSpec(site="h2d", rate=1.5)
        with pytest.raises(TypeError):
            FaultPlan(specs=[1, 2])

    def test_crash_budget_spent_once(self):
        inj = FaultPlan.crash_at_sweep(2).injector()
        inj.check("sweep", 0)
        inj.check("sweep", 1)
        with pytest.raises(InjectedCrash):
            inj.check("sweep", 2)
        # the budget is spent: a resumed run passes the same boundary
        inj.check("sweep", 2)
        assert inj.fired("sweep") == 1

    def test_rate_coin_is_deterministic(self):
        def decisions(seed):
            inj = FaultPlan.h2d_transient(rate=0.4, times=None, seed=seed).injector()
            out = []
            for i in range(64):
                try:
                    inj.check("h2d", f"id:{i}")
                    out.append(0)
                except TransientFault:
                    out.append(1)
            return out

        assert decisions(7) == decisions(7)
        assert decisions(7) != decisions(8)

    def test_times_budget_bounds_rate_faults(self):
        inj = FaultPlan.h2d_transient(rate=1.0, times=3, seed=0).injector()
        fired = 0
        for i in range(10):
            try:
                inj.check("h2d", i)
            except TransientFault:
                fired += 1
        assert fired == 3
        assert inj.fired() == 3

    def test_merge_keeps_both_specs(self):
        plan = FaultPlan.crash_at_sweep(1).merge(FaultPlan.storage_corrupt("p_src"))
        assert len(plan.specs) == 2
        assert isinstance(plan.injector(), FaultInjector)


# ---------------------------------------------------------------------------
# Crash → snapshot → resume: bit-identity + meter identity across the matrix
# ---------------------------------------------------------------------------
class TestCrashResume:
    @pytest.mark.parametrize("residency", RESIDENCIES)
    @pytest.mark.parametrize("execution", EXECUTIONS)
    def test_pagerank_resume_bit_identical(
        self, graph, dsss_path, tmp_path, residency, execution
    ):
        plan = ExecutionPlan(
            PageRank(),
            max_iters=6,
            tol=0.0,
            checkpoint=CheckpointSpec(
                directory=str(tmp_path / "snaps"), every=2, keep=2
            ),
        )
        ref = _session(graph, dsss_path, residency, execution).run(
            dataclasses.replace(plan, checkpoint=None)
        )

        sess = _session(graph, dsss_path, residency, execution)
        sess.inject_faults(FaultPlan.crash_at_sweep(5))
        with pytest.raises(InjectedCrash):
            sess.run(plan)
        snaps = list_snapshots(str(tmp_path / "snaps"))
        assert [s.split("/")[-1] for s in snaps] == [
            "sweep_00000002.npz",
            "sweep_00000004.npz",
        ]

        resumed = sess.run(plan, resume_from=str(tmp_path / "snaps"))
        assert (
            np.asarray(resumed.output) == np.asarray(ref.output)
        ).all(), "resumed result is not bit-identical"
        assert _meters_dict(resumed.meters) == _meters_dict(ref.meters)

    def test_bfs_resume_on_disk(self, graph, dsss_path, tmp_path):
        plan = ExecutionPlan(
            BFS(),
            program_kwargs={"root": 3},
            checkpoint=CheckpointSpec(directory=str(tmp_path / "s"), every=1),
        )
        ref = GraphSession.open(dsss_path, execution="packed").run(
            dataclasses.replace(plan, checkpoint=None)
        )
        sess = GraphSession.open(dsss_path, execution="packed")
        sess.inject_faults(FaultPlan.crash_at_sweep(2))
        with pytest.raises(InjectedCrash):
            sess.run(plan)
        resumed = sess.run(plan, resume_from=True)  # True → plan's directory
        assert (np.asarray(resumed.output) == np.asarray(ref.output)).all()
        assert _meters_dict(resumed.meters) == _meters_dict(ref.meters)

    def test_resume_rejects_mismatched_plan(self, graph, tmp_path):
        ck = CheckpointSpec(directory=str(tmp_path), every=1)
        sess = GraphSession(graph)
        sess.run(ExecutionPlan(PageRank(), max_iters=2, tol=0.0, checkpoint=ck))
        with pytest.raises(SnapshotError):
            sess.run(
                ExecutionPlan(BFS(), program_kwargs={"root": 0}),
                resume_from=latest_snapshot(str(tmp_path)),
            )

    def test_resume_from_empty_dir_is_fresh_start(self, graph, tmp_path):
        ref = GraphSession(graph).run(ExecutionPlan(PageRank(), max_iters=3, tol=0.0))
        got = GraphSession(graph).run(
            ExecutionPlan(PageRank(), max_iters=3, tol=0.0),
            resume_from=str(tmp_path),  # exists, holds no snapshots
        )
        assert (np.asarray(got.output) == np.asarray(ref.output)).all()

    def test_checkpoint_in_plan_key(self, tmp_path):
        a = ExecutionPlan(PageRank())
        b = ExecutionPlan(
            PageRank(), checkpoint=CheckpointSpec(directory=str(tmp_path))
        )
        assert a.batch_key() != b.batch_key()
        with pytest.raises(TypeError):
            ExecutionPlan(PageRank(), checkpoint=str(tmp_path))


# ---------------------------------------------------------------------------
# Self-healing storage reads
# ---------------------------------------------------------------------------
class TestSelfHealingReads:
    def test_transient_corruption_heals(self, graph, dsss_path):
        plan = ExecutionPlan(PageRank(), max_iters=4, tol=0.0)
        ref = GraphSession.open(dsss_path).run(plan)
        sess = GraphSession.open(
            dsss_path,
            verify=False,
            read_policy=ReadPolicy(max_retries=3, backoff_s=0.0),
            fault_plan=FaultPlan.storage_corrupt("p_dst", times=2),
        )
        got = sess.run(plan)
        assert sess.store.healed_reads >= 1
        assert not sess.store.quarantined
        assert (np.asarray(got.output) == np.asarray(ref.output)).all()

    def test_persistent_corruption_quarantines(self, dsss_path):
        sess = GraphSession.open(
            dsss_path,
            verify=False,
            read_policy=ReadPolicy(max_retries=2, backoff_s=0.0),
            fault_plan=FaultPlan.storage_corrupt("p_dst", times=None),
        )
        plan = ExecutionPlan(PageRank(), max_iters=3)
        with pytest.raises(DegradedReadError) as ei:
            sess.run(plan)
        err = ei.value
        assert err.segment == "p_dst"
        assert err.attempts == 3  # 1 + max_retries
        assert err.tile_range is not None
        assert "p_dst" in sess.store.quarantined
        # quarantine short-circuits: the same structured error, instantly
        with pytest.raises(DegradedReadError):
            sess.run(plan)

    def test_short_read_quarantines(self, dsss_path):
        sess = GraphSession.open(
            dsss_path,
            verify=False,
            read_policy=ReadPolicy(max_retries=1, backoff_s=0.0),
            fault_plan=FaultPlan.storage_short("blk_", times=None),
        )
        with pytest.raises(DegradedReadError):
            sess.run(ExecutionPlan(PageRank(), max_iters=3, execution="per_block"))

    def test_no_policy_keeps_failfast_contract(self, dsss_path):
        from repro.storage import ChecksumError

        sess = GraphSession.open(
            dsss_path,
            verify=False,
            fault_plan=FaultPlan.storage_corrupt("p_dst", times=None),
        )
        sess.store.attach_faults(sess.fault_injector)
        with pytest.raises(ChecksumError):
            sess.store.verify()

    def test_cli_repair_rebuilds_flipped_container(self, tmp_path):
        from repro.storage.__main__ import main as storage_main

        edges = tmp_path / "edges.txt"
        rng = np.random.default_rng(5)
        lines = [
            f"{a} {b}"
            for a, b in zip(rng.integers(0, 60, 400), rng.integers(0, 60, 400))
        ]
        edges.write_text("\n".join(lines) + "\n")
        out = str(tmp_path / "g.dsss")
        assert storage_main(["build", str(edges), out, "--P", "4"]) == 0

        from repro.storage import open_dsss

        seg = next(iter(open_dsss(out, verify=False).segments.values()))
        with open(out, "r+b") as f:  # real media damage, not an injector
            f.seek(seg.offset + 1)
            byte = f.read(1)
            f.seek(seg.offset + 1)
            f.write(bytes([byte[0] ^ 0xFF]))
        assert storage_main(["verify", out]) == 1
        assert storage_main(["verify", out, "--repair"]) == 1  # no --source
        assert (
            storage_main(["verify", out, "--repair", "--source", str(edges)]) == 0
        )
        assert storage_main(["verify", out]) == 0  # clean after the swap

    def test_repair_noop_on_clean_container(self, dsss_path):
        from repro.reliability.repair import repair_dsss

        report = repair_dsss(dsss_path)
        assert report["damaged"] == []
        assert report["repaired"] is False


# ---------------------------------------------------------------------------
# Serving: deadlines, retries, circuit breaker
# ---------------------------------------------------------------------------
SERVE_KW = dict(residency="host", execution="per_block", memory_budget=4096)


class TestServingDegradation:
    def test_expired_request_is_shed(self, graph):
        pool = SessionPool()
        key = pool.ensure(graph, **SERVE_KW)
        srv = GraphServer(pool)
        with pytest.raises(DeadlineExceeded):
            srv.serve(
                [
                    QueryRequest(
                        key,
                        ExecutionPlan(PageRank(), max_iters=50, tol=0.0),
                        deadline_s=1e-6,
                    )
                ]
            )
        st = srv.stats()
        assert st.timeouts == 1
        assert st.failed == 0  # a timeout is a shed, not a failure

    def test_midrun_cancel_leaves_others_unaffected(self, graph):
        pool = SessionPool()
        key = pool.ensure(graph, **SERVE_KW)

        async def go():
            async with GraphServer(pool, max_batch=1, max_wait_ms=0.0) as srv:
                doomed = await srv.submit(
                    QueryRequest(
                        key,
                        ExecutionPlan(PageRank(), max_iters=5000, tol=0.0),
                        deadline_s=0.05,
                    )
                )
                fine = await srv.submit(
                    QueryRequest(key, ExecutionPlan(BFS(), program_kwargs={"root": 0}))
                )
                got = await asyncio.gather(doomed, fine, return_exceptions=True)
                return got, srv.stats()

        (doomed, fine), st = asyncio.run(go())
        assert isinstance(doomed, DeadlineExceeded)
        assert not isinstance(fine, Exception)
        ref = GraphSession(graph, **SERVE_KW).run(
            ExecutionPlan(BFS(), program_kwargs={"root": 0})
        )
        assert (np.asarray(fine.result.output) == np.asarray(ref.output)).all()
        assert st.timeouts == 1 and st.failed == 0

    def test_transient_fault_retries_to_identical_result(self, graph):
        plan = ExecutionPlan(PageRank(), max_iters=4, tol=0.0)
        ref = GraphSession(graph, **SERVE_KW).run(plan)
        pool = SessionPool()
        key = pool.ensure(graph, **SERVE_KW)
        # burst bigger than the fetch layer's own retry budget → escapes
        # to the serving retry loop
        pool.session(key).inject_faults(
            FaultPlan.h2d_transient(rate=1.0, times=5, seed=3)
        )
        srv = GraphServer(pool)
        out = srv.serve([QueryRequest(key, plan, max_retries=3)])
        st = srv.stats()
        assert st.retries >= 1 and st.completed == 1 and st.failed == 0
        assert (np.asarray(out[0].result.output) == np.asarray(ref.output)).all()

    def test_retry_budget_exhaustion_fails(self, graph):
        pool = SessionPool()
        key = pool.ensure(graph, **SERVE_KW)
        pool.session(key).inject_faults(
            FaultPlan.h2d_transient(rate=1.0, times=None, seed=1)
        )
        srv = GraphServer(pool)
        with pytest.raises(TransientFault):
            srv.serve(
                [QueryRequest(key, ExecutionPlan(PageRank(), max_iters=3), max_retries=1)]
            )
        st = srv.stats()
        assert st.retries == 1 and st.failed == 1

    def test_circuit_breaker_trips_and_recovers(self, graph):
        pool = SessionPool(breaker_threshold=2, breaker_cooldown_s=0.15)
        key = pool.ensure(graph, **SERVE_KW)
        sess = pool.session(key)
        sess.inject_faults(FaultPlan.h2d_transient(rate=1.0, times=None, seed=1))
        srv = GraphServer(pool)
        plan = ExecutionPlan(PageRank(), max_iters=3)
        for _ in range(2):
            with pytest.raises(TransientFault):
                srv.serve([QueryRequest(key, plan)])
        assert pool.breaker_open(key)
        with pytest.raises(CircuitOpenError):
            srv.serve([QueryRequest(key, plan)])
        assert srv.stats().breaker_sheds == 1
        assert pool.stats().breakers_open == 1
        time.sleep(0.2)
        sess.inject_faults(None)  # the graph "recovers"
        out = srv.serve([QueryRequest(key, plan)])  # half-open trial
        assert len(out) == 1
        assert pool.stats().breakers_open == 0

    def test_failed_halfopen_trial_retrips(self, graph):
        pool = SessionPool(breaker_threshold=2, breaker_cooldown_s=0.05)
        key = pool.ensure(graph, **SERVE_KW)
        pool.session(key).inject_faults(
            FaultPlan.h2d_transient(rate=1.0, times=None, seed=1)
        )
        srv = GraphServer(pool)
        plan = ExecutionPlan(PageRank(), max_iters=3)
        for _ in range(2):
            with pytest.raises(TransientFault):
                srv.serve([QueryRequest(key, plan)])
        time.sleep(0.08)  # cooldown expires → half-open
        with pytest.raises(TransientFault):
            srv.serve([QueryRequest(key, plan)])  # trial fails...
        assert pool.breaker_open(key)  # ...and re-trips instantly

    def test_deadline_validation(self):
        with pytest.raises(ValueError):
            QueryRequest("g", ExecutionPlan(PageRank()), deadline_s=0.0)
        with pytest.raises(ValueError):
            QueryRequest("g", ExecutionPlan(PageRank()), max_retries=-1)


# ---------------------------------------------------------------------------
# SessionPool regressions: pinning, deferred eviction, race atomicity
# ---------------------------------------------------------------------------
class TestPoolRegressions:
    def test_pinned_session_never_evicted(self, graph):
        pool = SessionPool(max_open=1)
        a = pool.ensure(graph, residency="host")
        b = pool.ensure(_graph(seed=12), residency="host")
        sess_a = pool.acquire(a)
        pool.session(b)  # over max_open, but `a` is pinned
        assert pool._entries[a].session is sess_a  # survived
        pool.release(a)
        # a is now the idle LRU victim; the deferred eviction on release
        # restored the bound
        assert pool.stats().open_sessions == 1
        assert pool._entries[a].session is None

    def test_release_drops_stale_staged_bytes(self, graph):
        # Both graphs pinned with max_open=1: bounds temporarily exceeded.
        pool = SessionPool(max_open=1)
        a = pool.ensure(graph, residency="host")
        b = pool.ensure(_graph(seed=13), residency="host")
        pool.acquire(a)
        pool.acquire(b)
        assert pool.stats().open_sessions == 2  # nothing evictable yet
        pool.release(a)
        stats = pool.stats()
        assert stats.open_sessions == 1  # stale bytes dropped on release
        assert pool._entries[b].session is not None  # still-pinned survivor

    def test_double_release_raises(self, graph):
        pool = SessionPool()
        a = pool.ensure(graph)
        pool.acquire(a)
        pool.release(a)
        with pytest.raises(RuntimeError):
            pool.release(a)

    def test_evict_respects_pin(self, graph):
        pool = SessionPool()
        a = pool.ensure(graph)
        pool.acquire(a)
        assert pool.evict(a) is False
        pool.release(a)
        assert pool.evict(a) is True
        assert pool.evict(a) is False  # already cold

    def test_acquire_evict_race_is_atomic(self, graph):
        pool = SessionPool(max_open=1)
        keys = [pool.ensure(_graph(seed=20 + i), residency="host") for i in range(3)]
        errors = []

        def hammer(key):
            try:
                for _ in range(25):
                    s = pool.acquire(key)
                    assert s is not None
                    assert pool._entries[key].session is s  # pin held it open
                    pool.release(key)
            except Exception as e:  # pragma: no cover - failure capture
                errors.append(e)

        threads = [threading.Thread(target=hammer, args=(k,)) for k in keys]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert errors == []
        assert all(e.in_use == 0 for e in pool._entries.values())
        assert pool.stats().open_sessions <= 1


# ---------------------------------------------------------------------------
# CheckpointManager hardening
# ---------------------------------------------------------------------------
class TestCheckpointManagerHardening:
    def _state(self, v):
        return {"w": np.full((4,), float(v)), "b": np.arange(3.0) * v}

    def test_crash_debris_never_offered_for_restore(self, tmp_path):
        from repro.checkpoint.manager import CheckpointManager

        mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
        mgr.save(1, self._state(1))
        # Simulate a crash mid-save of step 2: orphan tmp dir, and a
        # published dir whose payload never landed.
        (tmp_path / ".tmp_step_2").mkdir()
        (tmp_path / ".tmp_step_2" / "arrays.npz").write_bytes(b"partial")
        (tmp_path / "step_0000000003").mkdir()
        (tmp_path / "step_0000000003" / "manifest.json").write_text("{}")
        assert mgr.all_steps() == [1]  # debris invisible
        restored, step = mgr.restore(self._state(0))
        assert step == 1
        assert (np.asarray(restored["w"]) == 1.0).all()
        mgr.save(2, self._state(2))  # next save sweeps the debris
        assert not (tmp_path / ".tmp_step_2").exists()
        assert not (tmp_path / "step_0000000003").exists()
        assert mgr.all_steps() == [1, 2]

    def test_resave_same_step_never_loses_the_copy(self, tmp_path):
        from repro.checkpoint.manager import CheckpointManager

        mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
        mgr.save(5, self._state(1))
        mgr.save(5, self._state(2))  # supersede in place
        restored, step = mgr.restore(self._state(0))
        assert step == 5
        assert (np.asarray(restored["w"]) == 2.0).all()
        assert not (tmp_path / ".trash_step_5").exists()

    def test_injected_crash_during_publish(self, tmp_path, monkeypatch):
        """Crash after the old step is renamed aside but before the new
        one lands: the trash copy still exists → nothing was lost; the
        next save completes and sweeps it."""
        import os as _os

        from repro.checkpoint.manager import CheckpointManager

        mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
        mgr.save(7, self._state(1))
        real_rename = _os.rename
        calls = {"n": 0}

        def crashy(src, dst):
            calls["n"] += 1
            if calls["n"] == 2:  # first = aside, second = publish
                raise OSError("injected crash at publish")
            real_rename(src, dst)

        monkeypatch.setattr("repro.checkpoint.manager.os.rename", crashy)
        with pytest.raises(OSError):
            mgr.save(7, self._state(2))
        monkeypatch.undo()
        assert mgr.all_steps() == []  # step 7 is mid-swap...
        assert (tmp_path / ".trash_step_7").exists()  # ...but not lost
        mgr.save(8, self._state(3))  # recovery save sweeps the debris
        assert mgr.all_steps() == [8]
        assert not (tmp_path / ".trash_step_7").exists()

    def test_keep_n_pruning(self, tmp_path):
        from repro.checkpoint.manager import CheckpointManager

        mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
        for s in range(1, 6):
            mgr.save(s, self._state(s))
        assert mgr.all_steps() == [4, 5]


# ---------------------------------------------------------------------------
# Legacy shim
# ---------------------------------------------------------------------------
def test_runtime_fault_shim_reexports():
    import repro.reliability.faults as canonical
    import repro.runtime.fault as shim

    for name in (
        "FailureInjector",
        "SimulatedFailure",
        "StepTimer",
        "StragglerWatchdog",
        "elastic_device_count",
    ):
        assert getattr(shim, name) is getattr(canonical, name)
