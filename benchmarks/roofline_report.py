"""Aggregate dry-run JSONs into the EXPERIMENTS.md roofline table.

Also models the update-sweep HBM traffic of the session's three execution
backends (``per_block`` / ``packed`` / ``packed_kernel``) for the
paper-scale graphs, so the roofline story covers the path the engine
actually dispatches — not just the distributed shard_map cell.
"""
import argparse
import glob
import json
import os
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

from repro.runtime.hlo_analysis import HW  # noqa: E402  (pure-python module)

# Per-edge-slot byte costs of one update sweep, by execution backend.
# elem = 4 (f32/int32); edge record = src + dst + weight = 12 B.
#
# * ``per_block``: streams the raw (unpadded) edges once (12 B) plus the
#   source-attribute gather (4 B), but pays hub-interval traffic per
#   sub-shard column — each of the P block rows re-reads and re-writes
#   its destination interval, an O(n·P) term no other path has.
# * ``packed`` (XLA scan): consumes the padded tile leaves (src, dst,
#   run_local, run_dst = 16 B/slot, + 4 B weights) plus the gather
#   (4 B), and — because the scan body is a chain of separate gather /
#   segment ops — XLA materializes the per-slot contributions and the
#   windowed run partials to HBM between them: two extra write+read
#   round trips of 4 B each (16 B/slot).
# * ``packed_kernel`` (fused Pallas): the same padded tile leaves and
#   gather, but contributions and run partials never leave VMEM — the
#   16 B/slot of intermediate traffic is fused away, leaving one
#   HBM→VMEM DMA per tile.
#
# All three read+write the attribute vectors (8 B/vertex); the padded
# paths pay the packing's padding ratio on every per-slot term.
_EDGE_RECORD = 12.0  # src + dst + w, bytes
_TILE_LEAVES = 16.0  # src + dst + run_local + run_dst, bytes/slot
_GATHER = 4.0  # source-attribute gather, bytes/slot
_INTERMEDIATE = 16.0  # scan-only: contribs + run partials, write+read
_WEIGHT = 4.0


def sweep_execution_model(n, m, P=32, padding_ratio=1.1, weighted=True):
    """Per-sweep FLOPs / HBM bytes of each execution backend.

    FLOPs are identical across backends (3 per edge: gather-combine
    mul+add, reduce add — the paths differ in data movement, not math);
    returns ``{backend: {flops, hbm_bytes, intensity, compute_s,
    memory_s, bound}}`` with times on the :class:`HW` roofline.
    """
    hw = HW()
    flops = 3.0 * m
    w = _WEIGHT if weighted else 0.0
    vertex = 8.0 * n  # attrs read + write
    pad = padding_ratio * m
    per_slot_tiles = _TILE_LEAVES + w + _GATHER
    bytes_by = {
        "per_block": (_EDGE_RECORD + _GATHER) * m + vertex + 8.0 * n * P,
        "packed": (per_slot_tiles + _INTERMEDIATE) * pad + vertex,
        "packed_kernel": per_slot_tiles * pad + vertex,
    }
    out = {}
    for backend, hbm in bytes_by.items():
        compute_s = flops / hw.peak_flops
        memory_s = hbm / hw.hbm_bw
        out[backend] = {
            "flops": flops,
            "hbm_bytes": hbm,
            "intensity": flops / hbm,
            "compute_s": compute_s,
            "memory_s": memory_s,
            "bound": "memory" if memory_s >= compute_s else "compute",
        }
    return out


def fmt_execution_table(n, m, P=32, padding_ratio=1.1, weighted=True):
    model = sweep_execution_model(n, m, P, padding_ratio, weighted)
    base = model["packed_kernel"]["hbm_bytes"]
    hdr = (
        "| execution | HBM GB/sweep | FLOP/B | memory (ms) | compute (ms) | "
        "bound | traffic vs kernel |"
    )
    lines = [hdr, "|" + "---|" * 7]
    for backend in ("per_block", "packed", "packed_kernel"):
        r = model[backend]
        lines.append(
            f"| {backend} | {r['hbm_bytes']/1e9:.2f} | "
            f"{r['intensity']:.3f} | {r['memory_s']*1e3:.2f} | "
            f"{r['compute_s']*1e3:.2f} | {r['bound']} | "
            f"{r['hbm_bytes']/base:.2f}x |"
        )
    return "\n".join(lines)


def fmt_trace_vs_roofline(trace_path, padding_ratio=1.1):
    """Measured per-sweep time (from an exported engine trace) against the
    analytic roofline of the backend that actually ran.

    The trace's "run" spans carry graph shape and backend; their "sweep"
    children carry measured wall time. ``measured/roofline`` is mean
    sweep time over the model's binding time — ≫1 means the backend is
    leaving roofline on the table (dispatch overhead, host scheduling);
    ≈1 is as fast as the memory system allows.
    """
    from repro.runtime.trace_analysis import load_events, run_summaries

    summaries = run_summaries(load_events(trace_path))
    hdr = (
        "| run | program | backend | residency | n | m | sweeps | "
        "measured sweep (ms) | roofline (ms) | measured/roofline |"
    )
    lines = [hdr, "|" + "---|" * 10]
    for r in summaries:
        if not r["n"] or not r["m"] or not r["sweeps"]:
            continue
        model = sweep_execution_model(
            r["n"], r["m"], P=r["P"] or 32, padding_ratio=padding_ratio
        )
        backend = (
            r["execution"] if r["execution"] in model else "per_block"
        )
        mm = model[backend]
        bound_s = max(mm["memory_s"], mm["compute_s"])
        meas = r["mean_sweep_s"]
        lines.append(
            f"| {r['run']} | {r['program']} | {backend} | "
            f"{r['residency']} | {r['n']:,} | {r['m']:,} | {r['sweeps']} | "
            f"{meas * 1e3:.3f} | {bound_s * 1e3:.3f} | "
            f"{meas / bound_s:.1f}x |"
        )
    return "\n".join(lines)


def load_all(out_dir: str = "results/dryrun"):
    rows = []
    for fn in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(fn) as f:
            rows.append(json.load(f))
    return rows


def fmt_table(rows, mesh="single"):
    rows = [r for r in rows if r["mesh"] == mesh]
    hdr = (
        "| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
        "dominant | useful FLOPs | roofline frac | peak GB/chip | fits |"
    )
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        peak = r.get("memory", {}).get("peak_estimate", r.get("bytes_per_chip_peak", 0)) or 0
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']*1e3:.2f} | "
            f"{r['memory_s']*1e3:.2f} | {r['collective_s']*1e3:.2f} | "
            f"{r['dominant']} | {r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} | {peak/1e9:.1f} | "
            f"{'Y' if peak < 16e9 else 'OVER'} |"
        )
    return "\n".join(lines)


# Paper Table III scales (kept in sync with core/distributed.GRAPH_SCALES,
# which is not imported here: that module pulls in jax at import time).
_PAPER_GRAPHS = {
    "live-journal": (4_850_000, 69_000_000),
    "twitter": (41_700_000, 1_470_000_000),
    "yahoo-web": (720_000_000, 6_640_000_000),
}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="results/dryrun")
    ap.add_argument("--P", type=int, default=32)
    ap.add_argument(
        "--padding-ratio", type=float, default=1.1,
        help="adaptive-packing padded/raw edge ratio for the execution "
        "model (bench_sweep.py measures ~1.0–1.1 on power-law graphs)",
    )
    ap.add_argument(
        "--trace", default=None,
        help="exported engine trace (Chrome JSON or .jsonl span dump); "
        "report measured per-sweep time vs the roofline model per "
        "execution backend instead of the dry-run tables",
    )
    args = ap.parse_args(argv)
    if args.trace:
        print(f"\n### measured vs roofline ({args.trace})\n")
        print(fmt_trace_vs_roofline(args.trace, args.padding_ratio))
        return
    rows = load_all(args.out_dir)
    for mesh in ("single", "multi"):
        print(f"\n### mesh: {mesh}\n")
        print(fmt_table(rows, mesh))
    # Single-machine execution-backend roofline, per paper-scale graph.
    for name, (n, m) in _PAPER_GRAPHS.items():
        print(
            f"\n### execution backends: {name} "
            f"(n={n:,}, m={m:,}, P={args.P}, one update sweep)\n"
        )
        print(fmt_execution_table(n, m, args.P, args.padding_ratio))
    # hillclimb candidates
    single = [r for r in rows if r["mesh"] == "single" and not r["arch"].startswith("graph:")]
    if single:
        worst = min(single, key=lambda r: r["roofline_fraction"])
        coll = max(single, key=lambda r: r["collective_s"])
        print("\nworst roofline fraction:", worst["arch"], worst["shape"], f"{worst['roofline_fraction']:.3f}")
        print("most collective-bound:  ", coll["arch"], coll["shape"], f"{coll['collective_s']*1e3:.1f}ms")


if __name__ == "__main__":
    main()
