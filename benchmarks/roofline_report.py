"""Aggregate dry-run JSONs into the EXPERIMENTS.md roofline table."""
import glob
import json
import os


def load_all(out_dir: str = "results/dryrun"):
    rows = []
    for fn in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(fn) as f:
            rows.append(json.load(f))
    return rows


def fmt_table(rows, mesh="single"):
    rows = [r for r in rows if r["mesh"] == mesh]
    hdr = (
        "| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
        "dominant | useful FLOPs | roofline frac | peak GB/chip | fits |"
    )
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        peak = r.get("memory", {}).get("peak_estimate", r.get("bytes_per_chip_peak", 0)) or 0
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']*1e3:.2f} | "
            f"{r['memory_s']*1e3:.2f} | {r['collective_s']*1e3:.2f} | "
            f"{r['dominant']} | {r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} | {peak/1e9:.1f} | "
            f"{'Y' if peak < 16e9 else 'OVER'} |"
        )
    return "\n".join(lines)


def main():
    rows = load_all()
    for mesh in ("single", "multi"):
        print(f"\n### mesh: {mesh}\n")
        print(fmt_table(rows, mesh))
    # hillclimb candidates
    single = [r for r in rows if r["mesh"] == "single" and not r["arch"].startswith("graph:")]
    if single:
        worst = min(single, key=lambda r: r["roofline_fraction"])
        coll = max(single, key=lambda r: r["collective_s"])
        print("\nworst roofline fraction:", worst["arch"], worst["shape"], f"{worst['roofline_fraction']:.3f}")
        print("most collective-bound:  ", coll["arch"], coll["shape"], f"{coll['collective_s']*1e3:.1f}ms")


if __name__ == "__main__":
    main()
