"""Paper Fig 7: interval-count (P) sweep for a global query (PageRank)
and a targeted query (BFS — activity skipping sensitivity)."""
from repro.core import NXGraphEngine, PageRank, BFS, build_dsss

from benchmarks._util import row, small_rmat, timeit


def run():
    el = small_rmat(13, 8)
    rows = []
    for P in [2, 4, 8, 16, 32]:
        g = build_dsss(el, P)
        eng = NXGraphEngine(g, PageRank(), strategy="spu")
        t = timeit(lambda: eng.run(3, tol=0.0), warmup=1, iters=2)
        rows.append((f"pagerank_P{P}", t, f"m={el.m}"))
        engb = NXGraphEngine(g, BFS(), strategy="spu")
        tb = timeit(lambda: engb.run(10**6, root=0), warmup=1, iters=2)
        rows.append((f"bfs_P{P}", tb, f"m={el.m}"))
    return [row(*r) for r in rows]


def main():
    print("\n".join(run()))


if __name__ == "__main__":
    main()
