"""Paper Fig 10 (thread sweep) — TPU analogue: device-grid sweep.

The container has ONE physical core, so wall-time speedups cannot
materialize; what the sweep shows is the work/collective split per grid
(the structural scaling a real pod realizes). Subprocesses are used so
each run can force its own host-device count.
"""
import json
import os
import subprocess
import sys

from benchmarks._util import row

_CHILD = r"""
import os, sys, json, time
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={sys.argv[1]}"
import jax, numpy as np
from repro.graph.generators import rmat
from repro.graph.preprocess import degree_and_densify
from repro.core.distributed import distributed_pagerank
R, C = int(sys.argv[2]), int(sys.argv[3])
src, dst = rmat(13, edge_factor=8, seed=1)
el = degree_and_densify(src, dst, drop_self_loops=True)
mesh = jax.make_mesh((R, C), ("data", "model"))
t0 = time.time(); ranks, it = distributed_pagerank(el, mesh, iters=3); dt = (time.time()-t0)/3
print(json.dumps({"sec_per_iter": dt, "m": int(el.m)}))
"""


def run():
    rows = []
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, PYTHONPATH=os.path.join(here, "src"))
    for n_dev, r, c in [(1, 1, 1), (2, 2, 1), (4, 2, 2), (8, 4, 2)]:
        out = subprocess.run(
            [sys.executable, "-c", _CHILD, str(n_dev), str(r), str(c)],
            capture_output=True,
            text=True,
            env=env,
            timeout=600,
        )
        line = out.stdout.strip().splitlines()[-1]
        d = json.loads(line)
        mteps = d["m"] / d["sec_per_iter"] / 1e6
        rows.append((f"grid_{r}x{c}", d["sec_per_iter"], f"MTEPS={mteps:.1f}"))
    return [row(*r) for r in rows]


def main():
    print("\n".join(run()))


if __name__ == "__main__":
    main()
