"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (paper-artifact mapping in
DESIGN.md §6). ``--quick`` skips the slowest suites.
"""
import argparse
import sys
import traceback

SUITES = [
    ("table4_subshard_order", "benchmarks.bench_subshard_order"),
    ("fig7_partitioning", "benchmarks.bench_partitioning"),
    ("fig8_spu_dpu", "benchmarks.bench_spu_dpu"),
    ("fig9_memory", "benchmarks.bench_memory"),
    ("fig10_parallelism", "benchmarks.bench_parallelism"),
    ("fig11_scalability", "benchmarks.bench_scalability"),
    ("fig12_algorithms", "benchmarks.bench_algorithms"),
    ("tables56_fig6_systems", "benchmarks.bench_pagerank_systems"),
    ("serving", "benchmarks.bench_serving"),
    ("lm_step", "benchmarks.bench_lm_step"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    skip_slow = {"fig10_parallelism"} if args.quick else set()
    print("suite,name,us_per_call,derived")
    failures = []
    for suite, module in SUITES:
        if suite in skip_slow:
            continue
        if args.only and args.only not in suite:
            continue
        try:
            mod = __import__(module, fromlist=["run"])
            for line in mod.run():
                print(f"{suite},{line}", flush=True)
        except Exception as e:
            failures.append((suite, repr(e)))
            traceback.print_exc()
    if failures:
        print(f"FAILURES: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
