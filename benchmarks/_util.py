"""Shared benchmark helpers."""
import time

import numpy as np

from repro.graph.generators import paper_dataset, rmat
from repro.graph.preprocess import degree_and_densify


def timeit(fn, *, warmup=1, iters=3):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters


def graph_standin(name):
    src, dst = paper_dataset(name)
    return degree_and_densify(src, dst, drop_self_loops=True)


def small_rmat(scale=12, ef=16, seed=0):
    src, dst = rmat(scale, edge_factor=ef, seed=seed)
    return degree_and_densify(src, dst, drop_self_loops=True)


def row(name, seconds, derived=""):
    return f"{name},{seconds*1e6:.1f},{derived}"
