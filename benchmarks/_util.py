"""Shared benchmark helpers."""
import datetime
import platform
import subprocess
import time

import numpy as np

from repro.graph.generators import paper_dataset, rmat
from repro.graph.preprocess import degree_and_densify

#: Version of the BENCH_*.json payload shape. Bump when a field is
#: renamed/removed so downstream comparisons across commits can refuse
#: to diff incompatible payloads instead of silently misreading them.
BENCH_SCHEMA_VERSION = 1


def git_sha(short: bool = True) -> str:
    """The repo's HEAD commit, or "unknown" outside a git checkout."""
    cmd = ["git", "rev-parse", *(["--short"] if short else []), "HEAD"]
    try:
        return subprocess.run(
            cmd, capture_output=True, text=True, timeout=10, check=True
        ).stdout.strip()
    except Exception:
        return "unknown"


def stamp(payload: dict, **extra) -> dict:
    """Attach provenance metadata to a benchmark payload (in place).

    Every BENCH_*.json carries the same ``meta`` block — schema version,
    git SHA, jax backend, wall-clock — so a results file is
    self-describing: which code produced it, on what accelerator, when.
    """
    import jax  # deferred: keep _util importable without staging a device

    now = datetime.datetime.now(datetime.timezone.utc)
    payload["meta"] = {
        "schema_version": BENCH_SCHEMA_VERSION,
        "git_sha": git_sha(),
        "backend": jax.default_backend(),
        "created_utc": now.isoformat(timespec="seconds"),
        "created_unix": now.timestamp(),
        "python": platform.python_version(),
        "platform": platform.platform(),
        **extra,
    }
    return payload


def timeit(fn, *, warmup=1, iters=3):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters


def graph_standin(name):
    src, dst = paper_dataset(name)
    return degree_and_densify(src, dst, drop_self_loops=True)


def small_rmat(scale=12, ef=16, seed=0):
    src, dst = rmat(scale, edge_factor=ef, seed=seed)
    return degree_and_densify(src, dst, drop_self_loops=True)


def row(name, seconds, derived=""):
    return f"{name},{seconds*1e6:.1f},{derived}"
