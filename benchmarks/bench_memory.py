"""Paper Fig 9 (memory sweep): budget → adaptive strategy + I/O/iteration.

Two sweeps over budgets below (and above) the total staged graph size:

* ``residency="device"`` (seed behaviour): the budget parameterizes the
  *modelled* traffic curve and the SPU/MPU/DPU selection points.
* ``residency="host"`` (out-of-core): the budget is *enforced* — non-
  resident sub-shards are streamed host→device per sweep with
  double-buffered prefetch, so for each budget the row also reports the
  measured-vs-modelled comparison, the raw transfer volume
  (``h2d``, bucket-padded bytes), the calibrated physical bytes/edge, and
  the peak device-held topology (pinned + 2-block streaming ring). This
  sweep pins ``execution="per_block"`` — it benchmarks the block fetcher
  specifically; a third sweep covers the packed tile-streaming path
  (``execution="packed"``, the out-of-core default since adaptive
  tiling), whose h2d is checked against the ``packed_h2d_bytes`` closed
  form.

A fourth sweep covers the *disk* tier: the graph is first built into a
``.dsss`` container by the bounded-RAM external-memory pipeline
(``repro.storage.build`` — its allocation ledger is asserted against the
chunk budget right here), then opened with ``GraphSession.open`` and run
under ``residency="disk"`` across device budgets in both execution
modes. Measured ``bytes_disk_read`` must equal the ``disk_read_bytes`` /
``packed_disk_bytes`` closed forms *exactly* for every row — that
assertion is what CI's bench-smoke job runs.

Run: ``PYTHONPATH=src python benchmarks/bench_memory.py [--smoke]
[--out BENCH_storage.json]`` (or via ``benchmarks/run.py``). Wall time on
this container barely varies with the budget (host→device is a memcpy,
not a disk); the reproduced claim is the traffic/selection curve, now
backed by performed transfers.
"""
import argparse
import dataclasses
import json
import os
import pathlib
import shutil
import sys
import tempfile

_ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_ROOT))  # so `benchmarks._util` resolves as a script
sys.path.insert(0, str(_ROOT / "src"))

from repro.core import (
    ExecutionPlan,
    GraphSession,
    PageRank,
    build_dsss,
    calibrate_edge_bytes,
    compare_measured,
    disk_read_bytes,
    packed_disk_bytes,
    packed_h2d_bytes,
)
from repro.core.session import _host_block_nbytes
from repro.storage import build_dsss_file

from benchmarks._util import row, small_rmat, stamp

ITERS = 2


def run(smoke: bool = False, payload: dict | None = None):
    el = small_rmat(10 if smoke else 13, 16)
    P = 8 if smoke else 16
    g = build_dsss(el, P)
    prog = PageRank()
    full = 2 * g.n_pad * prog.attr_bytes + g.total_edge_bytes(8)
    rows = []
    for residency in ("device", "host"):
        for frac in [0.05, 0.1, 0.25, 0.5, 0.75, 1.0, 1.25]:
            budget = int(full * frac)
            sess = GraphSession(g, memory_budget=budget, residency=residency)
            res = sess.run(
                ExecutionPlan(
                    prog, strategy="auto", max_iters=ITERS, tol=0.0,
                    # This sweep benchmarks the per-block fetcher; the
                    # packed streaming path gets its own sweep below.
                    execution="per_block",
                )
            )
            per = res.meters.per_iteration()
            choice = res.strategy
            p = sess.params_for(prog)
            max_block = max(h["e"] for h in sess.host_blocks.values()) * sess.Be
            cmp = compare_measured(
                per,
                p,
                choice.strategy,
                budget,
                slack_bytes=max_block + 2 * (g.n_pad - g.n) * prog.attr_bytes,
            )
            extra = (
                f"strategy={choice.strategy};Q={choice.Q};"
                f"read={per.bytes_read:.0f};write={per.bytes_written:.0f};"
                f"model_read={cmp.modelled_read:.0f};"
                f"within_slack={cmp.within_slack}"
            )
            if residency == "host":
                pinned_model, _ = sess.pinned_device_bytes()
                extra += (
                    f";h2d={per.bytes_h2d:.0f}"
                    f";Be_eff={calibrate_edge_bytes(p, per):.1f}"
                    f";pinned={pinned_model:.0f}"
                    f";peak={res.meters.peak_device_graph_bytes:.0f}"
                )
            rows.append(
                (
                    f"{residency}_budget_{frac:.2f}",
                    res.meters.wall_seconds / ITERS,
                    extra,
                )
            )
    # Packed tile streaming (the out-of-core default since adaptive
    # tiling): budget pins a tile prefix, chunks stream on top; measured
    # h2d must equal the layout closed form exactly.
    for frac in [0.05, 0.25, 0.5, 1.0, 1.25]:
        budget = int(full * frac)
        sess = GraphSession(g, memory_budget=budget, residency="host")
        res = sess.run(
            ExecutionPlan(
                prog, strategy="spu", max_iters=ITERS, tol=0.0,
                execution="packed",
            )
        )
        per = res.meters.per_iteration()
        splan = sess.packed_stream_plan("spu", prog.attr_bytes)
        model_h2d = packed_h2d_bytes(
            splan.num_tiles - splan.pin_tiles, splan.tile_edges,
            weighted=sess.has_weights,
        )
        pinned_model, _ = sess.pinned_device_bytes()
        assert per.bytes_h2d == model_h2d, (
            f"packed h2d {per.bytes_h2d} != closed form {model_h2d} "
            f"(budget frac {frac}) — streamed leaves and PACKED_SLOT_BYTES "
            "have drifted"
        )
        extra = (
            f"pin_tiles={splan.pin_tiles}/{splan.num_tiles}"
            f";chunk_tiles={splan.chunk_tiles}"
            f";h2d={per.bytes_h2d:.0f}"
            f";h2d_model={model_h2d:.0f}"
            f";h2d_exact=True"
            f";pinned={pinned_model:.0f}"
            f";peak={res.meters.peak_device_graph_bytes:.0f}"
        )
        rows.append(
            (
                f"host_packed_budget_{frac:.2f}",
                res.meters.wall_seconds / ITERS,
                extra,
            )
        )
    # Disk tier (paper §IV streamlined disk access): external-memory build
    # into a .dsss container, then disk-residency sweeps whose measured
    # bytes_disk_read must equal the closed forms exactly.
    build_budget = 1 << 20
    tmpdir = tempfile.mkdtemp(prefix="bench-dsss-")
    disk_rows = []
    try:
        path = os.path.join(tmpdir, "bench.dsss")

        def chunks():
            step = 1 << 15
            for lo in range(0, el.m, step):
                yield el.src[lo : lo + step], el.dst[lo : lo + step]

        stats = build_dsss_file(chunks, path, P, chunk_budget=build_budget)
        assert stats.peak_edge_bytes <= 2.05 * build_budget, (
            f"external build peak {stats.peak_edge_bytes} exceeds 2x the "
            f"chunk budget {build_budget} — the bounded-memory contract broke"
        )
        rows.append(
            (
                "disk_build",
                0.0,
                f"m={stats.m};peak_edge_bytes={stats.peak_edge_bytes}"
                f";budget={stats.chunk_budget};tiles={stats.num_tiles}"
                f"x{stats.tile_edges};file_bytes={os.path.getsize(path)}",
            )
        )
        if payload is not None:
            payload["build"] = dataclasses.asdict(stats)
            payload["file_bytes"] = os.path.getsize(path)
        host_budget = int(full * 0.25)  # partial RAM cache: disk tier is hot
        for frac in [0.05, 0.25, 1.0]:
            budget = int(full * frac)
            for execution in ("per_block", "packed"):
                sess = GraphSession.open(
                    path,
                    memory_budget=budget,
                    host_memory_budget=host_budget,
                    verify=(frac == 0.05 and execution == "per_block"),
                )
                plan = ExecutionPlan(
                    prog, strategy="auto", max_iters=ITERS, tol=0.0,
                    execution=execution,
                )
                res = sess.run(plan)
                per = res.meters.per_iteration()
                compiled = sess.compile(plan)
                if execution == "per_block":
                    nbytes = {
                        k: _host_block_nbytes(h)
                        for k, h in sess.host_blocks.items()
                    }
                    model_disk = disk_read_bytes(
                        nbytes, compiled.resident, compiled.host_cached
                    )
                    placement = f"host_cached={len(compiled.host_cached)}"
                else:
                    splan = sess.packed_stream_plan(
                        compiled.choice.strategy, compiled.params.Ba
                    )
                    model_disk = packed_disk_bytes(
                        splan.num_tiles - splan.pin_tiles - splan.host_tiles,
                        splan.tile_edges,
                        weighted=sess.has_weights,
                    )
                    placement = (
                        f"pin_tiles={splan.pin_tiles}"
                        f";host_tiles={splan.host_tiles}"
                        f"/{splan.num_tiles}"
                    )
                assert per.bytes_disk_read == model_disk, (
                    f"disk {execution} frac {frac}: measured "
                    f"{per.bytes_disk_read} != closed form {model_disk}"
                )
                extra = (
                    f"strategy={compiled.choice.strategy}"
                    f";disk_read={per.bytes_disk_read:.0f}"
                    f";disk_model={model_disk:.0f};disk_exact=True"
                    f";h2d={per.bytes_h2d:.0f};{placement}"
                    f";peak={res.meters.peak_device_graph_bytes:.0f}"
                )
                name = f"disk_{execution}_budget_{frac:.2f}"
                disk_rows.append(
                    {
                        "name": name,
                        "strategy": compiled.choice.strategy,
                        "seconds_per_iter": res.meters.wall_seconds / ITERS,
                        "bytes_disk_read_per_iter": per.bytes_disk_read,
                        "disk_model_bytes": model_disk,
                        "bytes_h2d_per_iter": per.bytes_h2d,
                        "peak_device_graph_bytes":
                            res.meters.peak_device_graph_bytes,
                    }
                )
                rows.append((name, res.meters.wall_seconds / ITERS, extra))
    finally:
        shutil.rmtree(tmpdir, ignore_errors=True)
    if payload is not None:
        payload["graph"] = {"n": g.n, "m": g.m, "P": g.P, "smoke": smoke}
        payload["disk_rows"] = disk_rows
        payload["rows"] = [row(*r) for r in rows]
    return [row(*r) for r in rows]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="smaller graph (CI bench-smoke lane)")
    ap.add_argument("--out", default=None,
                    help="write the disk-tier results as JSON")
    args = ap.parse_args()
    payload: dict = {}
    lines = run(smoke=args.smoke, payload=payload)
    print("\n".join(lines))
    if args.out:
        stamp(payload, bench="memory", smoke=args.smoke)
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
