"""Paper Fig 9 (memory sweep): budget -> adaptive strategy + I/O/iteration.

Wall time on this container does not vary with the simulated budget (no
real disk); the reproduced claim is the modeled+metered traffic curve and
the SPU/MPU/DPU selection points.
"""
from repro.core import NXGraphEngine, PageRank, build_dsss

from benchmarks._util import row, small_rmat


def run():
    el = small_rmat(13, 16)
    g = build_dsss(el, 16)
    prog = PageRank()
    full = 2 * g.n_pad * prog.attr_bytes + g.m * 8
    rows = []
    for frac in [0.05, 0.1, 0.25, 0.5, 0.75, 1.0, 1.25]:
        budget = int(full * frac)
        eng = NXGraphEngine(g, prog, strategy="auto", memory_budget=budget)
        res = eng.run(2, tol=0.0)
        per = res.meters.per_iteration()
        rows.append(
            (
                f"budget_{frac:.2f}",
                res.meters.wall_seconds / 2,
                f"strategy={eng.choice.strategy};Q={eng.choice.Q};"
                f"read={per.bytes_read:.0f};write={per.bytes_written:.0f}",
            )
        )
    return [row(*r) for r in rows]


def main():
    print("\n".join(run()))


if __name__ == "__main__":
    main()
