"""Paper Fig 9 (memory sweep): budget → adaptive strategy + I/O/iteration.

Two sweeps over budgets below (and above) the total staged graph size:

* ``residency="device"`` (seed behaviour): the budget parameterizes the
  *modelled* traffic curve and the SPU/MPU/DPU selection points.
* ``residency="host"`` (out-of-core): the budget is *enforced* — non-
  resident sub-shards are streamed host→device per sweep with
  double-buffered prefetch, so for each budget the row also reports the
  measured-vs-modelled comparison, the raw transfer volume
  (``h2d``, bucket-padded bytes), the calibrated physical bytes/edge, and
  the peak device-held topology (pinned + 2-block streaming ring). This
  sweep pins ``execution="per_block"`` — it benchmarks the block fetcher
  specifically; a third sweep covers the packed tile-streaming path
  (``execution="packed"``, the out-of-core default since adaptive
  tiling), whose h2d is checked against the ``packed_h2d_bytes`` closed
  form.

Run: ``PYTHONPATH=src python benchmarks/bench_memory.py``
(or via ``benchmarks/run.py``). Wall time on this container barely varies
with the budget (host→device is a memcpy, not a disk); the reproduced
claim is the traffic/selection curve, now backed by performed transfers.
"""
from repro.core import (
    ExecutionPlan,
    GraphSession,
    PageRank,
    build_dsss,
    calibrate_edge_bytes,
    compare_measured,
    packed_h2d_bytes,
)

from benchmarks._util import row, small_rmat

ITERS = 2


def run():
    el = small_rmat(13, 16)
    g = build_dsss(el, 16)
    prog = PageRank()
    full = 2 * g.n_pad * prog.attr_bytes + g.total_edge_bytes(8)
    rows = []
    for residency in ("device", "host"):
        for frac in [0.05, 0.1, 0.25, 0.5, 0.75, 1.0, 1.25]:
            budget = int(full * frac)
            sess = GraphSession(g, memory_budget=budget, residency=residency)
            res = sess.run(
                ExecutionPlan(
                    prog, strategy="auto", max_iters=ITERS, tol=0.0,
                    # This sweep benchmarks the per-block fetcher; the
                    # packed streaming path gets its own sweep below.
                    execution="per_block",
                )
            )
            per = res.meters.per_iteration()
            choice = res.strategy
            p = sess.params_for(prog)
            max_block = max(h["e"] for h in sess.host_blocks.values()) * sess.Be
            cmp = compare_measured(
                per,
                p,
                choice.strategy,
                budget,
                slack_bytes=max_block + 2 * (g.n_pad - g.n) * prog.attr_bytes,
            )
            extra = (
                f"strategy={choice.strategy};Q={choice.Q};"
                f"read={per.bytes_read:.0f};write={per.bytes_written:.0f};"
                f"model_read={cmp.modelled_read:.0f};"
                f"within_slack={cmp.within_slack}"
            )
            if residency == "host":
                pinned_model, _ = sess.pinned_device_bytes()
                extra += (
                    f";h2d={per.bytes_h2d:.0f}"
                    f";Be_eff={calibrate_edge_bytes(p, per):.1f}"
                    f";pinned={pinned_model:.0f}"
                    f";peak={res.meters.peak_device_graph_bytes:.0f}"
                )
            rows.append(
                (
                    f"{residency}_budget_{frac:.2f}",
                    res.meters.wall_seconds / ITERS,
                    extra,
                )
            )
    # Packed tile streaming (the out-of-core default since adaptive
    # tiling): budget pins a tile prefix, chunks stream on top; measured
    # h2d must equal the layout closed form exactly.
    for frac in [0.05, 0.25, 0.5, 1.0, 1.25]:
        budget = int(full * frac)
        sess = GraphSession(g, memory_budget=budget, residency="host")
        res = sess.run(
            ExecutionPlan(
                prog, strategy="spu", max_iters=ITERS, tol=0.0,
                execution="packed",
            )
        )
        per = res.meters.per_iteration()
        splan = sess.packed_stream_plan("spu", prog.attr_bytes)
        model_h2d = packed_h2d_bytes(
            splan.num_tiles - splan.pin_tiles, splan.tile_edges,
            weighted=sess.has_weights,
        )
        pinned_model, _ = sess.pinned_device_bytes()
        assert per.bytes_h2d == model_h2d, (
            f"packed h2d {per.bytes_h2d} != closed form {model_h2d} "
            f"(budget frac {frac}) — streamed leaves and PACKED_SLOT_BYTES "
            "have drifted"
        )
        extra = (
            f"pin_tiles={splan.pin_tiles}/{splan.num_tiles}"
            f";chunk_tiles={splan.chunk_tiles}"
            f";h2d={per.bytes_h2d:.0f}"
            f";h2d_model={model_h2d:.0f}"
            f";h2d_exact=True"
            f";pinned={pinned_model:.0f}"
            f";peak={res.meters.peak_device_graph_bytes:.0f}"
        )
        rows.append(
            (
                f"host_packed_budget_{frac:.2f}",
                res.meters.wall_seconds / ITERS,
                extra,
            )
        )
    return [row(*r) for r in rows]


def main():
    print("\n".join(run()))


if __name__ == "__main__":
    main()
