"""Serving throughput: sequential point queries vs the micro-batcher.

NXgraph's streamed sweeps make concurrent point queries (BFS
reachability, SSSP distances, personalized PageRank) an obvious batching
target: K compatible queries fused into one :meth:`GraphSession.run_batch`
pass read the topology once instead of K times, so the win grows with the
edge-to-attribute ratio. This benchmark quantifies that for the serving
subsystem:

* **sequential** — K solo ``session.run(plan)`` calls, the no-server
  baseline (also what a ``max_batch=1`` server degenerates to);
* **served** — the same K requests through :class:`GraphServer`
  (``max_batch=K``), which buckets them by ``plan.batch_key()`` and
  dispatches one fused batch.

Both paths are warmed first so compile time is excluded; results are
asserted bit-identical before any timing is trusted. Sweeps K ∈ {1, 4, 16}
over BFS and PageRank under streamed host residency (constrained budget —
the serving regime) and reports per-K speedup, QPS and batch occupancy.

Run: ``PYTHONPATH=src python benchmarks/bench_serving.py [--smoke]
[--out BENCH_serving.json] [--assert-speedup X]`` (or via
``benchmarks/run.py``). ``--assert-speedup`` fails the run when the
largest-K batched throughput is below X× sequential — CI's bench-smoke
lane runs with 1.2, the committed full run clears 2x.

``--inject-faults`` additionally serves a request wave against a session
with injected transient H2D faults (a deterministic burst that overflows
the fetch layer's own bounded retries, plus background rate noise) and
reports how the stack absorbed them: fetch-level heals, server-level
retries, failures. ``--assert-recovery`` turns that into a gate — every
request must complete bit-identical to a fault-free solo run with zero
failures, and the serving retry path must actually have fired.
"""
import argparse
import json
import pathlib
import sys
import tempfile
import time
import urllib.request

import numpy as np

_ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_ROOT))  # so `benchmarks._util` resolves as a script
sys.path.insert(0, str(_ROOT / "src"))

from repro.core import (  # noqa: E402
    BFS,
    ExecutionPlan,
    GraphSession,
    PageRank,
    TraceSpec,
    build_dsss,
)
from repro.obs import parse_prometheus  # noqa: E402
from repro.reliability import FaultPlan  # noqa: E402
from repro.serving import GraphServer, QueryRequest, SessionPool  # noqa: E402
from repro.storage import write_dsss  # noqa: E402

from benchmarks._util import small_rmat, stamp  # noqa: E402

KS = (1, 4, 16)


def _plans(program, k, n):
    if isinstance(program, PageRank):
        # K identical whole-graph analytic queries (PageRank takes no
        # Initialize kwargs) — the repeated-dashboard-query case; fusion
        # still reads the streamed topology once for all K.
        return [
            ExecutionPlan(program, strategy="spu", max_iters=3, tol=0.0)
            for _ in range(k)
        ]
    return [
        ExecutionPlan(
            program, strategy="spu", max_iters=n + 1,
            program_kwargs={"root": r},
        )
        for r in range(k)
    ]


def run(smoke: bool = False, payload: dict | None = None):
    el = small_rmat(10 if smoke else 13, 16)
    g = build_dsss(el, 8 if smoke else 16)
    budget = int(g.total_edge_bytes(8) * 0.25)  # streamed: serving regime
    iters = 2 if smoke else 3
    pool = SessionPool()
    pool.register("g", g, memory_budget=budget, residency="host")
    session = pool.session("g")
    rows = []
    lines = []
    for program, name in ((BFS(), "bfs"), (PageRank(), "pagerank")):
        for k in KS:
            plans = _plans(program, k, g.n)
            # Warm both paths (jit compile for solo and fused shapes) and
            # check the served results match the solo ones bit-for-bit —
            # a throughput number for wrong answers is worthless.
            solo = [session.run(p) for p in plans]
            server = GraphServer(pool, max_batch=k, max_wait_ms=2.0)
            served = server.serve([QueryRequest("g", p) for p in plans])
            for s, q in zip(solo, served):
                np.testing.assert_array_equal(s.attrs, q.result.attrs)

            t0 = time.perf_counter()
            for _ in range(iters):
                for p in plans:
                    session.run(p)
            seq_s = (time.perf_counter() - t0) / iters

            server = GraphServer(pool, max_batch=k, max_wait_ms=2.0)
            reqs = [QueryRequest("g", p) for p in plans]
            t0 = time.perf_counter()
            for _ in range(iters):
                server.serve(reqs)
            batch_s = (time.perf_counter() - t0) / iters
            st = server.stats()

            speedup = seq_s / batch_s
            rows.append(
                {
                    "program": name,
                    "k": k,
                    "seq_seconds": seq_s,
                    "batch_seconds": batch_s,
                    "speedup": speedup,
                    "seq_qps": k / seq_s,
                    "batch_qps": k / batch_s,
                    "mean_occupancy": st.mean_occupancy,
                    "fused_batches": st.fused_batches,
                    "batches": st.batches,
                    "mean_queue_s": st.mean_queue_s,
                    "mean_run_s": st.mean_run_s,
                    "p50_total_s": st.p50_total_s,
                    "p95_total_s": st.p95_total_s,
                    "p99_total_s": st.p99_total_s,
                }
            )
            lines.append(
                f"{name}_k{k},seq={seq_s*1e3:.1f}ms,batch={batch_s*1e3:.1f}ms,"
                f"speedup={speedup:.2f}x,qps={k/batch_s:.1f},"
                f"occupancy={st.mean_occupancy:.1f},"
                f"p50={st.p50_total_s*1e3:.1f}ms,p95={st.p95_total_s*1e3:.1f}ms,"
                f"p99={st.p99_total_s*1e3:.1f}ms"
            )
    if payload is not None:
        payload["graph"] = {
            "n": g.n, "m": g.m, "P": g.P, "smoke": smoke,
            "memory_budget": budget, "residency": "host",
        }
        payload["rows"] = rows
    return lines


def run_fault_injection(smoke: bool = False, payload: dict | None = None):
    """Serve a request wave through an injected-fault session.

    The fault plan layers a deterministic transient burst (larger than the
    fetch layer's bounded retry budget, so it must escape to the server's
    retry-with-backoff loop) on top of low-rate background transient noise
    (absorbed by the fetch layer's own retries). Recovery is judged
    against fault-free solo runs: same bits, zero failures.
    """
    el = small_rmat(9 if smoke else 12, 16)
    g = build_dsss(el, 8)
    budget = int(g.total_edge_bytes(8) * 0.25)  # streamed: faults can fire
    kw = dict(memory_budget=budget, residency="host", execution="per_block")
    k = 8
    plans = _plans(BFS(), k, g.n)
    solo = [GraphSession(g, **kw).run(p) for p in plans]

    pool = SessionPool(breaker_threshold=16)
    pool.register("g", g, **kw)
    pool.session("g").inject_faults(
        FaultPlan.h2d_transient(rate=1.0, times=5, seed=7).merge(
            FaultPlan.h2d_transient(rate=0.02, times=None, seed=11)
        )
    )
    server = GraphServer(pool, max_batch=4, max_wait_ms=2.0, telemetry_port=0)
    try:
        served = server.serve(
            [QueryRequest("g", p, max_retries=4) for p in plans]
        )
        st = server.stats()
        # Scrape the live endpoint *after* the wave: the CI consistency
        # gate checks the scraped Prometheus counters against the
        # ServerStats snapshot (they are equal by construction — each
        # scrape publishes a fresh snapshot first).
        text = urllib.request.urlopen(
            server.telemetry.url("/metrics"), timeout=10
        ).read().decode()
    finally:
        server.shutdown_telemetry()
    scraped = parse_prometheus(text)
    inj = pool.session("g").fault_injector
    for s, q in zip(solo, served):
        np.testing.assert_array_equal(s.attrs, q.result.attrs)
    row = {
        "requests": k,
        "completed": st.completed,
        "failed": st.failed,
        "timeouts": st.timeouts,
        "server_retries": st.retries,
        "breaker_sheds": st.breaker_sheds,
        "faults_fired": inj.fired(),
        "max_total_s": st.max_total_s,
        "scrape": {
            f: scraped.get((f"repro_serving_{f}_total", ()))
            for f in ("completed", "retries", "timeouts", "breaker_sheds",
                      "failed")
        },
        "scrape_transient_retries": scraped.get(
            ("repro_transient_retries_total", (("site", "h2d"),))
        ),
    }
    if payload is not None:
        payload["fault_injection"] = row
    line = (
        f"faults,fired={row['faults_fired']},"
        f"server_retries={row['server_retries']},"
        f"completed={row['completed']}/{k},failed={row['failed']},"
        f"p_max={row['max_total_s']*1e3:.1f}ms"
    )
    return [line], row


def run_traced_disk(trace_out: str, smoke: bool = False,
                    payload: dict | None = None):
    """Trace one disk-tier PageRank; verify the trace's byte exactness.

    Streams the graph out of a ``.dsss`` container under a constrained
    budget with ``ExecutionPlan(trace=TraceSpec(path=...))``, then reads
    the exported Perfetto trace back and asserts the per-sweep
    ``bytes_h2d``/``bytes_disk_read`` span attributes sum *exactly* to
    the run's ``Result.meters`` fields — the observability layer's core
    contract, checked on the real artifact CI uploads.
    """
    from repro.runtime.trace_analysis import load_events, run_summaries

    el = small_rmat(9 if smoke else 12, 16)
    g = build_dsss(el, 8)
    budget = int(g.total_edge_bytes(8) * 0.25)
    with tempfile.TemporaryDirectory() as td:
        store_path = str(pathlib.Path(td) / "g.dsss")
        write_dsss(g, store_path)
        sess = GraphSession.open(
            store_path, memory_budget=budget, host_memory_budget=2 * budget
        )
        assert sess.resolved_residency() == "disk"
        plan = ExecutionPlan(
            PageRank(), max_iters=5, tol=0.0,
            trace=TraceSpec(path=trace_out),
        )
        res = sess.run(plan)
    summary = run_summaries(load_events(trace_out))[-1]
    assert summary["bytes_h2d"] == res.meters.bytes_h2d, (
        f"trace sweep h2d sum {summary['bytes_h2d']} != "
        f"meters {res.meters.bytes_h2d}"
    )
    assert summary["bytes_disk_read"] == res.meters.bytes_disk_read, (
        f"trace sweep disk sum {summary['bytes_disk_read']} != "
        f"meters {res.meters.bytes_disk_read}"
    )
    assert res.meters.bytes_disk_read > 0, "disk tier never touched disk"
    row = {
        "trace": trace_out,
        "sweeps": summary["sweeps"],
        "bytes_h2d": summary["bytes_h2d"],
        "bytes_disk_read": summary["bytes_disk_read"],
        "mean_sweep_s": summary["mean_sweep_s"],
    }
    if payload is not None:
        payload["traced_disk"] = row
    return [
        f"trace,{trace_out},sweeps={row['sweeps']},"
        f"h2d={row['bytes_h2d']/1e6:.2f}MB,"
        f"disk={row['bytes_disk_read']/1e6:.2f}MB (sums == meters)"
    ], row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="smaller graph (CI bench-smoke lane)")
    ap.add_argument("--out", default=None, help="write results as JSON")
    ap.add_argument("--assert-speedup", type=float, default=None,
                    help="fail unless batched >= X times sequential at max K")
    ap.add_argument("--inject-faults", action="store_true",
                    help="also serve a wave through injected transient "
                    "H2D faults and report recovery counters")
    ap.add_argument("--assert-recovery", action="store_true",
                    help="fail unless the faulted wave completes fully, "
                    "bit-identical, with zero failures (implies "
                    "--inject-faults)")
    ap.add_argument("--assert-scrape", action="store_true",
                    help="scrape the faulted wave's /metrics endpoint and "
                    "fail unless the Prometheus counters equal the "
                    "ServerStats snapshot (implies --inject-faults)")
    ap.add_argument("--trace-out", default=None,
                    help="also run one traced disk-tier PageRank, write the "
                    "Perfetto trace here and assert its per-sweep byte "
                    "attrs sum exactly to Result.meters")
    args = ap.parse_args()
    payload: dict = {}
    lines = run(smoke=args.smoke, payload=payload)
    print("\n".join(lines))
    if args.assert_speedup is not None:
        rows = payload["rows"]
        best = max(r["speedup"] for r in rows if r["k"] == max(KS))
        assert best >= args.assert_speedup, (
            f"batched serving speedup {best:.2f}x at K={max(KS)} is below "
            f"the required {args.assert_speedup}x — micro-batching has "
            "stopped amortizing the streamed topology"
        )
        print(f"speedup gate passed: {best:.2f}x >= {args.assert_speedup}x")
    if args.inject_faults or args.assert_recovery or args.assert_scrape:
        flines, frow = run_fault_injection(smoke=args.smoke, payload=payload)
        print("\n".join(flines))
        if args.assert_scrape:
            sc = frow["scrape"]
            for f in ("completed", "retries", "timeouts", "breaker_sheds",
                      "failed"):
                want = frow["server_retries" if f == "retries" else f]
                assert sc[f] == want, (
                    f"scraped repro_serving_{f}_total={sc[f]} != "
                    f"ServerStats value {want}"
                )
            assert (frow["scrape_transient_retries"] or 0) >= 1, (
                "repro_transient_retries_total{site=h2d} missing or zero "
                "after an injected transient burst — the fetch-layer "
                f"retry counter is miswired: {frow}"
            )
            print(
                "scrape gate passed: serving counters == ServerStats, "
                f"transient_retries={frow['scrape_transient_retries']:.0f}"
            )
        if args.assert_recovery:
            assert frow["failed"] == 0 and frow["timeouts"] == 0, (
                f"faulted wave shed/failed requests: {frow}"
            )
            assert frow["completed"] == frow["requests"], (
                f"faulted wave incomplete: {frow}"
            )
            assert frow["server_retries"] >= 1, (
                "the deterministic fault burst never escaped to the "
                f"serving retry loop — injection is miswired: {frow}"
            )
            print(
                "recovery gate passed: "
                f"{frow['faults_fired']} faults absorbed, "
                f"{frow['server_retries']} server retries, 0 failures"
            )
    if args.trace_out:
        tlines, _ = run_traced_disk(
            args.trace_out, smoke=args.smoke, payload=payload
        )
        print("\n".join(tlines))
    if args.out:
        stamp(payload, bench="serving", smoke=args.smoke)
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
