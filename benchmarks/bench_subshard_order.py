"""Paper Table IV: dst-sorted fine-grained vs src-sorted coarse-grained.

The paper's 3.5x wall-clock speedup comes from eliminating write conflicts
between CPU worker threads — unreproducible on this 1-core container (both
layouts lower to the same sequential scatter). What IS measurable here is
the structural property the TPU adaptation depends on (DESIGN.md §2):

  * slot-window spread: max distinct hub slots per E_BLK edge block.
    dst-sorted guarantees spread <= E_BLK, which is exactly what lets
    kernels/dsss_spmv.py use a dense one-hot MXU reduction window.
    src-sorted blocks spread across the whole interval -> no bounded
    window -> no MXU path (the TPU analogue of "write conflicts").
  * dst-run-length: mean contiguous run of equal destinations (the
    paper's cache-locality argument for the secondary source sort).
"""
import numpy as np

from repro.core import NXGraphEngine, PageRank, build_dsss
from repro.core.baselines import build_graphchi_like
from repro.kernels.dsss_spmv import E_BLK

from benchmarks._util import row, small_rmat, timeit


def _spread_stats(g):
    """Max hub-slot spread per E_BLK block, across all sub-shards."""
    spreads = []
    runs = []
    for i in range(g.P):
        for j in range(g.P):
            ss = g.subshard(i, j)
            if ss.num_edges == 0:
                continue
            inv = ss.hub_inv
            for lo in range(0, len(inv), E_BLK):
                blk = inv[lo : lo + E_BLK]
                spreads.append(int(blk.max() - blk.min()) + 1)
            d = ss.dst_local
            runs.append(len(d) / max(1, int((np.diff(d) != 0).sum()) + 1))
    return max(spreads), float(np.mean(runs))


def run():
    el = small_rmat(13, 16)
    rows = []
    results = {}
    for label, g in [
        ("dst_sorted_fine", build_dsss(el, 8)),
        ("src_sorted_coarse", build_graphchi_like(el, 8)),
    ]:
        eng = NXGraphEngine(g, PageRank(), strategy="spu")
        t = timeit(lambda: eng.run(3, tol=0.0), warmup=1, iters=3)
        spread, run_len = _spread_stats(g)
        mxu_ok = spread <= E_BLK
        results[label] = t
        rows.append(
            (
                label,
                t,
                f"max_slot_spread={spread};mxu_window_ok={mxu_ok};"
                f"mean_dst_run={run_len:.2f}",
            )
        )
    speedup = results["src_sorted_coarse"] / results["dst_sorted_fine"]
    rows.append(
        (
            "table4_speedup_dst_over_src",
            0.0,
            f"{speedup:.2f}x(cpu-1core;paper-3.5x-is-thread-conflict-bound)",
        )
    )
    return [row(*r) for r in rows]


def main():
    print("\n".join(run()))


if __name__ == "__main__":
    main()
