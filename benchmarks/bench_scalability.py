"""Paper Fig 11: MTEPS vs graph scale (delaunay-like mesh family)."""
from repro.core import NXGraphEngine, PageRank, build_dsss
from repro.graph.generators import random_geometric
from repro.graph.preprocess import degree_and_densify

from benchmarks._util import row, timeit


def run():
    rows = []
    for scale in [13, 14, 15, 16]:
        src, dst = random_geometric(1 << scale, seed=scale)
        el = degree_and_densify(src, dst, drop_self_loops=True)
        g = build_dsss(el, 8)
        eng = NXGraphEngine(g, PageRank(), strategy="fused")
        res = eng.run(5, tol=0.0)
        t = timeit(lambda: eng.run(5, tol=0.0), warmup=0, iters=2) / 5
        rows.append(
            (f"delaunay_n{scale}", t, f"m={el.m};MTEPS={el.m/t/1e6:.1f}")
        )
    return [row(*r) for r in rows]


def main():
    print("\n".join(run()))


if __name__ == "__main__":
    main()
