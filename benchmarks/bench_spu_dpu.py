"""Paper Fig 8: SPU vs DPU wall time + slow-tier bytes (PageRank, BFS).

Uses the Session/Plan API: the graph is staged once per scale and both
strategies run against the same resident blocks, so the comparison measures
the schedules, not repeated staging.
"""
from repro.core import ExecutionPlan, GraphSession, PageRank, build_dsss

from benchmarks._util import row, small_rmat, timeit


def run():
    rows = []
    for scale, label in [(12, "small"), (14, "medium")]:
        el = small_rmat(scale, 12, seed=scale)
        g = build_dsss(el, 8)
        session = GraphSession(g)
        for strat in ["spu", "dpu"]:
            plan = ExecutionPlan(PageRank(), strategy=strat, max_iters=3, tol=0.0)
            res = session.run(plan)
            t = timeit(lambda: session.run(plan), warmup=0, iters=2)
            rows.append(
                (
                    f"pagerank_{label}_{strat}",
                    t,
                    f"bytes/iter={res.meters.per_iteration().bytes_total:.0f}",
                )
            )
    return [row(*r) for r in rows]


def main():
    print("\n".join(run()))


if __name__ == "__main__":
    main()
