"""Paper Fig 8: SPU vs DPU wall time + slow-tier bytes (PageRank, BFS)."""
from repro.core import NXGraphEngine, PageRank, BFS, build_dsss

from benchmarks._util import row, small_rmat, timeit


def run():
    rows = []
    for scale, label in [(12, "small"), (14, "medium")]:
        el = small_rmat(scale, 12, seed=scale)
        g = build_dsss(el, 8)
        for strat in ["spu", "dpu"]:
            eng = NXGraphEngine(g, PageRank(), strategy=strat)
            res = eng.run(3, tol=0.0)
            t = timeit(lambda: eng.run(3, tol=0.0), warmup=0, iters=2)
            rows.append(
                (
                    f"pagerank_{label}_{strat}",
                    t,
                    f"bytes/iter={res.meters.per_iteration().bytes_total:.0f}",
                )
            )
    return [row(*r) for r in rows]


def main():
    print("\n".join(run()))


if __name__ == "__main__":
    main()
