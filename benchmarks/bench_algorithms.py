"""Paper Fig 12 / Exp 7: BFS, WCC, SCC on the dataset stand-ins, plus the
batched multi-source BFS workload (K sources, one edge-stream pass)."""
import time

from repro.core import bfs, multi_bfs, scc, wcc

from benchmarks._util import graph_standin, row


def run():
    rows = []
    for name in ["live-journal"]:
        el = graph_standin(name)
        for algo, fn in [("bfs", lambda: bfs(el, root=0, P=8)),
                         ("wcc", lambda: wcc(el, P=8))]:
            t0 = time.perf_counter()
            fn()
            rows.append((f"{algo}_{name}", time.perf_counter() - t0, f"n={el.n};m={el.m}"))
        # Batched: 16 sources sharing one streamed pass — compare against
        # 16× the single-source row above to see the batching win.
        K = 16
        t0 = time.perf_counter()
        batch = multi_bfs(el, list(range(K)), P=8)
        rows.append(
            (
                f"multi_bfs{K}_{name}",
                time.perf_counter() - t0,
                f"n={el.n};m={el.m};fused={batch.fused};sweeps={batch.iterations}",
            )
        )
        t0 = time.perf_counter()
        scc(el, P=8)
        rows.append((f"scc_{name}", time.perf_counter() - t0, f"n={el.n};m={el.m}"))
    return [row(*r) for r in rows]


def main():
    print("\n".join(run()))


if __name__ == "__main__":
    main()
