"""Beyond-paper: smoke-config LM step timings per arch family (CPU)."""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import SyntheticLM, SyntheticLMConfig
from repro.optim import AdamW
from repro.train.state import make_train_state
from repro.train.step import make_train_step

from benchmarks._util import row, timeit


def run():
    rows = []
    for arch in ["gemma-2b", "deepseek-moe-16b", "falcon-mamba-7b", "recurrentgemma-9b"]:
        cfg = get_config(arch, smoke=True)
        opt = AdamW(learning_rate=1e-3)
        step = jax.jit(make_train_step(cfg, opt), donate_argnums=(0,))
        state = make_train_state(cfg, opt, jax.random.PRNGKey(0))
        data = SyntheticLM(SyntheticLMConfig(cfg.vocab_size, 64, 4))
        batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
        state, _ = step(state, batch)  # compile

        def one():
            nonlocal state
            state, m = step(state, batch)
            jax.block_until_ready(m["loss"])

        t = timeit(one, warmup=1, iters=3)
        tokens = 4 * 64
        rows.append((f"train_step_{arch}_smoke", t, f"tok/s={tokens/t:.0f}"))
    return [row(*r) for r in rows]


def main():
    print("\n".join(run()))


if __name__ == "__main__":
    main()
