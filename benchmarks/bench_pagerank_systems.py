"""Paper Tables V/VI + Fig 6: PageRank per-iteration on the twitter
stand-in, engine strategies vs the TurboGraph-like baseline, and the
measured MPU/TurboGraph-like I/O-ratio curve (Fig 6)."""
from repro.core import NXGraphEngine, PageRank, build_dsss
from repro.core.baselines import TurboGraphLikeEngine

from benchmarks._util import graph_standin, row, timeit


def run():
    el = graph_standin("twitter")  # scaled-down, skew-matched stand-in
    g = build_dsss(el, 12)
    prog = PageRank()
    rows = []
    for label, make in [
        ("nxgraph_spu", lambda: NXGraphEngine(g, prog, strategy="spu")),
        ("nxgraph_fused", lambda: NXGraphEngine(g, prog, strategy="fused")),
        ("turbograph_like", lambda: TurboGraphLikeEngine(g, prog)),
    ]:
        eng = make()
        t = timeit(lambda: eng.run(1, tol=0.0), warmup=1, iters=2)
        rows.append((f"pagerank_1iter_{label}", t, f"m={el.m}"))
    # Fig 6: measured I/O ratio sweep
    full = 2 * g.n_pad * prog.attr_bytes
    for frac in [0.1, 0.3, 0.5, 0.7, 0.9]:
        budget = int(full * frac)
        mpu = NXGraphEngine(g, prog, strategy="mpu", memory_budget=budget).run(
            1, tol=0.0
        )
        tg = TurboGraphLikeEngine(g, prog, memory_budget=budget).run(1, tol=0.0)
        ratio = mpu.meters.bytes_total / max(tg.meters.bytes_total, 1)
        rows.append(
            (f"fig6_io_ratio_budget{frac:.1f}", 0.0, f"mpu/tg={ratio:.3f}")
        )
    return [row(*r) for r in rows]


def main():
    print("\n".join(run()))


if __name__ == "__main__":
    main()
