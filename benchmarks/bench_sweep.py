"""Per-sweep wall time and dispatch count: per_block vs. packed execution.

The paper's headline claim is raw per-iteration speed; the per-block
executor pays O(P²) host→XLA round-trips per update sweep, so at realistic
P the run is dispatch-bound. This benchmark measures, for P ∈ {8, 16, 32}
(device residency, PageRank):

  * per-sweep wall seconds for both execution modes, and
  * jitted-primitive dispatches per sweep (counted by wrapping the
    session's jit entry points — the host round-trips the packed path is
    designed to eliminate; transfers and un-jitted glue ops are not
    counted).

It verifies bit-identity between the modes on every configuration and
writes ``BENCH_sweep.json`` (repo root by default) — the start of the perf
trajectory; CI runs the ``--smoke`` variant per PR so dispatch-count
regressions are visible in the artifact.

Usage::

    PYTHONPATH=src python benchmarks/bench_sweep.py            # full, writes BENCH_sweep.json
    PYTHONPATH=src python benchmarks/bench_sweep.py --smoke    # tiny graph, CI artifact
"""
import argparse
import dataclasses
import json
import pathlib
import sys

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

import jax  # noqa: E402

from repro.core import ExecutionPlan, GraphSession, PageRank, build_dsss  # noqa: E402
from repro.core import session as session_mod  # noqa: E402
from repro.graph.generators import erdos_renyi  # noqa: E402
from repro.graph.preprocess import degree_and_densify  # noqa: E402

# The session's jit entry points — one call == one host-scheduled XLA
# dispatch in the update loop.
_PER_BLOCK_PRIMITIVES = [
    "_block_gather_reduce",
    "_block_to_hub",
    "_block_from_hub",
    "_apply_interval",
    "_pre_iteration",
]


class DispatchCounter:
    """Counts calls to the session's jitted primitives while active."""

    def __init__(self):
        self.count = 0
        self._saved = {}

    def _wrap(self, fn):
        def counted(*a, **kw):
            self.count += 1
            return fn(*a, **kw)

        return counted

    def __enter__(self):
        for name in _PER_BLOCK_PRIMITIVES:
            fn = getattr(session_mod, name)
            self._saved[name] = fn
            setattr(session_mod, name, self._wrap(fn))
        real_jits = session_mod._packed_jits
        self._saved["_packed_jits"] = real_jits

        def counting_jits(donate):
            sweep, apply_all = real_jits(donate)
            return self._wrap(sweep), self._wrap(apply_all)

        session_mod._packed_jits = counting_jits
        return self

    def __exit__(self, *exc):
        for name, fn in self._saved.items():
            setattr(session_mod, name, fn)
        return False


def bench_one(session, strategy, execution, iters):
    plan = ExecutionPlan(
        PageRank(), strategy=strategy, max_iters=iters, tol=0.0, execution=execution
    )
    session.run(plan)  # warmup: staging + jit compilation
    with DispatchCounter() as counter:
        res = session.run(plan)
    assert res.iterations == iters
    return {
        "strategy": strategy,
        "mode": execution,
        "per_sweep_seconds": res.meters.wall_seconds / res.iterations,
        "dispatches_per_sweep": counter.count / res.iterations,
        "mteps": res.meters.mteps(),
        "attrs": res.attrs,
        "meters": res.meters,
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--p-values", type=int, nargs="+", default=[8, 16, 32])
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--m", type=int, default=120_000)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument(
        "--strategies", nargs="+", default=["spu", "dpu"],
        choices=["spu", "dpu", "mpu"],
    )
    ap.add_argument(
        "--out",
        default=str(pathlib.Path(__file__).resolve().parent.parent / "BENCH_sweep.json"),
    )
    ap.add_argument(
        "--smoke", action="store_true",
        help="tiny graph, P=[4], 2 sweeps — the CI artifact variant",
    )
    args = ap.parse_args(argv)
    if args.smoke:
        args.p_values, args.n, args.m, args.iters = [4], 400, 2_400, 2

    src, dst = erdos_renyi(args.n, args.m, seed=args.seed)
    el = degree_and_densify(src, dst, drop_self_loops=True)
    report = {
        "benchmark": "bench_sweep",
        "backend": jax.default_backend(),
        "graph": {
            "generator": "erdos_renyi",
            "n": el.n,
            "m": el.m,
            "seed": args.seed,
        },
        "iters_per_run": args.iters,
        "results": [],
        "speedups": [],
    }
    for P in args.p_values:
        g = build_dsss(el, P)
        sess = GraphSession(g, residency="device")
        packed = g.packed_sweep()
        print(
            f"P={P}: {len(sess.block_keys)} sub-shards, tile_edges="
            f"{packed.tile_edges}, padded_slots={packed.padded_edge_slots} "
            f"({packed.padded_edge_slots / max(g.m, 1):.2f}x edges)"
        )
        for strategy in args.strategies:
            rows = {}
            for execution in ("per_block", "packed"):
                r = bench_one(sess, strategy, execution, args.iters)
                rows[execution] = r
                print(
                    f"  {strategy:>4} {execution:>9}: "
                    f"{r['per_sweep_seconds'] * 1e3:8.2f} ms/sweep, "
                    f"{r['dispatches_per_sweep']:7.1f} dispatches/sweep"
                )
            np.testing.assert_array_equal(
                rows["per_block"].pop("attrs"), rows["packed"].pop("attrs")
            )
            m_pb = dataclasses.asdict(rows["per_block"].pop("meters"))
            m_pk = dataclasses.asdict(rows["packed"].pop("meters"))
            m_pb.pop("wall_seconds"), m_pk.pop("wall_seconds")
            assert m_pb == m_pk, "execution modes must meter identically"
            speedup = (
                rows["per_block"]["per_sweep_seconds"]
                / rows["packed"]["per_sweep_seconds"]
            )
            dispatch_ratio = (
                rows["per_block"]["dispatches_per_sweep"]
                / rows["packed"]["dispatches_per_sweep"]
            )
            print(
                f"  {strategy:>4}   speedup: {speedup:5.1f}x wall, "
                f"{dispatch_ratio:5.1f}x fewer dispatches "
                f"(bit-identical, meters identical)"
            )
            for execution in ("per_block", "packed"):
                report["results"].append({"P": P, **rows[execution]})
            report["speedups"].append(
                {
                    "P": P,
                    "strategy": strategy,
                    "wall_speedup": speedup,
                    "dispatch_ratio": dispatch_ratio,
                }
            )
    out = pathlib.Path(args.out)
    out.write_text(json.dumps(report, indent=2) + "\n")
    print(f"wrote {out}")
    return report


if __name__ == "__main__":
    main()
