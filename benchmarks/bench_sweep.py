"""Per-sweep wall time, dispatch count and padding: per_block vs. packed.

The paper's headline claim is raw per-iteration speed; the per-block
executor pays O(P²) host→XLA round-trips per update sweep, so at realistic
P the run is dispatch-bound. This benchmark measures:

* **Uniform section** (Erdős–Rényi, P ∈ {8, 16, 32}, device residency,
  PageRank): per-sweep wall seconds and jitted-primitive dispatches per
  sweep for both execution modes (counted by wrapping the session's jit
  entry points), with bit-identity and meter equality asserted per row.
* **Power-law section** (Zipf + R-MAT, P ∈ {16, 32} — the skew regime
  NXgraph §V targets): padded-edge ratio and per-sweep wall of the legacy
  one-tile-per-sub-shard packing vs. adaptive destination-aligned tiles,
  and out-of-core (`residency="host"`, budget ≈ half the edge bytes)
  per-sweep wall + raw h2d volume of packed streaming vs. the per-block
  fetcher — the downgrade adaptive tiling removed.

* **Frontier section** (BFS on R-MAT, ``residency="host"``, tight
  budget): physical per-sweep ``bytes_h2d`` of frontier-aware selective
  execution (``activity="auto"``) vs the full-sweep ``activity="off"``
  baseline, with the closed-form/meter exactness asserted and the
  late-iteration (collapsed-frontier) skip ratio reported.

* **Kernel section** (``execution="packed_kernel"`` vs ``"packed"`` on
  the same tiles): per-sweep wall + dispatch counts of the fused Pallas
  sweep against the XLA scan, asserting bit-identical attrs, identical
  meters, and exactly one fused ``pallas_call`` dispatch per sweep.
  Off-TPU the kernel runs in interpret mode, so its wall number is a
  correctness-path cost, not a speed claim — the claim is the dispatch
  shape and the bits.

Writes ``BENCH_sweep.json`` (repo root by default); CI runs the
``--smoke`` variant per PR with ``--assert-padding-ratio 1.25``,
``--assert-skip-ratio 5.0`` and ``--assert-kernel-parity`` so
dispatch-count, padding, frontier-skip *and* kernel-parity regressions
fail the build.

Usage::

    PYTHONPATH=src python benchmarks/bench_sweep.py            # full, writes BENCH_sweep.json
    PYTHONPATH=src python benchmarks/bench_sweep.py --smoke    # tiny graphs, CI artifact
"""
import argparse
import dataclasses
import json
import pathlib
import sys

import numpy as np

_ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_ROOT))  # so `benchmarks._util` resolves as a script
sys.path.insert(0, str(_ROOT / "src"))

import jax  # noqa: E402

from benchmarks._util import stamp  # noqa: E402

from repro.core import BFS, ExecutionPlan, GraphSession, PageRank, build_dsss  # noqa: E402
from repro.core import session as session_mod  # noqa: E402
from repro.core.iomodel import packed_h2d_bytes, selective_streamed_tiles  # noqa: E402
from repro.graph.generators import erdos_renyi, rmat, zipf  # noqa: E402
from repro.graph.preprocess import degree_and_densify  # noqa: E402

# The session's jit entry points — one call == one host-scheduled XLA
# dispatch in the update loop.
_PER_BLOCK_PRIMITIVES = [
    "_block_gather_reduce",
    "_block_to_hub",
    "_block_from_hub",
    "_apply_interval",
    "_pre_iteration",
]


class DispatchCounter:
    """Counts calls to the session's jitted primitives while active.

    ``count`` is every host-scheduled dispatch; ``kernel_count`` is the
    subset that went through the fused Pallas sweep executables
    (``execution="packed_kernel"``).
    """

    def __init__(self):
        self.count = 0
        self.kernel_count = 0
        self._saved = {}

    def _wrap(self, fn, kernel=False):
        def counted(*a, **kw):
            self.count += 1
            if kernel:
                self.kernel_count += 1
            return fn(*a, **kw)

        return counted

    def __enter__(self):
        for name in _PER_BLOCK_PRIMITIVES:
            fn = getattr(session_mod, name)
            self._saved[name] = fn
            setattr(session_mod, name, self._wrap(fn))
        real_jits = session_mod._packed_jits
        self._saved["_packed_jits"] = real_jits

        def counting_jits(donate):
            sweep, apply_all = real_jits(donate)
            return self._wrap(sweep), self._wrap(apply_all)

        session_mod._packed_jits = counting_jits
        real_select = session_mod._packed_select_jits
        self._saved["_packed_select_jits"] = real_select

        def counting_select(donate):
            return self._wrap(real_select(donate))

        session_mod._packed_select_jits = counting_select
        real_kernel = session_mod._packed_kernel_jits
        self._saved["_packed_kernel_jits"] = real_kernel

        def counting_kernel(donate):
            return self._wrap(real_kernel(donate), kernel=True)

        session_mod._packed_kernel_jits = counting_kernel
        real_kernel_select = session_mod._packed_kernel_select_jits
        self._saved["_packed_kernel_select_jits"] = real_kernel_select

        def counting_kernel_select(donate):
            return self._wrap(real_kernel_select(donate), kernel=True)

        session_mod._packed_kernel_select_jits = counting_kernel_select
        return self

    def __exit__(self, *exc):
        for name, fn in self._saved.items():
            setattr(session_mod, name, fn)
        return False


def bench_one(session, strategy, execution, iters):
    plan = ExecutionPlan(
        PageRank(), strategy=strategy, max_iters=iters, tol=0.0, execution=execution
    )
    session.run(plan)  # warmup: staging + jit compilation
    with DispatchCounter() as counter:
        res = session.run(plan)
    assert res.iterations == iters
    return {
        "strategy": strategy,
        "mode": execution,
        "per_sweep_seconds": res.meters.wall_seconds / res.iterations,
        "dispatches_per_sweep": counter.count / res.iterations,
        "fused_dispatches_per_sweep": counter.kernel_count / res.iterations,
        "mteps": res.meters.mteps(),
        "h2d_per_sweep": res.meters.bytes_h2d / res.iterations,
        "attrs": res.attrs,
        "meters": res.meters,
    }


def uniform_section(report, args):
    src, dst = erdos_renyi(args.n, args.m, seed=args.seed)
    el = degree_and_densify(src, dst, drop_self_loops=True)
    report["graph"] = {
        "generator": "erdos_renyi", "n": el.n, "m": el.m, "seed": args.seed,
    }
    for P in args.p_values:
        g = build_dsss(el, P)
        sess = GraphSession(g, residency="device")
        packed = g.packed_sweep()
        print(
            f"P={P}: {len(sess.block_keys)} sub-shards, tile_edges="
            f"{packed.tile_edges}, padded_slots={packed.padded_edge_slots} "
            f"({packed.padding_ratio:.2f}x edges)"
        )
        for strategy in args.strategies:
            rows = {}
            for execution in ("per_block", "packed"):
                r = bench_one(sess, strategy, execution, args.iters)
                rows[execution] = r
                print(
                    f"  {strategy:>4} {execution:>9}: "
                    f"{r['per_sweep_seconds'] * 1e3:8.2f} ms/sweep, "
                    f"{r['dispatches_per_sweep']:7.1f} dispatches/sweep"
                )
            np.testing.assert_array_equal(
                rows["per_block"].pop("attrs"), rows["packed"].pop("attrs")
            )
            m_pb = dataclasses.asdict(rows["per_block"].pop("meters"))
            m_pk = dataclasses.asdict(rows["packed"].pop("meters"))
            m_pb.pop("wall_seconds"), m_pk.pop("wall_seconds")
            assert m_pb == m_pk, "execution modes must meter identically"
            speedup = (
                rows["per_block"]["per_sweep_seconds"]
                / rows["packed"]["per_sweep_seconds"]
            )
            dispatch_ratio = (
                rows["per_block"]["dispatches_per_sweep"]
                / rows["packed"]["dispatches_per_sweep"]
            )
            print(
                f"  {strategy:>4}   speedup: {speedup:5.1f}x wall, "
                f"{dispatch_ratio:5.1f}x fewer dispatches "
                f"(bit-identical, meters identical)"
            )
            for execution in ("per_block", "packed"):
                report["results"].append({"P": P, **rows[execution]})
            report["speedups"].append(
                {
                    "P": P,
                    "strategy": strategy,
                    "wall_speedup": speedup,
                    "dispatch_ratio": dispatch_ratio,
                }
            )


def powerlaw_section(report, args):
    """Skewed graphs: old vs adaptive packing, packed-host vs per-block-host."""
    graphs = []
    if args.smoke:
        graphs.append(("zipf", zipf(2000, 14000, alpha=1.9, seed=args.seed)))
    else:
        graphs.append(("zipf", zipf(args.n, args.m, alpha=1.9, seed=args.seed)))
        graphs.append(("rmat", rmat(14, 8, seed=args.seed)))
    for gen_name, (src, dst) in graphs:
        el = degree_and_densify(src, dst, drop_self_loops=True)
        for P in args.pl_p_values:
            g = build_dsss(el, P)
            adaptive = g.packed_sweep("adaptive")
            legacy = g.packed_sweep("subshard")
            print(
                f"{gen_name} P={P} (n={el.n}, m={el.m}): padding "
                f"adaptive={adaptive.padding_ratio:.3f}x "
                f"(T={adaptive.tile_edges}, NT={adaptive.num_tiles}) vs "
                f"subshard={legacy.padding_ratio:.3f}x "
                f"(T={legacy.tile_edges}, NT={legacy.num_tiles})"
            )
            row = {
                "generator": gen_name,
                "P": P,
                "n": el.n,
                "m": el.m,
                "padding_ratio_adaptive": adaptive.padding_ratio,
                "padding_ratio_subshard": legacy.padding_ratio,
                "tile_edges_adaptive": adaptive.tile_edges,
                "tile_edges_subshard": legacy.tile_edges,
            }
            # Device residency: the packing ablation (same compiled path).
            dev_rows = {}
            for packing in ("subshard", "adaptive"):
                sess = GraphSession(g, residency="device", packing=packing)
                r = bench_one(sess, "spu", "packed", args.iters)
                dev_rows[packing] = r
                row[f"device_packed_{packing}_per_sweep_seconds"] = r[
                    "per_sweep_seconds"
                ]
                print(
                    f"  device packed/{packing:>8}: "
                    f"{r['per_sweep_seconds'] * 1e3:8.2f} ms/sweep"
                )
            np.testing.assert_array_equal(
                dev_rows["subshard"]["attrs"], dev_rows["adaptive"]["attrs"]
            )
            # Out-of-core: budget ≈ attrs + half the edge bytes, SPU.
            budget = 2 * g.n_pad * 8 + g.total_edge_bytes(8) // 2
            sess_h = GraphSession(g, memory_budget=budget, residency="host")
            host_rows = {}
            for execution in ("per_block", "packed"):
                r = bench_one(sess_h, "spu", execution, args.host_iters)
                host_rows[execution] = r
                row[f"host_{execution}_per_sweep_seconds"] = r["per_sweep_seconds"]
                row[f"host_{execution}_h2d_per_sweep"] = r["h2d_per_sweep"]
                print(
                    f"  host   {execution:>9}: "
                    f"{r['per_sweep_seconds'] * 1e3:8.2f} ms/sweep, "
                    f"h2d {r['h2d_per_sweep'] / 1e6:6.2f} MB/sweep, "
                    f"{r['dispatches_per_sweep']:6.1f} dispatches/sweep"
                )
            np.testing.assert_array_equal(
                host_rows["per_block"]["attrs"], host_rows["packed"]["attrs"]
            )
            # Host ≡ device bit-identity, at matching sweep counts (the
            # device ablation rows above may use a different iters).
            dev_ref = GraphSession(g, residency="device").run(
                ExecutionPlan(
                    PageRank(), strategy="spu", max_iters=args.host_iters,
                    tol=0.0, execution="packed",
                )
            )
            np.testing.assert_array_equal(
                host_rows["packed"]["attrs"], dev_ref.attrs
            )
            assert (
                host_rows["per_block"]["meters"].model_dict()
                == host_rows["packed"]["meters"].model_dict()
            ), "host execution modes must model-meter identically"
            row["host_wall_speedup"] = (
                row["host_per_block_per_sweep_seconds"]
                / row["host_packed_per_sweep_seconds"]
            )
            row["device_packing_wall_speedup"] = (
                row["device_packed_subshard_per_sweep_seconds"]
                / row["device_packed_adaptive_per_sweep_seconds"]
            )
            print(
                f"  adaptive vs subshard: {row['device_packing_wall_speedup']:.2f}x; "
                f"packed-host vs per-block-host: {row['host_wall_speedup']:.2f}x "
                "(bit-identical, model meters identical)"
            )
            report["powerlaw"].append(row)


def frontier_section(report, args):
    """Frontier-aware selective execution: BFS on R-MAT, host residency.

    Selective (``activity="auto"``, the default for monotone programs) vs
    the full-sweep ``activity="off"`` baseline, out-of-core. The physical
    per-sweep ``bytes_h2d`` is reconstructed from the run's
    ``activity_log`` via the iomodel closed form and asserted to match
    the measured meter exactly; the gated headline is the *late-iteration*
    skip — the trailing sweeps whose frontier has collapsed to ≤ P/2
    intervals, where NXgraph-style activity tracking pays off most.
    """
    scale = 13 if args.smoke else 15
    P = 16 if args.smoke else 32
    src, dst = rmat(scale, 4, seed=args.seed)
    el = degree_and_densify(src, dst, drop_self_loops=True)
    g = build_dsss(el, P)
    # A tight budget: nothing pins, chunks are fine-grained — the regime
    # where skipping inactive streamed chunks can actually bite.
    budget = int((2 * g.n_pad * 8 + g.total_edge_bytes(8)) * 0.05)
    plan_kw = dict(
        strategy="spu", max_iters=g.n + 1, execution="packed",
        program_kwargs={"root": 0},
    )
    runs = {}
    for activity in ("auto", "off"):
        sess = GraphSession(g, memory_budget=budget, residency="host")
        plan = ExecutionPlan(BFS(), activity=activity, **plan_kw)
        sess.run(plan)  # warmup: staging + jit compilation
        with DispatchCounter() as counter:
            res = sess.run(plan)
        runs[activity] = (sess, res, counter.count / res.iterations)
    sess, on, on_disp = runs["auto"]
    _, off, off_disp = runs["off"]
    np.testing.assert_array_equal(on.attrs, off.attrs)
    assert on.iterations == off.iterations
    # Measured-vs-modelled exactness: the per-sweep closed form over the
    # activity log reproduces the physical meter byte for byte.
    compiled = sess.compile(ExecutionPlan(BFS(), **plan_kw))
    splan = sess.packed_stream_plan(compiled.choice.strategy, 4)
    full_sweep = packed_h2d_bytes(
        splan.num_tiles - splan.pin_tiles, splan.tile_edges
    )
    per_sweep = [
        packed_h2d_bytes(
            selective_streamed_tiles(
                sess._packed_tile_activity(log),
                splan.pin_tiles,
                splan.chunk_tiles,
            ),
            splan.tile_edges,
        )
        for log in on.activity_log
    ]
    assert sum(per_sweep) == on.meters.bytes_h2d
    assert off.meters.bytes_h2d == full_sweep * off.iterations
    frontier = [int(log.sum()) for log in on.activity_log]
    # Late iterations: the trailing sweeps with a collapsed (≤ P/2) frontier.
    k = len(frontier)
    while k > 0 and frontier[k - 1] <= P // 2:
        k -= 1
    late = list(range(k, len(frontier))) or [len(frontier) - 1]
    late_on = sum(per_sweep[i] for i in late)
    late_skip_ratio = (full_sweep * len(late)) / max(late_on, 1.0)
    row = {
        "generator": "rmat",
        "scale": scale,
        "P": P,
        "n": el.n,
        "m": el.m,
        "sweeps": on.iterations,
        "frontier_intervals": frontier,
        "h2d_selective": on.meters.bytes_h2d,
        "h2d_off": off.meters.bytes_h2d,
        "h2d_ratio": off.meters.bytes_h2d / on.meters.bytes_h2d,
        "late_sweeps": late,
        "late_skip_ratio": late_skip_ratio,
        "dispatches_per_sweep_selective": on_disp,
        "dispatches_per_sweep_off": off_disp,
        "per_sweep_seconds_selective": on.meters.wall_seconds / on.iterations,
        "per_sweep_seconds_off": off.meters.wall_seconds / off.iterations,
    }
    print(
        f"frontier rmat scale={scale} P={P} (n={el.n}, m={el.m}): "
        f"{on.iterations} sweeps, frontier {frontier}; h2d "
        f"{on.meters.bytes_h2d / 1e6:.2f} MB selective vs "
        f"{off.meters.bytes_h2d / 1e6:.2f} MB off "
        f"({row['h2d_ratio']:.2f}x), late sweeps {late}: "
        f"{late_skip_ratio:.1f}x skip (bit-identical, meters exact)"
    )
    report["frontier"].append(row)


def kernel_section(report, args):
    """Fused Pallas sweep (``packed_kernel``) vs the XLA scan (``packed``).

    Both executables are driven through the identical session machinery
    (same staging, same streaming, same apply), so every row asserts
    bit-identical attrs and fully identical meters — including physical
    fields — and that the kernel mode dispatched exactly one fused
    ``pallas_call`` per update sweep with the same total dispatch count
    as the scan. Off-TPU the kernel runs under the Pallas interpreter,
    so wall seconds compare a debugging path against compiled XLA; on
    TPU (``backend == "compiled"``) they compare like against like.
    """
    from repro.kernels.dsss_spmv import default_interpret

    n, m, P, iters = (400, 2_400, 4, 2) if args.smoke else (3_000, 18_000, 8, 3)
    src, dst = erdos_renyi(n, m, seed=args.seed)
    el = degree_and_densify(src, dst, drop_self_loops=True)
    g = build_dsss(el, P)
    sess = GraphSession(g, residency="device")
    kernel_backend = "interpret" if default_interpret() else "compiled"
    for strategy in ("spu", "dpu"):
        rows = {}
        for execution in ("packed", "packed_kernel"):
            r = bench_one(sess, strategy, execution, iters)
            rows[execution] = r
            print(
                f"kernel {strategy:>4} {execution:>13}: "
                f"{r['per_sweep_seconds'] * 1e3:8.2f} ms/sweep, "
                f"{r['dispatches_per_sweep']:5.1f} dispatches/sweep "
                f"({r['fused_dispatches_per_sweep']:.1f} fused)"
            )
        np.testing.assert_array_equal(
            rows["packed"].pop("attrs"), rows["packed_kernel"].pop("attrs")
        )
        m_scan = dataclasses.asdict(rows["packed"].pop("meters"))
        m_kern = dataclasses.asdict(rows["packed_kernel"].pop("meters"))
        m_scan.pop("wall_seconds"), m_kern.pop("wall_seconds")
        assert m_scan == m_kern, "kernel and scan must meter identically"
        row = {
            "P": P,
            "n": el.n,
            "m": el.m,
            "strategy": strategy,
            "kernel_backend": kernel_backend,
            "scan_per_sweep_seconds": rows["packed"]["per_sweep_seconds"],
            "kernel_per_sweep_seconds": rows["packed_kernel"][
                "per_sweep_seconds"
            ],
            "scan_dispatches_per_sweep": rows["packed"]["dispatches_per_sweep"],
            "kernel_dispatches_per_sweep": rows["packed_kernel"][
                "dispatches_per_sweep"
            ],
            "fused_dispatches_per_sweep": rows["packed_kernel"][
                "fused_dispatches_per_sweep"
            ],
            "bit_identical": True,
            "meters_identical": True,
        }
        print(
            f"kernel {strategy:>4}   parity: bit-identical, meters identical, "
            f"{row['fused_dispatches_per_sweep']:.1f} fused dispatch/sweep "
            f"({kernel_backend})"
        )
        report["kernel"].append(row)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--p-values", type=int, nargs="+", default=[8, 16, 32])
    ap.add_argument("--pl-p-values", type=int, nargs="+", default=[16, 32])
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--m", type=int, default=120_000)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--host-iters", type=int, default=3)
    ap.add_argument(
        "--strategies", nargs="+", default=["spu", "dpu"],
        choices=["spu", "dpu", "mpu"],
    )
    ap.add_argument(
        "--assert-padding-ratio", type=float, default=None,
        help="fail (exit 1) if any power-law adaptive padding ratio exceeds this",
    )
    ap.add_argument(
        "--assert-skip-ratio", type=float, default=None,
        help="fail (exit 1) if the frontier section's late-iteration h2d "
        "skip ratio (selective vs activity='off') falls below this",
    )
    ap.add_argument(
        "--assert-kernel-parity", action="store_true",
        help="fail (exit 1) unless every kernel-section row is "
        "bit-identical and meter-identical to the scan with exactly one "
        "fused dispatch per sweep",
    )
    ap.add_argument(
        "--out",
        default=str(pathlib.Path(__file__).resolve().parent.parent / "BENCH_sweep.json"),
    )
    ap.add_argument(
        "--smoke", action="store_true",
        help="tiny graphs, P=[4]/[16], 2 sweeps — the CI artifact variant",
    )
    args = ap.parse_args(argv)
    if args.smoke:
        args.p_values, args.n, args.m, args.iters = [4], 400, 2_400, 2
        args.pl_p_values, args.host_iters = [16], 2

    report = {
        "benchmark": "bench_sweep",
        "backend": jax.default_backend(),
        "iters_per_run": args.iters,
        "results": [],
        "speedups": [],
        "powerlaw": [],
        "frontier": [],
        "kernel": [],
    }
    uniform_section(report, args)
    powerlaw_section(report, args)
    frontier_section(report, args)
    kernel_section(report, args)
    if args.assert_kernel_parity:
        for row in report["kernel"]:
            assert row["bit_identical"] and row["meters_identical"], (
                f"kernel {row['strategy']} P={row['P']}: parity broken"
            )
            assert row["fused_dispatches_per_sweep"] == 1.0, (
                f"kernel {row['strategy']} P={row['P']}: expected exactly "
                f"one fused dispatch per sweep, got "
                f"{row['fused_dispatches_per_sweep']}"
            )
            assert (
                row["kernel_dispatches_per_sweep"]
                == row["scan_dispatches_per_sweep"]
            ), (
                f"kernel {row['strategy']} P={row['P']}: dispatch shape "
                f"diverged ({row['kernel_dispatches_per_sweep']} vs "
                f"{row['scan_dispatches_per_sweep']})"
            )
        print(
            f"kernel-parity gate holds on all {len(report['kernel'])} "
            "kernel configurations"
        )
    if args.assert_skip_ratio is not None:
        for row in report["frontier"]:
            assert row["late_skip_ratio"] >= args.assert_skip_ratio, (
                f"frontier {row['generator']} scale={row['scale']} "
                f"P={row['P']}: late-iteration skip ratio "
                f"{row['late_skip_ratio']:.2f} below the "
                f"{args.assert_skip_ratio} bound"
            )
        print(
            f"late-iteration skip-ratio bound {args.assert_skip_ratio} holds "
            f"on all {len(report['frontier'])} frontier configurations"
        )
    if args.assert_padding_ratio is not None:
        for row in report["powerlaw"]:
            assert row["padding_ratio_adaptive"] <= args.assert_padding_ratio, (
                f"{row['generator']} P={row['P']}: adaptive padding "
                f"{row['padding_ratio_adaptive']:.3f} exceeds the "
                f"{args.assert_padding_ratio} bound"
            )
        print(
            f"padding-ratio bound {args.assert_padding_ratio} holds on all "
            f"{len(report['powerlaw'])} power-law configurations"
        )
    out = pathlib.Path(args.out)
    stamp(report, bench="sweep", smoke=args.smoke)
    out.write_text(json.dumps(report, indent=2) + "\n")
    print(f"wrote {out}")
    return report


if __name__ == "__main__":
    main()
