"""Scrapeable telemetry endpoint — ``/metrics`` + ``/healthz`` over stdlib.

A tiny :class:`http.server.ThreadingHTTPServer` in a daemon thread, so it
needs neither an asyncio loop nor any third-party dependency and survives
the serving loop's start/stop cycles (``GraphServer.serve`` runs one
event loop per wave; the scrape endpoint stays up in between so CI can
curl counters *after* a fault-injection wave completes).

Routes:

* ``GET /metrics`` — Prometheus text exposition of the process registry.
  ``on_scrape`` (if given) runs first, which is how :class:`~repro.
  serving.server.GraphServer` publishes a fresh ``ServerStats``/
  ``PoolStats`` snapshot per scrape — scraped serving counters are
  therefore *equal to* the stats object by construction, not eventually
  consistent with it.
* ``GET /healthz`` — JSON health document from ``health_fn``; HTTP 200
  when ``status == "ok"``, 503 otherwise (breaker open, queue saturated).
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from repro.obs.registry import REGISTRY

__all__ = ["TelemetryServer"]


class TelemetryServer:
    """Serve ``registry`` (default: the process registry) over HTTP.

    ``port=0`` binds an ephemeral port; read it back from ``address``
    after :meth:`start`. Usable as a context manager.
    """

    def __init__(
        self,
        *,
        registry=None,
        health_fn=None,
        on_scrape=None,
        host: str = "127.0.0.1",
        port: int = 0,
    ):
        self.registry = registry if registry is not None else REGISTRY
        self.health_fn = health_fn
        self.on_scrape = on_scrape
        self._host = host
        self._port = port
        self._httpd: ThreadingHTTPServer | None = None
        self._thread: threading.Thread | None = None

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "TelemetryServer":
        if self._httpd is not None:
            raise RuntimeError("telemetry server already started")
        owner = self

        class Handler(BaseHTTPRequestHandler):
            def log_message(self, *a):  # silence per-request stderr noise
                pass

            def do_GET(self):
                try:
                    owner._handle(self)
                except BrokenPipeError:
                    pass

        self._httpd = ThreadingHTTPServer((self._host, self._port), Handler)
        self._httpd.daemon_threads = True
        self._thread = threading.Thread(
            target=self._httpd.serve_forever,
            name="repro-telemetry",
            daemon=True,
        )
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._httpd is None:
            return
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5)
        self._httpd = None
        self._thread = None

    def __enter__(self) -> "TelemetryServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    @property
    def address(self) -> tuple[str, int]:
        """(host, bound port) — resolves ``port=0`` to the real port."""
        if self._httpd is None:
            raise RuntimeError("telemetry server is not started")
        return self._httpd.server_address[:2]

    def url(self, path: str = "/metrics") -> str:
        host, port = self.address
        return f"http://{host}:{port}{path}"

    # -- request handling ----------------------------------------------------
    def _handle(self, req: BaseHTTPRequestHandler) -> None:
        if req.path in ("/metrics", "/metrics/"):
            if self.on_scrape is not None:
                try:
                    self.on_scrape()
                except Exception as exc:
                    self._send(req, 500, f"scrape callback failed: {exc}\n")
                    return
            body = self.registry.render()
            self._send(
                req, 200, body,
                content_type="text/plain; version=0.0.4; charset=utf-8",
            )
        elif req.path in ("/healthz", "/healthz/"):
            doc = {"status": "ok"}
            if self.health_fn is not None:
                try:
                    doc = dict(self.health_fn())
                except Exception as exc:
                    doc = {"status": "error", "error": str(exc)}
            code = 200 if doc.get("status") == "ok" else 503
            self._send(
                req, code, json.dumps(doc, sort_keys=True) + "\n",
                content_type="application/json",
            )
        else:
            self._send(req, 404, "try /metrics or /healthz\n")

    @staticmethod
    def _send(req, code: int, body: str, *, content_type="text/plain") -> None:
        data = body.encode("utf-8")
        req.send_response(code)
        req.send_header("Content-Type", content_type)
        req.send_header("Content-Length", str(len(data)))
        req.end_headers()
        req.wfile.write(data)
