"""Structured trace spans — a bounded ring recorder with Perfetto export.

The engine wraps staging, every update sweep (annotated with that sweep's
physical ``bytes_h2d``/``bytes_disk_read`` deltas and active-interval
count), checkpoint writes and serving batch cuts in spans recorded here.
The ring (:class:`Tracer`) is lock-free-ish: spans are immutable tuples
appended to a ``collections.deque(maxlen=capacity)`` (atomic under the
GIL), with one tiny lock only around the thread-label table — recording
never blocks the sweep loop on another thread's export.

Export is Chrome/Perfetto ``trace_event`` JSON (``ph="X"`` complete
events, microsecond timestamps, ``M``-phase thread-name metadata), loadable
directly in https://ui.perfetto.dev. ``python -m repro.obs export-trace``
converts a raw ``.jsonl`` span dump into the same format offline.

Tracing is **off by default** — the disabled path is one attribute check
per gate site, which is what keeps the engine's no-trace overhead within
the ≤2% bench budget. Enable process-wide with :func:`enable_tracing`, or
per run with the :class:`TraceSpec` plan knob
(``ExecutionPlan(trace=TraceSpec(path="run.json"))``), which turns the
recorder on for that run's duration and writes its spans on completion.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import json
import threading
import time

__all__ = [
    "Span",
    "TraceSpec",
    "Tracer",
    "TRACER",
    "enable_tracing",
    "disable_tracing",
]


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """The tracing axis of an :class:`~repro.core.plan.ExecutionPlan`.

    Args:
      path: where to write this run's spans when it completes — Chrome
        ``trace_event`` JSON by default, or a raw one-span-per-line
        ``.jsonl`` dump when the path ends in ``.jsonl`` (convertible
        offline via ``python -m repro.obs export-trace``). ``None``
        records into the process ring without exporting.
      sweeps: record one span per update sweep (with per-sweep byte
        deltas); ``False`` keeps only the run/staging/checkpoint spans.

    The knob is observational: it deliberately does **not** participate in
    ``plan.batch_key()``, so traced and untraced requests still fuse (a
    fused batch records under the first member's spec).
    """

    path: str | None = None
    sweeps: bool = True


@dataclasses.dataclass(frozen=True)
class Span:
    """One completed span (seconds; ``ts`` is ``time.perf_counter`` based)."""

    seq: int
    name: str
    cat: str
    ts: float
    dur: float
    tid: int
    args: tuple  # sorted (key, value) pairs — kept hashable/immutable

    def args_dict(self) -> dict:
        return dict(self.args)


def _freeze_args(args: dict | None) -> tuple:
    if not args:
        return ()
    return tuple(sorted(args.items()))


class Tracer:
    """Bounded in-process span recorder.

    ``record``/``instant`` append unconditionally — *callers* gate on
    ``tracer.enabled`` (one branch) so the disabled path never builds an
    args dict. The ``span`` context manager gates itself and is the
    convenient form for non-hot call sites.
    """

    def __init__(self, capacity: int = 65536):
        self.enabled = False
        self._ring: collections.deque[Span] = collections.deque(
            maxlen=capacity
        )
        self._seq = itertools.count()
        self._lock = threading.Lock()
        self._tids: dict[str, int] = {}

    # -- recording -----------------------------------------------------------
    def tid_for(self, label: str | None = None) -> int:
        """Stable small integer for a logical track (default: this thread)."""
        if label is None:
            label = threading.current_thread().name
        tid = self._tids.get(label)
        if tid is None:
            with self._lock:
                tid = self._tids.setdefault(label, len(self._tids) + 1)
        return tid

    def record(
        self,
        name: str,
        t0: float,
        t1: float,
        *,
        cat: str = "repro",
        tid_label: str | None = None,
        args: dict | None = None,
    ) -> None:
        """Append one completed span (caller supplies perf_counter times)."""
        self._ring.append(
            Span(
                seq=next(self._seq),
                name=name,
                cat=cat,
                ts=t0,
                dur=max(t1 - t0, 0.0),
                tid=self.tid_for(tid_label),
                args=_freeze_args(args),
            )
        )

    def instant(
        self,
        name: str,
        *,
        cat: str = "repro",
        tid_label: str | None = None,
        args: dict | None = None,
    ) -> None:
        now = time.perf_counter()
        self.record(name, now, now, cat=cat, tid_label=tid_label, args=args)

    def span(self, name: str, *, cat: str = "repro", **args):
        """Context manager; records on exit iff the tracer is enabled."""
        return _SpanCtx(self, name, cat, args)

    # -- access / export -----------------------------------------------------
    def mark(self) -> int:
        """A position token; pass to ``spans``/``export`` as ``since``."""
        return next(self._seq)

    def spans(self, since: int = 0) -> list[Span]:
        return [s for s in list(self._ring) if s.seq >= since]

    def clear(self) -> None:
        self._ring.clear()

    def _tid_labels(self) -> dict[int, str]:
        with self._lock:
            return {tid: label for label, tid in self._tids.items()}

    def to_chrome(self, since: int = 0) -> dict:
        """Chrome/Perfetto ``trace_event`` JSON object for the recorded spans."""
        labels = self._tid_labels()
        events = [
            {
                "ph": "M",
                "pid": 1,
                "tid": tid,
                "name": "thread_name",
                "args": {"name": label},
            }
            for tid, label in sorted(labels.items())
        ]
        for s in self.spans(since):
            events.append(
                {
                    "name": s.name,
                    "cat": s.cat,
                    "ph": "X",
                    "pid": 1,
                    "tid": s.tid,
                    "ts": s.ts * 1e6,
                    "dur": s.dur * 1e6,
                    "args": s.args_dict(),
                }
            )
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export(self, path: str, since: int = 0) -> str:
        """Write spans to ``path`` — Chrome JSON, or raw jsonl for ``.jsonl``."""
        if path.endswith(".jsonl"):
            return self.dump(path, since=since)
        with open(path, "w") as fh:
            json.dump(self.to_chrome(since), fh)
        return path

    def dump(self, path: str, since: int = 0) -> str:
        """Raw one-span-per-line dump (offline-convertible, append-friendly)."""
        labels = self._tid_labels()
        with open(path, "w") as fh:
            for s in self.spans(since):
                fh.write(
                    json.dumps(
                        {
                            "name": s.name,
                            "cat": s.cat,
                            "ts": s.ts,
                            "dur": s.dur,
                            "tid": s.tid,
                            "tlabel": labels.get(s.tid, str(s.tid)),
                            "args": s.args_dict(),
                        }
                    )
                    + "\n"
                )
        return path


class _SpanCtx:
    __slots__ = ("_tracer", "_name", "_cat", "_args", "_t0", "_live")

    def __init__(self, tracer, name, cat, args):
        self._tracer = tracer
        self._name = name
        self._cat = cat
        self._args = args
        self._t0 = 0.0
        self._live = False

    def __enter__(self):
        self._live = self._tracer.enabled
        if self._live:
            self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        if self._live:
            self._tracer.record(
                self._name,
                self._t0,
                time.perf_counter(),
                cat=self._cat,
                args=self._args,
            )
        return False


#: The process-global tracer every repro subsystem records into.
TRACER = Tracer()


def enable_tracing(capacity: int | None = None) -> Tracer:
    """Turn the process tracer on (optionally resizing its ring in place —
    modules hold direct references to :data:`TRACER`, so it is never
    replaced)."""
    if capacity is not None and capacity != TRACER._ring.maxlen:
        TRACER._ring = collections.deque(TRACER._ring, maxlen=capacity)
    TRACER.enabled = True
    return TRACER


def disable_tracing() -> Tracer:
    TRACER.enabled = False
    return TRACER
