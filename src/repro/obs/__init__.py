"""repro.obs — zero-dependency observability: metrics, traces, scraping.

The cross-cutting layer that makes the engine's exact byte accounting
*visible* while it happens:

* :data:`REGISTRY` — process-wide metrics registry (counters / gauges /
  fixed-bucket histograms with labels). ``GraphSession`` runs, the block
  fetcher, the packed chunk streamer, storage self-healing reads,
  checkpoint publishes and the serving server/pool/breaker all publish
  into it at the same lines that charge ``Meters`` — registry deltas
  across a run recombine field-for-field with ``Result.meters``.
  Rendered as Prometheus text exposition by :meth:`MetricsRegistry.
  render`; disable everything with ``REPRO_OBS=0``.
* :data:`TRACER` — bounded ring recorder of structured spans (staging,
  each sweep with its physical byte deltas, checkpoint writes, serving
  batch cuts), exportable as Chrome/Perfetto ``trace_event`` JSON. Off
  by default; enable process-wide via :func:`enable_tracing` or per run
  via the :class:`TraceSpec` plan knob.
* :class:`TelemetryServer` — stdlib HTTP endpoint serving ``/metrics``
  and ``/healthz`` (attached to ``GraphServer`` via
  ``telemetry_port=...``).
* ``python -m repro.obs export-trace spans.jsonl -o trace.json`` —
  offline converter from raw span dumps to Perfetto-loadable JSON.
"""
from repro.obs.http import TelemetryServer
from repro.obs.registry import (
    DEFAULT_LATENCY_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    HistogramValue,
    MetricsRegistry,
    REGISTRY,
    parse_prometheus,
)
from repro.obs.trace import (
    Span,
    TraceSpec,
    Tracer,
    TRACER,
    disable_tracing,
    enable_tracing,
)

__all__ = [
    "DEFAULT_LATENCY_BUCKETS",
    "Counter",
    "Gauge",
    "Histogram",
    "HistogramValue",
    "MetricsRegistry",
    "REGISTRY",
    "Span",
    "TelemetryServer",
    "TraceSpec",
    "Tracer",
    "TRACER",
    "disable_tracing",
    "enable_tracing",
    "parse_prometheus",
]
