"""Process-wide metrics registry — counters, gauges, histograms with labels.

Zero-dependency (stdlib only) by design: the engine, the storage tier and
the serving stack all publish into one process-global
:data:`REGISTRY`, and anything that can speak HTTP can scrape it as
Prometheus text exposition (:meth:`MetricsRegistry.render`, served by
:class:`repro.obs.http.TelemetryServer`).

The publishing contract mirrors the engine's meter discipline: *metrics
are emitted where the data moves*. ``_BlockFetcher._upload`` increments
``repro_engine_bytes_total{kind="h2d"}`` on the same line that charges
``Meters.bytes_h2d``, so the registry's deltas across a run recombine
field-for-field with ``Result.meters`` (tests/test_obs.py asserts this
over the device/host/disk matrix).

Overhead: every mutating call checks ``registry.enabled`` first and
returns immediately when the registry is disabled (``REPRO_OBS=0`` in the
environment, or :meth:`MetricsRegistry.set_enabled`), so hot paths pay
one attribute load + branch. Enabled counters take one small lock per
increment — the finest-grained call sites are per-streamed-chunk and
per-sweep, far off the per-edge fast path.
"""
from __future__ import annotations

import math
import os
import threading

__all__ = [
    "DEFAULT_LATENCY_BUCKETS",
    "Counter",
    "Gauge",
    "Histogram",
    "HistogramValue",
    "MetricsRegistry",
    "REGISTRY",
    "parse_prometheus",
]

# Fixed latency buckets (seconds) shared by the serving percentile stats
# and the scraped histogram — 0.5 ms .. 10 s, roughly log-spaced.
DEFAULT_LATENCY_BUCKETS = (
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)


def _escape_label(v: str) -> str:
    return str(v).replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _fmt_value(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    f = float(v)
    return repr(int(f)) if f.is_integer() and abs(f) < 2**53 else repr(f)


class _Value:
    """One labeled time series of a counter/gauge family."""

    __slots__ = ("_family", "_lock", "value")

    def __init__(self, family):
        self._family = family
        self._lock = threading.Lock()
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if not self._family._registry.enabled:
            return
        with self._lock:
            self.value += amount

    def set(self, value: float) -> None:
        if not self._family._registry.enabled:
            return
        with self._lock:
            self.value = float(value)

    def dec(self, amount: float = 1.0) -> None:
        self.inc(-amount)


class HistogramValue:
    """A fixed-bucket histogram series; usable standalone or in a family.

    ``observe`` files the sample into the first bucket whose upper bound
    covers it; ``quantile(q)`` linearly interpolates within the covering
    bucket (the standard fixed-bucket estimator — exact at bucket edges,
    bounded error inside). Standalone instances (``family=None``) have no
    registry gate and always record — :class:`repro.serving.server.
    GraphServer` owns one per server so its percentile stats are not
    polluted by other servers in the process.
    """

    __slots__ = ("_family", "_lock", "bounds", "counts", "sum", "count")

    def __init__(self, buckets=DEFAULT_LATENCY_BUCKETS, family=None):
        self._family = family
        self._lock = threading.Lock()
        self.bounds = tuple(sorted(float(b) for b in buckets))
        self.counts = [0] * (len(self.bounds) + 1)  # +1: the +Inf bucket
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        if self._family is not None and not self._family._registry.enabled:
            return
        i = 0
        for i, b in enumerate(self.bounds):  # noqa: B007
            if value <= b:
                break
        else:
            i = len(self.bounds)
        with self._lock:
            self.counts[i] += 1
            self.sum += value
            self.count += 1

    def quantile(self, q: float) -> float:
        """Estimated q-quantile (0..1); 0.0 with no observations."""
        if self.count == 0:
            return 0.0
        rank = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if seen + c >= rank:
                lo = self.bounds[i - 1] if i > 0 else 0.0
                hi = self.bounds[i] if i < len(self.bounds) else self.bounds[-1]
                frac = (rank - seen) / c
                return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
            seen += c
        return self.bounds[-1]


class _Family:
    """A named metric with a label schema; children keyed by label values."""

    kind = "untyped"

    def __init__(self, registry, name: str, help: str, labelnames=()):
        self._registry = registry
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._lock = threading.Lock()
        self._children: dict[tuple, object] = {}

    def _make_child(self):
        return _Value(self)

    def labels(self, *values, **kv):
        if kv:
            if values:
                raise TypeError("pass label values positionally or by name")
            values = tuple(str(kv[n]) for n in self.labelnames)
        else:
            values = tuple(str(v) for v in values)
        if len(values) != len(self.labelnames):
            raise ValueError(
                f"{self.name}: expected labels {self.labelnames}, "
                f"got {values!r}"
            )
        child = self._children.get(values)
        if child is None:
            with self._lock:
                child = self._children.setdefault(values, self._make_child())
        return child

    # Label-less convenience: family proxies its single child.
    def inc(self, amount: float = 1.0):
        self.labels().inc(amount)

    def set(self, value: float):
        self.labels().set(value)

    def dec(self, amount: float = 1.0):
        self.labels().dec(amount)

    def observe(self, value: float):
        self.labels().observe(value)

    def samples(self):
        """Yield ``(labelvalues, child)`` pairs (stable label order)."""
        with self._lock:
            items = sorted(self._children.items())
        return items


class Counter(_Family):
    kind = "counter"


class Gauge(_Family):
    kind = "gauge"


class Histogram(_Family):
    kind = "histogram"

    def __init__(self, registry, name, help, labelnames=(), buckets=None):
        super().__init__(registry, name, help, labelnames)
        self.buckets = tuple(buckets or DEFAULT_LATENCY_BUCKETS)

    def _make_child(self):
        return HistogramValue(self.buckets, family=self)


class MetricsRegistry:
    """Name → metric family; idempotent registration; Prometheus render.

    Registering an existing name returns the existing family (so modules
    can declare their handles at import time without coordination) —
    re-registering with a different type or label schema raises.
    """

    def __init__(self, enabled: bool | None = None):
        if enabled is None:
            enabled = os.environ.get("REPRO_OBS", "1") != "0"
        self.enabled = bool(enabled)
        self._lock = threading.Lock()
        self._families: dict[str, _Family] = {}

    def set_enabled(self, enabled: bool) -> None:
        self.enabled = bool(enabled)

    def _register(self, cls, name, help, labelnames, **kw):
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                if not isinstance(fam, cls) or fam.labelnames != tuple(labelnames):
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{fam.kind} with labels {fam.labelnames}"
                    )
                return fam
            fam = cls(self, name, help, labelnames, **kw)
            self._families[name] = fam
            return fam

    def counter(self, name: str, help: str = "", labelnames=()) -> Counter:
        return self._register(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "", labelnames=()) -> Gauge:
        return self._register(Gauge, name, help, labelnames)

    def histogram(
        self, name: str, help: str = "", labelnames=(), buckets=None
    ) -> Histogram:
        return self._register(
            Histogram, name, help, labelnames, buckets=buckets
        )

    def get(self, name: str) -> _Family | None:
        return self._families.get(name)

    def value(self, name: str, **labels) -> float:
        """Current value of one series (0.0 if the series doesn't exist).

        The test/CI-facing read API: snapshot before, snapshot after, the
        delta is what the intervening code published.
        """
        fam = self._families.get(name)
        if fam is None:
            return 0.0
        values = tuple(str(labels[n]) for n in fam.labelnames)
        child = fam._children.get(values)
        if child is None:
            return 0.0
        if isinstance(child, HistogramValue):
            return float(child.count)
        return float(child.value)

    def render(self) -> str:
        """Prometheus text exposition (version 0.0.4) of every family."""
        out = []
        with self._lock:
            families = sorted(self._families.items())
        for name, fam in families:
            if fam.help:
                out.append(f"# HELP {name} {fam.help}")
            out.append(f"# TYPE {name} {fam.kind}")
            for values, child in fam.samples():
                pairs = [
                    f'{n}="{_escape_label(v)}"'
                    for n, v in zip(fam.labelnames, values)
                ]
                if isinstance(child, HistogramValue):
                    cum = 0
                    for bound, c in zip(
                        (*child.bounds, math.inf),
                        child.counts,
                    ):
                        cum += c
                        lab = ", ".join(
                            (*pairs, f'le="{_fmt_value(bound)}"')
                        ) if pairs else f'le="{_fmt_value(bound)}"'
                        out.append(f"{name}_bucket{{{lab}}} {cum}")
                    suffix = "{" + ", ".join(pairs) + "}" if pairs else ""
                    out.append(f"{name}_sum{suffix} {_fmt_value(child.sum)}")
                    out.append(f"{name}_count{suffix} {child.count}")
                else:
                    suffix = "{" + ", ".join(pairs) + "}" if pairs else ""
                    out.append(f"{name}{suffix} {_fmt_value(child.value)}")
        return "\n".join(out) + "\n"


def parse_prometheus(text: str) -> dict[tuple, float]:
    """Parse text exposition back into ``{(name, (('l','v'),...)): value}``.

    The scrape-side inverse of :meth:`MetricsRegistry.render`, used by the
    CI consistency gate (scraped counters == ``ServerStats`` fields) and
    by tests. Handles only the subset ``render`` emits — one metric per
    line, quoted label values, no exemplars.
    """
    out: dict[tuple, float] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        name_part, _, value_part = line.rpartition(" ")
        if "{" in name_part:
            name, _, rest = name_part.partition("{")
            rest = rest.rstrip("}")
            labels = []
            for item in _split_labels(rest):
                ln, _, lv = item.partition("=")
                labels.append((ln.strip(), lv.strip().strip('"')))
            key = (name, tuple(sorted(labels)))
        else:
            key = (name_part, ())
        value_part = value_part.strip()
        value = math.inf if value_part == "+Inf" else float(value_part)
        out[key] = value
    return out


def _split_labels(s: str) -> list[str]:
    items, depth, cur = [], False, []
    for ch in s:
        if ch == '"':
            depth = not depth
        if ch == "," and not depth:
            items.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        items.append("".join(cur))
    return [i.strip() for i in items if i.strip()]


#: The process-global default registry every repro subsystem publishes to.
REGISTRY = MetricsRegistry()
