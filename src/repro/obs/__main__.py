"""CLI: convert raw span dumps into Perfetto-loadable trace JSON.

``python -m repro.obs export-trace spans.jsonl [-o trace.json]``

The input is a one-span-per-line ``.jsonl`` dump (what
``Tracer.dump`` / ``TraceSpec(path="....jsonl")`` write); the output is
Chrome ``trace_event`` JSON, loadable at https://ui.perfetto.dev or
``chrome://tracing``. A Chrome-format input passes through unchanged
(handy for re-stamping an already-exported trace). With no ``-o`` the
output lands next to the input with a ``.json`` suffix.
"""
from __future__ import annotations

import argparse
import json
import sys


def _load_raw(path: str) -> list[dict]:
    with open(path) as fh:
        text = fh.read()
    try:  # whole-file JSON ⇒ already a Chrome trace object
        doc = json.loads(text)
    except json.JSONDecodeError:
        spans = [json.loads(line) for line in text.splitlines() if line.strip()]
    else:
        if isinstance(doc, dict) and "traceEvents" in doc:
            return doc["traceEvents"]
        spans = doc if isinstance(doc, list) else [doc]  # 1-line jsonl
    events: list[dict] = []
    seen_tids: dict[int, str] = {}
    for s in spans:
        tid = int(s.get("tid", 1))
        seen_tids.setdefault(tid, str(s.get("tlabel", tid)))
    for tid, label in sorted(seen_tids.items()):
        events.append(
            {
                "ph": "M",
                "pid": 1,
                "tid": tid,
                "name": "thread_name",
                "args": {"name": label},
            }
        )
    for s in spans:
        if "ph" in s:  # already an event, pass through
            events.append(s)
            continue
        events.append(
            {
                "name": s["name"],
                "cat": s.get("cat", "repro"),
                "ph": "X",
                "pid": 1,
                "tid": int(s.get("tid", 1)),
                "ts": float(s["ts"]) * 1e6,
                "dur": float(s.get("dur", 0.0)) * 1e6,
                "args": s.get("args", {}),
            }
        )
    return events


def export_trace(src: str, out: str | None = None) -> str:
    events = _load_raw(src)
    if out is None:
        out = (src[: -len(".jsonl")] if src.endswith(".jsonl") else src) + ".json"
    with open(out, "w") as fh:
        json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, fh)
    spans = [e for e in events if e.get("ph") == "X"]
    names = sorted({e["name"] for e in spans})
    print(
        f"wrote {out}: {len(spans)} spans "
        f"({', '.join(names[:8])}{'...' if len(names) > 8 else ''})"
    )
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs", description=__doc__
    )
    sub = ap.add_subparsers(dest="cmd", required=True)
    exp = sub.add_parser(
        "export-trace",
        help="convert a raw span dump (.jsonl) to Perfetto trace JSON",
    )
    exp.add_argument("src", help="span dump (.jsonl) or Chrome trace (.json)")
    exp.add_argument("-o", "--out", default=None, help="output path")
    args = ap.parse_args(argv)
    if args.cmd == "export-trace":
        export_trace(args.src, args.out)
        return 0
    return 2


if __name__ == "__main__":
    sys.exit(main())
