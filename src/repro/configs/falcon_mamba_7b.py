"""falcon-mamba-7b [ssm]: Mamba-1 architecture, attention-free.

64L d_model=4096, d_state=16, d_conv=4, expand=2 (d_inner 8192),
vocab=65024. long_500k applicable (O(1) state decode).
[arXiv:2410.05355; unverified]
"""
from repro.configs.base import ModelConfig, SSMConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b",
        family="ssm",
        num_layers=64,
        d_model=4096,
        num_heads=1,  # unused (attention-free)
        num_kv_heads=1,
        head_dim=1,
        d_ff=0,  # Mamba block subsumes the MLP
        vocab_size=65_024,
        pattern=("ssm",),
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
        tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b-smoke",
        family="ssm",
        num_layers=4,
        d_model=64,
        num_heads=1,
        num_kv_heads=1,
        head_dim=1,
        d_ff=0,
        vocab_size=512,
        pattern=("ssm",),
        ssm=SSMConfig(d_state=8, d_conv=4, expand=2),
    )


register("falcon-mamba-7b", full, smoke)
