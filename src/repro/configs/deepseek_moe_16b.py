"""deepseek-moe-16b [moe]: 2 shared + 64 routed top-6, fine-grained experts.

28L d_model=2048 16H (MHA kv=16) expert_ff=1408 vocab=102400; layer 0 is a
dense MLP (ff 10944). [arXiv:2401.06066; hf]
"""
from repro.configs.base import ModelConfig, MoEConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b",
        family="moe",
        num_layers=28,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=10944,  # dense-layer ff (layer 0)
        vocab_size=102_400,
        pattern=("global",),
        moe=MoEConfig(
            num_experts=64,
            top_k=6,
            expert_ff=1408,
            shared_ff=2 * 1408,  # 2 shared experts
            first_dense_layers=1,
            first_dense_ff=10944,
        ),
        tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b-smoke",
        family="moe",
        num_layers=4,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=160,
        vocab_size=512,
        pattern=("global",),
        moe=MoEConfig(
            num_experts=8,
            top_k=2,
            expert_ff=32,
            shared_ff=64,
            first_dense_layers=1,
            first_dense_ff=160,
        ),
        tie_embeddings=False,
    )


register("deepseek-moe-16b", full, smoke)
