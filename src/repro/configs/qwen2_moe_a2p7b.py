"""qwen2-moe-a2.7b [moe]: 60 routed top-4 + shared expert (ff 5632).

24L d_model=2048 16H (MHA kv=16) expert_ff=1408 vocab=151936, QKV bias.
60 experts pad to 64 for EP divisibility (dummy experts: zero weights,
never routed). [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
"""
from repro.configs.base import ModelConfig, MoEConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b",
        family="moe",
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=5632,
        vocab_size=151_936,
        pattern=("global",),
        qkv_bias=True,
        moe=MoEConfig(
            num_experts=60,
            top_k=4,
            expert_ff=1408,
            shared_ff=5632,  # HF: one shared expert of 4x1408
        ),
        tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b-smoke",
        family="moe",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        pattern=("global",),
        qkv_bias=True,
        moe=MoEConfig(num_experts=6, top_k=2, expert_ff=32, shared_ff=128),
        tie_embeddings=False,
    )


register("qwen2-moe-a2.7b", full, smoke)
