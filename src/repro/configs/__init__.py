"""Arch registry: importing this package registers all assigned configs."""
from repro.configs.base import (
    SHAPES,
    ModelConfig,
    ShapeConfig,
    get_config,
    list_archs,
    shape_is_applicable,
)

# Registration side effects — one module per assigned architecture.
from repro.configs import (  # noqa: F401
    codeqwen1_5_7b,
    deepseek_moe_16b,
    falcon_mamba_7b,
    gemma2_9b,
    gemma_2b,
    internvl2_26b,
    qwen2_5_14b,
    qwen2_moe_a2p7b,
    recurrentgemma_9b,
    whisper_medium,
)

__all__ = [
    "SHAPES",
    "ModelConfig",
    "ShapeConfig",
    "get_config",
    "list_archs",
    "shape_is_applicable",
]
