"""Config system: model configs, input shapes, and the arch registry.

Every assigned architecture is a frozen :class:`ModelConfig` in its own
``configs/<arch>.py`` file, registered under its public id so launchers can
select it with ``--arch <id>``. Each config also carries a ``smoke()``
reduction (same family, tiny dims) used by per-arch CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

__all__ = [
    "ModelConfig",
    "MoEConfig",
    "SSMConfig",
    "RGLRUConfig",
    "EncDecConfig",
    "VisionStubConfig",
    "ShapeConfig",
    "SHAPES",
    "register",
    "get_config",
    "list_archs",
    "pad_to_multiple",
]


def pad_to_multiple(x: int, mult: int = 128) -> int:
    """Pad a dimension (vocab, experts, ...) up for sharding divisibility."""
    return -(-x // mult) * mult


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int  # routed experts (pre-padding)
    top_k: int
    expert_ff: int  # d_ff per routed expert
    shared_ff: int = 0  # total d_ff of the always-on shared expert(s)
    first_dense_layers: int = 0  # leading dense layers (deepseek-moe: 1)
    first_dense_ff: int = 0  # d_ff of those dense layers
    capacity_factor: float = 1.25
    router_softcap: float | None = None

    @property
    def num_experts_padded(self) -> int:
        """Experts padded to a multiple of 16 so EP divides the model axis
        (qwen2-moe: 60 -> 64; dummy experts have zero weights and are never
        routed to)."""
        return pad_to_multiple(self.num_experts, 16)


@dataclasses.dataclass(frozen=True)
class SSMConfig:  # Mamba-1 (falcon-mamba)
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int | None = None  # default d_model // 16


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:  # Griffin / RecurrentGemma recurrent block
    lru_width: int | None = None  # default d_model
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class EncDecConfig:  # whisper
    num_encoder_layers: int = 24
    encoder_frames: int = 1500  # conv frontend is a STUB: precomputed frames


@dataclasses.dataclass(frozen=True)
class VisionStubConfig:  # internvl2
    num_patches: int = 256  # ViT frontend is a STUB: precomputed patch embeds


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # layer pattern, cycled over the depth: "global" | "local" | "recurrent"
    # | "ssm". len(pattern) is the scan-block size (compile-time constant).
    pattern: tuple[str, ...] = ("global",)
    window_size: int | None = None
    attn_softcap: float | None = None
    final_softcap: float | None = None
    qkv_bias: bool = False
    activation: str = "silu"  # silu -> SwiGLU, gelu -> GeGLU
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    post_norm: bool = False  # gemma2 sandwich norm
    scale_embed: bool = False  # gemma family: embeddings × sqrt(d_model)
    tie_embeddings: bool = True
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    rglru: RGLRUConfig | None = None
    encdec: EncDecConfig | None = None
    vision: VisionStubConfig | None = None
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat_policy: str = "nothing_saveable"
    # ops
    attn_impl: str = "ref"  # "ref" (jnp) | "pallas" (interpret on CPU)
    attn_chunk: int | None = None  # chunked attention for long prefill
    notes: str = ""

    # -- derived -------------------------------------------------------------
    @property
    def vocab_padded(self) -> int:
        return pad_to_multiple(self.vocab_size, 128)

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    @property
    def is_enc_dec(self) -> bool:
        return self.encdec is not None

    def num_params(self) -> int:
        """Parameter count (for 6·N·D model-FLOPs accounting)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_padded
        h, kv, hd = self.num_heads, self.num_kv_heads, self.head_dim
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
        per_kind = {}
        attn = d * h * hd + 2 * d * kv * hd + h * hd * d
        if self.qkv_bias:
            attn += (h + 2 * kv) * hd
        # gated MLPs (SwiGLU/GeGLU) have 3 matrices; plain GELU (whisper) 2.
        mlp_mats = 2 if self.activation == "gelu_plain" else 3
        per_kind["global"] = attn + mlp_mats * d * ff
        per_kind["local"] = per_kind["global"]
        if self.rglru is not None:
            w = self.rglru.lru_width or d
            # in/out proj + conv + gates (Griffin recurrent block) + mlp
            rec = 2 * d * w + self.rglru.conv_width * w + 2 * w * w + w * d
            per_kind["recurrent"] = rec + 3 * d * ff
        if self.ssm is not None:
            e = self.ssm.expand * d
            dtr = self.ssm.dt_rank or d // 16
            s = self.ssm.d_state
            per_kind["ssm"] = (
                2 * d * e  # in_proj (x, z)
                + self.ssm.d_conv * e
                + e * (dtr + 2 * s)  # x_proj
                + dtr * e  # dt_proj
                + e * s  # A_log
                + e  # D
                + e * d  # out_proj
            )
        if self.moe is not None:
            m = self.moe
            routed = m.num_experts * 3 * d * m.expert_ff
            shared = 3 * d * m.shared_ff
            router = d * m.num_experts
            per_kind["global"] = attn + routed + shared + router
        count = 0
        layers = self.layer_kinds()
        for kind in layers:
            count += per_kind[kind]
        if self.moe is not None and self.moe.first_dense_layers:
            # those layers were counted as MoE; swap in dense ff
            m = self.moe
            count -= m.first_dense_layers * (
                m.num_experts * 3 * d * m.expert_ff + 3 * d * m.shared_ff + d * m.num_experts
            )
            count += m.first_dense_layers * 3 * d * m.first_dense_ff
        if self.is_enc_dec:
            enc_attn = attn
            enc = self.encdec.num_encoder_layers * (enc_attn + mlp_mats * d * ff)
            cross = len(layers) * attn  # decoder cross-attention
            count += enc + cross
        return int(total + count)

    def active_params(self) -> int:
        """Active parameters per token (MoE: only top-k experts count)."""
        if self.moe is None:
            return self.num_params()
        m = self.moe
        d = self.d_model
        inactive = (
            (len(self.layer_kinds()) - m.first_dense_layers)
            * (m.num_experts - m.top_k)
            * 3
            * d
            * m.expert_ff
        )
        return int(self.num_params() - inactive)

    def layer_kinds(self) -> tuple[str, ...]:
        """Per-layer kind sequence: pattern cycled to num_layers."""
        reps = -(-self.num_layers // len(self.pattern))
        return (self.pattern * reps)[: self.num_layers]

    def scan_plan(self) -> tuple[int, tuple[str, ...]]:
        """(num_scanned_blocks, remainder_kinds). The scan body is one full
        pattern; a trailing partial pattern runs unscanned."""
        nb = self.num_layers // len(self.pattern)
        rem = self.layer_kinds()[nb * len(self.pattern) :]
        return nb, rem


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# Archs allowed to run long_500k (SSM / hybrid with local-only attention).
LONG_CONTEXT_ARCHS = {"falcon-mamba-7b", "recurrentgemma-9b"}

_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}
_SMOKE: dict[str, Callable[[], ModelConfig]] = {}


def register(arch_id: str, full: Callable[[], ModelConfig], smoke: Callable[[], ModelConfig]):
    _REGISTRY[arch_id] = full
    _SMOKE[arch_id] = smoke


def get_config(arch_id: str, *, smoke: bool = False) -> ModelConfig:
    import repro.configs  # noqa: F401  (triggers registration)

    table = _SMOKE if smoke else _REGISTRY
    if arch_id not in table:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    return table[arch_id]()


def list_archs() -> list[str]:
    import repro.configs  # noqa: F401

    return sorted(_REGISTRY)


def shape_is_applicable(arch_id: str, shape: str) -> bool:
    """long_500k only for sub-quadratic archs (DESIGN.md §Arch-applicability)."""
    if shape == "long_500k":
        return arch_id in LONG_CONTEXT_ARCHS
    return True
