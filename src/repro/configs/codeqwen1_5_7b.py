"""codeqwen1.5-7b [dense]: qwen1.5 arch, MHA, QKV bias.

32L d_model=4096 32H (kv=32) d_ff=13440 vocab=92416. [hf:Qwen/CodeQwen1.5-7B]
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="codeqwen1.5-7b",
        family="dense",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=32,
        head_dim=128,
        d_ff=13440,
        vocab_size=92_416,
        pattern=("global",),
        qkv_bias=True,
        tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="codeqwen1.5-7b-smoke",
        family="dense",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=160,
        vocab_size=512,
        pattern=("global",),
        qkv_bias=True,
        tie_embeddings=False,
    )


register("codeqwen1.5-7b", full, smoke)
