"""gemma-2b [dense]: MQA (kv=1), GeGLU, head_dim 256.

18L d_model=2048 8H (kv=1) d_ff=16384 vocab=256000. [arXiv:2403.08295; hf]
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma-2b",
        family="dense",
        num_layers=18,
        d_model=2048,
        num_heads=8,
        num_kv_heads=1,
        head_dim=256,
        d_ff=16384,
        vocab_size=256_000,
        pattern=("global",),
        activation="gelu",
        scale_embed=True,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma-2b-smoke",
        family="dense",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=1,
        head_dim=16,
        d_ff=192,
        vocab_size=512,
        pattern=("global",),
        activation="gelu",
    )


register("gemma-2b", full, smoke)
