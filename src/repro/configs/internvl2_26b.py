"""internvl2-26b [vlm]: InternLM2-20B backbone; InternViT frontend is a STUB.

48L d_model=6144 48H (GQA kv=8) head_dim=128 d_ff=16384 vocab=92553 (padded).
input_specs() supplies precomputed patch embeddings (B, 256, d_model) that
are prepended to the token embeddings. [arXiv:2404.16821; hf]
"""
from repro.configs.base import ModelConfig, VisionStubConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b",
        family="vlm",
        num_layers=48,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=92_553,
        pattern=("global",),
        vision=VisionStubConfig(num_patches=256),
        tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b-smoke",
        family="vlm",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        pattern=("global",),
        vision=VisionStubConfig(num_patches=8),
        tie_embeddings=False,
    )


register("internvl2-26b", full, smoke)
