"""recurrentgemma-9b [hybrid]: RG-LRU + local attention, 1 attn : 2 recurrent.

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000, window 2048,
head_dim 256, GeGLU. [arXiv:2402.19427; unverified]
"""
from repro.configs.base import ModelConfig, RGLRUConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        num_layers=38,
        d_model=4096,
        num_heads=16,
        num_kv_heads=1,
        head_dim=256,
        d_ff=12288,
        vocab_size=256_000,
        pattern=("recurrent", "recurrent", "local"),
        window_size=2048,
        activation="gelu",
        rglru=RGLRUConfig(lru_width=4096, conv_width=4),
        scale_embed=True,
        tie_embeddings=True,
        notes="Griffin 1:2 attn:recurrent; long_500k applicable (sub-quadratic).",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b-smoke",
        family="hybrid",
        num_layers=6,
        d_model=64,
        num_heads=4,
        num_kv_heads=1,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        pattern=("recurrent", "recurrent", "local"),
        window_size=32,
        activation="gelu",
        rglru=RGLRUConfig(lru_width=64, conv_width=4),
    )


register("recurrentgemma-9b", full, smoke)
