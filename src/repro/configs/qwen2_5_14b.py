"""qwen2.5-14b [dense]: GQA with QKV bias, SwiGLU.

48L d_model=5120 40H (GQA kv=8) head_dim=128 d_ff=13824 vocab=152064.
40 heads are not divisible by the 16-way model axis; attention falls back
to context-parallel sharding (sharding/rules.py). [hf:Qwen/Qwen2.5; hf]
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-14b",
        family="dense",
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=13824,
        vocab_size=152_064,
        pattern=("global",),
        qkv_bias=True,
        tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-14b-smoke",
        family="dense",
        num_layers=3,
        d_model=80,
        num_heads=5,  # preserves the non-divisible-heads property
        num_kv_heads=1,
        head_dim=16,
        d_ff=192,
        vocab_size=512,
        pattern=("global",),
        qkv_bias=True,
        tie_embeddings=False,
    )


register("qwen2.5-14b", full, smoke)
