"""whisper-medium [audio]: encoder-decoder; conv frontend is a STUB.

24L enc + 24L dec, d_model=1024 16H (MHA) d_ff=4096 vocab=51865 (padded to
51968). input_specs() supplies precomputed mel-frame embeddings
(B, 1500, d_model). Decode shapes exercise the DECODER with the fixed
1500-frame encoder stub. [arXiv:2212.04356; unverified]
"""
from repro.configs.base import EncDecConfig, ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium",
        family="audio",
        num_layers=24,  # decoder layers
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        head_dim=64,
        d_ff=4096,
        vocab_size=51_865,
        pattern=("global",),
        activation="gelu_plain",
        encdec=EncDecConfig(num_encoder_layers=24, encoder_frames=1500),
        tie_embeddings=True,
        notes="enc-dec; decoder cross-attends the 1500-frame encoder stub.",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium-smoke",
        family="audio",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        pattern=("global",),
        activation="gelu_plain",
        encdec=EncDecConfig(num_encoder_layers=2, encoder_frames=30),
    )


register("whisper-medium", full, smoke)
