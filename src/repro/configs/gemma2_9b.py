"""gemma2-9b [dense]: local(4096)+global alternating, logit softcaps, GeGLU.

42L d_model=3584 16H (GQA kv=8) head_dim=256 d_ff=14336 vocab=256000,
attn softcap 50, final softcap 30, sandwich norms. [arXiv:2408.00118; hf]
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b",
        family="dense",
        num_layers=42,
        d_model=3584,
        num_heads=16,
        num_kv_heads=8,
        head_dim=256,
        d_ff=14336,
        vocab_size=256_000,
        pattern=("local", "global"),
        window_size=4096,
        attn_softcap=50.0,
        final_softcap=30.0,
        activation="gelu",
        post_norm=True,
        scale_embed=True,
        tie_embeddings=True,
        notes="long_500k skipped: half the layers are full global attention.",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b-smoke",
        family="dense",
        num_layers=4,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        pattern=("local", "global"),
        window_size=16,
        attn_softcap=50.0,
        final_softcap=30.0,
        activation="gelu",
        post_norm=True,
    )


register("gemma2-9b", full, smoke)
