"""Offline analysis of exported engine traces — stdlib only.

Reads what :class:`repro.obs.Tracer` writes — Chrome ``trace_event``
JSON (``TraceSpec(path="run.json")`` / ``Tracer.export``) or the raw
one-span-per-line ``.jsonl`` dump — and folds the span stream back into
per-run facts: how long each update sweep took, how many bytes each
moved, which execution backend ran. ``benchmarks/roofline_report.py
--trace`` joins these summaries against the analytic per-sweep roofline
(:func:`sweep_execution_model`) to report measured-vs-modelled time per
backend.

The join key is the ``run`` id the engine stamps into every span it
records for one ``_execute`` call — "sweep" and "checkpoint" spans carry
the same ``args["run"]`` as their parent "run" span, so a trace holding
many runs (a sweep benchmark, a serving wave) decomposes exactly.
"""
from __future__ import annotations

import json

__all__ = ["load_events", "run_summaries", "fmt_run_table"]


def load_events(path: str) -> list[dict]:
    """Normalized spans from a trace file: ``ts``/``dur`` in seconds.

    Accepts Chrome ``trace_event`` JSON (timestamps in µs; ``M``-phase
    metadata events are dropped) or a raw ``.jsonl`` span dump
    (timestamps already in seconds).
    """
    with open(path) as fh:
        text = fh.read()
    try:
        doc = json.loads(text)
    except json.JSONDecodeError:
        spans = [json.loads(ln) for ln in text.splitlines() if ln.strip()]
        scale = 1.0
    else:
        if isinstance(doc, dict) and "traceEvents" in doc:
            spans = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
            scale = 1e-6
        elif isinstance(doc, list):
            spans = doc
            scale = 1.0
        else:
            spans = [doc]
            scale = 1.0
    return [
        {
            "name": s["name"],
            "cat": s.get("cat", "repro"),
            "ts": float(s.get("ts", 0.0)) * scale,
            "dur": float(s.get("dur", 0.0)) * scale,
            "args": dict(s.get("args", {})),
        }
        for s in spans
    ]


def run_summaries(events: list[dict]) -> list[dict]:
    """One summary per engine "run" span, with its sweeps folded in.

    Each summary carries the run's identity (program / strategy /
    residency / execution / graph shape), its total ``wall_s``, and the
    sweep-level aggregates: ``sweeps``/``sweep_wall_s``/``mean_sweep_s``
    plus the per-sweep physical byte sums (which, for a fresh run, equal
    the run's ``Result.meters`` fields — the exactness contract).
    """
    sweeps_by_run: dict = {}
    for e in events:
        if e["name"] == "sweep":
            sweeps_by_run.setdefault(e["args"].get("run"), []).append(e)
    out = []
    for e in events:
        if e["name"] != "run":
            continue
        a = e["args"]
        sw = sweeps_by_run.get(a.get("run"), [])
        sweep_wall = sum(s["dur"] for s in sw)
        out.append(
            {
                "run": a.get("run"),
                "program": a.get("program"),
                "strategy": a.get("strategy"),
                "residency": a.get("residency"),
                "execution": a.get("execution"),
                "K": a.get("K"),
                "n": a.get("n"),
                "m": a.get("m"),
                "P": a.get("P"),
                "converged": a.get("converged"),
                "wall_s": e["dur"],
                "sweeps": len(sw) or a.get("sweeps", 0),
                "sweep_wall_s": sweep_wall,
                "mean_sweep_s": sweep_wall / len(sw) if sw else 0.0,
                "bytes_h2d": sum(
                    s["args"].get("bytes_h2d", 0.0) for s in sw
                ),
                "bytes_disk_read": sum(
                    s["args"].get("bytes_disk_read", 0.0) for s in sw
                ),
            }
        )
    return out


def fmt_run_table(summaries: list[dict]) -> str:
    """Markdown table of per-run sweep facts (the ``--trace`` report)."""
    hdr = (
        "| run | program | backend | residency | sweeps | mean sweep (ms) "
        "| h2d MB | disk MB |"
    )
    lines = [hdr, "|" + "---|" * 8]
    for r in summaries:
        lines.append(
            f"| {r['run']} | {r['program']} | {r['execution']} | "
            f"{r['residency']} | {r['sweeps']} | "
            f"{r['mean_sweep_s'] * 1e3:.2f} | "
            f"{r['bytes_h2d'] / 1e6:.2f} | "
            f"{r['bytes_disk_read'] / 1e6:.2f} |"
        )
    return "\n".join(lines)
