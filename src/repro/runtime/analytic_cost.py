"""Analytic FLOP / HBM-byte model per (arch × shape) — the roofline basis.

``compiled.cost_analysis()`` counts while-loop bodies once (see
hlo_loops.py), so for scanned models it undercounts by the layer count.
Rather than unrolling 64-layer models at 512 partitions (hours of compile
time), the compute and memory roofline terms come from this analytic model
— exact for matmul FLOPs, a principled lower bound for HBM traffic — and
the weighted-HLO parse supplies the collective term. cost_analysis is kept
in the report as a diagnostic.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig, ShapeConfig

__all__ = ["analytic_cost", "AnalyticCost"]


@dataclasses.dataclass(frozen=True)
class AnalyticCost:
    flops_global: float  # executed FLOPs, whole step, all chips
    model_flops: float  # useful FLOPs: 6·N_active·T (train), 2·N_active·T (fwd)
    hbm_bytes_global: float  # lower-bound traffic, all chips (caller /chips)
    notes: str


def _attention_flops(cfg: ModelConfig, b: int, s: int, kv_len: int | None = None):
    """Per-layer score+PV matmul FLOPs for one attention layer (full
    rectangle: the chunked implementation computes masked positions too)."""
    kv = kv_len if kv_len is not None else s
    return 4.0 * b * s * kv * cfg.num_heads * cfg.head_dim


def _recurrence_flops(cfg: ModelConfig, b: int, s: int) -> dict[str, float]:
    out = {}
    if cfg.ssm is not None:
        e = cfg.ssm.expand * cfg.d_model
        n = cfg.ssm.d_state
        # decay/input/scan/output each touch (b, s, e, n)
        out["ssm"] = 10.0 * b * s * e * n
    if cfg.rglru is not None:
        w = cfg.rglru.lru_width or cfg.d_model
        out["recurrent"] = 12.0 * b * s * w
    return out


def _matmul_params(cfg: ModelConfig) -> float:
    """Parameters that participate in matmuls per token (active set).

    Embedding gather costs ~0 FLOPs; the head matmul uses V·D once (tied or
    not), so: tied -> active (table counted once, used once as matmul);
    untied -> active - V·D (one of the two tables is gather-only)."""
    active = cfg.active_params()
    vd = cfg.vocab_padded * cfg.d_model
    return float(active if cfg.tie_embeddings else active - vd)


def analytic_cost(cfg: ModelConfig, shape: ShapeConfig) -> AnalyticCost:
    b, s = shape.global_batch, shape.seq_len
    kinds = cfg.layer_kinds()
    mm = _matmul_params(cfg)
    rec = _recurrence_flops(cfg, b, s)

    if shape.kind in ("train", "prefill"):
        t = b * s
        fwd = 2.0 * mm * t
        for kind in kinds:
            if kind == "global":
                fwd += _attention_flops(cfg, b, s)
            elif kind == "local":
                fwd += _attention_flops(cfg, b, s, min(s, cfg.window_size or s))
            elif kind == "recurrent":
                fwd += rec.get("recurrent", 0.0)
            elif kind == "ssm":
                fwd += rec.get("ssm", 0.0)
        if cfg.is_enc_dec:
            tf = cfg.encdec.encoder_frames
            enc_mm = cfg.encdec.num_encoder_layers * (
                4 * cfg.d_model * cfg.num_heads * cfg.head_dim
                + (2 if cfg.activation == "gelu_plain" else 3)
                * cfg.d_model
                * cfg.d_ff
            )
            fwd += 2.0 * enc_mm * b * tf + cfg.encdec.num_encoder_layers * _attention_flops(cfg, b, tf)
        if shape.kind == "train":
            # fwd + bwd(2x) + full remat recompute (~1x, nothing_saveable)
            flops = 4.0 * fwd
            model = 6.0 * cfg.active_params() * t
            notes = "train: 4x fwd (fwd+bwd+remat)"
        else:
            flops = fwd
            model = 2.0 * cfg.active_params() * t
            notes = "prefill: 1x fwd"
    else:  # decode: one token per sequence
        t = b
        fwd = 2.0 * mm * t
        for kind in kinds:
            if kind == "global":
                fwd += _attention_flops(cfg, b, 1, s)
            elif kind == "local":
                fwd += _attention_flops(cfg, b, 1, min(s, cfg.window_size or s))
            elif kind == "recurrent":
                fwd += rec.get("recurrent", 0.0) / max(s, 1)
            elif kind == "ssm":
                fwd += rec.get("ssm", 0.0) / max(s, 1)
        flops = fwd
        model = 2.0 * cfg.active_params() * t
        notes = "decode: 1 token/seq"

    # ---- HBM traffic lower bound (per chip) --------------------------------
    # Parameters are fully sharded (FSDP x TP); activations batch-sharded.
    n_params = cfg.num_params()
    p_bytes = 4.0 * n_params  # fp32 master params
    act_bytes = 2.0 * b * s * cfg.d_model  # one bf16 residual stream
    if shape.kind == "train":
        # params: fwd read + bwd read + remat read (bf16 casts of fp32) +
        # optimizer read p,m,v + write p,m,v => ~9 passes over fp32 size / 4
        # in bf16-equivalents; keep it simple: 3 bf16 reads + 6 fp32 passes.
        param_traffic = 3 * 2.0 * n_params + 6 * p_bytes
        grad_traffic = 2 * p_bytes
        # saved residuals: write + read per layer boundary
        act_traffic = 2 * len(kinds) * act_bytes
        hbm = param_traffic + grad_traffic + act_traffic
    elif shape.kind == "prefill":
        hbm = 2.0 * n_params + len(kinds) * act_bytes
        # cache write
        hbm += 2.0 * 2 * len(kinds) * b * s * cfg.num_kv_heads * cfg.head_dim
    else:
        # decode: read all (active) params once + read the whole KV cache
        cache = 0.0
        for kind in kinds:
            if kind == "global":
                cache += 2 * 2.0 * b * s * cfg.num_kv_heads * cfg.head_dim
            elif kind == "local":
                w = min(s, cfg.window_size or s)
                cache += 2 * 2.0 * b * w * cfg.num_kv_heads * cfg.head_dim
            elif kind == "ssm":
                e = cfg.ssm.expand * cfg.d_model
                cache += 4.0 * b * e * cfg.ssm.d_state
            elif kind == "recurrent":
                w = cfg.rglru.lru_width or cfg.d_model
                cache += 4.0 * b * w
        hbm = 2.0 * cfg.active_params() + cache
    return AnalyticCost(
        flops_global=flops,
        model_flops=model,
        hbm_bytes_global=hbm,
        notes=notes,
    )
