"""Compatibility shim — these primitives moved to :mod:`repro.reliability`.

The train-loop fault-tolerance pieces (:class:`FailureInjector`,
:class:`StragglerWatchdog`, :func:`elastic_device_count`,
:class:`StepTimer`) now live in ``repro.reliability.faults`` alongside the
engine-level :class:`~repro.reliability.faults.FaultPlan` injection API,
so one module owns every injected failure. Import from
``repro.reliability`` in new code; this module re-exports the old names
so existing imports keep working.
"""
from __future__ import annotations

from repro.reliability.faults import (
    FailureInjector,
    SimulatedFailure,
    StepTimer,
    StragglerWatchdog,
    elastic_device_count,
)

__all__ = ["FailureInjector", "SimulatedFailure", "StragglerWatchdog", "elastic_device_count"]
