"""Fault tolerance: failure injection, straggler watchdog, elastic policy.

Large-scale runnability pieces that can be exercised on this container:

* :class:`FailureInjector` — deterministic chaos: raises at configured
  steps, standing in for preemptions/XLA aborts. The train loop's recovery
  path (restore-latest + resume) is tested against it.
* :class:`StragglerWatchdog` — EWMA step-time monitor; flags outlier steps
  (on a real pod, per-host step times feed this and the runbook response
  is checkpoint + evict + elastic re-mesh).
* :func:`elastic_device_count` — largest usable device count after
  excluding failed hosts, keeping the mesh factorization valid: the policy
  half of elastic scaling (the mechanism — reshard-on-load — lives in
  checkpoint/manager.py).
"""
from __future__ import annotations

import dataclasses
import time

__all__ = ["FailureInjector", "SimulatedFailure", "StragglerWatchdog", "elastic_device_count"]


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    """Raise SimulatedFailure at the given steps (each fires once)."""

    fail_at_steps: tuple[int, ...] = ()
    fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int) -> None:
        if step in self.fail_at_steps and step not in self.fired:
            self.fired.add(step)
            raise SimulatedFailure(f"injected failure at step {step}")


@dataclasses.dataclass
class StragglerWatchdog:
    """EWMA step-time outlier detector.

    ``update`` returns True when the step took more than ``threshold`` ×
    the smoothed time — the signal a production controller uses to start
    the mitigation runbook (snapshot, evict host, re-mesh).
    """

    alpha: float = 0.1
    threshold: float = 3.0
    warmup: int = 5
    _ewma: float = 0.0
    _count: int = 0
    flagged: list = dataclasses.field(default_factory=list)

    def update(self, step: int, step_seconds: float) -> bool:
        self._count += 1
        if self._count <= self.warmup:
            # establish a baseline before flagging
            self._ewma = (
                step_seconds
                if self._ewma == 0.0
                else (1 - self.alpha) * self._ewma + self.alpha * step_seconds
            )
            return False
        is_straggler = step_seconds > self.threshold * self._ewma
        if is_straggler:
            self.flagged.append((step, step_seconds, self._ewma))
        else:
            self._ewma = (1 - self.alpha) * self._ewma + self.alpha * step_seconds
        return is_straggler


def elastic_device_count(
    available: int, *, model_parallel: int = 1, minimum: int = 1
) -> int:
    """Largest device count ≤ available that keeps the mesh valid.

    The model axis is fixed (parameter shardings must divide it); the data
    axis absorbs the loss — so usable = model_parallel × floor(available /
    model_parallel). Checkpoint reshard-on-load does the rest.
    """
    usable = (available // model_parallel) * model_parallel
    if usable < minimum:
        raise RuntimeError(
            f"only {available} devices available; need >= {minimum}"
        )
    return usable


class StepTimer:
    def __init__(self):
        self._t = None

    def tick(self) -> float:
        now = time.perf_counter()
        dt = 0.0 if self._t is None else now - self._t
        self._t = now
        return dt
