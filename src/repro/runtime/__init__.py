"""Runtime analysis + fault tolerance utilities."""
