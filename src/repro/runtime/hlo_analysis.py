"""Roofline terms from a compiled XLA artifact (deliverable g).

``cost_analysis()`` supplies HLO FLOPs and bytes; collective bytes are NOT
in cost_analysis, so we parse the optimized HLO text and sum the operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute. Hardware constants are TPU v5e:

  peak 197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re

__all__ = ["HW", "collective_bytes", "roofline_terms", "RooflineReport"]


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12  # bf16 / chip
    hbm_bw: float = 819e9  # B/s per chip
    ici_bw: float = 50e9  # B/s per link
    hbm_bytes: float = 16e9  # capacity per chip


_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

# e.g.  %all-reduce.5 = f32[1024,512]{1,0} all-reduce(%x), replica_groups=...
# also tuple-shaped: (f32[8]{0}, f32[16]{0}) all-reduce(...)
_COLLECTIVE_RE = re.compile(
    r"=\s*(\(?[a-z0-9]+\[[^=]*?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum output-shape bytes per collective kind from optimized HLO text.

    -start/-done async pairs are counted once (on -start; bare ops always).
    Shapes are PER-PARTITION in SPMD HLO, so the totals are per-device
    bytes, which is what the ICI roofline term wants.
    """
    out: dict[str, float] = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        shape_text, kind, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":
            continue
        out[kind] = out.get(kind, 0.0) + _shape_bytes(shape_text)
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float  # per-chip FLOPs (SPMD module cost_analysis)
    hlo_bytes: float  # per-chip bytes accessed
    coll_bytes_per_chip: float
    coll_breakdown: dict
    model_flops: float  # 6·N·D (dense) or 6·N_active·D
    bytes_per_chip_peak: float  # from memory_analysis
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """model FLOPs / total compiled FLOPs (hlo_flops is per-device)."""
        total = self.hlo_flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """compute_term / max(all terms): 1.0 = perfectly compute-bound."""
        t = max(self.compute_s, self.memory_s, self.collective_s)
        return self.compute_s / t if t else 0.0

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["dominant"] = self.dominant
        d["useful_flops_ratio"] = self.useful_flops_ratio
        d["roofline_fraction"] = self.roofline_fraction
        return d


def roofline_terms(
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    cost: dict,
    hlo_text: str,
    model_flops: float,
    bytes_per_chip_peak: float,
    hw: HW = HW(),
) -> RooflineReport:
    # cost_analysis() runs on the per-device SPMD module: flops/bytes are
    # already per-chip (validated: gemma-2b train flops × 256 ≈ 6·N·D).
    flops = float(cost.get("flops", 0.0))
    btot = float(
        cost.get("bytes accessed", 0.0)
        or sum(v for k, v in cost.items() if k.startswith("bytes accessed"))
    )
    coll = collective_bytes(hlo_text)
    coll_total = float(sum(coll.values()))
    rep = RooflineReport(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        chips=chips,
        hlo_flops=flops,
        hlo_bytes=btot,
        coll_bytes_per_chip=coll_total,
        coll_breakdown=coll,
        model_flops=model_flops,
        bytes_per_chip_peak=bytes_per_chip_peak,
    )
    rep.compute_s = flops / hw.peak_flops
    rep.memory_s = btot / hw.hbm_bw
    rep.collective_s = coll_total / hw.ici_bw  # per-chip shapes
    return rep
