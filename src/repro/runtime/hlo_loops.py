"""Trip-count-aware HLO traversal.

XLA's ``HloCostAnalysis`` (and a naive text scan) counts a ``while`` body
ONCE — but scan-over-layers executes it ``num_layers`` times, so collective
bytes and FLOPs inside the loop are undercounted by the trip count. This
module parses the optimized HLO text into computations, recovers each
while-loop's trip count from its condition, propagates multipliers along
the call graph (whiles nest: a CE-chunk scan inside the layer scan inherits
both trips), and re-sums collective bytes with the correct weights.
"""
from __future__ import annotations

import re

from repro.runtime.hlo_analysis import _shape_bytes

__all__ = ["collective_bytes_weighted", "computation_multipliers"]

_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\(", re.M)
_WHILE_RE = re.compile(
    r"while\([^)]*\)\s*,\s*condition=%?([\w\.\-]+)\s*,\s*body=%?([\w\.\-]+)"
)
_CALL_RE = re.compile(
    r"(?:call|fusion)\([^)]*\)\s*,\s*(?:[^,]*,\s*)?(?:to_apply|calls)=%?([\w\.\-]+)"
)
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_COLLECTIVE_LINE = re.compile(
    r"=\s*(\(?[a-z0-9]+\[[^=]*?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\("
)


def _split_computations(hlo: str) -> dict[str, str]:
    """computation name -> body text.

    Header lines look like ``[ENTRY ]%name (params...) -> type {`` — params
    may contain nested parens (tuple types) and layout braces, so headers
    are recognized line-wise (the only lines that end with ``{``) and the
    body is brace-matched from the line end."""
    comps: dict[str, str] = {}
    lines = hlo.splitlines(keepends=True)
    offsets = []
    pos = 0
    for ln in lines:
        offsets.append(pos)
        pos += len(ln)
    for idx, ln in enumerate(lines):
        stripped = ln.rstrip()
        if not stripped.endswith("{") or "->" not in stripped:
            continue
        head = stripped.lstrip()
        if head.startswith("ENTRY"):
            head = head[len("ENTRY"):].lstrip()
        if not head:
            continue
        name = head.split()[0].split("(")[0].lstrip("%")
        if not name:
            continue
        start = offsets[idx] + len(ln)
        depth = 1
        i = start
        while i < len(hlo) and depth:
            c = hlo[i]
            if c == "{":
                depth += 1
            elif c == "}":
                depth -= 1
            i += 1
        comps[name] = hlo[start:i]
    return comps


def _trip_count(cond_body: str) -> int:
    """Largest s32[] constant in the while condition ≈ trip count."""
    consts = [int(c) for c in _CONST_RE.findall(cond_body)]
    return max(consts) if consts else 1


def computation_multipliers(hlo: str) -> dict[str, int]:
    """Execution-count multiplier per computation (entry = 1)."""
    comps = _split_computations(hlo)
    entry = None
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo, re.M)
    if m:
        entry = m.group(1)
    if entry is None or entry not in comps:
        # fall back: first computation
        entry = next(iter(comps), None)
    mult: dict[str, int] = {}

    def visit(name: str, factor: int):
        if name not in comps:
            return
        mult[name] = mult.get(name, 0) + factor
        body = comps[name]
        for wm in _WHILE_RE.finditer(body):
            cond, wbody = wm.group(1), wm.group(2)
            trips = _trip_count(comps.get(cond, ""))
            visit(wbody, factor * trips)
            visit(cond, factor * (trips + 1))
        for cm in _CALL_RE.finditer(body):
            visit(cm.group(1), factor)

    if entry:
        visit(entry, 1)
    return mult


def collective_bytes_weighted(hlo: str) -> dict[str, float]:
    """Collective bytes per kind, weighted by loop trip counts."""
    comps = _split_computations(hlo)
    mult = computation_multipliers(hlo)
    out: dict[str, float] = {}
    for name, body in comps.items():
        w = mult.get(name, 0)
        if w == 0:
            continue
        for m in _COLLECTIVE_LINE.finditer(body):
            shape_text, kind, phase = m.group(1), m.group(2), m.group(3)
            if phase == "-done":
                continue
            out[kind] = out.get(kind, 0.0) + w * _shape_bytes(shape_text)
    return out
