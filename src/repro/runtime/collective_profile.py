"""Per-shape weighted collective profile — the dry-run 'profiler'.

Groups trip-count-weighted collective bytes by (kind, shape) so the perf
loop can see WHICH tensors dominate the ICI term (the closest thing to a
comm profile without hardware).
"""
from __future__ import annotations

import re

from repro.runtime.hlo_analysis import _shape_bytes
from repro.runtime.hlo_loops import (
    _COLLECTIVE_LINE,
    _split_computations,
    computation_multipliers,
)

__all__ = ["collective_profile"]


def collective_profile(hlo: str, top: int = 12) -> list[tuple[str, float, int]]:
    """Returns [(descr, weighted_bytes, count), ...] sorted desc."""
    comps = _split_computations(hlo)
    mult = computation_multipliers(hlo)
    agg: dict[str, list[float]] = {}
    for name, body in comps.items():
        w = mult.get(name, 0)
        if w == 0:
            continue
        for m in _COLLECTIVE_LINE.finditer(body):
            shape_text, kind, phase = m.group(1), m.group(2), m.group(3)
            if phase == "-done":
                continue
            b = _shape_bytes(shape_text)
            key = f"{kind} {shape_text.strip()[:60]}"
            cur = agg.setdefault(key, [0.0, 0])
            cur[0] += w * b
            cur[1] += w
    rows = sorted(
        ((k, v[0], v[1]) for k, v in agg.items()), key=lambda r: -r[1]
    )
    return rows[:top]
