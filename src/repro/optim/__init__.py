"""Optimizers, schedules, clipping, gradient compression."""
from repro.optim.adafactor import Adafactor
from repro.optim.adamw import AdamW
from repro.optim.clipping import clip_by_global_norm, global_norm
from repro.optim.grad_compression import compress_for_sync, decompress_after_sync
from repro.optim.schedules import constant, cosine_with_warmup, linear_warmup

__all__ = [
    "AdamW",
    "Adafactor",
    "clip_by_global_norm",
    "global_norm",
    "compress_for_sync",
    "decompress_after_sync",
    "constant",
    "cosine_with_warmup",
    "linear_warmup",
]
