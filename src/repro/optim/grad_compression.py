"""Gradient compression for cross-pod sync (distributed-optimization trick).

On a multi-pod mesh the inter-pod links are the scarcest bandwidth; casting
gradients to bf16 before the cross-pod reduction halves that traffic at
negligible quality cost (loss-scale-safe: the reduction itself accumulates
in fp32). ``compress_for_sync`` is applied inside the train step when
``grad_sync == "compressed_bf16"``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["compress_for_sync", "decompress_after_sync"]


def compress_for_sync(grads, mode: str = "none"):
    if mode == "none":
        return grads
    if mode == "compressed_bf16":
        return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
    raise ValueError(f"unknown grad_sync mode {mode!r}")


def decompress_after_sync(grads, mode: str = "none"):
    if mode == "none":
        return grads
    return jax.tree.map(lambda g: g.astype(jnp.float32), grads)
