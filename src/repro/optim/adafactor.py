"""Adafactor (factored second moments) — memory-lean optimizer option.

For matrices, the second-moment estimate is factored into per-row and
per-column accumulators (Shazeer & Stern, 2018), cutting optimizer memory
from 2x params to ~1x + O(rows+cols) — the standard choice for the largest
assigned configs when HBM is tight.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["Adafactor"]


@dataclasses.dataclass(frozen=True)
class Adafactor:
    learning_rate: float | Callable = 1e-3
    decay: float = 0.8
    eps: float = 1e-30
    clip_threshold: float = 1.0

    def init(self, params):
        def make(p):
            if p.ndim >= 2:
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros_like(p, dtype=jnp.float32)}

        return {
            "acc": jax.tree.map(make, params, is_leaf=lambda x: hasattr(x, "ndim")),
            "count": jnp.zeros((), jnp.int32),
        }

    def _lr(self, count):
        if callable(self.learning_rate):
            return self.learning_rate(count)
        return jnp.asarray(self.learning_rate, jnp.float32)

    def update(self, grads, state, params):
        count = state["count"] + 1
        beta = 1.0 - (count.astype(jnp.float32) + 1) ** -self.decay
        lr = self._lr(count)

        def upd(p, g, acc):
            gf = g.astype(jnp.float32)
            g2 = jnp.square(gf) + self.eps
            if p.ndim >= 2:
                vr = beta * acc["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * acc["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), self.eps)
                vhat = (
                    vr[..., None] * vc[..., None, :] / denom[..., None]
                )
                u = gf / jnp.sqrt(vhat)
                new_acc = {"vr": vr, "vc": vc}
            else:
                v = beta * acc["v"] + (1 - beta) * g2
                u = gf / jnp.sqrt(v)
                new_acc = {"v": v}
            rms = jnp.sqrt(jnp.mean(jnp.square(u)))
            u = u / jnp.maximum(1.0, rms / self.clip_threshold)
            return (p - lr * u).astype(p.dtype), new_acc

        moved = jax.tree.map(
            upd, params, grads, state["acc"],
            is_leaf=lambda x: isinstance(x, dict) and ("vr" in x or "v" in x),
        )
        # tree of (param, acc) tuples -> two trees
        new_params = jax.tree.map(
            lambda t: t[0], moved, is_leaf=lambda x: isinstance(x, tuple)
        )
        new_acc = jax.tree.map(
            lambda t: t[1], moved, is_leaf=lambda x: isinstance(x, tuple)
        )
        return new_params, {"acc": new_acc, "count": count}
