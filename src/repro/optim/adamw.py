"""AdamW — hand-rolled (no optax in this container), pytree-native.

State is two moments per parameter plus a step counter; moments inherit the
parameter sharding (FSDP: optimizer state is sharded exactly like params,
which is what makes the 26B configs fit 16 GiB/chip in the dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = ["AdamW", "OptState"]


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: float | Callable[[jax.Array], jax.Array] = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    # parameters whose path matches any of these suffixes skip weight decay
    decay_mask: Callable[[Any], Any] | None = None

    def init(self, params):
        # Moments always fp32 — params may be stored bf16 (the production
        # mixed-precision config: bf16 weights + fp32 optimizer state).
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def _lr(self, count):
        if callable(self.learning_rate):
            return self.learning_rate(count)
        return jnp.asarray(self.learning_rate, jnp.float32)

    def update(self, grads, state, params):
        """Returns (new_params, new_state)."""
        count = state["count"] + 1
        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
            state["mu"],
            grads,
        )
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["nu"],
            grads,
        )
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)
        lr = self._lr(count)
        wd = self.weight_decay
        mask = (
            self.decay_mask(params)
            if self.decay_mask is not None
            else jax.tree.map(lambda p: p.ndim > 1, params)
        )

        def step(p, m, v, use_wd):
            upd = (m / c1) / (jnp.sqrt(v / c2) + self.eps)
            if wd:
                upd = upd + wd * p * jnp.asarray(use_wd, p.dtype)
            return (p - lr * upd).astype(p.dtype)

        new_params = jax.tree.map(step, params, mu, nu, mask)
        return new_params, {"mu": mu, "nu": nu, "count": count}
