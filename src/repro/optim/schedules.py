"""LR schedules as pure ``count -> lr`` callables."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["constant", "cosine_with_warmup", "linear_warmup"]


def constant(lr: float):
    return lambda count: jnp.asarray(lr, jnp.float32)


def linear_warmup(lr: float, warmup_steps: int):
    def f(count):
        c = count.astype(jnp.float32)
        return lr * jnp.minimum(1.0, c / max(warmup_steps, 1))
    return f


def cosine_with_warmup(lr: float, warmup_steps: int, total_steps: int, final_frac: float = 0.1):
    def f(count):
        c = count.astype(jnp.float32)
        warm = jnp.minimum(1.0, c / max(warmup_steps, 1))
        prog = jnp.clip((c - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return lr * warm * cos
    return f
