"""Divisibility-aware logical-axis sharding rules."""
from repro.sharding.rules import (
    LOGICAL_RULES,
    batch_spec,
    constrain,
    named_sharding,
    param_logical_axes,
    param_specs,
    spec_for,
    tree_shardings,
)

__all__ = [
    "LOGICAL_RULES",
    "batch_spec",
    "constrain",
    "named_sharding",
    "param_logical_axes",
    "param_specs",
    "spec_for",
    "tree_shardings",
]
