"""Logical-axis sharding rules — divisibility-aware (DESIGN.md §5).

Every parameter / activation dimension gets a *logical* name; rules map
logical names to mesh axes; :func:`spec_for` drops any mesh axis that does
not divide the concrete dimension (qwen2.5's 40 heads vs model=16, whisper's
odd vocab before padding, ...), guaranteeing that every (arch × shape × mesh)
cell lowers. The fallbacks (context/sequence parallelism for attention) are
encoded in the activation rules.

Default mapping:
  batch   -> ("pod", "data")   DP across pods and the data axis
  embed   -> "data"            FSDP storage sharding of params/optimizer
  vocab/heads/kv_heads/mlp/experts -> "model"   TP / EP
  kv_seq  -> "model"           flash-decoding fallback when heads don't fit
  seq     -> "data"            long-context cache sharding (SP)
  layers  -> None              scan-stacked depth: replicated
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "LOGICAL_RULES",
    "spec_for",
    "named_sharding",
    "param_logical_axes",
    "param_specs",
    "tree_shardings",
    "batch_spec",
    "constrain",
]

# logical axis -> tuple of mesh axes to try (joined as a tuple spec entry)
LOGICAL_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    # CE-loss logits keep vocab on "model": batch for the loss shards over
    # (pod, data) only, so the lm-head is never gathered (train profile v2
    # would otherwise all-gather the (V, D) head per CE chunk — measured
    # 50 GB/chip on codeqwen).
    "batch_ce": ("pod", "data"),
    "embed": ("data",),
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "mlp": ("model",),
    "experts": ("model",),
    "expert_mlp": (),
    "seq": (),
    "kv_seq": ("model",),
    # decode KV caches: sequence over "model" (flash-decoding style) — kv
    # head counts (8, 1, ...) rarely divide the 16-way model axis, cache
    # length always does. Batch still takes (pod, data).
    "cache_seq": ("model",),
    "layers": (),
    "conv": (),
    "state": (),
    "frames": (),
    "dt_rank": (),
    None: (),
}


# Serving profile: parameters are NOT FSDP-sharded over "data" — a decode
# step would otherwise all-gather every parameter once per token. TP-only
# weights fit HBM for every assigned arch (26B fp32 / 16 = 1.6 GB more than
# offset by removing per-token gathers).
SERVING_RULES: dict[str, tuple[str, ...]] = dict(LOGICAL_RULES)
SERVING_RULES["embed"] = ()

# Train profile v2 (§Perf iteration): pure FSDP / ZeRO-3. Batch data-
# parallel over EVERY mesh axis; parameters 2-D sharded over (data, model)
# for storage and all-gathered (bf16) per layer; no tensor parallelism =>
# no per-layer activation all-reduces (measured: the dominant train
# collective, 240 GB/chip f32 on codeqwen), and MoE dispatch stays fully
# local (no expert parallelism => no replicated global dispatch scatter).
# The vocab axis keeps "model" so embedding/lm-head stay 2-D sharded.
TRAIN_FSDP_RULES: dict[str, tuple[str, ...]] = dict(LOGICAL_RULES)
TRAIN_FSDP_RULES.update(
    batch=("pod", "data", "model"),
    embed=("data", "model"),
    vocab=("model",),
    heads=(),
    kv_heads=(),
    mlp=(),
    experts=(),
)


def _axes_that_divide(dim: int, axes: tuple[str, ...], mesh: Mesh) -> tuple[str, ...]:
    """Greedily keep the prefix of mesh axes whose product divides dim."""
    kept: list[str] = []
    prod = 1
    for ax in axes:
        if ax not in mesh.shape:
            continue
        nxt = prod * mesh.shape[ax]
        if dim % nxt == 0:
            kept.append(ax)
            prod = nxt
        else:
            break
    return tuple(kept)


def spec_for(
    logical: tuple[str | None, ...],
    shape: tuple[int, ...],
    mesh: Mesh,
    rules: dict | None = None,
) -> P:
    """Build a PartitionSpec for a tensor with the given logical axes."""
    rules = rules or LOGICAL_RULES
    assert len(logical) == len(shape), f"{logical} vs {shape}"
    entries: list[Any] = []
    used: set[str] = set()
    for name, dim in zip(logical, shape):
        want = rules.get(name, ())
        want = tuple(a for a in want if a not in used)
        kept = _axes_that_divide(dim, want, mesh)
        used.update(kept)
        if not kept:
            entries.append(None)
        elif len(kept) == 1:
            entries.append(kept[0])
        else:
            entries.append(tuple(kept))
    return P(*entries)


def named_sharding(logical, shape, mesh, rules=None) -> NamedSharding:
    return NamedSharding(mesh, spec_for(tuple(logical), tuple(shape), mesh, rules))


# ---------------------------------------------------------------------------
# Parameter-tree logical axes by path pattern.
# Paths look like "blocks/0/attn/wq" or "pre/0/moe/wi".
# ---------------------------------------------------------------------------
_PARAM_PATTERNS: list[tuple[str, tuple[str | None, ...]]] = [
    (r"embed$", ("vocab", "embed")),
    (r"lm_head$", ("vocab", "embed")),
    (r"attn/wq$", ("embed", "heads", "head_dim")),
    (r"attn/wk$", ("embed", "kv_heads", "head_dim")),
    (r"attn/wv$", ("embed", "kv_heads", "head_dim")),
    (r"attn/wo$", ("heads", "head_dim", "embed")),
    (r"attn/b[qkv]$", (None, None)),
    (r"cross/wq$", ("embed", "heads", "head_dim")),
    (r"cross/w[kv]$", ("embed", "kv_heads", "head_dim")),
    (r"cross/wo$", ("heads", "head_dim", "embed")),
    (r"cross/b[qkv]$", (None, None)),
    (r"mlp/wi$", ("embed", "mlp")),
    (r"mlp/wo$", ("mlp", "embed")),
    (r"shared/wi$", ("embed", "mlp")),
    (r"shared/wo$", ("mlp", "embed")),
    (r"moe/router$", ("embed", None)),
    (r"moe/wi$", ("experts", "embed", "expert_mlp")),
    (r"moe/wo$", ("experts", "expert_mlp", "embed")),
    # Mamba: shard the expanded inner dim (counts as "mlp")
    (r"ssm/in_proj$", ("embed", "mlp")),
    (r"ssm/conv_w$", ("conv", "mlp")),
    (r"ssm/conv_b$", ("mlp",)),
    (r"ssm/x_proj$", ("mlp", None)),
    (r"ssm/dt_proj$", ("dt_rank", "mlp")),
    (r"ssm/dt_bias$", ("mlp",)),
    (r"ssm/A_log$", ("mlp", "state")),
    (r"ssm/D$", ("mlp",)),
    (r"ssm/out_proj$", ("mlp", "embed")),
    # RG-LRU: lru width counts as "mlp"
    (r"rec/in_x$", ("embed", "mlp")),
    (r"rec/in_gate$", ("embed", "mlp")),
    (r"rec/conv_w$", ("conv", "mlp")),
    (r"rec/conv_b$", ("mlp",)),
    (r"rec/w[ax]$", ("mlp", None)),
    (r"rec/b[ax]$", ("mlp",)),
    (r"rec/lambda$", ("mlp",)),
    (r"rec/out$", ("mlp", "embed")),
    (r"(ln[12x]?|ln1_post|ln2_post|final_norm|lnx)/scale$", ("embed",)),
]

# decode-cache leaves (inputs/outputs of decode_step / prefill)
_CACHE_PATTERNS: list[tuple[str, tuple[str | None, ...]]] = [
    (r"kv/[kv]$", ("batch", "cache_seq", None, None)),
    (r"cross_kv/[kv]$", ("batch", "cache_seq", None, None)),
    (r"rec/h$", ("batch", "mlp")),
    (r"rec/conv$", ("batch", None, "mlp")),
    (r"ssm/conv$", ("batch", None, "mlp")),
    (r"ssm/ssm$", ("batch", "mlp", "state")),
]


def cache_logical_axes(path: str, shape: tuple[int, ...]) -> tuple[str | None, ...]:
    stacked = bool(re.search(r"(^|/)blocks/", path))
    for pat, axes in _CACHE_PATTERNS:
        if re.search(pat, path):
            return (("layers",) + tuple(axes)) if stacked else tuple(axes)
    return tuple([None] * len(shape))


def cache_specs(cache_shapes, mesh: Mesh):
    flat, treedef = jax.tree_util.tree_flatten(cache_shapes)
    paths = _tree_paths(cache_shapes)
    specs = []
    for (path, leaf), _ in zip(paths, flat):
        axes = cache_logical_axes(path, tuple(leaf.shape))
        specs.append(spec_for(axes, tuple(leaf.shape), mesh))
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_logical_axes(path: str, shape: tuple[int, ...]) -> tuple[str | None, ...]:
    """Logical axes for a parameter, by path suffix match. Scanned stacks
    ("blocks/...") carry a leading "layers" axis."""
    stacked = bool(re.search(r"(^|/)blocks/", path)) or bool(
        re.search(r"encoder/blocks", path)
    )
    for pat, axes in _PARAM_PATTERNS:
        if re.search(pat, path):
            if stacked:
                return ("layers",) + tuple(axes)
            return tuple(axes)
    # default: replicate
    return tuple([None] * len(shape))


def _tree_paths(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for kp, leaf in flat:
        parts = []
        for k in kp:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            else:
                parts.append(str(k))
        out.append(("/".join(parts), leaf))
    return out


def param_specs(param_shapes, mesh: Mesh, rules: dict | None = None):
    """PartitionSpec tree mirroring a parameter (shape) tree."""
    flat, treedef = jax.tree_util.tree_flatten(param_shapes)
    paths = _tree_paths(param_shapes)
    specs = []
    for (path, leaf), _ in zip(paths, flat):
        axes = param_logical_axes(path, tuple(leaf.shape))
        specs.append(spec_for(axes, tuple(leaf.shape), mesh, rules))
    return jax.tree_util.tree_unflatten(treedef, specs)


def tree_shardings(param_shapes, mesh: Mesh, rules: dict | None = None):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        param_specs(param_shapes, mesh, rules),
        is_leaf=lambda x: isinstance(x, P),
    )


def batch_spec(mesh: Mesh, batch: int) -> P:
    """Sharding for the leading batch dim of inputs."""
    axes = _axes_that_divide(batch, LOGICAL_RULES["batch"], mesh)
    if not axes:
        return P()
    return P(axes if len(axes) > 1 else axes[0])


def constrain(x, mesh: Mesh, logical: tuple[str | None, ...]):
    """with_sharding_constraint by logical axes (activation annotations)."""
    spec = spec_for(logical, tuple(x.shape), mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Active-mesh context: model code annotates activations with logical axes;
# the annotations are no-ops unless a launcher activated a mesh (CPU tests
# and single-device runs see unannotated pure functions).
# ---------------------------------------------------------------------------
import contextlib

_ACTIVE_MESH: list[tuple[Mesh, dict]] = []


@contextlib.contextmanager
def activate_mesh(mesh: Mesh, rules: dict | None = None):
    _ACTIVE_MESH.append((mesh, rules or LOGICAL_RULES))
    try:
        yield mesh
    finally:
        _ACTIVE_MESH.pop()


def active_mesh() -> Mesh | None:
    return _ACTIVE_MESH[-1][0] if _ACTIVE_MESH else None


def active_rules() -> dict:
    return _ACTIVE_MESH[-1][1] if _ACTIVE_MESH else LOGICAL_RULES


_IN_SHARD_MAP: list[bool] = []


@contextlib.contextmanager
def suppress_constraints():
    """Inside shard_map bodies, mesh axes are manual — with_sharding_
    constraint is illegal there, so annotations become no-ops."""
    _IN_SHARD_MAP.append(True)
    try:
        yield
    finally:
        _IN_SHARD_MAP.pop()


def maybe_constrain(x, *logical: str | None):
    """Divisibility-aware activation annotation; no-op without a mesh."""
    if not _ACTIVE_MESH or _IN_SHARD_MAP:
        return x
    mesh, rules = _ACTIVE_MESH[-1]
    spec = spec_for(tuple(logical), tuple(x.shape), mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
