"""Data pipelines (synthetic, deterministic, host-sharded)."""
from repro.data.pipeline import SyntheticLM, SyntheticLMConfig, batches

__all__ = ["SyntheticLM", "SyntheticLMConfig", "batches"]
