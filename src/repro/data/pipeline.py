"""Deterministic synthetic LM data pipeline (offline container).

Produces an infinite stream of ``(tokens, labels)`` batches from a counter-
seeded PRNG — deterministic given ``(seed, step)``, so a restarted job
resumes mid-epoch bit-identically (the checkpoint stores only the step).
Structure is injected so the LM loss actually decreases: a first-order
Markov chain over the vocab with a few high-probability successor patterns.

For multi-host training each host draws only its shard of the global batch
(``host_id``/``num_hosts``); on this single-process container both are 0/1.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

__all__ = ["SyntheticLMConfig", "SyntheticLM", "batches"]


@dataclasses.dataclass(frozen=True)
class SyntheticLMConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1
    markov_branch: int = 4  # successors per token (lower = easier)


class SyntheticLM:
    """Counter-based synthetic corpus: batch(step) is a pure function."""

    def __init__(self, cfg: SyntheticLMConfig):
        self.cfg = cfg
        if cfg.global_batch % cfg.num_hosts:
            raise ValueError("global_batch must divide evenly across hosts")
        self.local_batch = cfg.global_batch // cfg.num_hosts
        # Fixed Markov successor table (the learnable structure).
        rng = np.random.default_rng(cfg.seed)
        self.successors = rng.integers(
            0, cfg.vocab_size, size=(cfg.vocab_size, cfg.markov_branch)
        ).astype(np.int32)

    def batch(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed, step, cfg.host_id)
        )
        b, s = self.local_batch, cfg.seq_len
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab_size, size=b)
        choices = rng.integers(0, cfg.markov_branch, size=(b, s))
        # 10% random restarts keep entropy positive.
        restart = rng.random((b, s)) < 0.1
        random_tok = rng.integers(0, cfg.vocab_size, size=(b, s))
        for t in range(s):
            nxt = self.successors[toks[:, t], choices[:, t]]
            toks[:, t + 1] = np.where(restart[:, t], random_tok[:, t], nxt)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def iterator(self, start_step: int = 0) -> Iterator[dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch(step)
            step += 1


def batches(
    vocab_size: int,
    seq_len: int,
    global_batch: int,
    *,
    seed: int = 0,
    start_step: int = 0,
) -> Iterator[dict[str, np.ndarray]]:
    ds = SyntheticLM(
        SyntheticLMConfig(vocab_size, seq_len, global_batch, seed=seed)
    )
    return ds.iterator(start_step)
