"""Multi-pod NXgraph: the DSSS grid partitioned over a 2-D device mesh.

Mapping (DESIGN.md §2): the sub-shard grid becomes a (source-axis ×
destination-axis) device grid. Device (r, c) owns the edges with source in
row-chunk r and destination in column-chunk c — a device-granular
sub-shard, destination-sorted within. One iteration is:

  ToHub    — local gather + segment-reduce into a column-chunk partial
             (the *hub* is exactly the pre-reduce partial aggregate);
  FromHub  — ``psum`` of hubs over the source axis (this IS the paper's
             column-major hub fold, expressed as a collective);
  Exchange — ``all_gather`` of the new attributes over the destination
             axis, re-sliced to each device's source chunk (the paper's
             interval ping-pong crossing the mesh).

Single-pod: source axis = ("data",); multi-pod: ("pod", "data") — the pod
axis simply extends the source dimension of the grid, so hubs reduce
across pods too (this is what the multi-pod dry-run proves shards).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.graph.preprocess import EdgeList

try:  # jax >= 0.5 exposes shard_map at top level
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map  # type: ignore

__all__ = [
    "DeviceBlocks",
    "build_device_blocks",
    "make_pagerank_step",
    "distributed_pagerank",
    "graph_input_specs",
    "GRAPH_SCALES",
]


@dataclasses.dataclass
class DeviceBlocks:
    """Edge blocks stacked per device: (R, C, E_max) arrays."""

    n: int
    n_pad: int
    R: int
    C: int
    src_local: np.ndarray  # (R, C, E) int32, row-chunk-local source ids
    dst_local: np.ndarray  # (R, C, E) int32, column-chunk-local dst ids
    weight: np.ndarray  # (R, C, E) f32: 1/outdeg(src), 0 for padding
    row_chunk: int
    col_chunk: int


def build_device_blocks(el: EdgeList, R: int, C: int) -> DeviceBlocks:
    """Partition (degreed) edges into the R×C device grid, DSSS-sorted."""
    n = el.n
    n_pad = int(np.lcm(R, C) * -(-n // np.lcm(R, C)))
    row_chunk, col_chunk = n_pad // R, n_pad // C
    src, dst = el.src.astype(np.int64), el.dst.astype(np.int64)
    r = src // row_chunk
    c = dst // col_chunk
    order = np.lexsort((src, dst, c, r))  # destination-sorted within block
    src, dst = src[order], dst[order]
    r, c = r[order], c[order]
    block = r * C + c
    counts = np.bincount(block, minlength=R * C)
    e_max = max(int(counts.max()), 1)
    src_l = np.zeros((R * C, e_max), np.int32)
    dst_l = np.zeros((R * C, e_max), np.int32)
    w = np.zeros((R * C, e_max), np.float32)
    deg = el.out_degree.astype(np.float32)
    inv = np.where(deg > 0, 1.0 / np.maximum(deg, 1), 0.0)
    starts = np.zeros(R * C + 1, np.int64)
    np.cumsum(counts, out=starts[1:])
    for b in range(R * C):
        lo, hi = int(starts[b]), int(starts[b + 1])
        e = hi - lo
        src_l[b, :e] = (src[lo:hi] - (b // C) * row_chunk).astype(np.int32)
        dst_l[b, :e] = (dst[lo:hi] - (b % C) * col_chunk).astype(np.int32)
        w[b, :e] = inv[src[lo:hi]]
    return DeviceBlocks(
        n=n,
        n_pad=n_pad,
        R=R,
        C=C,
        src_local=src_l.reshape(R, C, e_max),
        dst_local=dst_l.reshape(R, C, e_max),
        weight=w.reshape(R, C, e_max),
        row_chunk=row_chunk,
        col_chunk=col_chunk,
    )


def make_pagerank_step(
    mesh,
    n: int,
    n_pad: int,
    *,
    src_axes: tuple[str, ...] = ("data",),
    dst_axis: str = "model",
    damping: float = 0.85,
):
    """Jitted one-iteration PageRank on the device grid.

    x, dangling_mask are sharded over the source axes; edge blocks over
    (source axes..., dst axis). Returns (step_fn, in_specs) for reuse by
    both the real runner and the dry-run."""
    R = int(np.prod([mesh.shape[a] for a in src_axes]))
    C = mesh.shape[dst_axis]
    row_chunk, col_chunk = n_pad // R, n_pad // C
    src_spec = P(src_axes if len(src_axes) > 1 else src_axes[0])
    blk_spec = P(src_axes if len(src_axes) > 1 else src_axes[0], dst_axis, None)

    def body(x_blk, dang_blk, src_l, dst_l, w):
        # x_blk: (row_chunk,) local source attributes
        # src_l/dst_l/w: (1, .., 1, E) local edge block
        e = src_l.shape[-1]
        src_ids = src_l.reshape(e)
        dst_ids = dst_l.reshape(e)
        wv = w.reshape(e)
        # -- ToHub: local contributions into the column-chunk partial
        contrib = x_blk[src_ids] * wv
        hub = jax.ops.segment_sum(contrib, dst_ids, num_segments=col_chunk)
        # -- FromHub: fold hubs across the source axis
        y_c = jax.lax.psum(hub, src_axes)  # (col_chunk,), complete
        # -- dangling mass (global scalar)
        dm = jax.lax.psum(jnp.sum(x_blk * dang_blk), src_axes)
        # -- exchange: new attributes back to source-axis sharding
        y_full = jax.lax.all_gather(
            y_c, dst_axis, tiled=True
        )  # (n_pad,) — chunk order == column order
        base = (1.0 - damping) / n
        new_full = base + damping * (y_full + dm / n)
        # padding rows stay zero so they never contribute mass
        valid = jnp.arange(n_pad) < n
        new_full = jnp.where(valid, new_full, 0.0)
        idx = jax.lax.axis_index(src_axes[0])
        for a in src_axes[1:]:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        my = jax.lax.dynamic_slice(new_full, (idx * row_chunk,), (row_chunk,))
        diff = jax.lax.psum(jnp.sum(jnp.abs(my - x_blk)), src_axes + (dst_axis,))
        return my, diff / mesh.shape[dst_axis]

    in_specs = (src_spec, src_spec, blk_spec, blk_spec, blk_spec)
    kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=(src_spec, P()))
    try:
        # check_vma only exists on newer jax; older releases call it check_rep.
        step = shard_map(body, check_vma=False, **kwargs)
    except TypeError:
        step = shard_map(body, check_rep=False, **kwargs)
    return jax.jit(step), (src_spec, blk_spec)


def distributed_pagerank(
    el: EdgeList,
    mesh,
    *,
    iters: int = 20,
    damping: float = 0.85,
    src_axes: tuple[str, ...] = ("data",),
    dst_axis: str = "model",
    tol: float = 0.0,
):
    """Run PageRank on the mesh; returns (ranks (n,), iterations)."""
    R = int(np.prod([mesh.shape[a] for a in src_axes]))
    C = mesh.shape[dst_axis]
    blocks = build_device_blocks(el, R, C)
    step, (src_spec, blk_spec) = make_pagerank_step(
        mesh,
        blocks.n,
        blocks.n_pad,
        src_axes=src_axes,
        dst_axis=dst_axis,
        damping=damping,
    )
    x = np.zeros(blocks.n_pad, np.float32)
    x[: blocks.n] = 1.0 / blocks.n
    dang = np.zeros(blocks.n_pad, np.float32)
    dang[: blocks.n] = (el.out_degree == 0).astype(np.float32)
    put = lambda a, spec: jax.device_put(a, NamedSharding(mesh, spec))
    x = put(x, src_spec)
    dang = put(dang, src_spec)
    src_l = put(blocks.src_local, blk_spec)
    dst_l = put(blocks.dst_local, blk_spec)
    w = put(blocks.weight, blk_spec)
    it = 0
    for it in range(1, iters + 1):
        x, diff = step(x, dang, src_l, dst_l, w)
        if tol and float(diff) < tol:
            break
    return np.asarray(x)[: blocks.n], it


# ---------------------------------------------------------------------------
# Dry-run support: paper-scale graphs as ShapeDtypeStructs (no allocation).
# ---------------------------------------------------------------------------
GRAPH_SCALES = {
    # name: (n, m) from paper Table III
    "live-journal": (4_850_000, 69_000_000),
    "twitter": (41_700_000, 1_470_000_000),
    "yahoo-web": (720_000_000, 6_640_000_000),
}


def graph_input_specs(name: str, mesh, src_axes=("data",), dst_axis="model"):
    """SDS stand-ins for a paper-scale graph on this mesh (dry-run)."""
    n, m = GRAPH_SCALES[name]
    R = int(np.prod([mesh.shape[a] for a in src_axes]))
    C = mesh.shape[dst_axis]
    lcm = int(np.lcm(R, C))
    n_pad = lcm * -(-n // lcm)
    e_max = -(-int(m * 1.10) // (R * C))  # 10% imbalance headroom
    src_spec = P(src_axes if len(src_axes) > 1 else src_axes[0])
    blk_spec = P(src_axes if len(src_axes) > 1 else src_axes[0], dst_axis, None)
    sds = jax.ShapeDtypeStruct
    mk = lambda shape, dt, spec: sds(shape, dt, sharding=NamedSharding(mesh, spec))
    return {
        "n": n,
        "n_pad": n_pad,
        "x": mk((n_pad,), jnp.float32, src_spec),
        "dang": mk((n_pad,), jnp.float32, src_spec),
        "src_l": mk((R, C, e_max), jnp.int32, blk_spec),
        "dst_l": mk((R, C, e_max), jnp.int32, blk_spec),
        "w": mk((R, C, e_max), jnp.float32, blk_spec),
    }


def _selftest():  # pragma: no cover — exercised via subprocess in tests
    import os

    assert os.environ.get("XLA_FLAGS", "").count("device_count"), (
        "run with XLA_FLAGS=--xla_force_host_platform_device_count=N"
    )
    from repro.core import NXGraphEngine, PageRank, build_dsss
    from repro.graph.generators import rmat
    from repro.graph.preprocess import degree_and_densify

    src, dst = rmat(9, edge_factor=8, seed=5)
    el = degree_and_densify(src, dst, drop_self_loops=True)
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    ranks, iters = distributed_pagerank(el, mesh, iters=12)
    ref = NXGraphEngine(build_dsss(el, 4), PageRank(), strategy="fused").run(
        12, tol=0.0
    )
    err = float(np.abs(ranks - ref.attrs).max())
    print(f"selftest: n={el.n} m={el.m} iters={iters} max_err={err:.3e}")
    assert err < 1e-6, err
    # multi-source-axis variant (pod axis folded into the source dim)
    mesh3 = jax.make_mesh((2, 1, 2), ("pod", "data", "model"))
    ranks3, _ = distributed_pagerank(
        el, mesh3, iters=12, src_axes=("pod", "data")
    )
    err3 = float(np.abs(ranks3 - ref.attrs).max())
    print(f"selftest multi-pod: max_err={err3:.3e}")
    assert err3 < 1e-6, err3
    print("selftest OK")


if __name__ == "__main__":
    _selftest()
