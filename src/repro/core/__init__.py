"""NXgraph core: the paper's contribution as a composable JAX module.

- :mod:`repro.core.dsss` — Destination-Sorted Sub-Shard structure (§II-A/III-A)
- :mod:`repro.core.session` — GraphSession: stage once, run many (batched) jobs
- :mod:`repro.core.plan` — ExecutionPlan: frozen, hashable job descriptions
- :mod:`repro.core.engine` — back-compat NXGraphEngine shim over Session/Plan
- :mod:`repro.core.vertex_programs` — Initialize/Update/Output programs (§II-B)
- :mod:`repro.core.iomodel` — Table II I/O closed forms + adaptive selection
- :mod:`repro.core.algorithms` — PageRank/BFS/WCC/SSSP/SCC drivers (§IV),
  plus batched ``multi_bfs`` / ``multi_sssp``
- :mod:`repro.core.baselines` — TurboGraph-like + GraphChi-like baselines (§III-C)
- :mod:`repro.core.distributed` — shard_map 2-D partitioned multi-pod engine
"""
from repro.core.dsss import DSSSGraph, PackedSweep, SubShard, build_dsss
from repro.core.plan import CheckpointSpec, ExecutionPlan, TraceSpec
from repro.core.session import (
    BatchResult,
    GraphSession,
    Meters,
    Result,
    clear_session_cache,
    get_session,
)
from repro.core.engine import NXGraphEngine
from repro.core.iomodel import (
    IOComparison,
    IOParams,
    StrategyChoice,
    calibrate_edge_bytes,
    compare_measured,
    disk_read_bytes,
    dpu_io,
    modelled_io,
    mpu_io,
    mpu_q,
    packed_disk_bytes,
    packed_h2d_bytes,
    select_strategy,
    spu_io,
    turbograph_like_io,
)
from repro.core.vertex_programs import (
    BFS,
    INF_DEPTH,
    PageRank,
    SSSP,
    VertexProgram,
    WCC,
)
from repro.core.algorithms import (
    bfs,
    multi_bfs,
    multi_sssp,
    pagerank,
    scc,
    sssp,
    wcc,
)

__all__ = [
    "DSSSGraph",
    "PackedSweep",
    "SubShard",
    "build_dsss",
    "GraphSession",
    "ExecutionPlan",
    "CheckpointSpec",
    "TraceSpec",
    "BatchResult",
    "get_session",
    "clear_session_cache",
    "Meters",
    "NXGraphEngine",
    "Result",
    "IOParams",
    "IOComparison",
    "StrategyChoice",
    "spu_io",
    "dpu_io",
    "mpu_io",
    "mpu_q",
    "modelled_io",
    "compare_measured",
    "calibrate_edge_bytes",
    "disk_read_bytes",
    "packed_disk_bytes",
    "packed_h2d_bytes",
    "select_strategy",
    "turbograph_like_io",
    "VertexProgram",
    "PageRank",
    "BFS",
    "WCC",
    "SSSP",
    "INF_DEPTH",
    "pagerank",
    "bfs",
    "wcc",
    "sssp",
    "scc",
    "multi_bfs",
    "multi_sssp",
]
