"""NXgraph core: the paper's contribution as a composable JAX module.

- :mod:`repro.core.dsss` — Destination-Sorted Sub-Shard structure (§II-A/III-A)
- :mod:`repro.core.engine` — SPU/DPU/MPU update engine + fused fast path (§III-B)
- :mod:`repro.core.vertex_programs` — Initialize/Update/Output programs (§II-B)
- :mod:`repro.core.iomodel` — Table II I/O closed forms + adaptive selection
- :mod:`repro.core.algorithms` — PageRank/BFS/WCC/SSSP/SCC drivers (§IV)
- :mod:`repro.core.baselines` — TurboGraph-like + GraphChi-like baselines (§III-C)
- :mod:`repro.core.distributed` — shard_map 2-D partitioned multi-pod engine
"""
from repro.core.dsss import DSSSGraph, SubShard, build_dsss
from repro.core.engine import Meters, NXGraphEngine, Result
from repro.core.iomodel import (
    IOParams,
    StrategyChoice,
    dpu_io,
    mpu_io,
    mpu_q,
    select_strategy,
    spu_io,
    turbograph_like_io,
)
from repro.core.vertex_programs import (
    BFS,
    INF_DEPTH,
    PageRank,
    SSSP,
    VertexProgram,
    WCC,
)
from repro.core.algorithms import bfs, pagerank, scc, sssp, wcc

__all__ = [
    "DSSSGraph",
    "SubShard",
    "build_dsss",
    "Meters",
    "NXGraphEngine",
    "Result",
    "IOParams",
    "StrategyChoice",
    "spu_io",
    "dpu_io",
    "mpu_io",
    "mpu_q",
    "select_strategy",
    "turbograph_like_io",
    "VertexProgram",
    "PageRank",
    "BFS",
    "WCC",
    "SSSP",
    "INF_DEPTH",
    "pagerank",
    "bfs",
    "wcc",
    "sssp",
    "scc",
]
