"""GraphSession — stage the graph once, run many programs, batch many queries.

NXgraph's core abstraction (paper §II-B) is a graph that *stays put* while
interval/sub-shard schedules stream over it. This module is that abstraction
as an API: a :class:`GraphSession` owns the device-staged DSSS blocks, the
fused edge arrays and the SPU residency sets — built once per graph — and
executes any number of :class:`repro.core.plan.ExecutionPlan` jobs against
them. ``session.run(plan)`` runs one job; ``session.run_batch(plans)`` fuses
K compatible jobs (e.g. 64 BFS sources, a parameter sweep) into a *single*
streamed pass over the edge blocks: attributes carry a leading batch axis
and every block primitive is vmapped over it, so the slow-tier edge traffic
is paid once, not K times.

Execution layout: attributes are held as ``(K, P, interval_size)`` — K
queries × P intervals — and all block primitives batch over the leading
axis (K = 1 for single runs; XLA collapses the unit axis). Byte-meter
accounting under batching: *edge* bytes are charged once per block per
sweep (the streamed pass is shared), while *interval* and *hub* bytes are
charged K× (each query owns its attribute state). ``meters.iterations``
always equals the number of update sweeps executed.

The per-iteration schedules themselves (SPU / DPU / MPU / fused, paper
§III-B) are unchanged from the engine; custom schedules (the TurboGraph-like
baseline) register via :meth:`GraphSession.register_strategy`.

Out-of-core execution (paper §I "streamlined disk access"): the session's
``residency`` axis decides whether sub-shard blocks live on the device
("device"), or stay as pinned host (numpy) buffers that are streamed to the
device per sweep with double-buffered prefetch ("host"), with the resident
set — the blocks the ``memory_budget`` pins in the fast tier — computed by
:meth:`GraphSession._resolve_residency` and *enforced* by
:class:`_BlockFetcher`. Graphs larger than the fast tier run in "host" mode
with device-held topology bounded by the budget (plus a two-block streaming
ring), bit-identical to the device-resident run.

Compiled sweeps (the ``execution`` axis): the paper's headline number is
raw per-iteration speed — its DSSS structure exists so the inner loop is a
streamlined, conflict-free pass over sorted edge blocks. The per-block
executor re-enters Python for every sub-shard (O(P²) jit dispatches per
sweep); with ``execution="packed"`` the session instead stages the
:class:`repro.core.dsss.PackedSweep` tile layout once — destination-
aligned fixed-size tiles cut only at destination-run boundaries, so
padding stays bounded on power-law graphs instead of being dictated by the
largest hub-heavy sub-shard — and runs the entire gather-reduce phase of a
sweep as **one** ``jax.lax.scan`` over the tile axis, one batched
accumulator init, and one batched apply — ~4 dispatches per sweep
regardless of P. Results are bit-identical to the per-block path for all
of SPU/DPU/MPU (see :class:`~repro.core.dsss.PackedSweep` for why the
run-aligned stream order reproduces every schedule's fold order exactly),
and the modelled byte/edge meters are computed from the packed metadata to
be field-for-field identical. Under enforced host residency the packed
path does not downgrade: the tile axis is chunked and streamed
host→device with the same double-buffered prefetch discipline as
:class:`_BlockFetcher` (a budget-pinned tile prefix stays device-resident,
each streamed chunk charges ``bytes_h2d``), so SPU/DPU/MPU all run packed
out-of-core.

Frontier-aware selective execution (the ``activity`` plan axis): monotone
programs (BFS/SSSP/WCC) track the per-sweep interval frontier — the
``changed`` output of the previous sweep — and, under ``activity="auto"``
(the default), skip everything that frontier cannot touch: inactive source
intervals on the per-block path, inactive tiles in the packed scan (a
compacted active-tile gather, bucketed to keep jit variants ≤ log2(NT)),
and inactive streamed chunks in the host/disk tiers — so the *physical*
``bytes_h2d`` / ``bytes_disk_read`` shrink with the frontier, not just the
modelled charges. Results are bit-identical to ``activity="off"`` full
sweeps (skipped work contributes exact ⊕-identities by the monotone
contract) and the per-sweep frontier trace is returned as
``Result.activity_log``, from which the iomodel activity terms
(``selective_streamed_tiles`` / ``streamed_block_bytes`` /
``disk_read_bytes(active_rows=...)``) reconstruct the byte meters exactly.

The third tier (paper §IV, the actual *disk*): a graph stored as a
``.dsss`` container (:mod:`repro.storage`) opens with
:meth:`GraphSession.open` into ``residency="disk"`` — the host-side
block buffers and packed tile arrays become read-only **mmap views of
the file**, so nothing edge-scale is resident in host RAM either. The
same streaming machinery (block fetcher / packed chunk streamer) then
moves data disk→device; each mmap fetch of a block or tile chunk that is
neither device-pinned (``memory_budget``) nor RAM-cached
(``host_memory_budget``, the mid tier of the three-level budget)
additionally charges ``Meters.bytes_disk_read`` — checked against the
``disk_read_bytes`` / ``packed_disk_bytes`` closed forms in
:mod:`repro.core.iomodel`. Results stay bit-identical and the model
meters field-identical across all three residencies.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
import os
import time
import weakref
from collections import OrderedDict
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dsss import (
    DSSSGraph,
    active_tile_mask,
    next_bucket,
    tile_source_spans,
)
from repro.core.iomodel import (
    IOParams,
    PACKED_SLOT_BYTES,
    StrategyChoice,
    modelled_io,
    mpu_q,
    select_strategy,
)
from repro.core.plan import ExecutionPlan
from repro.core.vertex_programs import VertexProgram, reduce_identity
from repro.obs.registry import REGISTRY as _REGISTRY
from repro.obs.trace import TRACER as _TRACER
from repro.reliability.checkpoint import (
    SnapshotError,
    latest_snapshot,
    load_snapshot,
    save_snapshot,
)
from repro.reliability.faults import FaultPlan, with_transient_retries

__all__ = [
    "GraphSession",
    "Meters",
    "MODEL_METER_FIELDS",
    "Result",
    "BatchResult",
    "CompiledPlan",
    "PackedStreamPlan",
    "IdentityLRU",
    "get_session",
    "clear_session_cache",
]


# The *modelled* Meters fields — identical across execution modes and
# residencies by contract (tests/test_packed_sweep.py, the residency
# property suite and bench_sweep all compare exactly this set; keeping the
# one list here is what stops the three from drifting when a field is
# added). The remaining fields (wall_seconds, bytes_h2d,
# peak_device_graph_bytes) are physical: they describe whichever data path
# actually ran.
MODEL_METER_FIELDS = (
    "bytes_read_edges",
    "bytes_read_intervals",
    "bytes_read_hubs",
    "bytes_written_hubs",
    "bytes_written_intervals",
    "iterations",
    "blocks_processed",
    "blocks_skipped",
    "edges_processed",
)


# ---------------------------------------------------------------------------
# Observability handles (repro.obs). The byte counter is incremented on the
# same lines that charge the corresponding Meters field — physical kinds
# (h2d, disk_read) at the transfer/mmap boundary, model kinds per sweep —
# so a run's registry deltas recombine field-for-field with Result.meters
# (tests/test_obs.py). All no-ops under REPRO_OBS=0.
# ---------------------------------------------------------------------------
_OBS_BYTES = _REGISTRY.counter(
    "repro_engine_bytes_total",
    "Engine bytes moved/charged, by Meters field (bytes_<kind>)",
    ("kind",),
)
_OBS_H2D = _OBS_BYTES.labels(kind="h2d")
_OBS_DISK = _OBS_BYTES.labels(kind="disk_read")
# Model-unit byte fields, charged as per-sweep deltas in _execute.
_OBS_MODEL_BYTES = tuple(
    (f, _OBS_BYTES.labels(kind=f[len("bytes_"):]))
    for f in MODEL_METER_FIELDS
    if f.startswith("bytes_")
)
_OBS_SWEEPS = _REGISTRY.counter(
    "repro_engine_sweeps_total", "Update sweeps executed"
)
_OBS_RUNS = _REGISTRY.counter(
    "repro_engine_runs_total",
    "Engine runs completed",
    ("program", "strategy", "residency", "execution"),
)
_OBS_PEAK = _REGISTRY.gauge(
    "repro_engine_peak_device_graph_bytes",
    "Device-held topology high-water mark of the last run (model units)",
)
_OBS_DRIFT = _REGISTRY.gauge(
    "repro_iomodel_drift_ratio",
    "Measured/modelled per-iteration slow-tier bytes of the last run with "
    "a Table II closed form (1.0 = the exactness contract holds live)",
    ("direction", "strategy"),
)
# Monotone per-process run ids, linking "sweep"/"checkpoint" trace spans
# to their enclosing "run" span's metadata.
_RUN_SEQ = itertools.count(1)


@dataclasses.dataclass
class Meters:
    """Slow-tier byte counters + scheduling statistics.

    The ``bytes_read_*`` / ``bytes_written_*`` fields are the paper's
    Table II slow-tier traffic, charged in *model units* (``e·Be`` per
    streamed block, ``interval_size·Ba`` per interval load/save). Under
    ``residency="host"`` the edge charges coincide with real host→device
    transfers — a block is charged exactly when it is actually copied —
    and two extra fields report the physical side of the same events:

    * ``bytes_h2d``: raw bytes of the numpy buffers actually shipped to
      the device (bucket-padded, index-encoded — ≥ the model bytes).
    * ``peak_device_graph_bytes``: high-water mark of device-held edge
      topology in model units (pinned resident set + the ≤2-block
      prefetch ring). Under ``residency="device"`` this is the whole
      graph; under ``"host"`` it is bounded by the memory budget plus
      the documented two-block streaming slack.
    * ``bytes_disk_read``: raw bytes fetched from the mmap'd ``.dsss``
      tier under ``residency="disk"`` — charged at the mmap-fetch layer
      whenever a streamed block / tile chunk is neither device-pinned
      nor host-RAM-cached (the ``host_memory_budget`` mid tier). It
      models cold-cache streaming: the OS page cache may physically
      absorb re-reads, but the meter charges each per-sweep fetch, which
      is what the ``disk_read_bytes`` / ``packed_disk_bytes`` closed
      forms (repro.core.iomodel) predict exactly. Zero under the other
      residencies.
    """

    bytes_read_edges: float = 0.0
    bytes_read_intervals: float = 0.0
    bytes_read_hubs: float = 0.0
    bytes_written_hubs: float = 0.0
    bytes_written_intervals: float = 0.0
    bytes_h2d: float = 0.0
    bytes_disk_read: float = 0.0
    peak_device_graph_bytes: float = 0.0
    iterations: int = 0
    blocks_processed: int = 0
    blocks_skipped: int = 0
    edges_processed: int = 0
    wall_seconds: float = 0.0

    def model_dict(self) -> dict:
        """The modelled fields only (see :data:`MODEL_METER_FIELDS`)."""
        return {f: getattr(self, f) for f in MODEL_METER_FIELDS}

    @property
    def bytes_read(self) -> float:
        return self.bytes_read_edges + self.bytes_read_intervals + self.bytes_read_hubs

    @property
    def bytes_written(self) -> float:
        return self.bytes_written_hubs + self.bytes_written_intervals

    @property
    def bytes_total(self) -> float:
        return self.bytes_read + self.bytes_written

    def per_iteration(self) -> "Meters":
        k = max(self.iterations, 1)
        out = Meters(**{f.name: getattr(self, f.name) for f in dataclasses.fields(self)})
        for f in (
            "bytes_read_edges",
            "bytes_read_intervals",
            "bytes_read_hubs",
            "bytes_written_hubs",
            "bytes_written_intervals",
            "bytes_h2d",
            "bytes_disk_read",
        ):
            setattr(out, f, getattr(self, f) / k)
        return out

    def mteps(self) -> float:
        """Million traversed edges per second (paper Fig. 11 metric)."""
        if self.wall_seconds <= 0:
            return float("nan")
        return self.edges_processed / self.wall_seconds / 1e6

    def merge(self, other: "Meters") -> "Meters":
        """Accumulate another run's counters into this one (in place).

        Every field sums — including ``iterations`` — so ``per_iteration()``
        of a merged meter remains the true per-sweep average. The one
        exception is ``peak_device_graph_bytes``, a high-water mark:
        merging takes the max (sequential runs reuse the same device).
        """
        for f in dataclasses.fields(self):
            if f.name == "peak_device_graph_bytes":
                setattr(self, f.name, max(getattr(self, f.name), getattr(other, f.name)))
            else:
                setattr(self, f.name, getattr(self, f.name) + getattr(other, f.name))
        return self


@dataclasses.dataclass
class Result:
    attrs: np.ndarray
    output: Any
    iterations: int
    converged: bool
    meters: Meters
    strategy: StrategyChoice
    # One (P,) bool array per executed sweep: the source intervals that
    # sweep processed (union over the batch). All-True every sweep for
    # non-selective runs; under selective execution this is the frontier
    # trace the iomodel activity terms (selective_streamed_tiles /
    # streamed_block_bytes / disk_read_bytes) reconstruct the physical
    # byte meters from, exactly. Shared by every member of a batch.
    activity_log: tuple = ()


@dataclasses.dataclass
class BatchResult:
    """K plans executed in one streamed pass.

    ``results[m]`` holds per-query attrs/output; every member shares the
    batch-level ``meters`` object (one edge stream, K attribute states).
    ``iterations`` is the number of shared update sweeps executed.
    """

    results: list[Result]
    meters: Meters
    iterations: int
    converged: bool
    fused: bool  # False when plans were incompatible and ran sequentially
    activity_log: tuple = ()  # per-sweep (P,) processed-interval bitmaps

    def __len__(self) -> int:
        return len(self.results)

    def __iter__(self):
        return iter(self.results)

    def __getitem__(self, m: int) -> Result:
        return self.results[m]


@dataclasses.dataclass(frozen=True)
class CompiledPlan:
    """A plan resolved against one session: strategy + residency, no state.

    ``residency`` is the *resolved* placement mode ("device", "host" or
    "disk" — never "auto"); ``resident`` is the set of sub-shard keys the
    memory budget pins in the fast tier. Under "host"/"disk" the
    resident set is enforced (those blocks are device-pinned, the rest
    are streamed per sweep — from pinned host buffers or from the mmap'd
    store); under "device" every block stays on device and the same
    resident set drives the modelled byte meters only. ``host_cached``
    is the disk tier's mid level: the sub-shards the
    ``host_memory_budget`` keeps materialized in host RAM, whose fetches
    do not charge ``bytes_disk_read`` (empty except under "disk").
    """

    params: IOParams
    choice: StrategyChoice
    resident: frozenset
    residency: str = "device"
    host_cached: frozenset = frozenset()
    # Resolved execution mode: "packed" (scan) or "packed_kernel" (fused
    # Pallas kernel) iff a compiled sweep path will actually run (an
    # SPU/DPU/MPU schedule — either residency), else "per_block".
    # Never "auto".
    execution: str = "per_block"
    # Resolved activity mode: "selective" iff the program is monotone and
    # the plan's activity axis is "auto" — frontier-aware interval/tile/
    # chunk skipping; else "off" (full sweeps). Never "auto".
    activity: str = "off"


@dataclasses.dataclass(frozen=True)
class PackedStreamPlan:
    """How packed execution places tiles under enforced host residency.

    ``pin_tiles`` leading tiles stay device-resident (the budget's fast
    tier, mirroring the per-block resident set: SPU pins the leftover
    after both attribute copies, DPU/MPU pin nothing — their I/O model
    streams every edge); the remaining tiles are streamed per sweep in
    chunks of ``chunk_tiles``, double-buffered, so peak device topology is
    the pinned prefix plus at most two chunks (``max_chunk_model_bytes``
    each — the packed counterpart of the per-block two-block slack).

    ``host_tiles`` is the disk tier's mid level (0 except for
    disk-backed sessions): the chunk-aligned count of tiles immediately
    after the pinned prefix that the ``host_memory_budget`` keeps
    materialized in host RAM — streaming those chunks charges
    ``bytes_h2d`` but not ``bytes_disk_read``; everything past
    ``pin_tiles + host_tiles`` re-reads from the mmap'd store each sweep.
    """

    pin_tiles: int
    chunk_tiles: int
    num_tiles: int
    tile_edges: int
    pin_model_bytes: float  # real-edge model bytes of the pinned prefix
    max_chunk_model_bytes: float  # largest streamed chunk, model units
    host_tiles: int = 0


# ---------------------------------------------------------------------------
# Jitted block primitives, batched over a leading K (query) axis via vmap.
# ``program`` is a frozen dataclass => hashable => usable as a static
# argument; jit caches one executable per (program, bucket, num_segments, K)
# combination, shared by every session/plan that uses the same program.
# Block index arrays are query-invariant and enter the vmapped body by
# closure (broadcast); attributes/accumulators carry K, and aux dicts enter
# as vmap operands: with ``aux_batched=False`` (the common case — one aux
# shared by all K queries) every aux leaf broadcasts (in_axes=None), with
# ``aux_batched=True`` every leaf carries its own leading K axis (per-query
# aux, e.g. a run_batch of MaxLabelForward plans with different masks) and
# is mapped — inside the vmap each query sees its own slice at the
# original ndim, so the per-leaf ``ndim == 1`` gather checks are unchanged.
# ---------------------------------------------------------------------------
def _aux_axes(aux: dict, aux_batched: bool):
    """vmap in_axes pytree for an aux dict under either batching mode."""
    return {k: (0 if aux_batched else None) for k in aux}



def _gather_reduce_core(
    program, prev_src, src_aux, dst_aux, src_local, dst_local, weights,
    e_valid, acc, num_segments, has_weights,
):
    vals = prev_src[src_local]
    s_aux = {k: (v[src_local] if getattr(v, "ndim", 0) == 1 else v) for k, v in src_aux.items()}
    d_aux = (
        {k: (v[dst_local] if getattr(v, "ndim", 0) == 1 else v) for k, v in dst_aux.items()}
        if program.needs_dst_aux
        else None
    )
    contrib = program.gather(vals, weights if has_weights else None, s_aux, d_aux)
    ident = reduce_identity(program.reduce, contrib.dtype)
    mask = jnp.arange(contrib.shape[0]) < e_valid
    contrib = jnp.where(mask, contrib, ident)
    if program.reduce == "sum":
        red = jax.ops.segment_sum(contrib, dst_local, num_segments=num_segments)
        return jnp.add(acc, red.astype(acc.dtype))
    if program.reduce == "min":
        red = jax.ops.segment_min(contrib, dst_local, num_segments=num_segments)
        return jnp.minimum(acc, red.astype(acc.dtype))
    red = jax.ops.segment_max(contrib, dst_local, num_segments=num_segments)
    return jnp.maximum(acc, red.astype(acc.dtype))


@functools.partial(
    jax.jit,
    static_argnames=("program", "num_segments", "has_weights", "aux_batched"),
)
def _block_gather_reduce(
    program: VertexProgram,
    prev_src: jnp.ndarray,  # (K, isize) source-interval attributes
    src_aux: dict,  # per-source-interval aux; (K,)-leading when aux_batched
    dst_aux: dict,  # per-dest-interval aux (or empty)
    src_local: jnp.ndarray,  # (bucket,)
    dst_local: jnp.ndarray,  # (bucket,)
    weights: jnp.ndarray | None,
    e_valid: jnp.ndarray,  # scalar int32: real edge count in the bucket
    acc: jnp.ndarray,  # (K, num_segments) running ⊕ accumulator
    num_segments: int,
    has_weights: bool,
    aux_batched: bool = False,
):
    def one(pv, a, sx, dx):
        return _gather_reduce_core(
            program, pv, sx, dx, src_local, dst_local, weights,
            e_valid, a, num_segments, has_weights,
        )

    return jax.vmap(
        one,
        in_axes=(
            0,
            0,
            _aux_axes(src_aux, aux_batched),
            _aux_axes(dst_aux, aux_batched),
        ),
    )(prev_src, acc, src_aux, dst_aux)


def _to_hub_core(
    program, prev_src, src_aux, dst_aux, src_local, hub_inv, dst_local,
    weights, e_valid, num_segments, has_weights,
):
    vals = prev_src[src_local]
    s_aux = {k: (v[src_local] if getattr(v, "ndim", 0) == 1 else v) for k, v in src_aux.items()}
    d_aux = (
        {k: (v[dst_local] if getattr(v, "ndim", 0) == 1 else v) for k, v in dst_aux.items()}
        if program.needs_dst_aux
        else None
    )
    contrib = program.gather(vals, weights if has_weights else None, s_aux, d_aux)
    ident = reduce_identity(program.reduce, contrib.dtype)
    mask = jnp.arange(contrib.shape[0]) < e_valid
    contrib = jnp.where(mask, contrib, ident)
    if program.reduce == "sum":
        return jax.ops.segment_sum(contrib, hub_inv, num_segments=num_segments)
    if program.reduce == "min":
        return jax.ops.segment_min(contrib, hub_inv, num_segments=num_segments)
    return jax.ops.segment_max(contrib, hub_inv, num_segments=num_segments)


@functools.partial(
    jax.jit,
    static_argnames=("program", "num_segments", "has_weights", "aux_batched"),
)
def _block_to_hub(
    program: VertexProgram,
    prev_src: jnp.ndarray,  # (K, isize)
    src_aux: dict,
    dst_aux: dict,
    src_local: jnp.ndarray,
    hub_inv: jnp.ndarray,  # (bucket,) edge -> hub slot
    dst_local: jnp.ndarray,
    weights: jnp.ndarray | None,
    e_valid: jnp.ndarray,
    num_segments: int,  # number of hub slots (unique destinations), padded
    has_weights: bool,
    aux_batched: bool = False,
):
    """ToHub (paper Alg. 6 line 4): partial ⊕ per unique destination."""

    def one(pv, sx, dx):
        return _to_hub_core(
            program, pv, sx, dx, src_local, hub_inv, dst_local,
            weights, e_valid, num_segments, has_weights,
        )

    return jax.vmap(
        one,
        in_axes=(
            0,
            _aux_axes(src_aux, aux_batched),
            _aux_axes(dst_aux, aux_batched),
        ),
    )(prev_src, src_aux, dst_aux)


@functools.partial(jax.jit, static_argnames=("program",))
def _block_from_hub(
    program: VertexProgram,
    acc: jnp.ndarray,  # (K, isize)
    hub_dst: jnp.ndarray,  # (u,) unique local destinations
    partial: jnp.ndarray,  # (K, u) hub values
    u_valid: jnp.ndarray,  # scalar: real number of hub slots
):
    """FromHub (paper Alg. 6 line 11): fold one hub into the accumulator."""

    def one(a, p):
        ident = reduce_identity(program.reduce, a.dtype)
        mask = jnp.arange(p.shape[0]) < u_valid
        p = jnp.where(mask, p.astype(a.dtype), ident)
        if program.reduce == "sum":
            return a.at[hub_dst].add(p, mode="drop")
        if program.reduce == "min":
            return a.at[hub_dst].min(p, mode="drop")
        return a.at[hub_dst].max(p, mode="drop")

    return jax.vmap(one)(acc, partial)


@functools.partial(jax.jit, static_argnames=("program", "aux_batched"))
def _apply_interval(
    program: VertexProgram,
    old: jnp.ndarray,  # (K, isize)
    acc: jnp.ndarray,  # (K, isize)
    aux: dict,  # interval view; (K,)-leading leaves when aux_batched
    globals_: dict,  # per-query iteration scalars, (K,)-leading leaves
    valid: jnp.ndarray,  # (isize,) bool — mask off padding in the last interval
    tol: jnp.ndarray,
    aux_batched: bool = False,
):
    def one(o, a, ax, gl):
        new = program.apply(o, a, ax, gl)
        new = jnp.where(valid, new, o)
        changed = jnp.any(program.changed(o, new, tol) & valid)
        return new, changed

    return jax.vmap(one, in_axes=(0, 0, _aux_axes(aux, aux_batched), 0))(
        old, acc, aux, globals_
    )


@functools.partial(jax.jit, static_argnames=("program", "aux_batched"))
def _pre_iteration(
    program: VertexProgram,
    attrs_flat: jnp.ndarray,
    aux: dict,
    aux_batched: bool = False,
):
    """Per-query iteration globals (e.g. PageRank dangling mass), (K,)-leaved."""
    return jax.vmap(
        lambda a, ax: program.pre_iteration(a, ax),
        in_axes=(0, _aux_axes(aux, aux_batched)),
    )(attrs_flat, aux)


def _fused_core(
    program, attrs, aux, src, dst, weights, valid, tol, n_pad, P, has_weights
):
    globals_ = program.pre_iteration(attrs, aux)
    vals = attrs[src]
    s_aux = {k: (v[src] if getattr(v, "ndim", 0) == 1 else v) for k, v in aux.items()}
    d_aux = (
        {k: (v[dst] if getattr(v, "ndim", 0) == 1 else v) for k, v in aux.items()}
        if program.needs_dst_aux
        else None
    )
    contrib = program.gather(vals, weights if has_weights else None, s_aux, d_aux)
    if program.reduce == "sum":
        red = jax.ops.segment_sum(contrib, dst, num_segments=n_pad)
    elif program.reduce == "min":
        red = jax.ops.segment_min(contrib, dst, num_segments=n_pad)
    else:
        red = jax.ops.segment_max(contrib, dst, num_segments=n_pad)
    red = red.astype(attrs.dtype)
    new = program.apply(attrs, red, aux, globals_)
    new = jnp.where(valid, new, attrs)
    changed = program.changed(attrs, new, tol) & valid
    changed_iv = jnp.any(changed.reshape(P, -1), axis=1)
    return new, changed_iv


@functools.partial(
    jax.jit,
    static_argnames=("program", "n_pad", "P", "has_weights", "aux_batched"),
)
def _fused_iteration(
    program: VertexProgram,
    attrs: jnp.ndarray,  # (K, n_pad)
    aux: dict,
    src: jnp.ndarray,
    dst: jnp.ndarray,
    weights: jnp.ndarray | None,
    valid: jnp.ndarray,
    tol: jnp.ndarray,
    n_pad: int,
    P: int,
    has_weights: bool,
    aux_batched: bool = False,
):
    def one(a, ax):
        return _fused_core(
            program, a, ax, src, dst, weights, valid, tol, n_pad, P, has_weights
        )

    return jax.vmap(one, in_axes=(0, _aux_axes(aux, aux_batched)))(attrs, aux)


# ---------------------------------------------------------------------------
# Compiled (tile-packed) sweep primitives. One jax.lax.scan over the packed
# tile axis replaces the per-sub-shard dispatch loop: the whole gather-reduce
# phase of an update sweep is a single XLA program (or, under host
# residency, one program per streamed tile chunk). Bit-identity with the
# per-block path holds because (a) tiles are cut only at destination-run
# boundaries, so every (sub-shard, destination) partial ⊕ is computed over
# the same values in the same order as the per-block segment reduce,
# (b) stream order folds each destination's sub-shard partials in ascending
# source-interval order — the fold order of SPU and of the DPU/MPU
# two-phase schedules alike — and (c) padding and inactive-row edges
# contribute exact ⊕-identities.
# ---------------------------------------------------------------------------
def _stack_interval_aux(aux: dict, P: int, isz: int) -> dict:
    """Reshape 1-D (n_pad,) aux leaves to (P, isz) interval rows in-trace."""
    return {
        k: (v.reshape(P, isz) if getattr(v, "ndim", 0) == 1 else v)
        for k, v in aux.items()
    }


def _packed_sweep_impl(
    program: VertexProgram,
    attrs_flat: jnp.ndarray,  # (K, n_pad) previous attributes (read-only)
    acc_flat: jnp.ndarray,  # (K, n_pad) running ⊕ accumulators (donatable)
    aux: dict,  # run-constant aux; (K,)-leading leaves when aux_batched
    tiles: dict,  # PackedSweep device arrays, (NT, ...) leaves
    row_active: jnp.ndarray,  # (P,) bool — sweep's active source intervals
    has_weights: bool,
    aux_batched: bool = False,
):
    """The gather-reduce phase of one update sweep over a tile sequence.

    Each scan step processes one destination-aligned tile: gather source
    attributes/aux by the tile's global ``src`` ids, segment-reduce the
    contributions by ``run_local`` (the ToHub windowed partial — one
    segment per (sub-shard, destination) run), then scatter-fold the run
    partials into the flat accumulator at ``run_dst`` (the FromHub fold).
    Update order within the scatter is ascending run order, i.e. exactly
    the schedules' ascending-source-interval fold order.

    Edges past ``e_valid`` (tile padding) and edges whose source interval
    is inactive this sweep (monotone activity tracking — the (P,) row
    mask is expanded to a per-vertex mask in-trace, so only P bools cross
    the host→device boundary per sweep) contribute exact ⊕-identities;
    padded run slots carry the ``n_pad`` sentinel in ``run_dst`` and are
    dropped by the scatter. Called once over all tiles under device
    residency, and once per streamed chunk (same executable, smaller
    leading axis) under host residency — the scan carry composes exactly.
    """
    T = tiles["src"].shape[-1]
    n_pad = attrs_flat.shape[-1]
    vert_active = jnp.repeat(
        row_active, n_pad // row_active.shape[0], total_repeat_length=n_pad
    )

    def body(carry, tile):
        src = tile["src"]
        dst = tile["dst"]
        run = tile["run_local"]
        run_dst = tile["run_dst"]
        w = tile["weights"] if has_weights else None
        mask = (jnp.arange(T) < tile["e_valid"]) & vert_active[src]

        def one(pv, aq, auxq):
            vals = pv[src]
            s_aux = {
                k: (v[src] if getattr(v, "ndim", 0) == 1 else v)
                for k, v in auxq.items()
            }
            d_aux = (
                {
                    k: (v[dst] if getattr(v, "ndim", 0) == 1 else v)
                    for k, v in auxq.items()
                }
                if program.needs_dst_aux
                else None
            )
            contrib = program.gather(vals, w, s_aux, d_aux)
            ident = reduce_identity(program.reduce, contrib.dtype)
            contrib = jnp.where(mask, contrib, ident)
            if program.reduce == "sum":
                red = jax.ops.segment_sum(contrib, run, num_segments=T)
                return aq.at[run_dst].add(red.astype(aq.dtype), mode="drop")
            if program.reduce == "min":
                red = jax.ops.segment_min(contrib, run, num_segments=T)
                return aq.at[run_dst].min(red.astype(aq.dtype), mode="drop")
            red = jax.ops.segment_max(contrib, run, num_segments=T)
            return aq.at[run_dst].max(red.astype(aq.dtype), mode="drop")

        return (
            jax.vmap(one, in_axes=(0, 0, _aux_axes(aux, aux_batched)))(
                attrs_flat, carry, aux
            ),
            None,
        )

    acc_flat, _ = jax.lax.scan(body, acc_flat, tiles)
    return acc_flat


def _apply_all_impl(
    program: VertexProgram,
    old: jnp.ndarray,  # (K, P, isz)
    acc: jnp.ndarray,  # (K, P, isz) (donatable)
    aux: dict,
    globals_: dict,  # (K,)-leading leaves from _pre_iteration
    valid: jnp.ndarray,  # (P, isz) bool
    tol: jnp.ndarray,
    aux_batched: bool = False,
):
    """All P interval applies of a sweep in one batched dispatch.

    Elementwise identical to P ``_apply_interval`` calls. Untouched
    monotone intervals carry identity accumulators, so their apply is an
    exact no-op and ``changed`` is False — matching the per-block skip.
    """
    K, P, isz = old.shape
    if aux_batched:
        # Per-query aux: (K, n_pad) leaves fold to (K, P, isz) interval
        # rows and map over the query axis alongside the attributes.
        aux2 = {
            k: (v.reshape(K, P, isz) if getattr(v, "ndim", 0) == 2 else v)
            for k, v in aux.items()
        }
        q_axes = {k: 0 for k in aux2}
    else:
        aux2 = _stack_interval_aux(aux, P, isz)
        q_axes = {k: None for k in aux2}

    def per_interval(o, a, auxv, v, gl):
        new = program.apply(o, a, auxv, gl)
        new = jnp.where(v, new, o)
        changed = jnp.any(program.changed(o, new, tol) & v)
        return new, changed

    def per_query(o, a, auxq, gl):
        iv_axes = {
            k: (0 if getattr(v, "ndim", 0) == 2 else None)
            for k, v in auxq.items()
        }
        return jax.vmap(per_interval, in_axes=(0, 0, iv_axes, 0, None))(
            o, a, auxq, valid, gl
        )

    return jax.vmap(per_query, in_axes=(0, 0, q_axes, 0))(
        old, acc, aux2, globals_
    )


@functools.lru_cache(maxsize=None)
def _packed_jits(donate: bool):
    """The two packed-sweep executables, with accumulator donation off-CPU.

    Donation lets XLA reuse the ⊕-accumulator buffer across the scan and
    the apply (the paper's in-place attribute update); the CPU backend
    does not support donation, so it is keyed off to avoid per-compile
    warnings there.
    """
    donate_kw = {"donate_argnums": (2,)} if donate else {}
    sweep = jax.jit(
        _packed_sweep_impl,
        static_argnames=("program", "has_weights", "aux_batched"),
        **donate_kw,
    )
    apply_all = jax.jit(
        _apply_all_impl,
        static_argnames=("program", "aux_batched"),
        **donate_kw,
    )
    return sweep, apply_all


def _packed_sweep_select_impl(
    program: VertexProgram,
    attrs_flat: jnp.ndarray,  # (K, n_pad)
    acc_flat: jnp.ndarray,  # (K, n_pad) (donatable)
    aux: dict,
    tiles: dict,  # (NT, ...) staged tile leaves
    idx: jnp.ndarray,  # (bucket,) int32 active tile indices, 0-padded
    a_valid: jnp.ndarray,  # scalar int32: real entries in idx
    row_active: jnp.ndarray,  # (P,) bool
    has_weights: bool,
    aux_batched: bool = False,
):
    """Compacted active-tile sweep: scan only the gathered tiles.

    ``idx`` holds the active tile indices in ascending order (so the scan
    preserves the full sweep's ascending-source-interval fold order),
    padded with tile 0 to a power-of-two bucket — padding entries are
    neutralized by forcing their ``e_valid`` to 0, which masks every edge
    to an exact ⊕-identity. The gather keeps the scan's tile shape
    static, so jit compiles at most ``log2(NT)`` bucket variants instead
    of one executable per frontier size.
    """
    sel = {k: v[idx] for k, v in tiles.items()}
    keep = jnp.arange(idx.shape[0]) < a_valid
    sel["e_valid"] = jnp.where(keep, sel["e_valid"], 0)
    return _packed_sweep_impl(
        program, attrs_flat, acc_flat, aux, sel, row_active, has_weights,
        aux_batched,
    )


@functools.lru_cache(maxsize=None)
def _packed_select_jits(donate: bool):
    """The compacted-gather sweep executable (selective packed path)."""
    donate_kw = {"donate_argnums": (2,)} if donate else {}
    return jax.jit(
        _packed_sweep_select_impl,
        static_argnames=("program", "has_weights", "aux_batched"),
        **donate_kw,
    )


@functools.lru_cache(maxsize=None)
def _packed_kernel_jits(donate: bool):
    """The fused Pallas sweep executable (``execution="packed_kernel"``).

    Call-signature-identical to ``_packed_jits``'s sweep, so the
    streaming (``_packed_host_sweep``) and slab (``_sweep_tile_slab``)
    drivers run either executable unchanged. The kernel resolves its own
    interpret flag at trace time (compiled on TPU, interpreted
    elsewhere); the batched apply is shared with the scan path.
    """
    from repro.kernels.packed_sweep import packed_sweep_update

    donate_kw = {"donate_argnums": (2,)} if donate else {}

    def _sweep(
        program, attrs_flat, acc_flat, aux, tiles, row_active,
        has_weights, aux_batched=False,
    ):
        return packed_sweep_update(
            program, attrs_flat, acc_flat, aux, tiles, row_active,
            has_weights, aux_batched,
        )

    return jax.jit(
        _sweep,
        static_argnames=("program", "has_weights", "aux_batched"),
        **donate_kw,
    )


@functools.lru_cache(maxsize=None)
def _packed_kernel_select_jits(donate: bool):
    """The compacted-gather fused-kernel executable (selective path)."""
    from repro.kernels.packed_sweep import packed_sweep_update_select

    donate_kw = {"donate_argnums": (2,)} if donate else {}

    def _select(
        program, attrs_flat, acc_flat, aux, tiles, idx, a_valid,
        row_active, has_weights, aux_batched=False,
    ):
        return packed_sweep_update_select(
            program, attrs_flat, acc_flat, aux, tiles, idx, a_valid,
            row_active, has_weights, aux_batched,
        )

    return jax.jit(
        _select,
        static_argnames=("program", "has_weights", "aux_batched"),
        **donate_kw,
    )


# ---------------------------------------------------------------------------
# Per-run context handed to the iteration bodies.
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _RunContext:
    session: "GraphSession"
    program: VertexProgram
    choice: StrategyChoice
    resident: frozenset
    params: IOParams
    aux: dict
    aux_views: list[dict]  # all P interval views, hoisted once per run
    valid: jnp.ndarray  # (P, isize) bool
    tol: jnp.ndarray
    K: int
    residency: str = "device"  # resolved placement ("device" | "host")
    fetcher: _BlockFetcher = None  # type: ignore[assignment]
    activity: str = "off"  # resolved activity ("selective" | "off")
    aux_batched: bool = False  # aux leaves carry a leading (K,) query axis
    execution: str = "per_block"  # resolved execution (never "auto")

    @property
    def block_keys(self) -> frozenset:
        return self.session.block_keys


def _rows_to_process(ctx: _RunContext, active: np.ndarray) -> list[int]:
    """Selective runs skip source intervals inactive for *every* query
    (paper §II-B activity tracking, unioned over the batch axis).

    Resolved per compile: ``"selective"`` iff the program is monotone
    (re-gathering an unchanged source is an exact no-op) and the plan did
    not force ``activity="off"`` — the A/B baseline where every interval
    is processed and every chunk streamed each sweep.
    """
    P = ctx.session.graph.P
    if ctx.activity == "selective":
        return [i for i in range(P) if active[:, i].any()]
    return list(range(P))


def _iteration_spu(ctx: _RunContext, attrs, active, meters: Meters):
    """Paper Algorithm 5: row-major, all intervals ping-pong resident."""
    sess, prog = ctx.session, ctx.program
    g = sess.graph
    isz = g.interval_size
    K = ctx.K
    globals_ = _pre_iteration(
        prog, attrs.reshape(K, -1), ctx.aux, aux_batched=ctx.aux_batched
    )
    ident = reduce_identity(prog.reduce, prog.dtype)
    acc = [jnp.full((K, isz), ident, prog.dtype) for _ in range(g.P)]
    touched = [False] * g.P
    rows = _rows_to_process(ctx, active)
    order = [
        (i, j) for i in rows for j in range(g.P) if (i, j) in ctx.block_keys
    ]
    fetch = ctx.fetcher.begin(order)
    for i, j in order:
        blk = fetch()
        acc[j] = _block_gather_reduce(
            prog,
            attrs[:, i],
            ctx.aux_views[i],
            ctx.aux_views[j] if prog.needs_dst_aux else {},
            blk["src_local"],
            blk["dst_local"],
            blk["weights"],
            blk["e_valid"],
            acc[j],
            num_segments=isz,
            has_weights=sess.has_weights,
            aux_batched=ctx.aux_batched,
        )
        touched[j] = True
        meters.blocks_processed += 1
        meters.edges_processed += blk["e"]
    meters.blocks_skipped += (g.P - len(rows)) * g.P
    new_cols = []
    active_next = np.zeros((K, g.P), dtype=bool)
    for j in range(g.P):
        if not touched[j] and prog.monotone:
            new_cols.append(attrs[:, j])
            continue
        new_j, changed = _apply_interval(
            prog, attrs[:, j], acc[j], ctx.aux_views[j], globals_,
            ctx.valid[j], ctx.tol, aux_batched=ctx.aux_batched,
        )
        new_cols.append(new_j)
        active_next[:, j] = np.asarray(changed)
    return jnp.stack(new_cols, axis=1), active_next


def _iteration_two_phase(ctx: _RunContext, attrs, active, meters: Meters, Q: int):
    """Paper Algorithms 6 (Q=0: DPU) and 7 (0<Q<P: MPU).

    Intervals < Q are ping-pong resident (SPU-like); intervals >= Q are
    cold: their contributions route through hubs and they are loaded/saved
    once per iteration. Interval and hub bytes are charged per query (K×):
    each query owns its attribute state, while the edge stream is shared.
    """
    sess, prog = ctx.session, ctx.program
    g = sess.graph
    isz = g.interval_size
    K = ctx.K
    globals_ = _pre_iteration(
        prog, attrs.reshape(K, -1), ctx.aux, aux_batched=ctx.aux_batched
    )
    ident = reduce_identity(prog.reduce, prog.dtype)
    acc = [jnp.full((K, isz), ident, prog.dtype) for _ in range(g.P)]
    touched = [False] * g.P
    # Hub state between the phases: (partial, hub_dst, u_valid, u). Keeping
    # the (small) hub metadata here means phase 2 never re-touches the edge
    # block — each sub-shard is fetched exactly once per sweep.
    hubs: dict[tuple[int, int], tuple] = {}
    rows = _rows_to_process(ctx, active)
    iv_bytes = isz * ctx.params.Ba * K

    # Every sub-shard is visited once: (j < Q or i >= Q) blocks in the
    # row-major phase, deferred (i < Q, j >= Q) blocks in the column-major
    # phase. Declaring the order up front drives the streaming prefetch.
    phase1 = [
        (i, j)
        for i in rows
        for j in range(g.P)
        if (j < Q or i >= Q) and (i, j) in ctx.block_keys
    ]
    phase2 = [
        (i, j)
        for j in range(g.P)
        if j >= Q
        for i in rows
        if i < Q and (i, j) in ctx.block_keys
    ]
    fetch = ctx.fetcher.begin(phase1 + phase2)

    def _direct(i: int, j: int, blk: dict) -> None:
        """UpdateInMemory (paper Alg. 7 lines 4, 10, 20)."""
        acc[j] = _block_gather_reduce(
            prog,
            attrs[:, i],
            ctx.aux_views[i],
            ctx.aux_views[j] if prog.needs_dst_aux else {},
            blk["src_local"],
            blk["dst_local"],
            blk["weights"],
            blk["e_valid"],
            acc[j],
            num_segments=isz,
            has_weights=sess.has_weights,
            aux_batched=ctx.aux_batched,
        )
        touched[j] = True
        meters.blocks_processed += 1
        meters.edges_processed += blk["e"]

    # Phase 1 (row-major): resident rows (i < Q) update resident
    # destinations (j < Q); cold rows (i >= Q) are loaded once, updating
    # resident destinations directly and cold destinations via ToHub.
    # Blocks (i < Q, j >= Q) are deferred to the column phase so that
    # only one cold accumulator is ever live (paper Alg. 7 lines 17-24).
    for i in rows:
        if i >= Q:
            meters.bytes_read_intervals += iv_bytes  # LoadFromDisk(I_i)
        for j in range(g.P):
            if (i, j) not in ctx.block_keys or not (j < Q or i >= Q):
                continue
            blk = fetch()
            if j < Q:
                _direct(i, j, blk)
            else:
                # UpdateToHub (cold source AND cold destination).
                partial = _block_to_hub(
                    prog,
                    attrs[:, i],
                    ctx.aux_views[i],
                    ctx.aux_views[j] if prog.needs_dst_aux else {},
                    blk["src_local"],
                    blk["hub_inv"],
                    blk["dst_local"],
                    blk["weights"],
                    blk["e_valid"],
                    num_segments=blk["u_bucket"],
                    has_weights=sess.has_weights,
                    aux_batched=ctx.aux_batched,
                )
                hubs[(i, j)] = (partial, blk["hub_dst"], blk["u_valid"], blk["u"])
                touched[j] = True
                meters.bytes_written_hubs += blk["u"] * (
                    ctx.params.Ba + sess.Bv
                ) * K
                meters.blocks_processed += 1
                meters.edges_processed += blk["e"]
    meters.blocks_skipped += (g.P - len(rows)) * g.P

    # Phase 2 (column-major): resident columns apply directly; cold
    # columns first take deferred resident-source blocks, then fold hubs,
    # then save (paper Alg. 6 lines 8-14 / Alg. 7 lines 17-26).
    new_cols: list[jnp.ndarray] = [None] * g.P  # type: ignore[list-item]
    active_next = np.zeros((K, g.P), dtype=bool)
    for j in range(g.P):
        if j >= Q:
            for i in rows:
                if i < Q and (i, j) in ctx.block_keys:
                    _direct(i, j, fetch())
            for i in rows:
                h = hubs.get((i, j))
                if h is None:
                    continue
                partial, hub_dst, u_valid, u = h
                acc[j] = _block_from_hub(prog, acc[j], hub_dst, partial, u_valid)
                meters.bytes_read_hubs += u * (ctx.params.Ba + sess.Bv) * K
        if not touched[j] and prog.monotone:
            new_cols[j] = attrs[:, j]
            continue
        if j >= Q and prog.monotone:
            # Monotone apply needs the previous attributes of a cold
            # interval — one extra interval read vs. the paper's
            # PageRank-style accounting (documented deviation).
            meters.bytes_read_intervals += iv_bytes
        new_j, changed = _apply_interval(
            prog, attrs[:, j], acc[j], ctx.aux_views[j], globals_,
            ctx.valid[j], ctx.tol, aux_batched=ctx.aux_batched,
        )
        new_cols[j] = new_j
        active_next[:, j] = np.asarray(changed)
        if j >= Q:
            meters.bytes_written_intervals += iv_bytes  # SaveToDisk(I_j)
    return jnp.stack(new_cols, axis=1), active_next


def _iteration_dpu(ctx, attrs, active, meters):
    return _iteration_two_phase(ctx, attrs, active, meters, Q=0)


def _iteration_mpu(ctx, attrs, active, meters):
    return _iteration_two_phase(ctx, attrs, active, meters, Q=ctx.choice.Q)


def _iteration_fused(ctx: _RunContext, attrs, active, meters: Meters):
    """One XLA program per iteration: global gather + segment-reduce.

    Produces bit-identical results to SPU for sum/min/max programs; this
    is the TPU-native fast path (HBM-resident, no host scheduling) and
    the baseline the Pallas kernel (kernels/dsss_spmv.py) is checked
    against.
    """
    sess, prog = ctx.session, ctx.program
    g = sess.graph
    K = ctx.K
    fa = sess.fused_arrays()
    flat, changed_iv = _fused_iteration(
        prog,
        attrs.reshape(K, -1),
        ctx.aux,
        fa["src"],
        fa["dst"],
        fa["weights"],
        ctx.valid.reshape(-1),
        ctx.tol,
        n_pad=g.n_pad,
        P=g.P,
        has_weights=sess.has_weights,
        aux_batched=ctx.aux_batched,
    )
    meters.blocks_processed += len(sess.block_keys)
    meters.edges_processed += g.m
    return flat.reshape(K, g.P, g.interval_size), np.asarray(changed_iv)


# ---------------------------------------------------------------------------
# Packed execution: the same SPU/DPU/MPU schedules, one compiled sweep.
# The numeric pass is strategy-independent (every schedule folds each
# destination interval in ascending source-interval order — see
# repro.core.dsss.PackedSweep); what distinguishes the strategies is their
# slow-tier traffic, which is charged here from the packed metadata with
# exactly the control flow of the per-block bodies.
# ---------------------------------------------------------------------------
def _charge_packed_spu(ctx: _RunContext, rows: list[int], meters: Meters) -> None:
    """Meter mutations of ``_iteration_spu``, from metadata alone."""
    sess = ctx.session
    g = sess.graph
    host = sess.host_blocks
    Be = sess.Be
    for i in rows:
        for j in range(g.P):
            if (i, j) not in ctx.block_keys:
                continue
            e = host[(i, j)]["e"]
            if (i, j) not in ctx.resident:
                meters.bytes_read_edges += e * Be
            meters.blocks_processed += 1
            meters.edges_processed += e
    meters.blocks_skipped += (g.P - len(rows)) * g.P


def _charge_packed_two_phase(
    ctx: _RunContext, rows: list[int], meters: Meters, Q: int
) -> None:
    """Meter mutations of ``_iteration_two_phase``, from metadata alone.

    Mirrors the two-phase control flow line for line — phase-1 direct and
    ToHub charges, deferred phase-2 direct blocks, hub folds, interval
    load/saves and the documented monotone cold-interval re-read — so the
    packed run's Meters are field-for-field identical to the per-block
    run's.
    """
    sess, prog = ctx.session, ctx.program
    g = sess.graph
    host = sess.host_blocks
    Be = sess.Be
    K = ctx.K
    iv_bytes = g.interval_size * ctx.params.Ba * K
    hub_bytes = ctx.params.Ba + sess.Bv
    touched = [False] * g.P
    hub_u: dict[tuple[int, int], int] = {}
    # Phase 1 (row-major): direct blocks (j < Q) and ToHub blocks (i >= Q,
    # j >= Q); cold source intervals load once.
    for i in rows:
        if i >= Q:
            meters.bytes_read_intervals += iv_bytes
        for j in range(g.P):
            if (i, j) not in ctx.block_keys or not (j < Q or i >= Q):
                continue
            e = host[(i, j)]["e"]
            if (i, j) not in ctx.resident:
                meters.bytes_read_edges += e * Be
            if j >= Q:
                u = host[(i, j)]["u"]
                hub_u[(i, j)] = u
                meters.bytes_written_hubs += u * hub_bytes * K
            touched[j] = True
            meters.blocks_processed += 1
            meters.edges_processed += e
    meters.blocks_skipped += (g.P - len(rows)) * g.P
    # Phase 2 (column-major): deferred (i < Q, j >= Q) direct blocks, hub
    # folds, then the cold-interval apply traffic.
    for j in range(g.P):
        if j >= Q:
            for i in rows:
                if i < Q and (i, j) in ctx.block_keys:
                    e = host[(i, j)]["e"]
                    if (i, j) not in ctx.resident:
                        meters.bytes_read_edges += e * Be
                    meters.blocks_processed += 1
                    meters.edges_processed += e
                    touched[j] = True
            for i in rows:
                u = hub_u.get((i, j))
                if u is not None:
                    meters.bytes_read_hubs += u * hub_bytes * K
        if not touched[j] and prog.monotone:
            continue
        if j >= Q and prog.monotone:
            # Monotone apply re-reads the cold interval's previous
            # attributes (documented deviation, as in the per-block path).
            meters.bytes_read_intervals += iv_bytes
        if j >= Q:
            meters.bytes_written_intervals += iv_bytes
    return None


def _packed_host_chunk(packed, lo: int, hi: int, has_weights: bool) -> dict:
    """Host (numpy) views of tiles [lo, hi) in the streaming leaf schema."""
    chunk = {
        "src": packed.src[lo:hi],
        "dst": packed.dst[lo:hi],
        "run_local": packed.run_local[lo:hi],
        "run_dst": packed.run_dst[lo:hi],
        "e_valid": packed.e_valid[lo:hi],
    }
    if has_weights:
        chunk["weights"] = packed.weights[lo:hi]
    return chunk


def _chunk_nbytes(chunk: dict) -> int:
    return sum(a.nbytes for a in chunk.values())


def _sweep_tile_slab(
    ctx: _RunContext, attrs_flat, acc, tiles, row_active, sweep, window
):
    """Run the packed scan over one staged tile slab, compacted to ``window``.

    ``tiles`` is a dict of device leaves with leading axis ``len(window)``
    (the full staged layout, or the pinned prefix). ``window=None`` (full
    sweep) and an all-True window use the plain scan — the exact
    executable the ``activity="off"`` baseline runs; a partial window
    gathers the active tiles into a power-of-two bucket and runs the
    compacted scan (≤ log2(NT) jit variants); an all-False window is a
    pure no-op. ``np.flatnonzero`` keeps the gathered tiles in ascending
    order, preserving the full sweep's fold order — bit-identity.
    """
    sess, prog = ctx.session, ctx.program
    hw = sess.has_weights
    if window is None or window.all():
        return sweep(
            prog, attrs_flat, acc, ctx.aux, tiles, row_active,
            has_weights=hw, aux_batched=ctx.aux_batched,
        )
    local = np.flatnonzero(window)
    if local.size == 0:
        return acc
    count = int(window.shape[0])
    bucket = min(next_bucket(int(local.size)), count)
    idx = np.zeros(bucket, np.int32)
    idx[: local.size] = local
    select_jits = (
        _packed_kernel_select_jits
        if ctx.execution == "packed_kernel"
        else _packed_select_jits
    )
    select = select_jits(jax.default_backend() != "cpu")
    return select(
        prog, attrs_flat, acc, ctx.aux, tiles,
        jnp.asarray(idx), jnp.asarray(np.int32(local.size)), row_active,
        has_weights=hw, aux_batched=ctx.aux_batched,
    )


def _packed_host_sweep(
    ctx: _RunContext, attrs_flat, acc, row_active, meters: Meters, sweep,
    tile_active=None,
):
    """Host-resident packed execution: stream tile chunks through the scan.

    The pinned tile prefix (what the memory budget keeps device-resident,
    see :meth:`GraphSession.packed_stream_plan`) runs first from its staged
    device arrays; the remaining tiles are cut into fixed chunks and
    streamed host→device with the same double-buffered discipline as
    :class:`_BlockFetcher` — while chunk ``c`` computes, chunk ``c+1``'s
    transfer is already in flight (``jax.device_put`` is async). Each
    streamed chunk charges its raw padded bytes to ``bytes_h2d`` and its
    real-edge model bytes to the ``peak_device_graph_bytes`` high-water
    mark (pinned prefix + at most two in-flight chunks). The *model* byte
    meters are charged from metadata exactly as under device residency —
    physical streaming never changes them.

    ``residency="disk"`` runs the same loop over mmap-backed tile arrays:
    chunks inside the ``host_memory_budget``-cached window (the plan's
    ``host_tiles``) are served from materialized RAM copies, every other
    chunk is sliced straight out of the file and additionally charges its
    raw bytes to ``bytes_disk_read`` — the ``packed_disk_bytes`` closed
    form.

    ``tile_active`` (selective execution) restricts the physical stream
    to the frontier: chunks containing no active tile are never fetched —
    no transfer, no ``bytes_h2d``/``bytes_disk_read`` charge — and the
    pinned prefix runs compacted to its active tiles. The closed forms
    gain the same activity term via
    :func:`repro.core.iomodel.selective_streamed_tiles`, keeping
    measured-vs-modelled equality exact.
    """
    sess, prog = ctx.session, ctx.program
    packed = sess._staged.packed_host(sess.packing)
    splan = sess.packed_stream_plan(ctx.choice.strategy, ctx.params.Ba)
    hw = sess.has_weights
    disk = ctx.residency == "disk"
    cache_end = splan.pin_tiles + splan.host_tiles
    pins, pin_model = sess._ensure_packed_pins(splan.pin_tiles)
    meters.peak_device_graph_bytes = max(
        meters.peak_device_graph_bytes, pin_model
    )
    if pins is not None:
        acc = _sweep_tile_slab(
            ctx, attrs_flat, acc, pins, row_active, sweep,
            None if tile_active is None else tile_active[: splan.pin_tiles],
        )
    nt = packed.num_tiles
    if splan.pin_tiles >= nt:
        return acc
    Be = sess.Be
    starts = [
        lo
        for lo in range(splan.pin_tiles, nt, splan.chunk_tiles)
        if tile_active is None
        or tile_active[lo : min(lo + splan.chunk_tiles, nt)].any()
    ]
    if not starts:
        return acc

    def fetch(idx: int) -> tuple[dict, Any, float, bool]:
        lo = starts[idx]
        hi = min(lo + splan.chunk_tiles, nt)
        cached = disk and hi <= cache_end
        if cached:
            host = sess._packed_ram_chunk(lo, hi)
        else:
            host = _packed_host_chunk(packed, lo, hi, hw)
        model = float(packed.e_valid[lo:hi].sum()) * Be
        # The chunk transfer is the packed path's "h2d" injection
        # boundary; transient faults retry in place (see
        # _BlockFetcher._upload for the discipline).
        dev = with_transient_retries(
            sess._injector, f"chunk:{lo}", lambda: jax.device_put(host)
        )
        return host, dev, model, cached

    cur = fetch(0)
    for idx in range(len(starts)):
        nxt = fetch(idx + 1) if idx + 1 < len(starts) else None
        host, dev, model, cached = cur
        nb = _chunk_nbytes(host)
        meters.bytes_h2d += nb
        _OBS_H2D.inc(nb)
        if disk and not cached:
            meters.bytes_disk_read += nb
            _OBS_DISK.inc(nb)
        live = pin_model + model + (nxt[2] if nxt is not None else 0.0)
        meters.peak_device_graph_bytes = max(
            meters.peak_device_graph_bytes, live
        )
        acc = sweep(
            prog, attrs_flat, acc, ctx.aux, dev, row_active,
            has_weights=hw, aux_batched=ctx.aux_batched,
        )
        cur = nxt
    return acc


def _iteration_packed(ctx: _RunContext, attrs, active, meters: Meters):
    """One update sweep as ~4 XLA dispatches, for any of SPU/DPU/MPU.

    pre-iteration globals → one accumulator init → one scan over the
    packed tiles (or one per streamed tile chunk under host residency) →
    one batched apply. The per-strategy slow-tier meters are charged from
    the packed metadata before the compiled pass runs.
    """
    sess, prog = ctx.session, ctx.program
    g = sess.graph
    K = ctx.K
    strategy = ctx.choice.strategy
    rows = _rows_to_process(ctx, active)
    if strategy == "spu":
        _charge_packed_spu(ctx, rows, meters)
    else:
        _charge_packed_two_phase(
            ctx, rows, meters, Q=0 if strategy == "dpu" else ctx.choice.Q
        )
    globals_ = _pre_iteration(
        prog, attrs.reshape(K, -1), ctx.aux, aux_batched=ctx.aux_batched
    )
    ident = reduce_identity(prog.reduce, prog.dtype)
    attrs_flat = attrs.reshape(K, g.n_pad)
    acc = jnp.full((K, g.n_pad), ident, prog.dtype)
    row_mask = np.zeros(g.P, dtype=bool)
    row_mask[rows] = True
    row_active = jnp.asarray(row_mask)
    # Selective execution: map the interval frontier onto the tile axis
    # (a tile is active iff any source interval in its span is) and run
    # the sweep compacted to active tiles / active streamed chunks. A
    # full frontier short-circuits to the plain sweep — the same
    # executable as activity="off".
    selective = ctx.activity == "selective" and not row_mask.all()
    tile_active = sess._packed_tile_activity(row_mask) if selective else None
    sweep, apply_all = _packed_jits(jax.default_backend() != "cpu")
    if ctx.execution == "packed_kernel":
        # Same streaming/selective drivers, fused-kernel sweep executable
        # (the batched apply is shared — it is already one dispatch).
        sweep = _packed_kernel_jits(jax.default_backend() != "cpu")
    if ctx.residency in ("host", "disk"):
        acc = _packed_host_sweep(
            ctx, attrs_flat, acc, row_active, meters, sweep, tile_active
        )
    else:
        tiles = sess._staged.packed_tiles(sess.packing)
        acc = _sweep_tile_slab(
            ctx, attrs_flat, acc, tiles, row_active, sweep, tile_active
        )
    acc = acc.reshape(K, g.P, g.interval_size)
    new, changed = apply_all(
        prog, attrs, acc, ctx.aux, globals_, ctx.valid, ctx.tol,
        aux_batched=ctx.aux_batched,
    )
    return new, np.asarray(changed)


def _batch_aux(prog: VertexProgram, g, kwargs_list: list[dict]) -> tuple[dict, bool]:
    """Build the batch's aux dict: shared, or vmap-stacked per query.

    When every query's ``make_aux`` output is identical (the common case —
    BFS roots and SSSP sources don't enter aux), the shared dict is
    returned with ``aux_batched=False`` and broadcasts across the batch
    exactly as before. When they differ but are stackable (same keys,
    shapes and dtypes — e.g. a batch of ``MaxLabelForward`` plans with
    different masks), every leaf is stacked with a leading ``(K,)`` query
    axis and ``aux_batched=True`` tells the primitives to vmap over it.
    Aux dicts that cannot be stacked raise :class:`TypeError` — silently
    applying query 0's aux to all K (the old behaviour) produced wrong
    results for queries 1..K-1.
    """
    aux_list = [prog.make_aux(g, **kw) for kw in kwargs_list]
    aux0 = aux_list[0]
    if len(aux_list) == 1:
        return aux0, False
    identical = True
    for aux in aux_list[1:]:
        if set(aux) != set(aux0):
            raise TypeError(
                f"aux-incompatible batch for program {prog.name!r}: queries "
                f"produced different aux keys ({sorted(aux0)} vs "
                f"{sorted(aux)}); run these plans individually"
            )
        for k in aux0:
            a, b = np.asarray(aux[k]), np.asarray(aux0[k])
            if a.shape != b.shape or a.dtype != b.dtype:
                raise TypeError(
                    f"aux-incompatible batch for program {prog.name!r}: "
                    f"leaf {k!r} differs in shape/dtype across queries "
                    f"({b.shape}/{b.dtype} vs {a.shape}/{a.dtype}); run "
                    "these plans individually"
                )
            if identical and not np.array_equal(a, b):
                identical = False
    if identical:
        return aux0, False
    stacked = {
        k: jnp.stack([jnp.asarray(a[k]) for a in aux_list]) for k in aux0
    }
    return stacked, True


# ---------------------------------------------------------------------------
# The session.
# ---------------------------------------------------------------------------
def _device_block(host: dict) -> dict:
    """Upload one padded host block (the 'shard file') to the device."""
    return {
        "src_local": jnp.asarray(host["src_local"], jnp.int32),
        "dst_local": jnp.asarray(host["dst_local"], jnp.int32),
        "hub_inv": jnp.asarray(host["hub_inv"], jnp.int32),
        "hub_dst": jnp.asarray(host["hub_dst"], jnp.int32),
        "e_valid": jnp.asarray(host["e"], jnp.int32),
        "u_valid": jnp.asarray(host["u"], jnp.int32),
        "e": host["e"],
        "u": host["u"],
        "u_bucket": host["u_bucket"],
        "weights": (
            None
            if host["weights"] is None
            else jnp.asarray(host["weights"], jnp.float32)
        ),
    }


def _host_block_nbytes(host: dict) -> int:
    """Raw bytes a host→device copy of this block actually ships."""
    total = 0
    for name in ("src_local", "dst_local", "hub_inv", "hub_dst", "weights"):
        arr = host.get(name)
        if arr is not None:
            total += arr.nbytes
    return total


class _StagedGraph:
    """Staged arrays that are a pure function of the graph.

    Shared between every :class:`GraphSession` variant of one graph (e.g.
    different memory budgets / residency modes). The *host* blocks — padded
    numpy sub-shard buffers, the in-memory equivalent of the paper's shard
    files — are built eagerly once; the full *device* mirror is staged
    lazily, only when a device-resident session first needs it, so
    host-streamed sessions never upload the whole graph.

    A disk-backed staging (``store`` given — a
    :class:`repro.storage.format.DSSSStore`) takes this one tier lower:
    the host block dict and the packed sweep become read-only **mmap
    views** of the ``.dsss`` file's block/tile segments, so building the
    staging allocates nothing edge-scale and the fetch layer pages data
    in straight from disk.
    """

    def __init__(self, graph: DSSSGraph, store=None):
        self.graph = graph
        self.store = store
        self.host_blocks = (
            store.host_blocks() if store is not None else graph.host_blocks()
        )
        self.block_keys = frozenset(self.host_blocks)
        self._device_blocks: dict[tuple[int, int], dict] | None = None
        self._packed_host: dict[str, Any] = {}  # packing mode -> PackedSweep
        self._packed_tiles: dict[str, dict] = {}  # packing mode -> device leaves
        self._packed_spans: dict[str, tuple] = {}  # mode -> (first_i, last_i)
        self.fused: dict | None = None
        self.kernel_operands: dict[tuple, tuple] = {}

    def device_blocks(self) -> dict[tuple[int, int], dict]:
        """The all-on-device block dict (staged once, residency="device")."""
        if self._device_blocks is None:
            with _TRACER.span(
                "stage_device_blocks", cat="staging",
                blocks=len(self.host_blocks),
            ):
                self._device_blocks = {
                    key: _device_block(host)
                    for key, host in self.host_blocks.items()
                }
        return self._device_blocks

    def packed_host(self, mode: str):
        """The host-side :class:`~repro.core.dsss.PackedSweep`, built once.

        This is the streaming source of truth under host residency (tile
        chunks are sliced straight out of these numpy arrays) and the
        metadata source for meters, stream planning and tests. Disk-backed
        stagings return the store's mmap'd tile section when its packing
        mode matches (a stored graph skips repacking); other modes fall
        back to an in-memory repack of the (mmap-backed) flat arrays.
        """
        packed = self._packed_host.get(mode)
        if packed is None:
            if self.store is not None:
                stored = self.store.packed()
                if stored is not None and stored.mode == mode:
                    packed = stored
            if packed is None:
                with _TRACER.span(
                    "stage_packed_host", cat="staging", mode=mode
                ):
                    packed = self.graph.packed_sweep(mode)
            self._packed_host[mode] = packed
        return packed

    def packed_tiles(self, mode: str) -> dict:
        """Device arrays of the tile-packed sweep layout, staged once.

        The scan carries exactly these leaves per tile (global endpoint
        ids, windowed run slots, the run→destination scatter map, weights
        when present, and the valid edge count); per-tile metadata
        (``base_slot``/``u``/``row_offset``/intervals) stays host-side on
        the :class:`~repro.core.dsss.PackedSweep` for meter accounting,
        stream planning and kernel-path consumers. Packed device mode
        never stages the per-block device mirror — these arrays *are* the
        device topology.
        """
        tiles = self._packed_tiles.get(mode)
        if tiles is None:
            from repro.kernels.ops import prepare_packed_tiles

            packed = self.packed_host(mode)
            with _TRACER.span(
                "stage_packed_tiles", cat="staging",
                mode=mode, tiles=int(packed.num_tiles),
            ):
                tiles = prepare_packed_tiles(
                    packed, has_weights=packed.weights is not None
                )
            self._packed_tiles[mode] = tiles
        return tiles

    def packed_spans(self, mode: str) -> tuple:
        """Per-tile inclusive source-interval spans, computed once.

        The ``(first_i, last_i)`` arrays of
        :func:`repro.core.dsss.tile_source_spans` — the host-side
        metadata selective execution folds the (P,) interval frontier
        onto the tile axis with, each sweep, in O(P + NT).
        """
        spans = self._packed_spans.get(mode)
        if spans is None:
            spans = tile_source_spans(
                self.packed_host(mode), self.graph.interval_size
            )
            self._packed_spans[mode] = spans
        return spans


class _BlockFetcher:
    """Per-run edge-block access layer — the enforcement point of residency.

    Every schedule body obtains sub-shard blocks exclusively through this
    object, in its declared sweep order, so edge byte meters are charged
    where the data actually moves instead of being recomputed per strategy:

    * ``residency="device"``: blocks come from the staged device mirror;
      a fetch of a key outside the resident set charges ``e·Be`` model
      bytes (the simulated slow tier — seed behaviour, unchanged).
    * ``residency="host"``: only the resident set is device-pinned.
      Fetching any other key performs a real host→device copy of the
      pinned host buffer, double-buffered: while block t computes, block
      t+1's transfer is already in flight (``jax.device_put`` is async).
      The charge is the same ``e·Be`` — it now *is* the transfer — and
      ``bytes_h2d`` additionally records the raw padded bytes shipped.
    * ``residency="disk"``: identical streaming discipline, but the host
      buffers are mmap views of the ``.dsss`` store. A fetch of a block
      that is neither device-pinned nor in the ``host_memory_budget``'s
      RAM cache touches the file and charges its raw padded bytes to
      ``bytes_disk_read`` at this — the mmap-fetch — layer; RAM-cached
      blocks are served from materialized copies free of disk charge.
      The model meters are charged exactly as under "host", so the
      modelled contract is residency-invariant.

    The streaming ring holds at most one prefetched block beyond the one
    in use, so peak device topology bytes stay ≤ resident + 2 blocks.
    """

    def __init__(
        self,
        session: "GraphSession",
        compiled: CompiledPlan,
        meters: Meters,
        pinned: dict[tuple[int, int], dict],
    ):
        self._session = session
        self._inj = session._injector
        self._resident = compiled.resident
        self._host_mode = compiled.residency in ("host", "disk")
        self._disk_mode = compiled.residency == "disk"
        self._host_cached = compiled.host_cached
        self._meters = meters
        self._pinned = pinned
        self._ring: dict[tuple[int, int], dict] = {}
        self._order: list[tuple[int, int]] = []
        self._pos = 0
        Be = session.Be
        host = session._staged.host_blocks
        self._model_bytes = {k: h["e"] * Be for k, h in host.items()}
        if self._host_mode:
            self._pinned_model = float(
                sum(self._model_bytes[k] for k in pinned)
            )
            # The pinned resident set occupies the device for the whole
            # run, whether or not any block is streamed on top of it.
            meters.peak_device_graph_bytes = max(
                meters.peak_device_graph_bytes, self._pinned_model
            )
        else:
            # Everything is device-resident: the high-water mark is the
            # whole staged topology, reported once up front.
            total = float(sum(self._model_bytes.values()))
            meters.peak_device_graph_bytes = max(
                meters.peak_device_graph_bytes, total
            )

    def begin(self, order: list[tuple[int, int]]) -> Callable[[], dict]:
        """Declare this sweep's block order; returns the sequential fetch.

        The first streamed block's transfer is issued immediately so the
        sweep starts with its double buffer warm.
        """
        self._order = order
        self._pos = 0
        if self._host_mode and order:
            self._prefetch(order[0])
        return self._next

    def _host_source(self, key: tuple[int, int]) -> dict:
        """The host-side buffers a streamed fetch ships — and the disk
        charge, levied exactly where the mmap pages are touched."""
        if self._disk_mode:
            if key in self._host_cached:
                return self._session._host_cache_block(key)
            host = self._session._staged.host_blocks[key]
            nb = _host_block_nbytes(host)
            self._meters.bytes_disk_read += nb
            _OBS_DISK.inc(nb)
            return host
        return self._session._staged.host_blocks[key]

    def _upload(self, key: tuple[int, int], host: dict) -> dict:
        """One host→device block transfer — the "h2d" injection boundary.

        Injected transient faults are retried in place (bounded, with
        backoff) so rate-based fault plans heal at the I/O layer; only a
        fault burst deeper than the retry budget escapes to the caller
        (where serving-level retry takes over). The ``bytes_h2d`` charge
        lands after success, so meters are identical however many retries
        it took.
        """
        blk = with_transient_retries(
            self._inj, f"block:{key[0]},{key[1]}", lambda: _device_block(host)
        )
        nb = _host_block_nbytes(host)
        self._meters.bytes_h2d += nb
        _OBS_H2D.inc(nb)
        return blk

    def _prefetch(self, key: tuple[int, int]) -> None:
        if key in self._pinned or key in self._ring:
            return
        self._ring[key] = self._upload(key, self._host_source(key))

    def _next(self) -> dict:
        key = self._order[self._pos]
        self._pos += 1
        if not self._host_mode:
            if key not in self._resident:
                self._meters.bytes_read_edges += self._model_bytes[key]
            return self._session._staged.device_blocks()[key]
        blk = self._pinned.get(key)
        if blk is not None:
            if self._pos < len(self._order):
                self._prefetch(self._order[self._pos])
            return blk
        blk = self._ring.pop(key, None)
        if blk is None:  # cold start / out-of-order access
            blk = self._upload(key, self._host_source(key))
        if self._pos < len(self._order):
            self._prefetch(self._order[self._pos])
        self._meters.bytes_read_edges += self._model_bytes[key]
        live = (
            self._pinned_model
            + self._model_bytes[key]
            + sum(self._model_bytes[k] for k in self._ring)
        )
        self._meters.peak_device_graph_bytes = max(
            self._meters.peak_device_graph_bytes, live
        )
        return blk


class GraphSession:
    """Staged graph state shared by every run.

    Args:
      graph: sharded :class:`DSSSGraph`.
      memory_budget: bytes of fast-tier memory (B_M). ``None`` = unlimited.
      residency: where sub-shard edge blocks live between sweeps.

        * ``"device"`` — every block is staged to the device once (the
          seed behaviour). ``memory_budget`` only parameterizes the
          *modelled* byte meters and the adaptive strategy choice.
        * ``"host"`` — the budget is **enforced**: only the resident set
          that :meth:`_resolve_residency` computes from ``memory_budget``
          is device-pinned; every other block stays a pinned host (numpy)
          buffer and is streamed to the device per sweep with
          double-buffered prefetch, in the schedule's sequential sub-shard
          order. Results are bit-identical to ``"device"`` and the
          modelled byte meters are unchanged — they now coincide with the
          real transfers (``Meters.bytes_h2d`` reports the raw bytes).
          Vertex-attribute state (``2·n_pad·Ba``) and hub state remain
          fast-tier resident; their slow-tier traffic under DPU/MPU
          remains modelled, as in the paper. The ``"fused"`` strategy is
          the explicitly device-resident fast path and ignores residency.
        * ``"disk"`` — the third tier (disk-backed sessions only; open
          one with :meth:`GraphSession.open`): host blocks and packed
          tiles are mmap views of a ``.dsss`` store, streamed
          disk→device by the same machinery as ``"host"``. The
          three-level budget applies: ``memory_budget`` pins device
          topology exactly as under "host", ``host_memory_budget``
          bounds a RAM cache of blocks / tile chunks (in streaming
          order, after the device pins; ``None`` caches everything), and
          every fetch outside both charges ``Meters.bytes_disk_read`` at
          the mmap layer. Results are bit-identical and the model meters
          field-identical to the other residencies.
        * ``"auto"`` — ``"disk"`` for disk-backed sessions; otherwise
          ``"host"`` when a ``memory_budget`` is set, ``"device"``
          otherwise (an unlimited budget pins everything, making the two
          modes identical).

      execution: how the SPU/DPU/MPU schedules drive the device.

        * ``"per_block"`` — the host-scheduled legacy path: one jit
          dispatch per sub-shard through :class:`_BlockFetcher` (O(P²)
          host round-trips per sweep). Always used for custom/fused
          strategies.
        * ``"packed"`` — the compiled sweep path: the
          :class:`repro.core.dsss.PackedSweep` tile layout is staged once
          and every update sweep runs as one ``lax.scan`` + one batched
          apply (~4 dispatches per sweep, independent of P). Under host
          residency the tile stream is chunked and streamed host→device
          with double-buffered prefetch (see
          :meth:`packed_stream_plan`) instead of staging — packed
          execution no longer downgrades out-of-core. Bit-identical
          results and field-for-field identical *model* meters either
          way (``bytes_h2d``/``peak_device_graph_bytes`` report the
          physical transfers of whichever path ran). Custom and fused
          schedules downgrade to ``"per_block"`` (they own their loop).
        * ``"packed_kernel"`` — the fused-kernel path: the same staged
          tile layout, but the sweep's gather→combine→run-reduce→
          hub-scatter runs inside one Pallas kernel
          (:func:`repro.kernels.packed_sweep.packed_sweep_update`) that
          grids over the tile axis with BlockSpec-pipelined HBM→VMEM
          tile DMA. Streaming, selective compaction, batching and every
          meter work exactly as under ``"packed"`` — only the sweep
          executable differs; results are bit-identical and model
          meters field-identical by construction (and by the parity
          suite). Off-TPU backends run the kernel in interpret mode
          (slow — validation only). Downgrades like ``"packed"`` for
          custom/fused schedules.
        * ``"auto"`` (default) — ``"packed_kernel"`` wherever packed
          applies *and* the jax backend compiles Pallas natively (TPU);
          ``"packed"`` elsewhere (an interpret-mode kernel would be a
          de-optimization), ``"per_block"`` where neither applies.

      packing: tile layout for the packed path — ``"adaptive"``
        (destination-aligned fixed-size tiles, chosen per graph to bound
        padding; the default for DSSS layouts), ``"subshard"`` (legacy
        one-tile-per-largest-sub-shard; forced for ``src_sorted`` graphs,
        whose scrambled destination runs only whole-sub-shard windows
        reduce correctly), or ``"auto"``.

      Be: bytes per edge in the I/O model (8 = two int32 ids; +4 is added
        automatically for weighted graphs).
      Bv: bytes per vertex id.

    Host-side staging happens once in ``__init__`` (padded per-sub-shard
    numpy buffers — the 'shard files'); device staging is all-at-once for
    ``"device"`` residency and budget-bounded for ``"host"``. Plans are
    compiled lazily and cached, so repeated ``run``/``run_batch`` calls
    re-use the staged blocks and the jit executables.
    """

    _strategies: dict[str, Callable] = {
        "spu": _iteration_spu,
        "dpu": _iteration_dpu,
        "mpu": _iteration_mpu,
        "fused": _iteration_fused,
    }

    def __init__(
        self,
        graph: DSSSGraph,
        *,
        memory_budget: int | None = None,
        residency: str = "auto",
        execution: str = "auto",
        packing: str = "auto",
        Be: int = 8,
        Bv: int = 4,
        staged: _StagedGraph | None = None,
        host_memory_budget: int | None = None,
        fault_plan: FaultPlan | None = None,
    ):
        if residency not in ("device", "host", "disk", "auto"):
            raise ValueError(
                "residency must be 'device', 'host', 'disk' or 'auto', "
                f"got {residency!r}"
            )
        if execution not in ("per_block", "packed", "packed_kernel", "auto"):
            raise ValueError(
                "execution must be 'per_block', 'packed', 'packed_kernel' "
                f"or 'auto', got {execution!r}"
            )
        if packing not in ("adaptive", "subshard", "auto"):
            raise ValueError(
                "packing must be 'adaptive', 'subshard' or 'auto', "
                f"got {packing!r}"
            )
        self.graph = graph
        self.memory_budget = memory_budget
        self.residency = residency
        self.execution = execution
        # Tile-packing layout for the compiled sweep path: "adaptive"
        # (destination-aligned fixed-size tiles) wherever the DSSS layout
        # allows it; src_sorted (GraphChi-like) graphs scramble destination
        # runs inside blocks, so only the whole-sub-shard packing groups
        # their per-destination reduces correctly.
        if packing == "auto":
            packing = "subshard" if graph.src_sorted else "adaptive"
        elif packing == "adaptive" and graph.src_sorted:
            raise ValueError(
                "packing='adaptive' requires destination-sorted sub-shards; "
                "src_sorted graphs support only packing='subshard'"
            )
        self.packing = packing
        self.has_weights = graph.weights is not None
        self.Be = Be + (4 if self.has_weights else 0)
        self.Bv = Bv
        self._hub_d = graph.mean_hub_in_degree()
        if staged is not None and staged.graph is not graph:
            raise ValueError("staged arrays belong to a different graph")
        self._staged = staged if staged is not None else _StagedGraph(graph)
        self._store = self._staged.store
        if residency == "disk" and self._store is None:
            raise ValueError(
                "residency='disk' requires a disk-backed session — open the "
                "graph from a .dsss container with GraphSession.open(path) "
                "(see repro.storage)"
            )
        if host_memory_budget is not None and self._store is None:
            raise ValueError(
                "host_memory_budget is the disk tier's RAM-cache bound and "
                "only applies to disk-backed sessions (GraphSession.open); "
                "in-memory sessions are bounded by memory_budget alone"
            )
        self.host_memory_budget = host_memory_budget
        # One live injector shared by every layer of this session (engine
        # loop, block fetcher, packed stream, backing store) so per-spec
        # fire budgets are spent once, globally.
        self._injector = None
        if fault_plan is not None:
            self.inject_faults(fault_plan)
        self._residency: dict[int, frozenset] = {}  # Ba -> resident set
        self._compiled: dict[tuple, CompiledPlan] = {}
        self._pinned: dict[tuple[int, int], dict] = {}  # host mode device pins
        # Packed host-mode pins: (pin_tiles, device leaves, model, actual).
        self._packed_pins: tuple[int, dict | None, float, float] | None = None
        self._stream_plans: dict[tuple[bool, int], PackedStreamPlan] = {}
        # Disk-tier RAM caches (the host_memory_budget mid tier): blocks /
        # packed tile chunks materialized out of the mmap'd store, bounded
        # by _resolve_host_cache / PackedStreamPlan.host_tiles.
        self._host_cache: dict[tuple[int, int], dict] = {}
        self._packed_ram: dict[tuple[int, int], dict] = {}

    @classmethod
    def open(
        cls,
        path: str,
        *,
        memory_budget: int | None = None,
        host_memory_budget: int | None = None,
        residency: str = "auto",
        execution: str = "auto",
        packing: str = "auto",
        Be: int = 8,
        Bv: int = 4,
        verify: bool = True,
        fault_plan: FaultPlan | None = None,
        read_policy=None,
    ) -> "GraphSession":
        """Open a ``.dsss`` container as a disk-backed session.

        The graph, its padded sub-shard blocks and its stored packed tile
        layout all become mmap views of the file — nothing edge-scale is
        materialized in host RAM, and ``residency`` defaults (via
        ``"auto"``) to ``"disk"``: sweeps stream blocks / tile chunks
        disk→device under the three-level
        ``memory_budget`` / ``host_memory_budget`` hierarchy.
        ``verify=True`` (default) checks every segment checksum first — a
        truncated or bit-flipped file fails loudly instead of computing
        garbage; pass ``verify=False`` to skip the full-file read for
        very large graphs.

        ``read_policy`` (a :class:`repro.storage.format.ReadPolicy`)
        enables *self-healing* segment reads instead: each block/tile
        segment is checksum-verified on first touch with bounded re-read +
        backoff, and a segment that stays bad is quarantined behind a
        structured :class:`repro.storage.format.DegradedReadError` — the
        fetch layer never returns garbage. ``fault_plan`` attaches a
        :class:`repro.reliability.FaultPlan` injector to the session and
        its store (see :meth:`inject_faults`).
        """
        from repro.storage.format import open_dsss

        store = open_dsss(path, verify=verify, read_policy=read_policy)
        graph = store.graph()
        return cls(
            graph,
            memory_budget=memory_budget,
            residency=residency,
            execution=execution,
            packing=packing,
            Be=Be,
            Bv=Bv,
            staged=_StagedGraph(graph, store=store),
            host_memory_budget=host_memory_budget,
            fault_plan=fault_plan,
        )

    @property
    def store(self):
        """The backing :class:`repro.storage.format.DSSSStore` (or None)."""
        return self._store

    def inject_faults(self, plan: FaultPlan | None) -> None:
        """Attach (or clear, with ``None``) a deterministic fault plan.

        Builds one live :class:`repro.reliability.FaultInjector` shared by
        the engine loop (``"sweep"`` site), the block fetcher / packed
        chunk streamer (``"h2d"`` site) and the backing ``.dsss`` store
        (``"storage"`` site), so a plan's fire budgets are accounted once
        across layers.
        """
        self._injector = plan.injector() if plan is not None else None
        if self._store is not None:
            self._store.attach_faults(self._injector)

    @property
    def fault_injector(self):
        """The live injector of the attached fault plan (or None)."""
        return self._injector

    def _heal_store_segments(self, prefix: str) -> None:
        """Verify-on-first-touch for the store segments a stream reads.

        Disk-residency self-healing: before the fetch layer pages block
        (``blk_*``) or packed tile (``p_*``) data out of the mmap, every
        backing segment is checksum-verified once — with bounded re-read +
        backoff under the store's :class:`~repro.storage.format.ReadPolicy`
        — so a torn read heals and persistent corruption surfaces as a
        structured :class:`~repro.storage.format.DegradedReadError` instead
        of garbage results. No-op without a read policy (the
        ``open(verify=True)`` whole-file check is then the only guard) and
        after the first touch (verified segments are remembered).
        """
        store = self._store
        if store is None or store.read_policy is None:
            return
        store.ensure_segments(
            n for n in store.segments if n.startswith(prefix)
        )

    @property
    def block_keys(self) -> frozenset:
        """Keys of the non-empty sub-shards (placement-independent)."""
        return self._staged.block_keys

    @property
    def host_blocks(self) -> dict[tuple[int, int], dict]:
        """The padded numpy 'shard files' (always present, never uploaded)."""
        return self._staged.host_blocks

    @property
    def blocks(self) -> dict[tuple[int, int], dict]:
        """Back-compat staged-block view.

        Under ``"device"``/``"auto"``-without-budget residency this is the
        all-on-device dict (staged once); under enforced ``"host"`` or
        ``"disk"`` residency it is the host-side dict (numpy buffers or
        mmap views) — returning the device mirror here would silently
        stage the whole graph and break the budget.
        """
        if self.resolved_residency() in ("host", "disk"):
            return self._staged.host_blocks
        return self._staged.device_blocks()

    def resolved_residency(self, override: str | None = None) -> str:
        """Resolve the residency axis to 'device', 'host' or 'disk'."""
        mode = override or self.residency
        if mode == "auto":
            if self._store is not None:
                mode = "disk"
            else:
                mode = "host" if self.memory_budget is not None else "device"
        if mode == "disk" and self._store is None:
            raise ValueError(
                "residency='disk' requires a disk-backed session — open the "
                "graph with GraphSession.open(path)"
            )
        return mode

    def resolved_execution(
        self,
        strategy: str,
        residency: str,
        override: str | None = None,
    ) -> str:
        """Resolve the execution axis: 'per_block' | 'packed' | 'packed_kernel'.

        ``strategy`` must already be resolved (a schedule name, not
        "auto") and ``residency`` must be 'device' or 'host'. The packed
        paths apply to the native block schedules (SPU/DPU/MPU) under
        *both* residencies — under "host" the tile chunks are streamed
        with double-buffered prefetch instead of the per-block fetcher, so
        out-of-core runs no longer downgrade. ``"auto"`` upgrades to the
        fused Pallas kernel only where it compiles natively (TPU backend,
        i.e. ``not default_interpret()``); elsewhere the interpret-mode
        kernel would be orders slower than the XLA scan, so auto keeps
        ``"packed"`` and ``"packed_kernel"`` must be requested explicitly
        (the parity suite does exactly that). The fused fast path and
        custom registered schedules run per-block even when a packed mode
        was requested explicitly (a forgiving downgrade, like
        residency="auto": results and meters are identical).
        """
        mode = override or self.execution
        applies = strategy in ("spu", "dpu", "mpu")
        if not applies:
            return "per_block"
        if mode == "auto":
            from repro.kernels.dsss_spmv import default_interpret

            mode = "packed" if default_interpret() else "packed_kernel"
        return mode

    # -- budget accounting ---------------------------------------------------
    def staged_host_bytes(self) -> int:
        """Raw host RAM the staged graph currently occupies (pool accounting).

        In-memory sessions: the padded numpy 'shard files' — the dominant
        per-graph staging cost a :class:`repro.serving.pool.SessionPool`
        charges against its capacity. Disk-backed sessions: the mmap views
        cost nothing resident, so only the materialized RAM caches (the
        ``host_memory_budget`` mid tier) count — the figure grows as
        cached blocks / tile chunks are first touched.
        """
        if self._store is not None:
            total = sum(
                _host_block_nbytes(b) for b in self._host_cache.values()
            )
            total += sum(
                sum(a.nbytes for a in chunk.values())
                for chunk in self._packed_ram.values()
            )
            return int(total)
        return int(
            sum(_host_block_nbytes(b) for b in self.host_blocks.values())
        )

    def pinned_device_bytes(self) -> tuple[float, float]:
        """(model, actual) bytes of the currently device-pinned topology.

        Covers both pinning mechanisms — per-block pins (per-block host
        execution) and the packed tile-prefix pins (packed host execution);
        at most one is populated at a time (each releases the other).
        Model bytes use the I/O-model accounting (``e·Be`` real edges, the
        same units as ``memory_budget``); actual bytes are the raw padded
        buffer sizes (bucket/tile padding makes them larger).
        """
        model = float(
            sum(self.host_blocks[k]["e"] * self.Be for k in self._pinned)
        )
        actual = float(
            sum(_host_block_nbytes(self.host_blocks[k]) for k in self._pinned)
        )
        if self._packed_pins is not None:
            model += self._packed_pins[2]
            actual += self._packed_pins[3]
        return model, actual

    def packed_stream_plan(self, strategy: str, Ba: int) -> PackedStreamPlan:
        """Tile placement for packed execution under host residency.

        Mirrors :meth:`_resolve_residency`'s budget semantics at tile
        granularity: for SPU the budget leftover after both attribute
        copies (``2·n_pad·Ba``) pins a prefix of the tile stream; DPU/MPU
        pin no edge topology (their Table II model streams ``m·Be`` every
        sweep). The streamed remainder is chunked to at most
        ``min(256 KiB, budget/4)`` of tile data per chunk (never below one
        tile), so tight budgets stream tile-by-tile while generous ones
        amortise dispatches — the double buffer keeps ≤ 2 chunks in
        flight.
        """
        pins_apply = strategy == "spu"
        key = (pins_apply, Ba)
        plan = self._stream_plans.get(key)
        if plan is not None:
            return plan
        packed = self._staged.packed_host(self.packing)
        nt, T = packed.num_tiles, packed.tile_edges
        Be = self.Be
        cum = np.cumsum(packed.e_valid.astype(np.int64)) * Be
        if self.memory_budget is None:
            pin = nt
        elif pins_apply:
            leftover = self.memory_budget - 2 * self.graph.n_pad * Ba
            pin = int(np.searchsorted(cum, leftover, side="right"))
        else:
            pin = 0
        pin_model = float(cum[pin - 1]) if pin else 0.0
        tile_bytes = max(T * Be, 1)
        target = 256 * 1024
        if self.memory_budget is not None:
            target = min(target, max(self.memory_budget // 4, tile_bytes))
        chunk = max(1, min(int(target // tile_bytes), max(nt - pin, 1)))
        max_chunk = 0.0
        for lo in range(pin, nt, chunk):
            hi = min(lo + chunk, nt)
            hi_cum = float(cum[hi - 1])
            lo_cum = float(cum[lo - 1]) if lo else 0.0
            max_chunk = max(max_chunk, hi_cum - lo_cum)
        # Disk tier's mid level: whole streamed chunks, in order, that the
        # host_memory_budget keeps materialized in RAM (chunk-aligned so a
        # chunk is either fully cached or fully mmap-streamed).
        host_tiles = 0
        if self._store is not None:
            if self.host_memory_budget is None:
                host_tiles = nt - pin
            else:
                per_edge = PACKED_SLOT_BYTES + (4 if self.has_weights else 0)
                leftover = self.host_memory_budget
                for lo in range(pin, nt, chunk):
                    hi = min(lo + chunk, nt)
                    raw = (hi - lo) * (T * per_edge + 4)
                    if leftover < raw:
                        break
                    leftover -= raw
                    host_tiles += hi - lo
        plan = PackedStreamPlan(
            pin_tiles=pin,
            chunk_tiles=chunk,
            num_tiles=nt,
            tile_edges=T,
            pin_model_bytes=pin_model,
            max_chunk_model_bytes=max_chunk,
            host_tiles=host_tiles,
        )
        self._stream_plans[key] = plan
        return plan

    def _packed_tile_activity(self, row_active: np.ndarray) -> np.ndarray:
        """(NT,) bool tile-activity map for this sweep's interval frontier.

        Derived from the previous sweep's ``changed`` output (the (P,)
        ``row_active`` bitmap) and the packed layout's per-tile source
        spans — see :func:`repro.core.dsss.active_tile_mask`. Conservative
        for coalesced tiles whose span covers an empty-but-active-counted
        interval (processed unnecessarily, never skipped wrongly).
        """
        first, last = self._staged.packed_spans(self.packing)
        return active_tile_mask(row_active, first, last)

    def _ensure_packed_pins(self, pin_tiles: int) -> tuple[dict | None, float]:
        """Device-pin exactly the leading ``pin_tiles`` tiles (host mode).

        Returns ``(device leaves or None, model bytes)``. Like
        :meth:`_ensure_pinned`, a changed pin count releases the previous
        device copies first; the per-block pin dict is also released (the
        two mechanisms must never both occupy the device).
        """
        self._pinned.clear()
        if self._packed_pins is not None and self._packed_pins[0] == pin_tiles:
            return self._packed_pins[1], self._packed_pins[2]
        self._packed_pins = None
        if pin_tiles <= 0:
            self._packed_pins = (0, None, 0.0, 0.0)
            return None, 0.0
        packed = self._staged.packed_host(self.packing)
        with _TRACER.span(
            "stage_packed_pins", cat="staging", tiles=pin_tiles
        ):
            host = _packed_host_chunk(packed, 0, pin_tiles, self.has_weights)
            dev = jax.device_put(host)
        model = float(packed.e_valid[:pin_tiles].sum()) * self.Be
        actual = float(_chunk_nbytes(host))
        self._packed_pins = (pin_tiles, dev, model, actual)
        return dev, model

    # -- strategy registry ---------------------------------------------------
    @classmethod
    def register_strategy(cls, name: str, iteration_fn: Callable) -> None:
        """Register a custom per-iteration schedule (e.g. a baseline).

        ``iteration_fn(ctx, attrs, active, meters) -> (attrs, active_next)``
        with ``attrs`` shaped ``(K, P, interval_size)`` and ``active``
        ``(K, P)`` bool.
        """
        cls._strategies[name] = iteration_fn

    # -- staging -------------------------------------------------------------
    def fused_arrays(self) -> dict:
        """Whole-graph edge arrays for the fused path, staged lazily once."""
        if self._staged.fused is None:
            g = self.graph
            with _TRACER.span(
                "stage_fused", cat="staging", m=int(g.m)
            ):
                self._staged.fused = dict(
                    src=jnp.asarray(g.src, jnp.int32),
                    dst=jnp.asarray(g.dst, jnp.int32),
                    weights=(
                        None if g.weights is None else jnp.asarray(g.weights)
                    ),
                )
        return self._staged.fused

    def kernel_operands(
        self, i: int, j: int, dtype, *, gather_op: str = "mul", reduce: str = "sum"
    ) -> tuple:
        """Pallas-kernel operands for SS[i, j], staged once per semiring.

        Returns ``(src_idx, hub_inv, weights, block_base)`` as produced by
        :func:`repro.kernels.ops.prepare_subshard_operands` — the TPU hot
        path equivalent of the staged jnp blocks.
        """
        key = (i, j, str(jnp.dtype(dtype)), gather_op, reduce)
        ops = self._staged.kernel_operands.get(key)
        if ops is None:
            from repro.kernels.ops import prepare_from_host_block

            # Stage from the already-built host buffer (shared with the
            # streaming path) instead of re-slicing the flat edge arrays.
            ops = prepare_from_host_block(
                self.host_blocks[(i, j)], dtype, gather_op=gather_op, reduce=reduce
            )
            self._staged.kernel_operands[key] = ops
        return ops

    # -- plan compilation ----------------------------------------------------
    def params_for(self, program: VertexProgram) -> IOParams:
        g = self.graph
        return IOParams(
            n=g.n, m=g.m, Ba=program.attr_bytes, Bv=self.Bv, Be=self.Be,
            d=self._hub_d, P=g.P,
        )

    def compile(self, plan: ExecutionPlan) -> CompiledPlan:
        """Resolve a plan's strategy + residency + execution + activity
        (cached)."""
        key = (
            plan.strategy, plan.program.attr_bytes, plan.residency,
            plan.execution, plan.activity, plan.program.monotone,
        )
        compiled = self._compiled.get(key)
        if compiled is None:
            params = self.params_for(plan.program)
            choice = self._resolve_choice(plan.strategy, params)
            residency = self.resolved_residency(plan.residency)
            compiled = CompiledPlan(
                params=params,
                choice=choice,
                resident=self._resolve_residency(plan.strategy, params),
                residency=residency,
                execution=self.resolved_execution(
                    choice.strategy, residency, plan.execution
                ),
                host_cached=(
                    self._resolve_host_cache(plan.strategy, params)
                    if residency == "disk"
                    else frozenset()
                ),
                activity=(
                    "selective"
                    if plan.program.monotone and plan.activity != "off"
                    else "off"
                ),
            )
            self._compiled[key] = compiled
        return compiled

    def _resolve_choice(self, strategy: str, params: IOParams) -> StrategyChoice:
        if strategy == "auto":
            # Disk-backed sessions select over the three-tier model: the
            # host_memory_budget mid tier adds the modelled disk re-stream
            # term to each candidate's read (see select_strategy).
            return select_strategy(
                params,
                self.memory_budget,
                host_B_M=(
                    self.host_memory_budget if self._store is not None else None
                ),
            )
        if strategy in ("spu", "dpu", "mpu", "fused"):
            Q = self.graph.P
            if strategy == "dpu":
                Q = 0
            elif strategy == "mpu":
                Q = mpu_q(params, self.memory_budget or 0)
            return StrategyChoice(strategy, Q, 0.0, 0.0)
        if strategy in self._strategies:
            return StrategyChoice(strategy, 0, 0.0, 0.0)
        raise ValueError(f"unknown strategy {strategy!r}")

    def _resolve_residency(self, strategy: str, params: IOParams) -> frozenset:
        """The single source of truth for which sub-shards the memory budget
        pins in the fast tier.

        SPU: both attribute copies (``2·n_pad·Ba``) come first; the
        leftover budget pins sub-shards in row-major (schedule) order.
        DPU/MPU: no edge blocks are pinned — attribute/hub state owns the
        fast tier (MPU's Q split governs *interval* residency, which stays
        attribute-side) and every edge block is streamed, exactly as the
        Table II ``m·Be`` read term assumes. Under ``residency="host"``
        this set is physically enforced by :class:`_BlockFetcher`; under
        ``"device"`` it drives the modelled meters only.
        """
        choice_strategy = (
            self._resolve_choice(strategy, params).strategy
            if strategy == "auto"
            else strategy
        )
        if choice_strategy != "spu":
            return frozenset()
        resident = self._residency.get(params.Ba)
        if resident is not None:
            return resident
        if self.memory_budget is None:
            resident = frozenset(self.block_keys)
        else:
            picked = set()
            host = self.host_blocks
            leftover = self.memory_budget - 2 * self.graph.n_pad * params.Ba
            for key in sorted(host):  # row-major, as the SPU schedule runs
                cost = host[key]["e"] * self.Be
                if leftover >= cost:
                    picked.add(key)
                    leftover -= cost
            resident = frozenset(picked)
        self._residency[params.Ba] = resident
        return resident

    def _resolve_host_cache(self, strategy: str, params: IOParams) -> frozenset:
        """The mid tier of the three-level budget (disk residency only).

        Which sub-shards the ``host_memory_budget`` keeps materialized in
        host RAM, picked in the schedules' row-major streaming order over
        the blocks the device budget did *not* pin, costed at their raw
        padded-buffer bytes (what the RAM copy actually occupies).
        ``host_memory_budget=None`` caches everything — the unlimited
        default mirrors ``memory_budget`` semantics. Fetches of cached
        blocks charge no ``bytes_disk_read``; with both budgets bounded,
        per-sweep disk traffic is exactly the ``disk_read_bytes`` closed
        form over the remaining blocks.
        """
        if self._store is None:
            return frozenset()
        resident = self._resolve_residency(strategy, params)
        host = self.host_blocks
        if self.host_memory_budget is None:
            return frozenset(k for k in host if k not in resident)
        picked = set()
        leftover = self.host_memory_budget
        for key in sorted(host):  # row-major, as the schedules stream
            if key in resident:
                continue
            cost = _host_block_nbytes(host[key])
            if leftover >= cost:
                picked.add(key)
                leftover -= cost
        return frozenset(picked)

    def _host_cache_block(self, key: tuple[int, int]) -> dict:
        """RAM-materialized copy of one mmap-backed block (built once)."""
        blk = self._host_cache.get(key)
        if blk is None:
            host = self._staged.host_blocks[key]
            blk = {
                k: (np.array(v) if isinstance(v, np.ndarray) else v)
                for k, v in host.items()
            }
            self._host_cache[key] = blk
        return blk

    def _packed_ram_chunk(self, lo: int, hi: int) -> dict:
        """RAM-materialized copy of one mmap-backed tile chunk (built once)."""
        chunk = self._packed_ram.get((lo, hi))
        if chunk is None:
            packed = self._staged.packed_host(self.packing)
            view = _packed_host_chunk(packed, lo, hi, self.has_weights)
            chunk = {k: np.array(v) for k, v in view.items()}
            self._packed_ram[(lo, hi)] = chunk
        return chunk

    def _ensure_pinned(self, resident: frozenset) -> dict[tuple[int, int], dict]:
        """Device-pin exactly the resident set (host residency only).

        Blocks leaving the resident set are released so successive plans
        with different strategies/budgets cannot accumulate device copies
        past the budget; blocks entering it are uploaded once and reused
        across runs. Packed tile pins are released for the same reason —
        only one pinning mechanism may occupy the device at a time.
        """
        self._packed_pins = None
        for key in [k for k in self._pinned if k not in resident]:
            del self._pinned[key]
        todo = [
            key
            for key in sorted(resident)
            if key in self.block_keys and key not in self._pinned
        ]
        if todo:
            with _TRACER.span(
                "stage_pins", cat="staging", blocks=len(todo)
            ):
                for key in todo:
                    self._pinned[key] = _device_block(self.host_blocks[key])
        return self._pinned

    def _interval_aux(self, aux: dict, k: int, batched: bool = False) -> dict:
        """Interval k's view of the aux dict.

        ``batched=True`` slices per-query ``(K, n_pad)`` leaves to
        ``(K, isz)`` — the leading query axis survives so the primitives'
        ``aux_batched`` vmap maps over it; scalars pass through either way.
        """
        isz = self.graph.interval_size
        if batched:
            return {
                key: (
                    v[:, k * isz : (k + 1) * isz]
                    if getattr(v, "ndim", 0) == 2
                    else v
                )
                for key, v in aux.items()
            }
        return {
            key: (v[k * isz : (k + 1) * isz] if getattr(v, "ndim", 0) == 1 else v)
            for key, v in aux.items()
        }

    # -- execution -----------------------------------------------------------
    def run(
        self,
        plan: ExecutionPlan,
        *,
        resume_from: str | bool | None = None,
        cancel: Callable[[int], None] | None = None,
    ) -> Result:
        """Execute one plan against the staged graph.

        ``resume_from`` restores a sweep-level snapshot and continues:
        a snapshot path, a checkpoint directory (its latest snapshot; an
        empty/missing directory starts fresh — the restore-latest-or-cold
        policy of the train loop), or ``True`` for the plan's own
        ``checkpoint.directory``. The resumed run is bit-identical to an
        uninterrupted one, with field-identical cumulative meters
        (``wall_seconds`` excepted — real elapsed time accumulates across
        attempts). ``cancel`` is a callable invoked with the completed
        sweep count before every sweep; raising
        :class:`repro.reliability.DeadlineExceeded` from it cancels the
        run cooperatively between sweeps (the serving deadline hook).
        """
        batch = self._execute(
            plan, [plan.kwargs_dict()], resume_from=resume_from, cancel=cancel
        )
        res = batch.results[0]
        assert res.iterations == res.meters.iterations, (
            "Result.iterations is defined as the number of update sweeps "
            "executed and must equal meters.iterations"
        )
        return res

    def run_batch(
        self,
        plans: list[ExecutionPlan],
        *,
        resume_from: str | bool | None = None,
        cancel: Callable[[int], None] | None = None,
    ) -> BatchResult:
        """Execute K plans, sharing one streamed pass over the edge blocks.

        Plans fuse when they share a ``batch_key()`` (program, strategy,
        limits and the residency/execution/activity axes) and their aux
        arrays are identical *or* stackable (same keys/shapes/dtypes —
        e.g. per-query masks); stackable aux runs vmapped with a leading
        query axis on the native SPU/DPU/MPU/fused schedules. Everything
        else falls back to sequential ``run`` calls (``fused=False``);
        results are identical either way. ``resume_from`` / ``cancel``
        behave as in :meth:`run`; a fused batch checkpoints and resumes
        as one unit (the snapshot holds all K queries' state).
        """
        if not plans:
            return BatchResult([], Meters(), 0, True, True)
        if self._fusable(plans):
            return self._execute(
                plans[0],
                [p.kwargs_dict() for p in plans],
                resume_from=resume_from,
                cancel=cancel,
            )
        if resume_from:
            raise ValueError(
                "resume_from requires a fusable batch (one snapshot holds "
                "the whole batch's state); these plans fall back to "
                "sequential runs — resume them individually"
            )
        meters = Meters()
        results = [self.run(p, cancel=cancel) for p in plans]
        for r in results:
            meters.merge(r.meters)
        return BatchResult(
            results=results,
            meters=meters,  # summed, incl. iterations (per_iteration stays true)
            iterations=max(r.iterations for r in results),
            converged=all(r.converged for r in results),
            fused=False,
        )

    def _fusable(self, plans: list[ExecutionPlan]) -> bool:
        head = plans[0]
        if any(p.batch_key() != head.batch_key() for p in plans[1:]):
            return False
        g = self.graph
        aux0 = head.program.make_aux(g, **head.kwargs_dict())
        identical = True
        for p in plans[1:]:
            aux = p.program.make_aux(g, **p.kwargs_dict())
            if set(aux) != set(aux0):
                return False
            for k in aux0:
                a, b = np.asarray(aux[k]), np.asarray(aux0[k])
                if a.shape != b.shape or a.dtype != b.dtype:
                    return False
                if identical and not np.array_equal(a, b):
                    identical = False
        if identical:
            return True
        # Differing-but-stackable aux fuses via the batched-aux vmap,
        # which only the native schedules' primitives implement; custom
        # registered strategies fall back to sequential runs.
        return self.compile(head).choice.strategy in (
            "spu", "dpu", "mpu", "fused",
        )

    def _resolve_resume(
        self, plan: ExecutionPlan, resume_from: str | bool | None
    ) -> str | None:
        """Turn a ``resume_from`` argument into a snapshot path (or None).

        A directory resumes from its latest snapshot — or starts fresh
        when it has none (restore-latest-or-cold, like the train loop);
        ``True`` uses the plan's own checkpoint directory; an explicit
        file path must exist.
        """
        if not resume_from:
            return None
        if resume_from is True:
            if plan.checkpoint is None:
                raise ValueError(
                    "resume_from=True needs plan.checkpoint to name the "
                    "snapshot directory"
                )
            resume_from = plan.checkpoint.directory
        if os.path.isdir(resume_from):
            return latest_snapshot(resume_from)
        if not os.path.exists(resume_from):
            raise SnapshotError(f"{resume_from}: no such snapshot")
        return resume_from

    def _save_sweep_snapshot(
        self, spec, plan, attrs, active, converged_at, sweeps,
        activity_log, meters, wall_seconds,
    ) -> None:
        """Atomically snapshot the full iteration state after one sweep."""
        g = self.graph
        mdict = {
            f.name: getattr(meters, f.name) for f in dataclasses.fields(meters)
        }
        # The live meter keeps accumulating; the snapshot records the real
        # elapsed time as of the save without mutating it.
        mdict["wall_seconds"] = wall_seconds
        meta = {
            "sweeps": sweeps,
            "meters": mdict,
            "program": plan.program.name,
            "K": len(converged_at),
            "P": int(g.P),
            "interval_size": int(g.interval_size),
            "n": int(g.n),
            "m": int(g.m),
        }
        arrays = {
            "attrs": np.asarray(attrs),
            "active": np.asarray(active),
            "activity_log": (
                np.stack(activity_log)
                if activity_log
                else np.zeros((0, g.P), dtype=bool)
            ),
            "converged_at": np.asarray(
                [-1 if c is None else c for c in converged_at], np.int64
            ),
        }
        save_snapshot(spec.directory, sweeps, arrays, meta, keep=spec.keep)

    def _restore_sweep_snapshot(
        self, path: str, plan: ExecutionPlan, K: int, meters: Meters
    ):
        """Load one snapshot back into live loop state (validated)."""
        arrays, meta = load_snapshot(path)
        g = self.graph
        expect = {
            "program": plan.program.name,
            "K": K,
            "P": int(g.P),
            "interval_size": int(g.interval_size),
            "n": int(g.n),
            "m": int(g.m),
        }
        for key, want in expect.items():
            got = meta.get(key)
            if got != want:
                raise SnapshotError(
                    f"{path}: snapshot has {key}={got!r} but the resuming "
                    f"plan/session needs {key}={want!r}"
                )
        # Restore the cumulative meters wholesale: the snapshot was taken
        # past this run's setup charges (pins/fused peak), so the restored
        # values already include them — resumed totals match the
        # uninterrupted run field for field.
        for name, value in meta["meters"].items():
            setattr(meters, name, value)
        attrs = jnp.asarray(arrays["attrs"])
        active = np.asarray(arrays["active"])
        converged_at = [
            None if c < 0 else int(c) for c in arrays["converged_at"]
        ]
        activity_log = [np.asarray(row) for row in arrays["activity_log"]]
        return attrs, active, converged_at, int(meta["sweeps"]), activity_log

    def _publish_iomodel_drift(self, compiled, meters: Meters) -> None:
        """Gauge the measured-vs-modelled byte ratio for this run.

        Per direction: (measured model-unit bytes per sweep) / (Table II
        closed-form bytes per sweep). 1.0 means the engine moved exactly
        what the paper's model predicts; activity-selective runs drift
        below 1.0 as the frontier shrinks. Strategies without a closed
        form (custom registrations) publish nothing.
        """
        iters = meters.iterations
        if not iters:
            return
        strategy = compiled.choice.strategy
        try:
            read, write = modelled_io(
                compiled.params, self.memory_budget, strategy
            )
        except ValueError:
            return
        if read > 0:
            _OBS_DRIFT.labels(direction="read", strategy=strategy).set(
                meters.bytes_read / iters / read
            )
        if write > 0:
            _OBS_DRIFT.labels(direction="write", strategy=strategy).set(
                meters.bytes_written / iters / write
            )

    def _execute(
        self,
        plan: ExecutionPlan,
        kwargs_list: list[dict],
        *,
        resume_from: str | bool | None = None,
        cancel: Callable[[int], None] | None = None,
    ) -> BatchResult:
        g = self.graph
        prog = plan.program
        compiled = self.compile(plan)
        if compiled.residency == "disk":
            # Self-healing reads: checksum-verify (once, with bounded
            # re-read under the store's ReadPolicy) every segment this
            # run's data path — pins and streams alike — will mmap, so a
            # bad segment surfaces as a structured DegradedReadError
            # here, before any garbage bytes reach the device.
            self._heal_store_segments(
                "blk_" if compiled.execution == "per_block" else "p_"
            )
        isz = g.interval_size
        K = len(kwargs_list)
        attrs = jnp.stack(
            [prog.init_attrs(g, **kw).reshape(g.P, isz) for kw in kwargs_list]
        )
        active = np.stack([prog.init_active(g, **kw) for kw in kwargs_list])
        aux, aux_batched = _batch_aux(prog, g, kwargs_list)
        if aux_batched and compiled.choice.strategy not in (
            "spu", "dpu", "mpu", "fused",
        ):
            raise TypeError(
                "plans with per-query aux cannot fuse under custom strategy "
                f"{compiled.choice.strategy!r} (its iteration body predates "
                "the batched-aux vmap); run them individually"
            )
        meters = Meters()
        # Observability: plan-scoped tracing turns the process recorder on
        # for this run's duration — staging/pinning included, so the flip
        # happens before the pins below. Per-sweep spans carry the sweep's
        # *physical* byte deltas (their sum over a fresh run equals
        # Result.meters.bytes_h2d / bytes_disk_read exactly — h2d/disk are
        # only ever charged inside sweeps). Model-unit byte counters are
        # published per sweep as meter deltas; the physical kinds are
        # published at the transfer/mmap boundaries themselves.
        tspec = plan.trace
        obs_on = _REGISTRY.enabled
        was_tracing = _TRACER.enabled
        tracing = was_tracing or tspec is not None
        trace_sweeps = tracing and (tspec is None or tspec.sweeps)
        run_id = next(_RUN_SEQ)
        mark = _TRACER.mark() if tracing else 0
        if tracing and not was_tracing:
            _TRACER.enabled = True
        try:
            # Per-block host/disk runs pin the resident set here; packed
            # host/disk runs pin a tile prefix lazily inside the sweep (the
            # block pins would double-book the device). Device runs leave
            # pins untouched.
            streamed = compiled.residency in ("host", "disk")
            pinned = (
                self._ensure_pinned(compiled.resident)
                if streamed and compiled.execution == "per_block"
                else {}
                if streamed
                else self._pinned
            )
            fetcher = _BlockFetcher(self, compiled, meters, pinned)
            if compiled.choice.strategy == "fused":
                # The fused path holds the whole edge list on device by
                # design (its point is HBM residency); report that honestly.
                meters.peak_device_graph_bytes = max(
                    meters.peak_device_graph_bytes, float(g.m * self.Be)
                )
            ctx = _RunContext(
                session=self,
                program=prog,
                choice=compiled.choice,
                resident=compiled.resident,
                params=compiled.params,
                aux=aux,
                # Hoisted: all P interval views of the (run-constant) aux
                # are sliced once here, not per (i, j) block inside the
                # sweeps.
                aux_views=[
                    self._interval_aux(aux, k, batched=aux_batched)
                    for k in range(g.P)
                ],
                valid=(jnp.arange(g.n_pad) < g.n).reshape(g.P, isz),
                tol=jnp.asarray(plan.tol, jnp.float32),
                K=K,
                residency=compiled.residency,
                fetcher=fetcher,
                activity=compiled.activity,
                aux_batched=aux_batched,
                execution=compiled.execution,
            )
            if compiled.execution in ("packed", "packed_kernel"):
                iteration = _iteration_packed
            else:
                iteration = self._strategies[compiled.choice.strategy]
            converged_at: list[int | None] = [
                0 if not active[m].any() else None for m in range(K)
            ]
            sweeps = 0
            activity_log: list[np.ndarray] = []
            wall0 = 0.0
            snap_path = self._resolve_resume(plan, resume_from)
            if snap_path is not None:
                attrs, active, converged_at, sweeps, activity_log = (
                    self._restore_sweep_snapshot(snap_path, plan, K, meters)
                )
                wall0 = meters.wall_seconds
            ckpt = plan.checkpoint
            inj = self._injector
            start = time.perf_counter()
            for _ in range(sweeps, plan.max_iters):
                if not active.any():
                    break
                # Cooperative cancellation (serving deadlines) and injected
                # crashes both land here, on the sweep boundary — never
                # mid-sweep, so checkpointed state is always consistent.
                if cancel is not None:
                    cancel(sweeps)
                if inj is not None:
                    inj.check("sweep", sweeps)
                # Record the sweep's processed-interval bitmap (the union
                # _rows_to_process acts on) before the sweep mutates `active`
                # — this is the trace the iomodel activity terms consume.
                if compiled.activity == "selective":
                    activity_log.append(active.any(axis=0).copy())
                else:
                    activity_log.append(np.ones(g.P, dtype=bool))
                if obs_on or trace_sweeps:
                    s_h2d = meters.bytes_h2d
                    s_disk = meters.bytes_disk_read
                    s_model = [getattr(meters, f) for f, _ in _OBS_MODEL_BYTES]
                    t_sweep = time.perf_counter()
                attrs, active = iteration(ctx, attrs, active, meters)
                sweeps += 1
                meters.iterations += 1
                if obs_on:
                    _OBS_SWEEPS.inc()
                    for (f, child), before in zip(_OBS_MODEL_BYTES, s_model):
                        delta = getattr(meters, f) - before
                        if delta:
                            child.inc(delta)
                if trace_sweeps:
                    _TRACER.record(
                        "sweep", t_sweep, time.perf_counter(), cat="engine",
                        args={
                            "run": run_id,
                            "sweep": sweeps - 1,
                            "bytes_h2d": meters.bytes_h2d - s_h2d,
                            "bytes_disk_read": meters.bytes_disk_read - s_disk,
                            "active_intervals": int(activity_log[-1].sum()),
                            "intervals": int(g.P),
                        },
                    )
                for m in range(K):
                    if converged_at[m] is None and not active[m].any():
                        converged_at[m] = sweeps
                if ckpt is not None and sweeps % ckpt.every == 0:
                    t_ck = time.perf_counter()
                    self._save_sweep_snapshot(
                        ckpt, plan, attrs, active, converged_at, sweeps,
                        activity_log, meters,
                        wall0 + (t_ck - start),
                    )
                    if tracing:
                        _TRACER.record(
                            "checkpoint", t_ck, time.perf_counter(),
                            cat="engine",
                            args={"run": run_id, "sweep": sweeps},
                        )
            end = time.perf_counter()
            meters.wall_seconds = wall0 + (end - start)
            if tracing:
                _TRACER.record(
                    "run", start, end, cat="engine",
                    args={
                        "run": run_id,
                        "program": prog.name,
                        "strategy": compiled.choice.strategy,
                        "residency": compiled.residency,
                        "execution": compiled.execution,
                        "K": K,
                        "n": int(g.n),
                        "m": int(g.m),
                        "P": int(g.P),
                        "sweeps": sweeps,
                        "bytes_h2d": meters.bytes_h2d,
                        "bytes_disk_read": meters.bytes_disk_read,
                        "converged": bool(not active.any()),
                    },
                )
                if tspec is not None and tspec.path:
                    _TRACER.export(tspec.path, since=mark)
        finally:
            if tracing and not was_tracing:
                _TRACER.enabled = was_tracing
        if obs_on:
            _OBS_RUNS.labels(
                program=prog.name,
                strategy=compiled.choice.strategy,
                residency=compiled.residency,
                execution=compiled.execution,
            ).inc()
            _OBS_PEAK.set(meters.peak_device_graph_bytes)
            self._publish_iomodel_drift(compiled, meters)
        results = []
        for m in range(K):
            flat = attrs[m].reshape(-1)
            # Per-query iterations: the sweep at which this member converged
            # (meaningful for monotone programs, where later sweeps are
            # no-ops for it); otherwise the shared sweep count.
            iterations = (
                converged_at[m]
                if prog.monotone and converged_at[m] is not None
                else sweeps
            )
            results.append(
                Result(
                    attrs=np.asarray(flat[: g.n]),
                    output=prog.output(flat, g),
                    iterations=iterations,
                    converged=converged_at[m] is not None,
                    meters=meters,
                    strategy=compiled.choice,
                    activity_log=tuple(activity_log),
                )
            )
        return BatchResult(
            results=results,
            meters=meters,
            iterations=sweeps,
            converged=not active.any(),
            fused=True,
            activity_log=tuple(activity_log),
        )


# ---------------------------------------------------------------------------
# Identity-keyed weak LRU — shared by the session cache below and the
# sharded-graph cache in repro.core.algorithms.
# ---------------------------------------------------------------------------
class IdentityLRU:
    """Small LRU keyed by ``(id(obj), *extra)`` with a weakref liveness guard.

    Keying by identity is deliberate (the cached value aliases the object's
    arrays); the weakref invalidates the slot so recycled ids can't alias a
    dead object.
    """

    def __init__(self, size: int = 8):
        self._size = size
        self._entries: "OrderedDict[tuple, tuple[weakref.ref, Any]]" = OrderedDict()

    def get_or_build(self, obj, extra: tuple, factory: Callable):
        key = (id(obj), *extra)
        entry = self._entries.get(key)
        if entry is not None and entry[0]() is obj:
            self._entries.move_to_end(key)
            return entry[1]
        value = factory()
        self._entries[key] = (weakref.ref(obj), value)
        while len(self._entries) > self._size:
            self._entries.popitem(last=False)
        return value

    def clear(self) -> None:
        self._entries.clear()


# Session LRU keyed by graph identity — lets the algorithm drivers
# (repro.core.algorithms) share one staged session per graph object. Each
# slot holds the graph's staged device arrays plus the session variants
# (per memory_budget/Be/Bv) built over them, so changing the budget never
# re-uploads the blocks. The cache intentionally keeps the last
# `size` graphs' blocks resident (an LRU retains by design — the cached
# session strongly references its graph); call clear_session_cache() to
# release them, or construct GraphSession directly for throwaway graphs.
_SESSION_LRU = IdentityLRU(size=8)


def get_session(
    graph: DSSSGraph,
    *,
    memory_budget: int | None = None,
    host_memory_budget: int | None = None,
    residency: str = "auto",
    execution: str = "auto",
    packing: str = "auto",
    Be: int = 8,
    Bv: int = 4,
) -> GraphSession:
    """The session for this graph object, staged at most once (LRU of 8).

    Only use this for graph objects the caller keeps alive across calls;
    for a throwaway graph, construct :class:`GraphSession` directly so the
    staged blocks die with it instead of pinning an LRU slot. Variants
    (budgets/residency/execution/packing/byte sizes) share one set of host
    buffers, one lazily-staged device mirror and one packed tile layout
    per packing mode. Every session axis participates in the variant key,
    so callers differing in *any* knob never wrongly share (or spuriously
    duplicate) a session. ``host_memory_budget`` is accepted and keyed for
    consistency and forwarded — in-memory graphs reject it with
    :class:`GraphSession`'s own error (it is the disk tier's RAM bound;
    disk-backed sessions come from :meth:`GraphSession.open` or a
    :class:`repro.serving.pool.SessionPool`, not this cache).
    """
    slot = _SESSION_LRU.get_or_build(
        graph, (), lambda: {"staged": _StagedGraph(graph), "variants": {}}
    )
    key = (
        memory_budget, host_memory_budget, residency, execution, packing,
        Be, Bv,
    )
    session = slot["variants"].get(key)
    if session is None:
        session = GraphSession(
            graph,
            memory_budget=memory_budget,
            host_memory_budget=host_memory_budget,
            residency=residency,
            execution=execution,
            packing=packing,
            Be=Be,
            Bv=Bv,
            staged=slot["staged"],
        )
        slot["variants"][key] = session
    return session


def clear_session_cache() -> None:
    """Release every cached session (and its device-staged blocks)."""
    _SESSION_LRU.clear()
