"""Closed-form slow-tier I/O models — paper Table II + §III-B/§III-C.

These formulas drive two things:

1. The **adaptive strategy selection** the paper describes ("NXgraph can
   adaptively choose the fastest strategy ... according to the graph size
   and the available memory resources"): given ``(n, m, Ba, Be, Bv, d,
   B_M, P)`` pick SPU / MPU(Q) / DPU by modelled total I/O.
2. The **property-test oracle**: the engine's byte meters must reproduce
   these closed forms (tests/test_iomodel_property.py), which is the
   paper-faithfulness proof of the I/O analysis. The meters are charged
   per *schedule event*, not per jit dispatch, so they are independent of
   the execution mode: the per-block executor charges them at the block
   fetcher and the packed compiled-sweep executor recomputes the same
   charges from the packed tile metadata — tests/test_packed_sweep.py
   pins field-for-field equality between the two.

On TPU the "slow tier" is HBM (single chip) or remote chips (pod); the same
formulas apply with ``B_M`` = fast-tier budget (VMEM / local HBM).
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "IOParams",
    "spu_io",
    "dpu_io",
    "mpu_io",
    "turbograph_like_io",
    "mpu_q",
    "select_strategy",
    "StrategyChoice",
    "modelled_io",
    "IOComparison",
    "compare_measured",
    "calibrate_edge_bytes",
    "packed_h2d_bytes",
    "packed_disk_bytes",
    "disk_read_bytes",
    "selective_streamed_tiles",
    "streamed_block_bytes",
    "selective_edge_bytes",
    "PACKED_SLOT_BYTES",
]


@dataclasses.dataclass(frozen=True)
class IOParams:
    """Byte-size parameters of the I/O model (paper Table I)."""

    n: int  # vertices
    m: int  # edges
    Ba: int = 8  # bytes per vertex attribute
    Bv: int = 4  # bytes per vertex id
    Be: int = 8  # bytes per edge
    d: float = 15.0  # mean in-degree of sub-shard destinations (hub factor)
    P: int = 16  # number of intervals


def spu_io(p: IOParams, B_M: int) -> tuple[float, float]:
    """SPU (paper §III-B1): requires ``B_M > 2n·Ba``.

    read  = m·Be + 2n·Ba − B_M   (clamped to [0, m·Be])
    write = 0
    """
    read = p.m * p.Be + 2 * p.n * p.Ba - B_M
    return float(min(max(read, 0), p.m * p.Be)), 0.0


def dpu_io(p: IOParams, B_M: int = 0) -> tuple[float, float]:
    """DPU (paper §III-B2): independent of B_M and P.

    read  = m·Be + m(Ba+Bv)/d + n·Ba
    write = m(Ba+Bv)/d + n·Ba
    """
    hub = p.m * (p.Ba + p.Bv) / p.d
    return float(p.m * p.Be + hub + p.n * p.Ba), float(hub + p.n * p.Ba)


def mpu_q(p: IOParams, B_M: int) -> int:
    """Paper §III-B3: ``Q ≤ B_M / (2 n Ba / P)`` ping-pong-resident intervals."""
    per_interval = 2 * -(-p.n // p.P) * p.Ba  # 2 · ceil(n/P) · Ba (ping-pong)
    return max(0, min(p.P, int(B_M // per_interval)))


def mpu_io(p: IOParams, B_M: int, *, continuous: bool = False) -> tuple[float, float]:
    """MPU (paper §III-B3). Q=P ⇒ SPU-like; Q=0 ⇒ DPU.

    read  = m·Be + ((P−Q)/P)·n·Ba + ((P−Q)²/P²)·m·(Ba+Bv)/d
    write =        ((P−Q)/P)·n·Ba + ((P−Q)²/P²)·m·(Ba+Bv)/d

    (The paper's §III-B3 display omits the 1/d hub compression it carries
    everywhere else — §III-C's B_MPU restores it; we keep 1/d throughout.)

    ``continuous=True`` uses the unquantized Q = (B_M/2n·Ba)·P that the
    paper's Fig. 6 comparison implicitly assumes (valid in the large-P
    limit). With integer Q and small P, MPU quantizes down to DPU and the
    Fig. 6 dominance over TurboGraph-like need not hold — see
    tests/test_engine_strategies.py.
    """
    if continuous:
        qfrac = min(1.0, B_M / max(2 * p.n * p.Ba, 1))
        cold = 1.0 - qfrac
    else:
        Q = mpu_q(p, B_M)
        cold = (p.P - Q) / p.P
    hub = cold * cold * p.m * (p.Ba + p.Bv) / p.d
    iv = cold * p.n * p.Ba
    return float(p.m * p.Be + iv + hub), float(iv + hub)


def turbograph_like_io(p: IOParams, B_M: int) -> tuple[float, float]:
    """TurboGraph/GridGraph-style block-load strategy (paper §III-C).

    With the I/O-optimal partitioning ``P* = 2n·Ba/B_M``:
      read  = m·Be + 2(n·Ba)²/B_M + n·Ba
      write = n·Ba
    """
    read = p.m * p.Be + 2 * (p.n * p.Ba) ** 2 / max(B_M, 1) + p.n * p.Ba
    return float(read), float(p.n * p.Ba)


@dataclasses.dataclass(frozen=True)
class StrategyChoice:
    strategy: str  # "spu" | "mpu" | "dpu"
    Q: int
    modelled_read: float
    modelled_write: float

    @property
    def modelled_total(self) -> float:
        return self.modelled_read + self.modelled_write


def modelled_io(p: IOParams, B_M: int | None, strategy: str) -> tuple[float, float]:
    """Closed-form (read, write) for one strategy — the property-test oracle.

    ``B_M=None`` means unlimited fast tier (SPU with everything resident).
    """
    if strategy == "spu":
        if B_M is None:
            return 0.0, 0.0
        return spu_io(p, B_M)
    if strategy == "dpu":
        return dpu_io(p)
    if strategy == "mpu":
        # No budget ⇒ Q = mpu_q(p, 0) = 0, matching the engine's explicit
        # "mpu" resolution (session._resolve_choice uses `memory_budget or 0`).
        return mpu_io(p, B_M if B_M is not None else 0)
    if strategy == "turbograph-like":
        # The baseline's formula needs a B_M for its P* partitioning term;
        # treat "unlimited" as both attribute copies fitting.
        return turbograph_like_io(p, B_M if B_M is not None else 2 * p.n * p.Ba)
    raise ValueError(f"no closed form for strategy {strategy!r}")


@dataclasses.dataclass(frozen=True)
class IOComparison:
    """Measured engine meters vs. the Table II closed forms, per iteration.

    ``slack_bytes`` is the documented discretization slack the measured
    numbers may deviate by:

    * SPU: residency is block-granular, so the resident prefix can undershoot
      the budget by at most one (largest) sub-shard — ≤ ``max_block·Be``.
    * DPU/MPU: the engine loads/saves *padded* intervals (``n_pad`` vs the
      formula's ``n``) — ≤ ``(n_pad − n)·Ba`` per read and per write; for
      monotone programs cold intervals are read once more than the
      PageRank-style accounting assumes (a documented deviation).
    """

    strategy: str
    modelled_read: float
    modelled_write: float
    measured_read: float
    measured_write: float
    slack_bytes: float

    @property
    def within_slack(self) -> bool:
        return (
            abs(self.measured_read - self.modelled_read) <= self.slack_bytes + 1e-6
            and abs(self.measured_write - self.modelled_write)
            <= self.slack_bytes + 1e-6
        )


def compare_measured(
    per_iteration_meters,
    p: IOParams,
    strategy: str,
    B_M: int | None,
    *,
    slack_bytes: float = 0.0,
) -> IOComparison:
    """Compare a run's per-iteration byte meters against the closed forms.

    ``per_iteration_meters`` is any object with ``bytes_read`` /
    ``bytes_written`` (i.e. ``Meters.per_iteration()``). This is the
    measured-vs-modelled hook the out-of-core executor is validated with:
    under ``residency="host"`` the measured edge bytes are real
    host→device transfers, so a pass here certifies the paper's I/O
    analysis against *performed*, not simulated, traffic.
    """
    read, write = modelled_io(p, B_M, strategy)
    return IOComparison(
        strategy=strategy,
        modelled_read=read,
        modelled_write=write,
        measured_read=float(per_iteration_meters.bytes_read),
        measured_write=float(per_iteration_meters.bytes_written),
        slack_bytes=float(slack_bytes),
    )


# Raw bytes per tile edge slot the packed host-streaming path ships: four
# int32 leaves (src, dst, run_local, run_dst) — plus float32 weights on
# weighted graphs and one int32 e_valid scalar per tile.
PACKED_SLOT_BYTES = 16


def packed_h2d_bytes(
    streamed_tiles: int, tile_edges: int, *, weighted: bool = False
) -> float:
    """Closed-form raw host→device bytes per sweep for packed streaming.

    Packed host execution ships every non-pinned tile each sweep — dense
    index/run leaves, so the volume is a pure function of the layout:
    ``streamed_tiles · (tile_edges · slot_bytes + 4)``. This is the packed
    counterpart of the per-block path's bucket-padded block bytes and is
    asserted to match ``Meters.bytes_h2d`` exactly in
    tests/test_packed_sweep.py — padding inflation (the adaptive packer's
    ``padding_ratio``) is therefore also the physical h2d inflation, which
    is why bounding it matters out-of-core (GraphMP-style semi-external
    streaming pays for every padded slot on the wire).
    """
    per_tile = tile_edges * (PACKED_SLOT_BYTES + (4 if weighted else 0)) + 4
    return float(streamed_tiles * per_tile)


def packed_disk_bytes(
    streamed_tiles: int, tile_edges: int, *, weighted: bool = False
) -> float:
    """Closed-form disk-tier bytes per sweep for packed disk streaming.

    Under ``residency="disk"`` the packed executor ships the same dense
    tile leaves as the host path, but sourced from the mmap'd ``.dsss``
    tile section, so the per-sweep disk volume is the same pure function
    of the layout as :func:`packed_h2d_bytes` — over only the tiles that
    are neither device-pinned nor RAM-cached
    (``num_tiles − pin_tiles − host_tiles`` of the session's
    :class:`~repro.core.session.PackedStreamPlan`). Asserted to match
    ``Meters.bytes_disk_read`` exactly in tests and the storage
    benchmark.
    """
    return packed_h2d_bytes(streamed_tiles, tile_edges, weighted=weighted)


def disk_read_bytes(
    block_nbytes, resident, host_cached, *, active_rows=None
) -> float:
    """Closed-form per-sweep disk reads of the per-block disk executor.

    ``block_nbytes`` maps sub-shard key ``(i, j)`` → raw bytes of its
    padded block arrays (the mmap'd segments the fetch touches); a sweep
    fetches each processed block exactly once, and only blocks that are
    neither device-pinned (``resident``) nor RAM-cached (``host_cached``)
    hit the disk tier. ``active_rows`` is the sweep's (P,) per-interval
    activity bitmap (``Result.activity_log`` entries) — under selective
    execution only blocks whose source interval is active are fetched at
    all, so the oracle stays exact for monotone programs too; ``None``
    means a full sweep (the non-monotone / ``activity="off"`` case).
    """
    return float(
        sum(
            b
            for k, b in block_nbytes.items()
            if k not in resident
            and k not in host_cached
            and (active_rows is None or active_rows[k[0]])
        )
    )


def selective_streamed_tiles(
    tile_active, pin_tiles: int, chunk_tiles: int
) -> int:
    """Streamed tile count of one frontier-aware packed sweep.

    The packed streaming loop walks the fixed chunk grid
    ``[lo, lo+chunk_tiles)`` for ``lo in range(pin_tiles, num_tiles,
    chunk_tiles)`` and, under selective execution, skips the fetch of any
    chunk containing no active tile (``tile_active`` from
    :func:`repro.core.dsss.active_tile_mask`). Chunks are fetched whole —
    partial-chunk gathers would break the prefetch pipeline — so the
    streamed count is the sum of full chunk sizes over active chunks.
    ``packed_h2d_bytes(selective_streamed_tiles(...), tile_edges)`` is
    the exact per-sweep ``bytes_h2d`` oracle; with ``pin_tiles`` set to
    the pin+host-cache boundary it is the ``bytes_disk_read`` oracle
    (both boundaries lie on the chunk grid by construction).
    """
    act = np.asarray(tile_active, dtype=bool)
    nt = int(act.shape[0])
    streamed = 0
    for lo in range(pin_tiles, nt, chunk_tiles):
        hi = min(lo + chunk_tiles, nt)
        if act[lo:hi].any():
            streamed += hi - lo
    return streamed


def streamed_block_bytes(block_nbytes, resident, active_rows=None) -> float:
    """Closed-form per-sweep ``bytes_h2d`` of the per-block host executor.

    ``block_nbytes`` maps sub-shard key ``(i, j)`` → raw bytes of its
    bucket-padded device arrays; a sweep ships every processed non-pinned
    block host→device once. ``active_rows`` restricts the sweep to active
    source intervals exactly as :func:`disk_read_bytes` does.
    """
    return float(
        sum(
            b
            for k, b in block_nbytes.items()
            if k not in resident and (active_rows is None or active_rows[k[0]])
        )
    )


def selective_edge_bytes(block_edges, resident, active_rows, Be) -> float:
    """Modelled edge-byte charge (``Be`` per edge) of one selective sweep.

    The model-side counterpart of :func:`streamed_block_bytes`:
    ``block_edges`` maps sub-shard key ``(i, j)`` → real edge count, and
    the charge covers every processed non-resident block. This is the
    activity term of the Table II read formulas — with ``active_rows``
    all-True it reduces to the full-sweep ``m·Be`` minus the resident
    prefix, which is what the original closed forms charge.
    """
    return float(
        sum(
            e * Be
            for k, e in block_edges.items()
            if k not in resident and (active_rows is None or active_rows[k[0]])
        )
    )


def calibrate_edge_bytes(p: IOParams, meters) -> float:
    """Physical bytes per modelled edge byte, from actual transfers.

    The model charges ``Be`` per edge; the machine ships bucket-padded
    int32 index buffers (+weights). ``meters.bytes_h2d /
    meters.bytes_read_edges`` is the measured inflation factor; multiply
    ``p.Be`` by it to predict wall-clock transfer volume from the closed
    forms. Returns ``p.Be`` unchanged when nothing was physically
    streamed (device residency).
    """
    if getattr(meters, "bytes_h2d", 0.0) <= 0.0 or meters.bytes_read_edges <= 0.0:
        return float(p.Be)
    return float(p.Be) * meters.bytes_h2d / meters.bytes_read_edges


def select_strategy(
    p: IOParams, B_M: int | None, *, host_B_M: int | None = None
) -> StrategyChoice:
    """Adaptive selection (paper abstract / §III-B).

    SPU whenever both ping-pong interval copies fit; otherwise MPU with the
    largest feasible Q (which degenerates to DPU at Q == 0). MPU's modelled
    I/O is monotone in Q, so no search is needed.

    ``host_B_M`` extends the two-level model to the three-tier
    disk/host/device hierarchy of ``residency="disk"`` (the session
    passes ``host_memory_budget`` here for disk-backed compiles):
    ``B_M`` remains the fast-tier (device) budget that drives the
    SPU/MPU/DPU split, and ``host_B_M`` is the mid-tier (host RAM)
    budget. Edge topology that fits neither the device pins nor the host
    cache re-streams from disk every sweep, adding
    ``max(0, m·Be − device_pinned − host_B_M)`` to the modelled read — a
    strategy-independent-shaped term except that SPU's device pins (its
    budget leftover after both *padded* attribute copies, ``2·n_pad·Ba``,
    matching ``GraphSession._resolve_residency``) also shelter edges
    from the disk tier. Like SPU residency itself, the enforcement is
    block-granular, so the continuous term here may undershoot the
    enforced traffic by up to one (largest) sub-shard — the same
    documented slack as :class:`IOComparison`.
    """
    if B_M is None:
        choice = StrategyChoice("spu", p.P, 0.0, 0.0)
    elif B_M >= 2 * p.P * -(-p.n // p.P) * p.Ba:  # 2 · n_pad · Ba
        r, w = spu_io(p, B_M)
        choice = StrategyChoice("spu", p.P, r, w)
    else:
        Q = mpu_q(p, B_M)
        r, w = mpu_io(p, B_M)
        choice = StrategyChoice("dpu" if Q == 0 else "mpu", Q, r, w)
    if host_B_M is not None:
        if choice.strategy == "spu":
            n_pad = p.P * -(-p.n // p.P)
            pinned = (
                p.m * p.Be
                if B_M is None
                else max(0, B_M - 2 * n_pad * p.Ba)
            )
        else:
            pinned = 0
        disk = max(0.0, p.m * p.Be - min(pinned, p.m * p.Be) - host_B_M)
        choice = StrategyChoice(
            choice.strategy,
            choice.Q,
            choice.modelled_read + disk,
            choice.modelled_write,
        )
    return choice
