"""High-level graph algorithms on the Session/Plan API (paper §IV tasks).

``pagerank`` / ``bfs`` / ``wcc`` / ``sssp`` are thin drivers that stage the
graph into a (LRU-cached) :class:`~repro.core.session.GraphSession` and run
one :class:`~repro.core.plan.ExecutionPlan`; repeated calls on the same
graph object re-use the staged blocks and jit caches. ``multi_bfs`` /
``multi_sssp`` are the batched drivers: K sources share one streamed pass
over the edge blocks (``session.run_batch``). ``scc`` is the
forward-backward colouring driver (trim + max-label forward propagation +
backward reachability), matching what single-machine engines of this
family implement on top of their iteration primitive — its repeated
forward/backward runs are exactly the "stage once, run many" access
pattern the session exists for.
"""
from __future__ import annotations

import numpy as np

from repro.core.dsss import DSSSGraph, build_dsss
from repro.core.plan import ExecutionPlan
from repro.core.session import (
    BatchResult,
    GraphSession,
    IdentityLRU,
    Result,
    get_session,
)
from repro.core.vertex_programs import (
    BFS,
    INF_DEPTH,
    WCC,
    MaxLabelForward,
    PageRank,
    ReachBackward,
    SSSP,
)
from repro.graph.preprocess import EdgeList

__all__ = ["pagerank", "bfs", "wcc", "sssp", "scc", "multi_bfs", "multi_sssp"]


# Sharded-graph LRU keyed by edge-list identity, so repeated driver calls
# on the same EdgeList hit the same DSSSGraph object — and therefore the
# same staged GraphSession (get_session is keyed by graph identity).
_DSSS_LRU = IdentityLRU(size=8)


def _as_graph(g: EdgeList | DSSSGraph, P: int) -> DSSSGraph:
    if isinstance(g, DSSSGraph):
        return g
    return _DSSS_LRU.get_or_build(g, (P,), lambda: build_dsss(g, P))


def _session(
    g,
    P: int,
    memory_budget: int | None,
    residency: str = "auto",
    execution: str = "auto",
) -> GraphSession:
    # Every axis flows into get_session's variant key, so drivers called
    # with different residency/execution knobs never wrongly share (or
    # spuriously duplicate) a pooled session.
    return get_session(
        _as_graph(g, P),
        memory_budget=memory_budget,
        residency=residency,
        execution=execution,
    )


def pagerank(
    g: EdgeList | DSSSGraph,
    *,
    P: int = 8,
    iters: int = 20,
    damping: float = 0.85,
    tol: float = 0.0,
    strategy: str = "auto",
    memory_budget: int | None = None,
    residency: str = "auto",
    execution: str = "auto",
) -> Result:
    sess = _session(g, P, memory_budget, residency, execution)
    # tol=0 → fixed iteration count (paper runs 10 PageRank iterations).
    return sess.run(
        ExecutionPlan(
            PageRank(damping=damping), strategy=strategy, max_iters=iters, tol=tol
        )
    )


def bfs(
    g: EdgeList | DSSSGraph,
    root: int = 0,
    *,
    P: int = 8,
    strategy: str = "auto",
    memory_budget: int | None = None,
    residency: str = "auto",
    execution: str = "auto",
) -> Result:
    sess = _session(g, P, memory_budget, residency, execution)
    return sess.run(
        ExecutionPlan(
            BFS(),
            strategy=strategy,
            max_iters=sess.graph.n + 1,
            program_kwargs={"root": root},
        )
    )


def multi_bfs(
    g: EdgeList | DSSSGraph,
    sources,
    *,
    P: int = 8,
    strategy: str = "auto",
    memory_budget: int | None = None,
    residency: str = "auto",
    execution: str = "auto",
    server=None,
) -> BatchResult:
    """BFS from K sources in one batched pass over the edge blocks.

    All K depth frontiers advance together: each sub-shard is streamed once
    per sweep (``meters.bytes_read_edges`` is the single-query cost, not
    K×) while the vmapped block primitives update K attribute states.

    With ``server=`` (a :class:`repro.serving.GraphServer`) the K sources
    are submitted as individual point queries instead: they flow through
    the server's queue and dynamic micro-batcher — which fuses them back
    onto ``run_batch`` — and return the same ``BatchResult`` shape, with
    identical per-query results.
    """
    sess = _session(g, P, memory_budget, residency, execution)
    plans = [
        ExecutionPlan(
            BFS(),
            strategy=strategy,
            max_iters=sess.graph.n + 1,
            program_kwargs={"root": int(r)},
        )
        for r in sources
    ]
    if server is not None:
        return server.serve_plans(
            sess.graph,
            plans,
            memory_budget=memory_budget,
            residency=residency,
            execution=execution,
        )
    return sess.run_batch(plans)


def wcc(
    g: EdgeList | DSSSGraph,
    *,
    P: int = 8,
    strategy: str = "auto",
    memory_budget: int | None = None,
    residency: str = "auto",
    execution: str = "auto",
) -> Result:
    """Weakly connected components — min-label propagation.

    WCC is defined on the *undirected* graph, so the propagation must run
    on a symmetrized edge set. An :class:`EdgeList` is symmetrized here
    (``g.symmetrized()``) before sharding; a pre-built :class:`DSSSGraph`
    must already be symmetric — callers shard with
    ``build_dsss(el.symmetrized(), P)`` — and an asymmetric one raises
    :class:`ValueError` instead of silently returning per-direction
    pseudo-components.
    """
    if isinstance(g, EdgeList):
        # Freshly built per call: a throwaway session, not an LRU slot —
        # the staged blocks must not outlive the call.
        graph = build_dsss(g.symmetrized(), P)
        sess = GraphSession(
            graph,
            memory_budget=memory_budget,
            residency=residency,
            execution=execution,
        )
    else:
        graph = g
        if not np.array_equal(graph.in_degree, graph.out_degree):
            raise ValueError(
                "wcc requires a symmetrized graph; this DSSSGraph has "
                "in_degree != out_degree. Build it with "
                "build_dsss(edge_list.symmetrized(), P), or pass the "
                "EdgeList itself and let wcc symmetrize."
            )
        sess = get_session(
            graph,
            memory_budget=memory_budget,
            residency=residency,
            execution=execution,
        )
    return sess.run(
        ExecutionPlan(WCC(), strategy=strategy, max_iters=graph.n + 1)
    )


def sssp(
    g: EdgeList | DSSSGraph,
    root: int = 0,
    *,
    P: int = 8,
    strategy: str = "auto",
    memory_budget: int | None = None,
    residency: str = "auto",
    execution: str = "auto",
) -> Result:
    sess = _session(g, P, memory_budget, residency, execution)
    return sess.run(
        ExecutionPlan(
            SSSP(),
            strategy=strategy,
            max_iters=sess.graph.n + 1,
            program_kwargs={"root": root},
        )
    )


def multi_sssp(
    g: EdgeList | DSSSGraph,
    sources,
    *,
    P: int = 8,
    strategy: str = "auto",
    memory_budget: int | None = None,
    residency: str = "auto",
    execution: str = "auto",
    server=None,
) -> BatchResult:
    """Weighted shortest paths from K sources, one streamed pass (batched).

    ``server=`` routes the K sources through the serving micro-batcher
    (see :func:`multi_bfs`).
    """
    sess = _session(g, P, memory_budget, residency, execution)
    plans = [
        ExecutionPlan(
            SSSP(),
            strategy=strategy,
            max_iters=sess.graph.n + 1,
            program_kwargs={"root": int(r)},
        )
        for r in sources
    ]
    if server is not None:
        return server.serve_plans(
            sess.graph,
            plans,
            memory_budget=memory_budget,
            residency=residency,
            execution=execution,
        )
    return sess.run_batch(plans)


def scc(
    el: EdgeList,
    *,
    P: int = 8,
    strategy: str = "auto",
    memory_budget: int | None = None,
    max_rounds: int = 10_000,
) -> np.ndarray:
    """Strongly connected components via trim + forward-backward colouring.

    Returns ``labels (n,)`` where ``labels[v]`` is the id of a canonical
    vertex of v's SCC (the max id reaching v within the component).

    Rounds:
      0. *Trim*: peel vertices with zero in- or out-degree within the live
         subgraph (each is its own SCC) until fixpoint.
      1. *Colour*: forward max-label propagation — ``color(v)`` = max live id
         that reaches v.
      2. *Roots*: vertices with ``color(v) == v``.
      3. *Reach*: backward propagation (on the transpose) of a reach flag
         from roots, restricted to same-colour edges. Reached vertices of
         colour c form exactly SCC(c); extract and go to 0.

    Both graphs are staged once; every round re-uses the two sessions.
    """
    fwd = build_dsss(el, P)
    bwd = build_dsss(el.reversed(), P)
    n, n_pad = fwd.n, fwd.n_pad
    # Both graphs are built per call, so the sessions are local too (they
    # are re-used across every colour/reach round below, then released).
    sess_fwd = GraphSession(fwd, memory_budget=memory_budget)
    sess_bwd = GraphSession(bwd, memory_budget=memory_budget)

    src, dst = el.src, el.dst
    mask = np.zeros(n_pad, np.int32)
    mask[:n] = 1
    labels = np.full(n, -1, np.int64)

    for _ in range(max_rounds):
        live = mask[:n].astype(bool)
        if not live.any():
            break
        # -- trim loop -------------------------------------------------------
        while True:
            live_edge = live[src] & live[dst]
            out_deg = np.bincount(src[live_edge], minlength=n)
            in_deg = np.bincount(dst[live_edge], minlength=n)
            trivial = live & ((out_deg == 0) | (in_deg == 0))
            if not trivial.any():
                break
            ids = np.nonzero(trivial)[0]
            labels[ids] = ids
            live[ids] = False
        mask[:n] = live.astype(np.int32)
        if not live.any():
            break
        # -- colour ----------------------------------------------------------
        init_labels = np.full(n_pad, -INF_DEPTH, np.int32)
        init_labels[:n][live] = np.nonzero(live)[0].astype(np.int32)
        res = sess_fwd.run(
            ExecutionPlan(
                MaxLabelForward(),
                strategy=strategy,
                max_iters=n + 1,
                program_kwargs={"labels": init_labels, "mask": mask},
            )
        )
        colors = np.full(n_pad, -1, np.int32)
        colors[:n] = res.attrs
        # -- roots & backward reach -------------------------------------------
        seed = np.zeros(n_pad, np.int32)
        root_ids = np.nonzero(live & (colors[:n] == np.arange(n)))[0]
        seed[root_ids] = 1
        res_b = sess_bwd.run(
            ExecutionPlan(
                ReachBackward(),
                strategy=strategy,
                max_iters=n + 1,
                program_kwargs={"reach": seed, "colors": colors, "mask": mask},
            )
        )
        reached = (res_b.attrs > 0) & live
        labels[reached] = colors[:n][reached]
        live[reached] = False
        mask[:n] = live.astype(np.int32)
    assert (labels >= 0).all(), "SCC driver failed to converge"
    return labels
