"""High-level graph algorithms on the NXgraph engine (paper §IV tasks).

``pagerank`` / ``bfs`` / ``wcc`` / ``sssp`` are thin drivers over one engine
run; ``scc`` is the forward-backward colouring driver (trim + max-label
forward propagation + backward reachability), matching what single-machine
engines of this family implement on top of their iteration primitive.
"""
from __future__ import annotations

import numpy as np

from repro.core.dsss import DSSSGraph, build_dsss
from repro.core.engine import NXGraphEngine, Result
from repro.core.vertex_programs import (
    BFS,
    INF_DEPTH,
    WCC,
    MaxLabelForward,
    PageRank,
    ReachBackward,
    SSSP,
)
from repro.graph.preprocess import EdgeList

__all__ = ["pagerank", "bfs", "wcc", "sssp", "scc"]


def _as_graph(g: EdgeList | DSSSGraph, P: int) -> DSSSGraph:
    return g if isinstance(g, DSSSGraph) else build_dsss(g, P)


def pagerank(
    g: EdgeList | DSSSGraph,
    *,
    P: int = 8,
    iters: int = 20,
    damping: float = 0.85,
    tol: float = 0.0,
    strategy: str = "auto",
    memory_budget: int | None = None,
) -> Result:
    graph = _as_graph(g, P)
    prog = PageRank(damping=damping)
    eng = NXGraphEngine(
        graph, prog, strategy=strategy, memory_budget=memory_budget
    )
    # tol=0 → fixed iteration count (paper runs 10 PageRank iterations).
    return eng.run(max_iters=iters, tol=tol)


def bfs(
    g: EdgeList | DSSSGraph,
    root: int = 0,
    *,
    P: int = 8,
    strategy: str = "auto",
    memory_budget: int | None = None,
) -> Result:
    graph = _as_graph(g, P)
    eng = NXGraphEngine(
        graph, BFS(), strategy=strategy, memory_budget=memory_budget
    )
    return eng.run(max_iters=graph.n + 1, root=root)


def wcc(
    g: EdgeList,
    *,
    P: int = 8,
    strategy: str = "auto",
    memory_budget: int | None = None,
) -> Result:
    """Weakly connected components — runs on the symmetrized graph."""
    graph = build_dsss(g.symmetrized(), P) if isinstance(g, EdgeList) else g
    eng = NXGraphEngine(
        graph, WCC(), strategy=strategy, memory_budget=memory_budget
    )
    return eng.run(max_iters=graph.n + 1)


def sssp(
    g: EdgeList | DSSSGraph,
    root: int = 0,
    *,
    P: int = 8,
    strategy: str = "auto",
    memory_budget: int | None = None,
) -> Result:
    graph = _as_graph(g, P)
    eng = NXGraphEngine(
        graph, SSSP(), strategy=strategy, memory_budget=memory_budget
    )
    return eng.run(max_iters=graph.n + 1, root=root)


def scc(
    el: EdgeList,
    *,
    P: int = 8,
    strategy: str = "auto",
    memory_budget: int | None = None,
    max_rounds: int = 10_000,
) -> np.ndarray:
    """Strongly connected components via trim + forward-backward colouring.

    Returns ``labels (n,)`` where ``labels[v]`` is the id of a canonical
    vertex of v's SCC (the max id reaching v within the component).

    Rounds:
      0. *Trim*: peel vertices with zero in- or out-degree within the live
         subgraph (each is its own SCC) until fixpoint.
      1. *Colour*: forward max-label propagation — ``color(v)`` = max live id
         that reaches v.
      2. *Roots*: vertices with ``color(v) == v``.
      3. *Reach*: backward propagation (on the transpose) of a reach flag
         from roots, restricted to same-colour edges. Reached vertices of
         colour c form exactly SCC(c); extract and go to 0.
    """
    fwd = build_dsss(el, P)
    bwd = build_dsss(el.reversed(), P)
    n, n_pad = fwd.n, fwd.n_pad
    eng_fwd = NXGraphEngine(
        fwd, MaxLabelForward(), strategy=strategy, memory_budget=memory_budget
    )
    eng_bwd = NXGraphEngine(
        bwd, ReachBackward(), strategy=strategy, memory_budget=memory_budget
    )

    src, dst = el.src, el.dst
    mask = np.zeros(n_pad, np.int32)
    mask[:n] = 1
    labels = np.full(n, -1, np.int64)

    for _ in range(max_rounds):
        live = mask[:n].astype(bool)
        if not live.any():
            break
        # -- trim loop -------------------------------------------------------
        while True:
            live_edge = live[src] & live[dst]
            out_deg = np.bincount(src[live_edge], minlength=n)
            in_deg = np.bincount(dst[live_edge], minlength=n)
            trivial = live & ((out_deg == 0) | (in_deg == 0))
            if not trivial.any():
                break
            ids = np.nonzero(trivial)[0]
            labels[ids] = ids
            live[ids] = False
        mask[:n] = live.astype(np.int32)
        if not live.any():
            break
        # -- colour ----------------------------------------------------------
        init_labels = np.full(n_pad, -INF_DEPTH, np.int32)
        init_labels[:n][live] = np.nonzero(live)[0].astype(np.int32)
        res = eng_fwd.run(
            max_iters=n + 1, labels=init_labels, mask=mask
        )
        colors = np.full(n_pad, -1, np.int32)
        colors[:n] = res.attrs
        # -- roots & backward reach -------------------------------------------
        seed = np.zeros(n_pad, np.int32)
        root_ids = np.nonzero(live & (colors[:n] == np.arange(n)))[0]
        seed[root_ids] = 1
        res_b = eng_bwd.run(
            max_iters=n + 1, reach=seed, colors=colors, mask=mask
        )
        reached = (res_b.attrs > 0) & live
        labels[reached] = colors[:n][reached]
        live[reached] = False
        mask[:n] = live.astype(np.int32)
    assert (labels >= 0).all(), "SCC driver failed to converge"
    return labels
