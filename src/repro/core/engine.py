"""Back-compat engine facade over the Session/Plan execution API.

The NXgraph update engine (SPU / DPU / MPU schedules, paper §III-B) now
lives in :mod:`repro.core.session`: a :class:`~repro.core.session.
GraphSession` owns the device-staged DSSS blocks and executes
:class:`~repro.core.plan.ExecutionPlan` jobs against them, including
batched multi-query passes (``session.run_batch``).

:class:`NXGraphEngine` is kept as a thin shim for existing callers: it
binds one (graph, program) pair to a private session and forwards
``run()`` to ``session.run(plan)``. Direct engine construction is
**deprecated** for new code — it re-stages the graph per program, which is
exactly the coupling the session API removes. Prefer::

    session = GraphSession(graph, memory_budget=...)
    result  = session.run(ExecutionPlan(PageRank(), max_iters=20, tol=0.0))

``Meters`` / ``Result`` are re-exported unchanged.
"""
from __future__ import annotations

from repro.core.dsss import DSSSGraph
from repro.core.plan import ExecutionPlan
from repro.core.session import GraphSession, Meters, Result

__all__ = ["NXGraphEngine", "Meters", "Result"]


class NXGraphEngine:
    """Host-scheduled NXgraph engine over a :class:`DSSSGraph` (shim).

    Args:
      graph: sharded graph.
      program: vertex program (semiring decomposition of Update).
      strategy: "auto" | "spu" | "dpu" | "mpu" | "fused" | a registered
        custom strategy. "auto" applies the paper's adaptive selection
        from ``memory_budget``.
      memory_budget: bytes of fast-tier memory (B_M). ``None`` = unlimited.
      residency: "device" | "host" | "disk" | "auto" — whether the budget
        is merely modelled (device-staged blocks, seed behaviour) or
        enforced by host- or disk-streamed execution ("disk" needs a
        disk-backed shared ``session`` opened via
        :meth:`GraphSession.open`). See :class:`GraphSession`. ``None``
        defaults to "auto" (host streaming iff a budget is set).
      execution: "per_block" | "packed" | "packed_kernel" | "auto" —
        host-scheduled dispatch-per-sub-shard vs. one compiled scan per
        update sweep (chunk-streamed under host residency) vs. the fused
        Pallas tile kernel. See :class:`GraphSession`. ``None`` defaults
        to "auto" (the best packed mode wherever one applies); results
        and model meters are identical.
      packing: "adaptive" | "subshard" | "auto" tile layout for packed
        execution (see :class:`GraphSession`). ``None`` defaults to
        "auto".
      Be: bytes per edge in the I/O model (8 = two int32 ids).
      Bv: bytes per vertex id.
      session: share an existing staged session instead of staging a new
        one (the upgrade path to the Session/Plan API).
    """

    def __init__(
        self,
        graph: DSSSGraph,
        program,
        *,
        strategy: str = "auto",
        memory_budget: int | None = None,
        residency: str | None = None,
        execution: str | None = None,
        packing: str | None = None,
        Be: int | None = None,
        Bv: int | None = None,
        session: GraphSession | None = None,
    ):
        if session is None:
            session = GraphSession(
                graph,
                memory_budget=memory_budget,
                residency="auto" if residency is None else residency,
                packing="auto" if packing is None else packing,
                Be=8 if Be is None else Be,
                Bv=4 if Bv is None else Bv,
            )
        else:
            # A shared session already fixes the staging + I/O-model
            # configuration; reject silently-ignored conflicting arguments.
            if session.graph is not graph:
                raise ValueError(
                    "session was staged for a different graph object than `graph`"
                )
            if residency is not None and session.resolved_residency(
                residency
            ) != session.resolved_residency():
                raise ValueError(
                    f"residency={residency!r} conflicts with the shared "
                    f"session's residency ({session.residency!r}); configure "
                    "it on the GraphSession"
                )
            if memory_budget is not None and memory_budget != session.memory_budget:
                raise ValueError(
                    f"memory_budget={memory_budget} conflicts with the shared "
                    f"session's budget ({session.memory_budget}); configure the "
                    "budget on the GraphSession"
                )
            expect_Be = None if Be is None else Be + (4 if session.has_weights else 0)
            if expect_Be is not None and expect_Be != session.Be:
                raise ValueError(
                    f"Be={Be} conflicts with the shared session's edge size; "
                    "configure Be on the GraphSession"
                )
            if Bv is not None and Bv != session.Bv:
                raise ValueError(
                    f"Bv={Bv} conflicts with the shared session's vertex-id "
                    "size; configure Bv on the GraphSession"
                )
            if (
                packing is not None
                and packing != "auto"
                and packing != session.packing
            ):
                raise ValueError(
                    f"packing={packing!r} conflicts with the shared session's "
                    f"tile packing ({session.packing!r}); configure it on the "
                    "GraphSession"
                )
        self.session = session
        self.g = graph
        self.program = program
        self.memory_budget = session.memory_budget
        self._strategy = strategy
        # Per-plan override: a shared session keeps its own default and
        # other engines on the same session are unaffected.
        self._execution = execution
        compiled = session.compile(
            ExecutionPlan(program, strategy=strategy, execution=execution)
        )
        self.params = compiled.params
        self.choice = compiled.choice
        self.resident = compiled.resident
        self.execution = compiled.execution

    # -- staged state (delegated to the shared session) ----------------------
    @property
    def blocks(self):
        return self.session.blocks

    @property
    def Be(self) -> int:
        return self.session.Be

    @property
    def Bv(self) -> int:
        return self.session.Bv

    @property
    def has_weights(self) -> bool:
        return self.session.has_weights

    # -- public API ----------------------------------------------------------
    def run(
        self,
        max_iters: int = 200,
        tol: float = 1e-10,
        checkpoint=None,
        resume_from=None,
        cancel=None,
        **program_kwargs,
    ) -> Result:
        """Forward to ``session.run``.

        ``checkpoint`` (a :class:`repro.reliability.CheckpointSpec`),
        ``resume_from`` and ``cancel`` pass straight through to the
        Session/Plan reliability machinery — see
        :meth:`GraphSession.run`.
        """
        plan = ExecutionPlan(
            self.program,
            strategy=self._strategy,
            max_iters=max_iters,
            tol=tol,
            execution=self._execution,
            checkpoint=checkpoint,
            program_kwargs=program_kwargs,
        )
        return self.session.run(plan, resume_from=resume_from, cancel=cancel)
