"""The NXgraph update engine: SPU / DPU / MPU schedules (paper §III-B).

Single-host execution model: the scheduler runs on the host (as NXgraph's
does), dispatching jitted block primitives per sub-shard; attribute state
lives on device. Three faithful strategies plus a beyond-paper ``fused``
strategy (whole iteration as one XLA program — the TPU fast path where
"disk" is HBM and XLA streams the edge buffer).

Byte meters: every strategy meters the bytes that cross the slow tier
(edges streamed, intervals loaded/spilled, hubs written/read) so the paper's
Table II closed forms can be property-tested against real schedules.

Activity tracking (paper §II-B): per-interval active flags; a monotone
program (BFS/WCC/SSSP) skips sub-shard rows whose source interval is
inactive; execution terminates when all intervals are inactive.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dsss import DSSSGraph
from repro.core.iomodel import IOParams, StrategyChoice, select_strategy
from repro.core.vertex_programs import VertexProgram, reduce_identity

__all__ = ["NXGraphEngine", "Meters", "Result"]


def _next_bucket(e: int, minimum: int = 8) -> int:
    b = minimum
    while b < e:
        b *= 2
    return b


@dataclasses.dataclass
class Meters:
    """Slow-tier byte counters + scheduling statistics."""

    bytes_read_edges: float = 0.0
    bytes_read_intervals: float = 0.0
    bytes_read_hubs: float = 0.0
    bytes_written_hubs: float = 0.0
    bytes_written_intervals: float = 0.0
    iterations: int = 0
    blocks_processed: int = 0
    blocks_skipped: int = 0
    edges_processed: int = 0
    wall_seconds: float = 0.0

    @property
    def bytes_read(self) -> float:
        return self.bytes_read_edges + self.bytes_read_intervals + self.bytes_read_hubs

    @property
    def bytes_written(self) -> float:
        return self.bytes_written_hubs + self.bytes_written_intervals

    @property
    def bytes_total(self) -> float:
        return self.bytes_read + self.bytes_written

    def per_iteration(self) -> "Meters":
        k = max(self.iterations, 1)
        out = Meters(**{f.name: getattr(self, f.name) for f in dataclasses.fields(self)})
        for f in (
            "bytes_read_edges",
            "bytes_read_intervals",
            "bytes_read_hubs",
            "bytes_written_hubs",
            "bytes_written_intervals",
        ):
            setattr(out, f, getattr(self, f) / k)
        return out

    def mteps(self) -> float:
        """Million traversed edges per second (paper Fig. 11 metric)."""
        if self.wall_seconds <= 0:
            return float("nan")
        return self.edges_processed / self.wall_seconds / 1e6


@dataclasses.dataclass
class Result:
    attrs: np.ndarray
    output: Any
    iterations: int
    converged: bool
    meters: Meters
    strategy: StrategyChoice


# ---------------------------------------------------------------------------
# Jitted block primitives. ``program`` is a frozen dataclass => hashable =>
# usable as a static argument; jit caches one executable per
# (program, bucket, num_segments) combination.
# ---------------------------------------------------------------------------
@functools.partial(
    jax.jit, static_argnames=("program", "num_segments", "has_weights")
)
def _block_gather_reduce(
    program: VertexProgram,
    prev_src: jnp.ndarray,  # (isize,) source-interval attributes
    src_aux: dict,  # per-source-interval aux (1-D sliced or scalar)
    dst_aux: dict,  # per-dest-interval aux (or empty)
    src_local: jnp.ndarray,  # (bucket,)
    dst_local: jnp.ndarray,  # (bucket,)
    weights: jnp.ndarray | None,
    e_valid: jnp.ndarray,  # scalar int32: real edge count in the bucket
    acc: jnp.ndarray,  # (num_segments,) running ⊕ accumulator
    num_segments: int,
    has_weights: bool,
):
    vals = prev_src[src_local]
    s_aux = {k: (v[src_local] if getattr(v, "ndim", 0) == 1 else v) for k, v in src_aux.items()}
    d_aux = (
        {k: (v[dst_local] if getattr(v, "ndim", 0) == 1 else v) for k, v in dst_aux.items()}
        if program.needs_dst_aux
        else None
    )
    contrib = program.gather(vals, weights if has_weights else None, s_aux, d_aux)
    ident = reduce_identity(program.reduce, contrib.dtype)
    mask = jnp.arange(contrib.shape[0]) < e_valid
    contrib = jnp.where(mask, contrib, ident)
    if program.reduce == "sum":
        red = jax.ops.segment_sum(contrib, dst_local, num_segments=num_segments)
        return jnp.add(acc, red.astype(acc.dtype))
    if program.reduce == "min":
        red = jax.ops.segment_min(contrib, dst_local, num_segments=num_segments)
        return jnp.minimum(acc, red.astype(acc.dtype))
    red = jax.ops.segment_max(contrib, dst_local, num_segments=num_segments)
    return jnp.maximum(acc, red.astype(acc.dtype))


@functools.partial(
    jax.jit, static_argnames=("program", "num_segments", "has_weights")
)
def _block_to_hub(
    program: VertexProgram,
    prev_src: jnp.ndarray,
    src_aux: dict,
    dst_aux: dict,
    src_local: jnp.ndarray,
    hub_inv: jnp.ndarray,  # (bucket,) edge -> hub slot
    dst_local: jnp.ndarray,
    weights: jnp.ndarray | None,
    e_valid: jnp.ndarray,
    num_segments: int,  # number of hub slots (unique destinations), padded
    has_weights: bool,
):
    """ToHub (paper Alg. 6 line 4): partial ⊕ per unique destination."""
    vals = prev_src[src_local]
    s_aux = {k: (v[src_local] if getattr(v, "ndim", 0) == 1 else v) for k, v in src_aux.items()}
    d_aux = (
        {k: (v[dst_local] if getattr(v, "ndim", 0) == 1 else v) for k, v in dst_aux.items()}
        if program.needs_dst_aux
        else None
    )
    contrib = program.gather(vals, weights if has_weights else None, s_aux, d_aux)
    ident = reduce_identity(program.reduce, contrib.dtype)
    mask = jnp.arange(contrib.shape[0]) < e_valid
    contrib = jnp.where(mask, contrib, ident)
    if program.reduce == "sum":
        return jax.ops.segment_sum(contrib, hub_inv, num_segments=num_segments)
    if program.reduce == "min":
        return jax.ops.segment_min(contrib, hub_inv, num_segments=num_segments)
    return jax.ops.segment_max(contrib, hub_inv, num_segments=num_segments)


@functools.partial(jax.jit, static_argnames=("program",))
def _block_from_hub(
    program: VertexProgram,
    acc: jnp.ndarray,  # (isize,)
    hub_dst: jnp.ndarray,  # (u,) unique local destinations
    partial: jnp.ndarray,  # (u,) hub values
    u_valid: jnp.ndarray,  # scalar: real number of hub slots
):
    """FromHub (paper Alg. 6 line 11): fold one hub into the accumulator."""
    ident = reduce_identity(program.reduce, acc.dtype)
    mask = jnp.arange(partial.shape[0]) < u_valid
    partial = jnp.where(mask, partial.astype(acc.dtype), ident)
    if program.reduce == "sum":
        return acc.at[hub_dst].add(partial, mode="drop")
    if program.reduce == "min":
        return acc.at[hub_dst].min(partial, mode="drop")
    return acc.at[hub_dst].max(partial, mode="drop")


@functools.partial(jax.jit, static_argnames=("program",))
def _apply_interval(
    program: VertexProgram,
    old: jnp.ndarray,
    acc: jnp.ndarray,
    aux: dict,
    globals_: dict,
    valid: jnp.ndarray,  # (isize,) bool — mask off padding in the last interval
    tol: jnp.ndarray,
):
    new = program.apply(old, acc, aux, globals_)
    new = jnp.where(valid, new, old)
    changed = jnp.any(program.changed(old, new, tol) & valid)
    return new, changed


class NXGraphEngine:
    """Host-scheduled NXgraph engine over a :class:`DSSSGraph`.

    Args:
      graph: sharded graph.
      program: vertex program (semiring decomposition of Update).
      strategy: "auto" | "spu" | "dpu" | "mpu" | "fused".
        "auto" applies the paper's adaptive selection from ``memory_budget``.
      memory_budget: bytes of fast-tier memory (B_M). ``None`` = unlimited.
      Be: bytes per edge in the I/O model (8 = two int32 ids).
      Bv: bytes per vertex id.
    """

    def __init__(
        self,
        graph: DSSSGraph,
        program: VertexProgram,
        *,
        strategy: str = "auto",
        memory_budget: int | None = None,
        Be: int = 8,
        Bv: int = 4,
    ):
        self.g = graph
        self.program = program
        self.Be = Be + (4 if graph.weights is not None else 0)
        self.Bv = Bv
        self.params = IOParams(
            n=graph.n,
            m=graph.m,
            Ba=program.attr_bytes,
            Bv=self.Bv,
            Be=self.Be,
            d=graph.mean_hub_in_degree(),
            P=graph.P,
        )
        self.memory_budget = memory_budget
        if strategy == "auto":
            self.choice = select_strategy(self.params, memory_budget)
        else:
            Q = graph.P
            if strategy == "dpu":
                Q = 0
            elif strategy == "mpu":
                from repro.core.iomodel import mpu_q

                Q = mpu_q(self.params, memory_budget or 0)
            self.choice = StrategyChoice(strategy, Q, 0.0, 0.0)
        self._prepare_blocks()
        self._prepare_residency()

    # -- preparation --------------------------------------------------------
    def _prepare_blocks(self) -> None:
        """Stage padded per-sub-shard device arrays (the 'shard files')."""
        g = self.g
        self.blocks: dict[tuple[int, int], dict] = {}
        for i in range(g.P):
            for j in range(g.P):
                e = g.subshard_edge_count(i, j)
                if e == 0:
                    continue
                ss = g.subshard(i, j)
                b = _next_bucket(e)
                pad = b - e
                blk = {
                    "src_local": jnp.asarray(
                        np.pad(ss.src_local, (0, pad)), jnp.int32
                    ),
                    "dst_local": jnp.asarray(
                        np.pad(ss.dst_local, (0, pad)), jnp.int32
                    ),
                    "hub_inv": jnp.asarray(np.pad(ss.hub_inv, (0, pad)), jnp.int32),
                    "e_valid": jnp.asarray(e, jnp.int32),
                    "e": e,
                    "u": ss.num_unique_dst,
                }
                ub = _next_bucket(max(ss.num_unique_dst, 1))
                blk["hub_dst"] = jnp.asarray(
                    np.pad(ss.hub_dst, (0, ub - ss.num_unique_dst)), jnp.int32
                )
                blk["u_valid"] = jnp.asarray(ss.num_unique_dst, jnp.int32)
                blk["u_bucket"] = ub
                if ss.weights is not None:
                    blk["weights"] = jnp.asarray(
                        np.pad(ss.weights, (0, pad)), jnp.float32
                    )
                else:
                    blk["weights"] = None
                self.blocks[(i, j)] = blk
        self.has_weights = g.weights is not None

    def _prepare_residency(self) -> None:
        """SPU edge residency: leftover budget pins sub-shards in memory."""
        g = self.g
        self.resident: set[tuple[int, int]] = set()
        if self.choice.strategy != "spu":
            return
        if self.memory_budget is None:
            self.resident = set(self.blocks)
            return
        leftover = self.memory_budget - 2 * g.n_pad * self.params.Ba
        for key in sorted(self.blocks):  # row-major, as the SPU schedule runs
            cost = self.blocks[key]["e"] * self.Be
            if leftover >= cost:
                self.resident.add(key)
                leftover -= cost

    def _interval_aux(self, aux: dict, k: int) -> dict:
        isz = self.g.interval_size
        return {
            key: (v[k * isz : (k + 1) * isz] if getattr(v, "ndim", 0) == 1 else v)
            for key, v in aux.items()
        }

    # -- public API ----------------------------------------------------------
    def run(
        self,
        max_iters: int = 200,
        tol: float = 1e-10,
        **program_kwargs,
    ) -> Result:
        g, prog = self.g, self.program
        isz = g.interval_size
        attrs = prog.init_attrs(g, **program_kwargs).reshape(g.P, isz)
        active = prog.init_active(g, **program_kwargs)
        aux = prog.make_aux(g, **program_kwargs)
        valid = (jnp.arange(g.n_pad) < g.n).reshape(g.P, isz)
        tol_arr = jnp.asarray(tol, jnp.float32)
        meters = Meters()
        start = time.perf_counter()
        it = 0
        converged = False
        strat = self.choice.strategy
        for it in range(1, max_iters + 1):
            if not active.any():
                converged = True
                it -= 1
                break
            attrs, active = self._dispatch(
                strat, attrs, active, aux, valid, tol_arr, meters
            )
            meters.iterations += 1
        else:
            converged = not active.any()
        flat = attrs.reshape(-1)
        meters.wall_seconds = time.perf_counter() - start
        return Result(
            attrs=np.asarray(flat[: g.n]),
            output=prog.output(flat, g),
            iterations=it,
            converged=converged,
            meters=meters,
            strategy=self.choice,
        )

    # -- iteration bodies ----------------------------------------------------
    def _dispatch(self, strat, attrs, active, aux, valid, tol, meters):
        if strat == "fused":
            return self._iteration_fused(attrs, active, aux, valid, tol, meters)
        if strat == "spu":
            return self._iteration_spu(attrs, active, aux, valid, tol, meters)
        if strat == "dpu":
            return self._iteration_two_phase(
                attrs, active, aux, valid, tol, meters, Q=0
            )
        if strat == "mpu":
            return self._iteration_two_phase(
                attrs, active, aux, valid, tol, meters, Q=self.choice.Q
            )
        raise ValueError(f"unknown strategy {strat!r}")

    def _rows_to_process(self, active: np.ndarray) -> list[int]:
        """Monotone programs skip inactive source intervals (paper §II-B)."""
        if self.program.monotone:
            return [i for i in range(self.g.P) if active[i]]
        return list(range(self.g.P))

    def _iteration_spu(self, attrs, active, aux, valid, tol, meters: Meters):
        """Paper Algorithm 5: row-major, all intervals ping-pong resident."""
        g, prog = self.g, self.program
        isz = g.interval_size
        globals_ = prog.pre_iteration(attrs.reshape(-1), aux)
        ident = reduce_identity(prog.reduce, prog.dtype)
        acc = [jnp.full(isz, ident, prog.dtype) for _ in range(g.P)]
        touched = [False] * g.P
        rows = self._rows_to_process(active)
        for i in rows:
            src_aux_i = self._interval_aux(aux, i)
            for j in range(g.P):
                blk = self.blocks.get((i, j))
                if blk is None:
                    continue
                acc[j] = _block_gather_reduce(
                    prog,
                    attrs[i],
                    src_aux_i,
                    self._interval_aux(aux, j) if prog.needs_dst_aux else {},
                    blk["src_local"],
                    blk["dst_local"],
                    blk["weights"],
                    blk["e_valid"],
                    acc[j],
                    num_segments=isz,
                    has_weights=self.has_weights,
                )
                touched[j] = True
                meters.blocks_processed += 1
                meters.edges_processed += blk["e"]
                if (i, j) not in self.resident:
                    meters.bytes_read_edges += blk["e"] * self.Be
        meters.blocks_skipped += (g.P - len(rows)) * g.P
        new_rows = []
        active_next = np.zeros(g.P, dtype=bool)
        for j in range(g.P):
            if not touched[j] and prog.monotone:
                new_rows.append(attrs[j])
                continue
            new_j, changed = _apply_interval(
                prog, attrs[j], acc[j], self._interval_aux(aux, j), globals_, valid[j], tol
            )
            new_rows.append(new_j)
            active_next[j] = bool(changed)
        return jnp.stack(new_rows), active_next

    def _iteration_two_phase(
        self, attrs, active, aux, valid, tol, meters: Meters, Q: int
    ):
        """Paper Algorithms 6 (Q=0: DPU) and 7 (0<Q<P: MPU).

        Intervals < Q are ping-pong resident (SPU-like); intervals >= Q are
        cold: their contributions route through hubs and they are
        loaded/saved once per iteration.
        """
        g, prog = self.g, self.program
        isz = g.interval_size
        globals_ = prog.pre_iteration(attrs.reshape(-1), aux)
        ident = reduce_identity(prog.reduce, prog.dtype)
        acc = [jnp.full(isz, ident, prog.dtype) for _ in range(g.P)]
        touched = [False] * g.P
        hubs: dict[tuple[int, int], jnp.ndarray] = {}
        rows = self._rows_to_process(active)
        iv_bytes = isz * self.params.Ba

        def _direct(i: int, j: int, blk: dict) -> None:
            """UpdateInMemory (paper Alg. 7 lines 4, 10, 20)."""
            acc[j] = _block_gather_reduce(
                prog,
                attrs[i],
                self._interval_aux(aux, i),
                self._interval_aux(aux, j) if prog.needs_dst_aux else {},
                blk["src_local"],
                blk["dst_local"],
                blk["weights"],
                blk["e_valid"],
                acc[j],
                num_segments=isz,
                has_weights=self.has_weights,
            )
            touched[j] = True
            meters.bytes_read_edges += blk["e"] * self.Be
            meters.blocks_processed += 1
            meters.edges_processed += blk["e"]

        # Phase 1 (row-major): resident rows (i < Q) update resident
        # destinations (j < Q); cold rows (i >= Q) are loaded once, updating
        # resident destinations directly and cold destinations via ToHub.
        # Blocks (i < Q, j >= Q) are deferred to the column phase so that
        # only one cold accumulator is ever live (paper Alg. 7 lines 17-24).
        for i in rows:
            if i >= Q:
                meters.bytes_read_intervals += iv_bytes  # LoadFromDisk(I_i)
            for j in range(g.P):
                blk = self.blocks.get((i, j))
                if blk is None:
                    continue
                if j < Q:
                    _direct(i, j, blk)
                elif i >= Q:
                    # UpdateToHub (cold source AND cold destination).
                    partial = _block_to_hub(
                        prog,
                        attrs[i],
                        self._interval_aux(aux, i),
                        self._interval_aux(aux, j) if prog.needs_dst_aux else {},
                        blk["src_local"],
                        blk["hub_inv"],
                        blk["dst_local"],
                        blk["weights"],
                        blk["e_valid"],
                        num_segments=blk["u_bucket"],
                        has_weights=self.has_weights,
                    )
                    hubs[(i, j)] = partial
                    touched[j] = True
                    meters.bytes_read_edges += blk["e"] * self.Be
                    meters.bytes_written_hubs += blk["u"] * (
                        self.params.Ba + self.Bv
                    )
                    meters.blocks_processed += 1
                    meters.edges_processed += blk["e"]
        meters.blocks_skipped += (g.P - len(rows)) * g.P

        # Phase 2 (column-major): resident columns apply directly; cold
        # columns first take deferred resident-source blocks, then fold hubs,
        # then save (paper Alg. 6 lines 8-14 / Alg. 7 lines 17-26).
        new_rows: list[jnp.ndarray] = [None] * g.P  # type: ignore[list-item]
        active_next = np.zeros(g.P, dtype=bool)
        for j in range(g.P):
            if j >= Q:
                for i in rows:
                    if i < Q:
                        blk = self.blocks.get((i, j))
                        if blk is not None:
                            _direct(i, j, blk)
                for i in rows:
                    h = hubs.get((i, j))
                    if h is None:
                        continue
                    blk = self.blocks[(i, j)]
                    acc[j] = _block_from_hub(
                        prog, acc[j], blk["hub_dst"], h, blk["u_valid"]
                    )
                    meters.bytes_read_hubs += blk["u"] * (self.params.Ba + self.Bv)
            if not touched[j] and prog.monotone:
                new_rows[j] = attrs[j]
                continue
            if j >= Q and prog.monotone:
                # Monotone apply needs the previous attributes of a cold
                # interval — one extra interval read vs. the paper's
                # PageRank-style accounting (documented deviation).
                meters.bytes_read_intervals += iv_bytes
            new_j, changed = _apply_interval(
                prog, attrs[j], acc[j], self._interval_aux(aux, j), globals_, valid[j], tol
            )
            new_rows[j] = new_j
            active_next[j] = bool(changed)
            if j >= Q:
                meters.bytes_written_intervals += iv_bytes  # SaveToDisk(I_j)
        return jnp.stack(new_rows), active_next

    # -- beyond-paper fused path ----------------------------------------------
    def _iteration_fused(self, attrs, active, aux, valid, tol, meters: Meters):
        """One XLA program per iteration: global gather + segment-reduce.

        Produces bit-identical results to SPU for sum/min/max programs; this
        is the TPU-native fast path (HBM-resident, no host scheduling) and
        the baseline the Pallas kernel (kernels/dsss_spmv.py) is checked
        against.
        """
        g, prog = self.g, self.program
        if not hasattr(self, "_fused_arrays"):
            self._fused_arrays = dict(
                src=jnp.asarray(g.src, jnp.int32),
                dst=jnp.asarray(g.dst, jnp.int32),
                weights=None if g.weights is None else jnp.asarray(g.weights),
            )
        fa = self._fused_arrays
        flat, changed_iv = _fused_iteration(
            prog,
            attrs.reshape(-1),
            aux,
            fa["src"],
            fa["dst"],
            fa["weights"],
            valid.reshape(-1),
            tol,
            n_pad=g.n_pad,
            P=g.P,
            has_weights=self.has_weights,
        )
        meters.blocks_processed += len(self.blocks)
        meters.edges_processed += g.m
        return flat.reshape(g.P, g.interval_size), np.asarray(changed_iv)


@functools.partial(
    jax.jit, static_argnames=("program", "n_pad", "P", "has_weights")
)
def _fused_iteration(
    program: VertexProgram,
    attrs: jnp.ndarray,  # (n_pad,)
    aux: dict,
    src: jnp.ndarray,
    dst: jnp.ndarray,
    weights: jnp.ndarray | None,
    valid: jnp.ndarray,
    tol: jnp.ndarray,
    n_pad: int,
    P: int,
    has_weights: bool,
):
    globals_ = program.pre_iteration(attrs, aux)
    vals = attrs[src]
    s_aux = {k: (v[src] if getattr(v, "ndim", 0) == 1 else v) for k, v in aux.items()}
    d_aux = (
        {k: (v[dst] if getattr(v, "ndim", 0) == 1 else v) for k, v in aux.items()}
        if program.needs_dst_aux
        else None
    )
    contrib = program.gather(vals, weights if has_weights else None, s_aux, d_aux)
    if program.reduce == "sum":
        red = jax.ops.segment_sum(contrib, dst, num_segments=n_pad)
    elif program.reduce == "min":
        red = jax.ops.segment_min(contrib, dst, num_segments=n_pad)
    else:
        red = jax.ops.segment_max(contrib, dst, num_segments=n_pad)
    red = red.astype(attrs.dtype)
    new = program.apply(attrs, red, aux, globals_)
    new = jnp.where(valid, new, attrs)
    changed = program.changed(attrs, new, tol) & valid
    changed_iv = jnp.any(changed.reshape(P, -1), axis=1)
    return new, changed_iv
