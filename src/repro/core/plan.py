"""Execution plans — frozen, hashable job descriptions for a GraphSession.

An :class:`ExecutionPlan` is *what to run*: a vertex program, a strategy
name, iteration limits and tolerances, plus the program's Initialize
kwargs (e.g. a BFS root). It deliberately contains no device state — the
staged graph lives in :class:`repro.core.session.GraphSession` — so one
plan can be compiled against many sessions and one session can execute
many plans. Because plans are hashable they key the session's compile
cache directly, and because the engine's jitted block primitives take the
(frozen) program as a static argument, jit executables persist across
plans that share a program.

Program kwargs may contain numpy/JAX arrays (the SCC driver passes label
and mask vectors); they are frozen into content-hashed
:class:`FrozenArray` wrappers so the plan stays hashable with value
semantics.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping

import numpy as np

from repro.core.vertex_programs import VertexProgram
from repro.obs.trace import TraceSpec
from repro.reliability.checkpoint import CheckpointSpec

__all__ = ["CheckpointSpec", "ExecutionPlan", "FrozenArray", "TraceSpec"]


@dataclasses.dataclass(frozen=True)
class FrozenArray:
    """An immutable, content-hashed snapshot of an array-valued kwarg."""

    data: bytes
    shape: tuple[int, ...]
    dtype: str

    @classmethod
    def freeze(cls, value) -> "FrozenArray":
        arr = np.asarray(value)
        return cls(data=arr.tobytes(), shape=arr.shape, dtype=str(arr.dtype))

    def thaw(self) -> np.ndarray:
        return np.frombuffer(self.data, dtype=np.dtype(self.dtype)).reshape(
            self.shape
        )


def _freeze_value(v):
    if isinstance(v, FrozenArray):
        return v
    if isinstance(v, (np.ndarray,)) or type(v).__module__.startswith("jax"):
        return FrozenArray.freeze(v)
    if isinstance(v, (list, tuple)):
        return tuple(_freeze_value(x) for x in v)
    if isinstance(v, np.generic):
        return v.item()
    return v


def _thaw_value(v):
    if isinstance(v, FrozenArray):
        return v.thaw()
    if isinstance(v, tuple):
        return tuple(_thaw_value(x) for x in v)
    return v


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """One job against a staged graph.

    Args:
      program: the vertex program (frozen dataclass — hashable).
      strategy: "auto" | "spu" | "dpu" | "mpu" | "fused" | a registered
        custom strategy name. "auto" resolves against the session's
        memory budget at compile time (paper's adaptive selection).
      max_iters: update-sweep budget.
      tol: convergence tolerance handed to ``program.changed``.
      residency: per-plan override of the session's residency axis —
        ``None`` (inherit), "device", "host", "disk" (disk-backed
        sessions only — blocks/tiles stream from the mmap'd ``.dsss``
        store) or "auto" (disk for disk-backed sessions, else host iff
        the session has a memory budget). See
        :class:`repro.core.session.GraphSession` for the semantics.
      execution: per-plan override of the session's execution axis —
        ``None`` (inherit), "per_block", "packed", "packed_kernel" or
        "auto". "per_block" is the host-scheduled legacy path (one jit
        dispatch per sub-shard); "packed" runs each update sweep as one
        compiled scan over the destination-aligned tile layout — under
        host residency the tile chunks are streamed with double-buffered
        prefetch, so out-of-core runs stay packed; "packed_kernel" runs
        the same sweep inside the fused Pallas kernel
        (:mod:`repro.kernels.packed_sweep` — compiled on TPU,
        interpret-mode elsewhere). All packed modes are SPU/DPU/MPU
        only; fused/custom schedules downgrade to "per_block". "auto"
        picks "packed_kernel" where Pallas compiles natively, else
        "packed", whenever either applies. Results and modelled meters
        are identical in every case. See
        :class:`repro.core.session.GraphSession`.
      activity: frontier-aware selective execution — ``"auto"`` (default)
        lets monotone programs (BFS/SSSP/WCC — ``program.monotone``) skip
        inactive source intervals, inactive packed tiles and inactive
        streamed chunks, so compute *and* physical
        ``bytes_h2d``/``bytes_disk_read`` shrink with the frontier;
        ``"off"`` forces full sweeps (the A/B baseline — every interval is
        processed and every chunk is streamed every sweep). Results are
        bit-identical either way: skipped work contributes exact
        ⊕-identities by the monotone contract. Non-monotone programs
        (PageRank) always run full sweeps regardless of this axis.
      checkpoint: sweep-level checkpoint/resume
        (:class:`repro.reliability.CheckpointSpec`) — ``None`` (default)
        disables snapshots; otherwise the engine atomically snapshots
        vertex state + activity bitmaps + cumulative meters to
        ``checkpoint.directory`` every ``checkpoint.every`` sweeps
        (keep-N pruned), and ``session.run(plan, resume_from=...)``
        restores one and continues, bit-identical to an uninterrupted
        run.
      trace: structured tracing (:class:`repro.obs.TraceSpec`) — ``None``
        (default) records nothing beyond what a globally enabled
        ``repro.obs.TRACER`` captures; a spec turns the span recorder on
        for this run (staging, per-sweep byte deltas, checkpoint writes)
        and, when ``trace.path`` is set, exports the run's spans as
        Perfetto-loadable Chrome ``trace_event`` JSON on completion.
        Observational only: deliberately *excluded* from
        :meth:`batch_key`, so traced and untraced requests still fuse (a
        fused batch traces under its first member's spec).
      program_kwargs: Initialize kwargs (e.g. ``{"root": 3}``). Arrays are
        frozen by content; pass a mapping, it is normalized to a sorted
        tuple in ``__post_init__``. Names are validated against
        ``program.accepted_kwargs()`` — an unknown name raises
        :class:`TypeError` here instead of being silently swallowed by the
        lifecycle methods' ``**kw`` catch-alls.
    """

    program: VertexProgram
    strategy: str = "auto"
    max_iters: int = 200
    tol: float = 1e-10
    residency: str | None = None
    execution: str | None = None
    activity: str = "auto"
    checkpoint: CheckpointSpec | None = None
    trace: TraceSpec | None = None
    program_kwargs: Any = ()

    def __post_init__(self):
        if self.checkpoint is not None and not isinstance(
            self.checkpoint, CheckpointSpec
        ):
            raise TypeError(
                "checkpoint must be a repro.reliability.CheckpointSpec or "
                f"None, got {type(self.checkpoint).__name__}"
            )
        if self.trace is not None and not isinstance(self.trace, TraceSpec):
            raise TypeError(
                "trace must be a repro.obs.TraceSpec or None, "
                f"got {type(self.trace).__name__}"
            )
        if self.residency not in (None, "device", "host", "disk", "auto"):
            raise ValueError(
                "residency must be None, 'device', 'host', 'disk' or 'auto', "
                f"got {self.residency!r}"
            )
        if self.execution not in (
            None, "per_block", "packed", "packed_kernel", "auto"
        ):
            raise ValueError(
                "execution must be None, 'per_block', 'packed', "
                f"'packed_kernel' or 'auto', got {self.execution!r}"
            )
        if self.activity not in ("auto", "off"):
            raise ValueError(
                f"activity must be 'auto' or 'off', got {self.activity!r}"
            )
        kw = self.program_kwargs
        if isinstance(kw, Mapping):
            items = kw.items()
        else:
            items = tuple(kw)
        frozen = tuple(sorted((str(k), _freeze_value(v)) for k, v in items))
        accepted = self.program.accepted_kwargs()
        unknown = sorted(k for k, _ in frozen if k not in accepted)
        if unknown:
            if accepted:
                hint = f"accepted kwargs: {sorted(accepted)}"
            else:
                hint = "it accepts no program_kwargs"
            raise TypeError(
                f"unknown program_kwargs {unknown} for program "
                f"{self.program.name!r}; {hint}"
            )
        object.__setattr__(self, "program_kwargs", frozen)

    # -- accessors -----------------------------------------------------------
    def kwargs_dict(self) -> dict[str, Any]:
        """Thawed Initialize kwargs, ready for ``program.init_attrs(...)``."""
        return {k: _thaw_value(v) for k, v in self.program_kwargs}

    def with_kwargs(self, **kw) -> "ExecutionPlan":
        """A copy of this plan with updated program kwargs (e.g. new root)."""
        merged = self.kwargs_dict()
        merged.update(kw)
        return dataclasses.replace(self, program_kwargs=merged)

    def batch_key(self) -> tuple:
        """Plans sharing a batch_key can fuse into one streamed pass.

        This is the grouping key of both :meth:`GraphSession.run_batch`
        and the serving micro-batcher
        (:class:`repro.serving.server.GraphServer` buckets queued requests
        by ``(graph, batch_key())``): program, strategy, iteration limits
        and the residency/execution/activity axes must agree — Initialize
        kwargs
        (BFS roots, SSSP sources, seeds) may differ. It is a *necessary*
        condition; fusion additionally requires identical aux arrays,
        which ``run_batch`` re-verifies before fusing (and falls back to
        sequential execution when violated, e.g. two PageRank programs
        frozen with different damping).
        """
        return (
            self.program,
            self.strategy,
            self.max_iters,
            self.tol,
            self.residency,
            self.execution,
            self.activity,
            self.checkpoint,
        )

    def compatible_with(self, other: "ExecutionPlan") -> bool:
        """True iff the two plans may fuse into one streamed pass."""
        return self.batch_key() == other.batch_key()
