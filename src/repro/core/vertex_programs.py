"""Vertex programs — the paper's Initialize / Update / Output model (§II-B).

A :class:`VertexProgram` factors the per-sub-shard ``Update`` into the
semiring decomposition every strategy (SPU/DPU/MPU) shares:

  ``contribution = gather(src_attr, edge_weight, src_aux)``  (per edge)
  ``reduced      = ⊕ contributions grouped by destination``  (sum/min/max)
  ``new_attr     = apply(old_attr, reduced, aux, globals)``  (per vertex)

``reduce`` being an associative/commutative monoid is what makes hubs (DPU)
correct: a hub stores the partial ⊕ of one sub-shard, and FromHub ⊕-folds
hubs — exactly the paper's incremental-attribute argument.

``monotone=True`` marks programs where re-applying an old contribution is a
no-op (min/max with ``apply ⊇ old``); only those may skip inactive source
intervals (paper's activity tracking). PageRank is not monotone: it stops
only when *every* interval is inactive.
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.identities import INF_DEPTH, reduce_identity

__all__ = [
    "VertexProgram",
    "PageRank",
    "BFS",
    "WCC",
    "SSSP",
    "MaxLabelForward",
    "ReachBackward",
    "INF_DEPTH",
    "reduce_identity",
]


def _check_root(g, root: int) -> None:
    # jax's clamped .at[] indexing would otherwise run the query silently
    # from the wrong (or a padding) vertex.
    if not 0 <= int(root) < g.n:
        raise ValueError(
            f"root {root} out of range for graph with n={g.n} vertices"
        )


# reduce_identity lives in repro.core.identities (shared with the kernel
# path's padding identities); re-exported here for existing importers.


@dataclasses.dataclass(frozen=True)
class VertexProgram:
    """Base class. Subclasses override gather/apply/changed as pure fns."""

    name: str = "base"
    reduce: str = "sum"  # "sum" | "min" | "max"
    dtype: Any = jnp.float32
    monotone: bool = False
    attr_bytes: int = 4  # Ba in the paper's I/O model
    needs_dst_aux: bool = False  # gather also sees destination-side aux

    # -- lifecycle ----------------------------------------------------------
    def init_attrs(self, g, **kw) -> jnp.ndarray:  # (n_pad,)
        raise NotImplementedError

    def init_active(self, g, **kw) -> np.ndarray:  # (P,) bool
        return np.ones(g.P, dtype=bool)

    def make_aux(self, g, **kw) -> dict[str, jnp.ndarray]:
        """Per-vertex auxiliary arrays, gathered alongside attributes."""
        return {}

    def accepted_kwargs(self) -> frozenset:
        """The Initialize kwarg names this program accepts.

        Harvested from the *named* parameters of ``init_attrs`` /
        ``init_active`` / ``make_aux`` (their ``**kw`` catch-alls exist
        only so the three can share one kwargs dict — a name none of them
        declares is a caller mistake, not a silently ignorable extra).
        :class:`repro.core.plan.ExecutionPlan` validates ``program_kwargs``
        against this set at construction; programs whose lifecycle methods
        genuinely forward unknown names somewhere else may override.
        """
        names = set()
        for fn in (self.init_attrs, self.init_active, self.make_aux):
            for p in inspect.signature(fn).parameters.values():
                if p.name in ("self", "g") or p.kind in (
                    inspect.Parameter.VAR_KEYWORD,
                    inspect.Parameter.VAR_POSITIONAL,
                ):
                    continue
                names.add(p.name)
        return frozenset(names)

    def pre_iteration(self, attrs: jnp.ndarray, aux) -> dict[str, jnp.ndarray]:
        """Iteration-level scalars (e.g. PageRank dangling mass)."""
        return {}

    # -- semiring pieces (pure, jit-traceable) -------------------------------
    def gather(self, src_vals, weights, src_aux, dst_aux=None) -> jnp.ndarray:
        raise NotImplementedError

    def apply(self, old, reduced, aux, globals_) -> jnp.ndarray:
        raise NotImplementedError

    def changed(self, old, new, tol) -> jnp.ndarray:
        return jnp.abs(new - old) > tol

    def output(self, attrs: jnp.ndarray, g):
        return np.asarray(attrs[: g.n])


@dataclasses.dataclass(frozen=True)
class PageRank(VertexProgram):
    """Synchronous (personalized) PageRank with dangling-mass redistribution.

    Matches ``networkx.pagerank``'s iteration:
      ``p' = damping · (Aᵀ (p/outdeg) + dangling·r) + (1−damping)·r``,
    where the reset distribution ``r`` is uniform ``1/n`` by default, or a
    personalization vector via the Initialize kwargs:

    * ``personalize=v`` — a vertex id: ``r`` is the one-hot distribution
      at ``v`` (the PPR point query; like a BFS ``root``, so a batch of
      these fuses through :meth:`GraphSession.run_batch` /
      ``repro.serving`` via the vmap-stacked per-query aux).
    * ``reset_dist=arr`` — an explicit ``(n,)`` non-negative vector,
      normalized to sum 1 (teleport-set / topic-sensitive PageRank).

    The default (no kwargs) path builds byte-identical aux to the
    unpersonalized program, so existing plans batch and cache exactly as
    before; personalized plans add a per-vertex ``"reset"`` aux leaf and
    start from ``r`` (they never fuse with default plans — different aux
    keys fall back to sequential runs, results unchanged).
    """

    name: str = "pagerank"
    reduce: str = "sum"
    dtype: Any = jnp.float32
    monotone: bool = False
    attr_bytes: int = 8  # paper assumes 8-byte attributes for PageRank
    damping: float = 0.85

    def _reset(self, g, personalize, reset_dist) -> np.ndarray | None:
        """The (n_pad,) reset distribution, or None for uniform 1/n."""
        if personalize is not None and reset_dist is not None:
            raise ValueError(
                "pass either personalize (a vertex id) or reset_dist "
                "(an (n,) distribution), not both"
            )
        if personalize is not None:
            _check_root(g, personalize)
            r = np.zeros(g.n_pad, np.float32)
            r[int(personalize)] = 1.0
            return r
        if reset_dist is not None:
            rd = np.asarray(reset_dist, np.float64)
            if rd.shape != (g.n,):
                raise ValueError(
                    f"reset_dist must have shape ({g.n},), got {rd.shape}"
                )
            total = rd.sum()
            if rd.min() < 0 or not total > 0:
                raise ValueError(
                    "reset_dist must be non-negative with positive sum"
                )
            r = np.zeros(g.n_pad, np.float32)
            r[: g.n] = (rd / total).astype(np.float32)
            return r
        return None

    def init_attrs(self, g, personalize=None, reset_dist=None, **kw):
        r = self._reset(g, personalize, reset_dist)
        if r is None:
            a = jnp.zeros(g.n_pad, self.dtype)
            return a.at[: g.n].set(jnp.asarray(1.0 / g.n, self.dtype))
        # Personalized runs start at the reset distribution — the PPR
        # random walk's own stationary starting point.
        return jnp.asarray(r, self.dtype)

    def make_aux(self, g, personalize=None, reset_dist=None, **kw):
        deg = np.asarray(g.out_degree, np.float32)
        inv = np.where(deg > 0, 1.0 / np.maximum(deg, 1), 0.0).astype(np.float32)
        dangling = ((deg == 0) & (np.arange(g.n_pad) < g.n)).astype(np.float32)
        aux = {
            "inv_out_degree": jnp.asarray(inv),
            "dangling": jnp.asarray(dangling),
            "inv_n": jnp.asarray(1.0 / g.n, jnp.float32),
        }
        r = self._reset(g, personalize, reset_dist)
        if r is not None:
            aux["reset"] = jnp.asarray(r)
        return aux

    def pre_iteration(self, attrs, aux):
        mass = jnp.sum(attrs * aux["dangling"].reshape(attrs.shape))
        return {"dangling_mass": mass}

    def gather(self, src_vals, weights, src_aux, dst_aux=None):
        contrib = src_vals * src_aux["inv_out_degree"]
        if weights is not None:
            contrib = contrib * weights
        return contrib

    def apply(self, old, reduced, aux, globals_):
        # Teleport target: the personalization vector when present (also
        # where dangling mass re-enters, networkx's default dangling
        # behaviour), else the uniform 1/n scalar — same expression.
        reset = aux["reset"] if "reset" in aux else aux["inv_n"]
        base = (1.0 - self.damping) * reset
        return base + self.damping * (
            reduced + globals_["dangling_mass"] * reset
        )

    def output(self, attrs, g):
        return np.asarray(attrs[: g.n], np.float64)


@dataclasses.dataclass(frozen=True)
class BFS(VertexProgram):
    """Paper Algorithms 2–4: min-depth propagation from a root."""

    name: str = "bfs"
    reduce: str = "min"
    dtype: Any = jnp.int32
    monotone: bool = True
    attr_bytes: int = 4

    def init_attrs(self, g, root: int = 0, **kw):
        _check_root(g, root)
        a = jnp.full(g.n_pad, INF_DEPTH, self.dtype)
        return a.at[root].set(0)

    def init_active(self, g, root: int = 0, **kw):
        _check_root(g, root)
        act = np.zeros(g.P, dtype=bool)
        act[root // g.interval_size] = True
        return act

    def gather(self, src_vals, weights, src_aux, dst_aux=None):
        # depth+1, saturating so INF stays INF.
        return jnp.where(src_vals >= INF_DEPTH, INF_DEPTH, src_vals + 1)

    def apply(self, old, reduced, aux, globals_):
        return jnp.minimum(old, reduced)

    def changed(self, old, new, tol):
        return new != old

    def output(self, attrs, g):
        """Paper Algorithm 4: max finite depth (spanning-tree depth)."""
        a = np.asarray(attrs[: g.n])
        finite = a[a < INF_DEPTH]
        return int(finite.max()) if finite.size else 0


@dataclasses.dataclass(frozen=True)
class WCC(VertexProgram):
    """Weakly connected components: min-label propagation.

    Run on the *symmetrized* graph (``EdgeList.symmetrized()``).
    """

    name: str = "wcc"
    reduce: str = "min"
    dtype: Any = jnp.int32
    monotone: bool = True
    attr_bytes: int = 4

    def init_attrs(self, g, **kw):
        a = jnp.full(g.n_pad, INF_DEPTH, self.dtype)
        return a.at[: g.n].set(jnp.arange(g.n, dtype=self.dtype))

    def gather(self, src_vals, weights, src_aux, dst_aux=None):
        return src_vals

    def apply(self, old, reduced, aux, globals_):
        return jnp.minimum(old, reduced)

    def changed(self, old, new, tol):
        return new != old


@dataclasses.dataclass(frozen=True)
class SSSP(VertexProgram):
    """Single-source shortest path (weighted Bellman-Ford flavour)."""

    name: str = "sssp"
    reduce: str = "min"
    dtype: Any = jnp.float32
    monotone: bool = True
    attr_bytes: int = 4

    def init_attrs(self, g, root: int = 0, **kw):
        _check_root(g, root)
        a = jnp.full(g.n_pad, jnp.inf, self.dtype)
        return a.at[root].set(0.0)

    def init_active(self, g, root: int = 0, **kw):
        _check_root(g, root)
        act = np.zeros(g.P, dtype=bool)
        act[root // g.interval_size] = True
        return act

    def gather(self, src_vals, weights, src_aux, dst_aux=None):
        w = weights if weights is not None else 1.0
        return src_vals + w

    def apply(self, old, reduced, aux, globals_):
        return jnp.minimum(old, reduced)

    def changed(self, old, new, tol):
        return new < old


# ---------------------------------------------------------------------------
# SCC building blocks (forward-backward colouring; driver in algorithms.py).
# Masked variants: vertices with mask == 0 are spectators — they neither
# contribute nor update, which lets the SCC driver peel extracted components.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MaxLabelForward(VertexProgram):
    """Forward max-label propagation over the masked subgraph."""

    name: str = "scc_fwd"
    reduce: str = "max"
    dtype: Any = jnp.int32
    monotone: bool = True
    attr_bytes: int = 4

    def init_attrs(self, g, labels=None, **kw):
        if labels is not None:
            return jnp.asarray(labels, self.dtype)
        a = jnp.full(g.n_pad, -INF_DEPTH, self.dtype)
        return a.at[: g.n].set(jnp.arange(g.n, dtype=self.dtype))

    def make_aux(self, g, mask=None, **kw):
        if mask is None:
            mask = np.ones(g.n_pad, np.int32)
        return {"mask": jnp.asarray(mask, jnp.int32)}

    def gather(self, src_vals, weights, src_aux, dst_aux=None):
        return jnp.where(src_aux["mask"] > 0, src_vals, -INF_DEPTH)

    def apply(self, old, reduced, aux, globals_):
        new = jnp.maximum(old, reduced)
        return jnp.where(aux["mask"] > 0, new, old)

    def changed(self, old, new, tol):
        return new != old


@dataclasses.dataclass(frozen=True)
class ReachBackward(VertexProgram):
    """Backward reachability within a colour class (run on transpose graph).

    attr is 1 for vertices known to reach their colour root, else 0; a vertex
    inherits reachability from an out-neighbour of the *same colour*.
    """

    name: str = "scc_bwd"
    reduce: str = "max"
    dtype: Any = jnp.int32
    monotone: bool = True
    attr_bytes: int = 4
    needs_dst_aux: bool = True

    def init_attrs(self, g, reach=None, **kw):
        assert reach is not None, "seed reach with colour roots"
        return jnp.asarray(reach, self.dtype)

    def make_aux(self, g, mask=None, colors=None, **kw):
        assert colors is not None
        if mask is None:
            mask = np.ones(g.n_pad, np.int32)
        return {
            "mask": jnp.asarray(mask, jnp.int32),
            "color": jnp.asarray(colors, jnp.int32),
        }

    def gather(self, src_vals, weights, src_aux, dst_aux=None):
        # On the transpose graph, "src" is the original edge's destination:
        # a contribution is valid only when both endpoints share a colour
        # (SCCs never straddle colour classes) and the source can reach
        # its colour root.
        live = (src_aux["mask"] > 0) & (src_vals > 0)
        same_color = src_aux["color"] == dst_aux["color"]
        return jnp.where(live & same_color, 1, 0).astype(self.dtype)

    def apply(self, old, reduced, aux, globals_):
        hit = (reduced > 0) & (aux["mask"] > 0)
        return jnp.where(hit, jnp.ones_like(old), old)

    def changed(self, old, new, tol):
        return new != old
