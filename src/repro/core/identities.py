"""The ⊕-identities of the reduce monoids — single source of truth.

Two *different* identity families exist on purpose, and every consumer must
pick the right one:

* :func:`reduce_identity` — the **algorithmic** identity folded into
  accumulators and masked-off contributions by the engine
  (``core/session.py``) and the vertex programs. For integer min/max it is
  ``±INF_DEPTH`` (2³⁰), the programs' "unreached" sentinel: BFS depths
  saturate at it, so the identity must match what ``apply``/``output``
  compare against.
* :func:`padding_identity` — the **segment-op-compatible** padding value
  used by the Pallas kernel path (``kernels/dsss_spmv.py`` /
  ``kernels/ops.py``). It must equal what ``jax.ops.segment_min`` /
  ``segment_max`` put in *empty* segments (±inf for floats, the integer
  dtype's extrema for ints), because the kernel's windowed partials are
  checked bitwise against those reference reductions.

Before this module each file hand-rolled its own variant and the integer
min/max values had already drifted (``INF_DEPTH`` vs ``iinfo.max``) — which
is correct, but only as long as each stays on its side; keeping both in one
place makes the split explicit and un-driftable.
"""
from __future__ import annotations

from typing import Any

import jax.numpy as jnp
import numpy as np

__all__ = [
    "INF_DEPTH",
    "reduce_identity",
    "padding_identity",
    "padding_identity_value",
    "segment_fill_value",
]

# The programs' saturating "infinite depth / distance" for integer min/max
# attributes (BFS depth, SSSP hop counts). Small enough that `x + 1` never
# overflows int32 during the monotone relaxation.
INF_DEPTH = np.int32(2**30)


def reduce_identity(reduce: str, dtype) -> Any:
    """Algorithmic ⊕-identity (engine accumulators, masked contributions)."""
    if reduce == "sum":
        return jnp.zeros((), dtype)
    if reduce == "min":
        return (
            jnp.array(INF_DEPTH, dtype)
            if jnp.issubdtype(dtype, jnp.integer)
            else jnp.array(jnp.inf, dtype)
        )
    if reduce == "max":
        return (
            jnp.array(-INF_DEPTH, dtype)
            if jnp.issubdtype(dtype, jnp.integer)
            else jnp.array(-jnp.inf, dtype)
        )
    raise ValueError(f"unknown reduce {reduce!r}")


def padding_identity(reduce: str, dtype) -> jnp.ndarray:
    """Segment-op-compatible identity (Pallas kernel padding, jnp scalar).

    Matches ``jax.ops.segment_{sum,min,max}`` empty-segment fill values
    exactly, so identity-padded kernel inputs are bitwise equivalent to the
    reference segment reductions.
    """
    return jnp.asarray(padding_identity_value(reduce, dtype), dtype)


def padding_identity_value(reduce: str, dtype) -> float | int:
    """Python-scalar variant of :func:`padding_identity` for numpy staging."""
    if reduce == "sum":
        return 0.0
    if jnp.issubdtype(jnp.dtype(dtype), jnp.floating):
        big: float | int = float("inf")
    else:
        big = int(jnp.iinfo(dtype).max)
    if reduce == "min":
        return big
    if reduce == "max":
        return -big
    raise ValueError(f"unknown reduce {reduce!r}")


def segment_fill_value(reduce: str, dtype):
    """The *empty-segment* fill of ``jax.ops.segment_{sum,min,max}``.

    A third family, distinct from both above: ``segment_max`` fills empty
    int segments with ``iinfo.min`` (-2³¹), whereas :func:`padding_identity`
    is ``-iinfo.max`` (-2³¹+1) — off by one. The fused packed-sweep kernel
    (``kernels/packed_sweep.py``) initializes its windowed run accumulator
    with this value so untouched slots are *bitwise* what the reference
    segment ops of ``_packed_sweep_impl`` leave behind.
    """
    dt = jnp.dtype(dtype)
    if reduce == "sum":
        return jnp.zeros((), dt)
    if jnp.issubdtype(dt, jnp.floating):
        lo, hi = -jnp.inf, jnp.inf
    else:
        lo, hi = jnp.iinfo(dt).min, jnp.iinfo(dt).max
    if reduce == "min":
        return jnp.array(hi, dt)
    if reduce == "max":
        return jnp.array(lo, dt)
    raise ValueError(f"unknown reduce {reduce!r}")
