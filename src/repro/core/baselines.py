"""Baseline update strategies the paper compares against (§III-C, §IV-B1).

1. **TurboGraph-like** (also GridGraph's scheme): no hubs; for every
   destination interval, *all* source intervals are re-loaded from the slow
   tier. With the I/O-optimal partitioning ``P ≈ 2n·Ba/B_M`` the per-
   iteration traffic is ``read = m·Be + n·P·Ba``, ``write = n·Ba`` —
   linear in P, which is the scaling weakness paper Fig. 6 exposes.

2. **GraphChi-like (src-sorted, coarse-grained)**: the same engine but the
   sub-shards keep GraphChi's source-major edge order, so the per-block
   reduction cannot use sorted-segment semantics and falls back to random
   scatter — the paper's Table IV ablation. Build the graph with
   ``build_dsss(el, P, src_sorted=True)`` and pass it to the normal
   :class:`~repro.core.engine.NXGraphEngine`; the scatter-order penalty is
   what bench_subshard_order.py measures.
"""
from __future__ import annotations

import numpy as np

from repro.core.dsss import DSSSGraph, build_dsss
from repro.core.engine import Meters, NXGraphEngine, Result
from repro.core.iomodel import IOParams
from repro.graph.preprocess import EdgeList

__all__ = ["TurboGraphLikeEngine", "turbograph_like_partitions", "build_graphchi_like"]


def turbograph_like_partitions(n: int, Ba: int, B_M: int) -> int:
    """The strategy's I/O-optimal P: smallest P with 2·(n/P)·Ba ≤ B_M."""
    return max(1, int(np.ceil(2 * n * Ba / max(B_M, 1))))


def build_graphchi_like(el: EdgeList, P: int) -> DSSSGraph:
    """Source-sorted sub-shards (GraphChi PSW layout) for the Table IV ablation."""
    return build_dsss(el, P, src_sorted=True)


class TurboGraphLikeEngine(NXGraphEngine):
    """TurboGraph/GridGraph-style block-load schedule (paper §III-C).

    Iterates destination intervals; for each, streams every source interval
    plus the connecting sub-shard. Produces identical results to SPU (same
    semiring), but meters the strategy's characteristic ``n·P·Ba``
    interval re-read traffic. Used by bench_pagerank_systems.py to
    reproduce the paper's Fig. 6 I/O-ratio curve with *measured* bytes.
    """

    def __init__(self, graph: DSSSGraph, program, *, memory_budget: int | None = None, Be: int = 8, Bv: int = 4):
        super().__init__(
            graph, program, strategy="spu", memory_budget=None, Be=Be, Bv=Bv
        )
        # Overwrite the auto-selected plan: this engine has exactly one
        # schedule, and nothing is resident between blocks.
        from repro.core.iomodel import StrategyChoice

        self.choice = StrategyChoice("turbograph-like", 0, 0.0, 0.0)
        self.memory_budget = memory_budget
        self.resident = set()

    def _dispatch(self, strat, attrs, active, aux, valid, tol, meters):
        return self._iteration_turbograph(attrs, active, aux, valid, tol, meters)

    def _iteration_turbograph(self, attrs, active, aux, valid, tol, meters: Meters):
        import jax.numpy as jnp

        from repro.core.engine import (
            _apply_interval,
            _block_gather_reduce,
        )
        from repro.core.vertex_programs import reduce_identity

        g, prog = self.g, self.program
        isz = g.interval_size
        globals_ = prog.pre_iteration(attrs.reshape(-1), aux)
        ident = reduce_identity(prog.reduce, prog.dtype)
        rows = self._rows_to_process(active)
        iv_bytes = isz * self.params.Ba
        new_rows = []
        active_next = np.zeros(g.P, dtype=bool)
        for j in range(g.P):
            acc = jnp.full(isz, ident, prog.dtype)
            touched = False
            meters.bytes_read_intervals += iv_bytes  # load destination block
            for i in rows:
                blk = self.blocks.get((i, j))
                if blk is None:
                    continue
                # Re-load the source interval for every (i, j) pair — the
                # n·P·Ba term that the paper's Fig. 6 analysis penalizes.
                meters.bytes_read_intervals += iv_bytes
                meters.bytes_read_edges += blk["e"] * self.Be
                meters.blocks_processed += 1
                meters.edges_processed += blk["e"]
                acc = _block_gather_reduce(
                    prog,
                    attrs[i],
                    self._interval_aux(aux, i),
                    self._interval_aux(aux, j) if prog.needs_dst_aux else {},
                    blk["src_local"],
                    blk["dst_local"],
                    blk["weights"],
                    blk["e_valid"],
                    acc,
                    num_segments=isz,
                    has_weights=self.has_weights,
                )
                touched = True
            if not touched and prog.monotone:
                new_rows.append(attrs[j])
                continue
            new_j, changed = _apply_interval(
                prog, attrs[j], acc, self._interval_aux(aux, j), globals_, valid[j], tol
            )
            new_rows.append(new_j)
            active_next[j] = bool(changed)
            meters.bytes_written_intervals += iv_bytes
        return jnp.stack(new_rows), active_next
