"""Baseline update strategies the paper compares against (§III-C, §IV-B1).

1. **TurboGraph-like** (also GridGraph's scheme): no hubs; for every
   destination interval, *all* source intervals are re-loaded from the slow
   tier. With the I/O-optimal partitioning ``P ≈ 2n·Ba/B_M`` the per-
   iteration traffic is ``read = m·Be + n·P·Ba``, ``write = n·Ba`` —
   linear in P, which is the scaling weakness paper Fig. 6 exposes.

2. **GraphChi-like (src-sorted, coarse-grained)**: the same engine but the
   sub-shards keep GraphChi's source-major edge order, so the per-block
   reduction cannot use sorted-segment semantics and falls back to random
   scatter — the paper's Table IV ablation. Build the graph with
   ``build_dsss(el, P, src_sorted=True)`` and run it through the normal
   session/engine; the scatter-order penalty is what
   bench_subshard_order.py measures.

The TurboGraph-like schedule plugs into the Session/Plan executor as a
registered custom strategy, so it batches over queries and meters exactly
like the native SPU/DPU/MPU schedules.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.dsss import DSSSGraph, build_dsss
from repro.core.engine import Meters, NXGraphEngine
from repro.core.session import (
    GraphSession,
    _apply_interval,
    _block_gather_reduce,
    _pre_iteration,
    _rows_to_process,
)
from repro.core.vertex_programs import reduce_identity
from repro.graph.preprocess import EdgeList

__all__ = ["TurboGraphLikeEngine", "turbograph_like_partitions", "build_graphchi_like"]


def turbograph_like_partitions(n: int, Ba: int, B_M: int) -> int:
    """The strategy's I/O-optimal P: smallest P with 2·(n/P)·Ba ≤ B_M."""
    return max(1, int(np.ceil(2 * n * Ba / max(B_M, 1))))


def build_graphchi_like(el: EdgeList, P: int) -> DSSSGraph:
    """Source-sorted sub-shards (GraphChi PSW layout) for the Table IV ablation."""
    return build_dsss(el, P, src_sorted=True)


def _iteration_turbograph(ctx, attrs, active, meters: Meters):
    """Column-major block-load schedule: every destination interval reloads
    all of its source intervals — the ``n·P·Ba`` re-read term of §III-C."""
    sess, prog = ctx.session, ctx.program
    g = sess.graph
    isz = g.interval_size
    K = ctx.K
    globals_ = _pre_iteration(prog, attrs.reshape(K, -1), ctx.aux)
    ident = reduce_identity(prog.reduce, prog.dtype)
    rows = _rows_to_process(ctx, active)
    iv_bytes = isz * ctx.params.Ba * K
    # Column-major sweep order; nothing is ever resident for this baseline,
    # so the fetcher streams (and charges) every block each sweep.
    order = [
        (i, j) for j in range(g.P) for i in rows if (i, j) in ctx.block_keys
    ]
    fetch = ctx.fetcher.begin(order)
    new_cols = []
    active_next = np.zeros((K, g.P), dtype=bool)
    for j in range(g.P):
        acc = jnp.full((K, isz), ident, prog.dtype)
        touched = False
        meters.bytes_read_intervals += iv_bytes  # load destination block
        for i in rows:
            if (i, j) not in ctx.block_keys:
                continue
            blk = fetch()
            # Re-load the source interval for every (i, j) pair — the
            # n·P·Ba term that the paper's Fig. 6 analysis penalizes.
            meters.bytes_read_intervals += iv_bytes
            meters.blocks_processed += 1
            meters.edges_processed += blk["e"]
            acc = _block_gather_reduce(
                prog,
                attrs[:, i],
                ctx.aux_views[i],
                ctx.aux_views[j] if prog.needs_dst_aux else {},
                blk["src_local"],
                blk["dst_local"],
                blk["weights"],
                blk["e_valid"],
                acc,
                num_segments=isz,
                has_weights=sess.has_weights,
            )
            touched = True
        if not touched and prog.monotone:
            new_cols.append(attrs[:, j])
            continue
        new_j, changed = _apply_interval(
            prog, attrs[:, j], acc, ctx.aux_views[j], globals_,
            ctx.valid[j], ctx.tol,
        )
        new_cols.append(new_j)
        active_next[:, j] = np.asarray(changed)
        meters.bytes_written_intervals += iv_bytes
    return jnp.stack(new_cols, axis=1), active_next


GraphSession.register_strategy("turbograph-like", _iteration_turbograph)


class TurboGraphLikeEngine(NXGraphEngine):
    """TurboGraph/GridGraph-style block-load schedule (paper §III-C).

    Iterates destination intervals; for each, streams every source interval
    plus the connecting sub-shard. Produces identical results to SPU (same
    semiring), but meters the strategy's characteristic ``n·P·Ba``
    interval re-read traffic. Used by bench_pagerank_systems.py to
    reproduce the paper's Fig. 6 I/O-ratio curve with *measured* bytes.
    """

    def __init__(
        self,
        graph: DSSSGraph,
        program,
        *,
        memory_budget: int | None = None,
        Be: int | None = None,
        Bv: int | None = None,
        session: GraphSession | None = None,
    ):
        super().__init__(
            graph,
            program,
            strategy="turbograph-like",
            memory_budget=None,
            Be=Be,
            Bv=Bv,
            session=session,
        )
        # This engine has exactly one schedule and nothing resident between
        # blocks; memory_budget only parameterizes its modelled-I/O formula.
        self.memory_budget = memory_budget
