"""Destination-Sorted Sub-Shard (DSSS) structure — paper §II-A / §III-A.

The *sharder*: vertices are split into ``P`` equal-sized intervals; edges are
split into ``P²`` sub-shards where ``SS[i, j]`` holds every edge with source
in interval ``i`` and destination in interval ``j``. Within a sub-shard,
edges are sorted by destination id first, then source id — the DSSS ordering
that (a) makes the per-block destination range contiguous and narrow
(conflict-free reduction), and (b) makes source gathers cache/VMEM friendly.

All ``P²`` sub-shards live as slices of one flat edge buffer sorted by
``(j, i, dst, src)`` — a single allocation instead of the paper's P² files
(which hit OS handle limits on Yahoo-web, paper §IV-D).

Hubs (paper §III-B2): for every sub-shard we precompute the *unique
destination* compression used by DPU hubs — ``hub_dst[k]`` local unique
destination ids and ``hub_inv`` mapping each edge to its hub slot. The hub
byte model ``m·(Ba+Bv)/d`` falls out of these counts exactly.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph.preprocess import EdgeList

__all__ = ["DSSSGraph", "PackedSweep", "build_dsss", "SubShard", "next_bucket"]


def next_bucket(e: int, minimum: int = 8) -> int:
    """Smallest power-of-two bucket >= e (jit shape-bucketing for blocks)."""
    b = minimum
    while b < e:
        b *= 2
    return b


@dataclasses.dataclass(frozen=True)
class SubShard:
    """A view of one sub-shard SS[i, j] (all arrays are slices, zero-copy).

    ``src_local``/``dst_local`` are offsets within the source / destination
    interval (so the engine's working set per block is two interval-sized
    arrays — the locality property).
    """

    i: int
    j: int
    src_local: np.ndarray  # int32 (e,)
    dst_local: np.ndarray  # int32 (e,)
    weights: np.ndarray | None  # float32 (e,) or None
    hub_dst: np.ndarray  # int32 (u,) unique local destinations (sorted)
    hub_inv: np.ndarray  # int32 (e,) edge -> hub slot
    src_sorted: bool = False  # True for the GraphChi-like baseline layout

    @property
    def num_edges(self) -> int:
        return int(self.src_local.shape[0])

    @property
    def num_unique_dst(self) -> int:
        return int(self.hub_dst.shape[0])


@dataclasses.dataclass(frozen=True)
class PackedSweep:
    """Tile-packed layout of one full update sweep (every non-empty sub-shard).

    All sub-shards are stacked, in row-major ``(i, j)`` order, into uniform
    ``(num_tiles, tile_edges)`` arrays — one tile per sub-shard, every tile
    padded to the size of the largest sub-shard bucket. Uniformity is what
    lets the executor run the *whole* sweep as a single ``jax.lax.scan``
    (or a Pallas grid) over the tile axis: one XLA dispatch per sweep
    instead of one host round-trip per sub-shard.

    Row-major tile order is load-bearing for bit-identity with the
    per-block executor: every destination interval's accumulator folds its
    sub-shard contributions in ascending source-interval order, which is
    exactly the fold order of the SPU schedule *and* of the DPU/MPU
    two-phase schedules (their per-``j`` order is deferred-direct blocks
    ``i < Q`` ascending, then hub folds ``i ≥ Q`` ascending — ``i``
    ascending overall, and a sub-shard's hub partial is bitwise equal to
    its direct segment-reduce because destination-sorting gives both the
    same per-destination edge fold order).

    One tile per sub-shard (rather than fixed-size chunks) is what keeps
    float ``sum`` programs bit-identical: splitting a destination's edge
    run across tiles would re-associate its partial sums. The cost is
    padding to the *largest* bucket — ``num_tiles · tile_edges`` edge
    slots against ``Σ bucket_e``; balanced partitions (the paper's
    equal-sized intervals) keep the ratio small, heavy skew trades memory
    for the dispatch win.

    ``hub_inv``/``base_slot``/``u`` carry the hub-window metadata (per-edge
    local hub slots, the global hub-slot base and unique-destination count
    of each tile). The compiled scan reduces over ``dst_local`` and the
    I/O meters are driven from the metadata; the hub fields are staged so
    a Pallas-grid sweep (the windowed-partial formulation of
    ``kernels/dsss_spmv.py``) can consume the same layout — no kernel
    consumer exists yet.
    """

    keys: tuple  # ((i, j), ...) row-major over non-empty sub-shards
    tile_edges: int  # T: padded edge capacity of every tile
    src_local: np.ndarray  # int32 (NT, T) source offsets within interval i
    dst_local: np.ndarray  # int32 (NT, T) destination offsets within interval j
    hub_inv: np.ndarray  # int32 (NT, T) edge -> hub slot, local to the tile
    weights: np.ndarray | None  # float32 (NT, T) or None
    e_valid: np.ndarray  # int32 (NT,) real edge count per tile
    src_interval: np.ndarray  # int32 (NT,) i of each tile
    dst_interval: np.ndarray  # int32 (NT,) j of each tile
    base_slot: np.ndarray  # int32 (NT,) global hub-slot base (hub_offsets[i, j])
    u: np.ndarray  # int32 (NT,) unique destinations (hub slots) per tile

    @property
    def num_tiles(self) -> int:
        return int(self.e_valid.shape[0])

    @property
    def padded_edge_slots(self) -> int:
        """Total edge slots the packing allocates (``num_tiles·tile_edges``)."""
        return self.num_tiles * self.tile_edges


@dataclasses.dataclass(frozen=True)
class DSSSGraph:
    """The sharded graph: P intervals × P² destination-sorted sub-shards."""

    n: int  # number of vertices (dense ids)
    m: int  # number of edges
    P: int  # number of intervals
    interval_size: int  # ceil(n / P); last interval padded
    src: np.ndarray  # int32 (m,) global ids, sorted by (j, i, dst, src)
    dst: np.ndarray  # int32 (m,)
    weights: np.ndarray | None
    offsets: np.ndarray  # int64 (P, P + 1): offsets[i, j] .. offsets[i, j+1]
    out_degree: np.ndarray  # int32 (n_pad,)
    in_degree: np.ndarray  # int32 (n_pad,)
    hub_dst_flat: np.ndarray  # int32: concatenated unique-dst lists
    hub_inv_flat: np.ndarray  # int32 (m,): edge -> slot within its hub
    hub_offsets: np.ndarray  # int64 (P, P + 1) into hub_dst_flat
    edgelist: EdgeList  # the pre-shard this was built from
    src_sorted: bool = False  # True when built with the baseline ordering

    # -- derived sizes ------------------------------------------------------
    @property
    def n_pad(self) -> int:
        return self.P * self.interval_size

    def interval_bounds(self, i: int) -> tuple[int, int]:
        lo = i * self.interval_size
        return lo, min(lo + self.interval_size, self.n)

    def subshard(self, i: int, j: int) -> SubShard:
        lo = int(self.offsets[i, j])
        hi = int(self.offsets[i, j + 1])
        hlo = int(self.hub_offsets[i, j])
        hhi = int(self.hub_offsets[i, j + 1])
        isz = self.interval_size
        return SubShard(
            i=i,
            j=j,
            src_local=(self.src[lo:hi] - i * isz).astype(np.int32),
            dst_local=(self.dst[lo:hi] - j * isz).astype(np.int32),
            weights=None if self.weights is None else self.weights[lo:hi],
            hub_dst=self.hub_dst_flat[hlo:hhi],
            hub_inv=self.hub_inv_flat[lo:hi],
            src_sorted=self.src_sorted,
        )

    def subshard_edge_count(self, i: int, j: int) -> int:
        return int(self.offsets[i, j + 1] - self.offsets[i, j])

    def padded_subshard(self, i: int, j: int) -> dict | None:
        """Host-side staging of SS[i, j] in the engine's 'shard file' format.

        Edge arrays are padded to a power-of-two bucket (so jit compiles one
        executable per bucket size, not per sub-shard) and the hub slot list
        to its own bucket. Returns ``None`` for empty sub-shards. The device
        upload happens once per graph in :class:`repro.core.session.
        GraphSession`; this method owns only the numpy-side layout.
        """
        e = self.subshard_edge_count(i, j)
        if e == 0:
            return None
        ss = self.subshard(i, j)
        pad = next_bucket(e) - e
        ub = next_bucket(max(ss.num_unique_dst, 1))
        blk = {
            "src_local": np.pad(ss.src_local, (0, pad)),
            "dst_local": np.pad(ss.dst_local, (0, pad)),
            "hub_inv": np.pad(ss.hub_inv, (0, pad)),
            "hub_dst": np.pad(ss.hub_dst, (0, ub - ss.num_unique_dst)),
            "e": e,
            "u": ss.num_unique_dst,
            "u_bucket": ub,
            "weights": (
                None
                if ss.weights is None
                else np.pad(ss.weights, (0, pad)).astype(np.float32)
            ),
        }
        return blk

    def host_blocks(self) -> dict[tuple[int, int], dict]:
        """All non-empty sub-shards as padded host buffers, keyed ``(i, j)``.

        This is the slow-tier image of the graph: the session keeps these
        numpy buffers pinned on the host and either mirrors them to the
        device once (``residency="device"``) or streams them per sweep
        (``residency="host"``). No device arrays are created here.
        """
        blocks: dict[tuple[int, int], dict] = {}
        for i in range(self.P):
            for j in range(self.P):
                blk = self.padded_subshard(i, j)
                if blk is not None:
                    blocks[(i, j)] = blk
        return blocks

    def packed_sweep(
        self, host_blocks: dict[tuple[int, int], dict] | None = None
    ) -> PackedSweep:
        """Tile-pack every non-empty sub-shard for the compiled sweep path.

        ``host_blocks`` (from :meth:`host_blocks`) can be passed to reuse
        already-staged padded buffers; otherwise they are built here. Pure
        numpy — the device upload happens once in
        ``repro.core.session._StagedGraph``.
        """
        if host_blocks is None:
            host_blocks = self.host_blocks()
        keys = tuple(sorted(host_blocks))  # row-major (i, j) — see PackedSweep
        nt = len(keys)
        T = max(
            (len(host_blocks[k]["src_local"]) for k in keys), default=8
        )
        src_local = np.zeros((nt, T), np.int32)
        dst_local = np.zeros((nt, T), np.int32)
        hub_inv = np.zeros((nt, T), np.int32)
        weights = None if self.weights is None else np.zeros((nt, T), np.float32)
        e_valid = np.zeros(nt, np.int32)
        src_iv = np.zeros(nt, np.int32)
        dst_iv = np.zeros(nt, np.int32)
        base_slot = np.zeros(nt, np.int32)
        u = np.zeros(nt, np.int32)
        for t, (i, j) in enumerate(keys):
            blk = host_blocks[(i, j)]
            b = len(blk["src_local"])  # bucket size of this sub-shard
            src_local[t, :b] = blk["src_local"]
            dst_local[t, :b] = blk["dst_local"]
            hub_inv[t, :b] = blk["hub_inv"]
            if weights is not None:
                weights[t, :b] = blk["weights"]
            e_valid[t] = blk["e"]
            src_iv[t] = i
            dst_iv[t] = j
            base_slot[t] = self.hub_offsets[i, j]
            u[t] = blk["u"]
        return PackedSweep(
            keys=keys,
            tile_edges=T,
            src_local=src_local,
            dst_local=dst_local,
            hub_inv=hub_inv,
            weights=weights,
            e_valid=e_valid,
            src_interval=src_iv,
            dst_interval=dst_iv,
            base_slot=base_slot,
            u=u,
        )

    def total_edge_bytes(self, Be: int) -> int:
        """Model bytes of the whole edge topology (``m·Be``) — the quantity
        a ``memory_budget`` must exceed for 100% edge residency."""
        return self.m * Be

    def mean_hub_in_degree(self) -> float:
        """The paper's ``d``: average in-degree of sub-shard destinations.

        ``d = m / Σ_{i,j} |unique dst in SS[i,j]|`` — the hub compression
        factor in the DPU I/O model (paper reports 10–20 for Yahoo-web).
        """
        # hub_offsets holds *cumulative* offsets into hub_dst_flat; the
        # global total is the final offset, not a column sum.
        total_unique = int(self.hub_offsets[-1, -1])
        return self.m / max(total_unique, 1)

    def density_matrix(self) -> np.ndarray:
        """(P, P) edge counts per sub-shard — used by schedulers/benchmarks."""
        return (self.offsets[:, 1:] - self.offsets[:, :-1]).astype(np.int64)


def build_dsss(
    el: EdgeList,
    P: int,
    *,
    src_sorted: bool = False,
) -> DSSSGraph:
    """The sharding pass (paper §III-A).

    Args:
      el: degreed (dense-id) edge list.
      P: number of intervals. The paper uses equal-sized vertex ranges and
        relies on fine-grained parallelism to absorb sub-shard imbalance.
      src_sorted: build the *GraphChi-like* layout instead (edges sorted by
        source within each sub-shard) — the ablation baseline of paper
        Table IV. Engine behaviour is identical; only memory-access order
        and the parallel reduction granularity change.
    """
    if P < 1:
        raise ValueError("P must be >= 1")
    n, m = el.n, el.m
    interval_size = -(-n // P)  # ceil
    src = el.src.astype(np.int64)
    dst = el.dst.astype(np.int64)
    si = src // interval_size  # source interval of each edge
    dj = dst // interval_size  # destination interval
    # Order edges by (source interval, dest interval) block, then by the
    # in-block DSSS order: destination id, then source id. np.lexsort keys
    # are *last-key-major*.
    if src_sorted:
        order = np.lexsort((dst, src, dj, si))
    else:
        order = np.lexsort((src, dst, dj, si))
    src_s = src[order].astype(np.int32)
    dst_s = dst[order].astype(np.int32)
    w_s = None if el.weights is None else el.weights[order]

    # offsets[i, j] via 2-D histogram of block ids.
    block = si[order] * P + dj[order]
    counts = np.bincount(block, minlength=P * P).reshape(P, P)
    flat_offsets = np.zeros(P * P + 1, dtype=np.int64)
    np.cumsum(counts.ravel(), out=flat_offsets[1:])
    offsets = np.zeros((P, P + 1), dtype=np.int64)
    offsets[:, 0] = flat_offsets[:-1].reshape(P, P)[:, 0]
    offsets[:, 1:] = flat_offsets[1:].reshape(P, P)

    # Hub (unique destination) compression per sub-shard. Because edges are
    # destination-sorted inside each sub-shard, uniques are found with one
    # vectorized pass: a new hub slot opens wherever dst changes or a new
    # sub-shard begins.
    isz = interval_size
    starts = flat_offsets[:-1]
    is_block_start = np.zeros(m, dtype=bool)
    is_block_start[starts[starts < m]] = True
    if src_sorted:
        # Destinations are not sorted inside a block; fall back to per-block
        # np.unique (the baseline pays this cost, as in the paper).
        hub_dst_parts: list[np.ndarray] = []
        hub_inv_flat = np.zeros(m, dtype=np.int32)
        hub_counts = np.zeros(P * P, dtype=np.int64)
        for b in range(P * P):
            lo, hi = int(flat_offsets[b]), int(flat_offsets[b + 1])
            if hi == lo:
                hub_dst_parts.append(np.zeros(0, dtype=np.int32))
                continue
            u, inv = np.unique(dst_s[lo:hi], return_inverse=True)
            hub_dst_parts.append((u - (b % P) * isz).astype(np.int32))
            hub_inv_flat[lo:hi] = inv.astype(np.int32)
            hub_counts[b] = len(u)
        hub_dst_flat = (
            np.concatenate(hub_dst_parts) if hub_dst_parts else np.zeros(0, np.int32)
        )
    else:
        new_slot = np.ones(m, dtype=bool)
        if m > 1:
            new_slot[1:] = (dst_s[1:] != dst_s[:-1]) | is_block_start[1:]
        slot_global = np.cumsum(new_slot) - 1 if m else np.zeros(0, np.int64)
        hub_dst_flat = (
            (dst_s[new_slot] - (dst_s[new_slot] // isz) * isz).astype(np.int32)
            if m
            else np.zeros(0, np.int32)
        )
        # per-block slot base = slot_global at block start
        hub_counts = np.zeros(P * P, dtype=np.int64)
        if m:
            blk_of_slot = np.repeat(
                np.arange(P * P), np.diff(flat_offsets)
            )[new_slot]
            hub_counts = np.bincount(blk_of_slot, minlength=P * P)
            slot_base = np.zeros(P * P, dtype=np.int64)
            np.cumsum(hub_counts[:-1], out=slot_base[1:])
            hub_inv_flat = (
                slot_global - np.repeat(slot_base, np.diff(flat_offsets))
            ).astype(np.int32)
        else:
            hub_inv_flat = np.zeros(0, np.int32)

    hub_offsets = np.zeros((P, P + 1), dtype=np.int64)
    hub_cum = np.zeros(P * P + 1, dtype=np.int64)
    np.cumsum(hub_counts, out=hub_cum[1:])
    hub_offsets[:, 0] = hub_cum[:-1].reshape(P, P)[:, 0]
    hub_offsets[:, 1:] = hub_cum[1:].reshape(P, P)

    n_pad = P * interval_size
    out_deg = np.zeros(n_pad, dtype=np.int32)
    out_deg[:n] = el.out_degree
    in_deg = np.zeros(n_pad, dtype=np.int32)
    in_deg[:n] = el.in_degree

    return DSSSGraph(
        n=n,
        m=m,
        P=P,
        interval_size=interval_size,
        src=src_s,
        dst=dst_s,
        weights=w_s,
        offsets=offsets,
        out_degree=out_deg,
        in_degree=in_deg,
        hub_dst_flat=hub_dst_flat,
        hub_inv_flat=hub_inv_flat,
        hub_offsets=hub_offsets,
        edgelist=el,
        src_sorted=src_sorted,
    )
