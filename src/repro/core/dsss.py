"""Destination-Sorted Sub-Shard (DSSS) structure — paper §II-A / §III-A.

The *sharder*: vertices are split into ``P`` equal-sized intervals; edges are
split into ``P²`` sub-shards where ``SS[i, j]`` holds every edge with source
in interval ``i`` and destination in interval ``j``. Within a sub-shard,
edges are sorted by destination id first, then source id — the DSSS ordering
that (a) makes the per-block destination range contiguous and narrow
(conflict-free reduction), and (b) makes source gathers cache/VMEM friendly.

All ``P²`` sub-shards live as slices of one flat edge buffer sorted by
``(j, i, dst, src)`` — a single allocation instead of the paper's P² files
(which hit OS handle limits on Yahoo-web, paper §IV-D).

Hubs (paper §III-B2): for every sub-shard we precompute the *unique
destination* compression used by DPU hubs — ``hub_dst[k]`` local unique
destination ids and ``hub_inv`` mapping each edge to its hub slot. The hub
byte model ``m·(Ba+Bv)/d`` falls out of these counts exactly.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph.preprocess import EdgeList

__all__ = [
    "DSSSGraph",
    "PackedSweep",
    "build_dsss",
    "SubShard",
    "next_bucket",
    "choose_tile_edges",
    "cut_runs_into_tiles",
    "tile_candidates",
    "tile_source_spans",
    "active_tile_mask",
]


def next_bucket(e: int, minimum: int = 8) -> int:
    """Smallest power-of-two bucket >= e (jit shape-bucketing for blocks)."""
    b = minimum
    while b < e:
        b *= 2
    return b


# Smallest tile size the adaptive chooser will consider on non-trivial
# graphs: one TPU lane row of edges. Smaller tiles can pack marginally
# tighter on low-skew graphs but fragment the scan into more steps than
# the padding saved is worth.
TILE_EDGES_FLOOR = 128


def cut_runs_into_tiles(bounds: np.ndarray, tile_edges: int) -> list[tuple[int, int]]:
    """Greedy destination-aligned cut: pack runs into ``tile_edges`` tiles.

    ``bounds`` is the (num_runs + 1,) array of cumulative run boundaries
    (edge offsets); returns ``(r0, r1)`` run-index spans, each spanning at
    most ``tile_edges`` edges, cutting only between runs. Requires
    ``tile_edges >= max run length`` (else a run is force-placed alone in
    an overfull tile — callers choose ``tile_edges`` to avoid this).
    """
    n_runs = len(bounds) - 1
    tiles: list[tuple[int, int]] = []
    r = 0
    while r < n_runs:
        limit = bounds[r] + tile_edges
        k = int(np.searchsorted(bounds, limit, side="right")) - 1
        k = min(max(k, r + 1), n_runs)
        tiles.append((r, k))
        r = k
    return tiles


def tile_candidates(m: int, max_run: int) -> list[int]:
    """Power-of-two tile sizes the adaptive chooser considers.

    From ``max(TILE_EDGES_FLOOR, bucket(max_run))`` — a run must fit one
    tile, or the cut rule would have to split a destination's fold — up to
    ``bucket(m)`` (a single tile). Shared with the external-memory builder
    (``repro.storage.build``), whose streaming greedy counters must pick
    the exact tile size :func:`choose_tile_edges` would, so a stored graph
    is layout-identical to an in-memory :meth:`DSSSGraph.packed_sweep`.
    """
    if m == 0:
        return [8]
    lo = max(min(TILE_EDGES_FLOOR, next_bucket(m)), next_bucket(max_run))
    hi = max(lo, next_bucket(m))
    out = []
    T = lo
    while T <= hi:
        out.append(T)
        T *= 2
    return out


def choose_tile_edges(run_lengths: np.ndarray) -> int:
    """Pick the tile size minimising total padded slots for these runs.

    Candidates come from :func:`tile_candidates`. Each candidate's exact
    padded footprint ``num_tiles · T`` is evaluated with the real greedy
    cut; ties prefer the *smaller* tile (finer granularity for budget
    pinning and chunked host streaming, at identical padding). This is
    what bounds the padded-edge ratio on power-law graphs, where the
    legacy max-sub-shard tile width is hub-degree-bound.
    """
    m = int(run_lengths.sum()) if len(run_lengths) else 0
    if m == 0:
        return 8
    bounds = np.concatenate([[0], np.cumsum(run_lengths)])
    best_T, best_slots = None, None
    for T in tile_candidates(m, int(run_lengths.max())):
        slots = len(cut_runs_into_tiles(bounds, T)) * T
        if best_slots is None or slots < best_slots:
            best_T, best_slots = T, slots
    return best_T


@dataclasses.dataclass(frozen=True)
class SubShard:
    """A view of one sub-shard SS[i, j] (all arrays are slices, zero-copy).

    ``src_local``/``dst_local`` are offsets within the source / destination
    interval (so the engine's working set per block is two interval-sized
    arrays — the locality property).
    """

    i: int
    j: int
    src_local: np.ndarray  # int32 (e,)
    dst_local: np.ndarray  # int32 (e,)
    weights: np.ndarray | None  # float32 (e,) or None
    hub_dst: np.ndarray  # int32 (u,) unique local destinations (sorted)
    hub_inv: np.ndarray  # int32 (e,) edge -> hub slot
    src_sorted: bool = False  # True for the GraphChi-like baseline layout

    @property
    def num_edges(self) -> int:
        return int(self.src_local.shape[0])

    @property
    def num_unique_dst(self) -> int:
        return int(self.hub_dst.shape[0])


@dataclasses.dataclass(frozen=True)
class PackedSweep:
    """Destination-aligned tile packing of one full update sweep.

    The flat DSSS edge array is already the whole sweep in execution
    order: row-major ``(i, j)`` sub-shards, destination-sorted inside
    each. This layout cuts that stream into uniform ``(num_tiles,
    tile_edges)`` windows so the executor can run the entire gather-reduce
    phase as a single ``jax.lax.scan`` (or stream tile chunks host→device)
    — one XLA dispatch instead of one host round-trip per sub-shard. The
    same schema is what the fused Pallas backend
    (:mod:`repro.kernels.packed_sweep`, ``execution="packed_kernel"``)
    grids over: one ``(tile_edges,)`` leaf slice per grid cell, DMA'd
    HBM→VMEM by BlockSpec index maps.

    **Cut rule (mode="adaptive"):** tiles are cut *only at destination-run
    boundaries* — a run being one sub-shard's maximal span of edges
    sharing a destination, i.e. exactly one hub slot. Large sub-shards
    therefore split across tiles and small consecutive sub-shards coalesce
    into shared tiles, but a destination's per-sub-shard edge run is never
    divided, so its partial ⊕ is computed over the same values in the same
    order as the per-block executor's segment reduce — bit-identity for
    float ``sum`` programs is preserved with near-uniform tile occupancy
    (``padding_ratio`` stays small on power-law graphs instead of being
    bound by the largest sub-shard). ``tile_edges`` is chosen per graph to
    minimise total padded slots (see :func:`choose_tile_edges`).

    **mode="subshard"** reproduces the legacy one-tile-per-sub-shard
    packing (tiles never cross or split sub-shards, ``tile_edges`` = the
    largest sub-shard bucket) in the same schema — kept for the padding
    benchmarks and because it is the only packing whose per-run reduce is
    also valid for ``src_sorted`` (GraphChi-like) layouts, where a
    destination's edges are not contiguous and only whole-sub-shard
    windows group them correctly.

    **Execution schema** (what the compiled scan consumes, per tile):

    * ``src`` / ``dst`` — global endpoint ids (vertex id == padded
      position, since intervals are the contiguous ranges
      ``[i·interval_size, …)``): the scan gathers attributes and aux
      directly from the flat ``(n_pad,)`` arrays, so a tile needs no
      single source/destination interval and coalescing is free.
    * ``run_local`` — per-edge hub slot *within the tile's slot window*
      (global hub slot − ``base_slot``): the per-tile segment reduce over
      ``run_local`` is precisely the ToHub windowed-partial formulation of
      ``kernels/dsss_spmv.py``, which is why tiles are also valid Pallas
      kernel inputs (:func:`repro.kernels.ops.prepare_from_packed_tile`).
    * ``run_dst`` — per run-slot global destination id (``n_pad`` sentinel
      past ``u``): the FromHub fold scatters the ≤ ``tile_edges`` run
      partials into the flat accumulator. A coalesced tile that wraps a
      whole row cycle can hold two runs with the *same* destination (from
      different source intervals), making the scatter carry duplicate
      indices; the ascending-``i`` fold order then relies on the scatter
      applying updates in index order. XLA serialises conflicting scatter
      updates in order on CPU and TPU — the same assumption every
      ``jax.ops.segment_*`` fold in this codebase (per-block path
      included) already makes — but it is implementation-defined on GPU,
      where float-``sum`` bit-identity would weaken to
      re-association-level equality in exactly those tiles (min/max are
      order-free either way).
    * ``e_valid`` — real edges; trailing padding is masked to exact
      ⊕-identities.

    Bit-identity with the per-block executor holds because (a) runs are
    never split, (b) the stream order folds every destination's sub-shard
    partials in ascending source-interval order — the fold order of SPU
    *and* of the DPU/MPU two-phase schedules (deferred-direct ``i < Q``
    ascending, then hub folds ``i ≥ Q`` ascending), and (c) a sub-shard's
    hub partial is bitwise equal to its direct segment-reduce because
    destination-sorting gives both the same per-destination fold order.

    ``src_interval`` / ``dst_interval`` / ``base_slot`` / ``row_offset`` /
    ``u`` are the per-tile metadata (intervals of the first edge, global
    hub-slot base, offset of the first edge in the flat DSSS edge array,
    run count) that drive meter recomputation, chunked host streaming and
    the kernel staging; they stay host-side.
    """

    mode: str  # "adaptive" | "subshard"
    m: int  # real edges covered (== graph.m)
    n_pad: int  # padded vertex count (the run_dst scatter sentinel)
    tile_edges: int  # T: padded edge capacity of every tile
    src: np.ndarray  # int32 (NT, T) global source ids (0-padded)
    dst: np.ndarray  # int32 (NT, T) global destination ids (0-padded)
    run_local: np.ndarray  # int32 (NT, T) edge -> run slot within the tile
    run_dst: np.ndarray  # int32 (NT, T) run slot -> global dst (n_pad pad)
    weights: np.ndarray | None  # float32 (NT, T) or None
    e_valid: np.ndarray  # int32 (NT,) real edge count per tile
    src_interval: np.ndarray  # int32 (NT,) i of the tile's first edge
    dst_interval: np.ndarray  # int32 (NT,) j of the tile's first edge
    base_slot: np.ndarray  # int64 (NT,) global hub slot of the first run
    u: np.ndarray  # int32 (NT,) runs (unique (sub-shard, dst)) per tile
    row_offset: np.ndarray  # int64 (NT,) flat edge offset of the first edge

    @property
    def num_tiles(self) -> int:
        return int(self.e_valid.shape[0])

    @property
    def padded_edge_slots(self) -> int:
        """Total edge slots the packing allocates (``num_tiles·tile_edges``)."""
        return self.num_tiles * self.tile_edges

    @property
    def padding_ratio(self) -> float:
        """Padded-slots / real-edges — 1.0 is a perfect packing."""
        return self.padded_edge_slots / max(self.m, 1)


def tile_source_spans(
    packed: PackedSweep, interval_size: int
) -> tuple[np.ndarray, np.ndarray]:
    """Per-tile source-interval span ``[first_i, last_i]`` (inclusive).

    ``src_interval`` records the interval of a tile's *first* edge; a
    coalesced tile can span several consecutive source intervals (the
    stream is row-major, so the span is always contiguous). The last
    interval is recovered from the tile's last real edge's source id.
    Empty tiles (``e_valid == 0`` cannot occur in a build, but a
    compacted gather may zero them) degenerate to ``last == first``.

    These spans drive frontier-aware selective execution: a tile can be
    skipped iff no source interval in its span is active — see
    :func:`active_tile_mask`.
    """
    nt = packed.num_tiles
    first = packed.src_interval.astype(np.int64)
    if nt == 0:
        return first, first.copy()
    last_edge = np.maximum(packed.e_valid.astype(np.int64), 1) - 1
    last_src = packed.src[np.arange(nt), last_edge].astype(np.int64)
    return first, np.maximum(first, last_src // interval_size)


def active_tile_mask(
    row_active: np.ndarray, first: np.ndarray, last: np.ndarray
) -> np.ndarray:
    """``(NT,)`` bool: does tile t contain any edge from an active interval?

    ``row_active`` is the (P,) per-interval activity bitmap from the
    previous sweep's ``changed`` output; ``first``/``last`` are the
    inclusive per-tile spans from :func:`tile_source_spans`. Computed as
    a prefix-sum range query so the whole map costs O(P + NT).

    For monotone programs, a False tile contributes only exact
    ⊕-identities (every source attribute in it is unchanged since last
    gathered), so skipping it preserves bit-identity with the full sweep.
    """
    row = np.asarray(row_active, dtype=np.int64)
    cum = np.concatenate([[0], np.cumsum(row)])
    return (cum[last + 1] - cum[first]) > 0


@dataclasses.dataclass(frozen=True)
class DSSSGraph:
    """The sharded graph: P intervals × P² destination-sorted sub-shards."""

    n: int  # number of vertices (dense ids)
    m: int  # number of edges
    P: int  # number of intervals
    interval_size: int  # ceil(n / P); last interval padded
    src: np.ndarray  # int32 (m,) global ids, sorted by (j, i, dst, src)
    dst: np.ndarray  # int32 (m,)
    weights: np.ndarray | None
    offsets: np.ndarray  # int64 (P, P + 1): offsets[i, j] .. offsets[i, j+1]
    out_degree: np.ndarray  # int32 (n_pad,)
    in_degree: np.ndarray  # int32 (n_pad,)
    hub_dst_flat: np.ndarray  # int32: concatenated unique-dst lists
    hub_inv_flat: np.ndarray  # int32 (m,): edge -> slot within its hub
    hub_offsets: np.ndarray  # int64 (P, P + 1) into hub_dst_flat
    edgelist: EdgeList  # the pre-shard this was built from
    src_sorted: bool = False  # True when built with the baseline ordering

    # -- derived sizes ------------------------------------------------------
    @property
    def n_pad(self) -> int:
        return self.P * self.interval_size

    def interval_bounds(self, i: int) -> tuple[int, int]:
        lo = i * self.interval_size
        return lo, min(lo + self.interval_size, self.n)

    def subshard(self, i: int, j: int) -> SubShard:
        lo = int(self.offsets[i, j])
        hi = int(self.offsets[i, j + 1])
        hlo = int(self.hub_offsets[i, j])
        hhi = int(self.hub_offsets[i, j + 1])
        isz = self.interval_size
        return SubShard(
            i=i,
            j=j,
            src_local=(self.src[lo:hi] - i * isz).astype(np.int32),
            dst_local=(self.dst[lo:hi] - j * isz).astype(np.int32),
            weights=None if self.weights is None else self.weights[lo:hi],
            hub_dst=self.hub_dst_flat[hlo:hhi],
            hub_inv=self.hub_inv_flat[lo:hi],
            src_sorted=self.src_sorted,
        )

    def subshard_edge_count(self, i: int, j: int) -> int:
        return int(self.offsets[i, j + 1] - self.offsets[i, j])

    def padded_subshard(self, i: int, j: int) -> dict | None:
        """Host-side staging of SS[i, j] in the engine's 'shard file' format.

        Edge arrays are padded to a power-of-two bucket (so jit compiles one
        executable per bucket size, not per sub-shard) and the hub slot list
        to its own bucket. Returns ``None`` for empty sub-shards. The device
        upload happens once per graph in :class:`repro.core.session.
        GraphSession`; this method owns only the numpy-side layout.
        """
        e = self.subshard_edge_count(i, j)
        if e == 0:
            return None
        ss = self.subshard(i, j)
        pad = next_bucket(e) - e
        ub = next_bucket(max(ss.num_unique_dst, 1))
        blk = {
            "src_local": np.pad(ss.src_local, (0, pad)),
            "dst_local": np.pad(ss.dst_local, (0, pad)),
            "hub_inv": np.pad(ss.hub_inv, (0, pad)),
            "hub_dst": np.pad(ss.hub_dst, (0, ub - ss.num_unique_dst)),
            "e": e,
            "u": ss.num_unique_dst,
            "u_bucket": ub,
            "weights": (
                None
                if ss.weights is None
                else np.pad(ss.weights, (0, pad)).astype(np.float32)
            ),
        }
        return blk

    def host_blocks(self) -> dict[tuple[int, int], dict]:
        """All non-empty sub-shards as padded host buffers, keyed ``(i, j)``.

        This is the slow-tier image of the graph: the session keeps these
        numpy buffers pinned on the host and either mirrors them to the
        device once (``residency="device"``) or streams them per sweep
        (``residency="host"``). No device arrays are created here.
        """
        blocks: dict[tuple[int, int], dict] = {}
        for i in range(self.P):
            for j in range(self.P):
                blk = self.padded_subshard(i, j)
                if blk is not None:
                    blocks[(i, j)] = blk
        return blocks

    def global_hub_slots(self) -> np.ndarray:
        """int64 (m,): each edge's *global* hub slot (run id).

        ``hub_inv_flat`` is local to its sub-shard; adding the sub-shard's
        cumulative slot base makes slot ids global and — because slot
        numbering follows the same row-major, destination-sorted order as
        the flat edge array — non-decreasing along the edge stream for the
        DSSS layout (``src_sorted`` graphs scramble them within blocks).
        """
        counts = np.diff(
            np.concatenate([[0], self.offsets[:, 1:].ravel()])
        )
        bases = np.repeat(self.hub_offsets[:, :-1].ravel(), counts)
        return bases + self.hub_inv_flat

    def packed_sweep(self, mode: str = "adaptive") -> PackedSweep:
        """Tile-pack the whole sweep for the compiled executor (pure numpy).

        ``mode="adaptive"`` (default, DSSS layout only): fixed-size tiles
        cut at destination-run boundaries, tile size chosen by
        :func:`choose_tile_edges`. ``mode="subshard"``: the legacy
        one-tile-per-sub-shard packing (required for ``src_sorted``
        graphs). Device upload happens once in
        ``repro.core.session._StagedGraph``.
        """
        if mode not in ("adaptive", "subshard"):
            raise ValueError(f"packing mode must be 'adaptive' or 'subshard', got {mode!r}")
        if mode == "adaptive" and self.src_sorted:
            raise ValueError(
                "adaptive tile packing needs destination-sorted sub-shards; "
                "src_sorted graphs must use mode='subshard' (a destination's "
                "edges are not contiguous, so only whole-sub-shard windows "
                "group its partial reduce correctly)"
            )
        m = self.m
        gslot = self.global_hub_slots()
        if mode == "adaptive":
            if m == 0:
                starts = np.zeros(0, np.int64)
            else:
                change = np.ones(m, dtype=bool)
                change[1:] = gslot[1:] != gslot[:-1]
                starts = np.flatnonzero(change).astype(np.int64)
            bounds = np.concatenate([starts, [m]])  # run r spans bounds[r:r+2]
            run_len = np.diff(bounds)
            T = choose_tile_edges(run_len)
            tile_runs = cut_runs_into_tiles(bounds, T)
        else:
            # One tile per non-empty sub-shard: forced cuts at block
            # boundaries, T = the largest sub-shard bucket (legacy packing).
            blk_bounds = self.offsets[:, 1:].ravel()
            blk_lo = np.concatenate([[0], blk_bounds[:-1]])
            nonempty = blk_bounds > blk_lo
            lo, hi = blk_lo[nonempty], blk_bounds[nonempty]
            T = next_bucket(int((hi - lo).max()) if len(lo) else 8)
            # Runs double as blocks here: each tile is one whole block.
            bounds = None
            tile_runs = [(int(a), int(b)) for a, b in zip(lo, hi)]
        nt = len(tile_runs)
        src = np.zeros((nt, T), np.int32)
        dst = np.zeros((nt, T), np.int32)
        run_local = np.zeros((nt, T), np.int32)
        run_dst = np.full((nt, T), self.n_pad, np.int32)
        weights = None if self.weights is None else np.zeros((nt, T), np.float32)
        e_valid = np.zeros(nt, np.int32)
        src_iv = np.zeros(nt, np.int32)
        dst_iv = np.zeros(nt, np.int32)
        base_slot = np.zeros(nt, np.int64)
        u = np.zeros(nt, np.int32)
        row_offset = np.zeros(nt, np.int64)
        isz = self.interval_size
        for t, span in enumerate(tile_runs):
            if mode == "adaptive":
                r0, r1 = span  # run index range
                lo_e, hi_e = int(bounds[r0]), int(bounds[r1])
                base = int(gslot[lo_e])
                nu = r1 - r0
            else:
                lo_e, hi_e = span  # edge range of one whole block
                base = int(gslot[lo_e] - self.hub_inv_flat[lo_e])
                nu = int(self.hub_inv_flat[lo_e:hi_e].max()) + 1
            e = hi_e - lo_e
            src[t, :e] = self.src[lo_e:hi_e]
            dst[t, :e] = self.dst[lo_e:hi_e]
            run_local[t, :e] = (gslot[lo_e:hi_e] - base).astype(np.int32)
            # Run slot -> global destination: the destination of any edge in
            # the run (scatter target of the FromHub fold).
            run_dst[t, :e][run_local[t, :e]] = dst[t, :e]
            if weights is not None:
                weights[t, :e] = self.weights[lo_e:hi_e]
            e_valid[t] = e
            src_iv[t] = self.src[lo_e] // isz
            dst_iv[t] = self.dst[lo_e] // isz
            base_slot[t] = base
            u[t] = nu
            row_offset[t] = lo_e
        return PackedSweep(
            mode=mode,
            m=m,
            n_pad=self.n_pad,
            tile_edges=T,
            src=src,
            dst=dst,
            run_local=run_local,
            run_dst=run_dst,
            weights=weights,
            e_valid=e_valid,
            src_interval=src_iv,
            dst_interval=dst_iv,
            base_slot=base_slot,
            u=u,
            row_offset=row_offset,
        )

    def total_edge_bytes(self, Be: int) -> int:
        """Model bytes of the whole edge topology (``m·Be``) — the quantity
        a ``memory_budget`` must exceed for 100% edge residency."""
        return self.m * Be

    def mean_hub_in_degree(self) -> float:
        """The paper's ``d``: average in-degree of sub-shard destinations.

        ``d = m / Σ_{i,j} |unique dst in SS[i,j]|`` — the hub compression
        factor in the DPU I/O model (paper reports 10–20 for Yahoo-web).
        """
        # hub_offsets holds *cumulative* offsets into hub_dst_flat; the
        # global total is the final offset, not a column sum.
        total_unique = int(self.hub_offsets[-1, -1])
        return self.m / max(total_unique, 1)

    def density_matrix(self) -> np.ndarray:
        """(P, P) edge counts per sub-shard — used by schedulers/benchmarks."""
        return (self.offsets[:, 1:] - self.offsets[:, :-1]).astype(np.int64)


def build_dsss(
    el: EdgeList,
    P: int,
    *,
    src_sorted: bool = False,
) -> DSSSGraph:
    """The sharding pass (paper §III-A).

    Args:
      el: degreed (dense-id) edge list.
      P: number of intervals. The paper uses equal-sized vertex ranges and
        relies on fine-grained parallelism to absorb sub-shard imbalance.
      src_sorted: build the *GraphChi-like* layout instead (edges sorted by
        source within each sub-shard) — the ablation baseline of paper
        Table IV. Engine behaviour is identical; only memory-access order
        and the parallel reduction granularity change.
    """
    if P < 1:
        raise ValueError("P must be >= 1")
    n, m = el.n, el.m
    interval_size = -(-n // P)  # ceil
    src = el.src.astype(np.int64)
    dst = el.dst.astype(np.int64)
    si = src // interval_size  # source interval of each edge
    dj = dst // interval_size  # destination interval
    # Order edges by (source interval, dest interval) block, then by the
    # in-block DSSS order: destination id, then source id. np.lexsort keys
    # are *last-key-major*.
    if src_sorted:
        order = np.lexsort((dst, src, dj, si))
    else:
        order = np.lexsort((src, dst, dj, si))
    src_s = src[order].astype(np.int32)
    dst_s = dst[order].astype(np.int32)
    w_s = None if el.weights is None else el.weights[order]

    # offsets[i, j] via 2-D histogram of block ids.
    block = si[order] * P + dj[order]
    counts = np.bincount(block, minlength=P * P).reshape(P, P)
    flat_offsets = np.zeros(P * P + 1, dtype=np.int64)
    np.cumsum(counts.ravel(), out=flat_offsets[1:])
    offsets = np.zeros((P, P + 1), dtype=np.int64)
    offsets[:, 0] = flat_offsets[:-1].reshape(P, P)[:, 0]
    offsets[:, 1:] = flat_offsets[1:].reshape(P, P)

    # Hub (unique destination) compression per sub-shard. Because edges are
    # destination-sorted inside each sub-shard, uniques are found with one
    # vectorized pass: a new hub slot opens wherever dst changes or a new
    # sub-shard begins.
    isz = interval_size
    starts = flat_offsets[:-1]
    is_block_start = np.zeros(m, dtype=bool)
    is_block_start[starts[starts < m]] = True
    if src_sorted:
        # Destinations are not sorted inside a block; fall back to per-block
        # np.unique (the baseline pays this cost, as in the paper).
        hub_dst_parts: list[np.ndarray] = []
        hub_inv_flat = np.zeros(m, dtype=np.int32)
        hub_counts = np.zeros(P * P, dtype=np.int64)
        for b in range(P * P):
            lo, hi = int(flat_offsets[b]), int(flat_offsets[b + 1])
            if hi == lo:
                hub_dst_parts.append(np.zeros(0, dtype=np.int32))
                continue
            u, inv = np.unique(dst_s[lo:hi], return_inverse=True)
            hub_dst_parts.append((u - (b % P) * isz).astype(np.int32))
            hub_inv_flat[lo:hi] = inv.astype(np.int32)
            hub_counts[b] = len(u)
        hub_dst_flat = (
            np.concatenate(hub_dst_parts) if hub_dst_parts else np.zeros(0, np.int32)
        )
    else:
        new_slot = np.ones(m, dtype=bool)
        if m > 1:
            new_slot[1:] = (dst_s[1:] != dst_s[:-1]) | is_block_start[1:]
        slot_global = np.cumsum(new_slot) - 1 if m else np.zeros(0, np.int64)
        hub_dst_flat = (
            (dst_s[new_slot] - (dst_s[new_slot] // isz) * isz).astype(np.int32)
            if m
            else np.zeros(0, np.int32)
        )
        # per-block slot base = slot_global at block start
        hub_counts = np.zeros(P * P, dtype=np.int64)
        if m:
            blk_of_slot = np.repeat(
                np.arange(P * P), np.diff(flat_offsets)
            )[new_slot]
            hub_counts = np.bincount(blk_of_slot, minlength=P * P)
            slot_base = np.zeros(P * P, dtype=np.int64)
            np.cumsum(hub_counts[:-1], out=slot_base[1:])
            hub_inv_flat = (
                slot_global - np.repeat(slot_base, np.diff(flat_offsets))
            ).astype(np.int32)
        else:
            hub_inv_flat = np.zeros(0, np.int32)

    hub_offsets = np.zeros((P, P + 1), dtype=np.int64)
    hub_cum = np.zeros(P * P + 1, dtype=np.int64)
    np.cumsum(hub_counts, out=hub_cum[1:])
    hub_offsets[:, 0] = hub_cum[:-1].reshape(P, P)[:, 0]
    hub_offsets[:, 1:] = hub_cum[1:].reshape(P, P)

    n_pad = P * interval_size
    out_deg = np.zeros(n_pad, dtype=np.int32)
    out_deg[:n] = el.out_degree
    in_deg = np.zeros(n_pad, dtype=np.int32)
    in_deg[:n] = el.in_degree

    return DSSSGraph(
        n=n,
        m=m,
        P=P,
        interval_size=interval_size,
        src=src_s,
        dst=dst_s,
        weights=w_s,
        offsets=offsets,
        out_degree=out_deg,
        in_degree=in_deg,
        hub_dst_flat=hub_dst_flat,
        hub_inv_flat=hub_inv_flat,
        hub_offsets=hub_offsets,
        edgelist=el,
        src_sorted=src_sorted,
    )
