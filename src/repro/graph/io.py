"""Binary + text graph I/O.

The paper stores the pre-shard and sub-shards as binary files; we keep the
same separation (raw edge list <-> preprocessed artifacts) but use npz
containers so a single file holds all sub-shard slices (avoids the paper's
OS open-file-handle limitation, §IV-D).
"""
from __future__ import annotations

import os

import numpy as np

from repro.graph.preprocess import EdgeList

__all__ = ["save_edges", "load_edges", "load_text_edges", "save_edgelist", "load_edgelist"]


def save_edges(path: str, src: np.ndarray, dst: np.ndarray, weights=None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    payload = {"src": src, "dst": dst}
    if weights is not None:
        payload["weights"] = weights
    np.savez_compressed(path, **payload)


def load_edges(path: str) -> tuple[np.ndarray, np.ndarray, np.ndarray | None]:
    with np.load(path) as z:
        return z["src"], z["dst"], (z["weights"] if "weights" in z else None)


def load_text_edges(path: str, comment: str = "#") -> tuple[np.ndarray, np.ndarray]:
    """SNAP-style whitespace edge list (``src dst`` per line)."""
    srcs: list[int] = []
    dsts: list[int] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith(comment):
                continue
            a, b = line.split()[:2]
            srcs.append(int(a))
            dsts.append(int(b))
    return np.asarray(srcs, dtype=np.int64), np.asarray(dsts, dtype=np.int64)


def save_edgelist(path: str, el: EdgeList) -> None:
    """Persist a preprocessed (degreed) edge list."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    payload = dict(
        src=el.src,
        dst=el.dst,
        n=np.int64(el.n),
        out_degree=el.out_degree,
        in_degree=el.in_degree,
        id_to_index=el.id_to_index,
    )
    if el.weights is not None:
        payload["weights"] = el.weights
    np.savez_compressed(path, **payload)


def load_edgelist(path: str) -> EdgeList:
    with np.load(path) as z:
        return EdgeList(
            src=z["src"],
            dst=z["dst"],
            n=int(z["n"]),
            out_degree=z["out_degree"],
            in_degree=z["in_degree"],
            id_to_index=z["id_to_index"],
            weights=(z["weights"] if "weights" in z else None),
        )
