"""Binary + text graph I/O.

The paper stores the pre-shard and sub-shards as binary files; we keep the
same separation (raw edge list <-> preprocessed artifacts) but use npz
containers so a single file holds all sub-shard slices (avoids the paper's
OS open-file-handle limitation, §IV-D). For graphs that should never be
fully memory-resident, the sharded binary container lives in
:mod:`repro.storage` — the chunked text reader here
(:func:`iter_text_edges`) is its build pipeline's front end.

Dtype contract: ``save_edges`` / ``save_edgelist`` persist arrays with the
caller's exact dtypes (``np.savez`` stores the dtype alongside the data;
inputs are only wrapped with ``np.asarray``, never cast), and the loaders
return them unchanged — asserted by ``tests/test_graph_io.py``.
"""
from __future__ import annotations

import itertools
import os
from typing import Iterator

import numpy as np

from repro.graph.preprocess import EdgeList

__all__ = [
    "save_edges",
    "load_edges",
    "iter_text_edges",
    "load_text_edges",
    "save_edgelist",
    "load_edgelist",
]


def save_edges(path: str, src: np.ndarray, dst: np.ndarray, weights=None) -> None:
    """Persist a raw edge list, preserving the caller's dtypes exactly."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    payload = {"src": np.asarray(src), "dst": np.asarray(dst)}
    if weights is not None:
        payload["weights"] = np.asarray(weights)
    np.savez_compressed(path, **payload)


def load_edges(path: str) -> tuple[np.ndarray, np.ndarray, np.ndarray | None]:
    with np.load(path) as z:
        return z["src"], z["dst"], (z["weights"] if "weights" in z else None)


def _parse_lines(
    lines: list[str], comment: str, dtype, weights: bool
) -> tuple[np.ndarray, ...] | None:
    """Vectorized-ish parse of one batch of edge-list lines."""
    tokens: list[str] = []
    wtokens: list[str] = []
    for line in lines:
        line = line.strip()  # handles CRLF and stray whitespace
        if not line or line.startswith(comment):
            continue
        parts = line.split()
        if len(parts) < 2 or (weights and len(parts) < 3):
            raise ValueError(f"malformed edge-list line: {line!r}")
        tokens.append(parts[0])
        tokens.append(parts[1])
        if weights:
            wtokens.append(parts[2])
    if not tokens:
        return None
    ids = np.array(tokens, dtype=dtype).reshape(-1, 2)
    out: tuple[np.ndarray, ...] = (
        np.ascontiguousarray(ids[:, 0]),
        np.ascontiguousarray(ids[:, 1]),
    )
    if weights:
        out += (np.array(wtokens, dtype=np.float32),)
    return out


def iter_text_edges(
    path: str,
    *,
    comment: str = "#",
    dtype=np.int64,
    weights: bool = False,
    chunk_edges: int = 1 << 20,
) -> Iterator[tuple[np.ndarray, ...]]:
    """Stream a SNAP-style whitespace edge list in bounded chunks.

    Yields ``(src, dst)`` — or ``(src, dst, weights)`` with
    ``weights=True`` (third column, float32) — arrays of at most
    ``chunk_edges`` edges per chunk, so arbitrarily large text inputs
    never materialize. Comment lines (``comment`` prefix), blank lines
    and CRLF line endings are handled; extra trailing columns are
    ignored; ``dtype`` sets the id dtype. This is the front end of the
    external-memory ``.dsss`` build (``repro.storage.build``), re-opened
    per pass.
    """
    with open(path, "r", newline=None) as f:
        while True:
            batch = list(itertools.islice(f, chunk_edges))
            if not batch:
                return
            parsed = _parse_lines(batch, comment, dtype, weights)
            if parsed is not None:
                yield parsed


def load_text_edges(
    path: str,
    comment: str = "#",
    *,
    dtype=np.int64,
    weights: bool = False,
    chunk_edges: int = 1 << 20,
) -> tuple[np.ndarray, ...]:
    """SNAP-style whitespace edge list (``src dst [weight]`` per line).

    A thin concatenation over :func:`iter_text_edges` (the streaming
    reader replaced the old pure-Python line loop); returns
    ``(src, dst)``, plus ``weights`` (float32) when ``weights=True``.
    """
    chunks = list(
        iter_text_edges(
            path, comment=comment, dtype=dtype, weights=weights,
            chunk_edges=chunk_edges,
        )
    )
    ncol = 3 if weights else 2
    if not chunks:
        empty = (np.zeros(0, dtype=dtype), np.zeros(0, dtype=dtype))
        return empty + ((np.zeros(0, np.float32),) if weights else ())
    return tuple(
        np.concatenate([c[k] for c in chunks]) for k in range(ncol)
    )


def save_edgelist(path: str, el: EdgeList) -> None:
    """Persist a preprocessed (degreed) edge list, dtypes preserved."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    payload = dict(
        src=np.asarray(el.src),
        dst=np.asarray(el.dst),
        n=np.int64(el.n),
        out_degree=np.asarray(el.out_degree),
        in_degree=np.asarray(el.in_degree),
        id_to_index=np.asarray(el.id_to_index),
    )
    if el.weights is not None:
        payload["weights"] = np.asarray(el.weights)
    np.savez_compressed(path, **payload)


def load_edgelist(path: str) -> EdgeList:
    with np.load(path) as z:
        return EdgeList(
            src=z["src"],
            dst=z["dst"],
            n=int(z["n"]),
            out_degree=z["out_degree"],
            in_degree=z["in_degree"],
            id_to_index=z["id_to_index"],
            weights=(z["weights"] if "weights" in z else None),
        )
