"""Graph substrate: generators, preprocessing (degreeing), and I/O.

This package provides everything *below* the NXgraph core: raw edge lists,
synthetic graph generators matched to the paper's benchmark families, the
"degreeing" pass (sparse index -> dense id densification, paper §III-A), and
binary on-disk formats.
"""
from repro.graph.generators import (
    rmat,
    erdos_renyi,
    random_geometric,
    ring,
    star,
    complete,
    paper_dataset,
)
from repro.graph.preprocess import degree_and_densify, EdgeList
from repro.graph.io import save_edges, load_edges

__all__ = [
    "rmat",
    "erdos_renyi",
    "random_geometric",
    "ring",
    "star",
    "complete",
    "paper_dataset",
    "degree_and_densify",
    "EdgeList",
    "save_edges",
    "load_edges",
]
