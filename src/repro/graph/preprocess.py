"""Degreeing: the first preprocessing step of NXgraph (paper §III-A).

Maps raw, possibly sparse vertex *indices* to dense, contiguous *ids*
(so interval storage needs only an offset + attribute array — constant-time
access), removes duplicate edges and optionally self loops, and computes
in/out degrees. Produces the mapping and reverse mapping the paper's
"degreer" emits, plus the pre-shard (id-space edge list) consumed by the
sharder in :mod:`repro.core.dsss`.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "EdgeList",
    "degree_and_densify",
    "merge_unique_ids",
    "map_to_dense",
]


@dataclasses.dataclass(frozen=True)
class EdgeList:
    """Pre-shard: dense-id edge list plus degree metadata.

    Attributes:
      src, dst:   int32 dense vertex ids, deduplicated.
      n:          number of (non-isolated) vertices. Ids are ``[0, n)``.
      out_degree: int32 ``(n,)`` out-degree per id.
      in_degree:  int32 ``(n,)`` in-degree per id.
      id_to_index: int64 ``(n,)`` reverse mapping (dense id -> raw index).
      weights:    optional float32 per-edge weights (aligned with src/dst).
    """

    src: np.ndarray
    dst: np.ndarray
    n: int
    out_degree: np.ndarray
    in_degree: np.ndarray
    id_to_index: np.ndarray
    weights: np.ndarray | None = None

    @property
    def m(self) -> int:
        return int(self.src.shape[0])

    def index_to_id(self, indices: np.ndarray) -> np.ndarray:
        """Raw index -> dense id (vectorised binary search on the mapping)."""
        pos = np.searchsorted(self.id_to_index, indices)
        pos = np.clip(pos, 0, len(self.id_to_index) - 1)
        ok = self.id_to_index[pos] == indices
        if not np.all(ok):
            raise KeyError("index not present in graph (isolated or unknown)")
        return pos.astype(np.int32)

    def reversed(self) -> "EdgeList":
        """Transpose graph (used by SCC's backward phase)."""
        return EdgeList(
            src=self.dst,
            dst=self.src,
            n=self.n,
            out_degree=self.in_degree,
            in_degree=self.out_degree,
            id_to_index=self.id_to_index,
            weights=self.weights,
        )

    def symmetrized(self) -> "EdgeList":
        """Undirected view: both edge directions (used by WCC).

        One fused dedup + degree pass: the sorted unique ``src·n + dst``
        keys *are* the deduplicated edge list (key // n, key % n), so the
        endpoints are decoded straight from them instead of re-gathering
        the doubled edge buffers, and — because the deduplicated
        symmetrized set is closed under transposition — a single bincount
        yields both degrees (out ≡ in). The old code paid two O(2m)
        fancy-indexed gathers plus two bincounts after already computing
        the keep set.
        """
        src = np.concatenate([self.src, self.dst])
        dst = np.concatenate([self.dst, self.src])
        key = src.astype(np.int64) * self.n + dst
        if self.weights is None:
            uniq = np.unique(key)
            w2 = None
        else:
            w = np.concatenate([self.weights] * 2)
            uniq, keep = np.unique(key, return_index=True)
            w2 = w[keep]
        src2 = (uniq // self.n).astype(np.int32)
        dst2 = (uniq % self.n).astype(np.int32)
        deg = np.bincount(src2, minlength=self.n).astype(np.int32)
        return EdgeList(
            src=src2,
            dst=dst2,
            n=self.n,
            out_degree=deg,
            in_degree=deg,  # symmetric set: in-degree == out-degree exactly
            id_to_index=self.id_to_index,
            weights=w2,
        )


def merge_unique_ids(acc: np.ndarray, *chunks: np.ndarray) -> np.ndarray:
    """Fold edge-chunk endpoints into a sorted unique id array.

    The chunked (external-memory) counterpart of ``np.unique`` over all
    endpoints in :func:`degree_and_densify`: calling this per streamed
    chunk accumulates exactly the dense-id mapping the one-shot pass
    computes, with peak memory O(vertices + chunk), never O(edges).
    """
    parts = [acc] + [np.asarray(c, dtype=np.int64).reshape(-1) for c in chunks]
    return np.unique(np.concatenate(parts))


def map_to_dense(id_to_index: np.ndarray, values: np.ndarray) -> np.ndarray:
    """Raw indices -> dense ids against a sorted mapping (validated).

    Same contract as :meth:`EdgeList.index_to_id` but as a free function
    over an explicit mapping array, so the streaming build pipeline can
    map chunks before the :class:`EdgeList` exists.
    """
    values = np.asarray(values, dtype=np.int64)
    pos = np.searchsorted(id_to_index, values)
    pos = np.clip(pos, 0, max(len(id_to_index) - 1, 0))
    if len(id_to_index) == 0 or not np.all(id_to_index[pos] == values):
        raise KeyError("index not present in the accumulated id mapping")
    return pos.astype(np.int32)


def degree_and_densify(
    src: np.ndarray,
    dst: np.ndarray,
    weights: np.ndarray | None = None,
    *,
    drop_self_loops: bool = False,
    dedup: bool = True,
) -> EdgeList:
    """The degreeing pass: raw sparse indices -> dense contiguous ids.

    Vertices with no incident edge are eliminated (the paper's vertex counts
    exclude isolated vertices — Table III footnote).
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    if src.shape != dst.shape:
        raise ValueError(f"src/dst shape mismatch: {src.shape} vs {dst.shape}")
    if drop_self_loops:
        keep = src != dst
        src, dst = src[keep], dst[keep]
        if weights is not None:
            weights = weights[keep]
    # Dense id assignment over the union of endpoints, sorted by raw index so
    # that the mapping is monotone (searchsorted-able reverse mapping).
    id_to_index, inverse = np.unique(
        np.concatenate([src, dst]), return_inverse=True
    )
    m = src.shape[0]
    src_id = inverse[:m].astype(np.int32)
    dst_id = inverse[m:].astype(np.int32)
    n = int(id_to_index.shape[0])
    if dedup:
        key = src_id.astype(np.int64) * n + dst_id
        _, keep_idx = np.unique(key, return_index=True)
        src_id, dst_id = src_id[keep_idx], dst_id[keep_idx]
        if weights is not None:
            weights = weights[keep_idx]
    out_deg = np.bincount(src_id, minlength=n).astype(np.int32)
    in_deg = np.bincount(dst_id, minlength=n).astype(np.int32)
    return EdgeList(
        src=src_id,
        dst=dst_id,
        n=n,
        out_degree=out_deg,
        in_degree=in_deg,
        id_to_index=id_to_index,
        weights=None if weights is None else weights.astype(np.float32),
    )
