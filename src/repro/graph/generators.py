"""Synthetic graph generators.

The container is offline, so the paper's real-world datasets (LiveJournal,
Twitter, Yahoo-web) are stood in for by RMAT graphs with matched degree skew,
and the delaunay_nXX synthetic family by 2-D random-geometric graphs (both
are planar-ish meshes with low, near-uniform degree, which is the property
the paper's scalability experiment exercises).

All generators are deterministic given ``seed`` and return ``(src, dst)``
int64 numpy arrays of *raw indices* (possibly sparse / with duplicates),
i.e. exactly what the degreeing pass (paper §III-A) expects as input.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "rmat",
    "zipf",
    "erdos_renyi",
    "random_geometric",
    "ring",
    "star",
    "complete",
    "paper_dataset",
]


def rmat(
    scale: int,
    edge_factor: int = 16,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """R-MAT power-law generator (Chakrabarti et al.), Graph500 defaults.

    ``2**scale`` vertices, ``edge_factor * 2**scale`` directed edges.
    The (a, b, c, d) quadrant probabilities reproduce the heavy skew of
    social graphs such as Twitter; with a == b == c == d it degenerates to
    Erdos-Renyi.
    """
    n_bits = scale
    m = edge_factor << scale
    rng = np.random.default_rng(seed)
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    d = 1.0 - a - b - c
    if d < -1e-9:
        raise ValueError("RMAT probabilities must sum to <= 1")
    # Draw each address bit independently: quadrant choice per bit level.
    for bit in range(n_bits):
        r = rng.random(m)
        # quadrant thresholds: [a, a+b, a+b+c, 1]
        src_bit = (r >= a + b).astype(np.int64)  # bottom half rows -> c or d
        in_bottom = r >= a + b
        in_right_top = (r >= a) & (r < a + b)
        in_right_bottom = r >= a + b + c
        dst_bit = (in_right_top | in_right_bottom).astype(np.int64)
        src |= src_bit << bit
        dst |= dst_bit << bit
        del in_bottom
    return src, dst


def zipf(
    n: int, m: int, alpha: float = 1.8, seed: int = 0
) -> tuple[np.ndarray, np.ndarray]:
    """Zipf in-degree graph: dst ~ rank^(-alpha), src uniform.

    The destination skew is the quantity that stresses destination-sorted
    layouts (hub edge runs grow with the top ranks' mass) — the adaptive
    tile-packing benchmarks and property tests use this as the controlled
    power-law counterpart to :func:`rmat`. ``alpha`` ≈ 1.8–2.2 matches the
    in-degree exponents reported for web/social graphs.
    """
    if n < 1:
        raise ValueError("zipf needs n >= 1")
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, n + 1, dtype=np.float64)
    p = ranks**-alpha
    p /= p.sum()
    # Destination ids are a random permutation of the ranks so hubs are not
    # clustered in the low intervals (interval 0 would otherwise hold every
    # hub, which is a different — partitioning — pathology).
    perm = rng.permutation(n)
    dst = perm[rng.choice(n, size=m, p=p)]
    src = rng.integers(0, n, size=m, dtype=np.int64)
    return src.astype(np.int64), dst.astype(np.int64)


def erdos_renyi(n: int, m: int, seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """G(n, m) uniform random directed graph (with possible duplicates)."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, size=m, dtype=np.int64)
    dst = rng.integers(0, n, size=m, dtype=np.int64)
    return src, dst


def random_geometric(
    n: int, k: int = 6, seed: int = 0
) -> tuple[np.ndarray, np.ndarray]:
    """Approximate delaunay_nXX: connect each point to its ~k nearest
    neighbours on a 2-D grid-bucketed unit square.

    True Delaunay triangulation needs scipy (not installed); k-NN on a
    bucketed grid yields the same structural class the paper uses the
    delaunay graphs for — planar-ish, bounded near-uniform degree meshes.
    Returns a symmetric (both directions) edge list.
    """
    rng = np.random.default_rng(seed)
    pts = rng.random((n, 2))
    g = max(1, int(np.sqrt(n / 4)))
    cell = np.minimum((pts * g).astype(np.int64), g - 1)
    cell_id = cell[:, 0] * g + cell[:, 1]
    order = np.argsort(cell_id, kind="stable")
    sorted_ids = cell_id[order]
    srcs: list[np.ndarray] = []
    dsts: list[np.ndarray] = []
    # Within each bucket connect consecutive points (by index order) in a
    # small sliding window — O(n k) and spatially local.
    starts = np.searchsorted(sorted_ids, np.arange(g * g), side="left")
    ends = np.searchsorted(sorted_ids, np.arange(g * g), side="right")
    for b in range(g * g):
        idx = order[starts[b] : ends[b]]
        if len(idx) < 2:
            continue
        for off in range(1, min(k // 2 + 1, len(idx))):
            s = idx[:-off]
            t = idx[off:]
            srcs.append(s)
            dsts.append(t)
    # Stitch neighbouring buckets with a coarse chain so the mesh is connected.
    bucket_rep = order[starts[starts < ends]] if np.any(starts < ends) else order[:1]
    if len(bucket_rep) > 1:
        srcs.append(bucket_rep[:-1])
        dsts.append(bucket_rep[1:])
    src = np.concatenate(srcs) if srcs else np.zeros(0, dtype=np.int64)
    dst = np.concatenate(dsts) if dsts else np.zeros(0, dtype=np.int64)
    return np.concatenate([src, dst]), np.concatenate([dst, src])


def ring(n: int) -> tuple[np.ndarray, np.ndarray]:
    v = np.arange(n, dtype=np.int64)
    return v, (v + 1) % n


def star(n: int) -> tuple[np.ndarray, np.ndarray]:
    """Hub vertex 0 -> all others (worst case for destination skew)."""
    leaves = np.arange(1, n, dtype=np.int64)
    return np.full(n - 1, 0, dtype=np.int64), leaves


def complete(n: int) -> tuple[np.ndarray, np.ndarray]:
    s, t = np.meshgrid(np.arange(n, dtype=np.int64), np.arange(n, dtype=np.int64))
    mask = s != t
    return s[mask].ravel(), t[mask].ravel()


# ---------------------------------------------------------------------------
# Paper-dataset stand-ins (offline container: scaled-down, skew-matched).
# ---------------------------------------------------------------------------
_PAPER_DATASETS = {
    # name: (generator, kwargs, paper n, paper m) — scaled for CPU runtime.
    "live-journal": ("rmat", dict(scale=15, edge_factor=14, seed=1), 4.85e6, 69.0e6),
    "twitter": ("rmat", dict(scale=16, edge_factor=22, seed=2), 41.7e6, 1.47e9),
    "yahoo-web": ("rmat", dict(scale=17, edge_factor=9, seed=3), 720e6, 6.64e9),
    "delaunay_n15": ("geo", dict(n=1 << 15, seed=20), 1.05e6, 6.29e6),
    "delaunay_n16": ("geo", dict(n=1 << 16, seed=21), 2.10e6, 12.6e6),
    "delaunay_n17": ("geo", dict(n=1 << 17, seed=22), 4.19e6, 25.2e6),
    "delaunay_n18": ("geo", dict(n=1 << 18, seed=23), 8.39e6, 50.3e6),
    "delaunay_n19": ("geo", dict(n=1 << 19, seed=24), 16.8e6, 101e6),
}


def paper_dataset(name: str) -> tuple[np.ndarray, np.ndarray]:
    """Scaled-down, skew-matched stand-in for a paper benchmark graph."""
    kind, kwargs, _, _ = _PAPER_DATASETS[name]
    if kind == "rmat":
        return rmat(**kwargs)
    return random_geometric(**kwargs)


def paper_dataset_names() -> list[str]:
    return list(_PAPER_DATASETS)
