"""Sweep-level checkpoint/resume for iterative engine runs.

A 100-iteration PageRank killed at sweep 99 should not restart from zero.
:class:`CheckpointSpec` is the plan axis (``ExecutionPlan(checkpoint=...)``)
that makes :meth:`GraphSession._execute` atomically snapshot the full
iteration state — vertex attributes for every fused query, the activity
bitmaps, the per-query convergence sweeps, the activity log, and the
cumulative :class:`~repro.core.session.Meters` — every ``every`` sweeps.

Snapshots are single ``.npz`` files written tmp → flush → fsync →
``os.replace`` → fsync(dir), so a crash at any instant leaves either the
previous complete snapshot or the new complete snapshot, never a torn
one. Keep-N pruning happens *after* publish and is derived purely from
the filename pattern (``sweep_%08d.npz``) — there is no separate index
file to orphan, so pruning is crash-safe by construction.

``session.run(plan, resume_from=...)`` restores the snapshot and
continues the loop; the contract (enforced by the chaos suite) is
bit-identical results and field-identical cumulative meters vs the
uninterrupted run — wall_seconds excepted, which accumulates real elapsed
time across attempts.
"""
from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

from repro.obs.registry import REGISTRY as _REGISTRY

_OBS_SAVES = _REGISTRY.counter(
    "repro_checkpoint_saves_total", "Checkpoint snapshots published"
)
_OBS_SAVE_BYTES = _REGISTRY.counter(
    "repro_checkpoint_bytes_total", "Bytes of published checkpoint snapshots"
)

__all__ = [
    "CheckpointSpec",
    "SnapshotError",
    "latest_snapshot",
    "list_snapshots",
    "load_snapshot",
    "save_snapshot",
    "snapshot_path",
]

_PATTERN = "sweep_%08d.npz"
_META_KEY = "__meta_json__"


class SnapshotError(RuntimeError):
    """A snapshot is unreadable or does not match the resuming plan."""


@dataclasses.dataclass(frozen=True)
class CheckpointSpec:
    """The checkpoint axis of an :class:`~repro.core.plan.ExecutionPlan`.

    Args:
      directory: where snapshots land (created on first save).
      every: snapshot cadence in sweeps (after every ``every``-th sweep).
      keep: how many most-recent snapshots survive pruning.
    """

    directory: str
    every: int = 1
    keep: int = 2

    def __post_init__(self):
        if not self.directory:
            raise ValueError("checkpoint directory must be non-empty")
        if self.every < 1:
            raise ValueError(f"checkpoint every must be >= 1, got {self.every}")
        if self.keep < 1:
            raise ValueError(f"checkpoint keep must be >= 1, got {self.keep}")


def snapshot_path(directory: str, sweep: int) -> str:
    return os.path.join(directory, _PATTERN % sweep)


def list_snapshots(directory: str) -> list[str]:
    """Complete snapshots in ``directory``, oldest first."""
    if not os.path.isdir(directory):
        return []
    names = [
        n
        for n in os.listdir(directory)
        if n.startswith("sweep_") and n.endswith(".npz")
    ]
    return [os.path.join(directory, n) for n in sorted(names)]


def latest_snapshot(directory: str) -> str | None:
    snaps = list_snapshots(directory)
    return snaps[-1] if snaps else None


def save_snapshot(
    directory: str,
    sweep: int,
    arrays: dict[str, np.ndarray],
    meta: dict,
    *,
    keep: int = 2,
) -> str:
    """Atomically publish one snapshot; prune to the newest ``keep``.

    The payload hits disk (flush + fsync) before ``os.replace`` makes it
    visible under its final name, and the directory is fsynced after the
    rename so the publish itself survives a crash. Returns the final path.
    """
    os.makedirs(directory, exist_ok=True)
    final = snapshot_path(directory, sweep)
    tmp = final + ".tmp"
    payload = dict(arrays)
    if _META_KEY in payload:
        raise ValueError(f"array name {_META_KEY!r} is reserved")
    payload[_META_KEY] = np.frombuffer(
        json.dumps(meta, sort_keys=True).encode("utf-8"), dtype=np.uint8
    )
    with open(tmp, "wb") as fh:
        np.savez(fh, **payload)
        fh.flush()
        os.fsync(fh.fileno())
    os.replace(tmp, final)
    dirfd = os.open(directory, os.O_RDONLY)
    try:
        os.fsync(dirfd)
    finally:
        os.close(dirfd)
    _OBS_SAVES.inc()
    _OBS_SAVE_BYTES.inc(os.path.getsize(final))
    # Prune after publish: the new snapshot is durable before any old one
    # dies, so a crash anywhere in here leaves >= keep restorable states.
    snaps = list_snapshots(directory)
    for stale in snaps[:-keep] if keep else snaps:
        if stale != final:
            os.unlink(stale)
    # Orphaned tmp files from crashed saves are dead weight — sweep them.
    for name in os.listdir(directory):
        if name.endswith(".npz.tmp"):
            os.unlink(os.path.join(directory, name))
    return final


def load_snapshot(path: str) -> tuple[dict[str, np.ndarray], dict]:
    """Read one snapshot back as ``(arrays, meta)``."""
    try:
        with np.load(path) as z:
            arrays = {k: z[k] for k in z.files if k != _META_KEY}
            if _META_KEY not in z.files:
                raise SnapshotError(f"{path}: missing snapshot metadata")
            meta = json.loads(bytes(z[_META_KEY]).decode("utf-8"))
    except (OSError, ValueError) as exc:
        raise SnapshotError(f"{path}: unreadable snapshot: {exc}") from exc
    return arrays, meta
