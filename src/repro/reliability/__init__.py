"""repro.reliability — fault injection, checkpoint/resume, degraded reads.

The reliability layer for the NXgraph engine: deterministic fault plans
injected at every real I/O boundary (:mod:`.faults`), atomic sweep-level
snapshot/resume for iterative runs (:mod:`.checkpoint`), and quarantined-
segment repair for the `.dsss` disk tier (:mod:`.repair` — imported
lazily, since it pulls in the storage build pipeline).

This package's eager imports are stdlib+numpy only, so ``core.plan`` and
``storage.format`` can depend on it without cycles.
"""
from repro.reliability.checkpoint import (
    CheckpointSpec,
    SnapshotError,
    latest_snapshot,
    list_snapshots,
    load_snapshot,
    save_snapshot,
)
from repro.reliability.faults import (
    DeadlineExceeded,
    FailureInjector,
    FaultInjector,
    FaultPlan,
    FaultSpec,
    InjectedCrash,
    SimulatedFailure,
    StepTimer,
    StragglerWatchdog,
    TransientFault,
    elastic_device_count,
    with_transient_retries,
)

__all__ = [
    "CheckpointSpec",
    "DeadlineExceeded",
    "FailureInjector",
    "FaultInjector",
    "FaultPlan",
    "FaultSpec",
    "InjectedCrash",
    "SimulatedFailure",
    "SnapshotError",
    "StepTimer",
    "StragglerWatchdog",
    "TransientFault",
    "elastic_device_count",
    "latest_snapshot",
    "list_snapshots",
    "load_snapshot",
    "save_snapshot",
    "with_transient_retries",
]
