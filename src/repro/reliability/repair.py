"""Rebuild quarantined ``.dsss`` segments from the raw edge source.

The last resort of the self-healing read path: when a segment stays
corrupt through the bounded re-read budget (persistent media damage, not
a torn read), the container itself is the casualty — but the raw edge
source that built it usually still exists. :func:`repair_dsss` scans the
damaged container, rebuilds a pristine replacement next to it with the
bounded external-memory build pipeline, verifies the replacement, and
atomically swaps it in (``os.replace``) — quarantine cleared, same path.

This is a whole-container rebuild, not a surgical segment splice: the
block and packed segments are derived views of one edge stream, so a
damaged ``p_src`` means re-deriving the tile layout anyway, and atomic
whole-file replacement is the only repair that can never leave a
half-patched container behind.

Kept out of ``repro.reliability``'s eager imports — it pulls in the
storage build pipeline (and through it the core engine); import it as
``from repro.reliability.repair import repair_dsss``.
"""
from __future__ import annotations

import os

__all__ = ["repair_dsss"]


def repair_dsss(
    path: str,
    source: str | None = None,
    *,
    weights: bool | None = None,
    P: int | None = None,
    **build_kwargs,
) -> dict:
    """Verify a container; rebuild it from ``source`` if any segment is bad.

    Args:
      path: the ``.dsss`` container to check/repair.
      source: text edge list the container was built from. ``None`` means
        report-only — damaged segments are listed but nothing is rebuilt.
      weights / P: rebuild parameters; default to the damaged container's
        own footer metadata (its footer survives segment corruption —
        both are crc-checked independently).
      build_kwargs: forwarded to
        :func:`repro.storage.build.build_from_text` (``chunk_budget``,
        ``drop_self_loops``, ...).

    Returns a report dict: ``{"path", "damaged": [segment names],
    "repaired": bool, "source"}``. Raises :class:`ValueError` when damage
    is found but no source was given, and propagates build/verify errors
    from a failed rebuild (the damaged original is left untouched — the
    swap only happens after the replacement verifies clean).
    """
    from repro.storage.build import build_from_text
    from repro.storage.format import DSSSStore, verify_dsss

    store = DSSSStore(path)
    damaged = store.scan()
    report = {
        "path": path,
        "damaged": damaged,
        "repaired": False,
        "source": source,
    }
    if not damaged:
        return report
    if source is None:
        raise ValueError(
            f"{path}: segments {damaged} are damaged and no --source edge "
            "list was given to rebuild from"
        )
    if P is None:
        P = int(store.meta["P"])
    if weights is None:
        weights = bool(store.meta.get("weighted", False))
    tmp = path + ".repair.tmp"
    try:
        build_from_text(source, tmp, P, weights=weights, **build_kwargs)
        verify_dsss(tmp)  # never swap in an unverified replacement
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    report["repaired"] = True
    return report
