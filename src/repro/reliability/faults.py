"""Deterministic fault injection for every layer that moves data.

Production graph serving fails in boring, repeatable ways — torn disk
reads, transient H2D transfer errors, stalled devices, killed processes —
and a reliability layer is only trustworthy if those failures can be
*reproduced on demand*. This module is the single injection API:

* :class:`FaultPlan` — a frozen, seedable description of which faults fire
  where. Specs target the real I/O boundaries by *site*:

  - ``"storage"`` — the ``.dsss`` segment verification reads in
    :mod:`repro.storage.format` (``corrupt`` / ``short`` torn reads,
    cleared after ``times`` re-reads or persistent with ``times=None``);
  - ``"h2d"`` — the host→device transfers in ``_BlockFetcher`` and the
    packed-stream chunk fetch (``transient`` errors, ``stall`` sleeps);
  - ``"sweep"`` — crash-at-sweep-N in the engine loop
    (:meth:`GraphSession._execute`);
  - ``"step"`` — the train-loop step injection the old
    ``repro.runtime.fault.FailureInjector`` provided (now a shim over
    this module).

* :class:`FaultInjector` — the live, counting instance a plan builds
  (``plan.injector()``). Sessions and stores share one injector so fire
  budgets are accounted once across layers.

Determinism: rate-based specs draw from a counter-hashed ``zlib.crc32``
stream of ``(seed, spec, occurrence)`` — the same plan against the same
deterministic call sequence fires at exactly the same events, so chaos
tests are replayable and bit-identity oracles stay meaningful.

Exception taxonomy: :class:`SimulatedFailure` (the legacy train-loop name)
is the base of every injected fault; :class:`InjectedCrash` models process
death (recover by resuming from a checkpoint), :class:`TransientFault`
models a retryable I/O error (recover by retrying the transfer / the
batch). :class:`DeadlineExceeded` is *not* a fault — it is the cooperative
between-sweep cancellation signal the serving deadline machinery raises.
"""
from __future__ import annotations

import dataclasses
import time
import zlib

from repro.obs.registry import REGISTRY as _REGISTRY

_OBS_RETRIES = _REGISTRY.counter(
    "repro_transient_retries_total",
    "Transient-fault retries at transfer boundaries",
    ("site",),
)
_OBS_RETRIES_H2D = _OBS_RETRIES.labels(site="h2d")

__all__ = [
    "DeadlineExceeded",
    "FailureInjector",
    "FaultInjector",
    "FaultPlan",
    "FaultSpec",
    "InjectedCrash",
    "SimulatedFailure",
    "StepTimer",
    "StragglerWatchdog",
    "TransientFault",
    "elastic_device_count",
    "with_transient_retries",
]


class SimulatedFailure(RuntimeError):
    """Base of every injected fault (the legacy train-loop name)."""


class InjectedCrash(SimulatedFailure):
    """An injected process-death analogue (recover via checkpoint/resume)."""


class TransientFault(SimulatedFailure):
    """An injected retryable I/O error (recover via bounded retry)."""


class DeadlineExceeded(RuntimeError):
    """A request's deadline passed — cooperative between-sweep cancellation."""


_SITES = ("storage", "h2d", "sweep", "step")
_KINDS = ("crash", "transient", "stall", "corrupt", "short")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One fault rule: where it fires, what it does, and how often.

    Args:
      site: injection boundary — one of ``"storage"``, ``"h2d"``,
        ``"sweep"``, ``"step"``.
      kind: what happens on a hit — ``"crash"`` raises
        :class:`InjectedCrash`, ``"transient"`` raises
        :class:`TransientFault`, ``"stall"`` sleeps ``stall_s``,
        ``"corrupt"``/``"short"`` (storage site) make the verification
        read observe flipped / truncated bytes.
      at: fire exactly at these integer identities (sweep / step numbers).
      match: substring filter on string identities (segment names, h2d
        transfer labels like ``"block:0,1"`` / ``"chunk:64"``); ``""``
        matches everything.
      rate: per-occurrence probability, drawn deterministically from the
        plan seed. ``0.0`` with empty ``at`` means "every matching event".
      times: total fire budget (``None`` = unlimited / persistent). For
        storage specs this is the number of consecutive *attempts* that
        observe the bad bytes — a torn read that clears after re-reads.
      stall_s: sleep duration for ``kind="stall"``.
    """

    site: str
    kind: str = "crash"
    at: tuple[int, ...] = ()
    match: str = ""
    rate: float = 0.0
    times: int | None = 1
    stall_s: float = 0.0

    def __post_init__(self):
        if self.site not in _SITES:
            raise ValueError(f"site must be one of {_SITES}, got {self.site!r}")
        if self.kind not in _KINDS:
            raise ValueError(f"kind must be one of {_KINDS}, got {self.kind!r}")
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {self.rate}")
        if self.times is not None and self.times < 1:
            raise ValueError(f"times must be >= 1 or None, got {self.times}")
        object.__setattr__(self, "at", tuple(int(s) for s in self.at))


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A frozen, seedable set of fault rules; ``injector()`` makes it live."""

    specs: tuple[FaultSpec, ...] = ()
    seed: int = 0

    def __post_init__(self):
        specs = tuple(self.specs)
        for s in specs:
            if not isinstance(s, FaultSpec):
                raise TypeError(
                    f"specs must be FaultSpec instances, got {type(s).__name__}"
                )
        object.__setattr__(self, "specs", specs)

    # -- convenience constructors -------------------------------------------
    @classmethod
    def crash_at_sweep(cls, sweep: int, *, seed: int = 0) -> "FaultPlan":
        """Kill the engine loop right before executing sweep ``sweep``
        (``sweep`` update sweeps have completed when it fires; fires once,
        so a resumed run proceeds)."""
        return cls(specs=(FaultSpec(site="sweep", at=(sweep,)),), seed=seed)

    @classmethod
    def crash_at_step(cls, *steps: int, seed: int = 0) -> "FaultPlan":
        """The train-loop injection: crash at the given step numbers."""
        return cls(
            specs=(FaultSpec(site="step", at=tuple(steps), times=len(steps)),),
            seed=seed,
        )

    @classmethod
    def h2d_transient(
        cls, *, rate: float = 0.0, times: int | None = 1,
        match: str = "", seed: int = 0,
    ) -> "FaultPlan":
        """Transient host→device transfer errors (``rate=0`` = every
        matching transfer, until the ``times`` budget is spent)."""
        return cls(
            specs=(
                FaultSpec(
                    site="h2d", kind="transient", rate=rate, times=times,
                    match=match,
                ),
            ),
            seed=seed,
        )

    @classmethod
    def h2d_stall(
        cls, stall_s: float, *, rate: float = 0.0, times: int | None = 1,
        seed: int = 0,
    ) -> "FaultPlan":
        """Slow-device injection: matching transfers sleep ``stall_s``."""
        return cls(
            specs=(
                FaultSpec(
                    site="h2d", kind="stall", stall_s=stall_s, rate=rate,
                    times=times,
                ),
            ),
            seed=seed,
        )

    @classmethod
    def storage_corrupt(
        cls, segment: str = "", *, times: int | None = None, seed: int = 0,
    ) -> "FaultPlan":
        """Segment reads matching ``segment`` observe corrupted bytes for
        the first ``times`` attempts (``None`` = persistent corruption)."""
        return cls(
            specs=(
                FaultSpec(site="storage", kind="corrupt", match=segment, times=times),
            ),
            seed=seed,
        )

    @classmethod
    def storage_short(
        cls, segment: str = "", *, times: int | None = None, seed: int = 0,
    ) -> "FaultPlan":
        """Segment reads matching ``segment`` come up short (truncated)."""
        return cls(
            specs=(
                FaultSpec(site="storage", kind="short", match=segment, times=times),
            ),
            seed=seed,
        )

    def merge(self, other: "FaultPlan") -> "FaultPlan":
        """Union of two plans (keeps this plan's seed)."""
        return FaultPlan(specs=self.specs + other.specs, seed=self.seed)

    def injector(self) -> "FaultInjector":
        return FaultInjector(self)


class FaultInjector:
    """The live, counting instance of a :class:`FaultPlan`.

    One injector is shared by every layer of a session (engine loop,
    block fetcher, packed stream, backing store) so per-spec fire budgets
    are spent once, globally — a ``times=1`` crash that fired during the
    first attempt stays quiet during the resumed run.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._fired = [0] * len(plan.specs)  # per-spec fire count
        self._occ = [0] * len(plan.specs)  # per-spec occurrence counter
        self.injected = 0  # total raises/stalls/corruptions delivered

    # -- accounting ----------------------------------------------------------
    def fired(self, site: str | None = None) -> int:
        """Total injections delivered (optionally for one site)."""
        if site is None:
            return self.injected
        return sum(
            n
            for n, spec in zip(self._fired, self.plan.specs)
            if spec.site == site
        )

    def _coin(self, spec_index: int, occurrence: int) -> float:
        key = f"{self.plan.seed}:{spec_index}:{occurrence}".encode()
        return zlib.crc32(key) / 0xFFFFFFFF

    def _hits(self, site: str, identity) -> "list[FaultSpec]":
        hits = []
        for i, spec in enumerate(self.plan.specs):
            if spec.site != site:
                continue
            if spec.times is not None and self._fired[i] >= spec.times:
                continue
            if spec.match and spec.match not in str(identity):
                continue
            if spec.at:
                hit = isinstance(identity, int) and identity in spec.at
            elif spec.rate > 0.0:
                occ = self._occ[i]
                self._occ[i] += 1
                hit = self._coin(i, occ) < spec.rate
            else:
                hit = True  # unconditional (until the budget is spent)
            if hit:
                self._fired[i] += 1
                self.injected += 1
                hits.append(spec)
        return hits

    # -- the injection points ------------------------------------------------
    def check(self, site: str, identity) -> None:
        """Consult the plan at one event; raise / stall on a hit.

        ``identity`` is the event's stable label: the sweep/step number
        (int) or the transfer label (str). Stalls execute before any
        raise, so a stall+crash plan stalls then dies, like hardware.
        """
        hits = self._hits(site, identity)
        for spec in hits:
            if spec.kind == "stall":
                time.sleep(spec.stall_s)
        for spec in hits:
            if spec.kind == "transient":
                raise TransientFault(
                    f"injected transient fault at {site} {identity!r}"
                )
            if spec.kind == "crash":
                raise InjectedCrash(f"injected crash at {site} {identity!r}")

    def storage_read(self, segment: str, attempt: int) -> str | None:
        """Decision for one storage verification read of ``segment``.

        Returns ``"corrupt"`` / ``"short"`` when the read should observe
        bad bytes, ``None`` for a clean read. Storage specs are
        *attempt-indexed*: a ``times=k`` torn read clears on the k-th
        re-read (bounded retry heals it); ``times=None`` is persistent
        media corruption (retry cannot heal — quarantine).
        """
        for spec in self.plan.specs:
            if spec.site != "storage":
                continue
            if spec.match and spec.match not in segment:
                continue
            if spec.times is None or attempt < spec.times:
                self.injected += 1
                return spec.kind
        return None


def with_transient_retries(
    injector: FaultInjector | None,
    identity: str,
    fn,
    *,
    retries: int = 3,
    backoff_s: float = 0.001,
):
    """Run one transfer with bounded retry-with-backoff on injected faults.

    The self-healing wrapper at the H2D boundary: a transient fault is
    retried up to ``retries`` times with exponential backoff before it
    escapes to the caller (where serving-level retry / the circuit breaker
    take over). With no injector attached this is exactly ``fn()``.
    """
    if injector is None:
        return fn()
    attempt = 0
    while True:
        try:
            injector.check("h2d", identity)
            return fn()
        except TransientFault:
            if attempt >= retries:
                raise
            _OBS_RETRIES_H2D.inc()
            time.sleep(backoff_s * (2.0**attempt))
            attempt += 1


# ---------------------------------------------------------------------------
# Legacy train-loop primitives (moved here from repro.runtime.fault — that
# module is now a re-export shim). FailureInjector keeps its exact API;
# its SimulatedFailure is the base class above, so the train loop's
# recovery path also catches engine-level InjectedCrash faults.
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class FailureInjector:
    """Raise SimulatedFailure at the given steps (each fires once)."""

    fail_at_steps: tuple[int, ...] = ()
    fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int) -> None:
        if step in self.fail_at_steps and step not in self.fired:
            self.fired.add(step)
            raise SimulatedFailure(f"injected failure at step {step}")


@dataclasses.dataclass
class StragglerWatchdog:
    """EWMA step-time outlier detector.

    ``update`` returns True when the step took more than ``threshold`` ×
    the smoothed time — the signal a production controller uses to start
    the mitigation runbook (snapshot, evict host, re-mesh). The serving
    layer reuses it as the slow-sweep detector: every dispatched batch's
    run time feeds one watchdog and flagged batches count into
    ``ServerStats.slow_batches`` (injected H2D stalls show up here).
    """

    alpha: float = 0.1
    threshold: float = 3.0
    warmup: int = 5
    _ewma: float = 0.0
    _count: int = 0
    flagged: list = dataclasses.field(default_factory=list)

    def update(self, step: int, step_seconds: float) -> bool:
        self._count += 1
        if self._count <= self.warmup:
            # establish a baseline before flagging
            self._ewma = (
                step_seconds
                if self._ewma == 0.0
                else (1 - self.alpha) * self._ewma + self.alpha * step_seconds
            )
            return False
        is_straggler = step_seconds > self.threshold * self._ewma
        if is_straggler:
            self.flagged.append((step, step_seconds, self._ewma))
        else:
            self._ewma = (1 - self.alpha) * self._ewma + self.alpha * step_seconds
        return is_straggler


def elastic_device_count(
    available: int, *, model_parallel: int = 1, minimum: int = 1
) -> int:
    """Largest device count ≤ available that keeps the mesh valid.

    The model axis is fixed (parameter shardings must divide it); the data
    axis absorbs the loss — so usable = model_parallel × floor(available /
    model_parallel). Checkpoint reshard-on-load does the rest.
    """
    usable = (available // model_parallel) * model_parallel
    if usable < minimum:
        raise RuntimeError(
            f"only {available} devices available; need >= {minimum}"
        )
    return usable


class StepTimer:
    def __init__(self):
        self._t = None

    def tick(self) -> float:
        now = time.perf_counter()
        dt = 0.0 if self._t is None else now - self._t
        self._t = now
        return dt
