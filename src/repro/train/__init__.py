"""Training substrate: state, step builder, fault-tolerant loop."""
from repro.train.state import TrainState, abstract_train_state, make_train_state
from repro.train.step import chunked_cross_entropy, make_loss_fn, make_train_step

__all__ = [
    "TrainState",
    "abstract_train_state",
    "make_train_state",
    "chunked_cross_entropy",
    "make_loss_fn",
    "make_train_step",
]
