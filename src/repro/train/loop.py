"""Fault-tolerant training driver.

``train(...)`` wires together: synthetic data, the jitted train step,
async checkpointing with keep-N, automatic restore-latest on start (so a
restarted job resumes), failure-injection-driven crash recovery (the
in-process analogue of a preemption restart loop), and the straggler
watchdog. The same driver backs examples/train_lm.py and the recovery
integration tests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ModelConfig
from repro.data.pipeline import SyntheticLM, SyntheticLMConfig
from repro.optim import AdamW
from repro.runtime.fault import (
    FailureInjector,
    SimulatedFailure,
    StragglerWatchdog,
)
from repro.train.state import make_train_state
from repro.train.step import make_train_step

__all__ = ["TrainLoopConfig", "train"]


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 20
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    seed: int = 0
    seq_len: int = 128
    global_batch: int = 8
    learning_rate: float = 3e-4
    clip_norm: float = 1.0
    accum_steps: int = 1
    grad_sync: str = "none"
    log_every: int = 10
    max_recoveries: int = 10


def train(
    cfg: ModelConfig,
    loop: TrainLoopConfig,
    *,
    failure_injector: FailureInjector | None = None,
    on_metrics: Callable[[int, dict], None] | None = None,
) -> dict:
    """Run (or resume) training. Returns summary stats.

    Crash recovery: any SimulatedFailure (or preemption-like error) inside
    the step loop triggers restore-from-latest and continues — the whole
    path a production controller would drive across processes, exercised
    in-process.
    """
    optimizer = AdamW(learning_rate=loop.learning_rate, weight_decay=0.01)
    step_fn = jax.jit(
        make_train_step(
            cfg,
            optimizer,
            clip_norm=loop.clip_norm,
            accum_steps=loop.accum_steps,
            grad_sync=loop.grad_sync,
        ),
        donate_argnums=(0,),
    )
    data = SyntheticLM(
        SyntheticLMConfig(
            vocab_size=cfg.vocab_size,
            seq_len=loop.seq_len,
            global_batch=loop.global_batch,
            seed=loop.seed,
        )
    )
    ckpt = CheckpointManager(loop.checkpoint_dir, keep=loop.keep)
    watchdog = StragglerWatchdog()

    state = make_train_state(cfg, optimizer, jax.random.PRNGKey(loop.seed))
    start_step = 0
    if ckpt.latest_step() is not None:
        state, start_step = ckpt.restore(state)

    losses: list[float] = []
    recoveries = 0
    step = start_step
    while step < loop.total_steps:
        try:
            t0 = time.perf_counter()
            if failure_injector is not None:
                failure_injector.check(step)
            batch = {k: jax.numpy.asarray(v) for k, v in data.batch(step).items()}
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.perf_counter() - t0
            watchdog.update(step, dt)
            if on_metrics is not None:
                on_metrics(step, {**{k: float(v) for k, v in metrics.items()}, "sec": dt})
            if loop.log_every and step % loop.log_every == 0:
                print(f"step {step:5d} loss {loss:.4f} ({dt:.2f}s)")
            step += 1
            if step % loop.checkpoint_every == 0 or step == loop.total_steps:
                ckpt.save(step, state)
        except SimulatedFailure as e:
            recoveries += 1
            if recoveries > loop.max_recoveries:
                raise
            print(f"!! {e} — recovering from latest checkpoint")
            # recovery: rebuild fresh state template, restore latest (or
            # restart from scratch if nothing was saved yet)
            state = make_train_state(
                cfg, optimizer, jax.random.PRNGKey(loop.seed)
            )
            if ckpt.latest_step() is not None:
                state, step = ckpt.restore(state)
            else:
                step = 0
    ckpt.wait()
    return {
        "final_step": step,
        "losses": losses,
        "recoveries": recoveries,
        "stragglers": list(watchdog.flagged),
        "first_loss": losses[0] if losses else float("nan"),
        "last_loss": losses[-1] if losses else float("nan"),
    }
