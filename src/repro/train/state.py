"""Train state pytree + construction helpers."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["TrainState", "make_train_state", "abstract_train_state"]


def TrainState(params, opt_state, step) -> dict:
    """Plain-dict train state (pytree-friendly, checkpoint-friendly)."""
    return {"params": params, "opt_state": opt_state, "step": step}


def make_train_state(cfg, optimizer, key) -> dict:
    from repro.models import init_params

    params = init_params(cfg, key)
    return TrainState(params, optimizer.init(params), jnp.zeros((), jnp.int32))


def abstract_train_state(cfg, optimizer) -> Any:
    """ShapeDtypeStruct tree of the train state — used by the dry-run
    (lower against specs; never allocate the 26B configs on CPU)."""
    from repro.models import init_params

    def build():
        params = init_params(cfg, jax.random.PRNGKey(0))
        return TrainState(
            params, optimizer.init(params), jnp.zeros((), jnp.int32)
        )

    return jax.eval_shape(build)
