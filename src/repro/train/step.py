"""Train step builder: loss (chunked CE + z-loss + MoE aux), grad
accumulation, clipping, optional bf16 gradient compression, optimizer.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import forward
from repro.optim import clip_by_global_norm, global_norm
from repro.sharding.rules import maybe_constrain
from repro.train.state import TrainState

__all__ = ["make_loss_fn", "make_train_step", "chunked_cross_entropy"]

CE_CHUNK = 256  # sequence positions per CE chunk (bounds fp32 softmax memory)


def chunked_cross_entropy(
    hidden, head, labels, *, z_loss: float = 1e-4, softcap: float | None = None
):
    """Fused head-projection + CE over hidden states (B, S, D), chunked.

    The (B, S, V) logits tensor NEVER materializes: each scan step projects
    CE_CHUNK positions through the (V, D) head, takes fp32 log-softmax, and
    discards. The chunk body is rematerialized in backward, so dlogits also
    stays O(chunk). Without this, train_4k × 256k-vocab transiently needs
    ~1 TB fp32 globally (measured: 685 GB/device temp in the dry-run).
    """
    b, s, d = hidden.shape
    nchunk = -(-s // CE_CHUNK)
    pad = nchunk * CE_CHUNK - s
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    hc = hidden.reshape(b, nchunk, CE_CHUNK, d).transpose(1, 0, 2, 3)
    yc = labels.reshape(b, nchunk, CE_CHUNK).transpose(1, 0, 2)

    @functools.partial(jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable)
    def chunk_loss(h, yy):
        lg = jnp.einsum("bcd,vd->bcv", h, head.astype(h.dtype))
        lg = maybe_constrain(lg, "batch", None, "vocab")
        if softcap is not None:
            lg = softcap * jnp.tanh(lg / softcap)
        lg = lg.astype(jnp.float32)
        lse = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(
            lg, jnp.maximum(yy, 0)[..., None], axis=-1
        )[..., 0]
        valid = (yy >= 0).astype(jnp.float32)
        nll = (lse - gold) * valid
        return nll.sum(), (jnp.square(lse) * valid).sum(), valid.sum()

    def step(carry, inp):
        tot, zl, cnt = carry
        h, yy = inp
        a, b_, c = chunk_loss(h, yy)
        return (tot + a, zl + b_, cnt + c), None

    (tot, zl, cnt), _ = jax.lax.scan(
        step, (jnp.zeros(()), jnp.zeros(()), jnp.zeros(())), (hc, yc)
    )
    cnt = jnp.maximum(cnt, 1.0)
    return tot / cnt + z_loss * zl / cnt, cnt


def _cast_params_for_compute(params, cfg: ModelConfig):
    """Master-weight mixed precision: cast >=2-D params to the compute dtype
    ONCE per step, while still sharded. All per-layer FSDP all-gathers then
    move bf16 instead of f32 (measured: halves the dominant train
    collectives). The cast's VJP converts the bf16 cotangents back to f32
    for the optimizer, so master weights stay exact."""
    dtype = jnp.dtype(cfg.dtype)

    def cast(x):
        if x.ndim >= 2 and x.dtype == jnp.float32:
            return x.astype(dtype)
        return x

    return jax.tree.map(cast, params)


def make_loss_fn(cfg: ModelConfig, *, z_loss: float = 1e-4, moe_aux_coef: float = 0.01):
    def loss_fn(params, batch):
        params = _cast_params_for_compute(params, cfg)
        extra = {
            k: batch[k]
            for k in ("patch_embeds", "frames")
            if k in batch
        }
        hidden, aux = forward(
            cfg, params, batch["tokens"], return_hidden=True, **extra
        )
        labels = batch["labels"]
        if cfg.vision is not None:
            # patch positions carry no next-token loss
            hidden = hidden[:, cfg.vision.num_patches :, :]
        head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
        loss, tokens = chunked_cross_entropy(
            hidden, head, labels, z_loss=z_loss, softcap=cfg.final_softcap
        )
        metrics = {"ce_loss": loss, "tokens": tokens}
        if "load_balance_loss" in aux:
            loss = loss + moe_aux_coef * aux["load_balance_loss"]
            metrics["load_balance_loss"] = aux["load_balance_loss"]
            metrics["dropped_fraction"] = aux.get("dropped_fraction", 0.0)
        metrics["loss"] = loss
        return loss, metrics

    return loss_fn


def make_train_step(
    cfg: ModelConfig,
    optimizer,
    *,
    clip_norm: float = 1.0,
    accum_steps: int = 1,
    grad_sync: str = "none",  # "none" | "compressed_bf16"
    z_loss: float = 1e-4,
):
    """Build the jit-able ``train_step(state, batch) -> (state, metrics)``.

    ``accum_steps > 1`` scans over microbatches (leading batch split),
    accumulating grads — in bf16 when ``grad_sync == "compressed_bf16"``,
    which halves the cross-pod gradient-reduction traffic (the accumulated
    tensor is what crosses the DP axes).
    """
    loss_fn = make_loss_fn(cfg, z_loss=z_loss)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    acc_dtype = jnp.bfloat16 if grad_sync == "compressed_bf16" else jnp.float32

    def train_step(state, batch):
        params = state["params"]
        if accum_steps == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape(
                    (accum_steps, x.shape[0] // accum_steps) + x.shape[1:]
                ),
                batch,
            )

            def acc_step(carry, mb):
                g_acc, loss_acc = carry
                (loss, metrics), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(acc_dtype), g_acc, g
                )
                return (g_acc, loss_acc + loss), metrics

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dtype), params
            )
            (grads, loss_sum), metrics_stack = jax.lax.scan(
                acc_step, (g0, jnp.zeros(())), micro
            )
            grads = jax.tree.map(
                lambda g: (g / accum_steps).astype(jnp.float32), grads
            )
            loss = loss_sum / accum_steps
            metrics = jax.tree.map(lambda m: m.mean(), metrics_stack)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        new_params, new_opt = optimizer.update(grads, state["opt_state"], params)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["loss"] = loss
        return TrainState(new_params, new_opt, state["step"] + 1), metrics

    return train_step
