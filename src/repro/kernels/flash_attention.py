"""Pallas TPU flash attention (online softmax) for the LM wing.

Tiled attention with the canonical TPU grid layout: ``(batch·q_heads,
q_blocks, kv_blocks)`` with the KV dimension innermost so the running
max / denominator / accumulator live in VMEM scratch across KV steps.

Features needed by the assigned architectures:
  * causal masking                       (all decoder LMs)
  * sliding-window masking               (gemma2 local layers, recurrentgemma)
  * logit soft-capping ``t·tanh(x/t)``   (gemma2)
  * GQA/MQA — KV head = q_head // group, folded into the BlockSpec
    ``index_map`` so KV tensors are never materialized per-q-head.

VMEM budget per grid step: q (BQ·D) + k,v (2·BK·D) + acc (BQ·D) + onehot
masks — with BQ=BK=512, D=256 fp32 that is ~1.5 MiB, comfortably inside
the ~16 MiB/core VMEM of v5e with double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention", "DEFAULT_BLOCK_Q", "DEFAULT_BLOCK_K"]

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _kernel(
    q_ref,  # (1, BQ, D)
    k_ref,  # (1, BK, D)
    v_ref,  # (1, BK, D)
    o_ref,  # (1, BQ, D)
    m_scr,  # (BQ,) running max
    l_scr,  # (BQ,) running denominator
    acc_scr,  # (BQ, D) running numerator
    *,
    scale: float,
    causal: bool,
    window: int | None,
    softcap: float | None,
    block_q: int,
    block_k: int,
    kv_len: int,
):
    qb = pl.program_id(1)
    kb = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale
    k = k_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (BQ, BK)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = qb * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = k_pos < kv_len  # padding guard
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    # Guard fully-masked rows: exp(NEG_INF - NEG_INF) would be 1.
    row_dead = m_cur <= NEG_INF / 2
    alpha = jnp.where(row_dead, 1.0, jnp.exp(m_prev - m_cur))
    p = jnp.exp(s - jnp.where(row_dead, 0.0, m_cur)[:, None])
    p = jnp.where(mask, p, 0.0)
    l_cur = alpha * l_scr[...] + jnp.sum(p, axis=1)
    acc = alpha[:, None] * acc_scr[...] + jnp.dot(
        p, v_ref[0].astype(jnp.float32), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_cur
    l_scr[...] = l_cur
    acc_scr[...] = acc

    @pl.when(kb == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, :] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal",
        "window",
        "softcap",
        "scale",
        "block_q",
        "block_k",
        "interpret",
    ),
)
def flash_attention(
    q: jax.Array,  # (B, Hq, Sq, D)
    k: jax.Array,  # (B, Hkv, Sk, D)
    v: jax.Array,  # (B, Hkv, Sk, D)
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    scale: float | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool | None = None,
) -> jax.Array:
    """Tiled online-softmax attention. Returns (B, Hq, Sq, D).

    GQA: ``Hq`` must be a multiple of ``Hkv``; KV blocks are indexed at
    ``head // group`` inside the BlockSpec index_map (no KV repetition in
    HBM or VMEM).

    ``interpret=None`` auto-selects like every other kernel in this
    package: compiled on TPU, interpret-mode elsewhere (see
    :func:`repro.kernels.dsss_spmv.default_interpret`). ``interpret`` is
    a static jit arg, so the resolution happens at trace time.
    """
    if interpret is None:
        from repro.kernels.dsss_spmv import default_interpret

        interpret = default_interpret()
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0, "q heads must be a multiple of kv heads"
    group = hq // hkv
    if scale is None:
        scale = d**-0.5
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    sq_pad = -(-sq // block_q) * block_q
    sk_pad = -(-sk // block_k) * block_k
    if sq_pad != sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, sq_pad - sq), (0, 0)))
    if sk_pad != sk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, sk_pad - sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, sk_pad - sk), (0, 0)))
    qf = q.reshape(b * hq, sq_pad, d)
    kf = k.reshape(b * hkv, sk_pad, d)
    vf = v.reshape(b * hkv, sk_pad, d)
    grid = (b * hq, sq_pad // block_q, sk_pad // block_k)

    def kv_index(h, qb, kb):
        # GQA indirection: flatten (batch, q_head) -> (batch, kv_head).
        return ((h // hq) * hkv + (h % hq) // group, kb, 0)

    out = pl.pallas_call(
        functools.partial(
            _kernel,
            scale=scale,
            causal=causal,
            window=window,
            softcap=softcap,
            block_q=block_q,
            block_k=block_k,
            kv_len=sk,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda h, qb, kb: (h, qb, 0)),
            pl.BlockSpec((1, block_k, d), kv_index),
            pl.BlockSpec((1, block_k, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda h, qb, kb: (h, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq_pad, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, sq_pad, d)[:, :, :sq, :]
