"""Jit'd public wrappers around the Pallas kernels.

``subshard_update`` is the full DSSS sub-shard update: the Pallas kernel
produces per-edge-block windowed hub partials, and a cheap slot-scatter
(the FromHub fold, O(unique destinations) ≪ O(edges)) turns them into the
destination-interval update. ``attention`` dispatches between the Pallas
flash kernel and the jnp reference by flag (models use this entry point).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.identities import padding_identity_value
from repro.kernels import ref as _ref
from repro.kernels.dsss_spmv import E_BLK, default_interpret, dsss_spmv_block_partials
from repro.kernels.flash_attention import flash_attention

__all__ = [
    "subshard_update",
    "attention",
    "prepare_subshard_operands",
    "prepare_from_subshard",
    "prepare_from_host_block",
    "prepare_from_packed_tile",
    "prepare_packed_tiles",
    "default_interpret",
    "E_BLK",
]


def prepare_subshard_operands(
    src_local: np.ndarray,
    hub_inv_global: np.ndarray,
    weights: np.ndarray | None,
    dtype,
    *,
    gather_op: str,
    reduce: str,
):
    """Host-side staging: pad edge arrays to E_BLK and compute block bases.

    Padded edges carry identity weights so they contribute the ⊕-identity:
    for ``mul``/sum  w=0 → contrib 0; for ``add``/min w=+inf → contrib inf.

    Supported (gather_op, reduce) pairs: ("mul","sum") — PageRank-family;
    ("add","min"/"max") — BFS/SSSP/WCC/label-propagation. "mul" with
    min/max has no finite multiplicative padding identity and no user.
    """
    if gather_op == "mul" and reduce != "sum":
        raise ValueError("gather_op='mul' requires reduce='sum'")
    e = len(src_local)
    e_pad = max(E_BLK, -(-e // E_BLK) * E_BLK)
    pad = e_pad - e
    ident_w = (
        padding_identity_value(reduce, jnp.dtype(dtype))
        if gather_op == "add"
        else 0.0
    )
    src_idx = np.pad(src_local, (0, pad))
    hub_inv = np.pad(
        hub_inv_global, (0, pad), constant_values=hub_inv_global[-1] if e else 0
    )
    # Build the padded weight buffer directly in the kernel dtype — no wide
    # intermediate (a float64 staging copy doubles transient memory on
    # large sub-shards for no precision gain: the values are cast anyway).
    w = np.empty(e_pad, np.dtype(jnp.dtype(dtype)))
    if weights is None:
        w[:e] = 1.0 if gather_op == "mul" else 0.0
    else:
        w[:e] = np.asarray(weights, w.dtype)
    w[e:] = ident_w
    block_base = hub_inv[::E_BLK].astype(np.int32)
    return (
        jnp.asarray(src_idx, jnp.int32),
        jnp.asarray(hub_inv, jnp.int32),
        jnp.asarray(w),
        jnp.asarray(block_base, jnp.int32),
    )


def prepare_from_subshard(ss, dtype, *, gather_op: str, reduce: str):
    """Stage kernel operands straight from a :class:`repro.core.dsss.SubShard`.

    The session hookup: ``GraphSession.kernel_operands(i, j, ...)`` caches
    the result per (sub-shard, semiring), so the TPU kernel path shares the
    stage-once lifecycle of the jnp block primitives.
    """
    return prepare_subshard_operands(
        ss.src_local, ss.hub_inv, ss.weights, dtype,
        gather_op=gather_op, reduce=reduce,
    )


def prepare_from_host_block(blk: dict, dtype, *, gather_op: str, reduce: str):
    """Stage kernel operands from a padded host block (the session's
    'shard file' dict from :meth:`repro.core.dsss.DSSSGraph.host_blocks`).

    The host buffers are bucket-padded for the jnp block primitives; the
    Pallas kernel pads to ``E_BLK`` with its own identity semantics, so we
    hand it the unpadded ``e``-edge prefix views (zero-copy slices).
    """
    e = blk["e"]
    return prepare_subshard_operands(
        blk["src_local"][:e],
        blk["hub_inv"][:e],
        None if blk["weights"] is None else blk["weights"][:e],
        dtype,
        gather_op=gather_op,
        reduce=reduce,
    )


def subshard_update(
    src_vals: jax.Array,  # (isize,)
    src_idx: jax.Array,  # (E_pad,) from prepare_subshard_operands
    hub_inv: jax.Array,
    weights: jax.Array,
    block_base: jax.Array,
    num_slots: int,
    *,
    gather_op: str = "mul",
    reduce: str = "sum",
    interpret: bool | None = None,
) -> jax.Array:
    """Full sub-shard ToHub on the Pallas kernel; returns (num_slots,) hub.

    ``interpret=None`` auto-selects: compiled on TPU, interpreted on every
    other backend (see :func:`repro.kernels.dsss_spmv.default_interpret`).
    """
    if interpret is None:
        interpret = default_interpret()
    return _subshard_update_jit(
        src_vals, src_idx, hub_inv, weights, block_base, num_slots,
        gather_op=gather_op, reduce=reduce, interpret=interpret,
    )


def prepare_from_packed_tile(packed, t: int, dtype, *, gather_op: str, reduce: str):
    """Stage kernel operands from one destination-aligned packed tile.

    A :class:`repro.core.dsss.PackedSweep` tile is a valid kernel edge
    stream by construction: its global hub slots (``base_slot +
    run_local``) are non-decreasing along the tile, so the windowed
    one-hot reduce of ``dsss_spmv`` applies unchanged. Tile source
    indices are *global* padded vertex ids — pass the flat ``(n_pad,)``
    attribute array as ``src_vals`` (the tile does not belong to a single
    source interval once sub-shards coalesce).
    """
    e = int(packed.e_valid[t])
    hub_inv_global = (
        packed.base_slot[t] + packed.run_local[t, :e].astype(np.int64)
    )
    # The windowed one-hot reduce is only sound over a non-decreasing slot
    # stream — true for every adaptive tile and for dst-sorted subshard
    # tiles, but NOT for a src_sorted graph's scrambled blocks.
    if e and np.any(np.diff(hub_inv_global) < 0):
        raise ValueError(
            f"tile {t} has decreasing hub slots (src_sorted layout?) — "
            "not a valid windowed kernel stream"
        )
    w = None if packed.weights is None else packed.weights[t, :e]
    return prepare_subshard_operands(
        packed.src[t, :e], hub_inv_global, w, dtype,
        gather_op=gather_op, reduce=reduce,
    )


def prepare_packed_tiles(packed, *, has_weights: bool) -> dict:
    """Stage the full tile-packed sweep layout as device operand leaves.

    The one upload both compiled backends share: the scan path
    (``core/session.py::_packed_sweep_impl``) carries these leaves through
    ``lax.scan``, and the fused kernel
    (:func:`repro.kernels.packed_sweep.packed_sweep_update`) grids over
    their leading (NT,) tile axis with BlockSpec-pipelined HBM→VMEM DMA.
    Per-tile metadata (``base_slot``/``u``/``row_offset``/intervals) stays
    host-side on the :class:`~repro.core.dsss.PackedSweep` for meter
    accounting and stream planning.
    """
    tiles = {
        "src": jnp.asarray(packed.src),
        "dst": jnp.asarray(packed.dst),
        "run_local": jnp.asarray(packed.run_local),
        "run_dst": jnp.asarray(packed.run_dst),
        "e_valid": jnp.asarray(packed.e_valid),
    }
    if has_weights:
        tiles["weights"] = jnp.asarray(packed.weights)
    return tiles


@functools.partial(
    jax.jit, static_argnames=("num_slots", "gather_op", "reduce", "interpret")
)
def _subshard_update_jit(
    src_vals: jax.Array,
    src_idx: jax.Array,
    hub_inv: jax.Array,
    weights: jax.Array,
    block_base: jax.Array,
    num_slots: int,
    *,
    gather_op: str,
    reduce: str,
    interpret: bool,
) -> jax.Array:
    partials = dsss_spmv_block_partials(
        src_vals,
        src_idx,
        hub_inv,
        weights,
        block_base,
        gather_op=gather_op,
        reduce=reduce,
        interpret=interpret,
    )  # (num_blocks, W)
    nb, w = partials.shape
    # Slot-scatter: partial row b covers slots [base_b, base_b + W); fold all
    # rows into the hub vector. O(num_blocks · W) ≪ O(edges) when d > 1.
    slot_ids = (block_base[:, None] + jnp.arange(w)[None, :]).reshape(-1)
    flat = partials.reshape(-1)
    if reduce == "sum":
        return jax.ops.segment_sum(flat, slot_ids, num_segments=num_slots)
    if reduce == "min":
        return jax.ops.segment_min(flat, slot_ids, num_segments=num_slots)
    return jax.ops.segment_max(flat, slot_ids, num_segments=num_slots)


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    scale: float | None = None,
    use_kernel: bool = False,
    interpret: bool | None = None,
) -> jax.Array:
    """Model-facing attention entry point.

    ``use_kernel=False`` (default on this CPU container) runs the jnp
    reference; ``use_kernel=True`` runs the Pallas flash kernel.
    ``interpret=None`` auto-selects (compiled on TPU, interpreted
    elsewhere — the latter validates the kernel on this container).
    """
    if use_kernel:
        if interpret is None:
            interpret = default_interpret()
        return flash_attention(
            q,
            k,
            v,
            causal=causal,
            window=window,
            softcap=softcap,
            scale=scale,
            interpret=interpret,
        )
    return _ref.attention_ref(
        q, k, v, causal=causal, window=window, softcap=softcap, scale=scale
    )
