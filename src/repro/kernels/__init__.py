"""Pallas kernels — the compiled substrate of the reproduction.

Since the `packed_kernel` execution backend landed, these are no longer a
validation sidecar: on TPU the engine's update sweep *is* a Pallas
kernel (off-TPU everything still runs in interpret mode for parity
testing, with the XLA scan as the fast CPU path).

- packed_sweep.py: the fused gather→combine→windowed-run-reduce→
  hub-scatter sweep over `PackedSweep` tiles — one `pallas_call` per
  update sweep, gridded over (query, tile) with BlockSpec-pipelined
  HBM→VMEM tile DMA; bit-identical to the scan path by exact fold-order
  reproduction. Selected via `execution="packed_kernel"` (or `"auto"`
  on TPU).
- dsss_spmv.py: the single-sub-shard ToHub update as an MXU one-hot
  windowed segment reduction (building block / standalone kernel).
- flash_attention.py: tiled online-softmax attention for the LM wing
  (causal / sliding-window / softcap / GQA-via-index_map).
- ops.py: jit'd wrappers and host-side operand staging; ref.py:
  pure-jnp oracles every kernel is swept against.

Every kernel resolves `interpret=None` through
`dsss_spmv.default_interpret()`: compiled on TPU, interpreted elsewhere.
"""
from repro.kernels.ops import (
    attention,
    prepare_packed_tiles,
    prepare_subshard_operands,
    subshard_update,
)
from repro.kernels.packed_sweep import (
    packed_sweep_update,
    packed_sweep_update_select,
)

__all__ = [
    "attention",
    "prepare_packed_tiles",
    "prepare_subshard_operands",
    "subshard_update",
    "packed_sweep_update",
    "packed_sweep_update_select",
]
