"""Pallas TPU kernels (TPU target; validated in interpret mode on CPU).

- dsss_spmv.py: the paper's DSSS sub-shard update (ToHub) as an MXU
  one-hot segment reduction — the graph engine's hot loop.
- flash_attention.py: tiled online-softmax attention for the LM wing
  (causal / sliding-window / softcap / GQA-via-index_map).
- ops.py: jit'd wrappers; ref.py: pure-jnp oracles.
"""
from repro.kernels.ops import attention, prepare_subshard_operands, subshard_update

__all__ = ["attention", "prepare_subshard_operands", "subshard_update"]
