"""Pallas TPU kernel for the DSSS sub-shard update (ToHub phase).

TPU-native re-expression of the paper's destination-sorted fine-grained
parallelism (§III-D). On CPU, destination sorting removes write conflicts
between threads; on TPU there are no conflicting threads, but the same sort
gives every *edge block* a dense, narrow range of **hub slots** (unique
destinations), so the per-block segment reduction becomes a small dense
``contribution · one_hot`` product that runs on the MXU — a conflict-free,
layout-aligned reduction instead of a serial scatter.

Pipeline per grid step (one edge block of ``E_BLK`` edges):

  HBM ──DMA──▶ VMEM:  src ids, hub slots, weights of the block
  VMEM:               source-interval attributes (resident — the paper's
                      "interval in memory"; SPU keeps it there all iteration)
  gather   contrib[e] = src_vals[src_idx[e]] ⊙ w[e]      (⊙ = mul | add)
  one-hot  oh[e, s]   = (hub_inv[e] − base_b == s)       (iota compare)
  reduce   sum: (1,E)·(E,W) MXU matmul;  min/max: masked VPU reduce
  out      per-block windowed hub partials (num_blocks, W)

The windowed trick is sound *because* edges are destination-sorted: hub
slots are non-decreasing along the edge stream, so a block of ``E_BLK``
edges touches at most ``E_BLK`` consecutive slots (``W = E_BLK``). The
final slot-scatter (FromHub) is O(unique destinations) and lives in
:mod:`repro.kernels.ops`.

Semiring modes:
  gather_op: "mul" (PageRank: rank/deg · w) | "add" (BFS/SSSP: depth + w)
  reduce:    "sum" | "min" | "max"
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.identities import padding_identity

__all__ = [
    "dsss_spmv_block_partials",
    "default_interpret",
    "E_BLK",
    "MINMAX_CHUNK",
]

E_BLK = 512  # edges per block; also the hub-slot window width W

# min/max reduce chunking: the windowed compare materializes
# (MINMAX_CHUNK, W) values at a time instead of (E_BLK, W) — peak VMEM for
# the compare is MINMAX_CHUNK·E_BLK·4 bytes (256 KB at 128×512 fp32) and is
# independent of E_BLK growth along the edge axis. min/max re-association
# is exact, so chunking cannot change results.
MINMAX_CHUNK = 128
assert E_BLK % MINMAX_CHUNK == 0, "chunked min/max reduce needs E_BLK % chunk == 0"


def default_interpret() -> bool:
    """Auto-select Pallas interpret mode: compile on TPU, interpret elsewhere.

    The kernel targets the TPU lowering; on CPU/GPU backends (this
    container, most CI) only the interpreter can execute it. Callers pass
    ``interpret=None`` to defer to this probe; an explicit bool always
    wins (e.g. interpret=True on TPU to debug the kernel itself).
    """
    return jax.default_backend() != "tpu"


def _kernel(
    src_vals_ref,  # (isize,)          resident source-interval attributes
    src_idx_ref,  # (E_BLK,)           edge source offsets within interval
    hub_inv_ref,  # (E_BLK,)           edge -> global hub slot
    w_ref,  # (E_BLK,)                 edge weights (identity-padded)
    base_ref,  # (1,)                  first hub slot of this block
    out_ref,  # (1, W)                 windowed hub partials for this block
    *,
    gather_op: str,
    reduce: str,
):
    contrib_dtype = out_ref.dtype
    vals = jnp.take(src_vals_ref[...], src_idx_ref[...], axis=0)
    w = w_ref[...]
    if gather_op == "mul":
        contrib = (vals * w).astype(contrib_dtype)
    else:
        contrib = (vals + w).astype(contrib_dtype)
    slots = hub_inv_ref[...] - base_ref[0]
    W = out_ref.shape[1]
    if reduce == "sum":
        # One-hot over the slot window. Destination-sorted edges guarantee
        # 0 <= slots < W for all valid edges; identity-padded edges may
        # fall anywhere and contribute the identity.
        # MXU path: (1, E) · (E, W).
        oh = slots[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, W), 1)
        out = jnp.dot(
            contrib[None, :], oh.astype(contrib_dtype), preferred_element_type=jnp.float32
        ).astype(contrib_dtype)
        out_ref[...] = out
    else:
        # Windowed segmented reduce for min/max, in chunks of MINMAX_CHUNK
        # edges: the full masked one-hot would materialize O(E_BLK · W)
        # values per block, which scales quadratically with the edge-block
        # size and blows VMEM on BFS/SSSP tiles; the chunked compare keeps
        # peak live values at O(MINMAX_CHUNK · W) while staying VPU-shaped
        # (min/max re-association is exact, so results are unchanged).
        ident = padding_identity(reduce, contrib_dtype)
        iota_w = jax.lax.broadcasted_iota(jnp.int32, (1, W), 1)
        num_chunks = slots.shape[0] // MINMAX_CHUNK

        def chunk(c, red):
            sl = jax.lax.dynamic_slice_in_dim(slots, c * MINMAX_CHUNK, MINMAX_CHUNK)
            cb = jax.lax.dynamic_slice_in_dim(contrib, c * MINMAX_CHUNK, MINMAX_CHUNK)
            masked = jnp.where(sl[:, None] == iota_w, cb[:, None], ident)
            part = (
                jnp.min(masked, axis=0) if reduce == "min" else jnp.max(masked, axis=0)
            )
            return (
                jnp.minimum(red, part) if reduce == "min" else jnp.maximum(red, part)
            )

        red = jax.lax.fori_loop(
            0, num_chunks, chunk, jnp.full((W,), ident, contrib_dtype)
        )
        out_ref[...] = red[None, :]


def dsss_spmv_block_partials(
    src_vals: jax.Array,  # (isize,) float
    src_idx: jax.Array,  # (E_pad,) int32, E_pad % E_BLK == 0
    hub_inv: jax.Array,  # (E_pad,) int32 global hub slots (non-decreasing)
    weights: jax.Array,  # (E_pad,) same dtype as src_vals, identity-padded
    block_base: jax.Array,  # (num_blocks,) int32 = hub_inv[b*E_BLK]
    *,
    gather_op: str = "mul",
    reduce: str = "sum",
    interpret: bool | None = None,
) -> jax.Array:
    """Run the kernel over all edge blocks; returns (num_blocks, W) partials.

    ``interpret=None`` (default) resolves via :func:`default_interpret` —
    compiled on TPU, interpreted elsewhere.
    """
    if interpret is None:
        interpret = default_interpret()
    return _block_partials_jit(
        src_vals, src_idx, hub_inv, weights, block_base,
        gather_op=gather_op, reduce=reduce, interpret=interpret,
    )


@functools.partial(
    jax.jit, static_argnames=("gather_op", "reduce", "interpret")
)
def _block_partials_jit(
    src_vals, src_idx, hub_inv, weights, block_base,
    *, gather_op: str, reduce: str, interpret: bool,
) -> jax.Array:
    e_pad = src_idx.shape[0]
    assert e_pad % E_BLK == 0, f"pad edges to a multiple of {E_BLK}"
    num_blocks = e_pad // E_BLK
    grid = (num_blocks,)
    return pl.pallas_call(
        functools.partial(_kernel, gather_op=gather_op, reduce=reduce),
        grid=grid,
        in_specs=[
            pl.BlockSpec(src_vals.shape, lambda b: (0,) * src_vals.ndim),
            pl.BlockSpec((E_BLK,), lambda b: (b,)),
            pl.BlockSpec((E_BLK,), lambda b: (b,)),
            pl.BlockSpec((E_BLK,), lambda b: (b,)),
            pl.BlockSpec((1,), lambda b: (b,)),
        ],
        out_specs=pl.BlockSpec((1, E_BLK), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((num_blocks, E_BLK), src_vals.dtype),
        interpret=interpret,
    )(src_vals, src_idx, hub_inv, weights, block_base)
