"""Fused Pallas kernel for the whole packed update sweep (the compiled path).

This is the tile-native big brother of ``dsss_spmv.py``: instead of one
windowed ToHub per sub-shard plus an XLA slot-scatter outside, one
``pallas_call`` consumes the :class:`repro.core.dsss.PackedSweep` layout
end to end —

  grid = (K, NT)                 query-major, tiles innermost
  HBM ──BlockSpec DMA──▶ VMEM:   per-tile src / dst / run_local / run_dst /
                                 e_valid / weights blocks (Pallas pipelines
                                 grid-mapped inputs, so tile t+1's DMA is in
                                 flight while tile t computes — the
                                 double-buffered streaming the DSSS layout
                                 was designed for)
  VMEM resident per query:       flat (n_pad,) attributes, aux leaves, the
                                 per-vertex activity mask, and the running
                                 ⊕-accumulator (an output block revisited
                                 across all NT tile steps, flushed once)
  per tile:  gather → combine (``program.gather``, traced into the kernel)
             → windowed run-reduce over the ``run_local`` hub-slot window
             → FromHub scatter of run partials into the accumulator at
               ``run_dst``

Bit-identity contract (the acceptance gate of the ``packed_kernel``
execution backend): results must equal ``_packed_sweep_impl``'s
(``core/session.py``) *bitwise*, which pins down the floating-point fold
order exactly:

* the per-run partial must be the **ascending-edge-order** left fold —
  what XLA's in-order scatter-add gives ``jax.ops.segment_sum``. A one-hot
  MXU matmul (the ``dsss_spmv`` sum path) re-associates the adds, so the
  sum path here is a sequential ``fori_loop`` over the tile's edges, each
  step a vectorized (T,) select-accumulate. min/max re-association is
  exact, so those reduce with the chunked masked compare (VPU-shaped, same
  idiom as ``dsss_spmv``), initialized with the *segment-op* fill value
  (:func:`repro.core.identities.segment_fill_value` — bitwise what empty
  segments hold in the reference).
* the FromHub fold must apply run partials in **ascending run order**
  (ascending source-interval order — the schedules' fold order). Grid
  steps are sequential and the scatter loop walks slots 0..T-1, so the
  order is exact by construction; padded run slots (``run_dst == n_pad``)
  leave the accumulator bit-untouched via a read-select-write (an
  unconditional ``acc + 0.0`` would flip ``-0.0`` to ``+0.0``).

Masking mirrors the scan path: edges past ``e_valid`` and edges whose
source vertex is inactive this sweep contribute exact ⊕-identities.

VMEM budget: per query the kernel keeps ``attrs + acc + activity + aux``
resident — (3 + #aux)·n_pad·4 bytes. That is the paper's own fused-tier
assumption (intervals sized to fit fast memory); graphs whose attribute
state outgrows VMEM belong to the scan path, which ``execution="auto"``
keeps selecting off-TPU.

``interpret=None`` resolves via :func:`repro.kernels.dsss_spmv.
default_interpret` — compiled on TPU, interpreted elsewhere (where the
parity suite runs it).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.identities import reduce_identity, segment_fill_value
from repro.kernels.dsss_spmv import MINMAX_CHUNK, default_interpret

__all__ = [
    "packed_sweep_update",
    "packed_sweep_update_select",
]

# Tile leaves in kernel operand order (weights appended when present).
_TILE_LEAVES = ("src", "dst", "run_local", "run_dst", "e_valid")


def _combine(reduce: str, a, b):
    if reduce == "sum":
        return a + b
    if reduce == "min":
        return jnp.minimum(a, b)
    return jnp.maximum(a, b)


def _kernel(
    attrs_ref,  # (1, n_pad)  query's previous attributes (resident)
    acc_in_ref,  # (1, n_pad) incoming ⊕-accumulator (streaming carry)
    act_ref,  # (1, n_pad)   int32 per-vertex activity mask (resident)
    *refs,  # aux refs, tile refs, out_ref — split by static aux_spec
    program,
    aux_spec: tuple,  # ((name, kind), ...) kind ∈ {"vertex", "scalar"}
    has_weights: bool,
    n_pad: int,
    T: int,
):
    out_ref = refs[-1]  # (1, n_pad) accumulator, revisited across tiles
    aux_refs = refs[: len(aux_spec)]
    tile_refs = refs[len(aux_spec) : -1]
    src_ref, dst_ref, run_ref, rdst_ref, ev_ref = tile_refs[:5]
    w_ref = tile_refs[5] if has_weights else None

    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():  # first tile of this query: load the carried accumulator
        out_ref[...] = acc_in_ref[...]

    attrs = attrs_ref[0]
    src = src_ref[0]
    dst = dst_ref[0]
    run = run_ref[0]
    rdst = rdst_ref[0]

    # -- gather + combine (the program's per-edge semiring term) ------------
    vals = jnp.take(attrs, src)
    s_aux: dict = {}
    d_aux: dict | None = {} if program.needs_dst_aux else None
    for (name, kind), ref in zip(aux_spec, aux_refs):
        if kind == "vertex":
            arr = ref[0]
            s_aux[name] = jnp.take(arr, src)
            if d_aux is not None:
                d_aux[name] = jnp.take(arr, dst)
        else:
            s_aux[name] = ref[0, 0]
            if d_aux is not None:
                d_aux[name] = ref[0, 0]
    w = w_ref[0] if has_weights else None
    contrib = program.gather(vals, w, s_aux, d_aux)
    ident = reduce_identity(program.reduce, contrib.dtype)
    iota_t = jax.lax.broadcasted_iota(jnp.int32, (T,), 0)
    mask = (iota_t < ev_ref[0]) & (jnp.take(act_ref[0], src) > 0)
    contrib = jnp.where(mask, contrib, ident)

    # -- windowed run-reduce over the hub-slot window -----------------------
    fill = segment_fill_value(program.reduce, contrib.dtype)
    if program.reduce == "sum":
        # Ascending-edge-order left fold: bitwise the reference
        # segment_sum (XLA applies scatter-add updates in order). Each
        # step is one vectorized (T,) select-accumulate on the VPU.
        def edge(e, win):
            c = jax.lax.dynamic_index_in_dim(contrib, e, keepdims=False)
            s = jax.lax.dynamic_index_in_dim(run, e, keepdims=False)
            return jnp.where(iota_t == s, win + c, win)

        win = jax.lax.fori_loop(
            0, T, edge, jnp.full((T,), fill, contrib.dtype)
        )
    else:
        # min/max re-association is exact — chunked masked compare
        # (the dsss_spmv VPU idiom). dynamic_slice clamps the last chunk
        # start, so a non-divisible T re-reads a few edges; min/max is
        # idempotent over duplicates, results unchanged.
        chunk = min(MINMAX_CHUNK, T)
        num_chunks = -(-T // chunk)
        iota_w = jax.lax.broadcasted_iota(jnp.int32, (1, T), 1)

        def chunk_body(c, red):
            sl = jax.lax.dynamic_slice_in_dim(run, c * chunk, chunk)
            cb = jax.lax.dynamic_slice_in_dim(contrib, c * chunk, chunk)
            masked = jnp.where(sl[:, None] == iota_w, cb[:, None], fill)
            part = (
                jnp.min(masked, axis=0)
                if program.reduce == "min"
                else jnp.max(masked, axis=0)
            )
            return _combine(program.reduce, red, part)

        win = jax.lax.fori_loop(
            0, num_chunks, chunk_body, jnp.full((T,), fill, contrib.dtype)
        )

    # -- FromHub: fold run partials into the accumulator at run_dst ---------
    # Sequential over slots 0..T-1 == ascending run order == the
    # schedules' ascending-source-interval fold order (bit-identity with
    # acc.at[run_dst].add/min/max, which serializes duplicates in order).
    acc_dtype = out_ref.dtype

    def run_fold(r, carry):
        idx = jax.lax.dynamic_index_in_dim(rdst, r, keepdims=False)
        valid = idx < n_pad  # padded slots carry the n_pad sentinel
        i = jnp.minimum(idx, n_pad - 1)
        v = jax.lax.dynamic_index_in_dim(win, r, keepdims=False)
        cur = pl.load(out_ref, (pl.ds(0, 1), pl.ds(i, 1)))
        upd = _combine(program.reduce, cur, v.astype(acc_dtype))
        pl.store(
            out_ref, (pl.ds(0, 1), pl.ds(i, 1)), jnp.where(valid, upd, cur)
        )
        return carry

    jax.lax.fori_loop(0, T, run_fold, 0)


def _normalize_aux(aux: dict, aux_batched: bool, K: int):
    """Flatten the aux dict to uniformly-2D operands + a static spec.

    Mirrors the scan path's per-query view (``v[src] if v.ndim == 1 else
    v``): after stripping the optional leading (K,) batch axis, 1-D
    leaves are per-vertex (gathered by endpoint), 0-D leaves are scalars.
    Each operand becomes (Ka, L) with Ka ∈ {1, K}; the BlockSpec index
    map broadcasts shared leaves across the query grid axis.
    """
    spec = []
    operands = []
    for name in sorted(aux):
        v = jnp.asarray(aux[name])
        per_query_ndim = v.ndim - (1 if aux_batched else 0)
        if per_query_ndim == 1:
            kind = "vertex"
            op = v if aux_batched else v[None, :]
        elif per_query_ndim == 0:
            kind = "scalar"
            op = v[:, None] if aux_batched else v[None, None]
        else:
            raise ValueError(
                f"aux leaf {name!r} has unsupported per-query rank "
                f"{per_query_ndim} for the packed kernel"
            )
        spec.append((name, kind))
        operands.append(op)
    return tuple(spec), operands


def packed_sweep_update(
    program,
    attrs_flat: jax.Array,  # (K, n_pad) previous attributes (read-only)
    acc_flat: jax.Array,  # (K, n_pad) running ⊕ accumulators (carry)
    aux: dict,  # run-constant aux; (K,)-leading leaves when aux_batched
    tiles: dict,  # PackedSweep device leaves, (NT, ...) leading axis
    row_active: jax.Array,  # (P,) bool — the sweep's active source intervals
    has_weights: bool,
    aux_batched: bool = False,
    interpret: bool | None = None,
) -> jax.Array:
    """One fused-kernel gather-reduce pass; drop-in for ``_packed_sweep_impl``.

    Call signature (minus ``interpret``) matches the scan implementation,
    so the session's streaming/selective machinery drives either
    executable unchanged: under host/disk residency ``tiles`` is one
    streamed chunk and ``acc_flat`` the carry between chunks.
    """
    if interpret is None:
        interpret = default_interpret()
    K, n_pad = attrs_flat.shape
    NT, T = tiles["src"].shape
    vert_active = jnp.repeat(
        row_active, n_pad // row_active.shape[0], total_repeat_length=n_pad
    ).astype(jnp.int32)[None, :]
    aux_spec, aux_ops = _normalize_aux(aux, aux_batched, K)

    def _bcast(op):  # (Ka, L): shared leaves pin block 0 on the query axis
        ka = op.shape[0]
        return pl.BlockSpec(
            (1, op.shape[1]),
            (lambda k, t: (k, 0)) if ka == K else (lambda k, t: (0, 0)),
        )

    in_specs = [
        pl.BlockSpec((1, n_pad), lambda k, t: (k, 0)),  # attrs
        pl.BlockSpec((1, n_pad), lambda k, t: (k, 0)),  # acc in
        pl.BlockSpec((1, n_pad), lambda k, t: (0, 0)),  # activity
        *[_bcast(op) for op in aux_ops],
        pl.BlockSpec((1, T), lambda k, t: (t, 0)),  # src
        pl.BlockSpec((1, T), lambda k, t: (t, 0)),  # dst
        pl.BlockSpec((1, T), lambda k, t: (t, 0)),  # run_local
        pl.BlockSpec((1, T), lambda k, t: (t, 0)),  # run_dst
        pl.BlockSpec((1,), lambda k, t: (t,)),  # e_valid
    ]
    operands = [
        attrs_flat,
        acc_flat,
        vert_active,
        *aux_ops,
        tiles["src"],
        tiles["dst"],
        tiles["run_local"],
        tiles["run_dst"],
        tiles["e_valid"],
    ]
    if has_weights:
        in_specs.append(pl.BlockSpec((1, T), lambda k, t: (t, 0)))
        operands.append(tiles["weights"])
    return pl.pallas_call(
        functools.partial(
            _kernel,
            program=program,
            aux_spec=aux_spec,
            has_weights=has_weights,
            n_pad=n_pad,
            T=T,
        ),
        grid=(K, NT),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, n_pad), lambda k, t: (k, 0)),
        out_shape=jax.ShapeDtypeStruct((K, n_pad), acc_flat.dtype),
        interpret=interpret,
    )(*operands)


def packed_sweep_update_select(
    program,
    attrs_flat: jax.Array,  # (K, n_pad)
    acc_flat: jax.Array,  # (K, n_pad)
    aux: dict,
    tiles: dict,  # (NT, ...) staged tile leaves
    idx: jax.Array,  # (bucket,) int32 active tile indices, 0-padded
    a_valid: jax.Array,  # scalar int32: real entries in idx
    row_active: jax.Array,  # (P,) bool
    has_weights: bool,
    aux_batched: bool = False,
    interpret: bool | None = None,
) -> jax.Array:
    """Selective-execution frontend: compact active tiles, then the kernel.

    Same contract as ``_packed_sweep_select_impl``: ``idx`` is ascending
    (fold order preserved), padding entries are neutralized by zeroing
    their ``e_valid`` so every edge masks to an exact ⊕-identity. The
    gather runs as plain XLA ops in front of the ``pallas_call``; the
    kernel grid then walks only the compacted bucket.
    """
    sel = {k: v[idx] for k, v in tiles.items()}
    keep = jnp.arange(idx.shape[0]) < a_valid
    sel["e_valid"] = jnp.where(keep, sel["e_valid"], 0)
    return packed_sweep_update(
        program, attrs_flat, acc_flat, aux, sel, row_active, has_weights,
        aux_batched, interpret,
    )
