"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These are the ground truth the kernels are swept against in
tests/test_kernels_*.py and tests/test_packed_kernel_property.py (shape ×
dtype × feature sweeps). The kernels themselves resolve ``interpret``
via :func:`repro.kernels.dsss_spmv.default_interpret` — compiled on TPU,
interpret-mode on every other backend, which is how the sweeps execute
them on CPU CI.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["subshard_update_ref", "attention_ref", "packed_sweep_update_ref"]


def subshard_update_ref(
    src_vals: jax.Array,  # (isize,)
    src_idx: jax.Array,  # (e,) int32
    hub_inv: jax.Array,  # (e,) int32 global hub slots
    weights: jax.Array,  # (e,)
    num_slots: int,
    *,
    gather_op: str = "mul",
    reduce: str = "sum",
) -> jax.Array:
    """Reference ToHub: gather + combine + segment-reduce by hub slot."""
    vals = src_vals[src_idx]
    contrib = vals * weights if gather_op == "mul" else vals + weights
    if reduce == "sum":
        return jax.ops.segment_sum(contrib, hub_inv, num_segments=num_slots)
    if reduce == "min":
        return jax.ops.segment_min(contrib, hub_inv, num_segments=num_slots)
    return jax.ops.segment_max(contrib, hub_inv, num_segments=num_slots)


def packed_sweep_update_ref(
    program,
    attrs_flat: jax.Array,  # (K, n_pad)
    acc_flat: jax.Array,  # (K, n_pad)
    aux: dict,
    tiles: dict,  # (NT, ...) PackedSweep tile leaves
    row_active: jax.Array,  # (P,) bool
    has_weights: bool,
    aux_batched: bool = False,
) -> jax.Array:
    """Reference fused sweep: per-tile gather → combine → segment-reduce
    by ``run_local`` → scatter-fold at ``run_dst``.

    Plain Python loops over tiles and queries with ``jax.ops.segment_*``
    and in-order ``.at[]`` scatters — the exact fold-order semantics
    :func:`repro.kernels.packed_sweep.packed_sweep_update` must reproduce
    *bitwise* (XLA applies duplicate scatter updates in ascending
    position order, pinning the float-sum association).
    """
    from repro.core.identities import reduce_identity

    K, n_pad = attrs_flat.shape
    NT, T = tiles["src"].shape
    P = row_active.shape[0]
    vert_active = jnp.repeat(
        row_active, n_pad // P, total_repeat_length=n_pad
    )
    acc = acc_flat
    for t in range(NT):
        src = tiles["src"][t]
        dst = tiles["dst"][t]
        run = tiles["run_local"][t]
        run_dst = tiles["run_dst"][t]
        w = tiles["weights"][t] if has_weights else None
        mask = (jnp.arange(T) < tiles["e_valid"][t]) & vert_active[src]
        rows = []
        for q in range(K):
            auxq = {
                k: (v[q] if aux_batched else v) for k, v in aux.items()
            }
            s_aux = {
                k: (v[src] if getattr(v, "ndim", 0) == 1 else v)
                for k, v in auxq.items()
            }
            d_aux = (
                {
                    k: (v[dst] if getattr(v, "ndim", 0) == 1 else v)
                    for k, v in auxq.items()
                }
                if program.needs_dst_aux
                else None
            )
            contrib = program.gather(attrs_flat[q][src], w, s_aux, d_aux)
            ident = reduce_identity(program.reduce, contrib.dtype)
            contrib = jnp.where(mask, contrib, ident)
            aq = acc[q]
            if program.reduce == "sum":
                red = jax.ops.segment_sum(contrib, run, num_segments=T)
                aq = aq.at[run_dst].add(red.astype(aq.dtype), mode="drop")
            elif program.reduce == "min":
                red = jax.ops.segment_min(contrib, run, num_segments=T)
                aq = aq.at[run_dst].min(red.astype(aq.dtype), mode="drop")
            else:
                red = jax.ops.segment_max(contrib, run, num_segments=T)
                aq = aq.at[run_dst].max(red.astype(aq.dtype), mode="drop")
            rows.append(aq)
        acc = jnp.stack(rows)
    return acc


def attention_ref(
    q: jax.Array,  # (B, Hq, Sq, D)
    k: jax.Array,  # (B, Hkv, Sk, D)
    v: jax.Array,  # (B, Hkv, Sk, D)
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    scale: float | None = None,
) -> jax.Array:
    """Naive fp32 softmax attention with the same masking semantics."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    group = hq // hkv
    if scale is None:
        scale = d**-0.5
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32) * scale, k.astype(jnp.float32)
    )
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
