"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These are the ground truth the kernels are swept against in
tests/test_kernels_*.py (shape × dtype × feature sweeps, interpret=True).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["subshard_update_ref", "attention_ref"]


def subshard_update_ref(
    src_vals: jax.Array,  # (isize,)
    src_idx: jax.Array,  # (e,) int32
    hub_inv: jax.Array,  # (e,) int32 global hub slots
    weights: jax.Array,  # (e,)
    num_slots: int,
    *,
    gather_op: str = "mul",
    reduce: str = "sum",
) -> jax.Array:
    """Reference ToHub: gather + combine + segment-reduce by hub slot."""
    vals = src_vals[src_idx]
    contrib = vals * weights if gather_op == "mul" else vals + weights
    if reduce == "sum":
        return jax.ops.segment_sum(contrib, hub_inv, num_segments=num_slots)
    if reduce == "min":
        return jax.ops.segment_min(contrib, hub_inv, num_segments=num_slots)
    return jax.ops.segment_max(contrib, hub_inv, num_segments=num_slots)


def attention_ref(
    q: jax.Array,  # (B, Hq, Sq, D)
    k: jax.Array,  # (B, Hkv, Sk, D)
    v: jax.Array,  # (B, Hkv, Sk, D)
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    scale: float | None = None,
) -> jax.Array:
    """Naive fp32 softmax attention with the same masking semantics."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    group = hq // hkv
    if scale is None:
        scale = d**-0.5
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32) * scale, k.astype(jnp.float32)
    )
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
