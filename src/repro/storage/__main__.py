"""CLI for the on-disk DSSS store.

    python -m repro.storage build edges.txt graph.dsss --P 16
    python -m repro.storage info graph.dsss
    python -m repro.storage verify graph.dsss
    python -m repro.storage verify graph.dsss --repair --source edges.txt

``build`` streams a SNAP-style text edge list (``src dst [weight]`` per
line, ``#`` comments) through the bounded-RAM external-memory pipeline;
``info`` prints the header and segment directory; ``verify`` recomputes
every segment checksum and exits non-zero on mismatch or truncation.
``verify --repair`` instead scans all segments, reports every damaged
one, and — given ``--source`` — rebuilds the container from the raw edge
list and atomically swaps the verified replacement in
(:func:`repro.reliability.repair.repair_dsss`).
"""
from __future__ import annotations

import argparse
import sys

from repro.storage.build import build_from_text
from repro.storage.format import FormatError, store_info, verify_dsss


def _cmd_build(args) -> int:
    stats = build_from_text(
        args.input,
        args.output,
        args.P,
        weights=args.weights,
        comment=args.comment,
        chunk_budget=args.chunk_budget,
        drop_self_loops=args.drop_self_loops,
        dedup=not args.keep_duplicates,
        packing=None if args.no_packed else "adaptive",
    )
    print(
        f"built {stats.path}: n={stats.n} m={stats.m} (raw {stats.m_raw}) "
        f"P={stats.P} blocks={stats.num_blocks} tiles={stats.num_tiles}"
        f"x{stats.tile_edges}"
    )
    print(
        f"bounded build: peak resident edge bytes {stats.peak_edge_bytes} "
        f"(budget {stats.chunk_budget}, {stats.num_chunks} chunks, "
        f"{stats.streamed_buckets} k-way-merged buckets, "
        f"spill {stats.spill_bytes} bytes)"
    )
    return 0


def _cmd_info(args) -> int:
    try:
        info = store_info(args.path)
    except (FormatError, OSError) as e:
        print(f"FAIL: {e}", file=sys.stderr)
        return 1
    meta = info["meta"]
    print(f"{args.path}: .dsss v{meta['version']}")
    print(
        f"  n={meta['n']} m={meta['m']} P={meta['P']} "
        f"interval_size={meta['interval_size']} "
        f"weighted={meta['weighted']} src_sorted={meta['src_sorted']} "
        f"blocks={meta.get('num_blocks')}"
    )
    if meta.get("packing"):
        print(
            f"  packed: {meta['packing']} tiles={meta.get('num_tiles')} "
            f"x{meta.get('tile_edges')} edges"
        )
    print(
        f"  file {info['file_bytes']} bytes, "
        f"{len(info['segments'])} segments ({info['segment_bytes']} bytes)"
    )
    for seg in info["segments"]:
        shape = "x".join(str(s) for s in seg["shape"])
        print(f"    {seg['name']:<16} {seg['dtype']:<8} ({shape})  {seg['nbytes']}B")
    return 0


def _cmd_verify(args) -> int:
    if args.repair:
        return _cmd_repair(args)
    try:
        store = verify_dsss(args.path)
    except (FormatError, OSError) as e:
        print(f"FAIL: {e}", file=sys.stderr)
        return 1
    print(
        f"OK: {args.path} ({len(store.segments)} segments, "
        f"n={store.meta['n']} m={store.meta['m']})"
    )
    return 0


def _cmd_repair(args) -> int:
    from repro.reliability.repair import repair_dsss

    try:
        report = repair_dsss(
            args.path,
            args.source,
            chunk_budget=args.chunk_budget,
        )
    except (FormatError, OSError, ValueError) as e:
        print(f"FAIL: {e}", file=sys.stderr)
        return 1
    if not report["damaged"]:
        print(f"OK: {args.path} (all segments clean, nothing to repair)")
        return 0
    print(
        f"repaired {args.path}: damaged segments "
        f"{', '.join(report['damaged'])} rebuilt from {report['source']}"
    )
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.storage")
    sub = ap.add_subparsers(dest="cmd", required=True)

    b = sub.add_parser("build", help="text edge list -> .dsss (bounded RAM)")
    b.add_argument("input")
    b.add_argument("output")
    b.add_argument("--P", type=int, default=16, help="number of intervals")
    b.add_argument("--weights", action="store_true", help="read a third column")
    b.add_argument("--comment", default="#")
    b.add_argument(
        "--chunk-budget", type=int, default=64 << 20,
        help="target resident edge-array bytes during the build",
    )
    b.add_argument("--drop-self-loops", action="store_true")
    b.add_argument("--keep-duplicates", action="store_true")
    b.add_argument(
        "--no-packed", action="store_true",
        help="skip the PackedSweep tile section",
    )
    b.set_defaults(fn=_cmd_build)

    i = sub.add_parser("info", help="print header + segment directory")
    i.add_argument("path")
    i.set_defaults(fn=_cmd_info)

    v = sub.add_parser("verify", help="recompute all segment checksums")
    v.add_argument("path")
    v.add_argument(
        "--repair", action="store_true",
        help="scan all segments and rebuild the container from --source "
        "if any are damaged (atomic swap after the rebuild verifies)",
    )
    v.add_argument(
        "--source", default=None,
        help="raw text edge list to rebuild damaged containers from",
    )
    v.add_argument(
        "--chunk-budget", type=int, default=64 << 20,
        help="rebuild chunk budget (see `build`)",
    )
    v.set_defaults(fn=_cmd_verify)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
