"""External-memory DSSS build: raw edge stream → ``.dsss`` in bounded RAM.

The in-memory pipeline (``degree_and_densify`` → ``build_dsss`` →
``write_dsss``) holds the whole edge set several times over; this module
produces the *identical* container layout while keeping resident
edge-array bytes bounded by the configured ``chunk_budget`` (GraphMP's
semi-external-memory discipline: vertex-scale state — degrees, the
dense-id mapping, the P² directory — stays in RAM; edge-scale state never
does). The classic partition-and-merge shape:

1. **id pass** — stream the input once, accumulating the sorted unique
   endpoint set (the dense-id mapping of the degreer) chunk by chunk.
2. **partition pass** — stream again: map each chunk to dense ids, bucket
   by ``(source interval, destination interval)``, sort each chunk's
   bucket slice by ``(dst, src)`` and append it to a single spill file
   (one file + an in-RAM run registry, not the paper's P² files — which
   hit OS handle limits, §IV-D). Each bucket is now a sequence of sorted
   runs.
3. **merge pass** — visit buckets in the schedules' row-major streaming
   order. A bucket that fits the budget is loaded and sorted whole;
   larger buckets are k-way merged from their runs with bounded read
   buffers (``heapq.merge`` is stable, so duplicate edges keep input
   order and dedup keeps the first occurrence — exactly
   ``degree_and_densify``'s semantics). The merged stream is deduplicated
   and emitted *streamingly* into spool files for every store segment:
   flat edges, hub arrays, and the bucket-padded per-block arrays. Run
   lengths feed per-candidate greedy tile counters, so the adaptive tile
   size is chosen exactly as :func:`repro.core.dsss.choose_tile_edges`
   would choose it — without ever materializing the run-length array.
4. **packed pass** — re-stream the flat spools with the chosen tile size,
   cutting tiles at destination-run boundaries (the identical greedy
   rule) and spooling the :class:`~repro.core.dsss.PackedSweep` arrays.
5. **assembly** — stream every spool into a :class:`~repro.storage.
   format.StoreWriter` with bounded copy buffers.

Every edge-scale allocation is charged to an internal ledger;
``BuildStats.peak_edge_bytes`` is the proof the bounded-memory contract
tests assert against.
"""
from __future__ import annotations

import dataclasses
import heapq
import os
import shutil
import tempfile
from typing import Callable, Iterable, Iterator

import numpy as np

from repro.core.dsss import next_bucket, tile_candidates
from repro.graph.preprocess import map_to_dense, merge_unique_ids
from repro.storage.format import FORMAT_VERSION, StoreWriter

__all__ = ["BuildStats", "build_dsss_file", "build_from_text"]

# Candidate tile sizes tracked by the streaming chooser: 2^3 .. 2^42
# (an edge count past 2^42 would overflow the greedy counters' premise).
_TILE_LOG2_LO, _TILE_LOG2_HI = 3, 42


@dataclasses.dataclass
class BuildStats:
    """What the build did — including the bounded-memory proof.

    ``peak_edge_bytes`` is the high-water mark of *resident edge-array
    bytes* (chunk buffers, bucket loads, merge/read buffers, tile
    buffers, assembly copy windows) charged by the builder's allocation
    ledger. Vertex-scale state (degrees, the id mapping, the P²
    directory) is excluded by design — it is O(n), the semi-external
    assumption. The bounded-build contract is
    ``peak_edge_bytes <= ~2 * chunk_budget``.
    """

    path: str
    n: int
    m: int
    m_raw: int
    P: int
    interval_size: int
    num_blocks: int
    chunk_budget: int
    chunk_edges: int
    num_chunks: int
    streamed_buckets: int
    spill_bytes: int
    peak_edge_bytes: int
    tile_edges: int
    num_tiles: int


class _Ledger:
    """Tracks live edge-array bytes by tag; ``peak`` is the contract."""

    def __init__(self):
        self._live: dict[str, int] = {}
        self.peak = 0

    def track(self, tag: str, *arrays) -> None:
        self._live[tag] = sum(int(a.nbytes) for a in arrays if a is not None)
        self._bump()

    def add(self, tag: str, nbytes: int) -> None:
        self._live[tag] = self._live.get(tag, 0) + int(nbytes)
        self._bump()

    def drop(self, tag: str) -> None:
        self._live.pop(tag, None)

    def _bump(self) -> None:
        total = sum(self._live.values())
        if total > self.peak:
            self.peak = total


class _Spool:
    """An append-only raw temp file holding one future store segment."""

    def __init__(self, path: str, dtype):
        self.path = path
        self.dtype = np.dtype(dtype)
        self._f = open(path, "wb")
        self.items = 0

    def append(self, arr) -> None:
        arr = np.ascontiguousarray(arr, dtype=self.dtype)
        arr.tofile(self._f)
        self.items += arr.size

    def append_zeros(self, count: int) -> None:
        if count > 0:
            self.append(np.zeros(count, self.dtype))

    def close(self) -> None:
        self._f.close()


class _TileChooser:
    """Streaming replica of :func:`repro.core.dsss.choose_tile_edges`.

    Maintains, for every power-of-two candidate tile size, the greedy
    destination-run-aligned cut's tile count, fed one closed run at a
    time. The greedy rule is the exact stream form of
    ``cut_runs_into_tiles``: a run joins the current tile iff its end
    stays within ``tile_start + T``, else it opens a new tile (a run
    longer than T force-opens a tile alone — never hit by the chosen
    candidates, whose floor is ``bucket(max_run)``).
    """

    def __init__(self):
        self.T = np.array(
            [1 << k for k in range(_TILE_LOG2_LO, _TILE_LOG2_HI + 1)], np.int64
        )
        self.tiles = np.zeros(len(self.T), np.int64)
        self.tile_start = np.zeros(len(self.T), np.int64)
        self.opened = False
        self.max_run = 0

    def close_run(self, start: int, end: int) -> None:
        if end - start > self.max_run:
            self.max_run = end - start
        if not self.opened:
            self.tiles[:] = 1
            self.tile_start[:] = start
            self.opened = True
            return
        over = end > self.tile_start + self.T
        self.tiles[over] += 1
        self.tile_start[over] = start

    def choose(self, m: int) -> int:
        if m >= 1 << _TILE_LOG2_HI:
            raise ValueError("edge count exceeds the tile chooser's range")
        best_T, best_slots = None, None
        for T in tile_candidates(m, self.max_run):
            idx = int(T).bit_length() - 1 - _TILE_LOG2_LO
            slots = int(self.tiles[idx]) * T
            if best_slots is None or slots < best_slots:
                best_T, best_slots = T, slots
        return best_T


def _normalize_chunk(chunk) -> tuple[np.ndarray, np.ndarray, np.ndarray | None]:
    if len(chunk) == 2:
        src, dst = chunk
        w = None
    else:
        src, dst, w = chunk
    src = np.asarray(src, dtype=np.int64).reshape(-1)
    dst = np.asarray(dst, dtype=np.int64).reshape(-1)
    if src.shape != dst.shape:
        raise ValueError("chunk src/dst length mismatch")
    if w is not None:
        w = np.asarray(w, dtype=np.float32).reshape(-1)
        if w.shape != src.shape:
            raise ValueError("chunk weights length mismatch")
    return src, dst, w


class _ExternalBuilder:
    def __init__(
        self,
        chunks: Callable[[], Iterable],
        out_path: str,
        P: int,
        *,
        chunk_budget: int,
        drop_self_loops: bool,
        dedup: bool,
        workdir: str | None,
        packing: str | None,
    ):
        if P < 1:
            raise ValueError("P must be >= 1")
        if packing not in ("adaptive", None):
            raise ValueError(
                "the external builder emits destination-sorted DSSS; packing "
                f"must be 'adaptive' or None, got {packing!r}"
            )
        self.chunks = chunks
        self.out_path = out_path
        self.P = P
        self.chunk_budget = int(chunk_budget)
        self.drop_self_loops = drop_self_loops
        self.dedup = dedup
        self.packing = packing
        self.workdir = workdir
        # ~64 bytes/edge of transient state per partition sub-chunk (raw
        # int64 pair + dense ids + block keys + lexsort order + records).
        self.chunk_edges = max(1024, self.chunk_budget // 64)
        self.load_bytes = max(64, self.chunk_budget // 4)
        self.io_chunk = max(4096, min(1 << 22, self.chunk_budget // 4))
        self.ledger = _Ledger()
        self.stats_chunks = 0
        self.streamed_buckets = 0
        self.m_raw = 0

    # -- pass 1: the dense-id mapping ---------------------------------------
    def pass_ids(self) -> None:
        # Per-chunk uniques are folded into the accumulator only when the
        # pending pile grows past a few chunks' worth — folding re-sorts
        # the whole O(n) accumulator, so doing it every sub-chunk would
        # make this pass O(num_chunks · n log n) on big graphs. The
        # pending bound keeps peak memory at O(n + a few chunks).
        uniq = np.zeros(0, np.int64)
        pending: list[np.ndarray] = []
        pending_items = 0
        fold_at = 4 * self.chunk_edges
        m_raw = 0
        self.weighted = False
        first = True
        for chunk in self.chunks():
            src, dst, w = _normalize_chunk(chunk)
            if first:
                # The weights column fixes the spill record dtype; noting
                # it here keeps chunks() at exactly two invocations.
                self.weighted = w is not None
                first = False
            for lo in range(0, len(src), self.chunk_edges):
                s = src[lo : lo + self.chunk_edges]
                d = dst[lo : lo + self.chunk_edges]
                if self.drop_self_loops:
                    keep = s != d
                    s, d = s[keep], d[keep]
                m_raw += len(s)
                self.ledger.track("id_chunk", s, d)
                part = np.unique(np.concatenate([s, d]))
                pending.append(part)
                pending_items += len(part)
                self.ledger.add("id_pending", part.nbytes)
                if pending_items >= fold_at:
                    uniq = merge_unique_ids(uniq, *pending)
                    pending, pending_items = [], 0
                    self.ledger.drop("id_pending")
                self.ledger.drop("id_chunk")
        if pending:
            uniq = merge_unique_ids(uniq, *pending)
            self.ledger.drop("id_pending")
        self.uniq = uniq
        self.n = int(len(uniq))
        self.interval_size = -(-self.n // self.P) if self.n else 0
        self.m_raw = m_raw

    # -- pass 2: partition into sorted runs ---------------------------------
    def pass_partition(self) -> None:
        P, isz = self.P, self.interval_size
        self.spill_path = os.path.join(self.workdir, "spill.bin")
        self.rec_dtype = np.dtype(
            [("d", "<i4"), ("s", "<i4")]
            + ([("w", "<f4")] if self.weighted else [])
        )
        rec = self.rec_dtype.itemsize
        runs: dict[int, list[tuple[int, int]]] = {}
        with open(self.spill_path, "wb") as sf:
            for chunk in self.chunks():
                src, dst, w = _normalize_chunk(chunk)
                if (w is not None) != self.weighted:
                    raise ValueError("chunks disagree on the weights column")
                for lo in range(0, len(src), self.chunk_edges):
                    s_raw = src[lo : lo + self.chunk_edges]
                    d_raw = dst[lo : lo + self.chunk_edges]
                    w_raw = None if w is None else w[lo : lo + self.chunk_edges]
                    if self.drop_self_loops:
                        keep = s_raw != d_raw
                        s_raw, d_raw = s_raw[keep], d_raw[keep]
                        if w_raw is not None:
                            w_raw = w_raw[keep]
                    if len(s_raw) == 0:
                        continue
                    self.stats_chunks += 1
                    s = map_to_dense(self.uniq, s_raw)
                    d = map_to_dense(self.uniq, d_raw)
                    block = (s.astype(np.int64) // isz) * P + d // isz
                    order = np.lexsort((s, d, block))
                    recs = np.empty(len(s), self.rec_dtype)
                    recs["d"] = d[order]
                    recs["s"] = s[order]
                    if w_raw is not None:
                        recs["w"] = w_raw[order]
                    bsort = block[order]
                    self.ledger.track(
                        "part_chunk", s_raw, d_raw, w_raw, s, d, block, order,
                        recs, bsort,
                    )
                    base = sf.tell()
                    recs.tofile(sf)
                    bnd = np.flatnonzero(np.diff(bsort)) + 1
                    edges = np.concatenate([[0], bnd, [len(recs)]])
                    for a, b in zip(edges[:-1], edges[1:]):
                        runs.setdefault(int(bsort[a]), []).append(
                            (base + int(a) * rec, int(b - a))
                        )
                    self.ledger.drop("part_chunk")
        self.runs = runs
        self.spill_bytes = os.path.getsize(self.spill_path)

    # -- pass 3: merge, dedup, and emit every segment stream -----------------
    def _run_records(self, f, offset: int, count: int) -> Iterator[tuple]:
        """Yield one sorted run's records as python tuples, block-buffered."""
        rec = self.rec_dtype.itemsize
        buf_items = max(
            64,
            (self.chunk_budget // 4) // rec // max(self._active_runs, 1),
        )
        pos = 0
        names = self.rec_dtype.names
        while pos < count:
            k = min(buf_items, count - pos)
            f.seek(offset + pos * rec)
            arr = np.fromfile(f, dtype=self.rec_dtype, count=k)
            pos += k
            cols = [arr[name].tolist() for name in names]
            for t in zip(*cols):
                yield t

    def _bucket_pieces(
        self, f, run_list: list[tuple[int, int]]
    ) -> Iterator[tuple[np.ndarray, np.ndarray, np.ndarray | None]]:
        """Sorted, bounded pieces of one bucket's merged record stream."""
        total = sum(c for _, c in run_list)
        rec = self.rec_dtype.itemsize
        if total * rec <= self.load_bytes:
            parts = []
            for off, cnt in run_list:
                f.seek(off)
                parts.append(np.fromfile(f, dtype=self.rec_dtype, count=cnt))
            recs = np.concatenate(parts)
            order = np.lexsort((recs["s"], recs["d"]))
            recs = recs[order]
            self.ledger.track("bucket_load", *parts, recs, order)
            d = recs["d"].copy()
            s = recs["s"].copy()
            w = recs["w"].copy() if self.weighted else None
            del parts, recs, order
            self.ledger.track("bucket_load", d, s, w)
            yield d, s, w
            self.ledger.drop("bucket_load")
            return
        # k-way bounded merge of the bucket's sorted runs. heapq.merge is
        # stable across iterables, so duplicate (dst, src) keys keep their
        # partition (= input) order and dedup keeps the first occurrence,
        # matching degree_and_densify exactly.
        self.streamed_buckets += 1
        self._active_runs = len(run_list)
        merged = heapq.merge(
            *(self._run_records(f, off, cnt) for off, cnt in run_list),
            key=lambda t: (t[0], t[1]),
        )
        piece = self.chunk_edges
        bd: list = []
        bs: list = []
        bw: list = []
        # Charge what the merge actually keeps resident: every run's read
        # buffer (the same buf_items formula as _run_records) plus the
        # output piece being accumulated.
        buf_items = max(
            64, (self.chunk_budget // 4) // rec // max(self._active_runs, 1)
        )
        self.ledger.add(
            "merge_buffers",
            self._active_runs * buf_items * rec + piece * rec,
        )
        for t in merged:
            bd.append(t[0])
            bs.append(t[1])
            if self.weighted:
                bw.append(t[2])
            if len(bd) >= piece:
                yield (
                    np.array(bd, np.int32),
                    np.array(bs, np.int32),
                    np.array(bw, np.float32) if self.weighted else None,
                )
                bd, bs, bw = [], [], []
        if bd:
            yield (
                np.array(bd, np.int32),
                np.array(bs, np.int32),
                np.array(bw, np.float32) if self.weighted else None,
            )
        self.ledger.drop("merge_buffers")

    def pass_merge(self) -> None:
        P, isz, n = self.P, self.interval_size, self.n
        sp = {
            name: _Spool(os.path.join(self.workdir, name + ".spool"), dt)
            for name, dt in (
                ("src", np.int32),
                ("dst", np.int32),
                ("hub_dst_flat", np.int32),
                ("hub_inv_flat", np.int32),
                ("blk_src_local", np.int32),
                ("blk_dst_local", np.int32),
                ("blk_hub_inv", np.int32),
                ("blk_hub_dst", np.int32),
            )
        }
        if self.weighted:
            sp["weights"] = _Spool(
                os.path.join(self.workdir, "weights.spool"), np.float32
            )
            sp["blk_weights"] = _Spool(
                os.path.join(self.workdir, "blk_weights.spool"), np.float32
            )
        self.spools = sp
        self.counts = np.zeros((P, P), np.int64)
        self.hub_counts = np.zeros((P, P), np.int64)
        self.out_deg = np.zeros(max(n, 1), np.int64)
        self.in_deg = np.zeros(max(n, 1), np.int64)
        self.blk_dir: list[tuple[int, int, int, int, int]] = []  # i,j,e,u,ub
        chooser = _TileChooser()
        self._active_runs = 1
        flat_pos = 0
        cur_run_start: int | None = None
        with open(self.spill_path, "rb") as f:
            for b in range(P * P):
                run_list = self.runs.get(b)
                if not run_list:
                    continue
                i, j = divmod(b, P)
                prev_d = prev_s = None  # dedup carry within the bucket
                last_run_d = None  # run carry within the block
                e_blk = 0
                u_blk = 0
                for d, s, w in self._bucket_pieces(f, run_list):
                    if self.dedup:
                        new = np.ones(len(d), bool)
                        new[1:] = (d[1:] != d[:-1]) | (s[1:] != s[:-1])
                        if prev_d is not None:
                            new[0] = (int(d[0]) != prev_d) or (int(s[0]) != prev_s)
                        d_k, s_k = d[new], s[new]
                        w_k = None if w is None else w[new]
                    else:
                        d_k, s_k, w_k = d, s, w
                    prev_d, prev_s = int(d[-1]), int(s[-1])
                    if len(d_k) == 0:
                        continue
                    self.ledger.track("merge_piece", d, s, w, d_k, s_k, w_k)
                    run_new = np.ones(len(d_k), bool)
                    run_new[1:] = d_k[1:] != d_k[:-1]
                    if last_run_d is not None:
                        run_new[0] = int(d_k[0]) != last_run_d
                    last_run_d = int(d_k[-1])
                    # Feed the streaming tile chooser one closed run at a
                    # time (runs close when the next one starts).
                    for p in np.flatnonzero(run_new):
                        a = flat_pos + int(p)
                        if cur_run_start is not None:
                            chooser.close_run(cur_run_start, a)
                        cur_run_start = a
                    slots = u_blk + np.cumsum(run_new) - 1
                    sp["src"].append(s_k)
                    sp["dst"].append(d_k)
                    sp["hub_inv_flat"].append(slots)
                    sp["blk_hub_inv"].append(slots)
                    hub_d = (d_k[run_new] - j * isz).astype(np.int32)
                    sp["hub_dst_flat"].append(hub_d)
                    sp["blk_hub_dst"].append(hub_d)
                    sp["blk_src_local"].append(s_k - i * isz)
                    sp["blk_dst_local"].append(d_k - j * isz)
                    if self.weighted:
                        sp["weights"].append(w_k)
                        sp["blk_weights"].append(w_k)
                    self.out_deg += np.bincount(s_k, minlength=len(self.out_deg))
                    self.in_deg += np.bincount(d_k, minlength=len(self.in_deg))
                    e_blk += len(d_k)
                    u_blk += int(run_new.sum())
                    flat_pos += len(d_k)
                    self.ledger.drop("merge_piece")
                if e_blk == 0:
                    continue
                self.counts[i, j] = e_blk
                self.hub_counts[i, j] = u_blk
                ub = next_bucket(max(u_blk, 1))
                bucket = next_bucket(e_blk)
                self.blk_dir.append((i, j, e_blk, u_blk, ub))
                # Bucket padding — the block stream stores padded arrays,
                # exactly like DSSSGraph.padded_subshard.
                for name in ("blk_src_local", "blk_dst_local", "blk_hub_inv"):
                    sp[name].append_zeros(bucket - e_blk)
                sp["blk_hub_dst"].append_zeros(ub - u_blk)
                if self.weighted:
                    sp["blk_weights"].append_zeros(bucket - e_blk)
        if cur_run_start is not None:
            chooser.close_run(cur_run_start, flat_pos)
        self.m = flat_pos
        self.chooser = chooser
        for s in sp.values():
            s.close()

    # -- pass 4: tile the flat stream with the chosen T ----------------------
    def pass_packed(self) -> None:
        P, isz = self.P, self.interval_size
        T = self.chooser.choose(self.m)
        self.tile_edges = T
        self.num_tiles = 0
        n_pad = P * isz
        psp = {
            name: _Spool(os.path.join(self.workdir, name + ".spool"), dt)
            for name, dt in (
                ("p_src", np.int32),
                ("p_dst", np.int32),
                ("p_run_local", np.int32),
                ("p_run_dst", np.int32),
                ("p_e_valid", np.int32),
                ("p_src_interval", np.int32),
                ("p_dst_interval", np.int32),
                ("p_base_slot", np.int64),
                ("p_u", np.int32),
                ("p_row_offset", np.int64),
            )
        }
        if self.weighted:
            psp["p_weights"] = _Spool(
                os.path.join(self.workdir, "p_weights.spool"), np.float32
            )
        self.packed_spools = psp
        if self.m == 0:
            for s in psp.values():
                s.close()
            return
        flat_offsets = np.zeros(P * P + 1, np.int64)
        np.cumsum(self.counts.ravel(), out=flat_offsets[1:])
        hub_base = np.zeros(P * P, np.int64)
        np.cumsum(self.hub_counts.ravel()[:-1], out=hub_base[1:])

        # Current tile / pending run accumulators (each bounded by T).
        tile: dict[str, list] = {"s": [], "d": [], "g": [], "w": []}
        run: dict[str, list] = {"s": [], "d": [], "g": [], "w": []}
        state = {
            "tile_start": 0, "base_slot": 0, "tile_u": 0, "tile_open": False,
            "run_start": 0,
        }

        def flush_tile():
            e = sum(len(a) for a in tile["s"])
            assert 0 < e <= T
            s_cat = np.concatenate(tile["s"])
            d_cat = np.concatenate(tile["d"])
            g_cat = np.concatenate(tile["g"])
            row_src = np.zeros(T, np.int32)
            row_src[:e] = s_cat
            row_dst = np.zeros(T, np.int32)
            row_dst[:e] = d_cat
            rl = (g_cat - state["base_slot"]).astype(np.int32)
            row_rl = np.zeros(T, np.int32)
            row_rl[:e] = rl
            row_rd = np.full(T, n_pad, np.int32)
            row_rd[rl] = d_cat
            self.ledger.track(
                "tile", s_cat, d_cat, g_cat, row_src, row_dst, row_rl, row_rd
            )
            psp["p_src"].append(row_src)
            psp["p_dst"].append(row_dst)
            psp["p_run_local"].append(row_rl)
            psp["p_run_dst"].append(row_rd)
            if self.weighted:
                w_cat = np.concatenate(tile["w"])
                row_w = np.zeros(T, np.float32)
                row_w[:e] = w_cat
                psp["p_weights"].append(row_w)
            psp["p_e_valid"].append(np.array([e], np.int32))
            psp["p_src_interval"].append(
                np.array([int(s_cat[0]) // isz], np.int32)
            )
            psp["p_dst_interval"].append(
                np.array([int(d_cat[0]) // isz], np.int32)
            )
            psp["p_base_slot"].append(np.array([state["base_slot"]], np.int64))
            psp["p_u"].append(np.array([state["tile_u"]], np.int32))
            psp["p_row_offset"].append(np.array([state["tile_start"]], np.int64))
            self.num_tiles += 1
            for key in tile:
                tile[key] = []
            state["tile_u"] = 0
            state["tile_open"] = False
            self.ledger.drop("tile")

        def close_pending(end_abs: int):
            if not run["s"]:
                return
            if state["tile_open"] and end_abs > state["tile_start"] + T:
                flush_tile()
            if not state["tile_open"]:
                state["tile_open"] = True
                state["tile_start"] = state["run_start"]
                state["base_slot"] = int(run["g"][0][0])
            for key in ("s", "d", "g", "w"):
                tile[key].extend(run[key])
                run[key] = []
            state["tile_u"] += 1

        prev_gslot = None
        for off, s_c, d_c, g_c, w_c in self._iter_flat(flat_offsets, hub_base):
            new_run = np.ones(len(g_c), bool)
            new_run[1:] = g_c[1:] != g_c[:-1]
            if prev_gslot is not None:
                new_run[0] = int(g_c[0]) != prev_gslot
            prev_gslot = int(g_c[-1])
            starts = np.flatnonzero(new_run)
            bounds = np.concatenate([starts, [len(g_c)]])
            if len(starts) == 0 or starts[0] != 0:
                # leading continuation of the pending run
                head = int(bounds[0]) if len(starts) else len(g_c)
                run["s"].append(s_c[:head])
                run["d"].append(d_c[:head])
                run["g"].append(g_c[:head])
                if self.weighted:
                    run["w"].append(w_c[:head])
            for q in range(len(starts)):
                p = int(starts[q])
                close_pending(off + p)
                state["run_start"] = off + p
                hi = int(bounds[q + 1])
                run["s"].append(s_c[p:hi])
                run["d"].append(d_c[p:hi])
                run["g"].append(g_c[p:hi])
                if self.weighted:
                    run["w"].append(w_c[p:hi])
        close_pending(self.m)
        if state["tile_open"]:
            flush_tile()
        for s in psp.values():
            s.close()

    def _iter_flat(self, flat_offsets: np.ndarray, hub_base: np.ndarray):
        """Stream (offset, src, dst, gslot, weights) chunks of the flat spools."""
        paths = self.spools
        step = self.chunk_edges
        with open(paths["src"].path, "rb") as fs, open(
            paths["dst"].path, "rb"
        ) as fd, open(paths["hub_inv_flat"].path, "rb") as fh:
            fw = open(paths["weights"].path, "rb") if self.weighted else None
            try:
                off = 0
                while off < self.m:
                    k = min(step, self.m - off)
                    s_c = np.fromfile(fs, np.int32, k)
                    d_c = np.fromfile(fd, np.int32, k)
                    h_c = np.fromfile(fh, np.int32, k)
                    w_c = np.fromfile(fw, np.float32, k) if fw else None
                    blk = (
                        np.searchsorted(
                            flat_offsets, np.arange(off, off + k), side="right"
                        )
                        - 1
                    )
                    g_c = hub_base[blk] + h_c
                    self.ledger.track("flat_chunk", s_c, d_c, h_c, w_c, blk, g_c)
                    yield off, s_c, d_c, g_c, w_c
                    self.ledger.drop("flat_chunk")
                    off += k
            finally:
                if fw:
                    fw.close()

    # -- assembly ------------------------------------------------------------
    def assemble(self) -> None:
        P, isz, n = self.P, self.interval_size, self.n
        n_pad = P * isz
        w = StoreWriter(self.out_path)

        def addf(name, dt, shape, path):
            return w.add_file(name, dt, shape, path, io_chunk=self.io_chunk)

        try:
            flat_offsets = np.zeros(P * P + 1, np.int64)
            np.cumsum(self.counts.ravel(), out=flat_offsets[1:])
            offsets = np.zeros((P, P + 1), np.int64)
            offsets[:, 0] = flat_offsets[:-1].reshape(P, P)[:, 0]
            offsets[:, 1:] = flat_offsets[1:].reshape(P, P)
            hub_cum = np.zeros(P * P + 1, np.int64)
            np.cumsum(self.hub_counts.ravel(), out=hub_cum[1:])
            hub_offsets = np.zeros((P, P + 1), np.int64)
            hub_offsets[:, 0] = hub_cum[:-1].reshape(P, P)[:, 0]
            hub_offsets[:, 1:] = hub_cum[1:].reshape(P, P)
            out_deg = np.zeros(n_pad, np.int32)
            out_deg[:n] = self.out_deg[:n]
            in_deg = np.zeros(n_pad, np.int32)
            in_deg[:n] = self.in_deg[:n]
            meta = {
                "format": "dsss",
                "version": FORMAT_VERSION,
                "n": n,
                "m": self.m,
                "P": P,
                "interval_size": isz,
                "weighted": self.weighted,
                "src_sorted": False,
                "num_blocks": len(self.blk_dir),
            }
            w.add_array("offsets", offsets)
            w.add_array("hub_offsets", hub_offsets)
            w.add_array("out_degree", out_deg)
            w.add_array("in_degree", in_deg)
            w.add_array("id_to_index", self.uniq)
            self.ledger.add("assembly_io", self.io_chunk)
            addf("src", np.int32, (self.m,), self.spools["src"].path)
            addf("dst", np.int32, (self.m,), self.spools["dst"].path)
            if self.weighted:
                addf(
                    "weights", np.float32, (self.m,), self.spools["weights"].path
                )
            total_hub = int(hub_cum[-1])
            addf(
                "hub_dst_flat", np.int32, (total_hub,),
                self.spools["hub_dst_flat"].path,
            )
            addf(
                "hub_inv_flat", np.int32, (self.m,),
                self.spools["hub_inv_flat"].path,
            )
            nb = len(self.blk_dir)
            dir_cols = list(zip(*self.blk_dir)) if nb else [[]] * 5
            w.add_array("blk_i", np.asarray(dir_cols[0], np.int32))
            w.add_array("blk_j", np.asarray(dir_cols[1], np.int32))
            w.add_array("blk_e", np.asarray(dir_cols[2], np.int64))
            w.add_array("blk_u", np.asarray(dir_cols[3], np.int64))
            w.add_array("blk_ub", np.asarray(dir_cols[4], np.int64))
            buckets = np.array(
                [next_bucket(e) for e in dir_cols[2]], np.int64
            ) if nb else np.zeros(0, np.int64)
            ubs = np.asarray(dir_cols[4], np.int64) if nb else np.zeros(0, np.int64)
            beo = np.zeros(nb, np.int64)
            bho = np.zeros(nb, np.int64)
            if nb:
                np.cumsum(buckets[:-1], out=beo[1:])
                np.cumsum(ubs[:-1], out=bho[1:])
            w.add_array("blk_edge_off", beo)
            w.add_array("blk_hub_off", bho)
            tot_slots = int(buckets.sum())
            tot_ub = int(ubs.sum())
            for name, shape in (
                ("blk_src_local", (tot_slots,)),
                ("blk_dst_local", (tot_slots,)),
                ("blk_hub_inv", (tot_slots,)),
                ("blk_hub_dst", (tot_ub,)),
            ):
                addf(name, np.int32, shape, self.spools[name].path)
            if self.weighted:
                addf(
                    "blk_weights", np.float32, (tot_slots,),
                    self.spools["blk_weights"].path,
                )
            if self.packing is not None:
                meta["packing"] = "adaptive"
                meta["tile_edges"] = self.tile_edges
                meta["num_tiles"] = self.num_tiles
                NT, T = self.num_tiles, self.tile_edges
                for name, dt, shape in (
                    ("p_src", np.int32, (NT, T)),
                    ("p_dst", np.int32, (NT, T)),
                    ("p_run_local", np.int32, (NT, T)),
                    ("p_run_dst", np.int32, (NT, T)),
                ):
                    addf(name, dt, shape, self.packed_spools[name].path)
                if self.weighted:
                    addf(
                        "p_weights", np.float32, (NT, T),
                        self.packed_spools["p_weights"].path,
                    )
                for name, dt, shape in (
                    ("p_e_valid", np.int32, (NT,)),
                    ("p_src_interval", np.int32, (NT,)),
                    ("p_dst_interval", np.int32, (NT,)),
                    ("p_base_slot", np.int64, (NT,)),
                    ("p_u", np.int32, (NT,)),
                    ("p_row_offset", np.int64, (NT,)),
                ):
                    addf(name, dt, shape, self.packed_spools[name].path)
            else:
                meta["packing"] = None
            self.ledger.drop("assembly_io")
            w.close(meta)
        except BaseException:
            w.abort()
            raise

    def run(self) -> BuildStats:
        owns_workdir = self.workdir is None
        if owns_workdir:
            self.workdir = tempfile.mkdtemp(
                prefix=".dsss-build-",
                dir=os.path.dirname(os.path.abspath(self.out_path)) or ".",
            )
        else:
            os.makedirs(self.workdir, exist_ok=True)
        try:
            self.pass_ids()  # also records self.weighted from chunk 1
            self.pass_partition()
            self.pass_merge()
            if self.packing is not None:
                self.pass_packed()
            else:
                self.tile_edges = 0
                self.num_tiles = 0
            self.assemble()
        finally:
            if owns_workdir:
                shutil.rmtree(self.workdir, ignore_errors=True)
        return BuildStats(
            path=self.out_path,
            n=self.n,
            m=self.m,
            m_raw=self.m_raw,
            P=self.P,
            interval_size=self.interval_size,
            num_blocks=len(self.blk_dir),
            chunk_budget=self.chunk_budget,
            chunk_edges=self.chunk_edges,
            num_chunks=self.stats_chunks,
            streamed_buckets=self.streamed_buckets,
            spill_bytes=self.spill_bytes,
            peak_edge_bytes=self.ledger.peak,
            tile_edges=self.tile_edges,
            num_tiles=self.num_tiles,
        )


def build_dsss_file(
    chunks: Callable[[], Iterable],
    out_path: str,
    P: int,
    *,
    chunk_budget: int = 64 << 20,
    drop_self_loops: bool = False,
    dedup: bool = True,
    workdir: str | None = None,
    packing: str | None = "adaptive",
) -> BuildStats:
    """Build a ``.dsss`` container from a re-iterable raw edge stream.

    Args:
      chunks: zero-argument callable returning a fresh iterator of
        ``(src, dst)`` or ``(src, dst, weights)`` array chunks. It is
        invoked multiple times (id pass, partition pass) and must yield
        the same data each time — e.g. ``lambda:
        iter_text_edges("edges.txt")``.
      out_path: destination ``.dsss`` path.
      P: number of vertex intervals.
      chunk_budget: target bytes of resident edge-array state. The
        builder derives its chunk, bucket-load and copy-buffer sizes from
        it and charges every edge-scale allocation to a ledger;
        ``BuildStats.peak_edge_bytes`` stays within ~2× this budget.
      drop_self_loops / dedup: same semantics (and identical results) as
        :func:`repro.graph.preprocess.degree_and_densify`.
      workdir: spill/spool directory (a sibling temp dir by default,
        removed afterwards).
      packing: ``"adaptive"`` stores the PackedSweep tile section with
        exactly the tile size :func:`repro.core.dsss.choose_tile_edges`
        would pick; ``None`` skips it.

    The resulting container is layout-identical to ``write_dsss(
    build_dsss(degree_and_densify(...), P))`` — the property suite pins
    this equivalence — but peak edge-resident memory is bounded by the
    chunk budget instead of O(m).
    """
    builder = _ExternalBuilder(
        chunks,
        out_path,
        P,
        chunk_budget=chunk_budget,
        drop_self_loops=drop_self_loops,
        dedup=dedup,
        workdir=workdir,
        packing=packing,
    )
    return builder.run()


def build_from_text(
    text_path: str,
    out_path: str,
    P: int,
    *,
    weights: bool = False,
    comment: str = "#",
    id_dtype=np.int64,
    **kwargs,
) -> BuildStats:
    """Front end: chunk-stream a SNAP-style text edge list into a build."""
    from repro.graph.io import iter_text_edges

    chunk_budget = kwargs.get("chunk_budget", 64 << 20)
    chunk_edges = max(1024, int(chunk_budget) // 64)

    def chunks():
        return iter_text_edges(
            text_path,
            comment=comment,
            dtype=id_dtype,
            weights=weights,
            chunk_edges=chunk_edges,
        )

    return build_dsss_file(chunks, out_path, P, **kwargs)
