"""repro.storage — the on-disk DSSS tier.

A versioned, memory-mappable ``.dsss`` container (:mod:`repro.storage.
format`), an external-memory build pipeline that produces it in bounded
RAM (:mod:`repro.storage.build`), and a CLI
(``python -m repro.storage build|info|verify``). Opened stores plug into
the execution engine as the third residency tier:
``GraphSession.open(path)`` / ``residency="disk"`` stream sub-shard
blocks and packed tile chunks disk→device through the existing
double-buffered prefetch machinery.
"""
from repro.storage.build import BuildStats, build_dsss_file, build_from_text
from repro.storage.format import (
    ChecksumError,
    DegradedReadError,
    DSSSStore,
    FormatError,
    ReadPolicy,
    open_dsss,
    store_info,
    verify_dsss,
    write_dsss,
)

__all__ = [
    "BuildStats",
    "build_dsss_file",
    "build_from_text",
    "ChecksumError",
    "DegradedReadError",
    "DSSSStore",
    "FormatError",
    "ReadPolicy",
    "open_dsss",
    "store_info",
    "verify_dsss",
    "write_dsss",
]
