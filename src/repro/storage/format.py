"""The ``.dsss`` on-disk container — a memory-mappable DSSS graph store.

The paper keeps sub-shards in binary files on disk and streams them
through memory (§IV "streamlined disk access"); the in-memory reproduction
so far only streamed host→device. This module is the missing bottom tier:
a single versioned file holding every staged artifact of a
:class:`repro.core.dsss.DSSSGraph` in the exact layout the execution
engine consumes, so a session can *mmap* the file and run without ever
materializing the graph in host RAM:

* **meta arrays** — ``offsets``/``hub_offsets`` tables, padded degree
  arrays, the dense-id reverse mapping;
* **flat edge segments** — ``src``/``dst``(/``weights``) and the hub
  arrays in DSSS streaming order (row-major ``(i, j)``,
  destination-sorted inside each sub-shard) — the fused path and
  re-packing read these;
* **sub-shard block stream + directory** — every non-empty sub-shard's
  *padded* block arrays (``src_local``/``dst_local``/``hub_inv``/
  ``hub_dst``/``weights``, bucket-padded exactly like
  :meth:`~repro.core.dsss.DSSSGraph.padded_subshard`) concatenated in the
  schedules' streaming order, with a per-block segment directory — the
  ``_BlockFetcher`` streams mmap views of these disk→device;
* **the packed sweep** — the PR-4 adaptive
  :class:`~repro.core.dsss.PackedSweep` tile arrays, so a stored graph
  skips repacking and packed execution streams tile chunks straight from
  the file.

Layout: a fixed 32-byte preamble (magic, version, footer pointer), then
64-byte-aligned binary segments, then a JSON *footer* holding the graph
metadata and the segment directory (name, dtype, shape, offset, nbytes,
crc32 per segment). Writing streams segments first and patches the
preamble last, so the external-memory builder never needs the directory
up front; a truncated or bit-flipped file fails the footer or segment
checksums instead of producing garbage results.
"""
from __future__ import annotations

import dataclasses
import json
import os
import struct
import time
import zlib
from typing import Any, BinaryIO

import numpy as np

from repro.core.dsss import DSSSGraph, PackedSweep, next_bucket
from repro.obs.registry import REGISTRY as _REGISTRY

_OBS_READ_RETRIES = _REGISTRY.counter(
    "repro_storage_read_retries_total",
    "Checksum-failed segment reads that were retried",
)
_OBS_HEALS = _REGISTRY.counter(
    "repro_storage_heals_total",
    "Segments that verified after at least one failed read",
)
_OBS_QUARANTINES = _REGISTRY.counter(
    "repro_storage_quarantines_total",
    "Segments quarantined after retry exhaustion",
)
from repro.graph.preprocess import EdgeList

__all__ = [
    "MAGIC",
    "FORMAT_VERSION",
    "ChecksumError",
    "DegradedReadError",
    "FormatError",
    "ReadPolicy",
    "Segment",
    "StoreWriter",
    "DSSSStore",
    "open_dsss",
    "write_dsss",
    "verify_dsss",
    "store_info",
]

MAGIC = b"NXGDSSS1"
FORMAT_VERSION = 1
_PREAMBLE = struct.Struct("<8sIQQI")  # magic, version, foot_off, foot_len, foot_crc
_ALIGN = 64
_IO_CHUNK = 1 << 22  # 4 MiB streaming unit for copies / verification


class FormatError(Exception):
    """The file is not a (readable) .dsss container."""


class ChecksumError(FormatError):
    """A segment's stored checksum does not match its bytes."""


class DegradedReadError(FormatError):
    """A segment stayed corrupt through the retry budget and is quarantined.

    Structured: names the exact segment, its byte extent, its tile span
    (packed ``p_*`` segments), and how many read attempts were spent — the
    report an operator (or ``repro.storage verify --repair``) acts on. The
    fetch layer raises this instead of ever returning garbage.
    """

    def __init__(
        self,
        path: str,
        segment: str,
        *,
        offset: int,
        nbytes: int,
        shape: tuple[int, ...],
        attempts: int,
        tile_range: tuple[int, int] | None = None,
    ):
        self.segment = segment
        self.offset = offset
        self.nbytes = nbytes
        self.shape = shape
        self.attempts = attempts
        self.tile_range = tile_range
        span = (
            f", tiles [{tile_range[0]}, {tile_range[1]})"
            if tile_range is not None
            else ""
        )
        super().__init__(
            f"{path}: segment {segment!r} quarantined after {attempts} read "
            f"attempts (bytes [{offset}, {offset + nbytes}){span}); rebuild "
            "it from the raw edge source with "
            "`python -m repro.storage verify --repair --source <edges>`"
        )


@dataclasses.dataclass(frozen=True)
class ReadPolicy:
    """Self-healing read discipline for segment verification.

    A segment whose checksum read fails is re-read up to ``max_retries``
    times with exponential backoff (torn reads heal); a segment still bad
    after the budget is quarantined behind a :class:`DegradedReadError`.
    """

    max_retries: int = 3
    backoff_s: float = 0.001
    backoff_factor: float = 2.0

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {self.max_retries}")
        if self.backoff_s < 0:
            raise ValueError(f"backoff_s must be >= 0, got {self.backoff_s}")
        if self.backoff_factor < 1.0:
            raise ValueError(
                f"backoff_factor must be >= 1, got {self.backoff_factor}"
            )


@dataclasses.dataclass(frozen=True)
class Segment:
    name: str
    dtype: str
    shape: tuple[int, ...]
    offset: int
    nbytes: int
    crc32: int

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "dtype": self.dtype,
            "shape": list(self.shape),
            "offset": self.offset,
            "nbytes": self.nbytes,
            "crc32": self.crc32,
        }

    @classmethod
    def from_json(cls, d: dict) -> "Segment":
        return cls(
            name=d["name"],
            dtype=d["dtype"],
            shape=tuple(int(s) for s in d["shape"]),
            offset=int(d["offset"]),
            nbytes=int(d["nbytes"]),
            crc32=int(d["crc32"]),
        )


def _expected_nbytes(dtype: str, shape: tuple[int, ...]) -> int:
    count = 1
    for s in shape:
        count *= int(s)
    return count * np.dtype(dtype).itemsize


class _SegmentStream:
    """An append-only segment whose length is unknown until closed.

    The external-memory builder writes flat/packed segments in bounded
    pieces; the stream tracks length and a running crc32 so the directory
    entry can be recorded at close time.
    """

    def __init__(self, writer: "StoreWriter", name: str, dtype):
        self._writer = writer
        self.name = name
        self.dtype = np.dtype(dtype)
        self.offset = writer._align()
        self.nbytes = 0
        self.crc = 0
        self.items = 0

    def append(self, arr: np.ndarray) -> None:
        arr = np.ascontiguousarray(arr, dtype=self.dtype)
        buf = arr.view(np.uint8).reshape(-1).data
        self._writer._f.write(buf)
        self.crc = zlib.crc32(buf, self.crc)
        self.nbytes += arr.nbytes
        self.items += arr.size
        self._writer._pos += arr.nbytes

    def close(self, shape: tuple[int, ...] | None = None) -> Segment:
        shape = (self.items,) if shape is None else tuple(int(s) for s in shape)
        if _expected_nbytes(str(self.dtype), shape) != self.nbytes:
            raise FormatError(
                f"segment {self.name!r}: closed with shape {shape} but "
                f"{self.nbytes} bytes were written"
            )
        seg = Segment(
            name=self.name,
            dtype=str(self.dtype),
            shape=shape,
            offset=self.offset,
            nbytes=self.nbytes,
            crc32=self.crc,
        )
        self._writer._record(seg)
        return seg


class StoreWriter:
    """Sequential .dsss writer: segments stream in, directory lands last."""

    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._f: BinaryIO = open(path, "wb")
        self._f.write(_PREAMBLE.pack(MAGIC, FORMAT_VERSION, 0, 0, 0))
        self._pos = _PREAMBLE.size
        self._segments: list[Segment] = []
        self._names: set[str] = set()
        self._closed = False

    def _align(self) -> int:
        pad = (-self._pos) % _ALIGN
        if pad:
            self._f.write(b"\x00" * pad)
            self._pos += pad
        return self._pos

    def _record(self, seg: Segment) -> None:
        if seg.name in self._names:
            raise FormatError(f"duplicate segment name {seg.name!r}")
        self._names.add(seg.name)
        self._segments.append(seg)

    def add_array(self, name: str, arr: np.ndarray) -> Segment:
        """Write one in-memory (or mmap) array as a segment."""
        arr = np.ascontiguousarray(arr)
        stream = self.stream(name, arr.dtype)
        # Stream in bounded windows so mmap-backed sources never fully
        # materialize (the writer is part of the bounded-RAM pipeline).
        flat = arr.reshape(-1)
        step = max(1, _IO_CHUNK // max(arr.itemsize, 1))
        for lo in range(0, flat.size, step):
            stream.append(flat[lo : lo + step])
        return stream.close(arr.shape)

    def stream(self, name: str, dtype) -> _SegmentStream:
        """Open an append-only segment (close() records it)."""
        return _SegmentStream(self, name, dtype)

    def add_file(
        self,
        name: str,
        dtype,
        shape: tuple[int, ...],
        src_path: str,
        *,
        io_chunk: int = _IO_CHUNK,
    ) -> Segment:
        """Stream a raw spool file (builder temp output) in as a segment.

        ``io_chunk`` bounds the copy window — the external builder passes
        a budget-derived size so assembly stays within its memory ledger.
        """
        stream = self.stream(name, dtype)
        itemsize = np.dtype(dtype).itemsize
        io_chunk = max(itemsize, (io_chunk // itemsize) * itemsize)
        with open(src_path, "rb") as src:
            while True:
                buf = src.read(io_chunk)
                if not buf:
                    break
                if len(buf) % itemsize:
                    raise FormatError(
                        f"spool {src_path!r} is not a whole number of "
                        f"{dtype} items"
                    )
                stream.append(np.frombuffer(buf, dtype=dtype))
        return stream.close(shape)

    def close(self, meta: dict) -> None:
        """Write the JSON footer and patch the preamble pointer."""
        if self._closed:
            return
        foot_off = self._align()
        footer = dict(meta)
        footer["segments"] = [s.to_json() for s in self._segments]
        blob = json.dumps(footer, sort_keys=True).encode("utf-8")
        self._f.write(blob)
        self._f.seek(0)
        self._f.write(
            _PREAMBLE.pack(
                MAGIC, FORMAT_VERSION, foot_off, len(blob), zlib.crc32(blob)
            )
        )
        self._f.flush()
        self._f.close()
        self._closed = True

    def abort(self) -> None:
        if not self._closed:
            self._f.close()
            self._closed = True
            if os.path.exists(self.path):
                os.unlink(self.path)


# ---------------------------------------------------------------------------
# Reader.
# ---------------------------------------------------------------------------
class DSSSStore:
    """An opened .dsss file: metadata + zero-copy mmap views of segments.

    ``array(name)`` returns a read-only :class:`numpy.memmap` of one
    segment; :meth:`graph`, :meth:`host_blocks` and :meth:`packed`
    assemble the engine-facing objects out of those views, so nothing
    edge-scale is resident in host RAM until a page is actually touched.
    """

    def __init__(
        self,
        path: str,
        *,
        verify: bool = False,
        read_policy: ReadPolicy | None = None,
    ):
        self.path = path
        # Self-healing read state: ``read_policy`` turns on
        # verify-on-first-touch (ensure_segment) with bounded re-read;
        # ``quarantined`` remembers segments that stayed bad so every
        # later fetch re-raises the same structured error instantly.
        self.read_policy = read_policy
        self.quarantined: dict[str, DegradedReadError] = {}
        self.healed_reads = 0
        self._verified: set[str] = set()
        self._injector = None
        size = os.path.getsize(path)
        if size < _PREAMBLE.size:
            raise FormatError(f"{path}: too small to be a .dsss file")
        with open(path, "rb") as f:
            magic, version, foot_off, foot_len, foot_crc = _PREAMBLE.unpack(
                f.read(_PREAMBLE.size)
            )
            if magic != MAGIC:
                raise FormatError(f"{path}: bad magic {magic!r}")
            if version != FORMAT_VERSION:
                raise FormatError(
                    f"{path}: unsupported format version {version} "
                    f"(expected {FORMAT_VERSION})"
                )
            if foot_off == 0 or foot_off + foot_len > size:
                raise FormatError(f"{path}: missing or truncated footer")
            f.seek(foot_off)
            blob = f.read(foot_len)
        if zlib.crc32(blob) != foot_crc:
            raise ChecksumError(f"{path}: footer checksum mismatch")
        footer = json.loads(blob.decode("utf-8"))
        self.meta: dict[str, Any] = {
            k: v for k, v in footer.items() if k != "segments"
        }
        self.segments: dict[str, Segment] = {}
        for d in footer["segments"]:
            seg = Segment.from_json(d)
            if seg.offset + seg.nbytes > size:
                raise ChecksumError(
                    f"{path}: segment {seg.name!r} extends past end of file "
                    "(truncated?)"
                )
            if _expected_nbytes(seg.dtype, seg.shape) != seg.nbytes:
                raise FormatError(
                    f"{path}: segment {seg.name!r} shape/nbytes mismatch"
                )
            self.segments[seg.name] = seg
        self._arrays: dict[str, np.ndarray] = {}
        self._graph: DSSSGraph | None = None
        self._blocks: dict[tuple[int, int], dict] | None = None
        self._packed: PackedSweep | None = None
        if verify:
            self.verify()

    # -- raw access ----------------------------------------------------------
    def has(self, name: str) -> bool:
        return name in self.segments

    def array(self, name: str) -> np.ndarray:
        """Read-only view of one segment (mmap; zero-copy, lazily paged)."""
        arr = self._arrays.get(name)
        if arr is None:
            seg = self.segments[name]
            if seg.nbytes == 0:
                arr = np.empty(seg.shape, dtype=np.dtype(seg.dtype))
            else:
                arr = np.memmap(
                    self.path,
                    dtype=np.dtype(seg.dtype),
                    mode="r",
                    offset=seg.offset,
                    shape=seg.shape,
                )
            self._arrays[name] = arr
        return arr

    def attach_faults(self, injector) -> None:
        """Attach (or clear) a :class:`repro.reliability.FaultInjector`.

        The injector's ``storage_read(segment, attempt)`` decisions make
        checksum reads observe corrupt / short bytes — the deterministic
        stand-in for torn reads and bad media the self-healing path is
        tested against. Clearing resets the verified-segment memo so a
        new plan re-exercises the reads.
        """
        self._injector = injector
        self._verified.clear()

    def _checksum_segment(self, seg: Segment, *, attempt: int = 0) -> None:
        """Recompute one segment's checksum — one bounded-chunk read attempt.

        This is the storage fault-injection boundary: an attached injector
        can make this attempt observe a short (truncated) or corrupt
        (crc-perturbed) read. Raises :class:`ChecksumError` on any
        mismatch; never returns bad bytes to a caller.
        """
        decision = (
            self._injector.storage_read(seg.name, attempt)
            if self._injector is not None
            else None
        )
        if decision == "short":
            raise ChecksumError(
                f"{self.path}: segment {seg.name!r} truncated "
                "(injected short read)"
            )
        with open(self.path, "rb") as f:
            f.seek(seg.offset)
            remaining, crc = seg.nbytes, 0
            while remaining:
                buf = f.read(min(_IO_CHUNK, remaining))
                if not buf:
                    raise ChecksumError(
                        f"{self.path}: segment {seg.name!r} truncated"
                    )
                crc = zlib.crc32(buf, crc)
                remaining -= len(buf)
        if decision == "corrupt":
            crc ^= 0xDEADBEEF  # the injected bit flip
        if crc != seg.crc32:
            raise ChecksumError(
                f"{self.path}: segment {seg.name!r} checksum mismatch "
                f"(stored {seg.crc32:#010x}, computed {crc:#010x})"
            )

    def verify(self) -> None:
        """Recompute every segment checksum; raise :class:`ChecksumError`.

        Reads the file sequentially in bounded chunks — verification of an
        out-of-core graph never materializes it.
        """
        for seg in self.segments.values():
            self._checksum_segment(seg)

    def scan(self) -> list[str]:
        """Names of segments whose checksum currently fails (no retries).

        The repair tool's damage report: unlike :meth:`verify` it keeps
        going past the first failure, and unlike :meth:`ensure_segment`
        it neither retries nor quarantines.
        """
        bad = []
        for seg in self.segments.values():
            try:
                self._checksum_segment(seg)
            except ChecksumError:
                bad.append(seg.name)
        return bad

    def ensure_segment(self, name: str) -> None:
        """Verify one segment on first touch, healing torn reads.

        No-op without a :class:`ReadPolicy` (the opt-in) or when the
        segment already verified. A failing checksum read is retried up
        to ``max_retries`` times with exponential backoff —
        ``healed_reads`` counts recoveries; exhaustion quarantines the
        segment and raises the structured :class:`DegradedReadError`
        (re-raised instantly on every later touch).
        """
        policy = self.read_policy
        if policy is None or name in self._verified:
            return
        err = self.quarantined.get(name)
        if err is not None:
            raise err
        seg = self.segments[name]
        attempt = 0
        delay = policy.backoff_s
        while True:
            try:
                self._checksum_segment(seg, attempt=attempt)
            except ChecksumError as exc:
                if attempt >= policy.max_retries:
                    tile_range = (
                        (0, int(seg.shape[0]))
                        if name.startswith("p_") and seg.shape
                        else None
                    )
                    err = DegradedReadError(
                        self.path,
                        name,
                        offset=seg.offset,
                        nbytes=seg.nbytes,
                        shape=seg.shape,
                        attempts=attempt + 1,
                        tile_range=tile_range,
                    )
                    self.quarantined[name] = err
                    _OBS_QUARANTINES.inc()
                    raise err from exc
                _OBS_READ_RETRIES.inc()
                time.sleep(delay)
                delay *= policy.backoff_factor
                attempt += 1
            else:
                if attempt:
                    self.healed_reads += 1
                    _OBS_HEALS.inc()
                self._verified.add(name)
                return

    def ensure_segments(self, names) -> None:
        """:meth:`ensure_segment` over an iterable of segment names."""
        for name in names:
            self.ensure_segment(name)

    # -- engine-facing assembly ---------------------------------------------
    def graph(self) -> DSSSGraph:
        """The mmap-backed :class:`DSSSGraph` (cached; arrays stay views)."""
        if self._graph is None:
            meta = self.meta
            n, m = int(meta["n"]), int(meta["m"])
            out_deg = self.array("out_degree")
            in_deg = self.array("in_degree")
            weights = self.array("weights") if self.has("weights") else None
            edgelist = EdgeList(
                src=self.array("src"),
                dst=self.array("dst"),
                n=n,
                out_degree=out_deg[:n],
                in_degree=in_deg[:n],
                id_to_index=self.array("id_to_index"),
                weights=weights,
            )
            self._graph = DSSSGraph(
                n=n,
                m=m,
                P=int(meta["P"]),
                interval_size=int(meta["interval_size"]),
                src=self.array("src"),
                dst=self.array("dst"),
                weights=weights,
                offsets=np.asarray(self.array("offsets")),
                out_degree=out_deg,
                in_degree=in_deg,
                hub_dst_flat=self.array("hub_dst_flat"),
                hub_inv_flat=self.array("hub_inv_flat"),
                hub_offsets=np.asarray(self.array("hub_offsets")),
                edgelist=edgelist,
                src_sorted=bool(meta["src_sorted"]),
            )
        return self._graph

    def host_blocks(self) -> dict[tuple[int, int], dict]:
        """Padded sub-shard blocks as mmap views — the disk-tier image.

        Leaf-for-leaf identical to
        :meth:`repro.core.dsss.DSSSGraph.host_blocks`, but every array is
        a view into the block stream segments: building this dict
        allocates nothing edge-scale, and a fetch only pages in the block
        actually touched.
        """
        if self._blocks is None:
            bi = self.array("blk_i")
            bj = self.array("blk_j")
            be = self.array("blk_e")
            bu = self.array("blk_u")
            bub = self.array("blk_ub")
            beo = self.array("blk_edge_off")
            bho = self.array("blk_hub_off")
            bsl = self.array("blk_src_local")
            bdl = self.array("blk_dst_local")
            bhi = self.array("blk_hub_inv")
            bhd = self.array("blk_hub_dst")
            bw = self.array("blk_weights") if self.has("blk_weights") else None
            blocks: dict[tuple[int, int], dict] = {}
            for k in range(len(bi)):
                e, u, ub = int(be[k]), int(bu[k]), int(bub[k])
                eo, ho = int(beo[k]), int(bho[k])
                bucket = next_bucket(e)
                blocks[(int(bi[k]), int(bj[k]))] = {
                    "src_local": bsl[eo : eo + bucket],
                    "dst_local": bdl[eo : eo + bucket],
                    "hub_inv": bhi[eo : eo + bucket],
                    "hub_dst": bhd[ho : ho + ub],
                    "e": e,
                    "u": u,
                    "u_bucket": ub,
                    "weights": None if bw is None else bw[eo : eo + bucket],
                }
            self._blocks = blocks
        return self._blocks

    def packed(self) -> PackedSweep | None:
        """The stored :class:`PackedSweep` (mmap leaves), or ``None``."""
        if self.meta.get("packing") is None:
            return None
        if self._packed is None:
            self._packed = PackedSweep(
                mode=str(self.meta["packing"]),
                m=int(self.meta["m"]),
                n_pad=int(self.meta["P"]) * int(self.meta["interval_size"]),
                tile_edges=int(self.meta["tile_edges"]),
                src=self.array("p_src"),
                dst=self.array("p_dst"),
                run_local=self.array("p_run_local"),
                run_dst=self.array("p_run_dst"),
                weights=self.array("p_weights") if self.has("p_weights") else None,
                e_valid=self.array("p_e_valid"),
                src_interval=self.array("p_src_interval"),
                dst_interval=self.array("p_dst_interval"),
                base_slot=self.array("p_base_slot"),
                u=self.array("p_u"),
                row_offset=self.array("p_row_offset"),
            )
        return self._packed


def open_dsss(
    path: str,
    *,
    verify: bool = False,
    read_policy: ReadPolicy | None = None,
) -> DSSSStore:
    """Open a .dsss container (``verify=True`` checks every segment crc).

    ``read_policy`` opts in to self-healing reads: segments verify on
    first touch with bounded re-read + backoff and quarantine behind
    :class:`DegradedReadError` when they stay bad (see
    :meth:`DSSSStore.ensure_segment`).
    """
    return DSSSStore(path, verify=verify, read_policy=read_policy)


def verify_dsss(path: str) -> DSSSStore:
    """Fully verify a container; returns the opened store on success."""
    return DSSSStore(path, verify=True)


def _base_meta(graph: DSSSGraph) -> dict:
    return {
        "format": "dsss",
        "version": FORMAT_VERSION,
        "n": graph.n,
        "m": graph.m,
        "P": graph.P,
        "interval_size": graph.interval_size,
        "weighted": graph.weights is not None,
        "src_sorted": bool(graph.src_sorted),
    }


def _write_blocks(w: StoreWriter, blocks: dict[tuple[int, int], dict]) -> None:
    keys = sorted(blocks)  # row-major (i, j): the schedules' streaming order
    nb = len(keys)
    weighted = any(blocks[k]["weights"] is not None for k in keys)
    bi = np.fromiter((k[0] for k in keys), np.int32, nb)
    bj = np.fromiter((k[1] for k in keys), np.int32, nb)
    be = np.fromiter((blocks[k]["e"] for k in keys), np.int64, nb)
    bu = np.fromiter((blocks[k]["u"] for k in keys), np.int64, nb)
    bub = np.fromiter((blocks[k]["u_bucket"] for k in keys), np.int64, nb)
    buckets = np.fromiter((next_bucket(blocks[k]["e"]) for k in keys), np.int64, nb)
    beo = np.zeros(nb, np.int64)
    np.cumsum(buckets[:-1], out=beo[1:])
    bho = np.zeros(nb, np.int64)
    np.cumsum(bub[:-1], out=bho[1:])
    for name, arr in (
        ("blk_i", bi), ("blk_j", bj), ("blk_e", be), ("blk_u", bu),
        ("blk_ub", bub), ("blk_edge_off", beo), ("blk_hub_off", bho),
    ):
        w.add_array(name, arr)
    for leaf, name in (
        ("src_local", "blk_src_local"),
        ("dst_local", "blk_dst_local"),
        ("hub_inv", "blk_hub_inv"),
    ):
        s = w.stream(name, np.int32)
        for k in keys:
            s.append(blocks[k][leaf])
        s.close()
    s = w.stream("blk_hub_dst", np.int32)
    for k in keys:
        s.append(blocks[k]["hub_dst"])
    s.close()
    if weighted:
        s = w.stream("blk_weights", np.float32)
        for k in keys:
            s.append(blocks[k]["weights"])
        s.close()


def _write_packed(w: StoreWriter, packed: PackedSweep) -> None:
    w.add_array("p_src", packed.src)
    w.add_array("p_dst", packed.dst)
    w.add_array("p_run_local", packed.run_local)
    w.add_array("p_run_dst", packed.run_dst)
    if packed.weights is not None:
        w.add_array("p_weights", packed.weights)
    w.add_array("p_e_valid", packed.e_valid)
    w.add_array("p_src_interval", packed.src_interval)
    w.add_array("p_dst_interval", packed.dst_interval)
    w.add_array("p_base_slot", packed.base_slot)
    w.add_array("p_u", packed.u)
    w.add_array("p_row_offset", packed.row_offset)


def write_dsss(graph: DSSSGraph, path: str, *, packing: str = "auto") -> DSSSStore:
    """Serialize an in-memory :class:`DSSSGraph` to a .dsss container.

    ``packing`` selects the stored :class:`PackedSweep` layout
    (``"auto"`` → adaptive, or subshard for ``src_sorted`` graphs);
    ``packing=None`` skips the packed section. The external-memory
    builder (:mod:`repro.storage.build`) produces byte-identical segment
    *contents* without ever holding the graph — this writer is the
    in-memory reference (and the small-graph convenience path).
    """
    if packing == "auto":
        packing = "subshard" if graph.src_sorted else "adaptive"
    w = StoreWriter(path)
    try:
        meta = _base_meta(graph)
        w.add_array("offsets", graph.offsets)
        w.add_array("hub_offsets", graph.hub_offsets)
        w.add_array("out_degree", graph.out_degree)
        w.add_array("in_degree", graph.in_degree)
        w.add_array("id_to_index", np.asarray(graph.edgelist.id_to_index, np.int64))
        w.add_array("src", graph.src)
        w.add_array("dst", graph.dst)
        if graph.weights is not None:
            w.add_array("weights", graph.weights)
        w.add_array("hub_dst_flat", graph.hub_dst_flat)
        w.add_array("hub_inv_flat", graph.hub_inv_flat)
        blocks = graph.host_blocks()
        meta["num_blocks"] = len(blocks)
        _write_blocks(w, blocks)
        if packing is not None:
            packed = graph.packed_sweep(packing)
            meta["packing"] = packed.mode
            meta["tile_edges"] = packed.tile_edges
            meta["num_tiles"] = packed.num_tiles
            _write_packed(w, packed)
        else:
            meta["packing"] = None
        w.close(meta)
    except BaseException:
        w.abort()
        raise
    return DSSSStore(path)


def store_info(path: str) -> dict:
    """Human-facing summary of a container (the CLI ``info`` command)."""
    store = DSSSStore(path)
    total = sum(s.nbytes for s in store.segments.values())
    return {
        "path": path,
        "file_bytes": os.path.getsize(path),
        "segment_bytes": total,
        "meta": dict(store.meta),
        "segments": [
            {
                "name": s.name,
                "dtype": s.dtype,
                "shape": list(s.shape),
                "nbytes": s.nbytes,
            }
            for s in store.segments.values()
        ],
    }
