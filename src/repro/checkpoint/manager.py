"""Checkpointing: npz payload + JSON index, async save, keep-N, elastic
restore.

Design points for the 1000-node story:
  * arrays are saved UNSHARDED (gathered) with a JSON manifest of the tree
    structure — restoring onto a *different* mesh (shrunk after a node
    failure, grown after repair) is just placing the same logical arrays
    with new shardings: reshard-on-load is free by construction;
  * saves run on a background thread (async checkpointing: training does
    not stall on disk);
  * ``keep`` most-recent checkpoints are retained; partial writes are
    atomic (tmp dir + fsync + rename), so a crash mid-save never corrupts
    the restore chain: payload and manifest are fsynced before the
    publish rename, a superseded step is renamed aside (never deleted in
    place) before its replacement lands, and ``all_steps`` only counts
    *complete* step directories — orphaned tmp/trash/partial directories
    from a crash are swept by the next save's GC.
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    return flat, treedef


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # -- save -----------------------------------------------------------------
    def save(self, step: int, state, *, block: bool = False) -> None:
        """Snapshot ``state`` (any pytree of arrays) at ``step``."""
        flat, treedef = _flatten(state)
        # Materialize on host NOW (cheap addressable copy) so training can
        # mutate/donate device buffers while the writer thread runs.
        host_flat = [np.asarray(x) for x in flat]
        self.wait()
        if self.async_save and not block:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_flat, treedef), daemon=True
            )
            self._thread.start()
        else:
            self._write(step, host_flat, treedef)

    def _write(self, step: int, host_flat, treedef) -> None:
        tmp = os.path.join(self.directory, f".tmp_step_{step}")
        final = os.path.join(self.directory, f"step_{step:010d}")
        trash = os.path.join(self.directory, f".trash_step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)  # stale leftover from a crashed save
        os.makedirs(tmp)
        with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
            np.savez(f, **{f"a{i}": a for i, a in enumerate(host_flat)})
            f.flush()
            os.fsync(f.fileno())
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(
                {
                    "step": step,
                    "num_arrays": len(host_flat),
                    "treedef": str(treedef),
                },
                f,
            )
            f.flush()
            os.fsync(f.fileno())
        # Publish: move a superseded step ASIDE (rename is atomic; rmtree
        # is not) so no crash point leaves us without a complete copy of
        # this step, then swing the tmp dir into place and fsync the
        # parent so the renames are durable.
        if os.path.exists(trash):
            shutil.rmtree(trash)
        if os.path.exists(final):
            os.rename(final, trash)
        os.rename(tmp, final)  # atomic publish
        self._fsync_dir(self.directory)
        if os.path.exists(trash):
            shutil.rmtree(trash)
        self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    @staticmethod
    def _fsync_dir(path: str) -> None:
        fd = os.open(path, os.O_RDONLY)
        try:
            os.fsync(fd)
        except OSError:
            pass  # some filesystems refuse directory fsync
        finally:
            os.close(fd)

    def _complete(self, name: str) -> bool:
        d = os.path.join(self.directory, name)
        return os.path.exists(os.path.join(d, "arrays.npz")) and os.path.exists(
            os.path.join(d, "manifest.json")
        )

    def _gc(self) -> None:
        # Sweep crash debris first: orphaned tmp/trash dirs and published
        # step dirs missing their payload (a rmtree interrupted mid-prune).
        for name in os.listdir(self.directory):
            path = os.path.join(self.directory, name)
            if name.startswith((".tmp_step_", ".trash_step_")):
                shutil.rmtree(path, ignore_errors=True)
            elif name.startswith("step_") and not self._complete(name):
                shutil.rmtree(path, ignore_errors=True)
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"))

    # -- restore ----------------------------------------------------------------
    def all_steps(self) -> list[int]:
        """Steps with a *complete* (payload + manifest) directory — a
        crash-truncated directory is never offered for restore."""
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and self._complete(name):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like, step: int | None = None, *, shardings=None):
        """Restore into the structure of ``like`` (a pytree template).

        ``shardings``: optional matching tree of NamedShardings — this is
        the elastic-remesh path: the same logical arrays are placed onto
        whatever mesh the restarted job has.
        """
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step:010d}")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = [z[f"a{i}"] for i in range(len(z.files))]
        like_flat, treedef = _flatten(like)
        if len(like_flat) != len(flat):
            raise ValueError(
                f"checkpoint has {len(flat)} arrays, template expects "
                f"{len(like_flat)} — architecture mismatch?"
            )
        out = []
        for tmpl, arr in zip(like_flat, flat):
            a = np.asarray(arr)
            if tuple(a.shape) != tuple(tmpl.shape):
                raise ValueError(
                    f"shape mismatch {a.shape} vs {tmpl.shape} on restore"
                )
            out.append(a.astype(tmpl.dtype))
        restored = jax.tree_util.tree_unflatten(treedef, out)
        if shardings is not None:
            restored = jax.tree.map(
                lambda x, s: jax.device_put(x, s), restored, shardings
            )
        else:
            restored = jax.tree.map(jax.numpy.asarray, restored)
        return restored, step
