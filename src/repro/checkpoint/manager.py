"""Checkpointing: npz payload + JSON index, async save, keep-N, elastic
restore.

Design points for the 1000-node story:
  * arrays are saved UNSHARDED (gathered) with a JSON manifest of the tree
    structure — restoring onto a *different* mesh (shrunk after a node
    failure, grown after repair) is just placing the same logical arrays
    with new shardings: reshard-on-load is free by construction;
  * saves run on a background thread (async checkpointing: training does
    not stall on disk);
  * ``keep`` most-recent checkpoints are retained; partial writes are
    atomic (tmp file + rename), so a crash mid-save never corrupts the
    restore chain.
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    return flat, treedef


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # -- save -----------------------------------------------------------------
    def save(self, step: int, state, *, block: bool = False) -> None:
        """Snapshot ``state`` (any pytree of arrays) at ``step``."""
        flat, treedef = _flatten(state)
        # Materialize on host NOW (cheap addressable copy) so training can
        # mutate/donate device buffers while the writer thread runs.
        host_flat = [np.asarray(x) for x in flat]
        self.wait()
        if self.async_save and not block:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_flat, treedef), daemon=True
            )
            self._thread.start()
        else:
            self._write(step, host_flat, treedef)

    def _write(self, step: int, host_flat, treedef) -> None:
        tmp = os.path.join(self.directory, f".tmp_step_{step}")
        final = os.path.join(self.directory, f"step_{step:010d}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(
            os.path.join(tmp, "arrays.npz"),
            **{f"a{i}": a for i, a in enumerate(host_flat)},
        )
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(
                {
                    "step": step,
                    "num_arrays": len(host_flat),
                    "treedef": str(treedef),
                },
                f,
            )
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"))

    # -- restore ----------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like, step: int | None = None, *, shardings=None):
        """Restore into the structure of ``like`` (a pytree template).

        ``shardings``: optional matching tree of NamedShardings — this is
        the elastic-remesh path: the same logical arrays are placed onto
        whatever mesh the restarted job has.
        """
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step:010d}")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = [z[f"a{i}"] for i in range(len(z.files))]
        like_flat, treedef = _flatten(like)
        if len(like_flat) != len(flat):
            raise ValueError(
                f"checkpoint has {len(flat)} arrays, template expects "
                f"{len(like_flat)} — architecture mismatch?"
            )
        out = []
        for tmpl, arr in zip(like_flat, flat):
            a = np.asarray(arr)
            if tuple(a.shape) != tuple(tmpl.shape):
                raise ValueError(
                    f"shape mismatch {a.shape} vs {tmpl.shape} on restore"
                )
            out.append(a.astype(tmpl.dtype))
        restored = jax.tree_util.tree_unflatten(treedef, out)
        if shardings is not None:
            restored = jax.tree.map(
                lambda x, s: jax.device_put(x, s), restored, shardings
            )
        else:
            restored = jax.tree.map(jax.numpy.asarray, restored)
        return restored, step
