"""Checkpointing: async npz snapshots with keep-N and elastic restore."""
from repro.checkpoint.manager import CheckpointManager

__all__ = ["CheckpointManager"]
