"""repro: NXgraph-on-TPU — graph engine + LM framework (see DESIGN.md)."""
