"""LLM serving demo: length-bucketed batching, prefill + decode, sampling.

This is the *language-model* serving demo that rode along with the seed
repo's LM framework — it batches token-generation requests against
``repro.models`` and has nothing to do with the graph engine. The graph
query serving subsystem (request queue, dynamic micro-batching onto
``GraphSession.run_batch``, admission control) lives in
:mod:`repro.serving.server`; ``repro.serving`` exports only that API.
Import this module explicitly (``from repro.serving import llm_demo``) to
use the LM demo.

The batcher buckets queued requests by prompt length (uniform-length
batches keep the cache layout exact — no left-pad attention pollution),
prefills each bucket as one batch, then decodes all sequences in lockstep
with per-request stop handling. Greedy / temperature / top-k sampling.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import Model

__all__ = ["Request", "ServeEngine", "sample_token"]


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: list[int]
    max_new_tokens: int = 16
    temperature: float = 0.0  # 0 => greedy
    top_k: int = 0
    eos_id: int | None = None


def sample_token(logits, key, temperature: float, top_k: int):
    """logits: (B, V). Returns (B,) int32."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.random.categorical(key, logits).astype(jnp.int32)


class ServeEngine:
    """Stateless-model, stateful-queue serving engine."""

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8, seed: int = 0):
        self.cfg = cfg
        self.model = Model(cfg)
        self.params = params
        self.max_batch = max_batch
        self.key = jax.random.PRNGKey(seed)
        self._queue: list[Request] = []
        self._decode_jit = jax.jit(
            lambda params, cache, tok, pos: self.model.decode(params, cache, tok, pos)
        )

    # -- queue ------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self._queue.append(req)

    def _take_bucket(self) -> list[Request]:
        """Pop up to max_batch requests sharing one prompt length."""
        if not self._queue:
            return []
        by_len: dict[int, list[Request]] = defaultdict(list)
        for r in self._queue:
            by_len[len(r.prompt)].append(r)
        # largest bucket first: maximizes batch utilization
        length = max(by_len, key=lambda k: len(by_len[k]))
        bucket = by_len[length][: self.max_batch]
        taken = set(id(r) for r in bucket)
        self._queue = [r for r in self._queue if id(r) not in taken]
        return bucket

    # -- execution ----------------------------------------------------------
    def run(self) -> dict[int, list[int]]:
        """Drain the queue; returns request_id -> generated token list."""
        results: dict[int, list[int]] = {}
        while self._queue:
            bucket = self._take_bucket()
            results.update(self._run_bucket(bucket))
        return results

    def _run_bucket(self, bucket: Sequence[Request]) -> dict[int, list[int]]:
        b = len(bucket)
        prompt_len = len(bucket[0].prompt)
        max_new = max(r.max_new_tokens for r in bucket)
        max_len = prompt_len + max_new + 1
        tokens = jnp.asarray([r.prompt for r in bucket], jnp.int32)
        last_logits, cache = self.model.prefill(
            self.params, tokens, max_len=max_len
        )
        out: dict[int, list[int]] = {r.request_id: [] for r in bucket}
        done = np.zeros(b, bool)
        cur = last_logits[:, 0, : self.cfg.vocab_size]
        for t in range(max_new):
            self.key, sub = jax.random.split(self.key)
            temps = bucket[0].temperature  # per-bucket sampling params
            topk = bucket[0].top_k
            nxt = sample_token(cur.astype(jnp.float32), sub, temps, topk)
            nxt_np = np.asarray(nxt)
            for i, r in enumerate(bucket):
                if done[i] or t >= r.max_new_tokens:
                    done[i] = True
                    continue
                tok = int(nxt_np[i])
                out[r.request_id].append(tok)
                if r.eos_id is not None and tok == r.eos_id:
                    done[i] = True
            if done.all():
                break
            logits, cache = self._decode_jit(
                self.params,
                cache,
                nxt[:, None],
                jnp.asarray(prompt_len + t, jnp.int32),
            )
            cur = logits[:, 0, : self.cfg.vocab_size]
        return out
